//! Bench: adaptive hot-path controllers vs the static-knob sweep
//! (§SLA / adaptive controllers).
//!
//! One virtual-time trace, run once per operating point:
//!
//! * a **steady phase** — 200 serial requests ~20 ms apart. Every
//!   request is a lone batch leader, so with a static window of W ms
//!   each one pays W ms of batch wait for followers that never come;
//!   the adaptive controller watches the recent batch-wait p99 against
//!   the function's 150 ms SLO budget and collapses the window,
//! * a **scale-to-zero moment** — the pool is evicted and the
//!   maintainer ticks once (static `min_warm` top-up vs the adaptive
//!   Holt forecast top-up), then
//! * a **burst** — 8 simultaneous requests on real threads. Static
//!   settings open on cold ground and pay full cold starts; the
//!   forecast run lands on pre-provisioned warm containers.
//!
//! Static sweep: `batch_window_ms` in {0, 10, 25, 50, 100} plus a
//! keep-warm overprovision point (window 50, `min_warm` 4). The
//! adaptive run starts from the same knobs as the window-50 point.
//!
//! Per-request latency is `InvocationRecord::response()` (the
//! platform-side decomposition), so concurrent burst members never
//! inherit a sibling's virtual-clock advances. Acceptance, asserted
//! here and recorded in the JSON: the adaptive run beats EVERY static
//! setting on at least one of {steady batch-wait p99, SLA-violation
//! rate @1 s}, and is never worse than the best static setting by
//! more than 10% (plus one-request-in-the-trace absolute slack) on
//! either metric.
//!
//! Emits `BENCH_adaptive.json` (machine-readable) next to the run so
//! the controller/static gap is trackable across PRs.
//!
//! `cargo bench --bench bench_adaptive`

use lambdaserve::configparse::{PlatformConfig, PolicyConfig};
use lambdaserve::platform::registry::FunctionPolicy;
use lambdaserve::platform::Invoker;
use lambdaserve::runtime::MockEngine;
use lambdaserve::util::json::{obj, Json};
use lambdaserve::util::{Clock, ManualClock};
use std::sync::Arc;
use std::time::Duration;

/// The function's end-to-end SLO (ms): tight enough that a 50 ms
/// static window alone blows the controller's batch-wait budget
/// (`BATCH_WAIT_SLO_FRACTION` * 150 = 37.5 ms).
const SLO_MS: u64 = 150;
/// Paper-style SLA reporting targets, seconds.
const SLA_TARGETS: [f64; 4] = [0.5, 1.0, 2.0, 5.0];
const STEADY_N: u64 = 200;
/// Steady-phase samples skipped before the tail p99 (the adaptive
/// run's AIMD transient is ~7 flushes; 50 is generous).
const STEADY_SKIP: usize = 50;
const BURST_N: usize = 8;

struct Setting {
    name: &'static str,
    window_ms: u64,
    min_warm: usize,
    adaptive: bool,
}

struct Report {
    name: &'static str,
    /// p99 (ms) of per-request batch wait over the steady-phase tail.
    steady_wait_p99_ms: f64,
    /// p99 (ms) of per-request batch wait over the whole trace.
    full_wait_p99_ms: f64,
    /// Violation rate per SLA target over the whole trace.
    viol: Vec<f64>,
    /// Share of requests inside the function's own 150 ms SLO.
    slo_attainment: f64,
    latency_p99_s: f64,
    cold_starts: usize,
    warm_ahead_of_burst: usize,
}

fn p99(samples: &[f64]) -> f64 {
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).expect("no NaN latencies"));
    if xs.is_empty() {
        return 0.0;
    }
    let idx = ((xs.len() as f64 * 0.99).ceil() as usize).clamp(1, xs.len()) - 1;
    xs[idx]
}

fn run(s: &Setting) -> Report {
    let engine = Arc::new(MockEngine::paper_zoo());
    let clock = ManualClock::new();
    let cfg = PlatformConfig {
        max_batch_size: 8,
        batch_window_ms: s.window_ms,
        policy: PolicyConfig { enabled: s.adaptive, ..Default::default() },
        ..Default::default()
    };
    let p = Arc::new(Invoker::new(cfg, engine, clock.clone()));
    // 1536 MB: the effective forward pass is ~122.5 ms, so the 150 ms
    // SLO leaves ~27 ms of headroom for the window.
    p.deploy_full(
        "api",
        "squeezenet",
        "pallas",
        1536,
        FunctionPolicy {
            min_warm: s.min_warm,
            slo_target_ms: Some(SLO_MS),
            ..Default::default()
        },
    )
    .expect("deploy");
    if s.min_warm > 0 {
        p.maintain(); // static keep-warm floor in place before traffic
    }
    let mut waits_ms: Vec<f64> = Vec::new();
    let mut lats_s: Vec<f64> = Vec::new();
    // Steady phase: serial lone leaders, ~20 ms apart.
    for i in 0..STEADY_N {
        let r = p.invoke("api", i).expect("steady invoke").record;
        waits_ms.push(r.batch_wait.as_secs_f64() * 1e3);
        lats_s.push(r.response().as_secs_f64());
        clock.sleep(Duration::from_millis(20));
    }
    let steady_wait_p99_ms = p99(&waits_ms[STEADY_SKIP..]);
    // Scale-to-zero, then one maintenance tick before the burst: the
    // static `min_warm` top-up vs the adaptive forecast top-up.
    p.evict_all();
    p.maintain();
    let warm_ahead_of_burst = p.pool.warm_count("api");
    let burst: Vec<_> = (0..BURST_N as u64)
        .map(|i| {
            let p = p.clone();
            std::thread::spawn(move || p.invoke("api", 10_000 + i).expect("burst invoke").record)
        })
        .collect();
    for h in burst {
        let r = h.join().expect("burst thread");
        waits_ms.push(r.batch_wait.as_secs_f64() * 1e3);
        lats_s.push(r.response().as_secs_f64());
    }
    let n = lats_s.len() as f64;
    let viol = SLA_TARGETS
        .iter()
        .map(|t| lats_s.iter().filter(|l| **l > *t).count() as f64 / n)
        .collect();
    let slo = SLO_MS as f64 / 1e3;
    Report {
        name: s.name,
        steady_wait_p99_ms,
        full_wait_p99_ms: p99(&waits_ms),
        viol,
        slo_attainment: lats_s.iter().filter(|l| **l <= slo).count() as f64 / n,
        latency_p99_s: p99(&lats_s),
        cold_starts: p.scaler.cold_provision_count(),
        warm_ahead_of_burst,
    }
}

fn main() {
    println!("=== adaptive controllers vs the static sweep ===\n");
    println!(
        "trace: {STEADY_N} lone-leader requests @ ~20 ms gaps, scale-to-zero, \
         one maintainer tick, {BURST_N}-wide burst; squeezenet @1536 MB, SLO {SLO_MS} ms\n"
    );

    let settings = [
        Setting { name: "static w=0", window_ms: 0, min_warm: 0, adaptive: false },
        Setting { name: "static w=10", window_ms: 10, min_warm: 0, adaptive: false },
        Setting { name: "static w=25", window_ms: 25, min_warm: 0, adaptive: false },
        Setting { name: "static w=50", window_ms: 50, min_warm: 0, adaptive: false },
        Setting { name: "static w=100", window_ms: 100, min_warm: 0, adaptive: false },
        Setting { name: "static w=50 warm=4", window_ms: 50, min_warm: 4, adaptive: false },
        Setting { name: "adaptive (base w=50)", window_ms: 50, min_warm: 0, adaptive: true },
    ];
    let reports: Vec<Report> = settings.iter().map(run).collect();

    println!(
        "{:<22} {:>12} {:>9} {:>9} {:>9} {:>9} {:>8} {:>6} {:>6}",
        "setting", "wait p99(ms)", "v@0.5s", "v@1s", "v@2s", "v@5s", "SLO-ok", "cold", "warm"
    );
    for r in &reports {
        println!(
            "{:<22} {:>12.2} {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}% {:>7.1}% {:>6} {:>6}",
            r.name,
            r.steady_wait_p99_ms,
            r.viol[0] * 100.0,
            r.viol[1] * 100.0,
            r.viol[2] * 100.0,
            r.viol[3] * 100.0,
            r.slo_attainment * 100.0,
            r.cold_starts,
            r.warm_ahead_of_burst,
        );
    }
    println!();

    // ---- acceptance: the controllers must dominate the sweep ----
    let (statics, adaptive_rs): (Vec<&Report>, Vec<&Report>) = {
        let mut st = Vec::new();
        let mut ad = Vec::new();
        for (s, r) in settings.iter().zip(&reports) {
            if s.adaptive {
                ad.push(r);
            } else {
                st.push(r);
            }
        }
        (st, ad)
    };
    let a = adaptive_rs[0];
    // Beat every static setting on at least one of the two metrics.
    let mut beats_all = true;
    for s in &statics {
        let on_wait = a.steady_wait_p99_ms < s.steady_wait_p99_ms;
        let on_sla = a.viol[1] < s.viol[1];
        println!(
            "adaptive vs {:<20} beats on: {}{}{}",
            s.name,
            if on_wait { "batch-wait p99 " } else { "" },
            if on_sla { "SLA@1s" } else { "" },
            if !on_wait && !on_sla { "NOTHING" } else { "" },
        );
        beats_all &= on_wait || on_sla;
    }
    // Never worse than the best static by >10% on either metric. The
    // absolute slack is one trace quantum: 1 ms of wait, one request
    // out of the 208 in the violation rate.
    let best_wait = statics.iter().map(|r| r.steady_wait_p99_ms).fold(f64::INFINITY, f64::min);
    let one_req = 1.0 / (STEADY_N as f64 + BURST_N as f64);
    let wait_ok = a.steady_wait_p99_ms <= best_wait * 1.10 + 1.0;
    let mut sla_ok = true;
    for (i, t) in SLA_TARGETS.iter().enumerate() {
        let best = statics.iter().map(|r| r.viol[i]).fold(f64::INFINITY, f64::min);
        let ok = a.viol[i] <= best * 1.10 + one_req;
        println!(
            "@{t:.1}s: adaptive {:.2}% vs best static {:.2}% -> {}",
            a.viol[i] * 100.0,
            best * 100.0,
            if ok { "within 10%" } else { "WORSE" }
        );
        sla_ok &= ok;
    }
    println!(
        "steady batch-wait p99: adaptive {:.2} ms vs best static {:.2} ms -> {}",
        a.steady_wait_p99_ms,
        best_wait,
        if wait_ok { "within 10%" } else { "WORSE" }
    );
    assert!(beats_all, "adaptive must beat every static setting on >=1 metric");
    assert!(wait_ok && sla_ok, "adaptive must stay within 10% of the best static setting");
    println!("\nacceptance: PASS");

    let rows = reports
        .iter()
        .zip(&settings)
        .map(|(r, s)| {
            obj(vec![
                ("setting", Json::Str(r.name.to_string())),
                ("adaptive", Json::Bool(s.adaptive)),
                ("batch_window_ms", Json::Num(s.window_ms as f64)),
                ("min_warm", Json::Num(s.min_warm as f64)),
                ("steady_batch_wait_p99_ms", Json::Num(r.steady_wait_p99_ms)),
                ("full_batch_wait_p99_ms", Json::Num(r.full_wait_p99_ms)),
                (
                    "sla_violation_rates",
                    Json::Arr(
                        SLA_TARGETS
                            .iter()
                            .zip(&r.viol)
                            .map(|(t, v)| {
                                obj(vec![
                                    ("target_s", Json::Num(*t)),
                                    ("rate", Json::Num(*v)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("slo_attainment", Json::Num(r.slo_attainment)),
                ("latency_p99_s", Json::Num(r.latency_p99_s)),
                ("cold_starts", Json::Num(r.cold_starts as f64)),
                ("warm_ahead_of_burst", Json::Num(r.warm_ahead_of_burst as f64)),
            ])
        })
        .collect();
    let out = obj(vec![
        ("bench", Json::Str("adaptive".to_string())),
        ("model", Json::Str("squeezenet".to_string())),
        ("memory_mb", Json::Num(1536.0)),
        ("slo_target_ms", Json::Num(SLO_MS as f64)),
        ("steady_requests", Json::Num(STEADY_N as f64)),
        ("burst_requests", Json::Num(BURST_N as f64)),
        ("settings", Json::Arr(rows)),
        ("beats_every_static_on_one_metric", Json::Bool(true)),
        ("within_10pct_of_best_static", Json::Bool(true)),
    ]);
    std::fs::write("BENCH_adaptive.json", out.to_string()).expect("write BENCH_adaptive.json");
    println!("wrote BENCH_adaptive.json");
}
