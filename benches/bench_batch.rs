//! Bench: micro-batching throughput vs batch size (§Perf).
//!
//! Two layers, both on the MockEngine (no artifacts needed):
//!
//! * the *modeled* economics — the mock's sublinear batch cost
//!   (`1 + 0.25·(n-1)` of a solo pass) as requests-per-second-of-
//!   compute, which is what a real batched kernel buys, and
//! * the *measured* platform overhead — wall ns/request through
//!   `Engine::predict_batch` and the full `Container::execute_batch`
//!   path (governor + accounting) with zero-cost models, i.e. what
//!   the batching machinery itself costs per coalesced request.
//!
//! `cargo bench --bench bench_batch`

use lambdaserve::configparse::BootstrapConfig;
use lambdaserve::platform::registry::FunctionRegistry;
use lambdaserve::platform::{Container, CpuGovernor};
use lambdaserve::runtime::{Engine, MockEngine, MockModelCosts, BATCH_COST_MARGINAL};
use lambdaserve::util::{Clock, ManualClock, SplitMix64};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    for _ in 0..iters.min(1000) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:<44} {per:>12.0} ns/op   ({iters} iters)");
    per
}

fn main() {
    println!("=== micro-batching: throughput vs batch size ===\n");

    // Modeled economics: requests served per second of container
    // compute, from the mock's sublinear batch-cost model.
    let zoo = MockEngine::paper_zoo();
    let sq = zoo.manifest("squeezenet").unwrap();
    let solo_s = 0.105; // squeezenet full-speed predict
    println!("model {} ({} classes): solo pass {:.0} ms", sq.name, sq.num_classes, solo_s * 1e3);
    println!("{:>6} {:>14} {:>16} {:>10}", "batch", "total (ms)", "req/s compute", "speedup");
    for n in [1usize, 2, 4, 8, 16, 32] {
        let total = solo_s * (1.0 + BATCH_COST_MARGINAL * (n as f64 - 1.0));
        let rps = n as f64 / total;
        println!(
            "{:>6} {:>14.1} {:>16.1} {:>9.2}x",
            n,
            total * 1e3,
            rps,
            rps / (1.0 / solo_s)
        );
    }
    println!();

    // Measured machinery overhead: zero-cost model so everything left
    // is dispatch + accounting, per coalesced request.
    let engine = Arc::new(MockEngine::new(vec![MockModelCosts {
        predict: Duration::ZERO,
        init_run: Duration::ZERO,
        compile: Duration::ZERO,
        manifest: MockModelCosts::paper_like("m", 1, 5.0, 85).manifest,
    }]));
    let (handle, _) = engine.create_instance("m", "pallas").unwrap();
    for n in [1usize, 8, 32] {
        let seeds: Vec<u64> = (0..n as u64).collect();
        bench(&format!("engine.predict_batch n={n} (per request)"), 100_000 / n, || {
            let preds = engine.predict_batch(&handle, &seeds).unwrap();
            std::hint::black_box(preds);
        });
    }

    let reg = FunctionRegistry::new(engine.clone());
    let spec = reg.deploy("m", "m", "pallas", 1536).unwrap();
    let clock: Arc<dyn Clock> = ManualClock::new();
    let gov = CpuGovernor::new(1792, clock.clone());
    let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
    let mut rng = SplitMix64::new(1);
    let mut container =
        Container::provision(spec, engine.clone(), &gov, &cfg, &clock, &mut rng).unwrap();
    for n in [1usize, 8, 32] {
        let seeds: Vec<u64> = (0..n as u64).collect();
        bench(&format!("container.execute_batch n={n} (per request)"), 50_000 / n, || {
            let out = container.execute_batch(&gov, &clock, &seeds).unwrap();
            std::hint::black_box(out);
        });
    }
    println!("\nserved by the bench container: {}", container.served);
}
