//! Bench: micro-batching throughput vs batch size (§Perf).
//!
//! Three layers, all on the MockEngine (no artifacts needed):
//!
//! * the *modeled* economics — the mock's sublinear batch cost
//!   (`1 + 0.25·(n-1)` of a solo pass) as requests-per-second-of-
//!   compute, which is what a real batched kernel buys,
//! * the *kernel-ladder* sweep — the same flush under increasing
//!   `batch_kernel_max`, where a flush of n runs as k ladder chunks at
//!   `1 + 0.25·(k-1) + 0.10·(n-k)` of a solo pass, so per-request cost
//!   must fall strictly as larger compiled rungs engage, and
//! * the *measured* platform overhead — wall ns/request through
//!   `Engine::predict_batch` and the full `Container::execute_batch`
//!   path (governor + accounting) with zero-cost models, i.e. what
//!   the batching machinery itself costs per coalesced request.
//!
//! Emits `BENCH_batch.json` (machine-readable) next to the run so the
//! perf trajectory is trackable across PRs.
//!
//! `cargo bench --bench bench_batch`

use lambdaserve::configparse::BootstrapConfig;
use lambdaserve::platform::registry::FunctionRegistry;
use lambdaserve::platform::{Container, CpuGovernor};
use lambdaserve::runtime::{
    ladder_chunks, Engine, MockEngine, MockModelCosts, BATCH_COST_MARGINAL, KERNEL_COST_MARGINAL,
};
use lambdaserve::util::json::{obj, Json};
use lambdaserve::util::{Clock, ManualClock, SplitMix64};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    for _ in 0..iters.min(1000) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:<44} {per:>12.0} ns/op   ({iters} iters)");
    per
}

fn main() {
    println!("=== micro-batching: throughput vs batch size ===\n");

    // Modeled economics: requests served per second of container
    // compute, from the mock's sublinear batch-cost model.
    let zoo = MockEngine::paper_zoo();
    let sq = zoo.manifest("squeezenet").unwrap();
    let solo_s = 0.105; // squeezenet full-speed predict
    println!("model {} ({} classes): solo pass {:.0} ms", sq.name, sq.num_classes, solo_s * 1e3);
    println!("{:>6} {:>14} {:>16} {:>10}", "batch", "total (ms)", "req/s compute", "speedup");
    for n in [1usize, 2, 4, 8, 16, 32] {
        let total = solo_s * (1.0 + BATCH_COST_MARGINAL * (n as f64 - 1.0));
        let rps = n as f64 / total;
        println!(
            "{:>6} {:>14.1} {:>16.1} {:>9.2}x",
            n,
            total * 1e3,
            rps,
            rps / (1.0 / solo_s)
        );
    }
    println!();

    // Kernel-ladder sweep: one flush of n = 8 under each ladder top.
    // k ladder chunks cost `1 + 0.25·(k-1) + 0.10·(n-k)` of a solo
    // pass (the mock's honest amortization model — pinned by
    // ManualClock tests), so per-request cost falls strictly as larger
    // compiled batch-N rungs engage. `batch_kernel_max = 1` is the
    // pre-ladder pipeline exactly.
    let flush_n = 8usize;
    println!("--- batch-N kernel ladder: flush of n={flush_n} ---");
    println!(
        "{:>16} {:>8} {:>12} {:>16} {:>10}",
        "batch_kernel_max", "kernels", "total (ms)", "per-req (ms)", "speedup"
    );
    let mut ladder_rows = Vec::new();
    let mut baseline_per_req = 0.0f64;
    for ladder in [1usize, 2, 4, 8] {
        let chunks = ladder_chunks(flush_n, ladder);
        let k = chunks.len() as f64;
        let nf = flush_n as f64;
        let total =
            solo_s * (1.0 + BATCH_COST_MARGINAL * (k - 1.0) + KERNEL_COST_MARGINAL * (nf - k));
        let per_req = total / nf;
        if ladder == 1 {
            baseline_per_req = per_req;
        }
        println!(
            "{:>16} {:>8} {:>12.1} {:>16.2} {:>9.2}x",
            ladder,
            chunks.len(),
            total * 1e3,
            per_req * 1e3,
            baseline_per_req / per_req
        );
        ladder_rows.push(obj(vec![
            ("batch_kernel_max", Json::Num(ladder as f64)),
            ("flush_n", Json::Num(flush_n as f64)),
            ("kernel_launches", Json::Num(chunks.len() as f64)),
            ("total_ms", Json::Num(total * 1e3)),
            ("per_request_ms", Json::Num(per_req * 1e3)),
            ("speedup_vs_ladder1", Json::Num(baseline_per_req / per_req)),
        ]));
    }
    println!();

    // Measured machinery overhead: zero-cost model so everything left
    // is dispatch + accounting, per coalesced request.
    let engine = Arc::new(MockEngine::new(vec![MockModelCosts {
        predict: Duration::ZERO,
        init_run: Duration::ZERO,
        compile: Duration::ZERO,
        manifest: MockModelCosts::paper_like("m", 1, 5.0, 85).manifest,
    }]));
    let (handle, _) = engine.create_instance("m", "pallas").unwrap();
    let mut machinery_rows = Vec::new();
    for n in [1usize, 8, 32] {
        let seeds: Vec<u64> = (0..n as u64).collect();
        let ns = bench(&format!("engine.predict_batch n={n} (per request)"), 100_000 / n, || {
            let preds = engine.predict_batch(&handle, &seeds).unwrap();
            std::hint::black_box(preds);
        });
        machinery_rows.push(obj(vec![
            ("n", Json::Num(n as f64)),
            ("predict_batch_ns_per_request", Json::Num(ns / n as f64)),
        ]));
    }

    // Same flush through the ladder path: the report must name the
    // largest compiled rung, and the machinery cost stays flat.
    engine.set_batch_kernel_max(4);
    let seeds: Vec<u64> = (0..8u64).collect();
    let (_, report) = engine.predict_batch_report(&handle, &seeds).unwrap();
    println!("ladder flush n=8 under max=4: kernel_batch_n={}", report.kernel_batch_n);
    assert_eq!(report.kernel_batch_n, 4);
    bench("engine.predict_batch_report n=8 ladder=4", 100_000 / 8, || {
        let out = engine.predict_batch_report(&handle, &seeds).unwrap();
        std::hint::black_box(out);
    });
    engine.set_batch_kernel_max(1);

    let reg = FunctionRegistry::new(engine.clone());
    let spec = reg.deploy("m", "m", "pallas", 1536).unwrap();
    let clock: Arc<dyn Clock> = ManualClock::new();
    let gov = CpuGovernor::new(1792, clock.clone());
    let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
    let mut rng = SplitMix64::new(1);
    let mut container =
        Container::provision(spec, engine.clone(), &gov, &cfg, &clock, &mut rng).unwrap();
    for n in [1usize, 8, 32] {
        let seeds: Vec<u64> = (0..n as u64).collect();
        bench(&format!("container.execute_batch n={n} (per request)"), 50_000 / n, || {
            let out = container.execute_batch(&gov, &clock, &seeds).unwrap();
            std::hint::black_box(out);
        });
    }

    let out = obj(vec![
        ("bench", Json::Str("batch".to_string())),
        ("model", Json::Str("squeezenet".to_string())),
        ("solo_ms", Json::Num(solo_s * 1e3)),
        ("ladder_sweep", Json::Arr(ladder_rows)),
        ("machinery", Json::Arr(machinery_rows)),
    ]);
    std::fs::write("BENCH_batch.json", out.to_string()).expect("write BENCH_batch.json");
    println!("\nwrote BENCH_batch.json");
    println!("served by the bench container: {}", container.served);
}
