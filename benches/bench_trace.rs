//! Bench: invocation-tracing overhead on the warm hot path
//! (observability / the exemplar ring).
//!
//! Three operating points over the same virtual-time trace — one cold
//! start, then `N` serial warm invocations of squeezenet @1024 MB on a
//! ManualClock (so the measured wall time is pure platform code, not
//! simulated latency):
//!
//! * **off** — `trace.enabled = false` (the default). The acceptance
//!   bar is structural inertness: no trace ids minted, every ring
//!   gauge zero, the ring untouched.
//! * **sampled** — `trace.sample_rate = 0.1`. Every invocation still
//!   assembles its trace (ids are minted for correlation), but steady
//!   warm traffic is coin-flipped into the ring at ~10%.
//! * **always** — `trace.sample_rate = 1.0`. Every trace is retained
//!   (until the ring's capacity evicts the oldest).
//!
//! Timings are reported for eyeballing the per-invoke overhead; the
//! assertions are on the counters, which are deterministic (seeded
//! SplitMix64 sampling stream).
//!
//! Emits `BENCH_trace.json` (machine-readable) so the tracing tax is
//! trackable across PRs.
//!
//! `cargo bench --bench bench_trace`

use lambdaserve::configparse::{PlatformConfig, TraceConfig};
use lambdaserve::platform::Invoker;
use lambdaserve::runtime::MockEngine;
use lambdaserve::util::json::{obj, Json};
use lambdaserve::util::ManualClock;
use std::sync::Arc;
use std::time::Instant;

const WARM_N: u64 = 5_000;

struct Mode {
    name: &'static str,
    enabled: bool,
    sample_rate: f64,
}

struct Report {
    name: &'static str,
    ns_per_invoke: f64,
    retained: u64,
    sampled_out: u64,
    ring_bytes: u64,
    ids_minted: bool,
}

fn run(m: &Mode) -> Report {
    let engine = Arc::new(MockEngine::paper_zoo());
    let clock = ManualClock::new();
    let cfg = PlatformConfig {
        trace: TraceConfig {
            enabled: m.enabled,
            sample_rate: m.sample_rate,
            ..Default::default()
        },
        ..Default::default()
    };
    let p = Arc::new(Invoker::new(cfg, engine, clock));
    p.deploy("sq", "squeezenet", "pallas", 1024).expect("deploy");
    // One cold start outside the measured window (always interesting,
    // so it seeds the ring in the enabled modes).
    let cold = p.invoke("sq", 0).expect("cold invoke");
    let ids_minted = cold.record.trace_id.is_some();

    let t0 = Instant::now();
    for i in 1..=WARM_N {
        let out = p.invoke("sq", i).expect("warm invoke");
        assert_eq!(out.record.trace_id.is_some(), m.enabled, "{}: id minting", m.name);
    }
    let ns_per_invoke = t0.elapsed().as_nanos() as f64 / WARM_N as f64;

    Report {
        name: m.name,
        ns_per_invoke,
        retained: p.trace.retained(),
        sampled_out: p.trace.sampled_out(),
        ring_bytes: p.trace.ring_bytes(),
        ids_minted,
    }
}

fn main() {
    println!("=== invocation-tracing overhead on the warm path ===\n");
    println!("{WARM_N} serial warm invocations, squeezenet @1024 MB, ManualClock\n");

    let modes = [
        Mode { name: "off", enabled: false, sample_rate: 0.0 },
        Mode { name: "sampled 10%", enabled: true, sample_rate: 0.1 },
        Mode { name: "always", enabled: true, sample_rate: 1.0 },
    ];
    let reports: Vec<Report> = modes.iter().map(run).collect();

    println!(
        "{:<12} {:>14} {:>10} {:>12} {:>12}",
        "mode", "ns/invoke", "retained", "sampled_out", "ring bytes"
    );
    for r in &reports {
        println!(
            "{:<12} {:>14.0} {:>10} {:>12} {:>12}",
            r.name, r.ns_per_invoke, r.retained, r.sampled_out, r.ring_bytes
        );
    }
    println!();

    // ---- acceptance ----
    let off = &reports[0];
    assert!(!off.ids_minted, "off: no trace id on the cold record");
    assert_eq!(
        (off.retained, off.sampled_out, off.ring_bytes),
        (0, 0, 0),
        "off: the trace layer is structurally inert"
    );

    let sampled = &reports[1];
    assert!(sampled.ids_minted);
    // Cold exemplar always kept; the warm steady stream is ~10%.
    // Deterministic (seeded stream), but bounded loosely so a reseed
    // doesn't break the bench: 4%..20% of the steady traffic.
    let steady_kept = sampled.retained - 1;
    assert_eq!(steady_kept + sampled.sampled_out, WARM_N, "every warm invoke coin-flipped");
    let share = steady_kept as f64 / WARM_N as f64;
    assert!(
        (0.04..=0.20).contains(&share),
        "sampled: steady retention {share:.3} far from the 0.1 rate"
    );

    let always = &reports[2];
    assert!(always.ids_minted);
    assert_eq!(always.sampled_out, 0, "always: the coin never drops a trace");
    let capacity = TraceConfig::default().ring_capacity as u64;
    assert_eq!(
        always.retained,
        WARM_N + 1,
        "always: every invocation retained (ring evicts, the counter is lifetime)"
    );
    assert!(always.ring_bytes > 0);
    println!(
        "acceptance: PASS (off inert; sampled {steady_kept}/{WARM_N} steady kept; \
         always retained {} with ring capacity {capacity})",
        always.retained
    );

    let rows = reports
        .iter()
        .zip(&modes)
        .map(|(r, m)| {
            obj(vec![
                ("mode", Json::Str(r.name.to_string())),
                ("enabled", Json::Bool(m.enabled)),
                ("sample_rate", Json::Num(m.sample_rate)),
                ("ns_per_invoke", Json::Num(r.ns_per_invoke)),
                ("traces_retained", Json::Num(r.retained as f64)),
                ("traces_sampled_out", Json::Num(r.sampled_out as f64)),
                ("trace_ring_bytes", Json::Num(r.ring_bytes as f64)),
            ])
        })
        .collect();
    let out = obj(vec![
        ("bench", Json::Str("trace".to_string())),
        ("model", Json::Str("squeezenet".to_string())),
        ("memory_mb", Json::Num(1024.0)),
        ("warm_requests", Json::Num(WARM_N as f64)),
        ("ring_capacity", Json::Num(capacity as f64)),
        ("modes", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_trace.json", out.to_string()).expect("write BENCH_trace.json");
    println!("wrote BENCH_trace.json");
}
