//! Bench: L3 hot-path microbenchmarks (§Perf).
//!
//! The platform must not be the bottleneck: the paper's latency minus
//! prediction time is a near-constant network/gateway cost, so our
//! per-invoke platform overhead (routing + pool + governor + billing +
//! metrics, everything except compute and simulated sleeps) has to sit
//! in the microsecond range. This bench measures it, plus the
//! substrate hot paths it is built on, plus the contended-acquire
//! profile of the sharded warm pool (`platform.pool_shards`) against
//! the single-lock baseline.
//!
//! Emits `BENCH_hotpath.json` (machine-readable) next to the run so
//! the perf trajectory is trackable across PRs.
//!
//! `cargo bench --bench bench_hotpath`

use lambdaserve::configparse::{BootstrapConfig, PlatformConfig};
use lambdaserve::platform::registry::FunctionRegistry;
use lambdaserve::platform::{Container, CpuGovernor, Invoker, WarmPool};
use lambdaserve::runtime::{synthetic_image, MockEngine, MockModelCosts};
use lambdaserve::stats::Histogram;
use lambdaserve::util::json::{obj, Json};
use lambdaserve::util::{Clock, ManualClock, SplitMix64};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warm up.
    for _ in 0..iters.min(1000) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:<44} {per:>12.0} ns/op   ({iters} iters)");
    per
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

/// N hot functions × M threads hammering `acquire`/`release` on one
/// pool. Every cycle takes (and releases) a warm container, so with
/// `shards = 1` all threads serialize on the single idle mutex and a
/// release wakes the whole herd; with `shards > 1` each function's
/// traffic stays on its own bucket. Returns `(p50, p99)` ns/cycle.
fn contended_acquire(shards: usize, functions: usize, threads: usize, iters: usize) -> (u64, u64) {
    let engine = Arc::new(MockEngine::paper_zoo());
    let reg = FunctionRegistry::new(engine.clone());
    let clock: Arc<dyn Clock> = ManualClock::new();
    let pool = WarmPool::sharded(1000, 300.0, clock.clone(), shards);
    let gov = CpuGovernor::new(1792, clock.clone());
    let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
    let mut rng = SplitMix64::new(7);
    let names: Vec<String> = (0..functions).map(|i| format!("f{i}")).collect();
    for name in &names {
        let spec = reg.deploy(name, "squeezenet", "pallas", 1536).unwrap();
        // Two warm containers per function: a pair of threads on the
        // same function contends on the shard lock, not on container
        // availability.
        for _ in 0..2 {
            let c = Container::provision(
                spec.clone(),
                engine.clone(),
                &gov,
                &cfg,
                &clock,
                &mut rng,
            )
            .unwrap();
            pool.release(c);
        }
    }
    let mut samples: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let pool = &pool;
                let name = names[t % functions].clone();
                s.spawn(move || {
                    // Per-thread warm-up outside the timed window.
                    for _ in 0..1000 {
                        if let Some(c) = pool.acquire(&name) {
                            pool.release(c);
                        }
                    }
                    let mut local = Vec::with_capacity(iters);
                    for _ in 0..iters {
                        let t0 = Instant::now();
                        if let Some(c) = pool.acquire(&name) {
                            pool.release(c);
                        }
                        local.push(t0.elapsed().as_nanos() as u64);
                    }
                    local
                })
            })
            .collect();
        let mut all = Vec::with_capacity(threads * iters);
        for h in handles {
            all.extend(h.join().unwrap());
        }
        all
    });
    samples.sort_unstable();
    (percentile(&samples, 0.50), percentile(&samples, 0.99))
}

fn main() {
    println!("=== L3 hot-path microbenchmarks ===\n");

    // The headline number: full invoke pipeline overhead with a
    // zero-cost model, no simulated delays, warm container, manual
    // clock (sleeps are no-ops) — everything left is platform work.
    let engine = Arc::new(MockEngine::new(vec![MockModelCosts {
        predict: Duration::ZERO,
        init_run: Duration::ZERO,
        compile: Duration::ZERO,
        manifest: MockModelCosts::paper_like("m", 1, 5.0, 85).manifest,
    }]));
    let config = PlatformConfig {
        bootstrap: BootstrapConfig { simulate_delays: false, ..Default::default() },
        ..Default::default()
    };
    let clock = ManualClock::new();
    let platform = Invoker::new(config, engine, clock);
    platform.deploy("f", "m", "pallas", 1536).unwrap();
    platform.invoke("f", 0).unwrap(); // warm the container
    let mut seed = 0u64;
    let invoke_ns = bench("invoke (warm, zero-cost model) = L3 overhead", 100_000, || {
        seed += 1;
        platform.invoke("f", seed).unwrap();
    });

    // Contended acquire: same workload (8 hot functions × 8 threads),
    // single-lock pool vs the sharded one. The p99 gap is the price of
    // the cross-function thundering herd.
    println!("\n--- contended acquire: 8 functions x 8 threads ---");
    let (functions, threads, iters) = (8usize, 8usize, 20_000usize);
    let mut contended = Vec::new();
    for shards in [1usize, 8] {
        let (p50, p99) = contended_acquire(shards, functions, threads, iters);
        println!(
            "acquire/release cycle, pool_shards={shards:<2}          p50 {p50:>8} ns   p99 {p99:>8} ns"
        );
        contended.push(obj(vec![
            ("pool_shards", Json::Num(shards as f64)),
            ("functions", Json::Num(functions as f64)),
            ("threads", Json::Num(threads as f64)),
            ("iters_per_thread", Json::Num(iters as f64)),
            ("p50_ns", Json::Num(p50 as f64)),
            ("p99_ns", Json::Num(p99 as f64)),
        ]));
    }
    println!();

    // Substrate hot paths.
    let mut h = Histogram::new();
    let mut rng = SplitMix64::new(1);
    let hist_ns = bench("histogram.record", 1_000_000, || {
        h.record(rng.gen_range(1, 10_000_000_000));
    });

    let mut rng2 = SplitMix64::new(2);
    let rng_ns = bench("splitmix64.next_u64", 1_000_000, || {
        std::hint::black_box(rng2.next_u64());
    });

    bench("synthetic_image 224x224", 200, || {
        std::hint::black_box(synthetic_image(224, 224, 7));
    });

    // Read at runtime so the bench binary builds without artifacts.
    if let Ok(manifest) = std::fs::read_to_string("artifacts/squeezenet.json") {
        bench("json parse (squeezenet manifest)", 2_000, || {
            std::hint::black_box(Json::parse(&manifest).unwrap());
        });
    }

    let out = obj(vec![
        ("bench", Json::Str("hotpath".to_string())),
        ("invoke_warm_ns", Json::Num(invoke_ns)),
        ("histogram_record_ns", Json::Num(hist_ns)),
        ("splitmix64_ns", Json::Num(rng_ns)),
        ("contended_acquire", Json::Arr(contended)),
    ]);
    std::fs::write("BENCH_hotpath.json", out.to_string()).expect("write BENCH_hotpath.json");
    println!("\nwrote BENCH_hotpath.json");
    println!("metrics snapshot: {} records collected", platform.metrics.len());
}
