//! Bench: L3 hot-path microbenchmarks (§Perf).
//!
//! The platform must not be the bottleneck: the paper's latency minus
//! prediction time is a near-constant network/gateway cost, so our
//! per-invoke platform overhead (routing + pool + governor + billing +
//! metrics, everything except compute and simulated sleeps) has to sit
//! in the microsecond range. This bench measures it, plus the
//! substrate hot paths it is built on.
//!
//! `cargo bench --bench bench_hotpath`

use lambdaserve::configparse::{BootstrapConfig, PlatformConfig};
use lambdaserve::platform::Invoker;
use lambdaserve::runtime::{synthetic_image, MockEngine, MockModelCosts};
use lambdaserve::stats::Histogram;
use lambdaserve::util::json::Json;
use lambdaserve::util::{ManualClock, SplitMix64};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warm up.
    for _ in 0..iters.min(1000) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:<44} {per:>12.0} ns/op   ({iters} iters)");
    per
}

fn main() {
    println!("=== L3 hot-path microbenchmarks ===\n");

    // The headline number: full invoke pipeline overhead with a
    // zero-cost model, no simulated delays, warm container, manual
    // clock (sleeps are no-ops) — everything left is platform work.
    let engine = Arc::new(MockEngine::new(vec![MockModelCosts {
        predict: Duration::ZERO,
        init_run: Duration::ZERO,
        compile: Duration::ZERO,
        manifest: MockModelCosts::paper_like("m", 1, 5.0, 85).manifest,
    }]));
    let config = PlatformConfig {
        bootstrap: BootstrapConfig { simulate_delays: false, ..Default::default() },
        ..Default::default()
    };
    let clock = ManualClock::new();
    let platform = Invoker::new(config, engine, clock);
    platform.deploy("f", "m", "pallas", 1536).unwrap();
    platform.invoke("f", 0).unwrap(); // warm the container
    let mut seed = 0u64;
    bench("invoke (warm, zero-cost model) = L3 overhead", 100_000, || {
        seed += 1;
        platform.invoke("f", seed).unwrap();
    });

    // Substrate hot paths.
    let mut h = Histogram::new();
    let mut rng = SplitMix64::new(1);
    bench("histogram.record", 1_000_000, || {
        h.record(rng.gen_range(1, 10_000_000_000));
    });

    let mut rng2 = SplitMix64::new(2);
    bench("splitmix64.next_u64", 1_000_000, || {
        std::hint::black_box(rng2.next_u64());
    });

    bench("synthetic_image 224x224", 200, || {
        std::hint::black_box(synthetic_image(224, 224, 7));
    });

    // Read at runtime so the bench binary builds without artifacts.
    if let Ok(manifest) = std::fs::read_to_string("artifacts/squeezenet.json") {
        bench("json parse (squeezenet manifest)", 2_000, || {
            std::hint::black_box(Json::parse(&manifest).unwrap());
        });
    }

    println!("\nmetrics snapshot: {} records collected", platform.metrics.len());
}
