//! Bench: snapshot/restore — cold vs restored provision latency as a
//! function of weight size (§Perf).
//!
//! Runs on the MockEngine + ManualClock, so the numbers are the
//! platform's *modeled* provision economics in virtual time (what the
//! experiments and SLA analyses see), plus the measured wall overhead
//! of the snapshot machinery itself (capture + restore round trip
//! through the store with zero-cost models).
//!
//! `cargo bench --bench bench_snapshot`

use lambdaserve::configparse::{BootstrapConfig, CapturePolicy, SnapshotConfig};
use lambdaserve::platform::registry::FunctionRegistry;
use lambdaserve::platform::{CpuGovernor, SnapshotStore, StartKind};
use lambdaserve::runtime::{Engine, MockEngine, MockModelCosts};
use lambdaserve::util::{Clock, ManualClock, SplitMix64};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    println!("=== snapshot/restore: provision latency vs weight size ===\n");

    let engine: Arc<dyn Engine> = Arc::new(MockEngine::paper_zoo());
    let reg = FunctionRegistry::new(engine.clone());
    let snap_cfg = SnapshotConfig {
        enabled: true,
        capture_policy: CapturePolicy::Sync,
        ..Default::default()
    };
    println!(
        "restore_bw {:.0} MB/s, capacity {} MB, capture sync; 1024 MB functions\n",
        snap_cfg.restore_bw / 1e6,
        snap_cfg.capacity_bytes >> 20
    );
    println!(
        "{:>10} {:>10} {:>12} {:>14} {:>9}",
        "model", "MB", "cold (s)", "restored (s)", "speedup"
    );
    for model in ["squeezenet", "resnet18", "resnext50"] {
        let spec = reg.deploy(model, model, "pallas", 1024).unwrap();
        let clock: Arc<dyn Clock> = ManualClock::new();
        let gov = CpuGovernor::new(1792, clock.clone());
        let bootstrap = BootstrapConfig::default();
        let store = Arc::new(SnapshotStore::new(snap_cfg.clone()));
        let mut rng = SplitMix64::new(7);
        // First provision: full cold (compile + init + bootstrap),
        // captured synchronously.
        let cold = store
            .provision(&spec, &engine, &gov, &bootstrap, &clock, &mut rng)
            .unwrap();
        // Second provision: restored from the checkpoint.
        let restored = store
            .provision(&spec, &engine, &gov, &bootstrap, &clock, &mut rng)
            .unwrap();
        assert_eq!(cold.start_kind_for_first_use(), StartKind::Cold);
        assert_eq!(restored.start_kind_for_first_use(), StartKind::Restored);
        let cold_s = cold.provision_cost.total().as_secs_f64();
        let rest_s = restored.provision_cost.total().as_secs_f64();
        let bytes = engine.manifest(model).unwrap().param_bytes;
        println!(
            "{:>10} {:>10.1} {:>12.3} {:>14.3} {:>8.1}x",
            model,
            bytes as f64 / 1e6,
            cold_s,
            rest_s,
            cold_s / rest_s
        );
    }

    // Measured machinery overhead: zero-cost model, real clock — what
    // the capture and restore paths themselves cost in wall time.
    println!("\n=== machinery overhead (zero-cost model, wall time) ===\n");
    let engine: Arc<dyn Engine> = Arc::new(MockEngine::new(vec![MockModelCosts {
        predict: std::time::Duration::ZERO,
        init_run: std::time::Duration::ZERO,
        compile: std::time::Duration::ZERO,
        manifest: MockModelCosts::paper_like("m", 1, 5.0, 85).manifest,
    }]));
    let (handle, _) = engine.create_instance("m", "pallas").unwrap();
    const ITERS: usize = 50_000;
    let t0 = Instant::now();
    for _ in 0..ITERS {
        let blob = engine.snapshot_instance(&handle).unwrap();
        std::hint::black_box(&blob);
    }
    println!(
        "engine.snapshot_instance {:>10.0} ns/op   ({ITERS} iters)",
        t0.elapsed().as_nanos() as f64 / ITERS as f64
    );
    let blob = engine.snapshot_instance(&handle).unwrap();
    let t0 = Instant::now();
    for _ in 0..ITERS {
        let (h, stats) = engine.restore_instance("m", "pallas", &blob).unwrap();
        std::hint::black_box(&stats);
        engine.drop_instance(&h);
    }
    println!(
        "engine.restore_instance  {:>10.0} ns/op   ({ITERS} iters, incl. drop)",
        t0.elapsed().as_nanos() as f64 / ITERS as f64
    );
    assert_eq!(engine.live_instances(), 1, "bench leaked instances");
}
