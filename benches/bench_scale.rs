//! Bench: Figure 7 (workload spec) and Figures 8-10 — scalability
//! under the step ramp, on the calibrated mock engine + real clock
//! (the paper-scale ramp peaks at 100 req/s with multi-second service
//! times — horizontal-scale territory; `--scale` shrinks it shape-
//! preserving, default 0.2).
//!
//! `cargo bench --bench bench_scale` regenerates results/fig{7,8,9,10}.csv.

use lambdaserve::experiments::{run, EngineKind, ExpCtx};
use std::time::Instant;

fn main() {
    let mut ctx = ExpCtx::new(EngineKind::Mock);
    ctx.out_dir = "results".into();
    ctx.scale = std::env::var("LAMBDASERVE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    for id in ["fig7", "fig8", "fig9", "fig10"] {
        let t0 = Instant::now();
        run(id, &ctx).expect(id);
        println!("[{id} regenerated in {:.1}s]\n", t0.elapsed().as_secs_f64());
    }
}
