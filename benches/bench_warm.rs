//! Bench: Figures 1-3 — warm execution across memory sizes, all three
//! models, on the REAL artifacts (PJRT engine).
//!
//! `cargo bench --bench bench_warm` regenerates results/fig{1,2,3}.csv.
//! Set LAMBDASERVE_ENGINE=mock for a fast calibrated run.

use lambdaserve::experiments::{run, EngineKind, ExpCtx};
use std::time::Instant;

fn main() {
    let kind = match std::env::var("LAMBDASERVE_ENGINE").as_deref() {
        Ok("mock") => EngineKind::Mock,
        _ => EngineKind::Pjrt,
    };
    let mut ctx = ExpCtx::new(kind);
    ctx.out_dir = "results".into();
    // The paper's 25 sequential requests; LAMBDASERVE_REPS trims the
    // sweep for time-boxed runs (the printed tables show the count).
    ctx.reps = std::env::var("LAMBDASERVE_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(25);
    for id in ["fig1", "fig2", "fig3"] {
        let t0 = Instant::now();
        run(id, &ctx).expect(id);
        println!("[{id} regenerated in {:.1}s]\n", t0.elapsed().as_secs_f64());
    }
}
