//! Bench: the ablations — keep-alive TTL sweep, serverless-vs-dedicated
//! cost crossover, the §5 memory recommender, and (with artifacts) the
//! Pallas-vs-reference kernel comparison.
//!
//! `cargo bench --bench bench_ablation`

use lambdaserve::experiments::{run, EngineKind, ExpCtx};
use std::time::Instant;

fn main() {
    let mut ctx = ExpCtx::new(EngineKind::Mock);
    ctx.out_dir = "results".into();
    for id in ["abl-keepalive", "abl-provisioned", "abl-memopt"] {
        let t0 = Instant::now();
        run(id, &ctx).expect(id);
        println!("[{id} regenerated in {:.1}s]\n", t0.elapsed().as_secs_f64());
    }
    // The kernel ablation needs real artifacts.
    let mut pjrt = ExpCtx::new(EngineKind::Pjrt);
    pjrt.out_dir = "results".into();
    pjrt.reps = 10;
    let t0 = Instant::now();
    run("abl-kernel", &pjrt).expect("abl-kernel");
    println!("[abl-kernel regenerated in {:.1}s]", t0.elapsed().as_secs_f64());
}
