//! Bench: Table 1 regeneration + billing-meter hot-path timing.
//!
//! `cargo bench --bench bench_billing`

use lambdaserve::configparse::PricingConfig;
use lambdaserve::experiments::{run_table1, EngineKind, ExpCtx};
use lambdaserve::platform::BillingMeter;
use std::time::{Duration, Instant};

fn main() {
    // Regenerate Table 1 (also writes results/table1.csv).
    let mut ctx = ExpCtx::new(EngineKind::Mock);
    ctx.out_dir = "results".into();
    run_table1(&ctx).expect("table1");

    // Hot path: charge() throughput (the meter sits on every invoke).
    let meter = BillingMeter::new(PricingConfig::default());
    let n = 200_000;
    let t0 = Instant::now();
    for i in 0..n {
        meter
            .charge("f", 1024, Duration::from_millis(100 + (i % 1000)))
            .unwrap();
    }
    let dt = t0.elapsed();
    println!(
        "\nbilling.charge: {n} calls in {:.3}s = {:.0} ns/call",
        dt.as_secs_f64(),
        dt.as_nanos() as f64 / n as f64
    );
}
