//! Bench: Figures 4-6 — cold execution across memory sizes (real
//! model load on every cold start; the 10-minute gaps are virtual).
//!
//! `cargo bench --bench bench_cold` regenerates results/fig{4,5,6}.csv.

use lambdaserve::experiments::{run, EngineKind, ExpCtx};
use std::time::Instant;

fn main() {
    let kind = match std::env::var("LAMBDASERVE_ENGINE").as_deref() {
        Ok("mock") => EngineKind::Mock,
        _ => EngineKind::Pjrt,
    };
    let mut ctx = ExpCtx::new(kind);
    ctx.out_dir = "results".into();
    for id in ["fig4", "fig5", "fig6"] {
        let t0 = Instant::now();
        run(id, &ctx).expect(id);
        println!("[{id} regenerated in {:.1}s]\n", t0.elapsed().as_secs_f64());
    }
}
