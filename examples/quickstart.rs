//! Quickstart: deploy a model on the serverless platform and serve a
//! few predictions, printing the cold/warm latency split and the bill.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Uses the real PJRT engine and real AOT artifacts. The first request
//! pays the cold start (sandbox + runtime init + package fetch + real
//! model compile/load); subsequent requests reuse the warm container.

use lambdaserve::configparse::PlatformConfig;
use lambdaserve::platform::Invoker;
use lambdaserve::runtime::PjrtEngine;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let config = PlatformConfig::default();
    println!("loading AOT artifacts from {}/ ...", config.artifacts_dir);
    let engine = Arc::new(PjrtEngine::new(Path::new(&config.artifacts_dir), 1)?);

    // A live platform: real clock, real compute, simulated Lambda
    // bootstrap + CPU-share semantics.
    let platform = Invoker::live(config, engine);

    // Deploy SqueezeNet at the paper's mid-range memory size.
    let spec = platform.deploy("classify", "squeezenet", "pallas", 1024)?;
    println!(
        "deployed `{}` -> {} @ {} MB (CPU share {:.2})\n",
        spec.name,
        spec.model,
        spec.memory_mb,
        platform.governor().share(spec.memory_mb)
    );

    for seed in 0..5u64 {
        let out = platform
            .invoke("classify", seed)
            .map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let r = &out.record;
        println!(
            "request {seed}: class={:<4} ({:.3} prob)  {}  predict={:.3}s  \
             response={:.3}s  billed={} ms  ${:.8}",
            out.prediction.top1,
            out.prediction.top_prob,
            r.start,
            r.predict.as_secs_f64(),
            r.response().as_secs_f64(),
            r.billed_ms,
            r.cost_dollars,
        );
    }

    println!(
        "\ntotal bill: ${:.8} over {} invocations ({} cold); {:.2} GB-s",
        platform.billing.total_dollars(),
        platform.metrics.len(),
        platform.metrics.cold_count(),
        platform.billing.total_gb_seconds(),
    );
    Ok(())
}
