//! The paper's core experiment, end to end on real inference: warm,
//! cold, and snapshot-restored memory sweeps for one model, printed
//! side by side — a compact version of Figures 1 & 4 (SqueezeNet by
//! default) plus the snapshot-on vs snapshot-off cold ablation.
//!
//!     cargo run --release --example paper_sweep [-- model [reps]]
//!
//! 10-minute cold gaps run on the manual clock (instant), while every
//! prediction and model load is real XLA compute; see DESIGN.md §4.

use lambdaserve::configparse::{CapturePolicy, PlatformConfig, MEMORY_SIZES_2017};
use lambdaserve::platform::Invoker;
use lambdaserve::runtime::PjrtEngine;
use lambdaserve::stats::mean_ci95;
use lambdaserve::util::ManualClock;
use lambdaserve::workload::{run_closed_loop, ColdProbe, WarmProbe};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("squeezenet");
    let reps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(10);

    let config = PlatformConfig::default();
    let engine = Arc::new(PjrtEngine::new(Path::new(&config.artifacts_dir), 1)?);
    println!(
        "{model}: warm ({reps} reqs @1s) vs cold (5 reqs @10min) vs snapshot-restored\n"
    );
    println!(
        "{:>8}  {:>12} {:>12}  {:>12} {:>12}  {:>12}",
        "MB", "warm lat(s)", "warm pred(s)", "cold lat(s)", "cold pred(s)", "rest lat(s)"
    );

    for mem in MEMORY_SIZES_2017 {
        let clock = ManualClock::new();
        let platform = Invoker::new(config.clone(), engine.clone(), clock);
        if platform.deploy("f", model, "pallas", mem).is_err() {
            println!("{mem:>8}  {:>12} (below the model's peak-memory floor)", "-");
            continue;
        }
        // Warm probe (discarded first request absorbs the cold start).
        let warm = run_closed_loop(
            &platform,
            "f",
            &WarmProbe { requests: reps, interval: Duration::from_secs(1) },
            1,
        );
        let (wl, _) = mean_ci95(&warm.latencies_s());
        let (wp, _) = mean_ci95(&warm.predicts_s());

        // Cold probe: clear the pool, then 10-minute-gap requests.
        platform.evict_all();
        let cold = run_closed_loop(&platform, "f", &ColdProbe::default(), 2);
        assert_eq!(cold.cold_count(), cold.ok_samples().len());
        let (cl, _) = mean_ci95(&cold.latencies_s());
        let (cp, _) = mean_ci95(&cold.predicts_s());

        // Snapshot ablation: the same cold probe with snapshot/restore
        // on — a fresh platform whose first (discarded-by-hand) cold
        // start seeds the checkpoint, so every probed provision
        // restores instead of recompiling.
        let mut snap_config = config.clone();
        snap_config.snapshot.enabled = true;
        snap_config.snapshot.capture_policy = CapturePolicy::Sync;
        let clock = ManualClock::new();
        let snap_platform = Invoker::new(snap_config, engine.clone(), clock);
        snap_platform.deploy("f", model, "pallas", mem)?;
        snap_platform
            .invoke("f", 0)
            .map_err(|e| anyhow::anyhow!("snapshot seed invoke: {e}"))?;
        snap_platform.evict_all();
        let rest = run_closed_loop(&snap_platform, "f", &ColdProbe::default(), 3);
        assert_eq!(rest.restored_count(), rest.ok_samples().len(), "all probes restored");
        let (rl, _) = mean_ci95(&rest.latencies_s());

        println!("{mem:>8}  {wl:>12.3} {wp:>12.3}  {cl:>12.3} {cp:>12.3}  {rl:>12.3}");
    }
    println!("\n(the paper's shape: all fall with memory; cold stays several seconds");
    println!(" above warm because sandbox+runtime+model-load dominate, while the");
    println!(" restored column pays sandbox + restore I/O only — the checkpoint");
    println!(" ablation the snapshot subsystem buys)");
    Ok(())
}
