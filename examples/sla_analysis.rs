//! The paper's §5 claim, quantified: "this bimodal latency
//! distribution can risk the adherence to SLAs".
//!
//! Simulates a day of sparse production traffic against one deployed
//! function, then reports the latency distribution (p50/p95/p99/max),
//! the cold fraction, and the SLA-violation rate for a range of SLA
//! targets — with and without the §5 "keep warm" mitigation
//! (pre-warmed containers + short keep-alive vs default), and with the
//! snapshot/restore mitigation (cold provisions restore from a
//! checkpoint instead of paying runtime init + package fetch + model
//! load). A closing ablation table puts snapshot-on and snapshot-off
//! side by side per SLA target, mirroring the keep-warm comparison.
//!
//! End-to-end accounting (post-dispatcher): a request's latency
//! includes its admission-queue wait — both for served requests (the
//! record's `queue` component) and for refused ones (a 503 after a
//! parked deadline held the client for the whole deadline, and counts
//! as a violation at EVERY SLA target). The original example predated
//! the dispatcher and undercounted response time for parked requests.
//!
//!     cargo run --release --example sla_analysis

use lambdaserve::configparse::{CapturePolicy, PlatformConfig};
use lambdaserve::experiments::pct;
use lambdaserve::platform::{Invoker, StartKind};
use lambdaserve::runtime::MockEngine;
use lambdaserve::stats::Summary;
use lambdaserve::util::ManualClock;
use lambdaserve::workload::{run_closed_loop, PoissonArrivals};
use std::sync::Arc;
use std::time::Duration;

const SLA_TARGETS: [f64; 4] = [0.5, 1.0, 2.0, 5.0];

struct DayReport {
    summary: Summary,
    cold_frac: f64,
    restored_frac: f64,
    /// p99 over the provisioned (cold or restored) requests only —
    /// the tail the mitigations attack.
    provisioned_p99_s: f64,
    /// (sla_target_s, violation_rate) with refusals counted as
    /// violations at every target.
    slas: Vec<(f64, f64)>,
    refused: usize,
    queue_wait_p99_s: f64,
}

fn run_day(keep_alive_s: f64, prewarm: usize, snapshot: bool) -> DayReport {
    let engine = Arc::new(MockEngine::paper_zoo());
    let mut config = PlatformConfig { keep_alive_s, ..Default::default() };
    config.snapshot.enabled = snapshot;
    // Sync capture keeps the virtual-time run deterministic; the
    // capture itself rides the FIRST cold start of the day.
    config.snapshot.capture_policy = CapturePolicy::Sync;
    let clock = ManualClock::new();
    let platform = Invoker::new(config, engine, clock);
    platform.deploy("api", "squeezenet", "pallas", 1024).unwrap();
    if prewarm > 0 {
        platform.prewarm("api", prewarm).unwrap();
    }
    // One request every ~4 minutes for 24 h ≈ 360 requests.
    let sched = PoissonArrivals {
        rps: 1.0 / 240.0,
        duration: Duration::from_secs(24 * 3600),
        seed: 42,
    };
    let report = run_closed_loop(&platform, "api", &sched, 7);
    let lats = report.latencies_s();
    let summary = Summary::from_samples(&lats);
    let served = report.ok_samples().len().max(1);
    let cold_frac = report.cold_count() as f64 / served as f64;
    let restored_frac = report.restored_count() as f64 / served as f64;
    let provisioned: Vec<f64> = report
        .ok_samples()
        .iter()
        .filter(|s| s.start != StartKind::Warm)
        .map(|s| s.latency.as_secs_f64())
        .collect();
    let provisioned_p99_s = Summary::from_samples(&provisioned).p99;
    // A refused request (429/503) is an SLA violation at any target:
    // the client waited its bounded queue delay and got no answer.
    let refused = report.throttled + report.saturated;
    let total = lats.len() + refused;
    let slas = SLA_TARGETS
        .iter()
        .map(|sla| {
            let served_viol = lats.iter().filter(|l| **l > *sla).count();
            ((*sla), (served_viol + refused) as f64 / total.max(1) as f64)
        })
        .collect();
    // The true dispatch wait served requests paid, straight from the
    // streaming per-function shard.
    let queue_wait_p99_s =
        platform.metrics.function_metrics("api").queue_wait.p99() as f64 / 1e9;
    DayReport {
        summary,
        cold_frac,
        restored_frac,
        provisioned_p99_s,
        slas,
        refused,
        queue_wait_p99_s,
    }
}

fn print_block(name: &str, r: &DayReport) {
    let s = &r.summary;
    println!("--- {name} ---");
    println!(
        "  n={}  mean={:.3}s  p50={:.3}s  p95={:.3}s  p99={:.3}s  max={:.3}s",
        s.n, s.mean, s.p50, s.p95, s.p99, s.max
    );
    println!(
        "  cold-start fraction: {}   restored: {}   refused: {}   queue wait p99: {:.3}s",
        pct(r.cold_frac),
        pct(r.restored_frac),
        r.refused,
        r.queue_wait_p99_s
    );
    for (sla, viol) in &r.slas {
        println!("  SLA {sla:>4.1}s -> {:>6} violations", pct(*viol));
    }
    println!();
}

fn main() {
    println!("24h of sparse traffic (Poisson, ~4 min between requests), squeezenet @1024MB\n");

    // The paper's situation: default platform, no mitigation.
    let off = run_day(300.0, 0, false);
    print_block("default platform (5 min keep-alive)", &off);

    // §5 mitigation 1: platform keeps containers warm much longer.
    let r = run_day(3600.0, 0, false);
    print_block("long keep-alive (60 min)", &r);

    // §5 mitigation 2: declarative pre-warming (and long TTL).
    let r = run_day(3600.0, 2, false);
    print_block("pre-warmed x2 + 60 min keep-alive", &r);

    // Snapshot/restore: same default platform, but every cold
    // provision after the first restores from a checkpoint.
    let snap = run_day(300.0, 0, true);
    print_block("snapshot-restore (5 min keep-alive)", &snap);

    // The ablation, side by side: what the restore path alone does to
    // the provisioned-start tail and the SLA-violation rate.
    println!("--- snapshot ablation (default keep-alive) ---");
    println!(
        "  provisioned-start p99: off={:.3}s  on={:.3}s",
        off.provisioned_p99_s, snap.provisioned_p99_s
    );
    println!("  {:>10} {:>12} {:>12}", "SLA (s)", "off", "snapshot");
    for ((sla, off_viol), (_, snap_viol)) in off.slas.iter().zip(&snap.slas) {
        println!("  {sla:>10.1} {:>12} {:>12}", pct(*off_viol), pct(*snap_viol));
    }
    println!();
    println!("the bimodality (p99 >> p50) tracks the cold fraction — exactly the");
    println!("paper's SLA-risk argument; keep-warm mitigations collapse the tail by");
    println!("avoiding provisions, snapshot-restore by making each provision cheap.");
    println!("latencies include admission-queue wait end to end, and refusals count");
    println!("as violations at every SLA target.");
}
