//! The paper's §5 claim, quantified: "this bimodal latency
//! distribution can risk the adherence to SLAs".
//!
//! Simulates a day of sparse production traffic against one deployed
//! function, then reports the latency distribution (p50/p95/p99/max),
//! the cold fraction, and the SLA-violation rate for a range of SLA
//! targets — with and without the §5 "keep warm" mitigation
//! (pre-warmed containers + short keep-alive vs default).
//!
//!     cargo run --release --example sla_analysis

use lambdaserve::configparse::PlatformConfig;
use lambdaserve::platform::Invoker;
use lambdaserve::runtime::MockEngine;
use lambdaserve::stats::Summary;
use lambdaserve::util::ManualClock;
use lambdaserve::workload::{run_closed_loop, PoissonArrivals};
use std::sync::Arc;
use std::time::Duration;

fn run_day(keep_alive_s: f64, prewarm: usize) -> (Summary, f64, Vec<(f64, f64)>) {
    let engine = Arc::new(MockEngine::paper_zoo());
    let config = PlatformConfig { keep_alive_s, ..Default::default() };
    let clock = ManualClock::new();
    let platform = Invoker::new(config, engine, clock);
    platform.deploy("api", "squeezenet", "pallas", 1024).unwrap();
    if prewarm > 0 {
        platform.prewarm("api", prewarm).unwrap();
    }
    // One request every ~4 minutes for 24 h ≈ 360 requests.
    let sched = PoissonArrivals {
        rps: 1.0 / 240.0,
        duration: Duration::from_secs(24 * 3600),
        seed: 42,
    };
    let report = run_closed_loop(&platform, "api", &sched, 7);
    let lats = report.latencies_s();
    let summary = Summary::from_samples(&lats);
    let cold_frac = report.cold_count() as f64 / report.ok_samples().len().max(1) as f64;
    let slas = [0.5, 1.0, 2.0, 5.0]
        .iter()
        .map(|sla| {
            let viol = lats.iter().filter(|l| **l > *sla).count() as f64
                / lats.len().max(1) as f64;
            (*sla, viol)
        })
        .collect();
    (summary, cold_frac, slas)
}

fn print_block(name: &str, s: &Summary, cold: f64, slas: &[(f64, f64)]) {
    println!("--- {name} ---");
    println!(
        "  n={}  mean={:.3}s  p50={:.3}s  p95={:.3}s  p99={:.3}s  max={:.3}s",
        s.n, s.mean, s.p50, s.p95, s.p99, s.max
    );
    println!("  cold-start fraction: {:.1}%", cold * 100.0);
    for (sla, viol) in slas {
        println!("  SLA {sla:>4.1}s -> {:5.1}% violations", viol * 100.0);
    }
    println!();
}

fn main() {
    println!("24h of sparse traffic (Poisson, ~4 min between requests), squeezenet @1024MB\n");

    // The paper's situation: default platform, no mitigation.
    let (s, cold, slas) = run_day(300.0, 0);
    print_block("default platform (5 min keep-alive)", &s, cold, &slas);

    // §5 mitigation 1: platform keeps containers warm much longer.
    let (s, cold, slas) = run_day(3600.0, 0);
    print_block("long keep-alive (60 min)", &s, cold, &slas);

    // §5 mitigation 2: declarative pre-warming (and long TTL).
    let (s, cold, slas) = run_day(3600.0, 2);
    print_block("pre-warmed x2 + 60 min keep-alive", &s, cold, &slas);

    println!("the bimodality (p99 >> p50) tracks the cold fraction — exactly the");
    println!("paper's SLA-risk argument; keep-warm mitigations collapse the tail.");
}
