//! The paper's §5 claim, quantified: "this bimodal latency
//! distribution can risk the adherence to SLAs".
//!
//! Simulates a day of sparse production traffic against one deployed
//! function, then reports the latency distribution (p50/p95/p99/max),
//! the cold fraction, and the SLA-violation rate for a range of SLA
//! targets — with and without the §5 "keep warm" mitigation
//! (pre-warmed containers + short keep-alive vs default), with the
//! snapshot/restore mitigation (cold provisions restore from a
//! checkpoint instead of paying runtime init + package fetch + model
//! load), and with the adaptive controllers (deploy-time eager
//! snapshot capture removes the first full cold start of the day).
//! Ablation tables put each mitigation on and off side by side per
//! SLA target.
//!
//! End-to-end accounting (post-dispatcher): a request's latency
//! includes its admission-queue wait — both for served requests (the
//! record's `queue` component) and for refused ones (a 503 after a
//! parked deadline held the client for the whole deadline, and counts
//! as a violation at EVERY SLA target). The original example predated
//! the dispatcher and undercounted response time for parked requests.
//!
//! Each experiment also prints the waterfalls of its five slowest
//! retained traces (tracing on, sample rate 1.0) — the per-stage span
//! timeline makes the SLA story concrete: the slow requests are the
//! ones whose bars are dominated by provision children, not kernel
//! execution.
//!
//!     cargo run --release --example sla_analysis [all|abl-snapshot|abl-adaptive]
//!
//! The positional experiment id selects which blocks run: `all` (the
//! default) runs everything, `abl-snapshot` just the snapshot-on/off
//! ablation, `abl-adaptive` just the adaptive-controller ablation.

use lambdaserve::configparse::{CapturePolicy, PlatformConfig};
use lambdaserve::experiments::pct;
use lambdaserve::platform::{Invoker, StartKind};
use lambdaserve::runtime::MockEngine;
use lambdaserve::stats::Summary;
use lambdaserve::util::ManualClock;
use lambdaserve::workload::{run_closed_loop, PoissonArrivals};
use std::sync::Arc;
use std::time::Duration;

const SLA_TARGETS: [f64; 4] = [0.5, 1.0, 2.0, 5.0];

struct DayReport {
    summary: Summary,
    cold_frac: f64,
    restored_frac: f64,
    /// p99 over the provisioned (cold or restored) requests only —
    /// the tail the mitigations attack.
    provisioned_p99_s: f64,
    /// (sla_target_s, violation_rate) with refusals counted as
    /// violations at every target.
    slas: Vec<(f64, f64)>,
    refused: usize,
    queue_wait_p99_s: f64,
    /// Waterfalls of the five slowest retained traces — the span
    /// timelines behind the tail of the latency distribution.
    slowest_waterfalls: Vec<String>,
}

fn run_day(keep_alive_s: f64, prewarm: usize, snapshot: bool, adaptive: bool) -> DayReport {
    let engine = Arc::new(MockEngine::paper_zoo());
    let mut config = PlatformConfig { keep_alive_s, ..Default::default() };
    config.snapshot.enabled = snapshot;
    // Sync capture keeps the virtual-time run deterministic; the
    // capture itself rides the FIRST cold start of the day — or, with
    // the adaptive controllers on, the deploy-time eager capture.
    config.snapshot.capture_policy = CapturePolicy::Sync;
    config.policy.enabled = adaptive;
    // Trace every request (sample rate 1.0) so `slowest` ranks over
    // the whole day, not just the tail-retained exemplars; ~360
    // requests fit the default 512-entry ring.
    config.trace.enabled = true;
    config.trace.sample_rate = 1.0;
    let clock = ManualClock::new();
    let platform = Invoker::new(config, engine, clock);
    platform.deploy("api", "squeezenet", "pallas", 1024).unwrap();
    if prewarm > 0 {
        platform.prewarm("api", prewarm).unwrap();
    }
    // One request every ~4 minutes for 24 h ≈ 360 requests.
    let sched = PoissonArrivals {
        rps: 1.0 / 240.0,
        duration: Duration::from_secs(24 * 3600),
        seed: 42,
    };
    let report = run_closed_loop(&platform, "api", &sched, 7);
    let lats = report.latencies_s();
    let summary = Summary::from_samples(&lats);
    let served = report.ok_samples().len().max(1);
    let cold_frac = report.cold_count() as f64 / served as f64;
    let restored_frac = report.restored_count() as f64 / served as f64;
    let provisioned: Vec<f64> = report
        .ok_samples()
        .iter()
        .filter(|s| s.start != StartKind::Warm)
        .map(|s| s.latency.as_secs_f64())
        .collect();
    let provisioned_p99_s = Summary::from_samples(&provisioned).p99;
    // A refused request (429/503) is an SLA violation at any target:
    // the client waited its bounded queue delay and got no answer.
    let refused = report.throttled + report.saturated;
    let total = lats.len() + refused;
    let slas = SLA_TARGETS
        .iter()
        .map(|sla| {
            let served_viol = lats.iter().filter(|l| **l > *sla).count();
            ((*sla), (served_viol + refused) as f64 / total.max(1) as f64)
        })
        .collect();
    // The true dispatch wait served requests paid, straight from the
    // streaming per-function shard.
    let queue_wait_p99_s =
        platform.metrics.function_metrics("api").queue_wait.p99() as f64 / 1e9;
    let slowest_waterfalls =
        platform.trace.slowest(5).iter().map(|t| t.waterfall()).collect();
    DayReport {
        summary,
        cold_frac,
        restored_frac,
        provisioned_p99_s,
        slas,
        refused,
        queue_wait_p99_s,
        slowest_waterfalls,
    }
}

fn print_block(name: &str, r: &DayReport) {
    let s = &r.summary;
    println!("--- {name} ---");
    println!(
        "  n={}  mean={:.3}s  p50={:.3}s  p95={:.3}s  p99={:.3}s  max={:.3}s",
        s.n, s.mean, s.p50, s.p95, s.p99, s.max
    );
    println!(
        "  cold-start fraction: {}   restored: {}   refused: {}   queue wait p99: {:.3}s",
        pct(r.cold_frac),
        pct(r.restored_frac),
        r.refused,
        r.queue_wait_p99_s
    );
    for (sla, viol) in &r.slas {
        println!("  SLA {sla:>4.1}s -> {:>6} violations", pct(*viol));
    }
    println!();
}

fn print_ablation(title: &str, left: (&str, &DayReport), right: (&str, &DayReport)) {
    println!("--- {title} ---");
    println!(
        "  provisioned-start p99: {}={:.3}s  {}={:.3}s",
        left.0, left.1.provisioned_p99_s, right.0, right.1.provisioned_p99_s
    );
    println!("  {:>10} {:>12} {:>12}", "SLA (s)", left.0, right.0);
    for ((sla, l_viol), (_, r_viol)) in left.1.slas.iter().zip(&right.1.slas) {
        println!("  {sla:>10.1} {:>12} {:>12}", pct(*l_viol), pct(*r_viol));
    }
    println!();
}

fn print_slowest(name: &str, r: &DayReport) {
    println!("--- {name}: five slowest traces ---");
    for w in &r.slowest_waterfalls {
        for line in w.lines() {
            println!("  {line}");
        }
        println!();
    }
}

fn run_keepwarm() {
    // The paper's situation: default platform, no mitigation.
    let off = run_day(300.0, 0, false, false);
    print_block("default platform (5 min keep-alive)", &off);
    print_slowest("default platform", &off);

    // §5 mitigation 1: platform keeps containers warm much longer.
    let r = run_day(3600.0, 0, false, false);
    print_block("long keep-alive (60 min)", &r);

    // §5 mitigation 2: declarative pre-warming (and long TTL).
    let r = run_day(3600.0, 2, false, false);
    print_block("pre-warmed x2 + 60 min keep-alive", &r);
}

fn run_abl_snapshot() {
    let off = run_day(300.0, 0, false, false);
    print_block("default platform (5 min keep-alive)", &off);

    // Snapshot/restore: same default platform, but every cold
    // provision after the first restores from a checkpoint.
    let snap = run_day(300.0, 0, true, false);
    print_block("snapshot-restore (5 min keep-alive)", &snap);

    // The ablation, side by side: what the restore path alone does to
    // the provisioned-start tail and the SLA-violation rate.
    print_ablation(
        "snapshot ablation (default keep-alive)",
        ("off", &off),
        ("snapshot", &snap),
    );
    print_slowest("snapshot-restore", &snap);
}

fn run_abl_adaptive() {
    // Adaptive controllers over the snapshot platform: deploy-time
    // eager capture means even the day's FIRST provision restores —
    // the static run still pays one full cold start to seed the store.
    let fixed = run_day(300.0, 0, true, false);
    print_block("snapshot-restore, static knobs", &fixed);
    let adaptive = run_day(300.0, 0, true, true);
    print_block("snapshot-restore + adaptive controllers", &adaptive);
    print_ablation(
        "adaptive ablation (snapshot platform)",
        ("static", &fixed),
        ("adaptive", &adaptive),
    );
    print_slowest("snapshot-restore + adaptive", &adaptive);
    println!("adaptive eagerly captures at deploy, so the first provision of the");
    println!("day restores instead of paying the full runtime-init + fetch + load");
    println!("chain; under sparse traffic the other two controllers stay quiet");
    println!("(no queue depth -> no window growth, no batches -> ladder untouched).");
    println!();
}

fn main() {
    let id = std::env::args().nth(1).unwrap_or_else(|| "all".to_string());
    println!("24h of sparse traffic (Poisson, ~4 min between requests), squeezenet @1024MB\n");
    match id.as_str() {
        "all" => {
            run_keepwarm();
            run_abl_snapshot();
            run_abl_adaptive();
            println!("the bimodality (p99 >> p50) tracks the cold fraction — exactly the");
            println!("paper's SLA-risk argument; keep-warm mitigations collapse the tail by");
            println!("avoiding provisions, snapshot-restore by making each provision cheap,");
            println!("and the adaptive controllers by capturing the checkpoint up front.");
            println!("latencies include admission-queue wait end to end, and refusals count");
            println!("as violations at every SLA target.");
        }
        "abl-snapshot" => run_abl_snapshot(),
        "abl-adaptive" => run_abl_adaptive(),
        other => {
            eprintln!("unknown experiment id {other:?} (all|abl-snapshot|abl-adaptive)");
            std::process::exit(2);
        }
    }
}
