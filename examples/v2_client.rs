//! v2 API tour through the typed client SDK: boot a gateway on the
//! mock engine, then deploy / invoke (sync + async) / stats /
//! reconfigure / undeploy over real HTTP.
//!
//! ```sh
//! cargo run --example v2_client
//! ```

use lambdaserve::configparse::{BootstrapConfig, PlatformConfig};
use lambdaserve::gateway::{ApiClient, DeploySpec, Gateway, ReconfigureSpec};
use lambdaserve::platform::Invoker;
use lambdaserve::runtime::MockEngine;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // Gateway on an ephemeral port, mock engine, no simulated
    // bootstrap delays (the paper-calibrated cold-start components
    // would otherwise make this tour take seconds).
    let config = PlatformConfig {
        bootstrap: BootstrapConfig { simulate_delays: false, ..Default::default() },
        ..Default::default()
    };
    let platform = Arc::new(Invoker::live(config, Arc::new(MockEngine::paper_zoo())));
    let gw = Gateway::bind("127.0.0.1:0", 8, platform)?;
    let addr = gw.local_addr().to_string();
    let shutdown = gw.shutdown_handle();
    let server = std::thread::spawn(move || gw.serve());
    println!("gateway: http://{addr}");

    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(60));

    // Deploy with the full v2 spec: memory, warm-pool policy, cap.
    let f = api.deploy(
        &DeploySpec::new("classify", "squeezenet")
            .memory_mb(1024)
            .min_warm(1)
            .max_concurrency(8),
    )?;
    println!(
        "deployed {} ({} @ {} MB, min_warm={}, warm={})",
        f.name, f.model, f.memory_mb, f.min_warm, f.warm_containers
    );

    // Sync invocations: the first rides the pre-warmed container.
    for seed in [1u64, 2] {
        let r = api.invoke("classify", Some(seed))?;
        println!(
            "sync  seed={seed}: top1={} start={} response={:.3}s billed={}ms",
            r.top1, r.start, r.response_s, r.billed_ms
        );
    }

    // Async invocation: 202 + id, then poll.
    let id = api.invoke_async("classify", Some(3))?;
    println!("async seed=3: accepted as {id}");
    let done = api.wait_invocation(&id, Duration::from_millis(20), Duration::from_secs(60))?;
    if let Some(r) = done.result {
        println!(
            "async seed=3: {} start={} response={:.3}s billed={}ms",
            done.status, r.start, r.response_s, r.billed_ms
        );
    }

    // Per-function stats.
    let s = api.stats("classify")?;
    println!(
        "stats: {} invocations ({} cold), mean response {:.3}s, total ${:.8}",
        s.invocations, s.cold_starts, s.response_mean_s, s.cost_dollars_total
    );

    // Reconfigure to a bigger memory tier (cycles warm containers).
    let f = api.reconfigure(
        "classify",
        &ReconfigureSpec { memory_mb: Some(1536), ..Default::default() },
    )?;
    println!("reconfigured to {} MB", f.memory_mb);
    let r = api.invoke("classify", Some(4))?;
    println!("post-reconfigure: start={} (cold: spec changed)", r.start);

    // Undeploy and shut down.
    let reaped = api.undeploy("classify")?;
    println!("undeployed ({reaped} containers reaped)");

    shutdown.shutdown();
    server.join().unwrap()?;
    Ok(())
}
