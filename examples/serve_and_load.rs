//! END-TO-END VALIDATION DRIVER: boot the full serving stack (real AOT
//! model on the PJRT engine behind the HTTP gateway), fire a batched
//! load of real HTTP requests, and report latency/throughput — proving
//! all layers compose: Pallas kernel -> JAX model -> HLO artifact ->
//! Rust PJRT runtime -> container platform -> HTTP gateway -> client.
//!
//!     make artifacts && cargo run --release --example serve_and_load
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use lambdaserve::configparse::PlatformConfig;
use lambdaserve::exec::ThreadPool;
use lambdaserve::gateway::Gateway;
use lambdaserve::httpd::{http_get, http_post};
use lambdaserve::platform::Invoker;
use lambdaserve::runtime::PjrtEngine;
use lambdaserve::stats::Summary;
use lambdaserve::util::json::Json;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const REQUESTS: usize = 40;
const CONCURRENCY: usize = 4;

fn main() -> anyhow::Result<()> {
    let config = PlatformConfig::default();
    println!("booting gateway with real PJRT engine (2 shards)...");
    let engine = Arc::new(PjrtEngine::new(Path::new(&config.artifacts_dir), 2)?);
    let platform = Arc::new(Invoker::live(config, engine));
    let gw = Gateway::bind("127.0.0.1:0", 16, platform.clone())?;
    let addr = gw.local_addr().to_string();
    let shutdown = gw.shutdown_handle();
    let server = std::thread::spawn(move || gw.serve());

    // Deploy over HTTP, like a real operator would.
    let tmo = Duration::from_secs(300);
    let r = http_post(&addr, "/v1/functions?name=classify&model=squeezenet&mem=1536", b"", tmo)?;
    anyhow::ensure!(r.status == 200, "deploy failed: {}", r.body_str());
    println!("deployed squeezenet @1536MB via POST /v1/functions");

    // Pre-warm to the target concurrency (pays the compiles up front).
    let t0 = Instant::now();
    let r = http_post(&addr, &format!("/v1/prewarm/classify?n={CONCURRENCY}"), b"", tmo)?;
    anyhow::ensure!(r.status == 200, "prewarm failed: {}", r.body_str());
    println!("pre-warmed {CONCURRENCY} containers in {:.1}s", t0.elapsed().as_secs_f64());

    // Batched load: REQUESTS real HTTP GETs at CONCURRENCY in flight.
    println!("\nfiring {REQUESTS} requests at concurrency {CONCURRENCY}...");
    let pool = ThreadPool::new(CONCURRENCY, "loadgen");
    let lat: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let addr = addr.clone();
            let lat = lat.clone();
            pool.submit(move || {
                let t = Instant::now();
                let r = http_get(&addr, &format!("/v1/invoke/classify?seed={i}"), tmo).unwrap();
                assert_eq!(r.status, 200, "{}", r.body_str());
                let j = Json::parse(&r.body_str()).unwrap();
                assert!(j.get("top1").unwrap().as_f64().is_some());
                lat.lock().unwrap().push(t.elapsed().as_secs_f64());
            })
        })
        .collect();
    for h in handles {
        h.join();
    }
    let wall = t0.elapsed().as_secs_f64();

    let lats = lat.lock().unwrap().clone();
    let s = Summary::from_samples(&lats);
    println!("\n=== end-to-end serving report (squeezenet @1536MB, pallas artifact) ===");
    println!("requests:    {REQUESTS} ok, 0 failed");
    println!("wall time:   {wall:.2}s");
    println!("throughput:  {:.2} req/s", REQUESTS as f64 / wall);
    println!(
        "latency:     mean={:.3}s p50={:.3}s p95={:.3}s p99={:.3}s max={:.3}s",
        s.mean, s.p50, s.p95, s.p99, s.max
    );

    let stats = http_get(&addr, "/v1/stats", tmo)?;
    let j = Json::parse(&stats.body_str())?;
    println!(
        "platform:    {} invocations, {} cold starts, {} containers, peak conc {}, ${:.6} billed",
        j.get("invocations").unwrap().as_u64().unwrap(),
        j.get("cold_starts").unwrap().as_u64().unwrap(),
        j.get("containers_alive").unwrap().as_u64().unwrap(),
        j.get("peak_concurrency").unwrap().as_u64().unwrap(),
        j.get("total_cost_dollars").unwrap().as_f64().unwrap(),
    );

    shutdown.shutdown();
    server.join().unwrap()?;
    println!("\nall layers composed: pallas kernel -> HLO artifact -> PJRT -> platform -> HTTP");
    Ok(())
}
