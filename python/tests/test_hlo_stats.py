"""Tests for the L2 HLO analysis tool."""

import jax
import jax.numpy as jnp

from compile import aot
from compile import hlo_stats as H

jax.config.update("jax_platform_name", "cpu")


def _lower(fn, *specs):
    return aot.to_hlo_text(jax.jit(fn).lower(*specs))


def test_census_counts_known_graph():
    spec = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    text = _lower(lambda a, b: jnp.maximum(a @ b + 1.0, 0.0), spec, spec)
    census = H.op_census(text)
    assert census.get("dot", 0) == 1
    assert census.get("add", 0) >= 1
    assert census.get("maximum", 0) >= 1


def test_summarize_fields():
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = _lower(lambda a: jnp.tanh(a) * 2.0, spec)
    s = H.summarize(text)
    assert s["total_ops"] > 0
    assert s["heavy_ops"] == 0
    assert s["while_loops"] == 0


def test_conv_counted_as_heavy():
    x = jax.ShapeDtypeStruct((1, 8, 8, 3), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 3, 3, 4), jnp.float32)

    def conv(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    s = H.summarize(_lower(conv, x, w))
    assert s["heavy_ops"] == 1


def test_pallas_kernel_lowers_to_while_loop():
    """interpret-mode pallas grids become HLO while loops (the compact
    lowering the runtime relies on — not unrolled per grid cell)."""
    from compile.kernels import matmul as pk
    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    text = _lower(lambda a, b: pk.matmul_fused(a, b, bm=16, bn=16, bk=16),
                  spec, spec)
    s = H.summarize(text)
    assert s["while_loops"] >= 1
    assert s["heavy_ops"] >= 1
