"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including non-multiples of the tile sizes,
the degenerate 1x1 case, and shapes straddling block boundaries) and
dtypes; assert_allclose against ref.py is the core correctness signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import matmul as pk
from compile.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")

# Small tiles so hypothesis shapes exercise multi-block grids cheaply.
TILES = dict(bm=16, bn=16, bk=16)


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- matmul

@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    relu=st.booleans(),
    bias=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_fused_matches_ref(m, k, n, relu, bias, seed):
    r = rng(seed)
    x = r.standard_normal((m, k), dtype=np.float32)
    w = r.standard_normal((k, n), dtype=np.float32)
    b = r.standard_normal(n).astype(np.float32) if bias else None
    got = pk.matmul_fused(jnp.asarray(x), jnp.asarray(w),
                          None if b is None else jnp.asarray(b),
                          relu=relu, **TILES)
    want = kref.matmul_fused_ref(jnp.asarray(x), jnp.asarray(w),
                                 None if b is None else jnp.asarray(b),
                                 relu=relu)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [
    (16, 16, 16),      # exactly one tile
    (32, 16, 48),      # multi-tile, exact multiples
    (17, 16, 16),      # M one past a block boundary
    (16, 33, 16),      # K straddles two blocks + remainder
    (1, 1, 1),         # degenerate
    (128, 256, 64),    # larger K-loop
])
def test_matmul_block_boundaries(m, k, n):
    r = rng(m * 1000 + k * 100 + n)
    x = r.standard_normal((m, k), dtype=np.float32)
    w = r.standard_normal((k, n), dtype=np.float32)
    got = pk.matmul_fused(jnp.asarray(x), jnp.asarray(w), **TILES)
    assert_allclose(np.asarray(got), x @ w, rtol=1e-4, atol=1e-4)


def test_matmul_default_tiles_large():
    """Default 128-tiles on a shape typical of a fire-module conv."""
    r = rng(7)
    x = r.standard_normal((3025, 96), dtype=np.float32)
    w = r.standard_normal((96, 128), dtype=np.float32)
    b = r.standard_normal(128).astype(np.float32)
    got = pk.matmul_fused(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                          relu=True)
    want = np.maximum(x @ w + b, 0.0)
    assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    r = rng(11)
    x = jnp.asarray(r.standard_normal((24, 24)), dtype=dtype)
    w = jnp.asarray(r.standard_normal((24, 24)), dtype=dtype)
    got = pk.matmul_fused(x, w, **TILES)
    want = kref.matmul_fused_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    assert got.dtype == dtype
    assert_allclose(np.asarray(got, dtype=np.float32),
                    np.asarray(want, dtype=np.float32), rtol=tol, atol=tol)


def test_matmul_relu_clamps_negative():
    x = jnp.asarray([[-1.0, 2.0]], dtype=jnp.float32)
    w = jnp.asarray([[1.0], [0.0]], dtype=jnp.float32)
    out = pk.matmul_fused(x, w, relu=True, **TILES)
    assert float(out[0, 0]) == 0.0


def test_matmul_shape_errors():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 4))
    with pytest.raises(ValueError, match="contraction"):
        pk.matmul_fused(x, w)
    with pytest.raises(ValueError, match="2-D"):
        pk.matmul_fused(jnp.zeros((2, 2, 2)), w)
    with pytest.raises(ValueError, match="bias"):
        pk.matmul_fused(jnp.zeros((4, 6)), w, jnp.zeros((5,)))


def test_matmul_under_jit():
    """The kernel must lower inside jit (the AOT path does exactly this)."""
    r = rng(3)
    x = r.standard_normal((20, 36), dtype=np.float32)
    w = r.standard_normal((36, 12), dtype=np.float32)
    f = jax.jit(lambda a, b: pk.matmul_fused(a, b, **TILES))
    assert_allclose(np.asarray(f(x, w)), x @ w, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- conv1x1

@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(1, 14),
    w=st.integers(1, 14),
    cin=st.integers(1, 40),
    cout=st.integers(1, 40),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv1x1_matches_lax_conv(h, w, cin, cout, relu, seed):
    r = rng(seed)
    x = r.standard_normal((1, h, w, cin), dtype=np.float32)
    wt = r.standard_normal((cin, cout), dtype=np.float32)
    b = r.standard_normal(cout).astype(np.float32)
    got = pk.conv1x1(jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b),
                     relu=relu, **TILES)
    want = kref.conv1x1_ref(jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b),
                            relu=relu)
    assert got.shape == (1, h, w, cout)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_conv1x1_requires_nhwc():
    with pytest.raises(ValueError, match="NHWC"):
        pk.conv1x1(jnp.zeros((4, 4)), jnp.zeros((4, 4)))
    with pytest.raises(ValueError, match="channel"):
        pk.conv1x1(jnp.zeros((1, 2, 2, 3)), jnp.zeros((4, 5)))


# ---------------------------------------------------------------- softmax

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    n=st.integers(1, 1200),
    scale=st.floats(0.1, 100.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_matches_ref(b, n, scale, seed):
    r = rng(seed)
    x = (r.standard_normal((b, n)) * scale).astype(np.float32)
    got = pk.softmax(jnp.asarray(x))
    want = kref.softmax_ref(jnp.asarray(x))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(got).sum(axis=-1), 1.0, rtol=1e-5)


def test_softmax_stable_at_large_logits():
    x = jnp.asarray([[1e4, 1e4 - 1.0]], dtype=jnp.float32)
    out = np.asarray(pk.softmax(x))
    assert np.isfinite(out).all()
    assert_allclose(out.sum(), 1.0, rtol=1e-6)


def test_softmax_rejects_non_2d():
    with pytest.raises(ValueError, match="2-D"):
        pk.softmax(jnp.zeros((3,)))


# --------------------------------------------------- perf-model helpers

def test_vmem_footprint_default_tiles_fit_budget():
    # 128^2 f32 tiles: x + w + o + bias = 192.5 KiB/step; x2 for
    # double-buffering still well under the 16 MiB VMEM budget.
    fp = pk.vmem_footprint_bytes(128, 128, 128)
    assert fp == (128 * 128 * 3 + 128) * 4
    assert 2 * fp < 16 * 1024 * 1024


def test_mxu_utilization_estimates():
    # Exact multiples of 128 at full MXU edge -> utilization 1.0.
    assert pk.mxu_utilization_estimate(256, 256, 256, 128, 128, 128) == 1.0
    # Padding waste reduces utilization.
    u = pk.mxu_utilization_estimate(129, 128, 128, 128, 128, 128)
    assert 0.4 < u < 0.6
    # Narrow tiles leave MXU lanes idle.
    u2 = pk.mxu_utilization_estimate(128, 128, 128, 32, 128, 128)
    assert abs(u2 - 0.25) < 1e-9
