"""Tests for the L1 analytic roofline tool."""

import pytest

from compile import roofline as R


def test_squeezenet_sites_match_architecture():
    sites = R.matmul_sites("squeezenet")
    names = [s[0] for s in sites]
    # 8 fire modules x (squeeze + expand1) + conv10 = 17 1x1 convs.
    assert len(sites) == 17
    assert "fire2.squeeze" in names
    assert "conv10" in names
    # fire2.squeeze at 224px: after conv1 s2 + pool3 s2 VALID -> 55x55.
    site = dict((s[0], s) for s in sites)["fire2.squeeze"]
    assert site[1:] == (55 * 55, 96, 16)


def test_resnet_classifier_site():
    sites = R.matmul_sites("resnet18")
    names = [s[0] for s in sites]
    assert "fc" in names
    fc = [s for s in sites if s[0] == "fc"][0]
    assert fc[1:] == (1, 512, 1000)


def test_resnext_has_many_pointwise_sites():
    sites = R.matmul_sites("resnext50")
    # 16 bottlenecks x (reduce + expand) + downsamples are strided or
    # recorded only when stride 1 ... at least 32 sites + fc.
    assert len(sites) >= 33


def test_analyze_fields_and_ranges():
    rows = R.analyze("squeezenet", 128, 128, 128)
    assert len(rows) == 17
    for r in rows:
        assert 0.0 < r["mxu_util"] <= 1.0
        assert 0.0 < r["roofline_frac"] <= 1.0
        assert r["vmem_per_step"] > 0
        assert r["vmem_frac_2buf"] < 0.1, "tiles well under VMEM"


def test_summarize_weighted_util_reasonable():
    s = R.summarize(R.analyze("squeezenet", 128, 128, 128))
    # The §Perf claim: >= 0.55 FLOP-weighted MXU utilization at 128^3
    # with kernel-mirrored tile shrinking (squeeze layers have K=16..96).
    assert s["flops_weighted_mxu_util"] >= 0.55, s
    assert s["max_vmem_frac"] < 0.1


def test_resnext_kernel_dominates_and_utilizes():
    # ResNeXt's 1x1 reduce/expand convs carry most FLOPs: the Pallas
    # kernel serves >= 7 GFLOPs at >= 0.75 estimated MXU utilization.
    s = R.summarize(R.analyze("resnext50", 128, 128, 128))
    assert s["kernel_gflops"] > 6.0
    assert s["flops_weighted_mxu_util"] >= 0.75, s
    assert s["flops_weighted_roofline"] >= 0.9


def test_small_tiles_hurt_utilization():
    big = R.summarize(R.analyze("squeezenet", 128, 128, 128))
    small = R.summarize(R.analyze("squeezenet", 32, 32, 32))
    assert small["flops_weighted_mxu_util"] < big["flops_weighted_mxu_util"]


def test_spec_walk_does_not_leak_patches():
    from compile import layers as L
    before = L.conv2d
    R.matmul_sites("squeezenet")
    assert L.conv2d is before, "monkeypatch restored"
