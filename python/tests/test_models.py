"""L2 model correctness: shapes, determinism, paper-size match, and
pallas-vs-ref agreement for every zoo entry (at reduced resolution so
the suite stays fast; parameter counts are resolution-independent).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

H = 64  # reduced test resolution; param counts don't depend on it


@pytest.fixture(scope="module")
def params_cache():
    cache = {}

    def get(name):
        if name not in cache:
            cache[name] = M.materialize_params(name, H, H)
        return cache[name]

    return get


def test_zoo_contents():
    assert set(M.ZOO) == {"squeezenet", "resnet18", "resnext50"}


@pytest.mark.parametrize("name", list(M.ZOO))
def test_param_sizes_match_paper(name):
    """Param bytes must land within 10% of the paper's model sizes
    (5 / 45 / 98 MB) — the architecture reproduction signal."""
    spec = M.param_spec(name)
    mb = spec.size_bytes() / 1e6
    paper = M.ZOO[name].paper_size_mb
    assert abs(mb - paper) / paper < 0.10, (name, mb, paper)


@pytest.mark.parametrize("name,count", [
    ("squeezenet", 52), ("resnet18", 42), ("resnext50", 108)])
def test_param_counts_stable(name, count):
    assert M.param_spec(name).count == count


@pytest.mark.parametrize("name", list(M.ZOO))
def test_flops_positive_and_ordered(name):
    f = M.flops(name, H, H)
    assert f > 0


def test_flops_ordering_at_224():
    f = {n: M.flops(n) for n in M.ZOO}
    assert f["squeezenet"] < f["resnet18"] < f["resnext50"]


@pytest.mark.parametrize("name", list(M.ZOO))
def test_init_matches_spec(name, params_cache):
    params = params_cache(name)
    spec = M.param_spec(name, H, H)
    assert len(params) == spec.count
    for p, s in zip(params, spec.shapes):
        assert p.shape == s
        assert p.dtype == jnp.float32


@pytest.mark.parametrize("name", list(M.ZOO))
def test_init_flat_has_total_elements(name):
    flat = jax.jit(M.make_init(name, H, H))()
    assert flat.shape == (M.param_spec(name, H, H).num_elements(),)
    assert flat.dtype == jnp.float32


def test_init_applies_he_scaling():
    """First squeezenet param is conv1.w (7x7x3 fan-in 147): its std
    must be ~sqrt(2/147), far from the unit-normal draw."""
    params = M.materialize_params("squeezenet", H, H)
    import numpy as np
    std = float(np.asarray(params[0]).std())
    expect = (2.0 / 147.0) ** 0.5
    assert abs(std - expect) / expect < 0.05, (std, expect)


@pytest.mark.parametrize("name", list(M.ZOO))
def test_init_deterministic(name, params_cache):
    a = params_cache(name)
    b = M.materialize_params(name, H, H)
    for x, y in zip(a, b):
        assert_allclose(np.asarray(x), np.asarray(y), rtol=0, atol=0)


@pytest.mark.parametrize("name", list(M.ZOO))
def test_infer_output_contract(name, params_cache):
    """infer -> probs[1,1000] summing to 1 (argmax happens in Rust)."""
    params = params_cache(name)
    img = np.random.default_rng(0).random((1, H, H, 3), dtype=np.float32)
    probs = jax.jit(M.make_infer(name, H, H))(*params, img)
    assert probs.shape == (1, M.NUM_CLASSES)
    assert probs.dtype == jnp.float32
    assert_allclose(float(probs.sum()), 1.0, rtol=1e-4)
    assert (np.asarray(probs) >= 0).all()


@pytest.mark.parametrize("name", list(M.ZOO))
def test_pallas_and_ref_variants_agree(name, params_cache):
    """End-to-end L1-in-L2 signal: the full model with Pallas kernels
    must match the same model on the pure-jnp path."""
    params = params_cache(name)
    img = np.random.default_rng(1).random((1, H, H, 3), dtype=np.float32)
    p_pallas = jax.jit(
        M.make_infer(name, H, H, use_pallas=True))(*params, img)
    p_ref = jax.jit(
        M.make_infer(name, H, H, use_pallas=False))(*params, img)
    assert_allclose(np.asarray(p_pallas), np.asarray(p_ref),
                    rtol=1e-3, atol=1e-5)
    assert int(np.asarray(p_pallas).argmax()) == int(np.asarray(p_ref).argmax())


@pytest.mark.parametrize("name", list(M.ZOO))
def test_infer_depends_on_image(name, params_cache):
    params = params_cache(name)
    r = np.random.default_rng(2)
    f = jax.jit(M.make_infer(name, H, H))
    p1 = f(*params, r.random((1, H, H, 3), dtype=np.float32))
    p2 = f(*params, r.random((1, H, H, 3), dtype=np.float32))
    assert float(np.abs(np.asarray(p1) - np.asarray(p2)).max()) > 0
