"""Layer-2 building-block tests: Ctx bookkeeping, conv dispatch,
spec-pass shape algebra vs real execution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import layers as L

jax.config.update("jax_platform_name", "cpu")


def _params_from_spec(spec, seed=0):
    """Generate per-param arrays the way the init artifact does."""
    r = np.random.default_rng(seed)
    return [jnp.asarray(r.standard_normal(s, dtype=np.float32) * std)
            for s, std in zip(spec.shapes, spec.stds)]


def _spec_ctx(build):
    ctx = L.Ctx("spec")
    build(ctx)
    return ctx


def _apply_ctx(params, use_pallas=True):
    return L.Ctx("apply", params=params, use_pallas=use_pallas)


def test_ctx_rejects_bad_mode():
    with pytest.raises(AssertionError):
        L.Ctx("train")


def test_spec_pass_records_params_without_compute():
    ctx = L.Ctx("spec")
    x = L._SpecTensor((1, 8, 8, 3))
    out = L.conv2d(ctx, "c", x, 3, 16, 3)
    assert isinstance(out, L._SpecTensor)
    assert out.shape == (1, 8, 8, 16)
    assert ctx.spec.names == ["c.w", "c.b"]
    assert ctx.spec.shapes == [(3, 3, 3, 16), (16,)]
    assert ctx.flops == 2 * 8 * 8 * 16 * 27


def test_spec_records_he_std():
    ctx = L.Ctx("spec")
    L.conv2d(ctx, "c", L._SpecTensor((1, 8, 8, 3)), 3, 4, 3)
    # weight std = sqrt(2/27), bias std = 0.1
    assert abs(ctx.spec.stds[0] - (2.0 / 27.0) ** 0.5) < 1e-9
    assert ctx.spec.stds[1] == 0.1
    ctx2 = L.Ctx("spec")
    L.conv2d(ctx2, "c", L._SpecTensor((1, 8, 8, 3)), 3, 4, 3, std_scale=0.2)
    assert abs(ctx2.spec.stds[0] - 0.2 * (2.0 / 27.0) ** 0.5) < 1e-9


def test_apply_consumes_params_in_order():
    ctx = _spec_ctx(lambda c: (
        L.conv2d(c, "a", L._SpecTensor((1, 4, 4, 3)), 3, 4, 3),
        L.conv2d(c, "b", L._SpecTensor((1, 4, 4, 4)), 4, 2, 1)))
    params = _params_from_spec(ctx.spec)

    actx = _apply_ctx(params)
    x = jnp.ones((1, 4, 4, 3))
    y = L.conv2d(actx, "a", x, 3, 4, 3)
    z = L.conv2d(actx, "b", y, 4, 2, 1)
    assert z.shape == (1, 4, 4, 2)
    assert actx.cursor == 4


def test_apply_asserts_on_shape_mismatch():
    actx = _apply_ctx([jnp.zeros((3, 3, 3, 4)), jnp.zeros((4,))])
    with pytest.raises(AssertionError):
        L.conv2d(actx, "c", jnp.ones((1, 4, 4, 3)), 3, 5, 3)


@pytest.mark.parametrize("ksize,stride,padding", [
    (3, 1, "SAME"), (3, 2, "SAME"), (7, 2, "SAME"), (1, 1, "SAME"),
    (3, 2, "VALID")])
def test_spec_conv_shape_matches_real(ksize, stride, padding):
    ctx = L.Ctx("spec")
    spec_out = L.conv2d(ctx, "c", L._SpecTensor((1, 13, 13, 3)), 3, 5,
                        ksize, stride=stride, padding=padding)
    actx = _apply_ctx(_params_from_spec(ctx.spec))
    real_out = L.conv2d(actx, "c", jnp.ones((1, 13, 13, 3)), 3, 5, ksize,
                        stride=stride, padding=padding)
    assert spec_out.shape == real_out.shape


@pytest.mark.parametrize("ksize,stride,padding", [
    (3, 2, "VALID"), (3, 2, "SAME"), (2, 2, "VALID")])
def test_spec_pool_shape_matches_real(ksize, stride, padding):
    spec_out = L.maxpool(L.Ctx("spec"), L._SpecTensor((1, 13, 13, 3)),
                         ksize, stride, padding)
    real_out = L.maxpool(_apply_ctx([]), jnp.ones((1, 13, 13, 3)),
                         ksize, stride, padding)
    assert spec_out.shape == real_out.shape


def test_conv1x1_dispatch_equals_lax_path():
    """The pallas 1x1 fast path and the generic lax path must agree."""
    ctx = _spec_ctx(lambda c: L.conv2d(c, "c", L._SpecTensor((1, 6, 6, 8)), 8, 12, 1))
    params = _params_from_spec(ctx.spec)
    x = jnp.asarray(np.random.default_rng(0).random((1, 6, 6, 8),
                                                    dtype=np.float32))
    got = L.conv2d(_apply_ctx(params, use_pallas=True), "c", x, 8, 12, 1)
    want = L.conv2d(_apply_ctx(params, use_pallas=False), "c", x, 8, 12, 1)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_grouped_conv_param_shape():
    ctx = L.Ctx("spec")
    L.conv2d(ctx, "g", L._SpecTensor((1, 8, 8, 32)), 32, 32, 3, groups=8)
    assert ctx.spec.shapes[0] == (3, 3, 4, 32)


def test_global_avgpool():
    x = jnp.arange(2 * 3 * 3 * 4, dtype=jnp.float32).reshape(2, 3, 3, 4)
    out = L.global_avgpool(_apply_ctx([]), x)
    assert out.shape == (2, 4)
    assert_allclose(np.asarray(out), np.asarray(x.mean(axis=(1, 2))))


def test_add_relu():
    a = jnp.asarray([[-2.0, 1.0]])
    b = jnp.asarray([[1.0, 1.0]])
    out = L.add_relu(_apply_ctx([]), a, b)
    assert_allclose(np.asarray(out), [[0.0, 2.0]])


def test_add_relu_spec_asserts_shape_match():
    with pytest.raises(AssertionError):
        L.add_relu(L.Ctx("spec"), L._SpecTensor((1, 2)), L._SpecTensor((1, 3)))


def test_classifier_sums_to_one():
    ctx = _spec_ctx(lambda c: L.classifier(c, "fc", L._SpecTensor((1, 16)), 16, 10))
    probs = L.classifier(_apply_ctx(_params_from_spec(ctx.spec)), "fc",
                         jnp.ones((1, 16)), 16, 10)
    assert probs.shape == (1, 10)
    assert_allclose(float(probs.sum()), 1.0, rtol=1e-5)


def test_param_spec_bookkeeping():
    spec = L.ParamSpec()
    spec.add("a", (2, 3), 0.5)
    spec.add("b", (4,))
    assert spec.count == 2
    assert spec.num_elements() == 10
    assert spec.size_bytes() == 40
    assert spec.stds == [0.5, 1.0]
    assert spec.to_json() == [{"name": "a", "shape": [2, 3]},
                              {"name": "b", "shape": [4]}]
