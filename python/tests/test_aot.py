"""AOT bridge tests: HLO-text emission, manifest consistency, and an
in-python round-trip (compile the emitted XlaComputation text back
through the jax CPU client where possible).

Full cross-language round-trip (rust loads the artifacts) is covered by
``rust/tests/`` — these tests pin the python half of the contract.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

H = 64  # tiny build keeps the suite fast


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    index = aot.build(out, ["squeezenet"], H, H, variant="both",
                      verbose=False)
    return out, index


def test_emits_all_files(built):
    out, _ = built
    names = set(os.listdir(out))
    assert {"squeezenet_init.hlo.txt", "squeezenet_infer.hlo.txt",
            "squeezenet_ref_init.hlo.txt", "squeezenet_ref_infer.hlo.txt",
            "squeezenet.json", "zoo.json"} <= names


def test_hlo_text_parses_as_hlo_module(built):
    out, _ = built
    for f in ("squeezenet_init.hlo.txt", "squeezenet_infer.hlo.txt"):
        text = open(os.path.join(out, f)).read()
        assert text.startswith("HloModule"), f
        assert "ENTRY" in text, f


def test_infer_hlo_has_param_count_plus_image(built):
    out, _ = built
    text = open(os.path.join(out, "squeezenet_infer.hlo.txt")).read()
    spec = M.param_spec("squeezenet", H, H)
    # HLO entry params: param_0..param_{P-1} then the image.
    entry = text[text.index("ENTRY"):]
    header = entry[:entry.index("\n")]
    assert header.count("parameter(") == 0  # params listed in body
    n_params = entry.count(" parameter(")
    assert n_params == spec.count + 1


def test_manifest_consistency(built):
    out, index = built
    man = json.load(open(os.path.join(out, "squeezenet.json")))
    spec = M.param_spec("squeezenet", H, H)
    assert man["param_count"] == spec.count
    assert man["param_elements"] == spec.num_elements()
    assert man["param_bytes"] == spec.size_bytes()
    assert man["input_shape"] == [1, H, H, 3]
    assert man["num_classes"] == 1000
    assert man["paper_peak_mem_mb"] == 85
    assert [tuple(p["shape"]) for p in man["params"]] == list(spec.shapes)
    assert man["artifacts"]["pallas"]["infer"] == "squeezenet_infer.hlo.txt"
    # zoo index mirrors the per-model manifest
    zoo = json.load(open(os.path.join(out, "zoo.json")))
    assert zoo["height"] == H and zoo["seed"] == M.SEED
    assert zoo["models"][0]["name"] == "squeezenet"


def test_build_rejects_unknown_model(tmp_path):
    with pytest.raises(KeyError):
        aot.build(str(tmp_path), ["vgg16"], H, H, variant="pallas",
                  verbose=False)


def test_init_hlo_is_rng_only(built):
    """The init artifact must not contain the forward pass (no conv,
    no dot beyond RNG plumbing) — cold-start cost attribution depends
    on this separation."""
    out, _ = built
    text = open(os.path.join(out, "squeezenet_init.hlo.txt")).read()
    assert "convolution" not in text


def test_infer_hlo_contains_convolutions(built):
    out, _ = built
    text = open(os.path.join(out, "squeezenet_infer.hlo.txt")).read()
    assert "convolution" in text  # 3x3/7x7 convs on the native path


def test_hlo_text_parses_back(built):
    """The emitted text must re-parse as an HloModule — the same parser
    entry point (`HloModuleProto::from_text_file`) the rust runtime
    uses.  Full execute-and-compare lives in rust/tests/."""
    out, _ = built
    from jax._src.lib import xla_client as xc
    for f in ("squeezenet_init.hlo.txt", "squeezenet_infer.hlo.txt"):
        text = open(os.path.join(out, f)).read()
        mod = xc._xla.hlo_module_from_text(text)
        proto = mod.as_serialized_hlo_module_proto()
        assert len(proto) > 0


def test_artifact_shapes_in_entry_signature(built):
    """Entry computation signature must carry the manifest's image shape
    and the (probs, top1) result tuple."""
    out, _ = built
    text = open(os.path.join(out, "squeezenet_infer.hlo.txt")).read()
    entry = text[text.index("ENTRY"):]
    assert f"f32[1,{H},{H},3]" in entry
    assert "f32[1,1000]" in entry
