"""L1 analytic roofline: VMEM footprint + MXU utilization per layer.

interpret=True wallclock is CPU-numpy time, not a TPU proxy, so block
shapes for the Pallas matmul are chosen analytically (DESIGN.md
§Hardware-Adaptation, EXPERIMENTS.md §Perf). This tool walks every
1x1-conv / classifier matmul in a zoo model, evaluates candidate tile
shapes, and reports estimated MXU utilization, VMEM per grid step, and
the arithmetic-intensity-limited roofline fraction.

Usage::

    python -m compile.roofline [model] [--tiles 128,128,128]
"""

from __future__ import annotations

import argparse
from typing import List, Tuple

from compile import layers as L
from compile import model as M
from compile.kernels import matmul as pk

# TPUv4-class reference constants (the translation target for the
# paper's CPU numbers; see DESIGN.md §Hardware-Adaptation).
VMEM_BYTES = 16 * 1024 * 1024
HBM_BW = 1.2e12  # bytes/s
MXU_FLOPS = 2 * 128 * 128 * 940e6  # one MXU pass/cycle at ~940 MHz


def matmul_sites(name: str, height: int = 224,
                 width: int = 224) -> List[Tuple[str, int, int, int]]:
    """Every (site, M, K, N) the Pallas kernel serves in `name`'s graph:
    1x1 stride-1 convs as (N*H*W, Cin, Cout) plus the classifier."""
    info = M.ZOO[name]

    sites: List[Tuple[str, int, int, int]] = []

    class Probe(L.Ctx):
        def param(self, pname, shape, fan_in, std_scale=1.0):
            return super().param(pname, shape, fan_in, std_scale)

    ctx = Probe("spec")

    # Wrap conv2d/classifier to record matmul shapes during the spec walk.
    orig_conv2d = L.conv2d
    orig_classifier = L.classifier

    def conv2d_probe(c, cname, x, cin, cout, ksize, stride=1, padding="SAME",
                     relu=True, groups=1, std_scale=1.0):
        if ksize == 1 and stride == 1 and groups == 1 and c is ctx:
            n, h, w, _ = x.shape
            sites.append((cname, n * h * w, cin, cout))
        return orig_conv2d(c, cname, x, cin, cout, ksize, stride=stride,
                           padding=padding, relu=relu, groups=groups,
                           std_scale=std_scale)

    def classifier_probe(c, cname, x, cin, nclasses):
        if c is ctx:
            sites.append((cname, x.shape[0], cin, nclasses))
        return orig_classifier(c, cname, x, cin, nclasses)

    L.conv2d = conv2d_probe
    L.classifier = classifier_probe
    try:
        info.fn(ctx, L._SpecTensor((1, height, width, 3)))
    finally:
        L.conv2d = orig_conv2d
        L.classifier = orig_classifier
    return sites


def analyze(name: str, bm: int, bn: int, bk: int,
            height: int = 224, width: int = 224) -> List[dict]:
    """Per-site analytics for one tile configuration."""
    rows = []
    for site, m, k, n in matmul_sites(name, height, width):
        # Mirror the kernel's tile-shrinking for small problems
        # (matmul_fused clamps each tile to the rounded problem dim).
        bm_e = min(bm, pk._round_up(m, 8))
        bn_e = min(bn, pk._round_up(n, 8))
        bk_e = min(bk, pk._round_up(k, 8))
        util = pk.mxu_utilization_estimate(m, n, k, bm_e, bn_e, bk_e)
        vmem = pk.vmem_footprint_bytes(bm_e, bn_e, bk_e)
        flops = 2 * m * k * n
        bytes_moved = 4 * (m * k + k * n + m * n)
        intensity = flops / bytes_moved
        # Roofline: fraction of MXU peak reachable given HBM bandwidth.
        roof = min(1.0, intensity * HBM_BW / MXU_FLOPS)
        rows.append({
            "site": site,
            "mkn": (m, k, n),
            "mxu_util": util,
            "vmem_per_step": vmem,
            "vmem_frac_2buf": 2 * vmem / VMEM_BYTES,
            "intensity": intensity,
            "roofline_frac": roof,
            "flops": flops,
        })
    return rows


def summarize(rows: List[dict]) -> dict:
    total = sum(r["flops"] for r in rows) or 1
    wutil = sum(r["mxu_util"] * r["flops"] for r in rows) / total
    wroof = sum(r["roofline_frac"] * r["flops"] for r in rows) / total
    return {
        "sites": len(rows),
        "kernel_gflops": total / 1e9,
        "flops_weighted_mxu_util": wutil,
        "flops_weighted_roofline": wroof,
        "max_vmem_frac": max((r["vmem_frac_2buf"] for r in rows), default=0.0),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("model", nargs="?", default="squeezenet")
    ap.add_argument("--tiles", default="128,128,128")
    ap.add_argument("--height", type=int, default=224)
    args = ap.parse_args()
    bm, bn, bk = (int(x) for x in args.tiles.split(","))
    rows = analyze(args.model, bm, bn, bk, args.height, args.height)
    print(f"{args.model} @ {args.height}px, tiles {bm}x{bn}x{bk}")
    print(f"{'site':18} {'M,K,N':>20} {'MXUutil':>8} {'VMEM/step':>10} {'roofline':>9}")
    for r in rows:
        m, k, n = r["mkn"]
        print(f"{r['site']:18} {f'{m},{k},{n}':>20} {r['mxu_util']:8.2f} "
              f"{r['vmem_per_step']/1024:8.1f}Ki {r['roofline_frac']:9.2f}")
    s = summarize(rows)
    print(f"\nFLOP-weighted MXU utilization: {s['flops_weighted_mxu_util']:.2f}")
    print(f"FLOP-weighted roofline fraction: {s['flops_weighted_roofline']:.2f}")
    print(f"peak VMEM (2x buffered): {s['max_vmem_frac']*100:.1f}% of 16 MiB")
    print(f"kernel GFLOPs: {s['kernel_gflops']:.2f} over {s['sites']} sites")


if __name__ == "__main__":
    main()
