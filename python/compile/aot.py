"""AOT bridge: lower the model zoo to HLO-text artifacts for Rust.

Interchange format is HLO **text**, not serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.  Both entry points
are single-array-valued and lowered with ``return_tuple=False`` — the
0.5.1 C API segfaults converting tuple buffers to literals, so the
calling convention avoids tuples entirely (see compile.model).

Per model this emits into ``--out-dir`` (default ``artifacts/``):

* ``<name>_init.hlo.txt``  — ``init() -> (params...)``
* ``<name>_infer.hlo.txt`` — ``infer(params..., image) -> (probs, top1)``
* ``<name>.json``          — manifest: shapes, param spec, FLOPs,
  paper-reported size / peak memory (used by the platform's
  deployability floor and the billing model).

plus a ``zoo.json`` index.  Python never runs after this; the Rust
binary is self-contained once artifacts exist.

Usage::

    python -m compile.aot --out-dir ../artifacts [--height 224]
                          [--models squeezenet,resnet18,resnext50]
                          [--variant pallas|ref|both]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text()


def lower_model(name: str, height: int, width: int, use_pallas: bool):
    """Lower init + infer for one zoo entry; returns (init_txt, infer_txt)."""
    init = M.make_init(name, height, width)
    infer = M.make_infer(name, height, width, use_pallas=use_pallas)
    pspec = M.param_spec(name, height, width)

    init_lowered = jax.jit(init).lower()
    arg_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in pspec.shapes]
    arg_specs.append(jax.ShapeDtypeStruct((1, height, width, 3), jnp.float32))
    infer_lowered = jax.jit(infer).lower(*arg_specs)
    return to_hlo_text(init_lowered), to_hlo_text(infer_lowered)


def build(out_dir: str, models, height: int, width: int, variant: str,
          verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    index = {"height": height, "width": width, "seed": M.SEED, "models": []}
    for name in models:
        info = M.ZOO[name]
        ctx = M.spec(name, height, width)
        entry = {
            "name": name,
            "input_shape": [1, height, width, 3],
            "num_classes": M.NUM_CLASSES,
            "param_count": ctx.spec.count,
            "param_elements": ctx.spec.num_elements(),
            "param_bytes": ctx.spec.size_bytes(),
            "flops": ctx.flops,
            "paper_size_mb": info.paper_size_mb,
            "paper_peak_mem_mb": info.paper_peak_mem_mb,
            "params": ctx.spec.to_json(),
            "artifacts": {},
        }
        variants = ["pallas", "ref"] if variant == "both" else [variant]
        for var in variants:
            t0 = time.time()
            init_txt, infer_txt = lower_model(name, height, width,
                                              use_pallas=(var == "pallas"))
            suffix = "" if var == "pallas" else "_ref"
            init_path = f"{name}{suffix}_init.hlo.txt"
            infer_path = f"{name}{suffix}_infer.hlo.txt"
            with open(os.path.join(out_dir, init_path), "w") as f:
                f.write(init_txt)
            with open(os.path.join(out_dir, infer_path), "w") as f:
                f.write(infer_txt)
            entry["artifacts"][var] = {"init": init_path, "infer": infer_path}
            if verbose:
                print(f"[aot] {name}/{var}: init={len(init_txt)/1e3:.0f}kB "
                      f"infer={len(infer_txt)/1e3:.0f}kB "
                      f"({time.time()-t0:.1f}s)")
        with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
            json.dump(entry, f, indent=2)
        index["models"].append(entry)
    with open(os.path.join(out_dir, "zoo.json"), "w") as f:
        json.dump(index, f, indent=2)
    return index


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(M.ZOO))
    ap.add_argument("--height", type=int, default=224)
    ap.add_argument("--width", type=int, default=0,
                    help="defaults to --height")
    ap.add_argument("--variant", choices=["pallas", "ref", "both"],
                    default="both")
    args = ap.parse_args()
    width = args.width or args.height
    models = [m.strip() for m in args.models.split(",") if m.strip()]
    for m in models:
        if m not in M.ZOO:
            raise SystemExit(f"unknown model {m!r}; zoo: {list(M.ZOO)}")
    build(args.out_dir, models, args.height, width, args.variant)


if __name__ == "__main__":
    main()
