"""Layer-2 model zoo: name -> (init, infer, manifest metadata).

Each model is AOT-lowered to two HLO-text artifacts:

* ``<name>_init.hlo.txt`` — ``init() -> flat f32[N]``: one seeded RNG
  draw scaled per-parameter (He std for weights, 0.1 for folded-BN
  biases) and concatenated in ParamSpec order.  Run once per cold start
  by the Rust runtime, which slices it into per-parameter device
  buffers that stay resident while the container is warm (this *is*
  the "model load" the paper pays at every cold start).  A single flat
  output (instead of a 50+-element tuple) keeps the RNG graph small —
  one threefry instead of one per parameter — and avoids XLA tuple
  literals, which the xla_extension 0.5.1 C API cannot convert.
* ``<name>_infer.hlo.txt`` — ``infer(param_0, ..., param_{P-1}, image)
  -> probs[1, 1000]``: the forward pass, batch 1 (argmax in Rust).

The paper served pretrained MXNet checkpoints; this study is about
*performance*, which is architecture-determined (FLOPs, parameter
bytes), so seeded random weights preserve every relevant behaviour —
see DESIGN.md §Substitutions.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from compile import layers as L
from compile.models import resnet18, resnext50_32x4d, squeezenet_v10

SEED = 20171001  # deterministic across builds; rust tests pin outputs
NUM_CLASSES = 1000


@dataclasses.dataclass(frozen=True)
class ModelInfo:
    """Static metadata for one zoo entry (mirrored into the manifest)."""

    name: str
    fn: Callable
    # Paper-reported numbers (Ishakian et al. §3): model file size and
    # the measured peak memory of the Lambda function. The platform uses
    # peak_mem_mb as the deployability floor, reproducing the missing
    # small-memory data points in Figs 2-6.
    paper_size_mb: float
    paper_peak_mem_mb: int


ZOO: Dict[str, ModelInfo] = {
    "squeezenet": ModelInfo("squeezenet", squeezenet_v10, 5.0, 85),
    "resnet18": ModelInfo("resnet18", resnet18, 45.0, 229),
    "resnext50": ModelInfo("resnext50", resnext50_32x4d, 98.0, 429),
}


def spec(name: str, height: int = 224, width: int = 224) -> L.Ctx:
    """Shape/FLOP pass: returns the Ctx with ParamSpec + FLOP ledger."""
    info = ZOO[name]
    ctx = L.Ctx("spec")
    image = L._SpecTensor((1, height, width, 3))
    out = info.fn(ctx, image)
    assert out.shape == (1, NUM_CLASSES), out.shape
    return ctx


def make_init(name: str, height: int = 224, width: int = 224) -> Callable:
    """Returns ``init() -> flat f32[N]`` (jit-able, deterministic)."""
    pspec = param_spec(name, height, width)
    total = pspec.num_elements()

    def init():
        flat = jax.random.normal(jax.random.PRNGKey(SEED), (total,),
                                 dtype=jnp.float32)
        parts = []
        off = 0
        for shape, std in zip(pspec.shapes, pspec.stds):
            n = 1
            for d in shape:
                n *= d
            parts.append(flat[off:off + n] * std)
            off += n
        return jnp.concatenate(parts)

    return init


def materialize_params(name: str, height: int = 224,
                       width: int = 224) -> List[jax.Array]:
    """Host-side equivalent of what the Rust runtime does with the init
    artifact's flat output: slice + reshape into per-param arrays."""
    pspec = param_spec(name, height, width)
    flat = jax.jit(make_init(name, height, width))()
    out = []
    off = 0
    for shape in pspec.shapes:
        n = 1
        for d in shape:
            n *= d
        out.append(flat[off:off + n].reshape(shape))
        off += n
    return out


def make_infer(name: str, height: int = 224, width: int = 224,
               use_pallas: bool = True) -> Callable:
    """Returns ``infer(*params, image) -> probs`` (argmax in Rust)."""
    info = ZOO[name]

    def infer(*args):
        params, image = list(args[:-1]), args[-1]
        ctx = L.Ctx("apply", params=params, use_pallas=use_pallas)
        return info.fn(ctx, image)

    return infer


def flops(name: str, height: int = 224, width: int = 224) -> int:
    return spec(name, height, width).flops


def param_spec(name: str, height: int = 224, width: int = 224) -> L.ParamSpec:
    return spec(name, height, width).spec
