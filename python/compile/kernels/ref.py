"""Pure-jnp oracles for the Pallas kernels.

Every kernel in :mod:`compile.kernels.matmul` has a reference here with
an identical signature (minus tiling knobs).  ``python/tests`` sweeps
shapes/dtypes with hypothesis and asserts ``allclose`` between the two —
this is the core L1 correctness signal.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul_fused_ref(x: jax.Array, w: jax.Array,
                     b: Optional[jax.Array] = None, *,
                     relu: bool = False) -> jax.Array:
    out = jnp.dot(x, w, preferred_element_type=x.dtype)
    if b is not None:
        out = out + b
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def conv1x1_ref(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                *, relu: bool = False) -> jax.Array:
    """1x1 conv oracle via lax.conv_general_dilated (independent path)."""
    # (Cin, Cout) -> HWIO
    w4 = w[None, None, :, :]
    out = jax.lax.conv_general_dilated(
        x, w4, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        out = out + b
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def softmax_ref(x: jax.Array) -> jax.Array:
    return jax.nn.softmax(x, axis=-1)
