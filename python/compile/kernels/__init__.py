"""L1: Pallas kernels for the inference hot-spots + jnp oracles."""

from compile.kernels.matmul import (  # noqa: F401
    conv1x1,
    matmul_fused,
    mxu_utilization_estimate,
    softmax,
    vmem_footprint_bytes,
)
