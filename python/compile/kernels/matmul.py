"""Layer-1 Pallas kernels: tiled fused matmul (+bias, +ReLU) and softmax.

These are the inference hot-spots of the three paper models:

* every 1x1 convolution (the dominant FLOP class in SqueezeNet fire
  modules and ResNeXt bottlenecks) is lowered to a ``(N*H*W, Cin) x
  (Cin, Cout)`` matmul and dispatched to :func:`matmul_fused`;
* the classifier head (global-pool -> 1000-way linear -> softmax) uses
  :func:`matmul_fused` + :func:`softmax`.

The matmul kernel is blocked for the TPU memory hierarchy: ``(bm, bk)``
x ``(bk, bn)`` VMEM tiles streamed over a 3-D grid ``(M/bm, N/bn,
K/bk)`` with an accumulator initialised on the first K-step.  On this
CPU-only image the kernels MUST run with ``interpret=True`` (real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot
execute); interpret mode lowers the same grid to plain HLO loops so the
AOT artifact runs anywhere.  See DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile sizes.  128 is the MXU systolic-array edge; a
# (128, 128) f32 tile is 64 KiB, so x/w/o tiles plus double-buffering fit
# comfortably in the ~16 MiB VMEM budget (see EXPERIMENTS.md §Perf for
# the footprint table).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128

# interpret=True is mandatory on CPU-only images; kept as a module flag
# so a TPU build can flip it in one place.
INTERPRET = True


def _matmul_kernel(x_ref, w_ref, o_ref, *, nsteps_k: int, has_bias: bool,
                   relu: bool, b_ref=None):
    """One (bm, bn) output tile; accumulates over the K grid axis."""
    # Dummy use keeps signature uniform; b_ref handled in fused kernel.
    del nsteps_k, has_bias, relu, b_ref
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=o_ref.dtype)


def _matmul_fused_kernel(x_ref, w_ref, b_ref, o_ref, *, nsteps_k: int,
                         relu: bool):
    """Matmul tile with bias add + optional ReLU fused on the last K-step.

    Fusing the epilogue into the kernel avoids a second HBM round-trip
    over the (M, N) output — the same motivation as fused epilogues in
    cuBLAS/CUTLASS, re-expressed for the Pallas grid.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=o_ref.dtype)

    @pl.when(pl.program_id(2) == nsteps_k - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...]
        if relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def matmul_fused(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                 *, relu: bool = False, bm: int = DEFAULT_BM,
                 bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                 interpret: Optional[bool] = None) -> jax.Array:
    """``relu(x @ w + b)`` as a tiled Pallas kernel.

    Arbitrary ``(M, K) x (K, N)`` shapes are supported: inputs are
    zero-padded up to the tile grid and the result is sliced back.  Zero
    padding is exact for matmul + bias; for ReLU it is exact as well
    because padded rows/cols are discarded before any later use.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul_fused expects 2-D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if interpret is None:
        interpret = INTERPRET

    m, k = x.shape
    _, n = w.shape
    # Shrink tiles for small problems so tiny layers do not pay a full
    # 128^3 tile of padded zeros.
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    if b is None:
        bias = jnp.zeros((np_,), dtype=x.dtype)
    else:
        if b.shape != (n,):
            raise ValueError(f"bias shape {b.shape} != ({n},)")
        bias = _pad_to(b, 0, bn)

    kernel = functools.partial(_matmul_fused_kernel, nsteps_k=grid[2], relu=relu)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp, bias)
    return out[:m, :n]


def _round_up(v: int, multiple: int) -> int:
    return ((v + multiple - 1) // multiple) * multiple


def conv1x1(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None, *,
            relu: bool = False, **tile_kw) -> jax.Array:
    """Pointwise (1x1, stride-1) convolution via the Pallas matmul.

    ``x``: NHWC activations, ``w``: (Cin, Cout) weights.  The spatial
    dims are flattened into the matmul M axis — a pure layout reshape,
    no data movement in HLO.
    """
    if x.ndim != 4:
        raise ValueError(f"conv1x1 expects NHWC, got {x.shape}")
    n, h, w_, c = x.shape
    cin, cout = w.shape
    if c != cin:
        raise ValueError(f"channel mismatch: x has {c}, w has {cin}")
    flat = x.reshape(n * h * w_, c)
    out = matmul_fused(flat, w, b, relu=relu, **tile_kw)
    return out.reshape(n, h, w_, cout)


def _softmax_kernel(x_ref, o_ref):
    """Numerically-stable softmax over the last axis of one block row."""
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def softmax(x: jax.Array, *, interpret: Optional[bool] = None) -> jax.Array:
    """Row softmax as a single-block Pallas kernel (classifier head)."""
    if x.ndim != 2:
        raise ValueError(f"softmax expects 2-D (batch, classes), got {x.shape}")
    if interpret is None:
        interpret = INTERPRET
    return pl.pallas_call(
        _softmax_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def vmem_footprint_bytes(bm: int, bn: int, bk: int,
                         dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (x, w, bias, out tiles).

    Used by EXPERIMENTS.md §Perf; interpret-mode wallclock is not a TPU
    proxy, so block-shape tuning is driven by this + MXU-utilization
    estimates instead.
    """
    x_tile = bm * bk * dtype_bytes
    w_tile = bk * bn * dtype_bytes
    b_tile = bn * dtype_bytes
    o_tile = bm * bn * dtype_bytes
    return x_tile + w_tile + b_tile + o_tile


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int, bn: int,
                             bk: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes doing useful work, given padding waste."""
    mp, np_, kp = (_round_up(m, bm), _round_up(n, bn), _round_up(k, bk))
    useful = m * n * k
    issued = mp * np_ * kp
    # Per-tile systolic efficiency: tiles narrower than the MXU edge
    # leave lanes idle.
    lane = min(bm, mxu) * min(bn, mxu) / float(mxu * mxu)
    return (useful / issued) * lane
