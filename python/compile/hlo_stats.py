"""L2 performance analysis: op-census + fusion stats of lowered HLO.

Used by the §Perf pass (EXPERIMENTS.md): verifies that the lowered
modules contain no redundant recomputation, counts fusions vs raw ops,
and estimates the arithmetic intensity of the hot entry computation.

Usage::

    python -m compile.hlo_stats ../artifacts/squeezenet_infer.hlo.txt
"""

from __future__ import annotations

import collections
import re
import sys
from typing import Dict


# `%name = <type> opcode(args...)`: the opcode is the token right
# before the first '(' after the '='; types may themselves be tuples
# ("(s32[], f32[...])"), so skip one balanced type group if present.
OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\))?[^(]*?([a-z][\w\-]*)\(")


def op_census(hlo_text: str) -> Dict[str, int]:
    """Count HLO opcodes across the whole module."""
    census: Dict[str, int] = collections.Counter()
    for line in hlo_text.splitlines():
        m = OP_RE.match(line)
        if m:
            census[m.group(1)] += 1
    return dict(census)


def summarize(hlo_text: str) -> Dict[str, float]:
    """Headline stats for EXPERIMENTS.md §Perf."""
    census = op_census(hlo_text)
    total = sum(census.values())
    heavy = sum(census.get(k, 0) for k in ("convolution", "dot"))
    fusion = census.get("fusion", 0)
    elementwise = sum(
        census.get(k, 0)
        for k in ("add", "multiply", "maximum", "subtract", "divide", "exponential"))
    return {
        "total_ops": total,
        "heavy_ops": heavy,
        "fusions": fusion,
        "elementwise_ops": elementwise,
        "while_loops": census.get("while", 0),
        # Unfused elementwise ops after compilation would indicate
        # missed fusion; at the *input* HLO level this is the fusion
        # opportunity count.
        "elementwise_per_heavy": (elementwise / heavy) if heavy else 0.0,
    }


def main() -> None:
    if len(sys.argv) < 2:
        raise SystemExit(__doc__)
    for path in sys.argv[1:]:
        text = open(path).read()
        s = summarize(text)
        census = op_census(text)
        top = sorted(census.items(), key=lambda kv: -kv[1])[:12]
        print(f"== {path}")
        for k, v in s.items():
            print(f"   {k:22s} {v:,.1f}" if isinstance(v, float) else f"   {k:22s} {v:,}")
        print("   top ops:", ", ".join(f"{k}x{v}" for k, v in top))


if __name__ == "__main__":
    main()
