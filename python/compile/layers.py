"""Layer-2 building blocks shared by the three paper models.

Everything is NHWC / HWIO, inference-only (BatchNorm is folded into the
preceding convolution's weight+bias at init time — see
:func:`init_conv`), and batch-size 1 on the request path.

The 1x1 stride-1 convolutions route through the Layer-1 Pallas kernel
(:func:`compile.kernels.conv1x1`); spatial convolutions use XLA's native
``conv_general_dilated``.  ``use_pallas=False`` swaps every kernel call
for its jnp oracle, which gives an end-to-end pure-XLA reference model
used by the python tests *and* an AOT "baseline" artifact variant for
the kernel-ablation bench.
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import matmul as pk
from compile.kernels import ref as kref

Params = List[jax.Array]


class ParamSpec:
    """Ordered record of every parameter array a model consumes.

    The AOT manifest serializes this so the Rust runtime knows the
    artifact's calling convention: ``init() -> flat f32[N]`` (all
    params concatenated in spec order, already He/bias-scaled) and
    ``infer(param_0, ..., param_{P-1}, image) -> probs`` with params in
    spec order.  (A flat init output + separate infer args avoids XLA
    tuple literals entirely — the xla_extension 0.5.1 C API crashes
    converting large tuple buffers to literals.)
    """

    def __init__(self):
        self.names: List[str] = []
        self.shapes: List[Tuple[int, ...]] = []
        self.stds: List[float] = []

    def add(self, name: str, shape: Tuple[int, ...],
            std: float = 1.0) -> int:
        self.names.append(name)
        self.shapes.append(tuple(int(d) for d in shape))
        self.stds.append(float(std))
        return len(self.shapes) - 1

    @property
    def count(self) -> int:
        return len(self.shapes)

    def num_elements(self) -> int:
        return sum(int(math.prod(s)) for s in self.shapes)

    def size_bytes(self, dtype_bytes: int = 4) -> int:
        return self.num_elements() * dtype_bytes

    def to_json(self) -> list:
        return [{"name": n, "shape": list(s)}
                for n, s in zip(self.names, self.shapes)]


class Ctx:
    """Build-time context threaded through a model definition.

    One pass with ``mode='spec'`` records the ParamSpec and FLOP count;
    ``mode='init'`` generates He-initialised parameters; ``mode='apply'``
    consumes the params list in the same order.  A single model
    definition therefore cannot go out of sync with its init or its
    manifest.
    """

    def __init__(self, mode: str, *, key: Optional[jax.Array] = None,
                 params: Optional[Params] = None, use_pallas: bool = True):
        assert mode in ("spec", "init", "apply")
        self.mode = mode
        self.key = key
        self.params = list(params) if params is not None else []
        self.cursor = 0
        self.spec = ParamSpec()
        self.flops = 0
        self.use_pallas = use_pallas

    def param(self, name: str, shape: Tuple[int, ...],
              fan_in: int, std_scale: float = 1.0) -> Optional[jax.Array]:
        # He initialisation std, recorded in the spec; the init
        # artifact applies it to slices of one flat RNG draw.
        std = math.sqrt(2.0 / max(fan_in, 1)) * std_scale
        self.spec.add(name, shape, std)
        if self.mode != "apply":
            return None
        p = self.params[self.cursor]
        self.cursor += 1
        assert p.shape == shape, f"{name}: {p.shape} != {shape}"
        return p

    def bias(self, name: str, n: int) -> Optional[jax.Array]:
        # Folded-BN bias: small random offset (a trained BN beta is
        # O(0.1)); keeps activations centred so deep stacks do not
        # saturate to all-zero under ReLU with random weights.
        self.spec.add(name, (n,), 0.1)
        if self.mode != "apply":
            return None
        p = self.params[self.cursor]
        self.cursor += 1
        assert p.shape == (n,), name
        return p


def conv2d(ctx: Ctx, name: str, x, cin: int, cout: int, ksize: int,
           stride: int = 1, padding: str = "SAME", relu: bool = True,
           groups: int = 1, std_scale: float = 1.0):
    """Convolution + folded-BN bias + optional ReLU.

    1x1 stride-1 ungrouped convs dispatch to the Pallas matmul kernel;
    everything else uses XLA's native convolution.  ``std_scale < 1``
    mimics the zero-init-residual trick (He et al.) so deep residual
    stacks keep unit-order activations under synthetic weights.
    """
    kshape = (ksize, ksize, cin // groups, cout)
    fan_in = ksize * ksize * (cin // groups)
    w = ctx.param(f"{name}.w", kshape, fan_in, std_scale)
    b = ctx.bias(f"{name}.b", cout)

    def flop_count(out_h, out_w):
        return 2 * out_h * out_w * cout * fan_in

    if ctx.mode != "apply":
        # spec/init passes are shape-only: record dims + FLOP ledger,
        # never build compute (keeps the init artifact to pure RNG).
        return _SpecTensor.conv(x, cout, ksize, stride, padding, ctx,
                                flop_count)

    n, h, ww, _ = x.shape
    if ksize == 1 and stride == 1 and groups == 1:
        w2 = w.reshape(cin, cout)
        if ctx.use_pallas:
            out = pk.conv1x1(x, w2, b, relu=relu)
        else:
            out = kref.conv1x1_ref(x, w2, b, relu=relu)
        ctx.flops += flop_count(h, ww)
        return out

    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    out = out + b
    if relu:
        out = jnp.maximum(out, 0.0)
    ctx.flops += flop_count(out.shape[1], out.shape[2])
    return out


class _SpecTensor:
    """Shape-only tensor used during the ``spec`` pass (no compute)."""

    def __init__(self, shape):
        self.shape = tuple(int(d) for d in shape)

    @staticmethod
    def conv(x, cout, ksize, stride, padding, ctx, flop_count):
        n, h, w, _ = x.shape
        if padding == "SAME":
            oh, ow = -(-h // stride), -(-w // stride)
        else:
            oh = (h - ksize) // stride + 1
            ow = (w - ksize) // stride + 1
        ctx.flops += flop_count(oh, ow)
        return _SpecTensor((n, oh, ow, cout))

    @staticmethod
    def pool(x, ksize, stride, padding):
        n, h, w, c = x.shape
        if padding == "SAME":
            oh, ow = -(-h // stride), -(-w // stride)
        else:
            oh = (h - ksize) // stride + 1
            ow = (w - ksize) // stride + 1
        return _SpecTensor((n, oh, ow, c))


def maxpool(ctx: Ctx, x, ksize: int, stride: int, padding: str = "VALID"):
    if ctx.mode != "apply":
        return _SpecTensor.pool(x, ksize, stride, padding)
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, ksize, ksize, 1),
        (1, stride, stride, 1), padding)


def global_avgpool(ctx: Ctx, x):
    if ctx.mode != "apply":
        return _SpecTensor((x.shape[0], x.shape[3]))
    return jnp.mean(x, axis=(1, 2))


def classifier(ctx: Ctx, name: str, x, cin: int, nclasses: int):
    """Linear head + softmax; both on the Pallas kernels."""
    w = ctx.param(f"{name}.w", (cin, nclasses), cin)
    b = ctx.bias(f"{name}.b", nclasses)
    ctx.flops += 2 * cin * nclasses
    if ctx.mode != "apply":
        return _SpecTensor((x.shape[0], nclasses))
    if ctx.use_pallas:
        logits = pk.matmul_fused(x, w, b)
        probs = pk.softmax(logits)
    else:
        logits = kref.matmul_fused_ref(x, w, b)
        probs = kref.softmax_ref(logits)
    return probs


def add_relu(ctx: Ctx, a, b):
    if ctx.mode != "apply":
        assert a.shape == b.shape, (a.shape, b.shape)
        return a
    return jnp.maximum(a + b, 0.0)
