"""SqueezeNet v1.0 (Iandola et al., 2016) — the paper's 5 MB model.

Fire modules: a 1x1 "squeeze" conv followed by parallel 1x1 and 3x3
"expand" convs, concatenated.  The 1x1 convs (two thirds of the layers)
run on the Layer-1 Pallas matmul kernel.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile import layers as L


def _fire(ctx: L.Ctx, name: str, x, cin: int, squeeze: int, e1: int, e3: int):
    s = L.conv2d(ctx, f"{name}.squeeze", x, cin, squeeze, 1)
    a = L.conv2d(ctx, f"{name}.expand1", s, squeeze, e1, 1)
    b = L.conv2d(ctx, f"{name}.expand3", s, squeeze, e3, 3)
    if ctx.mode != "apply":
        n, h, w, _ = a.shape
        return L._SpecTensor((n, h, w, e1 + e3))
    return jnp.concatenate([a, b], axis=-1)


def squeezenet_v10(ctx: L.Ctx, image):
    """``image``: (1, H, W, 3) NHWC float32 -> (probs[1,1000])."""
    x = L.conv2d(ctx, "conv1", image, 3, 96, 7, stride=2)
    x = L.maxpool(ctx, x, 3, 2)
    x = _fire(ctx, "fire2", x, 96, 16, 64, 64)
    x = _fire(ctx, "fire3", x, 128, 16, 64, 64)
    x = _fire(ctx, "fire4", x, 128, 32, 128, 128)
    x = L.maxpool(ctx, x, 3, 2)
    x = _fire(ctx, "fire5", x, 256, 32, 128, 128)
    x = _fire(ctx, "fire6", x, 256, 48, 192, 192)
    x = _fire(ctx, "fire7", x, 384, 48, 192, 192)
    x = _fire(ctx, "fire8", x, 384, 64, 256, 256)
    x = L.maxpool(ctx, x, 3, 2)
    x = _fire(ctx, "fire9", x, 512, 64, 256, 256)
    # conv10: 1x1 conv straight to 1000 classes, then global average
    # pool — SqueezeNet has no fully-connected layer.
    x = L.conv2d(ctx, "conv10", x, 512, 1000, 1)
    x = L.global_avgpool(ctx, x)
    if ctx.mode != "apply":
        return x
    from compile.kernels import matmul as pk
    from compile.kernels import ref as kref
    return pk.softmax(x) if ctx.use_pallas else kref.softmax_ref(x)
