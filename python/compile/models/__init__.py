"""The three paper models (SqueezeNet v1.0, ResNet-18, ResNeXt-50 32x4d)."""

from compile.models.squeezenet import squeezenet_v10  # noqa: F401
from compile.models.resnet18 import resnet18  # noqa: F401
from compile.models.resnext50 import resnext50_32x4d  # noqa: F401
