"""ResNet-18 (He et al., 2015) — the paper's 45 MB model.

Basic residual blocks (two 3x3 convs); downsampling shortcuts are 1x1
convs, which run on the Layer-1 Pallas kernel when stride is 1.  BN is
folded into conv bias at init (inference-only), see layers.Ctx.
"""

from __future__ import annotations

from compile import layers as L


def _basic_block(ctx: L.Ctx, name: str, x, cin: int, cout: int, stride: int):
    out = L.conv2d(ctx, f"{name}.conv1", x, cin, cout, 3, stride=stride)
    out = L.conv2d(ctx, f"{name}.conv2", out, cout, cout, 3, relu=False,
                   std_scale=0.2)
    if stride != 1 or cin != cout:
        x = L.conv2d(ctx, f"{name}.down", x, cin, cout, 1, stride=stride,
                     relu=False)
    return L.add_relu(ctx, out, x)


def resnet18(ctx: L.Ctx, image):
    """``image``: (1, H, W, 3) NHWC float32 -> (probs[1,1000])."""
    x = L.conv2d(ctx, "conv1", image, 3, 64, 7, stride=2)
    x = L.maxpool(ctx, x, 3, 2, padding="SAME")
    plan = [(64, 64, 1), (64, 64, 1),
            (64, 128, 2), (128, 128, 1),
            (128, 256, 2), (256, 256, 1),
            (256, 512, 2), (512, 512, 1)]
    for i, (cin, cout, stride) in enumerate(plan):
        x = _basic_block(ctx, f"layer{i}", x, cin, cout, stride)
    x = L.global_avgpool(ctx, x)
    return L.classifier(ctx, "fc", x, 512, 1000)
