"""ResNeXt-50 32x4d (Xie et al., 2016) — the paper's 98 MB model.

Bottleneck blocks with 32-way grouped 3x3 convolutions.  The 1x1
reduce/expand convs around each grouped conv dominate the FLOPs and run
on the Layer-1 Pallas kernel; the grouped conv uses XLA's native
``feature_group_count`` path.
"""

from __future__ import annotations

from compile import layers as L

CARDINALITY = 32
BASE_WIDTH = 4


def _bottleneck(ctx: L.Ctx, name: str, x, cin: int, planes: int,
                stride: int, expansion: int = 4):
    width = planes * BASE_WIDTH // 64 * CARDINALITY  # e.g. planes=64 -> 128
    cout = planes * expansion
    out = L.conv2d(ctx, f"{name}.reduce", x, cin, width, 1)
    out = L.conv2d(ctx, f"{name}.grouped", out, width, width, 3,
                   stride=stride, groups=CARDINALITY)
    out = L.conv2d(ctx, f"{name}.expand", out, width, cout, 1, relu=False,
                   std_scale=0.2)
    if stride != 1 or cin != cout:
        x = L.conv2d(ctx, f"{name}.down", x, cin, cout, 1, stride=stride,
                     relu=False)
    return L.add_relu(ctx, out, x)


def resnext50_32x4d(ctx: L.Ctx, image):
    """``image``: (1, H, W, 3) NHWC float32 -> (probs[1,1000])."""
    x = L.conv2d(ctx, "conv1", image, 3, 64, 7, stride=2)
    x = L.maxpool(ctx, x, 3, 2, padding="SAME")
    cin = 64
    for stage, (planes, blocks, stride) in enumerate(
            [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]):
        for b in range(blocks):
            s = stride if b == 0 else 1
            x = _bottleneck(ctx, f"s{stage}b{b}", x, cin, planes, s)
            cin = planes * 4
    x = L.global_avgpool(ctx, x)
    return L.classifier(ctx, "fc", x, 2048, 1000)
