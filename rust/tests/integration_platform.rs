//! End-to-end platform integration on the mock engine: gateway HTTP
//! round-trips over full workloads, multi-function isolation, and the
//! experiment harness invariants that don't need real artifacts.

use lambdaserve::configparse::{BootstrapConfig, PlatformConfig};
use lambdaserve::gateway::Gateway;
use lambdaserve::httpd::{http_get, http_post};
use lambdaserve::platform::{Invoker, StartKind};
use lambdaserve::runtime::{MockEngine, MockModelCosts};
use lambdaserve::util::json::Json;
use lambdaserve::util::ManualClock;
use lambdaserve::workload::{run_closed_loop, PoissonArrivals, WarmProbe};
use std::sync::Arc;
use std::time::Duration;

fn fast_config() -> PlatformConfig {
    PlatformConfig {
        bootstrap: BootstrapConfig { simulate_delays: false, ..Default::default() },
        ..Default::default()
    }
}

fn fast_engine() -> Arc<MockEngine> {
    Arc::new(MockEngine::new(vec![
        MockModelCosts::paper_like("squeezenet", 3, 5.0, 85),
        MockModelCosts::paper_like("resnet18", 5, 46.7, 229),
    ]))
}

#[test]
fn multi_function_pools_are_isolated() {
    let clock = ManualClock::new();
    let p = Invoker::new(PlatformConfig::default(), fast_engine(), clock);
    p.deploy("a", "squeezenet", "pallas", 512).unwrap();
    p.deploy("b", "resnet18", "pallas", 512).unwrap();

    p.invoke("a", 1).unwrap();
    // b's first invoke is cold even though a has a warm container.
    let rb = p.invoke("b", 1).unwrap();
    assert_eq!(rb.record.start, StartKind::Cold);
    assert_eq!(p.pool.warm_count("a"), 1);
    assert_eq!(p.pool.warm_count("b"), 1);
    // Each reuses its own.
    assert_eq!(p.invoke("a", 2).unwrap().record.start, StartKind::Warm);
    assert_eq!(p.invoke("b", 2).unwrap().record.start, StartKind::Warm);
    assert_eq!(p.pool.total_alive(), 2);
}

#[test]
fn poisson_day_simulation_costs_track_billing() {
    let clock = ManualClock::new();
    let p = Invoker::new(PlatformConfig::default(), fast_engine(), clock);
    p.deploy("a", "squeezenet", "pallas", 1024).unwrap();
    let sched =
        PoissonArrivals { rps: 0.01, duration: Duration::from_secs(6 * 3600), seed: 3 };
    let report = run_closed_loop(&p, "a", &sched, 17);
    assert!(!report.samples.is_empty());
    assert!((report.total_cost() - p.billing.total_dollars()).abs() < 1e-12);
    // Sparse traffic (mean gap 100 s) with a 300 s TTL: mixed cold/warm.
    let cold = report.cold_count();
    assert!(cold > 0 && cold < report.samples.len(), "cold={cold}");
}

#[test]
fn gateway_serves_warm_probe_over_http() {
    let p = Arc::new(Invoker::live(fast_config(), fast_engine()));
    p.deploy("sq", "squeezenet", "pallas", 1536).unwrap();
    let gw = Gateway::bind("127.0.0.1:0", 8, p.clone()).unwrap();
    let addr = gw.local_addr().to_string();
    let sh = gw.shutdown_handle();
    let t = std::thread::spawn(move || gw.serve().unwrap());
    let tmo = Duration::from_secs(10);

    // JMeter-style warm probe over real HTTP: discard one, measure 10.
    let mut latencies = Vec::new();
    for i in 0..11 {
        let t0 = std::time::Instant::now();
        let r = http_get(&addr, &format!("/v1/invoke/sq?seed={i}"), tmo).unwrap();
        assert_eq!(r.status, 200);
        if i > 0 {
            latencies.push(t0.elapsed());
        }
        let j = Json::parse(&r.body_str()).unwrap();
        let expect = if i == 0 { "cold" } else { "warm" };
        assert_eq!(j.get("start").unwrap().as_str(), Some(expect), "request {i}");
    }
    assert_eq!(latencies.len(), 10);

    let stats = http_get(&addr, "/v1/stats", tmo).unwrap();
    let j = Json::parse(&stats.body_str()).unwrap();
    assert_eq!(j.get("invocations").unwrap().as_u64(), Some(11));
    assert_eq!(j.get("cold_starts").unwrap().as_u64(), Some(1));

    sh.shutdown();
    t.join().unwrap();
}

#[test]
fn gateway_throttles_with_429() {
    let config = PlatformConfig { max_containers: 1, ..fast_config() };
    let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
        "squeezenet",
        300, // slow enough to hold the one container busy
        5.0,
        85,
    )]));
    let p = Arc::new(Invoker::live(config, engine));
    p.deploy("sq", "squeezenet", "pallas", 1536).unwrap();
    let gw = Gateway::bind("127.0.0.1:0", 8, p).unwrap();
    let addr = gw.local_addr().to_string();
    let sh = gw.shutdown_handle();
    let t = std::thread::spawn(move || gw.serve().unwrap());
    let tmo = Duration::from_secs(30);

    // Two concurrent requests against capacity 1: one succeeds, the
    // other gets 429.
    let a1 = addr.clone();
    let h1 = std::thread::spawn(move || http_get(&a1, "/v1/invoke/sq?seed=1", tmo).unwrap().status);
    std::thread::sleep(Duration::from_millis(50));
    let s2 = http_get(&addr, "/v1/invoke/sq?seed=2", tmo).unwrap().status;
    let s1 = h1.join().unwrap();
    assert_eq!(s1, 200);
    assert_eq!(s2, 429, "second concurrent request throttled");

    sh.shutdown();
    t.join().unwrap();
}

/// Acceptance: min_warm capacity survives an idle gap longer than the
/// keep-alive TTL. The background maintainer thread (wall-clock tick
/// timer) sweeps the stale containers and replenishes the target on a
/// virtual platform clock — before the fix, the pre-warmed capacity
/// silently decayed and the next request after the gap was cold.
#[test]
fn min_warm_pool_survives_idle_gap_longer_than_ttl() {
    let clock = ManualClock::new();
    let p = Arc::new(Invoker::new(PlatformConfig::default(), fast_engine(), clock.clone()));
    p.deploy_full("sq", "squeezenet", "pallas", 512, 2, None).unwrap();
    assert_eq!(p.pool.warm_count("sq"), 2);
    assert!(Invoker::start_maintainer(&p, Duration::from_millis(2)));

    // The paper's forced-cold regime: idle past the 300 s keep-alive.
    clock.sleep(Duration::from_secs(601));
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while p.maintainer_replenished() < 2 {
        assert!(std::time::Instant::now() < deadline, "maintainer never replenished the pool");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(p.pool.warm_count("sq"), 2, "warm capacity restored to min_warm");
    // The restored capacity is fresh, so the next invocation is warm —
    // and it is NOT counted as a request-visible cold provision.
    assert_eq!(p.invoke("sq", 1).unwrap().record.start, StartKind::Warm);
    assert_eq!(p.scaler.cold_provision_count(), 0);
    p.stop_maintainer();
}

/// Acceptance: stats snapshots are internally consistent while
/// invocations hammer the sink from many threads — the counters and
/// the split histograms of one snapshot always agree (the old
/// aggregation read the record vector under four separate locks and
/// could tear).
#[test]
fn concurrent_invoke_vs_stats_snapshots_are_consistent() {
    use std::sync::atomic::{AtomicBool, Ordering};

    let p = Arc::new(Invoker::live(fast_config(), fast_engine()));
    p.deploy("sq", "squeezenet", "pallas", 1536).unwrap();
    let stop = Arc::new(AtomicBool::new(false));

    let readers: Vec<_> = (0..2)
        .map(|_| {
            let p = p.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut checks = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    let m = p.metrics.function_metrics("sq");
                    assert_eq!(m.invocations, m.cold_starts + m.warm_starts());
                    assert_eq!(m.response_cold.count(), m.cold_starts, "torn cold counters");
                    assert_eq!(m.response_warm.count(), m.warm_starts(), "torn warm counters");
                    assert_eq!(m.predict_all().count(), m.invocations);
                    let t = p.metrics.platform_metrics();
                    assert_eq!(t.invocations, t.cold_starts + t.warm_starts());
                    assert_eq!(t.response_all().count(), t.invocations);
                    checks += 1;
                }
                checks
            })
        })
        .collect();

    let writers: Vec<_> = (0..4)
        .map(|t| {
            let p = p.clone();
            std::thread::spawn(move || {
                for i in 0..50u64 {
                    p.invoke("sq", t * 1000 + i).unwrap();
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    for r in readers {
        assert!(r.join().unwrap() > 0, "reader must have observed snapshots");
    }

    let m = p.metrics.function_metrics("sq");
    assert_eq!(m.invocations, 200);
    assert_eq!(m.invocations, m.cold_starts + m.warm_starts());
    assert_eq!(m.response_all().count(), 200);
    assert_eq!(p.metrics.len(), 200);
}

#[test]
fn warm_probe_latency_decomposition_holds() {
    // latency = network + queue + (cold parts) + predict; verify the
    // identity on every sample of a probe.
    let clock = ManualClock::new();
    let p = Invoker::new(PlatformConfig::default(), fast_engine(), clock);
    p.deploy("a", "squeezenet", "pallas", 512).unwrap();
    let report = run_closed_loop(&p, "a", &WarmProbe::default(), 5);
    for s in report.ok_samples() {
        assert!(s.latency >= s.predict, "{s:?}");
        // network floor: rtt 35 ms.
        assert!(s.latency - s.predict >= Duration::from_millis(35), "{s:?}");
    }
}
