//! End-to-end platform integration on the mock engine: gateway HTTP
//! round-trips over full workloads, multi-function isolation, and the
//! experiment harness invariants that don't need real artifacts.

use lambdaserve::configparse::{BootstrapConfig, PlatformConfig};
use lambdaserve::gateway::Gateway;
use lambdaserve::httpd::{http_get, http_post};
use lambdaserve::platform::{FunctionPolicy, Invoker, StartKind};
use lambdaserve::runtime::{MockEngine, MockModelCosts};
use lambdaserve::util::json::Json;
use lambdaserve::util::ManualClock;
use lambdaserve::workload::{run_closed_loop, PoissonArrivals, WarmProbe};
use std::sync::Arc;
use std::time::Duration;

fn fast_config() -> PlatformConfig {
    PlatformConfig {
        bootstrap: BootstrapConfig { simulate_delays: false, ..Default::default() },
        ..Default::default()
    }
}

fn fast_engine() -> Arc<MockEngine> {
    Arc::new(MockEngine::new(vec![
        MockModelCosts::paper_like("squeezenet", 3, 5.0, 85),
        MockModelCosts::paper_like("resnet18", 5, 46.7, 229),
    ]))
}

#[test]
fn multi_function_pools_are_isolated() {
    let clock = ManualClock::new();
    let p = Invoker::new(PlatformConfig::default(), fast_engine(), clock);
    p.deploy("a", "squeezenet", "pallas", 512).unwrap();
    p.deploy("b", "resnet18", "pallas", 512).unwrap();

    p.invoke("a", 1).unwrap();
    // b's first invoke is cold even though a has a warm container.
    let rb = p.invoke("b", 1).unwrap();
    assert_eq!(rb.record.start, StartKind::Cold);
    assert_eq!(p.pool.warm_count("a"), 1);
    assert_eq!(p.pool.warm_count("b"), 1);
    // Each reuses its own.
    assert_eq!(p.invoke("a", 2).unwrap().record.start, StartKind::Warm);
    assert_eq!(p.invoke("b", 2).unwrap().record.start, StartKind::Warm);
    assert_eq!(p.pool.total_alive(), 2);
}

#[test]
fn poisson_day_simulation_costs_track_billing() {
    let clock = ManualClock::new();
    let p = Invoker::new(PlatformConfig::default(), fast_engine(), clock);
    p.deploy("a", "squeezenet", "pallas", 1024).unwrap();
    let sched =
        PoissonArrivals { rps: 0.01, duration: Duration::from_secs(6 * 3600), seed: 3 };
    let report = run_closed_loop(&p, "a", &sched, 17);
    assert!(!report.samples.is_empty());
    assert!((report.total_cost() - p.billing.total_dollars()).abs() < 1e-12);
    // Sparse traffic (mean gap 100 s) with a 300 s TTL: mixed cold/warm.
    let cold = report.cold_count();
    assert!(cold > 0 && cold < report.samples.len(), "cold={cold}");
}

#[test]
fn gateway_serves_warm_probe_over_http() {
    let p = Arc::new(Invoker::live(fast_config(), fast_engine()));
    p.deploy("sq", "squeezenet", "pallas", 1536).unwrap();
    let gw = Gateway::bind("127.0.0.1:0", 8, p.clone()).unwrap();
    let addr = gw.local_addr().to_string();
    let sh = gw.shutdown_handle();
    let t = std::thread::spawn(move || gw.serve().unwrap());
    let tmo = Duration::from_secs(10);

    // JMeter-style warm probe over real HTTP: discard one, measure 10.
    let mut latencies = Vec::new();
    for i in 0..11 {
        let t0 = std::time::Instant::now();
        let r = http_get(&addr, &format!("/v1/invoke/sq?seed={i}"), tmo).unwrap();
        assert_eq!(r.status, 200);
        if i > 0 {
            latencies.push(t0.elapsed());
        }
        let j = Json::parse(&r.body_str()).unwrap();
        let expect = if i == 0 { "cold" } else { "warm" };
        assert_eq!(j.get("start").unwrap().as_str(), Some(expect), "request {i}");
    }
    assert_eq!(latencies.len(), 10);

    let stats = http_get(&addr, "/v1/stats", tmo).unwrap();
    let j = Json::parse(&stats.body_str()).unwrap();
    assert_eq!(j.get("invocations").unwrap().as_u64(), Some(11));
    assert_eq!(j.get("cold_starts").unwrap().as_u64(), Some(1));

    sh.shutdown();
    t.join().unwrap();
}

/// Acceptance (real threads, real HTTP): a burst of concurrent
/// invokes exceeding warm capacity but within queue capacity
/// completes with ZERO 429s — requests park in the dispatcher and
/// drain as containers free — and the queue wait shows up in the
/// per-function stats percentiles.
#[test]
fn gateway_absorbs_burst_within_queue_capacity() {
    let config = PlatformConfig { max_containers: 2, ..fast_config() };
    let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
        "squeezenet",
        100, // long enough that the burst genuinely overlaps
        5.0,
        85,
    )]));
    let p = Arc::new(Invoker::live(config, engine));
    p.deploy("sq", "squeezenet", "pallas", 1536).unwrap();
    let gw = Gateway::bind("127.0.0.1:0", 8, p.clone()).unwrap();
    let addr = gw.local_addr().to_string();
    let sh = gw.shutdown_handle();
    let t = std::thread::spawn(move || gw.serve().unwrap());
    let tmo = Duration::from_secs(30);

    // 6 concurrent requests against 2 capacity slots: the overflow
    // parks (bounded queue, 2 s default deadline) instead of failing.
    // A barrier lines the clients up so the burst genuinely overlaps
    // even on a loaded CI runner.
    let barrier = Arc::new(std::sync::Barrier::new(6));
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let addr = addr.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                http_get(&addr, &format!("/v1/invoke/sq?seed={i}"), tmo).unwrap().status
            })
        })
        .collect();
    let statuses: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(statuses, vec![200; 6], "burst absorbed with zero 429s/503s");
    assert_eq!(p.scaler.throttled_count(), 0);
    assert_eq!(p.scaler.saturated_count(), 0);
    assert!(p.pool.total_alive() <= 2, "the cap was never exceeded");

    // The wait is measured: per-function stats expose queue-wait
    // percentiles, and at least one parked request waited for a full
    // service time.
    let r = http_get(&addr, "/v2/functions/sq/stats", tmo).unwrap();
    let j = Json::parse(&r.body_str()).unwrap();
    assert_eq!(j.get("invocations").unwrap().as_u64(), Some(6));
    assert_eq!(j.get("throttled").unwrap().as_u64(), Some(0));
    assert_eq!(j.get("queue_expired").unwrap().as_u64(), Some(0));
    assert_eq!(j.get("queue_depth").unwrap().as_u64(), Some(0), "queue drained");
    let p99 = j.get("queue_wait_p99_s").unwrap().as_f64().unwrap();
    assert!(p99 > 0.05, "parked requests show real queue wait, p99={p99}");

    sh.shutdown();
    t.join().unwrap();
}

/// Acceptance (tentpole): with `max_batch_size = 8`, a concurrent
/// same-function burst over real HTTP coalesces into strictly fewer
/// engine forward passes than requests — every request still gets its
/// own 200 with its own correct prediction — and the batch-size
/// percentiles appear in BOTH stats routes.
#[test]
fn gateway_batches_concurrent_burst_into_fewer_passes() {
    const BURST: usize = 8;
    let config = PlatformConfig {
        max_batch_size: BURST,
        batch_window_ms: 500, // early flush at 8 usually ends it sooner
        max_containers: 2,
        ..fast_config()
    };
    let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
        "squeezenet",
        60,
        5.0,
        85,
    )]));
    let p = Arc::new(Invoker::live(config, engine.clone()));
    p.deploy_full(
        "sq",
        "squeezenet",
        "pallas",
        1536,
        FunctionPolicy { min_warm: 1, ..Default::default() },
    )
    .unwrap();
    let gw = Gateway::bind("127.0.0.1:0", 2 * BURST, p.clone()).unwrap();
    let addr = gw.local_addr().to_string();
    let sh = gw.shutdown_handle();
    let t = std::thread::spawn(move || gw.serve().unwrap());
    let tmo = Duration::from_secs(30);

    let passes_before = engine.predict_calls.load(std::sync::atomic::Ordering::SeqCst);
    let barrier = Arc::new(std::sync::Barrier::new(BURST));
    let handles: Vec<_> = (0..BURST)
        .map(|i| {
            let addr = addr.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                let r = http_get(&addr, &format!("/v1/invoke/sq?seed={i}"), tmo).unwrap();
                (r.status, r.body_str())
            })
        })
        .collect();
    let responses: Vec<(u16, String)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (status, body) in &responses {
        assert_eq!(*status, 200, "{body}");
    }
    let passes =
        engine.predict_calls.load(std::sync::atomic::Ordering::SeqCst) - passes_before;
    assert!(
        (passes as usize) < BURST,
        "{BURST} requests must coalesce into fewer forward passes, got {passes}"
    );

    // Every request got its own seed's classification (the mock is a
    // deterministic function of the seed): compare as multisets.
    use lambdaserve::runtime::Engine as _;
    let solo = MockEngine::new(vec![MockModelCosts::paper_like("squeezenet", 60, 5.0, 85)]);
    let (h, _) = solo.create_instance("squeezenet", "pallas").unwrap();
    let mut expect: Vec<u64> =
        (0..BURST as u64).map(|s| solo.predict(&h, s).unwrap().top1 as u64).collect();
    let mut got: Vec<u64> = responses
        .iter()
        .map(|(_, body)| {
            Json::parse(body).unwrap().get("top1").unwrap().as_u64().unwrap()
        })
        .collect();
    expect.sort_unstable();
    got.sort_unstable();
    assert_eq!(got, expect, "each member got its own prediction");

    // Batch telemetry on BOTH stats routes.
    let r = http_get(&addr, "/v2/functions/sq/stats", tmo).unwrap();
    let j = Json::parse(&r.body_str()).unwrap();
    assert_eq!(j.get("invocations").unwrap().as_u64(), Some(BURST as u64));
    assert!(j.get("batched_requests").unwrap().as_u64().unwrap() >= 2);
    assert!(j.get("batched_share").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("batch_size_p95").unwrap().as_u64().unwrap() >= 2);
    assert!(j.get("batch_wait_p99_s").unwrap().as_f64().unwrap() >= 0.0);
    let r = http_get(&addr, "/v2/stats", tmo).unwrap();
    let j = Json::parse(&r.body_str()).unwrap();
    assert!(j.get("batch_size_p95").unwrap().as_u64().unwrap() >= 2);
    assert!(j.get("batches_executed").unwrap().as_u64().unwrap() >= 1);
    assert!(j.get("batched_requests").unwrap().as_u64().unwrap() >= 2);
    assert!(j.get("largest_batch").unwrap().as_u64().unwrap() >= 2);

    sh.shutdown();
    t.join().unwrap();
}

/// Acceptance: a parked request whose dispatch deadline passes gets
/// 503 + `Retry-After` (not 429), and the expiry is visible in the
/// dispatcher telemetry of `/v2/stats`.
#[test]
fn gateway_deadline_expiry_returns_503_with_retry_after() {
    let config = PlatformConfig { max_containers: 1, ..fast_config() };
    let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
        "squeezenet",
        3000, // one slow request holds the only container
        5.0,
        85,
    )]));
    let p = Arc::new(Invoker::live(config, engine));
    let gw = Gateway::bind("127.0.0.1:0", 8, p.clone()).unwrap();
    let addr = gw.local_addr().to_string();
    let sh = gw.shutdown_handle();
    let t = std::thread::spawn(move || gw.serve().unwrap());
    let tmo = Duration::from_secs(30);

    // Deploy with a short per-function deadline override so the test
    // does not sit out the 2 s platform default.
    let r = http_post(
        &addr,
        "/v2/functions",
        br#"{"name": "sq", "model": "squeezenet", "memory_mb": 1536, "queue_deadline_ms": 150}"#,
        tmo,
    )
    .unwrap();
    assert_eq!(r.status, 201, "{}", r.body_str());
    let j = Json::parse(&r.body_str()).unwrap();
    assert_eq!(j.get("queue_deadline_ms").unwrap().as_u64(), Some(150), "override echoed");

    let a1 = addr.clone();
    let h1 = std::thread::spawn(move || http_get(&a1, "/v1/invoke/sq?seed=1", tmo).unwrap().status);
    std::thread::sleep(Duration::from_millis(100)); // let it occupy the slot
    let resp = http_get(&addr, "/v1/invoke/sq?seed=2", tmo).unwrap();
    assert_eq!(resp.status, 503, "deadline expiry is 503, not 429: {}", resp.body_str());
    assert!(
        resp.headers.get("retry-after").is_some(),
        "503 carries Retry-After: {:?}",
        resp.headers
    );

    // The same condition through v2 (the slow request still holds the
    // slot for seconds) yields the structured envelope.
    let resp =
        http_post(&addr, "/v2/functions/sq/invocations", br#"{"seed": 3}"#, tmo).unwrap();
    assert_eq!(resp.status, 503, "{}", resp.body_str());
    let j = Json::parse(&resp.body_str()).unwrap();
    assert_eq!(j.path(&["error", "code"]).unwrap().as_str(), Some("queue_deadline_expired"));
    assert!(resp.headers.get("retry-after").is_some());

    assert_eq!(h1.join().unwrap(), 200, "the in-flight request was unaffected");

    let r = http_get(&addr, "/v2/stats", tmo).unwrap();
    let j = Json::parse(&r.body_str()).unwrap();
    assert!(
        j.get("queue_deadline_expired").unwrap().as_u64().unwrap() >= 1,
        "expiry counted in dispatcher telemetry"
    );
    assert!(j.get("saturated").unwrap().as_u64().unwrap() >= 1);

    sh.shutdown();
    t.join().unwrap();
}

/// Acceptance (ManualClock): the same burst-absorption contract holds
/// on virtual time — concurrent invokes over capacity park and drain
/// with zero 429s/503s, and the parked waiters' virtual-deadline
/// machinery never misfires while capacity is actively cycling.
#[test]
fn burst_drains_with_zero_rejections_on_manual_clock() {
    let clock = ManualClock::new();
    // Instant bootstrap: simulated multi-second cold-start sleeps
    // would advance the SHARED virtual clock past the parked waiters'
    // deadlines — here the contention itself is under test, not the
    // cold-start model.
    let config = PlatformConfig { max_containers: 2, ..fast_config() };
    let p = Arc::new(Invoker::new(config, fast_engine(), clock));
    p.deploy("sq", "squeezenet", "pallas", 512).unwrap();

    std::thread::scope(|s| {
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let p = p.clone();
                s.spawn(move || p.invoke("sq", i))
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap().expect("every burst request completes");
            assert!(out.record.billed_ms > 0);
        }
    });
    assert_eq!(p.scaler.throttled_count(), 0, "zero 429s");
    assert_eq!(p.scaler.saturated_count(), 0, "zero 503s");
    assert_eq!(p.dispatcher.expired_total(), 0);
    assert_eq!(p.dispatcher.total_depth(), 0, "queue fully drained");
    assert!(p.pool.total_alive() <= 2, "container cap respected");
    let m = p.metrics.function_metrics("sq");
    assert_eq!(m.invocations, 6);
    assert_eq!(m.queue_wait.count(), 6, "queue wait recorded for every request");
}

/// Acceptance: min_warm capacity survives an idle gap longer than the
/// keep-alive TTL. The background maintainer thread (wall-clock tick
/// timer) sweeps the stale containers and replenishes the target on a
/// virtual platform clock — before the fix, the pre-warmed capacity
/// silently decayed and the next request after the gap was cold.
#[test]
fn min_warm_pool_survives_idle_gap_longer_than_ttl() {
    let clock = ManualClock::new();
    let p = Arc::new(Invoker::new(PlatformConfig::default(), fast_engine(), clock.clone()));
    p.deploy_full(
        "sq",
        "squeezenet",
        "pallas",
        512,
        FunctionPolicy { min_warm: 2, ..Default::default() },
    )
    .unwrap();
    assert_eq!(p.pool.warm_count("sq"), 2);
    assert!(Invoker::start_maintainer(&p, Duration::from_millis(2)));

    // The paper's forced-cold regime: idle past the 300 s keep-alive.
    clock.sleep(Duration::from_secs(601));
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while p.maintainer_replenished() < 2 {
        assert!(std::time::Instant::now() < deadline, "maintainer never replenished the pool");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(p.pool.warm_count("sq"), 2, "warm capacity restored to min_warm");
    // The restored capacity is fresh, so the next invocation is warm —
    // and it is NOT counted as a request-visible cold provision.
    assert_eq!(p.invoke("sq", 1).unwrap().record.start, StartKind::Warm);
    assert_eq!(p.scaler.cold_provision_count(), 0);
    p.stop_maintainer();
}

/// Acceptance: stats snapshots are internally consistent while
/// invocations hammer the sink from many threads — the counters and
/// the split histograms of one snapshot always agree (the old
/// aggregation read the record vector under four separate locks and
/// could tear).
#[test]
fn concurrent_invoke_vs_stats_snapshots_are_consistent() {
    use std::sync::atomic::{AtomicBool, Ordering};

    let p = Arc::new(Invoker::live(fast_config(), fast_engine()));
    p.deploy("sq", "squeezenet", "pallas", 1536).unwrap();
    let stop = Arc::new(AtomicBool::new(false));

    let readers: Vec<_> = (0..2)
        .map(|_| {
            let p = p.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut checks = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    let m = p.metrics.function_metrics("sq");
                    assert_eq!(m.invocations, m.cold_starts + m.warm_starts());
                    assert_eq!(m.response_cold.count(), m.cold_starts, "torn cold counters");
                    assert_eq!(m.response_warm.count(), m.warm_starts(), "torn warm counters");
                    assert_eq!(m.predict_all().count(), m.invocations);
                    let t = p.metrics.platform_metrics();
                    assert_eq!(t.invocations, t.cold_starts + t.warm_starts());
                    assert_eq!(t.response_all().count(), t.invocations);
                    checks += 1;
                }
                checks
            })
        })
        .collect();

    let writers: Vec<_> = (0..4)
        .map(|t| {
            let p = p.clone();
            std::thread::spawn(move || {
                for i in 0..50u64 {
                    p.invoke("sq", t * 1000 + i).unwrap();
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    for r in readers {
        assert!(r.join().unwrap() > 0, "reader must have observed snapshots");
    }

    let m = p.metrics.function_metrics("sq");
    assert_eq!(m.invocations, 200);
    assert_eq!(m.invocations, m.cold_starts + m.warm_starts());
    assert_eq!(m.response_all().count(), 200);
    assert_eq!(p.metrics.len(), 200);
}

#[test]
fn warm_probe_latency_decomposition_holds() {
    // latency = network + queue + (cold parts) + predict; verify the
    // identity on every sample of a probe.
    let clock = ManualClock::new();
    let p = Invoker::new(PlatformConfig::default(), fast_engine(), clock);
    p.deploy("a", "squeezenet", "pallas", 512).unwrap();
    let report = run_closed_loop(&p, "a", &WarmProbe::default(), 5);
    for s in report.ok_samples() {
        assert!(s.latency >= s.predict, "{s:?}");
        // network floor: rtt 35 ms.
        assert!(s.latency - s.predict >= Duration::from_millis(35), "{s:?}");
    }
}
