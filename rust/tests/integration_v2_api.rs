//! End-to-end v2 API integration: gateway over real HTTP, driven
//! through the typed client SDK — deploy, sync + async invocation,
//! polling, reconfigure, per-function stats, undeploy, and the v1
//! shim coexistence.

use lambdaserve::configparse::{BootstrapConfig, PlatformConfig};
use lambdaserve::gateway::{ApiClient, DeploySpec, Gateway, ReconfigureSpec};
use lambdaserve::httpd::http_get;
use lambdaserve::platform::Invoker;
use lambdaserve::runtime::{MockEngine, MockModelCosts};
use lambdaserve::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

fn fast_platform() -> Arc<Invoker> {
    let engine = Arc::new(MockEngine::new(vec![
        MockModelCosts::paper_like("squeezenet", 2, 5.0, 85),
        MockModelCosts::paper_like("resnet18", 4, 46.7, 229),
    ]));
    let config = PlatformConfig {
        bootstrap: BootstrapConfig { simulate_delays: false, ..Default::default() },
        ..Default::default()
    };
    Arc::new(Invoker::live(config, engine))
}

fn start_gateway() -> (String, lambdaserve::httpd::ShutdownHandle, std::thread::JoinHandle<()>) {
    let gw = Gateway::bind("127.0.0.1:0", 8, fast_platform()).unwrap();
    let addr = gw.local_addr().to_string();
    let sh = gw.shutdown_handle();
    let t = std::thread::spawn(move || gw.serve().unwrap());
    (addr, sh, t)
}

#[test]
fn sdk_full_lifecycle_sync_and_async() {
    let (addr, sh, t) = start_gateway();
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(10));

    api.health().unwrap();

    // Deploy with the full v2 spec.
    let f = api
        .deploy(&DeploySpec::new("sq", "squeezenet").memory_mb(1024).min_warm(1))
        .unwrap();
    assert_eq!(f.name, "sq");
    assert_eq!(f.memory_mb, 1024);
    assert_eq!(f.min_warm, 1);
    assert_eq!(f.warm_containers, 1, "min_warm pre-provisioned");

    // Duplicate deploy -> 409 typed error.
    let dup = api.deploy(&DeploySpec::new("sq", "squeezenet")).unwrap_err();
    assert_eq!(dup.status, 409);
    assert_eq!(dup.code, "already_exists");

    // Sync invoke: pre-warmed, so the first start is warm.
    let r1 = api.invoke("sq", Some(7)).unwrap();
    assert_eq!(r1.start, "warm");
    assert!(r1.billed_ms > 0);
    assert!(r1.response_s > 0.0);

    // Async invoke: 202 + id, poll to completion through the SDK.
    let id = api.invoke_async("sq", Some(8)).unwrap();
    assert!(id.starts_with("inv-"));
    let done = api
        .wait_invocation(&id, Duration::from_millis(2), Duration::from_secs(20))
        .unwrap();
    assert_eq!(done.status, "done");
    assert_eq!(done.function, "sq");
    let result = done.result.expect("completed result");
    assert!(result.start == "warm" || result.start == "cold");
    assert!(result.billed_ms > 0);
    assert!(result.cost_dollars > 0.0);

    // Per-function stats reflect both invocations.
    let stats = api.stats("sq").unwrap();
    assert_eq!(stats.invocations, 2);
    assert_eq!(stats.cold_starts + stats.warm_starts, 2);
    assert_eq!(stats.throttled, 0);
    assert!(stats.billed_ms_total >= r1.billed_ms);
    assert!(stats.cost_dollars_total > 0.0);
    assert!(stats.response_mean_s > 0.0);
    // Cold/warm split percentiles: the sync invocation was warm, so
    // the warm histogram is populated; the cold histogram is empty
    // unless the async run went cold.
    assert!(stats.response_warm_p50_s > 0.0);
    assert!(stats.response_warm_p99_s >= stats.response_warm_p50_s);
    if stats.cold_starts == 0 {
        assert_eq!(stats.response_cold_p99_s, 0.0, "empty cold histogram reads as zero");
    }

    // List shows exactly our function.
    let fns = api.functions().unwrap();
    assert_eq!(fns.len(), 1);
    assert_eq!(fns[0].name, "sq");

    // Reconfigure: bump memory AND clear the pre-warm target (else
    // the new min_warm would be re-provisioned at the new spec and
    // the next invocation would be warm); old containers cycle.
    let f = api
        .reconfigure(
            "sq",
            &ReconfigureSpec {
                memory_mb: Some(1536),
                min_warm: Some(0),
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(f.memory_mb, 1536);
    assert_eq!(f.min_warm, 0);
    let r = api.invoke("sq", Some(9)).unwrap();
    assert_eq!(r.start, "cold", "stale warm containers evicted on reconfigure");

    // Undeploy, then everything 404s.
    api.undeploy("sq").unwrap();
    let err = api.invoke("sq", Some(1)).unwrap_err();
    assert_eq!(err.status, 404);
    assert_eq!(err.code, "not_found");
    let err = api.function("sq").unwrap_err();
    assert_eq!(err.status, 404);
    let err = api.undeploy("sq").unwrap_err();
    assert_eq!(err.status, 404);

    sh.shutdown();
    t.join().unwrap();
}

#[test]
fn sdk_async_errors_and_expiry_semantics() {
    let (addr, sh, t) = start_gateway();
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(10));

    // Async submit for an unknown function fails at submit time.
    let err = api.invoke_async("ghost", None).unwrap_err();
    assert_eq!(err.status, 404);

    // Unknown invocation id -> 404.
    let err = api.invocation("inv-ffffffff").unwrap_err();
    assert_eq!(err.status, 404);

    // A function undeployed with jobs still queued surfaces "failed"
    // results rather than losing them.
    api.deploy(&DeploySpec::new("rn", "resnet18").memory_mb(512)).unwrap();
    let id = api.invoke_async("rn", Some(1)).unwrap();
    let done = api
        .wait_invocation(&id, Duration::from_millis(2), Duration::from_secs(20))
        .unwrap();
    assert_eq!(done.status, "done");

    sh.shutdown();
    t.join().unwrap();
}

#[test]
fn v1_and_v2_share_one_platform() {
    let (addr, sh, t) = start_gateway();
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(10));
    let tmo = Duration::from_secs(10);

    // Deploy through v2, invoke through the v1 GET shim.
    api.deploy(&DeploySpec::new("sq", "squeezenet").memory_mb(1024)).unwrap();
    let r = http_get(&addr, "/v1/invoke/sq?seed=1", tmo).unwrap();
    assert_eq!(r.status, 200);
    let j = Json::parse(&r.body_str()).unwrap();
    assert_eq!(j.get("start").unwrap().as_str(), Some("cold"));

    // The v1 invocation shows up in v2 per-function stats.
    let stats = api.stats("sq").unwrap();
    assert_eq!(stats.invocations, 1);
    assert_eq!(stats.cold_starts, 1);

    // And the v1 global stats see the same platform.
    let r = http_get(&addr, "/v1/stats", tmo).unwrap();
    let j = Json::parse(&r.body_str()).unwrap();
    assert_eq!(j.get("invocations").unwrap().as_u64(), Some(1));

    sh.shutdown();
    t.join().unwrap();
}

/// Pre-warm provisioning is operator-paid capacity, not a cold start:
/// `/v2/stats` must report the two supply sides separately, and a
/// request served by a pre-warmed container keeps the request-visible
/// cold-start rate at zero.
#[test]
fn platform_stats_split_provision_sources() {
    let (addr, sh, t) = start_gateway();
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(10));
    let tmo = Duration::from_secs(10);

    api.deploy(&DeploySpec::new("sq", "squeezenet").memory_mb(1024).min_warm(2)).unwrap();
    let r = api.invoke("sq", Some(1)).unwrap();
    assert_eq!(r.start, "warm");

    let resp = http_get(&addr, "/v2/stats", tmo).unwrap();
    assert_eq!(resp.status, 200);
    let j = Json::parse(&resp.body_str()).unwrap();
    assert_eq!(j.get("invocations").unwrap().as_u64(), Some(1));
    assert_eq!(j.get("cold_starts").unwrap().as_u64(), Some(0), "prewarm is not a cold start");
    assert_eq!(j.get("warm_starts").unwrap().as_u64(), Some(1));
    assert_eq!(j.get("cold_provisions").unwrap().as_u64(), Some(0));
    assert!(j.get("prewarm_provisions").unwrap().as_u64().unwrap() >= 2);
    // Cold/warm split percentiles are served platform-wide too.
    assert!(j.get("response_warm_p50_s").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(j.get("response_cold_p99_s").unwrap().as_f64(), Some(0.0));

    sh.shutdown();
    t.join().unwrap();
}

/// Admission-queue config: deploy-time overrides round-trip through
/// the SDK, PATCH can set and clear them (null = platform default),
/// and the stats surfaces expose the new saturation fields.
#[test]
fn queue_config_roundtrip_and_stats_fields() {
    let (addr, sh, t) = start_gateway();
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(10));

    let f = api
        .deploy(
            &DeploySpec::new("sq", "squeezenet")
                .memory_mb(1024)
                .queue_capacity(5)
                .queue_deadline_ms(1500),
        )
        .unwrap();
    assert_eq!(f.queue_capacity, Some(5));
    assert_eq!(f.queue_deadline_ms, Some(1500));

    // PATCH: change the deadline, keep the capacity.
    let f = api
        .reconfigure(
            "sq",
            &ReconfigureSpec { queue_deadline_ms: Some(Some(800)), ..Default::default() },
        )
        .unwrap();
    assert_eq!(f.queue_capacity, Some(5), "untouched override kept");
    assert_eq!(f.queue_deadline_ms, Some(800));

    // PATCH null: revert both to the platform defaults.
    let f = api
        .reconfigure(
            "sq",
            &ReconfigureSpec {
                queue_capacity: Some(None),
                queue_deadline_ms: Some(None),
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(f.queue_capacity, None);
    assert_eq!(f.queue_deadline_ms, None);

    // An out-of-range deadline override is rejected at deploy time.
    let err = api
        .deploy(&DeploySpec::new("bad", "squeezenet").memory_mb(512).queue_deadline_ms(7_200_000))
        .unwrap_err();
    assert_eq!(err.status, 400);

    // Typed stats carry the queue fields on both surfaces.
    api.invoke("sq", Some(1)).unwrap();
    let s = api.stats("sq").unwrap();
    assert_eq!(s.invocations, 1);
    assert_eq!(s.queue_depth, 0);
    assert_eq!(s.queue_expired, 0);
    assert!(s.queue_wait_p99_s >= 0.0);
    let ps = api.platform_stats().unwrap();
    assert_eq!(ps.invocations, 1);
    assert_eq!(ps.queue_depth, 0);
    assert_eq!(ps.queue_deadline_expired, 0);
    assert_eq!(ps.saturated, 0);
    assert!(ps.queue_depth_peak <= 1, "uncontended invoke barely queued");
    assert_eq!(ps.containers_alive, 1);

    sh.shutdown();
    t.join().unwrap();
}

/// Micro-batching config: deploy-time overrides round-trip through
/// the SDK and the function resource JSON, PATCH can set and clear
/// them (null = platform default), invalid values are rejected, and
/// the new stats fields are served on both surfaces (zero off the
/// batching path).
#[test]
fn batching_config_roundtrip_and_stats_fields() {
    let (addr, sh, t) = start_gateway();
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(10));

    let f = api
        .deploy(
            &DeploySpec::new("sq", "squeezenet")
                .memory_mb(1024)
                .max_batch_size(4)
                .batch_window_ms(40),
        )
        .unwrap();
    assert_eq!(f.max_batch_size, Some(4));
    assert_eq!(f.batch_window_ms, Some(40));

    // PATCH: shrink the window, keep the size override.
    let f = api
        .reconfigure(
            "sq",
            &ReconfigureSpec { batch_window_ms: Some(Some(10)), ..Default::default() },
        )
        .unwrap();
    assert_eq!(f.max_batch_size, Some(4), "untouched override kept");
    assert_eq!(f.batch_window_ms, Some(10));

    // PATCH null: revert both to the platform defaults (batching off).
    let f = api
        .reconfigure(
            "sq",
            &ReconfigureSpec {
                max_batch_size: Some(None),
                batch_window_ms: Some(None),
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(f.max_batch_size, None);
    assert_eq!(f.batch_window_ms, None);

    // A zero batch size is rejected (1 is "off"; 0 is a config bug).
    let err = api
        .deploy(&DeploySpec::new("bad", "squeezenet").memory_mb(512).max_batch_size(0))
        .unwrap_err();
    assert_eq!(err.status, 400);

    // Solo invocations carry the unbatched markers and the stats
    // fields read zero on both surfaces.
    let r = api.invoke("sq", Some(1)).unwrap();
    assert_eq!(r.batch_size, 1);
    assert_eq!(r.batch_wait_s, 0.0);
    let s = api.stats("sq").unwrap();
    assert_eq!(s.batched_requests, 0);
    assert_eq!(s.batched_share, 0.0);
    assert_eq!(s.batch_size_p99, 0);
    assert_eq!(s.batch_wait_p99_s, 0.0);
    let ps = api.platform_stats().unwrap();
    assert_eq!(ps.batches_executed, 0);
    assert_eq!(ps.largest_batch, 0);
    assert_eq!(ps.batched_requests, 0);

    sh.shutdown();
    t.join().unwrap();
}

#[test]
fn per_function_concurrency_cap_is_enforced_over_http() {
    let (addr, sh, t) = start_gateway();
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(30));

    // Cap rn at 1 concurrent invocation and flood it asynchronously:
    // the cap throttles concurrent workers, but accepted (202) jobs
    // are requeued with backoff, so every one must complete.
    api.deploy(&DeploySpec::new("rn", "resnet18").memory_mb(1024).max_concurrency(1)).unwrap();
    let ids: Vec<String> = (0..4).map(|i| api.invoke_async("rn", Some(i)).unwrap()).collect();
    for id in &ids {
        let s = api
            .wait_invocation(id, Duration::from_millis(2), Duration::from_secs(30))
            .unwrap();
        assert_eq!(s.status, "done", "invocation {id}: {:?}", s.error);
        assert!(s.result.is_some());
    }

    // A sync burst against the same cap still sees 429s: the cap
    // check precedes admission — the dispatch queue absorbs capacity
    // pressure, not concurrency-cap violations.
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(30));
                api.invoke("rn", Some(100 + i))
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let ok = results.iter().filter(|r| r.is_ok()).count();
    let throttled = results
        .iter()
        .filter(|r| matches!(r, Err(e) if e.status == 429 && e.code == "throttled"))
        .count();
    assert_eq!(ok + throttled, 4, "only 200s and 429s expected: {results:?}");
    assert!(ok >= 1, "at least one sync invocation must get through");

    // The 429s are attributed to the function's own stats shard (the
    // async workers' transient cap hits land there too).
    let stats = api.stats("rn").unwrap();
    assert!(stats.throttled >= throttled as u64, "sync 429s counted per function");
    assert_eq!(stats.invocations, 4 + ok as u64, "completed async + successful sync");

    sh.shutdown();
    t.join().unwrap();
}

/// Snapshot/restore over HTTP: the per-function `snapshot` override
/// round-trips through deploy/PATCH (tri-state null), a forced-cold
/// invocation restores from the checkpoint the first cold seeded, and
/// both stats routes serve the snapshot gauges + the per-component
/// provision percentiles.
#[test]
fn snapshot_roundtrip_restore_and_stats_fields_over_http() {
    use lambdaserve::configparse::CapturePolicy;
    let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
        "squeezenet",
        2,
        5.0,
        85,
    )]));
    let mut config = PlatformConfig {
        bootstrap: BootstrapConfig { simulate_delays: false, ..Default::default() },
        ..Default::default()
    };
    config.snapshot.enabled = true;
    config.snapshot.capture_policy = CapturePolicy::Sync;
    // Keep a platform handle so the test can force warm-pool misses.
    let p = Arc::new(Invoker::live(config, engine));
    let gw = Gateway::bind("127.0.0.1:0", 8, p.clone()).unwrap();
    let addr = gw.local_addr().to_string();
    let sh = gw.shutdown_handle();
    let t = std::thread::spawn(move || gw.serve().unwrap());
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(10));

    // Override round-trip: explicit false, PATCH to true, null clears.
    let f = api
        .deploy(&DeploySpec::new("sq", "squeezenet").memory_mb(1024).snapshot(false))
        .unwrap();
    assert_eq!(f.snapshot, Some(false));
    let f = api
        .reconfigure("sq", &ReconfigureSpec { snapshot: Some(Some(true)), ..Default::default() })
        .unwrap();
    assert_eq!(f.snapshot, Some(true));
    let f = api
        .reconfigure("sq", &ReconfigureSpec { snapshot: Some(None), ..Default::default() })
        .unwrap();
    assert_eq!(f.snapshot, None, "null clears to the (enabled) platform default");

    // First invocation: full cold + sync capture.
    let r = api.invoke("sq", Some(1)).unwrap();
    assert_eq!(r.start, "cold");
    // Force the next provision to miss the warm pool, then restore.
    p.evict_all();
    let r = api.invoke("sq", Some(2)).unwrap();
    assert_eq!(r.start, "restored");
    assert!(r.response_s > 0.0);

    // Function route: restored split + component percentiles + gauges.
    let s = api.stats("sq").unwrap();
    assert_eq!(s.invocations, 2);
    assert_eq!(s.cold_starts, 1);
    assert_eq!(s.restored_starts, 1);
    assert_eq!(s.warm_starts, 0);
    assert!(s.response_restored_p99_s > 0.0);
    assert!(s.response_restored_p99_s < s.response_cold_p99_s, "restored beats cold");
    assert!(s.provision_model_load_p99_s > 0.0, "the cold start's real compile+init");
    assert!(s.provision_restore_p99_s > 0.0, "the restored start's weight upload");
    assert_eq!(s.provision_runtime_init_p99_s, 0.0, "simulate_delays off");
    assert_eq!(s.snapshot_hits, 1);
    assert_eq!(s.snapshot_misses, 1);
    assert_eq!(s.snapshot_captures, 1);
    assert_eq!(s.snapshot_evictions, 0);
    assert_eq!(s.snapshot_bytes, 5_000_000, "squeezenet weights stored");

    // Platform route: same gauges + the provision-source split.
    let ps = api.platform_stats().unwrap();
    assert_eq!(ps.restored_starts, 1);
    assert_eq!(ps.cold_provisions, 1);
    assert_eq!(ps.restored_provisions, 1);
    assert_eq!(ps.snapshot_hits, 1);
    assert_eq!(ps.snapshot_captures, 1);
    assert_eq!(ps.snapshot_bytes, 5_000_000);
    assert_eq!(ps.snapshot_stale, 0);

    // Undeploy invalidates the shape's snapshot: stale counted, bytes
    // released.
    api.undeploy("sq").unwrap();
    let ps = api.platform_stats().unwrap();
    assert_eq!(ps.snapshot_stale, 1);
    assert_eq!(ps.snapshot_bytes, 0);

    sh.shutdown();
    t.join().unwrap();
}

// ---- invocation tracing over HTTP ----

/// A trace-enabled gateway on a ManualClock: the simulated provision
/// delays advance virtual time, so every span duration is exact.
/// `maintainer_interval_s = 0` keeps the background sweeper off the
/// virtual clock.
fn traced_manual_gateway(
    sample_rate: f64,
) -> (
    String,
    Arc<Invoker>,
    lambdaserve::httpd::ShutdownHandle,
    std::thread::JoinHandle<()>,
) {
    let engine = Arc::new(MockEngine::paper_zoo());
    let clock = lambdaserve::util::ManualClock::new();
    let mut config = PlatformConfig { maintainer_interval_s: 0.0, ..Default::default() };
    config.trace.enabled = true;
    config.trace.sample_rate = sample_rate;
    let p = Arc::new(Invoker::new(config, engine, clock));
    let gw = Gateway::bind("127.0.0.1:0", 8, p.clone()).unwrap();
    let addr = gw.local_addr().to_string();
    let sh = gw.shutdown_handle();
    let t = std::thread::spawn(move || gw.serve().unwrap());
    (addr, p, sh, t)
}

/// Acceptance: over real HTTP on a ManualClock, the cold invocation's
/// trace reports provision children that match the per-component
/// provision percentiles on the function stats route exactly (one
/// cold start, so p50 IS that start's cost), and the duration-bearing
/// spans sum to the reported response.
#[test]
fn cold_trace_provision_children_match_stats_over_http() {
    let (addr, _p, sh, t) = traced_manual_gateway(1.0);
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(30));

    api.deploy(&DeploySpec::new("sq", "squeezenet").memory_mb(1024)).unwrap();
    let r = api.invoke("sq", Some(1)).unwrap();
    assert_eq!(r.start, "cold");
    let trace_id = r.trace_id.expect("trace id minted while tracing is on");
    assert!(trace_id.starts_with("tr-"));

    let trace = api.invocation_trace(&trace_id).unwrap();
    assert_eq!(trace.trace_id, trace_id);
    assert_eq!(trace.function, "sq");
    assert_eq!(trace.start, "cold");
    let child = |stage: &str| {
        let s = trace
            .spans
            .iter()
            .find(|s| s.stage == stage)
            .unwrap_or_else(|| panic!("missing span {stage}"));
        assert_eq!(s.parent.as_deref(), Some("provision"), "{stage} nests under provision");
        s.duration_s
    };
    let stats = api.stats("sq").unwrap();
    let close = |a: f64, b: f64| (a - b).abs() < 1e-9;
    assert!(close(child("sandbox"), stats.provision_sandbox_p50_s));
    assert!(close(child("runtime_init"), stats.provision_runtime_init_p50_s));
    assert!(close(child("package_fetch"), stats.provision_package_fetch_p50_s));
    assert!(close(child("model_load"), stats.provision_model_load_p50_s));
    // Full cold start: nothing restored, and the kernel_exec note
    // carries the rung annotation.
    assert!(close(child("restore"), 0.0));
    let exec = trace.spans.iter().find(|s| s.stage == "kernel_exec").unwrap();
    assert!(exec.note.as_deref().unwrap().contains("kernel_batch_n="), "{:?}", exec.note);
    // Span-sum identity, reconstructed from the wire: every span
    // except the provision parent, the admission marker, and billing.
    let sum: f64 = trace
        .spans
        .iter()
        .filter(|s| !matches!(s.stage.as_str(), "provision" | "admission" | "billing"))
        .map(|s| s.duration_s)
        .sum();
    assert!(close(sum, trace.response_s), "sum={sum} response={}", trace.response_s);
    assert!(close(trace.response_s, r.response_s));

    // The stats routes carry the ring gauges.
    assert_eq!(stats.traces_retained, 1);
    assert_eq!(stats.traces_sampled_out, 0);
    assert!(stats.trace_ring_bytes > 0);
    let ps = api.platform_stats().unwrap();
    assert_eq!(ps.traces_retained, 1);
    assert!(ps.trace_ring_bytes > 0);

    sh.shutdown();
    t.join().unwrap();
}

/// Acceptance: a burst against a tight SLO retains every violator in
/// the exemplar ring, while steady traffic at `sample_rate = 0` is
/// sampled out (only the tail-interesting cold exemplar survives).
#[test]
fn burst_retains_slo_violators_and_samples_out_steady() {
    let (addr, p, sh, t) = traced_manual_gateway(0.0);
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(30));

    // "tight": a 1 ms budget every real invocation blows. "steady": a
    // 60 s budget even the simulated cold start sits well under, so
    // nothing there is SLO-interesting.
    api.deploy(&DeploySpec::new("tight", "squeezenet").memory_mb(1024).slo_target_ms(1))
        .unwrap();
    api.deploy(&DeploySpec::new("steady", "squeezenet").memory_mb(1024).slo_target_ms(60_000))
        .unwrap();
    for i in 0..8 {
        api.invoke("tight", Some(i)).unwrap();
        api.invoke("steady", Some(i)).unwrap();
    }

    // Every violator retained: 1 cold + 7 warm, all over 1 ms.
    let slow = api.function_traces("tight", Some("slow"), Some(100)).unwrap();
    assert_eq!(slow.len(), 8, "all SLO violators kept despite sample_rate = 0");
    assert!(slow.iter().all(|tr| tr.slo_violation && tr.slo_target_ms == 1));

    // Steady traffic: only the cold exemplar survives the zero rate.
    let kept = api.function_traces("steady", None, Some(100)).unwrap();
    assert_eq!(kept.len(), 1);
    assert_eq!(kept[0].kind, "cold");
    assert_eq!(api.function_traces("steady", Some("slow"), Some(100)).unwrap().len(), 0);
    assert_eq!(p.trace.retained(), 9);
    assert_eq!(p.trace.sampled_out(), 7, "the steady warm invocations");
    let ps = api.platform_stats().unwrap();
    assert_eq!(ps.traces_retained, 9);
    assert_eq!(ps.traces_sampled_out, 7);

    sh.shutdown();
    t.join().unwrap();
}

/// Trace route plumbing: async `inv-…` ids resolve through the result
/// store to the same trace, bad query parameters are 400s, and a
/// trace-disabled platform answers 404 `tracing_disabled` (while the
/// invocation response carries a null trace id).
#[test]
fn trace_route_resolution_and_validation() {
    let (addr, _p, sh, t) = traced_manual_gateway(1.0);
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(30));
    let tmo = Duration::from_secs(10);

    api.deploy(&DeploySpec::new("sq", "squeezenet").memory_mb(1024)).unwrap();
    let id = api.invoke_async("sq", Some(1)).unwrap();
    let done = api
        .wait_invocation(&id, Duration::from_millis(2), Duration::from_secs(30))
        .unwrap();
    let trace_id = done.result.unwrap().trace_id.expect("async result carries the trace id");

    // Both spellings resolve to the same retained trace.
    let by_inv = api.invocation_trace(&id).unwrap();
    let by_tr = api.invocation_trace(&trace_id).unwrap();
    assert_eq!(by_inv.trace_id, trace_id);
    assert_eq!(by_tr.trace_id, trace_id);
    assert_eq!(by_inv.spans.len(), by_tr.spans.len());
    // The async hop is visible: a non-zero admission span precedes
    // the queue wait.
    assert_eq!(by_inv.spans[0].stage, "admission");

    // Unknown ids and bad parameters.
    let err = api.invocation_trace("tr-ffffffff").unwrap_err();
    assert_eq!(err.status, 404);
    let err = api.invocation_trace("inv-ffffffff").unwrap_err();
    assert_eq!(err.status, 404);
    let err = api.function_traces("sq", Some("lukewarm"), None).unwrap_err();
    assert_eq!((err.status, err.code.as_str()), (400, "invalid_kind"));
    let r = http_get(&addr, "/v2/functions/sq/traces?limit=0", tmo).unwrap();
    assert_eq!(r.status, 400);
    let err = api.function_traces("ghost", None, None).unwrap_err();
    assert_eq!(err.status, 404);

    sh.shutdown();
    t.join().unwrap();

    // Tracing off (the default gateway): null trace ids, 404s with the
    // dedicated code on both routes.
    let (addr, sh, t) = start_gateway();
    let api = ApiClient::new(&addr).with_timeout(Duration::from_secs(10));
    api.deploy(&DeploySpec::new("sq", "squeezenet").memory_mb(1024)).unwrap();
    let r = api.invoke("sq", Some(1)).unwrap();
    assert_eq!(r.trace_id, None, "no trace id while tracing is off");
    let err = api.invocation_trace("tr-00000001").unwrap_err();
    assert_eq!((err.status, err.code.as_str()), (404, "tracing_disabled"));
    let err = api.function_traces("sq", None, None).unwrap_err();
    assert_eq!((err.status, err.code.as_str()), (404, "tracing_disabled"));
    let s = api.stats("sq").unwrap();
    assert_eq!((s.traces_retained, s.traces_sampled_out, s.trace_ring_bytes), (0, 0, 0));

    sh.shutdown();
    t.join().unwrap();
}
