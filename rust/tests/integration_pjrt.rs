//! Integration tests over the REAL artifacts (run `make artifacts`
//! first): PJRT engine round-trip, platform cold/warm semantics on
//! real inference, and pallas-vs-ref numeric agreement across the
//! python/rust boundary.
//!
//! Every test here is `#[ignore]`d by default because the artifacts
//! are environment-dependent build products (JAX/Pallas AOT pipeline)
//! that the repo does not ship. Opt in with
//! `cargo test --test integration_pjrt -- --ignored` after building
//! them.
//!
//! One shared engine keeps compile cost bounded; tests take care to be
//! independent of ordering.

use lambdaserve::configparse::{BootstrapConfig, PlatformConfig};
use lambdaserve::platform::{Invoker, StartKind};
use lambdaserve::runtime::{Engine, PjrtEngine};
use lambdaserve::util::{Clock as _, ManualClock};
use std::path::Path;
use std::sync::{Arc, OnceLock};

fn artifacts_dir() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").leak()
}

trait Leak {
    fn leak(self) -> &'static Path;
}

impl Leak for std::path::PathBuf {
    fn leak(self) -> &'static Path {
        Box::leak(self.into_boxed_path())
    }
}

fn shared_engine() -> Arc<PjrtEngine> {
    static ENGINE: OnceLock<Arc<PjrtEngine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            Arc::new(
                PjrtEngine::new(artifacts_dir(), 1)
                    .expect("run `make artifacts` before `cargo test`"),
            )
        })
        .clone()
}

#[test]
#[ignore = "requires real AOT artifacts (run `make artifacts` with the JAX/Pallas toolchain first); the repo ships without them"]
fn zoo_lists_three_paper_models() {
    let engine = shared_engine();
    for (name, size_mb, peak) in
        [("squeezenet", 5.0, 85), ("resnet18", 46.7, 229), ("resnext50", 100.0, 429)]
    {
        let m = engine.manifest(name).unwrap();
        assert!((m.param_bytes as f64 / 1e6 - size_mb).abs() < 1.0, "{name}");
        assert_eq!(m.paper_peak_mem_mb, peak);
        assert_eq!(m.input_shape, vec![1, 224, 224, 3]);
        assert!(m.artifacts.contains_key("pallas") && m.artifacts.contains_key("ref"));
    }
}

#[test]
#[ignore = "requires real AOT artifacts (run `make artifacts` with the JAX/Pallas toolchain first); the repo ships without them"]
fn squeezenet_predict_roundtrip() {
    let engine = shared_engine();
    let (h, stats) = engine.create_instance("squeezenet", "pallas").unwrap();
    // Weight bytes match the manifest (real init ran).
    assert_eq!(stats.weight_bytes, engine.manifest("squeezenet").unwrap().param_bytes);
    assert!(stats.init_run.as_secs_f64() > 0.0);

    let p1 = engine.predict(&h, 42).unwrap();
    assert!((0..1000).contains(&p1.top1));
    assert!(p1.top_prob > 0.0 && p1.top_prob <= 1.0);
    assert!(p1.compute.as_secs_f64() > 0.001, "real compute happened");

    // Same seed -> identical prediction (deterministic artifact).
    let p2 = engine.predict(&h, 42).unwrap();
    assert_eq!(p1.top1, p2.top1);
    assert_eq!(p1.top_prob, p2.top_prob);

    engine.drop_instance(&h);
}

#[test]
#[ignore = "requires real AOT artifacts (run `make artifacts` with the JAX/Pallas toolchain first); the repo ships without them"]
fn pallas_and_ref_artifacts_agree() {
    // The L1 correctness signal ACROSS the language boundary: the
    // artifact with Pallas kernels and the pure-XLA reference must
    // classify identically (same weights, same image).
    let engine = shared_engine();
    let (hp, _) = engine.create_instance("squeezenet", "pallas").unwrap();
    let (hr, _) = engine.create_instance("squeezenet", "ref").unwrap();
    for seed in [1u64, 7, 99] {
        let a = engine.predict(&hp, seed).unwrap();
        let b = engine.predict(&hr, seed).unwrap();
        assert_eq!(a.top1, b.top1, "seed {seed}");
        assert!((a.top_prob - b.top_prob).abs() < 1e-3, "seed {seed}");
    }
    engine.drop_instance(&hp);
    engine.drop_instance(&hr);
}

#[test]
#[ignore = "requires real AOT artifacts (run `make artifacts` with the JAX/Pallas toolchain first); the repo ships without them"]
fn platform_cold_warm_on_real_inference() {
    let engine = shared_engine();
    let clock = ManualClock::new();
    let config = PlatformConfig {
        bootstrap: BootstrapConfig::default(),
        ..Default::default()
    };
    let p = Invoker::new(config, engine.clone(), clock.clone());
    p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();

    let cold = p.invoke("sq", 1).unwrap();
    assert_eq!(cold.record.start, StartKind::Cold);
    assert!(cold.record.model_load.as_secs_f64() > 0.0, "real model load counted");
    assert!(cold.record.predict > cold.record.predict_full_speed, "throttled at 1024 MB");

    let warm = p.invoke("sq", 2).unwrap();
    assert_eq!(warm.record.start, StartKind::Warm);
    assert!(warm.record.response() < cold.record.response());

    // 10-minute gap (manual clock) -> eviction -> cold again.
    clock.sleep(std::time::Duration::from_secs(600));
    let again = p.invoke("sq", 3).unwrap();
    assert_eq!(again.record.start, StartKind::Cold);
}

#[test]
#[ignore = "requires real AOT artifacts (run `make artifacts` with the JAX/Pallas toolchain first); the repo ships without them"]
fn throttle_scales_real_predict_time() {
    let engine = shared_engine();
    let clock = ManualClock::new();
    let p = Invoker::new(PlatformConfig::default(), engine.clone(), clock);
    p.deploy("small", "squeezenet", "pallas", 256).unwrap();
    p.deploy("big", "squeezenet", "pallas", 1536).unwrap();
    p.invoke("small", 0).unwrap();
    p.invoke("big", 0).unwrap();
    let small = p.invoke("small", 5).unwrap().record;
    let big = p.invoke("big", 5).unwrap().record;
    let ratio = small.predict.as_secs_f64() / big.predict.as_secs_f64();
    // share ratio = 1536/256 = 6, modulo real-compute jitter.
    assert!(ratio > 3.0, "memory throttling visible on real compute: {ratio}");
}
