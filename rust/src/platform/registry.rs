//! Function registry: deployment records and validation.
//!
//! A deployed function = (name, model, artifact variant, memory size).
//! Deployment enforces the paper's observed constraints: memory must be
//! a valid Lambda tier and at least the function's measured peak usage
//! (85/229/429 MB for the three models) — this reproduces the missing
//! small-memory data points in Figures 2-6.

use crate::configparse::MemorySize;
use crate::runtime::Engine;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

#[derive(Debug, Clone, PartialEq)]
pub struct FunctionSpec {
    /// Function name (route key at the gateway).
    pub name: String,
    /// Zoo model this function serves.
    pub model: String,
    /// Artifact variant ("pallas" | "ref").
    pub variant: String,
    /// Configured memory size, MB.
    pub memory_mb: MemorySize,
    /// Peak memory required to run (from the manifest).
    pub peak_mem_mb: u32,
    /// Deployment package bytes (model + code), for cold-start I/O.
    pub package_bytes: u64,
    /// Warm-pool policy: containers pre-warmed at deploy/reconfigure
    /// (the paper's §5 "keep warm" knob, now part of the spec).
    pub min_warm: usize,
    /// Per-function in-flight cap; `None` leaves only the account-wide
    /// container cap.
    pub max_concurrency: Option<usize>,
    /// Admission-queue depth override for this function; `None` falls
    /// back to `platform.queue_capacity`.
    pub queue_capacity: Option<usize>,
    /// Admission-deadline override in milliseconds; `None` falls back
    /// to `platform.queue_deadline_ms`.
    pub queue_deadline_ms: Option<u64>,
    /// Micro-batching override: max requests coalesced into one
    /// batched forward pass; `None` falls back to
    /// `platform.max_batch_size` (1 = batching off).
    pub max_batch_size: Option<usize>,
    /// Micro-batching override: how long a batch leader holds its
    /// container open for followers, in milliseconds; `None` falls
    /// back to `platform.batch_window_ms`.
    pub batch_window_ms: Option<u64>,
    /// Snapshot/restore override: `Some(true/false)` forces the
    /// checkpoint-restore cold path on/off for this function; `None`
    /// falls back to `platform.snapshot.enabled`.
    pub snapshot: Option<bool>,
    /// End-to-end latency SLO for this function, milliseconds; the
    /// adaptive batch-window controller defends it. `None` falls back
    /// to `policy.slo_target_ms`.
    pub slo_target_ms: Option<u64>,
    /// Adaptive-controller override: `Some(true/false)` forces the
    /// policy engine's feedback loops on/off for this function; `None`
    /// falls back to `policy.enabled`.
    pub adaptive: Option<bool>,
}

/// Deploy-time policy knobs (everything beyond the identity tuple
/// `name/model/variant/memory`): warm-pool target, concurrency cap,
/// admission-queue overrides, micro-batching overrides. `None` fields
/// fall back to the platform-wide defaults. Grew out of the old
/// positional `deploy_full` tail, which stopped scaling at four
/// knobs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FunctionPolicy {
    pub min_warm: usize,
    pub max_concurrency: Option<usize>,
    pub queue_capacity: Option<usize>,
    pub queue_deadline_ms: Option<u64>,
    pub max_batch_size: Option<usize>,
    pub batch_window_ms: Option<u64>,
    pub snapshot: Option<bool>,
    pub slo_target_ms: Option<u64>,
    pub adaptive: Option<bool>,
}

impl FunctionPolicy {
    /// The policy embodied by an existing spec (reconfigure reads
    /// this, then overlays the patch).
    pub fn of(spec: &FunctionSpec) -> Self {
        Self {
            min_warm: spec.min_warm,
            max_concurrency: spec.max_concurrency,
            queue_capacity: spec.queue_capacity,
            queue_deadline_ms: spec.queue_deadline_ms,
            max_batch_size: spec.max_batch_size,
            batch_window_ms: spec.batch_window_ms,
            snapshot: spec.snapshot,
            slo_target_ms: spec.slo_target_ms,
            adaptive: spec.adaptive,
        }
    }
}

pub struct FunctionRegistry {
    engine: Arc<dyn Engine>,
    /// Valid configurable tiers (min, max, step): Lambda 2017 was
    /// 128..=1536 in 64 MB increments.
    mem_min: MemorySize,
    mem_max: MemorySize,
    mem_step: MemorySize,
    functions: RwLock<BTreeMap<String, Arc<FunctionSpec>>>,
}

impl FunctionRegistry {
    pub fn new(engine: Arc<dyn Engine>) -> Self {
        Self {
            engine,
            mem_min: 128,
            mem_max: 1536,
            mem_step: 64,
            functions: RwLock::new(BTreeMap::new()),
        }
    }

    /// Deploy (or redeploy) a function with default policy (no
    /// pre-warm target, no per-function concurrency cap).
    pub fn deploy(
        &self,
        name: &str,
        model: &str,
        variant: &str,
        memory_mb: MemorySize,
    ) -> Result<Arc<FunctionSpec>> {
        self.deploy_full(name, model, variant, memory_mb, FunctionPolicy::default())
    }

    /// Deploy (or redeploy) a function. Validates the memory tier and
    /// the model's peak-memory floor against the engine's manifest.
    pub fn deploy_full(
        &self,
        name: &str,
        model: &str,
        variant: &str,
        memory_mb: MemorySize,
        policy: FunctionPolicy,
    ) -> Result<Arc<FunctionSpec>> {
        let spec = self.validated_spec(name, model, variant, memory_mb, policy)?;
        self.functions.write().unwrap().insert(name.to_string(), spec.clone());
        Ok(spec)
    }

    /// Atomic create: like [`Self::deploy_full`] but fails instead of
    /// overwriting an existing deployment (the v2 POST semantics — two
    /// racing creates cannot both succeed).
    pub fn create_full(
        &self,
        name: &str,
        model: &str,
        variant: &str,
        memory_mb: MemorySize,
        policy: FunctionPolicy,
    ) -> Result<Arc<FunctionSpec>> {
        let spec = self.validated_spec(name, model, variant, memory_mb, policy)?;
        let mut functions = self.functions.write().unwrap();
        if functions.contains_key(name) {
            bail!("function {name:?} is already deployed");
        }
        functions.insert(name.to_string(), spec.clone());
        Ok(spec)
    }

    /// Shared validation: name charset, memory tier, model manifest,
    /// peak-memory floor, concurrency cap, queue- and batch-policy
    /// sanity.
    fn validated_spec(
        &self,
        name: &str,
        model: &str,
        variant: &str,
        memory_mb: MemorySize,
        policy: FunctionPolicy,
    ) -> Result<Arc<FunctionSpec>> {
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            bail!("invalid function name {name:?}");
        }
        if memory_mb < self.mem_min
            || memory_mb > self.mem_max
            || (memory_mb - self.mem_min) % self.mem_step != 0
        {
            bail!(
                "invalid memory size {memory_mb} MB (valid: {}..={} step {})",
                self.mem_min,
                self.mem_max,
                self.mem_step
            );
        }
        let manifest = self.engine.manifest(model)?;
        if !manifest.artifacts.contains_key(variant) {
            bail!("model {model} has no artifact variant {variant:?}");
        }
        if memory_mb < manifest.paper_peak_mem_mb {
            bail!(
                "function {name}: {memory_mb} MB is below the model's peak \
                 memory requirement of {} MB (the paper could not deploy \
                 this configuration either)",
                manifest.paper_peak_mem_mb
            );
        }
        if let Some(0) = policy.max_concurrency {
            bail!("function {name}: max_concurrency must be at least 1 when set");
        }
        if let Some(ms) = policy.queue_deadline_ms {
            // Same ceiling as the platform-wide config: a parked
            // request holds a gateway worker thread for the wait.
            if ms > crate::configparse::MAX_QUEUE_DEADLINE_MS {
                bail!(
                    "function {name}: queue_deadline_ms must be at most {} (one hour)",
                    crate::configparse::MAX_QUEUE_DEADLINE_MS
                );
            }
        }
        if let Some(0) = policy.max_batch_size {
            bail!("function {name}: max_batch_size must be at least 1 when set (1 = off)");
        }
        if let Some(ms) = policy.batch_window_ms {
            // A leader holds a container AND a gateway worker thread
            // open for the window: same one-hour sanity ceiling.
            if ms > crate::configparse::MAX_QUEUE_DEADLINE_MS {
                bail!(
                    "function {name}: batch_window_ms must be at most {} (one hour)",
                    crate::configparse::MAX_QUEUE_DEADLINE_MS
                );
            }
        }
        if let Some(ms) = policy.slo_target_ms {
            // A zero SLO budget is unservable; past the ceiling it is
            // almost certainly a unit mistake, like the deadlines.
            if ms == 0 || ms > crate::configparse::MAX_QUEUE_DEADLINE_MS {
                bail!(
                    "function {name}: slo_target_ms must be in [1, {}] when set (one hour)",
                    crate::configparse::MAX_QUEUE_DEADLINE_MS
                );
            }
        }
        Ok(Arc::new(FunctionSpec {
            name: name.to_string(),
            model: model.to_string(),
            variant: variant.to_string(),
            memory_mb,
            peak_mem_mb: manifest.paper_peak_mem_mb,
            package_bytes: manifest.package_bytes(),
            min_warm: policy.min_warm,
            max_concurrency: policy.max_concurrency,
            queue_capacity: policy.queue_capacity,
            queue_deadline_ms: policy.queue_deadline_ms,
            max_batch_size: policy.max_batch_size,
            batch_window_ms: policy.batch_window_ms,
            snapshot: policy.snapshot,
            slo_target_ms: policy.slo_target_ms,
            adaptive: policy.adaptive,
        }))
    }

    pub fn get(&self, name: &str) -> Result<Arc<FunctionSpec>> {
        self.functions
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("function {name:?} is not deployed"))
    }

    pub fn remove(&self, name: &str) -> bool {
        self.functions.write().unwrap().remove(name).is_some()
    }

    pub fn list(&self) -> Vec<Arc<FunctionSpec>> {
        self.functions.read().unwrap().values().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockEngine;

    fn reg() -> FunctionRegistry {
        FunctionRegistry::new(Arc::new(MockEngine::paper_zoo()))
    }

    #[test]
    fn deploy_and_get() {
        let r = reg();
        let spec = r.deploy("sq-512", "squeezenet", "pallas", 512).unwrap();
        assert_eq!(spec.memory_mb, 512);
        assert_eq!(spec.peak_mem_mb, 85);
        assert_eq!(r.get("sq-512").unwrap(), spec);
        assert_eq!(r.list().len(), 1);
        assert!(r.remove("sq-512"));
        assert!(r.get("sq-512").is_err());
    }

    #[test]
    fn redeploy_overwrites() {
        let r = reg();
        r.deploy("f", "squeezenet", "pallas", 512).unwrap();
        r.deploy("f", "squeezenet", "pallas", 1024).unwrap();
        assert_eq!(r.get("f").unwrap().memory_mb, 1024);
        assert_eq!(r.list().len(), 1);
    }

    #[test]
    fn create_full_refuses_existing_name() {
        let r = reg();
        r.create_full("f", "squeezenet", "pallas", 512, FunctionPolicy::default()).unwrap();
        let err = r
            .create_full("f", "squeezenet", "pallas", 1024, FunctionPolicy::default())
            .unwrap_err();
        assert!(err.to_string().contains("already deployed"));
        assert_eq!(r.get("f").unwrap().memory_mb, 512, "loser must not overwrite");
        // Invalid specs are rejected before touching the map.
        assert!(r
            .create_full("g", "squeezenet", "pallas", 100, FunctionPolicy::default())
            .is_err());
        assert!(r.get("g").is_err());
    }

    #[test]
    fn memory_tier_validation() {
        let r = reg();
        assert!(r.deploy("f", "squeezenet", "pallas", 100).is_err(), "below min");
        assert!(r.deploy("f", "squeezenet", "pallas", 2048).is_err(), "above max");
        assert!(r.deploy("f", "squeezenet", "pallas", 130).is_err(), "off-step");
        assert!(r.deploy("f", "squeezenet", "pallas", 192).is_ok(), "64 MB step ok");
    }

    #[test]
    fn peak_memory_floor_matches_paper() {
        let r = reg();
        // SqueezeNet peaks at 85 MB -> deployable at 128 MB.
        assert!(r.deploy("sq", "squeezenet", "pallas", 128).is_ok());
        // ResNet-18 peaks at 229 MB -> 128 MB must fail, 256 MB works.
        assert!(r.deploy("rn", "resnet18", "pallas", 128).is_err());
        assert!(r.deploy("rn", "resnet18", "pallas", 256).is_ok());
        // ResNeXt-50 peaks at 429 MB -> first deployable tier is 448;
        // of the paper's 128-step sweep, 512 MB.
        assert!(r.deploy("rx", "resnext50", "pallas", 384).is_err());
        assert!(r.deploy("rx", "resnext50", "pallas", 512).is_ok());
    }

    #[test]
    fn deploy_full_policy_fields() {
        let r = reg();
        let spec = r
            .deploy_full(
                "sq",
                "squeezenet",
                "pallas",
                512,
                FunctionPolicy {
                    min_warm: 2,
                    max_concurrency: Some(8),
                    max_batch_size: Some(4),
                    batch_window_ms: Some(25),
                    snapshot: Some(true),
                    slo_target_ms: Some(800),
                    adaptive: Some(true),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(spec.min_warm, 2);
        assert_eq!(spec.max_concurrency, Some(8));
        assert_eq!(spec.max_batch_size, Some(4));
        assert_eq!(spec.batch_window_ms, Some(25));
        assert_eq!(spec.snapshot, Some(true));
        assert_eq!(spec.slo_target_ms, Some(800));
        assert_eq!(spec.adaptive, Some(true));
        assert_eq!(FunctionPolicy::of(&spec).slo_target_ms, Some(800));
        assert_eq!(FunctionPolicy::of(&spec).adaptive, Some(true));
        assert_eq!(FunctionPolicy::of(&spec).max_batch_size, Some(4), "policy round-trips");
        assert_eq!(FunctionPolicy::of(&spec).snapshot, Some(true));
        // Plain deploy defaults.
        let spec = r.deploy("sq2", "squeezenet", "pallas", 512).unwrap();
        assert_eq!(spec.min_warm, 0);
        assert_eq!(spec.max_concurrency, None);
        assert_eq!(spec.max_batch_size, None);
        assert_eq!(spec.batch_window_ms, None);
        assert_eq!(spec.snapshot, None, "platform default applies");
        // A zero cap would make the function uninvokable.
        let zero_cap = FunctionPolicy { max_concurrency: Some(0), ..Default::default() };
        assert!(r.deploy_full("sq3", "squeezenet", "pallas", 512, zero_cap).is_err());
        // A zero batch size is nonsense (1 is "off"); an over-ceiling
        // window is almost certainly a unit mistake.
        let zero_batch = FunctionPolicy { max_batch_size: Some(0), ..Default::default() };
        assert!(r.deploy_full("sq4", "squeezenet", "pallas", 512, zero_batch).is_err());
        let huge_window =
            FunctionPolicy { batch_window_ms: Some(4_000_000), ..Default::default() };
        assert!(r.deploy_full("sq5", "squeezenet", "pallas", 512, huge_window).is_err());
        // SLO targets get the same sanity bounds as the deadlines.
        let zero_slo = FunctionPolicy { slo_target_ms: Some(0), ..Default::default() };
        assert!(r.deploy_full("sq6", "squeezenet", "pallas", 512, zero_slo).is_err());
        let huge_slo = FunctionPolicy { slo_target_ms: Some(4_000_000), ..Default::default() };
        assert!(r.deploy_full("sq7", "squeezenet", "pallas", 512, huge_slo).is_err());
    }

    #[test]
    fn rejects_unknown_model_variant_name() {
        let r = reg();
        assert!(r.deploy("f", "vgg", "pallas", 512).is_err());
        assert!(r.deploy("f", "squeezenet", "tpu", 512).is_err());
        assert!(r.deploy("bad name!", "squeezenet", "pallas", 512).is_err());
        assert!(r.deploy("", "squeezenet", "pallas", 512).is_err());
    }
}
