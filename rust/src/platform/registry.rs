//! Function registry: deployment records and validation.
//!
//! A deployed function = (name, model, artifact variant, memory size).
//! Deployment enforces the paper's observed constraints: memory must be
//! a valid Lambda tier and at least the function's measured peak usage
//! (85/229/429 MB for the three models) — this reproduces the missing
//! small-memory data points in Figures 2-6.

use crate::configparse::MemorySize;
use crate::runtime::Engine;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

#[derive(Debug, Clone, PartialEq)]
pub struct FunctionSpec {
    /// Function name (route key at the gateway).
    pub name: String,
    /// Zoo model this function serves.
    pub model: String,
    /// Artifact variant ("pallas" | "ref").
    pub variant: String,
    /// Configured memory size, MB.
    pub memory_mb: MemorySize,
    /// Peak memory required to run (from the manifest).
    pub peak_mem_mb: u32,
    /// Deployment package bytes (model + code), for cold-start I/O.
    pub package_bytes: u64,
}

pub struct FunctionRegistry {
    engine: Arc<dyn Engine>,
    /// Valid configurable tiers (min, max, step): Lambda 2017 was
    /// 128..=1536 in 64 MB increments.
    mem_min: MemorySize,
    mem_max: MemorySize,
    mem_step: MemorySize,
    functions: RwLock<BTreeMap<String, Arc<FunctionSpec>>>,
}

impl FunctionRegistry {
    pub fn new(engine: Arc<dyn Engine>) -> Self {
        Self {
            engine,
            mem_min: 128,
            mem_max: 1536,
            mem_step: 64,
            functions: RwLock::new(BTreeMap::new()),
        }
    }

    /// Deploy (or redeploy) a function. Validates the memory tier and
    /// the model's peak-memory floor against the engine's manifest.
    pub fn deploy(
        &self,
        name: &str,
        model: &str,
        variant: &str,
        memory_mb: MemorySize,
    ) -> Result<Arc<FunctionSpec>> {
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            bail!("invalid function name {name:?}");
        }
        if memory_mb < self.mem_min
            || memory_mb > self.mem_max
            || (memory_mb - self.mem_min) % self.mem_step != 0
        {
            bail!(
                "invalid memory size {memory_mb} MB (valid: {}..={} step {})",
                self.mem_min,
                self.mem_max,
                self.mem_step
            );
        }
        let manifest = self.engine.manifest(model)?;
        if !manifest.artifacts.contains_key(variant) {
            bail!("model {model} has no artifact variant {variant:?}");
        }
        if memory_mb < manifest.paper_peak_mem_mb {
            bail!(
                "function {name}: {memory_mb} MB is below the model's peak \
                 memory requirement of {} MB (the paper could not deploy \
                 this configuration either)",
                manifest.paper_peak_mem_mb
            );
        }
        let spec = Arc::new(FunctionSpec {
            name: name.to_string(),
            model: model.to_string(),
            variant: variant.to_string(),
            memory_mb,
            peak_mem_mb: manifest.paper_peak_mem_mb,
            package_bytes: manifest.package_bytes(),
        });
        self.functions.write().unwrap().insert(name.to_string(), spec.clone());
        Ok(spec)
    }

    pub fn get(&self, name: &str) -> Result<Arc<FunctionSpec>> {
        self.functions
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("function {name:?} is not deployed"))
    }

    pub fn remove(&self, name: &str) -> bool {
        self.functions.write().unwrap().remove(name).is_some()
    }

    pub fn list(&self) -> Vec<Arc<FunctionSpec>> {
        self.functions.read().unwrap().values().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockEngine;

    fn reg() -> FunctionRegistry {
        FunctionRegistry::new(Arc::new(MockEngine::paper_zoo()))
    }

    #[test]
    fn deploy_and_get() {
        let r = reg();
        let spec = r.deploy("sq-512", "squeezenet", "pallas", 512).unwrap();
        assert_eq!(spec.memory_mb, 512);
        assert_eq!(spec.peak_mem_mb, 85);
        assert_eq!(r.get("sq-512").unwrap(), spec);
        assert_eq!(r.list().len(), 1);
        assert!(r.remove("sq-512"));
        assert!(r.get("sq-512").is_err());
    }

    #[test]
    fn redeploy_overwrites() {
        let r = reg();
        r.deploy("f", "squeezenet", "pallas", 512).unwrap();
        r.deploy("f", "squeezenet", "pallas", 1024).unwrap();
        assert_eq!(r.get("f").unwrap().memory_mb, 1024);
        assert_eq!(r.list().len(), 1);
    }

    #[test]
    fn memory_tier_validation() {
        let r = reg();
        assert!(r.deploy("f", "squeezenet", "pallas", 100).is_err(), "below min");
        assert!(r.deploy("f", "squeezenet", "pallas", 2048).is_err(), "above max");
        assert!(r.deploy("f", "squeezenet", "pallas", 130).is_err(), "off-step");
        assert!(r.deploy("f", "squeezenet", "pallas", 192).is_ok(), "64 MB step ok");
    }

    #[test]
    fn peak_memory_floor_matches_paper() {
        let r = reg();
        // SqueezeNet peaks at 85 MB -> deployable at 128 MB.
        assert!(r.deploy("sq", "squeezenet", "pallas", 128).is_ok());
        // ResNet-18 peaks at 229 MB -> 128 MB must fail, 256 MB works.
        assert!(r.deploy("rn", "resnet18", "pallas", 128).is_err());
        assert!(r.deploy("rn", "resnet18", "pallas", 256).is_ok());
        // ResNeXt-50 peaks at 429 MB -> first deployable tier is 448;
        // of the paper's 128-step sweep, 512 MB.
        assert!(r.deploy("rx", "resnext50", "pallas", 384).is_err());
        assert!(r.deploy("rx", "resnext50", "pallas", 512).is_ok());
    }

    #[test]
    fn rejects_unknown_model_variant_name() {
        let r = reg();
        assert!(r.deploy("f", "vgg", "pallas", 512).is_err());
        assert!(r.deploy("f", "squeezenet", "tpu", 512).is_err());
        assert!(r.deploy("bad name!", "squeezenet", "pallas", 512).is_err());
        assert!(r.deploy("", "squeezenet", "pallas", 512).is_err());
    }
}
