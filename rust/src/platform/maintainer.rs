//! Background warm-pool maintenance.
//!
//! The paper's §5 asks for a "keep containers warm" knob; the spec's
//! `min_warm` provides it — but pre-warmed containers still age out
//! through the keep-alive TTL, so without upkeep the operator-paid
//! warm capacity silently decays back to cold starts during idle gaps
//! (exactly the 10-minute-gap regime the paper measures). The
//! [`PoolMaintainer`] closes the loop: a background thread that on a
//! configurable tick runs the keep-alive eviction sweep and then
//! replenishes every deployed function back up to its `min_warm`
//! target through the prewarm path.
//!
//! The thread holds only a [`Weak`] platform reference (upgraded per
//! tick), stops promptly via a condvar'd flag, and joins on drop.
//! Time-virtualized tests don't need the thread at all: one tick is
//! [`Platform::maintain`], callable directly under a `ManualClock`.

use super::invoker::Platform;
use crate::util::clock::{Clock, Nanos, VirtualWaitPacer};
use crate::util::{plock, pwait_timeout};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::Duration;

/// What one maintenance tick did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MaintenanceReport {
    /// Containers reaped by the keep-alive sweep.
    pub evicted: usize,
    /// Containers provisioned to restore `min_warm` targets.
    pub replenished: usize,
}

struct Shared {
    stop: Mutex<bool>,
    cv: Condvar,
    ticks: AtomicU64,
    evicted: AtomicUsize,
    replenished: AtomicUsize,
}

/// Handle to the background maintenance thread.
pub struct PoolMaintainer {
    shared: Arc<Shared>,
    handle: Option<JoinHandle<()>>,
}

impl PoolMaintainer {
    /// Spawn the maintenance thread, ticking every `interval` of
    /// *platform* time. Under a virtual clock the timer follows the
    /// test-owned clock: wall time alone never produces a tick, and
    /// the thread never advances virtual time itself — it parks in
    /// short wall slices and re-checks the virtual deadline.
    pub fn start(platform: &Arc<Platform>, interval: Duration) -> Self {
        let shared = Arc::new(Shared {
            stop: Mutex::new(false),
            cv: Condvar::new(),
            ticks: AtomicU64::new(0),
            evicted: AtomicUsize::new(0),
            replenished: AtomicUsize::new(0),
        });
        let weak = Arc::downgrade(platform);
        let clock = Arc::clone(platform.clock());
        // First deadline is fixed before the thread runs, so a test
        // that advances a ManualClock right after start() cannot race
        // the spawn and push the first tick out by the advance amount.
        let first_deadline = clock.now().saturating_add(interval.as_nanos() as Nanos);
        let thread_shared = shared.clone();
        let handle = std::thread::Builder::new()
            .name("pool-maintainer".into())
            .spawn(move || maintainer_loop(weak, clock, interval, first_deadline, thread_shared))
            .expect("spawn pool-maintainer thread");
        Self { shared, handle: Some(handle) }
    }

    /// Completed maintenance ticks.
    pub fn ticks(&self) -> u64 {
        self.shared.ticks.load(Ordering::SeqCst)
    }

    /// Containers reaped across all ticks.
    pub fn evicted_total(&self) -> usize {
        self.shared.evicted.load(Ordering::SeqCst)
    }

    /// Containers replenished across all ticks.
    pub fn replenished_total(&self) -> usize {
        self.shared.replenished.load(Ordering::SeqCst)
    }

    /// Signal the thread to stop and join it. Idempotent.
    pub fn stop(&mut self) {
        *plock(&self.shared.stop) = true;
        self.shared.cv.notify_all();
        if let Some(handle) = self.handle.take() {
            // The thread's transient upgrade can be the LAST strong
            // platform ref, which would run this drop chain on the
            // maintainer thread itself — joining would deadlock.
            // Detaching is safe: the loop exits on the stop flag.
            if handle.thread().id() == std::thread::current().id() {
                return;
            }
            let _ = handle.join();
        }
    }
}

impl Drop for PoolMaintainer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn maintainer_loop(
    platform: Weak<Platform>,
    clock: Arc<dyn Clock>,
    interval: Duration,
    first_deadline: Nanos,
    shared: Arc<Shared>,
) {
    let interval_ns = interval.as_nanos() as Nanos;
    let mut deadline = first_deadline;
    loop {
        // Interruptible sleep until the *platform-clock* deadline: a
        // stop() mid-interval wakes us.
        {
            let mut stop = plock(&shared.stop);
            while !*stop {
                let now = clock.now();
                if now >= deadline {
                    break;
                }
                // Real clock: park for the exact remainder. Virtual
                // clock: the test owns time, so park in short wall
                // slices and re-check — never advance virtual time
                // from a background daemon.
                let park = if clock.is_real() {
                    Duration::from_nanos(deadline - now)
                } else {
                    VirtualWaitPacer::WAIT_SLICE
                };
                let (guard, _) = pwait_timeout(&shared.cv, stop, park);
                stop = guard;
            }
            if *stop {
                return;
            }
        }
        // Upgrade only for the tick so the maintainer never keeps a
        // dropped platform alive.
        let Some(p) = platform.upgrade() else { return };
        let report = p.maintain();
        shared.ticks.fetch_add(1, Ordering::SeqCst);
        shared.evicted.fetch_add(report.evicted, Ordering::SeqCst);
        shared.replenished.fetch_add(report.replenished, Ordering::SeqCst);
        deadline = clock.now().saturating_add(interval_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configparse::PlatformConfig;
    use crate::platform::{Invoker, StartKind};
    use crate::runtime::MockEngine;
    use crate::util::ManualClock;
    use std::time::Instant;

    fn platform(max_containers: usize) -> (Arc<Platform>, Arc<ManualClock>) {
        let clock = ManualClock::new();
        let cfg = PlatformConfig { max_containers, ..Default::default() };
        let p = Arc::new(Invoker::new(cfg, Arc::new(MockEngine::paper_zoo()), clock.clone()));
        (p, clock)
    }

    fn min_warm(n: usize) -> crate::platform::FunctionPolicy {
        crate::platform::FunctionPolicy { min_warm: n, ..Default::default() }
    }

    #[test]
    fn manual_tick_replenishes_decayed_min_warm() {
        let (p, clock) = platform(1000);
        p.deploy_full("sq", "squeezenet", "pallas", 512, min_warm(2)).unwrap();
        assert_eq!(p.pool.warm_count("sq"), 2);
        // Idle past the keep-alive TTL: the warm capacity has decayed.
        clock.sleep(Duration::from_secs(601));
        let report = p.maintain();
        assert_eq!(report.evicted, 2, "stale pre-warmed containers reaped");
        assert_eq!(report.replenished, 2, "min_warm restored");
        assert_eq!(p.pool.warm_count("sq"), 2);
        // The restored capacity is fresh: the next invocation is warm.
        assert_eq!(p.invoke("sq", 1).unwrap().record.start, StartKind::Warm);
        // Replenishment went through the prewarm path, not the
        // request-visible cold-provision counter.
        assert_eq!(p.scaler.cold_provision_count(), 0);
        assert_eq!(p.scaler.prewarm_provision_count(), 4);
    }

    #[test]
    fn maintain_respects_container_cap_and_missing_functions() {
        let (p, clock) = platform(1);
        p.deploy_full("sq", "squeezenet", "pallas", 512, min_warm(2)).unwrap();
        // Cap 1: deploy-time prewarm got only 1 of the 2.
        assert_eq!(p.pool.warm_count("sq"), 1);
        clock.sleep(Duration::from_secs(601));
        let report = p.maintain();
        assert_eq!(report.evicted, 1);
        assert_eq!(report.replenished, 1, "cap bounds the top-up, no spin");
        assert_eq!(p.pool.total_alive(), 1);
        // Undeployed functions are simply skipped.
        p.undeploy("sq").unwrap();
        let report = p.maintain();
        assert_eq!(report, MaintenanceReport::default());
    }

    #[test]
    fn maintain_is_noop_within_ttl() {
        let (p, clock) = platform(1000);
        p.deploy_full("sq", "squeezenet", "pallas", 512, min_warm(2)).unwrap();
        clock.sleep(Duration::from_secs(100));
        assert_eq!(p.maintain(), MaintenanceReport::default());
        assert_eq!(p.pool.warm_count("sq"), 2);
    }

    #[test]
    fn background_thread_replenishes_and_joins() {
        let (p, clock) = platform(1000);
        p.deploy_full("sq", "squeezenet", "pallas", 512, min_warm(1)).unwrap();
        assert!(Invoker::start_maintainer(&p, Duration::from_millis(2)));
        assert!(!Invoker::start_maintainer(&p, Duration::from_millis(2)), "second start no-ops");
        clock.sleep(Duration::from_secs(601));
        let deadline = Instant::now() + Duration::from_secs(10);
        while p.maintainer_replenished() < 1 {
            assert!(Instant::now() < deadline, "maintainer never replenished");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(p.pool.warm_count("sq"), 1);
        assert!(p.maintainer_ticks() >= 1);
        p.stop_maintainer();
        assert!(Invoker::start_maintainer(&p, Duration::from_millis(2)), "restartable after stop");
        // Dropping the platform joins the thread (no hang, no leak).
        drop(p);
    }

    #[test]
    fn manualclock_ticks_follow_virtual_time_not_wall_time() {
        let (p, clock) = platform(1000);
        p.deploy_full("sq", "squeezenet", "pallas", 512, min_warm(1)).unwrap();
        assert!(Invoker::start_maintainer(&p, Duration::from_millis(5)));
        // Plenty of wall time passes, but virtual time stands still:
        // the tick timer must not fire. (With the old Instant::now()
        // deadline this races through ~6 wall-clock ticks.)
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(p.maintainer_ticks(), 0, "tick timer leaked wall time under ManualClock");
        // Advancing the virtual clock past the keep-alive TTL and the
        // tick interval makes the next tick evict + replenish.
        clock.sleep(Duration::from_secs(601));
        let deadline = Instant::now() + Duration::from_secs(10);
        while p.maintainer_ticks() < 1 {
            assert!(Instant::now() < deadline, "maintainer never ticked on virtual time");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(p.maintainer_replenished() >= 1, "decayed min_warm restored on virtual tick");
        assert_eq!(p.pool.warm_count("sq"), 1);
        p.stop_maintainer();
    }

    #[test]
    fn zero_interval_disables() {
        let (p, _) = platform(1000);
        assert!(!Invoker::start_maintainer(&p, Duration::ZERO));
        assert_eq!(p.maintainer_ticks(), 0);
    }
}
