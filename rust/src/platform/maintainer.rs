//! Background warm-pool maintenance.
//!
//! The paper's §5 asks for a "keep containers warm" knob; the spec's
//! `min_warm` provides it — but pre-warmed containers still age out
//! through the keep-alive TTL, so without upkeep the operator-paid
//! warm capacity silently decays back to cold starts during idle gaps
//! (exactly the 10-minute-gap regime the paper measures). The
//! [`PoolMaintainer`] closes the loop: a background thread that on a
//! configurable tick runs the keep-alive eviction sweep and then
//! replenishes every deployed function back up to its `min_warm`
//! target through the prewarm path.
//!
//! The thread holds only a [`Weak`] platform reference (upgraded per
//! tick), stops promptly via a condvar'd flag, and joins on drop.
//! Time-virtualized tests don't need the thread at all: one tick is
//! [`Platform::maintain`], callable directly under a `ManualClock`.

use super::invoker::Platform;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What one maintenance tick did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MaintenanceReport {
    /// Containers reaped by the keep-alive sweep.
    pub evicted: usize,
    /// Containers provisioned to restore `min_warm` targets.
    pub replenished: usize,
}

struct Shared {
    stop: Mutex<bool>,
    cv: Condvar,
    ticks: AtomicU64,
    evicted: AtomicUsize,
    replenished: AtomicUsize,
}

/// Handle to the background maintenance thread.
pub struct PoolMaintainer {
    shared: Arc<Shared>,
    handle: Option<JoinHandle<()>>,
}

impl PoolMaintainer {
    /// Spawn the maintenance thread, ticking every `interval` of wall
    /// time (the platform clock may still be virtual: eviction reads
    /// platform time, the tick timer reads wall time).
    pub fn start(platform: &Arc<Platform>, interval: Duration) -> Self {
        let shared = Arc::new(Shared {
            stop: Mutex::new(false),
            cv: Condvar::new(),
            ticks: AtomicU64::new(0),
            evicted: AtomicUsize::new(0),
            replenished: AtomicUsize::new(0),
        });
        let weak = Arc::downgrade(platform);
        let thread_shared = shared.clone();
        let handle = std::thread::Builder::new()
            .name("pool-maintainer".into())
            .spawn(move || maintainer_loop(weak, interval, thread_shared))
            .expect("spawn pool-maintainer thread");
        Self { shared, handle: Some(handle) }
    }

    /// Completed maintenance ticks.
    pub fn ticks(&self) -> u64 {
        self.shared.ticks.load(Ordering::SeqCst)
    }

    /// Containers reaped across all ticks.
    pub fn evicted_total(&self) -> usize {
        self.shared.evicted.load(Ordering::SeqCst)
    }

    /// Containers replenished across all ticks.
    pub fn replenished_total(&self) -> usize {
        self.shared.replenished.load(Ordering::SeqCst)
    }

    /// Signal the thread to stop and join it. Idempotent.
    pub fn stop(&mut self) {
        *self.shared.stop.lock().unwrap() = true;
        self.shared.cv.notify_all();
        if let Some(handle) = self.handle.take() {
            // The thread's transient upgrade can be the LAST strong
            // platform ref, which would run this drop chain on the
            // maintainer thread itself — joining would deadlock.
            // Detaching is safe: the loop exits on the stop flag.
            if handle.thread().id() == std::thread::current().id() {
                return;
            }
            let _ = handle.join();
        }
    }
}

impl Drop for PoolMaintainer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn maintainer_loop(platform: Weak<Platform>, interval: Duration, shared: Arc<Shared>) {
    loop {
        // Interruptible sleep: a stop() mid-interval wakes us.
        {
            let mut stop = shared.stop.lock().unwrap();
            let deadline = Instant::now() + interval;
            while !*stop {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = shared.cv.wait_timeout(stop, deadline - now).unwrap();
                stop = guard;
            }
            if *stop {
                return;
            }
        }
        // Upgrade only for the tick so the maintainer never keeps a
        // dropped platform alive.
        let Some(p) = platform.upgrade() else { return };
        let report = p.maintain();
        shared.ticks.fetch_add(1, Ordering::SeqCst);
        shared.evicted.fetch_add(report.evicted, Ordering::SeqCst);
        shared.replenished.fetch_add(report.replenished, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configparse::PlatformConfig;
    use crate::platform::{Invoker, StartKind};
    use crate::runtime::MockEngine;
    use crate::util::ManualClock;

    fn platform(max_containers: usize) -> (Arc<Platform>, Arc<ManualClock>) {
        let clock = ManualClock::new();
        let cfg = PlatformConfig { max_containers, ..Default::default() };
        let p = Arc::new(Invoker::new(cfg, Arc::new(MockEngine::paper_zoo()), clock.clone()));
        (p, clock)
    }

    fn min_warm(n: usize) -> crate::platform::FunctionPolicy {
        crate::platform::FunctionPolicy { min_warm: n, ..Default::default() }
    }

    #[test]
    fn manual_tick_replenishes_decayed_min_warm() {
        let (p, clock) = platform(1000);
        p.deploy_full("sq", "squeezenet", "pallas", 512, min_warm(2)).unwrap();
        assert_eq!(p.pool.warm_count("sq"), 2);
        // Idle past the keep-alive TTL: the warm capacity has decayed.
        clock.sleep(Duration::from_secs(601));
        let report = p.maintain();
        assert_eq!(report.evicted, 2, "stale pre-warmed containers reaped");
        assert_eq!(report.replenished, 2, "min_warm restored");
        assert_eq!(p.pool.warm_count("sq"), 2);
        // The restored capacity is fresh: the next invocation is warm.
        assert_eq!(p.invoke("sq", 1).unwrap().record.start, StartKind::Warm);
        // Replenishment went through the prewarm path, not the
        // request-visible cold-provision counter.
        assert_eq!(p.scaler.cold_provision_count(), 0);
        assert_eq!(p.scaler.prewarm_provision_count(), 4);
    }

    #[test]
    fn maintain_respects_container_cap_and_missing_functions() {
        let (p, clock) = platform(1);
        p.deploy_full("sq", "squeezenet", "pallas", 512, min_warm(2)).unwrap();
        // Cap 1: deploy-time prewarm got only 1 of the 2.
        assert_eq!(p.pool.warm_count("sq"), 1);
        clock.sleep(Duration::from_secs(601));
        let report = p.maintain();
        assert_eq!(report.evicted, 1);
        assert_eq!(report.replenished, 1, "cap bounds the top-up, no spin");
        assert_eq!(p.pool.total_alive(), 1);
        // Undeployed functions are simply skipped.
        p.undeploy("sq").unwrap();
        let report = p.maintain();
        assert_eq!(report, MaintenanceReport::default());
    }

    #[test]
    fn maintain_is_noop_within_ttl() {
        let (p, clock) = platform(1000);
        p.deploy_full("sq", "squeezenet", "pallas", 512, min_warm(2)).unwrap();
        clock.sleep(Duration::from_secs(100));
        assert_eq!(p.maintain(), MaintenanceReport::default());
        assert_eq!(p.pool.warm_count("sq"), 2);
    }

    #[test]
    fn background_thread_replenishes_and_joins() {
        let (p, clock) = platform(1000);
        p.deploy_full("sq", "squeezenet", "pallas", 512, min_warm(1)).unwrap();
        assert!(Invoker::start_maintainer(&p, Duration::from_millis(2)));
        assert!(!Invoker::start_maintainer(&p, Duration::from_millis(2)), "second start no-ops");
        clock.sleep(Duration::from_secs(601));
        let deadline = Instant::now() + Duration::from_secs(10);
        while p.maintainer_replenished() < 1 {
            assert!(Instant::now() < deadline, "maintainer never replenished");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(p.pool.warm_count("sq"), 1);
        assert!(p.maintainer_ticks() >= 1);
        p.stop_maintainer();
        assert!(Invoker::start_maintainer(&p, Duration::from_millis(2)), "restartable after stop");
        // Dropping the platform joins the thread (no hang, no leak).
        drop(p);
    }

    #[test]
    fn zero_interval_disables() {
        let (p, _) = platform(1000);
        assert!(!Invoker::start_maintainer(&p, Duration::ZERO));
        assert_eq!(p.maintainer_ticks(), 0);
    }
}
