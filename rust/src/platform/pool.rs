//! Warm-container pool with keep-alive eviction and capacity waiting.
//!
//! Per-function LIFO stacks of warm containers (LIFO maximizes reuse
//! and lets the oldest containers age out, matching observed Lambda
//! behaviour), a global container count against the platform cap, and
//! keep-alive eviction: a container idle longer than the TTL is reaped
//! on the next sweep. The paper forces cold starts with 10-minute gaps
//! precisely because the platform's TTL was below that.
//!
//! The pool is *waitable*: every state change that can free capacity
//! (release, retire, reservation cancel, eviction sweep) bumps a
//! generation counter and signals a condvar, so an admitted request
//! that finds no warm container and no free slot parks in
//! [`WarmPool::acquire_or_reserve`] until capacity appears or its
//! deadline (platform-clock time) passes — instead of the old instant
//! `try_reserve` failure. On virtual clocks the waiters double as the
//! time driver of last resort: when nothing frees capacity for a few
//! wall slices, a parked waiter advances virtual time toward its own
//! deadline so a deadline expiry can never hang a time-virtualized
//! run.

use super::container::Container;
use crate::util::clock::Nanos;
use crate::util::{plock, pwait_timeout, Clock, VirtualWaitPacer};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Result of [`WarmPool::acquire_or_reserve`].
pub enum AcquireOutcome {
    /// A warm container was handed out (warm start).
    Container(Container),
    /// A capacity slot was reserved; the caller cold-provisions.
    Reserved,
    /// The deadline passed without a container or a free slot.
    TimedOut,
    /// The caller's interrupt probe fired while parked (e.g. a batch
    /// opened that this request can join instead of waiting for a
    /// container); only returned by
    /// [`WarmPool::acquire_or_reserve_or`].
    Interrupted,
}

pub struct WarmPool {
    /// function name -> warm containers (LIFO).
    idle: Mutex<BTreeMap<String, Vec<Container>>>,
    /// All containers alive (busy + warm) against `max_containers`.
    total: AtomicUsize,
    max_containers: usize,
    keep_alive_ns: u64,
    clock: Arc<dyn Clock>,
    /// Generation counter bumped on every capacity-freeing change;
    /// parked waiters re-check on each bump.
    waiters: Mutex<u64>,
    waiter_cv: Condvar,
}

impl WarmPool {
    pub fn new(max_containers: usize, keep_alive_s: f64, clock: Arc<dyn Clock>) -> Self {
        Self {
            idle: Mutex::new(BTreeMap::new()),
            total: AtomicUsize::new(0),
            max_containers,
            keep_alive_ns: (keep_alive_s * 1e9) as u64,
            clock,
            waiters: Mutex::new(0),
            waiter_cv: Condvar::new(),
        }
    }

    /// Wake every parked waiter: a container or a capacity slot may
    /// have freed (also called by the invoker when a per-function
    /// concurrency slot frees, so throttled async workers can re-try).
    pub fn notify_waiters(&self) {
        *plock(&self.waiters) += 1;
        self.waiter_cv.notify_all();
    }

    /// Try to take a warm container for `function`. Runs an eviction
    /// sweep for that function first, so an expired container is never
    /// handed out (it is reaped instead — the paper's forced-cold
    /// mechanism).
    ///
    /// Single-pass: the sweep, the pop, and the `total` adjustment for
    /// the reaped containers all happen under one `idle` lock hold, so
    /// a concurrent `try_reserve` never sees already-dead containers
    /// still counted against the cap (which used to surface as
    /// spurious 429s while actually under capacity). Only the engine
    /// teardown (`reap`) runs outside the lock.
    pub fn acquire(&self, function: &str) -> Option<Container> {
        let now = self.clock.now();
        let ttl = self.keep_alive_ns;
        let mut dead: Vec<Container> = Vec::new();
        let hit = {
            let mut g = plock(&self.idle);
            let (hit, emptied) = match g.get_mut(function) {
                None => (None, false),
                Some(stack) => {
                    // Evict expired (oldest are at the bottom).
                    let mut keep = Vec::with_capacity(stack.len());
                    for c in stack.drain(..) {
                        if now.saturating_sub(c.last_used) > ttl {
                            dead.push(c);
                        } else {
                            keep.push(c);
                        }
                    }
                    *stack = keep;
                    let hit = stack.pop();
                    (hit, stack.is_empty())
                }
            };
            if emptied {
                // Drained entries are dropped so churned function
                // names don't grow the map without bound.
                g.remove(function);
            }
            if !dead.is_empty() {
                self.total.fetch_sub(dead.len(), Ordering::SeqCst);
            }
            hit
        };
        let reaped = !dead.is_empty();
        for mut c in dead {
            c.reap();
        }
        if reaped {
            // Reaping decremented `total`: capacity freed.
            self.notify_waiters();
        }
        hit.map(|mut c| {
            c.activate();
            c
        })
    }

    /// Return a busy container to the warm pool.
    pub fn release(&self, mut container: Container) {
        container.park(&self.clock);
        {
            let mut g = plock(&self.idle);
            g.entry(container.spec.name.clone()).or_default().push(container);
        }
        self.notify_waiters();
    }

    /// Reserve a slot for a new (cold) container; `false` when the
    /// platform is at its container cap (throttling: HTTP 429).
    pub fn try_reserve(&self) -> bool {
        let mut cur = self.total.load(Ordering::SeqCst);
        loop {
            if cur >= self.max_containers {
                return false;
            }
            match self.total.compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => return true,
                Err(c) => cur = c,
            }
        }
    }

    /// Release a reservation after a failed provision.
    pub fn cancel_reservation(&self) {
        self.total.fetch_sub(1, Ordering::SeqCst);
        self.notify_waiters();
    }

    /// Destroy a container without returning it to the pool.
    pub fn retire(&self, mut container: Container) {
        container.reap();
        self.total.fetch_sub(1, Ordering::SeqCst);
        self.notify_waiters();
    }

    /// Block until a warm container for `function` or a free capacity
    /// slot is available, or until the platform clock reaches
    /// `deadline`. This is the admission path's waitable primitive:
    /// the first iteration tries immediately (an uncontended request
    /// never parks), after which the caller sleeps on the pool condvar
    /// and re-checks on every capacity-freeing change.
    pub fn acquire_or_reserve(&self, function: &str, deadline: Nanos) -> AcquireOutcome {
        self.acquire_or_reserve_or(function, deadline, || false)
    }

    /// [`Self::acquire_or_reserve`] with an interrupt probe: checked
    /// on every wakeup (after the container/slot probes — holding real
    /// capacity always beats the alternative), a true probe returns
    /// [`AcquireOutcome::Interrupted`] so the caller can take another
    /// path (the invoker joins a freshly opened micro-batch instead of
    /// keeping waiting for a container). The probe is also consulted
    /// before declaring a timeout: an open batch at the deadline
    /// converts a would-be 503 into a served, batched request.
    pub fn acquire_or_reserve_or(
        &self,
        function: &str,
        deadline: Nanos,
        interrupt: impl Fn() -> bool,
    ) -> AcquireOutcome {
        let mut pacer = VirtualWaitPacer::new();
        loop {
            // Capture the generation BEFORE probing so a change that
            // lands between the probe and the wait is never missed.
            let generation = *plock(&self.waiters);
            if let Some(c) = self.acquire(function) {
                return AcquireOutcome::Container(c);
            }
            if self.try_reserve() {
                return AcquireOutcome::Reserved;
            }
            if interrupt() {
                return AcquireOutcome::Interrupted;
            }
            if self.clock.now() >= deadline {
                return AcquireOutcome::TimedOut;
            }
            self.wait_for_generation(generation, deadline, &mut pacer);
        }
    }

    /// Park until any capacity-freeing change or until the platform
    /// clock reaches `deadline` (the async workers' inter-attempt
    /// wait; replaces their old fixed wall-clock backoff).
    pub fn wait_for_change(&self, deadline: Nanos) {
        let mut pacer = VirtualWaitPacer::new();
        loop {
            let generation = *plock(&self.waiters);
            if self.clock.now() >= deadline {
                return;
            }
            if self.wait_for_generation(generation, deadline, &mut pacer) {
                return;
            }
        }
    }

    /// One bounded wait for the generation to move past `gen`;
    /// returns whether a change was observed. The
    /// [`VirtualWaitPacer`] keeps the wait live on virtual clocks: a
    /// plain deadline-capped condvar wait on a real clock, short wall
    /// slices plus a self-driven advance toward `deadline` on a
    /// virtual one (see its docs — the batch collector waits with the
    /// same pacer).
    fn wait_for_generation(
        &self,
        generation: u64,
        deadline: Nanos,
        pacer: &mut VirtualWaitPacer,
    ) -> bool {
        let changed = {
            let g = plock(&self.waiters);
            if *g != generation {
                true
            } else {
                let timeout = pacer.next_timeout(&*self.clock, deadline);
                let (g, _) = pwait_timeout(&self.waiter_cv, g, timeout);
                *g != generation
            }
        };
        pacer.on_wake(&*self.clock, changed, deadline);
        changed
    }

    /// Sweep every function's stack, reaping expired containers and
    /// dropping fully-drained map entries. Returns the number reaped.
    /// `total` is adjusted under the lock (see [`Self::acquire`]).
    pub fn evict_expired(&self) -> usize {
        let now = self.clock.now();
        let ttl = self.keep_alive_ns;
        let mut dead = Vec::new();
        {
            let mut g = plock(&self.idle);
            for stack in g.values_mut() {
                let mut keep = Vec::with_capacity(stack.len());
                for c in stack.drain(..) {
                    if now.saturating_sub(c.last_used) > ttl {
                        dead.push(c);
                    } else {
                        keep.push(c);
                    }
                }
                *stack = keep;
            }
            g.retain(|_, stack| !stack.is_empty());
            if !dead.is_empty() {
                self.total.fetch_sub(dead.len(), Ordering::SeqCst);
            }
        }
        let n = dead.len();
        for mut c in dead {
            c.reap();
        }
        if n > 0 {
            self.notify_waiters();
        }
        n
    }

    /// Evict every warm container of one function (undeploy /
    /// reconfigure: stale-spec containers must not serve again).
    /// Returns the number reaped; busy containers are untouched and
    /// retire through the normal release path.
    pub fn evict_function(&self, function: &str) -> usize {
        let dead: Vec<Container> = {
            let mut g = plock(&self.idle);
            let dead = g.remove(function).unwrap_or_default();
            if !dead.is_empty() {
                self.total.fetch_sub(dead.len(), Ordering::SeqCst);
            }
            dead
        };
        let n = dead.len();
        for mut c in dead {
            c.reap();
        }
        if n > 0 {
            self.notify_waiters();
        }
        n
    }

    /// Evict everything (tests / forced cold).
    pub fn evict_all(&self) -> usize {
        let mut dead = Vec::new();
        {
            let mut g = plock(&self.idle);
            for (_, mut stack) in std::mem::take(&mut *g) {
                dead.append(&mut stack);
            }
            if !dead.is_empty() {
                self.total.fetch_sub(dead.len(), Ordering::SeqCst);
            }
        }
        let n = dead.len();
        for mut c in dead {
            c.reap();
        }
        if n > 0 {
            self.notify_waiters();
        }
        n
    }

    /// Containers currently alive (warm + busy).
    pub fn total_alive(&self) -> usize {
        self.total.load(Ordering::SeqCst)
    }

    /// Warm containers for one function.
    pub fn warm_count(&self, function: &str) -> usize {
        plock(&self.idle).get(function).map_or(0, Vec::len)
    }

    /// Function entries currently tracked in the idle map (sweeps must
    /// drop drained entries so churned names don't leak).
    pub fn tracked_functions(&self) -> usize {
        plock(&self.idle).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configparse::BootstrapConfig;
    use crate::platform::registry::FunctionRegistry;
    use crate::platform::throttle::CpuGovernor;
    use crate::runtime::{Engine as _, MockEngine};
    use crate::util::{ManualClock, SplitMix64};
    use std::time::Duration;

    struct Fixture {
        pool: WarmPool,
        engine: Arc<MockEngine>,
        spec: Arc<crate::platform::registry::FunctionSpec>,
        gov: CpuGovernor,
        clock: Arc<ManualClock>,
        dyn_clock: Arc<dyn Clock>,
        rng: SplitMix64,
    }

    fn fixture(max: usize, keep_alive_s: f64) -> Fixture {
        let engine = Arc::new(MockEngine::paper_zoo());
        let reg = FunctionRegistry::new(engine.clone());
        let spec = reg.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        let clock = ManualClock::new();
        let dyn_clock: Arc<dyn Clock> = clock.clone();
        Fixture {
            pool: WarmPool::new(max, keep_alive_s, dyn_clock.clone()),
            engine,
            spec,
            gov: CpuGovernor::new(1792, dyn_clock.clone()),
            clock,
            dyn_clock,
            rng: SplitMix64::new(0),
        }
    }

    /// Reserve + provision; `None` when at the container cap.
    fn try_provision(f: &mut Fixture) -> Option<Container> {
        if !f.pool.try_reserve() {
            return None;
        }
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        Some(
            Container::provision(
                f.spec.clone(),
                f.engine.clone(),
                &f.gov,
                &cfg,
                &f.dyn_clock,
                &mut f.rng,
            )
            .unwrap(),
        )
    }

    fn provision(f: &mut Fixture) -> Container {
        try_provision(f).expect("under cap")
    }

    #[test]
    fn acquire_empty_returns_none() {
        let f = fixture(10, 600.0);
        assert!(f.pool.acquire("sq").is_none());
        assert!(f.pool.acquire("unknown").is_none());
    }

    #[test]
    fn release_then_acquire_reuses() {
        let mut f = fixture(10, 600.0);
        let c = provision(&mut f);
        let id = c.id;
        f.pool.release(c);
        assert_eq!(f.pool.warm_count("sq"), 1);
        let c2 = f.pool.acquire("sq").unwrap();
        assert_eq!(c2.id, id, "same container comes back");
        assert_eq!(f.pool.warm_count("sq"), 0);
        f.pool.retire(c2);
        assert_eq!(f.pool.total_alive(), 0);
    }

    #[test]
    fn lifo_order() {
        let mut f = fixture(10, 600.0);
        let c1 = provision(&mut f);
        let c2 = provision(&mut f);
        let (id1, id2) = (c1.id, c2.id);
        f.pool.release(c1);
        f.pool.release(c2);
        assert_eq!(f.pool.acquire("sq").map(|c| {
            let id = c.id;
            f.pool.retire(c);
            id
        }), Some(id2), "most recently used first");
        assert_eq!(f.pool.acquire("sq").map(|c| {
            let id = c.id;
            f.pool.retire(c);
            id
        }), Some(id1));
    }

    #[test]
    fn keep_alive_eviction_on_acquire() {
        let mut f = fixture(10, 600.0);
        let c = provision(&mut f);
        f.pool.release(c);
        // Advance past the TTL: the paper's 10-minute forced-cold gap.
        f.clock.sleep(Duration::from_secs(601));
        assert!(f.pool.acquire("sq").is_none(), "expired container not handed out");
        assert_eq!(f.pool.total_alive(), 0, "expired container reaped");
        assert_eq!(f.engine.live_instances(), 0);
    }

    #[test]
    fn keep_alive_survives_within_ttl() {
        let mut f = fixture(10, 600.0);
        let c = provision(&mut f);
        f.pool.release(c);
        f.clock.sleep(Duration::from_secs(599));
        let c = f.pool.acquire("sq");
        assert!(c.is_some(), "within TTL container is reused");
        f.pool.retire(c.unwrap());
    }

    #[test]
    fn evict_expired_sweep() {
        let mut f = fixture(10, 100.0);
        let c1 = provision(&mut f);
        f.pool.release(c1);
        f.clock.sleep(Duration::from_secs(50));
        let c2 = provision(&mut f);
        f.pool.release(c2);
        f.clock.sleep(Duration::from_secs(60)); // c1 is 110s idle, c2 is 60s
        assert_eq!(f.pool.evict_expired(), 1);
        assert_eq!(f.pool.warm_count("sq"), 1);
        assert_eq!(f.pool.total_alive(), 1);
    }

    #[test]
    fn capacity_reservations() {
        let f = fixture(2, 600.0);
        assert!(f.pool.try_reserve());
        assert!(f.pool.try_reserve());
        assert!(!f.pool.try_reserve(), "at cap");
        f.pool.cancel_reservation();
        assert!(f.pool.try_reserve(), "cancellation frees a slot");
        assert_eq!(f.pool.total_alive(), 2);
    }

    #[test]
    fn evict_function_reaps_only_that_stack() {
        let mut f = fixture(10, 600.0);
        let c = provision(&mut f);
        f.pool.release(c);
        let c = provision(&mut f);
        f.pool.release(c);
        assert_eq!(f.pool.evict_function("unknown"), 0);
        assert_eq!(f.pool.evict_function("sq"), 2);
        assert_eq!(f.pool.warm_count("sq"), 0);
        assert_eq!(f.pool.total_alive(), 0);
        assert_eq!(f.engine.live_instances(), 0);
    }

    #[test]
    fn evict_all() {
        let mut f = fixture(10, 600.0);
        for _ in 0..3 {
            let c = provision(&mut f);
            f.pool.release(c);
        }
        assert_eq!(f.pool.warm_count("sq"), 3);
        assert_eq!(f.pool.evict_all(), 3);
        assert_eq!(f.pool.total_alive(), 0);
        assert_eq!(f.engine.live_instances(), 0);
    }

    /// Regression (spurious 429): a thread that finds only expired
    /// containers must already have released their capacity by the
    /// time its `acquire` returns — and, because the sweep is now
    /// single-pass, at any point where another thread can observe the
    /// pool (the `idle` lock released), `total` no longer counts dead
    /// containers. With C expired containers at cap C, C concurrent
    /// acquire-then-reserve threads must therefore ALL get a slot;
    /// under the old drop-relock sweep this raced and spuriously
    /// exhausted capacity.
    #[test]
    fn expired_sweep_frees_capacity_atomically() {
        const CAP: usize = 4;
        for _round in 0..25 {
            let mut f = fixture(CAP, 100.0);
            for _ in 0..CAP {
                let c = provision(&mut f);
                f.pool.release(c);
            }
            f.clock.sleep(Duration::from_secs(101));
            std::thread::scope(|s| {
                for _ in 0..CAP {
                    s.spawn(|| {
                        assert!(f.pool.acquire("sq").is_none(), "expired, never handed out");
                        assert!(
                            f.pool.try_reserve(),
                            "reaped capacity visible to the thread that swept it"
                        );
                    });
                }
            });
            assert_eq!(f.pool.total_alive(), CAP, "all slots re-reserved");
            assert_eq!(f.engine.live_instances(), 0, "all expired instances reaped");
            for _ in 0..CAP {
                f.pool.cancel_reservation();
            }
        }
    }

    /// Regression: sweeps and acquire must drop fully-drained map
    /// entries, or an undeploy-heavy workload grows the idle map
    /// without bound.
    #[test]
    fn sweeps_drop_empty_map_entries() {
        let mut f = fixture(10, 100.0);
        // evict_expired path.
        let c = provision(&mut f);
        f.pool.release(c);
        assert_eq!(f.pool.tracked_functions(), 1);
        f.clock.sleep(Duration::from_secs(101));
        assert_eq!(f.pool.evict_expired(), 1);
        assert_eq!(f.pool.tracked_functions(), 0, "evict_expired drops drained entry");
        // acquire-sweep path.
        let c = provision(&mut f);
        f.pool.release(c);
        f.clock.sleep(Duration::from_secs(101));
        assert!(f.pool.acquire("sq").is_none());
        assert_eq!(f.pool.tracked_functions(), 0, "acquire drops drained entry");
        // acquire popping the last live container also drops the entry.
        let c = provision(&mut f);
        f.pool.release(c);
        let c = f.pool.acquire("sq").expect("live container");
        assert_eq!(f.pool.tracked_functions(), 0);
        f.pool.retire(c);
        // evict_all drains the whole map.
        let c = provision(&mut f);
        f.pool.release(c);
        f.pool.evict_all();
        assert_eq!(f.pool.tracked_functions(), 0, "evict_all drops all entries");
    }

    /// The waitable primitive: a thread that finds no capacity parks
    /// in `acquire_or_reserve` and is handed the container released by
    /// another thread — no polling, no 429.
    #[test]
    fn acquire_or_reserve_wakes_on_release() {
        let mut f = fixture(1, 600.0);
        let c = provision(&mut f);
        let id = c.id;
        // Pool at cap with the container "busy" (held by this test).
        std::thread::scope(|s| {
            let pool = &f.pool;
            let clock = &f.clock;
            let waiter = s.spawn(move || {
                // Far-future deadline: must return via wakeup, not expiry.
                match pool.acquire_or_reserve("sq", u64::MAX) {
                    AcquireOutcome::Container(c) => {
                        let got = c.id;
                        pool.retire(c);
                        got
                    }
                    _ => panic!("expected the released container"),
                }
            });
            // Let the waiter park, then free the container.
            std::thread::sleep(Duration::from_millis(20));
            clock.sleep(Duration::from_secs(1)); // virtual time moves too
            pool.release(c);
            assert_eq!(waiter.join().unwrap(), id, "parked thread got the released container");
        });
        assert_eq!(f.pool.total_alive(), 0);
    }

    /// A parked waiter whose (virtual) deadline passes times out — on
    /// a non-real clock the waiter itself advances time when nothing
    /// frees capacity, so the expiry needs no outside driver.
    #[test]
    fn acquire_or_reserve_times_out_on_virtual_deadline() {
        let mut f = fixture(1, 600.0);
        let _held = provision(&mut f); // cap consumed, never released
        let deadline = f.dyn_clock.now() + 200_000_000; // 200 ms virtual
        let t0 = std::time::Instant::now();
        assert!(matches!(f.pool.acquire_or_reserve("sq", deadline), AcquireOutcome::TimedOut));
        assert!(f.dyn_clock.now() >= deadline, "virtual clock reached the deadline");
        // The whole wait self-drove in a few wall milliseconds.
        assert!(t0.elapsed() < Duration::from_secs(5));
        f.pool.retire(_held);
    }

    /// The interrupt probe: a parked waiter returns `Interrupted` when
    /// the probe fires (woken by `notify_waiters`), but real capacity
    /// always wins over the interrupt.
    #[test]
    fn acquire_or_reserve_interrupt_probe() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let mut f = fixture(1, 600.0);
        let _held = provision(&mut f); // at cap
        let flag = AtomicBool::new(false);
        // Probe already true: immediate interrupt, no timeout burned.
        flag.store(true, Ordering::SeqCst);
        let deadline = f.dyn_clock.now() + 60_000_000_000;
        assert!(matches!(
            f.pool.acquire_or_reserve_or("sq", deadline, || flag.load(Ordering::SeqCst)),
            AcquireOutcome::Interrupted
        ));
        // Probe true but capacity free: capacity wins.
        f.pool.retire(_held);
        assert!(matches!(
            f.pool.acquire_or_reserve_or("sq", deadline, || true),
            AcquireOutcome::Reserved
        ));
        f.pool.cancel_reservation();
        // A parked waiter wakes into the interrupt when the flag flips
        // and the pool is notified.
        let held = provision(&mut f);
        std::thread::scope(|s| {
            let pool = &f.pool;
            let flag = &flag;
            flag.store(false, Ordering::SeqCst);
            let waiter = s.spawn(move || {
                pool.acquire_or_reserve_or("sq", u64::MAX, || flag.load(Ordering::SeqCst))
            });
            std::thread::sleep(Duration::from_millis(20));
            flag.store(true, Ordering::SeqCst);
            pool.notify_waiters();
            assert!(matches!(waiter.join().unwrap(), AcquireOutcome::Interrupted));
        });
        f.pool.retire(held);
    }

    /// Uncontended calls never park: a warm container or a free slot
    /// is taken on the first probe even with an already-passed
    /// deadline (try-once semantics for `queue_deadline_ms = 0`).
    #[test]
    fn acquire_or_reserve_uncontended_is_immediate() {
        let mut f = fixture(2, 600.0);
        let c = provision(&mut f);
        f.pool.release(c);
        match f.pool.acquire_or_reserve("sq", 0) {
            AcquireOutcome::Container(c) => f.pool.retire(c),
            _ => panic!("warm container expected"),
        }
        match f.pool.acquire_or_reserve("sq", 0) {
            AcquireOutcome::Reserved => f.pool.cancel_reservation(),
            _ => panic!("free slot expected"),
        }
        // At cap with a spent deadline: immediate timeout.
        let _a = provision(&mut f);
        let _b = provision(&mut f);
        assert!(matches!(f.pool.acquire_or_reserve("sq", 0), AcquireOutcome::TimedOut));
        f.pool.retire(_a);
        f.pool.retire(_b);
    }

    /// A thread that panics while holding the pool's mutexes (the
    /// batch-leader-crash blast radius) must not take the pool down
    /// with it: release, acquire, and the waitable path all recover
    /// through the poisoned locks.
    #[test]
    fn pool_survives_poisoned_mutexes() {
        let mut f = fixture(4, 600.0);
        let c = provision(&mut f);
        std::thread::scope(|s| {
            let pool = &f.pool;
            let _ = s
                .spawn(|| {
                    let _idle = pool.idle.lock().unwrap();
                    let _gen = pool.waiters.lock().unwrap();
                    panic!("die holding both pool locks");
                })
                .join();
        });
        assert!(f.pool.idle.is_poisoned());
        assert!(f.pool.waiters.is_poisoned());
        let id = c.id;
        f.pool.release(c);
        assert_eq!(f.pool.warm_count("sq"), 1, "release works through poison");
        match f.pool.acquire_or_reserve("sq", u64::MAX) {
            AcquireOutcome::Container(c) => {
                assert_eq!(c.id, id, "waitable acquire works through poison");
                f.pool.retire(c);
            }
            _ => panic!("expected the released container"),
        }
        assert_eq!(f.pool.total_alive(), 0);
    }

    /// Property: through arbitrary interleavings of provision/release/
    /// acquire/advance, the pool never exceeds its cap and never leaks
    /// engine instances.
    #[test]
    fn prop_pool_invariants() {
        crate::testkit::forall_cases("pool invariants", 60, |ops: &Vec<(u32, u64)>| {
            let mut f = fixture(4, 100.0);
            let mut held: Vec<Container> = Vec::new();
            for (op, arg) in ops {
                match op % 4 {
                    0 => {
                        if let Some(c) = try_provision(&mut f) {
                            held.push(c);
                        }
                    }
                    1 => {
                        if let Some(c) = held.pop() {
                            f.pool.release(c);
                        }
                    }
                    2 => {
                        if let Some(c) = f.pool.acquire("sq") {
                            held.push(c);
                        }
                    }
                    _ => {
                        f.clock.sleep(Duration::from_secs(arg % 200));
                        f.pool.evict_expired();
                    }
                }
                let alive = f.pool.total_alive();
                if alive > 4 {
                    return Err(format!("cap exceeded: {alive}"));
                }
                let live = f.engine.live_instances();
                let pooled = f.pool.warm_count("sq");
                if live != pooled + held.len() {
                    return Err(format!(
                        "instance leak: engine={live} pooled={pooled} held={}",
                        held.len()
                    ));
                }
            }
            for c in held.drain(..) {
                f.pool.retire(c);
            }
            f.pool.evict_all();
            if f.engine.live_instances() != 0 {
                return Err("instances leaked at teardown".into());
            }
            Ok(())
        });
    }
}
