//! Warm-container pool with keep-alive eviction and capacity waiting,
//! sharded by function hash.
//!
//! Per-function LIFO stacks of warm containers (LIFO maximizes reuse
//! and lets the oldest containers age out, matching observed Lambda
//! behaviour), a global container count against the platform cap, and
//! keep-alive eviction: a container idle longer than the TTL is reaped
//! on the next sweep. The paper forces cold starts with 10-minute gaps
//! precisely because the platform's TTL was below that.
//!
//! The pool is *waitable*: every state change that can free capacity
//! (release, retire, reservation cancel, eviction sweep) bumps a
//! generation counter and signals a condvar, so an admitted request
//! that finds no warm container and no free slot parks in
//! [`WarmPool::acquire_or_reserve`] until capacity appears or its
//! deadline (platform-clock time) passes — instead of the old instant
//! `try_reserve` failure. On virtual clocks the waiters double as the
//! time driver of last resort: when nothing frees capacity for a few
//! wall slices, a parked waiter advances virtual time toward its own
//! deadline so a deadline expiry can never hang a time-virtualized
//! run.
//!
//! **Sharding.** The idle map and the waiter generation/condvar pair
//! are split into [`PoolShard`]s keyed by a hash of the function name
//! (`pool_shards` in the platform config; `1` — the default — is the
//! old single-lock pool, bit-for-bit). A hot function's release storm
//! then bumps and signals only its own shard, so parked waiters of
//! unrelated functions stay parked instead of stampeding awake on
//! every release (the cross-function thundering herd). Events that
//! free *global* capacity (retire, reservation cancel, eviction
//! sweeps) still broadcast to every shard — a waiter parked for a
//! capacity slot on shard A must see a slot freed by a retire on
//! shard B. Capacity itself stays ONE lock-free atomic against
//! `max_containers`: the cap is account-wide by definition, a
//! per-shard budget split would silently turn `max_containers = 1`
//! into "one per shard", and a CAS on an atomic was never the
//! contention — the mutexes and the `notify_all` were.

use super::container::Container;
use crate::util::clock::Nanos;
use crate::util::{plock, pwait_timeout, Clock, VirtualWaitPacer};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Result of [`WarmPool::acquire_or_reserve`].
pub enum AcquireOutcome {
    /// A warm container was handed out (warm start).
    Container(Container),
    /// A capacity slot was reserved; the caller cold-provisions.
    Reserved,
    /// The deadline passed without a container or a free slot.
    TimedOut,
    /// The caller's interrupt probe fired while parked (e.g. a batch
    /// opened that this request can join instead of waiting for a
    /// container); only returned by
    /// [`WarmPool::acquire_or_reserve_or`].
    Interrupted,
}

/// One hash bucket of the pool: a slice of the idle map plus its own
/// waiter generation and condvar, so waits and wakes are scoped to the
/// functions that hash here.
struct PoolShard {
    /// function name -> warm containers (LIFO), for the functions
    /// hashing to this shard.
    idle: Mutex<BTreeMap<String, Vec<Container>>>,
    /// Generation counter bumped on every change relevant to this
    /// shard; parked waiters re-check on each bump.
    waiters: Mutex<u64>,
    waiter_cv: Condvar,
}

impl PoolShard {
    fn new() -> Self {
        Self {
            idle: Mutex::new(BTreeMap::new()),
            waiters: Mutex::new(0),
            waiter_cv: Condvar::new(),
        }
    }
}

pub struct WarmPool {
    /// Per-function-hash shards (see the module docs); never empty.
    shards: Vec<PoolShard>,
    /// All containers alive (busy + warm) against `max_containers` —
    /// global on purpose (the cap is account-wide; see module docs).
    total: AtomicUsize,
    max_containers: usize,
    keep_alive_ns: u64,
    clock: Arc<dyn Clock>,
}

impl WarmPool {
    /// Single-shard pool: the pre-sharding behaviour, bit-for-bit.
    pub fn new(max_containers: usize, keep_alive_s: f64, clock: Arc<dyn Clock>) -> Self {
        Self::sharded(max_containers, keep_alive_s, clock, 1)
    }

    /// Pool with `shards` hash buckets (`platform.pool_shards`); `0`
    /// is clamped to 1.
    pub fn sharded(
        max_containers: usize,
        keep_alive_s: f64,
        clock: Arc<dyn Clock>,
        shards: usize,
    ) -> Self {
        Self {
            shards: (0..shards.max(1)).map(|_| PoolShard::new()).collect(),
            total: AtomicUsize::new(0),
            max_containers,
            keep_alive_ns: (keep_alive_s * 1e9) as u64,
            clock,
        }
    }

    /// Number of hash buckets (the `pool_shards` gauge).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// FNV-1a of the function name modulo the shard count — stable
    /// across calls so a function always lives on one shard.
    fn shard_index(&self, function: &str) -> usize {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in function.as_bytes() {
            h ^= u64::from(*b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        (h % self.shards.len() as u64) as usize
    }

    fn shard_for(&self, function: &str) -> &PoolShard {
        &self.shards[self.shard_index(function)]
    }

    /// Bump one shard's generation and wake its parked waiters.
    fn notify_shard(shard: &PoolShard) {
        *plock(&shard.waiters) += 1;
        shard.waiter_cv.notify_all();
    }

    /// Wake every parked waiter on every shard: a *global* capacity
    /// slot may have freed (retire, reservation cancel, eviction), and
    /// a waiter parked for capacity on any shard can take it.
    pub fn notify_waiters(&self) {
        for shard in &self.shards {
            Self::notify_shard(shard);
        }
    }

    /// Wake only `function`'s shard: a per-function event (container
    /// released, per-function concurrency slot freed) cannot help
    /// waiters of functions hashing elsewhere, so they stay parked.
    pub fn notify_function(&self, function: &str) {
        Self::notify_shard(self.shard_for(function));
    }

    /// Try to take a warm container for `function`. Runs an eviction
    /// sweep for that function first, so an expired container is never
    /// handed out (it is reaped instead — the paper's forced-cold
    /// mechanism).
    ///
    /// Single-pass: the sweep, the pop, and the `total` adjustment for
    /// the reaped containers all happen under one shard `idle` lock
    /// hold, so a concurrent `try_reserve` never sees already-dead
    /// containers still counted against the cap (which used to surface
    /// as spurious 429s while actually under capacity). Only the engine
    /// teardown (`reap`) runs outside the lock.
    pub fn acquire(&self, function: &str) -> Option<Container> {
        let now = self.clock.now();
        let ttl = self.keep_alive_ns;
        let shard = self.shard_for(function);
        let mut dead: Vec<Container> = Vec::new();
        let hit = {
            let mut g = plock(&shard.idle);
            let (hit, emptied) = match g.get_mut(function) {
                None => (None, false),
                Some(stack) => {
                    // Evict expired (oldest are at the bottom).
                    let mut keep = Vec::with_capacity(stack.len());
                    for c in stack.drain(..) {
                        if now.saturating_sub(c.last_used) > ttl {
                            dead.push(c);
                        } else {
                            keep.push(c);
                        }
                    }
                    *stack = keep;
                    let hit = stack.pop();
                    (hit, stack.is_empty())
                }
            };
            if emptied {
                // Drained entries are dropped so churned function
                // names don't grow the map without bound.
                g.remove(function);
            }
            if !dead.is_empty() {
                self.total.fetch_sub(dead.len(), Ordering::SeqCst);
            }
            hit
        };
        let reaped = !dead.is_empty();
        for mut c in dead {
            c.reap();
        }
        if reaped {
            // Reaping decremented `total`: GLOBAL capacity freed, so
            // waiters on every shard get a look.
            self.notify_waiters();
        }
        hit.map(|mut c| {
            c.activate();
            c
        })
    }

    /// Return a busy container to the warm pool. Wakes only the
    /// function's own shard: no capacity changed hands, so waiters of
    /// unrelated functions have nothing to re-check.
    pub fn release(&self, mut container: Container) {
        container.park(&self.clock);
        let shard = self.shard_for(&container.spec.name);
        {
            let mut g = plock(&shard.idle);
            g.entry(container.spec.name.clone()).or_default().push(container);
        }
        Self::notify_shard(shard);
    }

    /// Reserve a slot for a new (cold) container; `false` when the
    /// platform is at its container cap (throttling: HTTP 429).
    pub fn try_reserve(&self) -> bool {
        let mut cur = self.total.load(Ordering::SeqCst);
        loop {
            if cur >= self.max_containers {
                return false;
            }
            match self.total.compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => return true,
                Err(c) => cur = c,
            }
        }
    }

    /// Release a reservation after a failed provision.
    pub fn cancel_reservation(&self) {
        self.total.fetch_sub(1, Ordering::SeqCst);
        self.notify_waiters();
    }

    /// Destroy a container without returning it to the pool.
    pub fn retire(&self, mut container: Container) {
        container.reap();
        self.total.fetch_sub(1, Ordering::SeqCst);
        self.notify_waiters();
    }

    /// Block until a warm container for `function` or a free capacity
    /// slot is available, or until the platform clock reaches
    /// `deadline`. This is the admission path's waitable primitive:
    /// the first iteration tries immediately (an uncontended request
    /// never parks), after which the caller sleeps on its function's
    /// shard condvar and re-checks on every relevant change.
    pub fn acquire_or_reserve(&self, function: &str, deadline: Nanos) -> AcquireOutcome {
        self.acquire_or_reserve_or(function, deadline, || false)
    }

    /// [`Self::acquire_or_reserve`] with an interrupt probe: checked
    /// on every wakeup (after the container/slot probes — holding real
    /// capacity always beats the alternative), a true probe returns
    /// [`AcquireOutcome::Interrupted`] so the caller can take another
    /// path (the invoker joins a freshly opened micro-batch instead of
    /// keeping waiting for a container). The probe is also consulted
    /// before declaring a timeout: an open batch at the deadline
    /// converts a would-be 503 into a served, batched request.
    pub fn acquire_or_reserve_or(
        &self,
        function: &str,
        deadline: Nanos,
        interrupt: impl Fn() -> bool,
    ) -> AcquireOutcome {
        let shard = self.shard_for(function);
        let mut pacer = VirtualWaitPacer::new();
        loop {
            // Capture the shard generation BEFORE probing so a change
            // that lands between the probe and the wait is never
            // missed.
            let generation = *plock(&shard.waiters);
            if let Some(c) = self.acquire(function) {
                return AcquireOutcome::Container(c);
            }
            if self.try_reserve() {
                return AcquireOutcome::Reserved;
            }
            if interrupt() {
                return AcquireOutcome::Interrupted;
            }
            if self.clock.now() >= deadline {
                return AcquireOutcome::TimedOut;
            }
            Self::wait_for_generation(shard, &*self.clock, generation, deadline, &mut pacer);
        }
    }

    /// Park until a change relevant to `function` (its shard's
    /// generation moves: a release for a sibling, or any global
    /// capacity event — those broadcast to every shard) or until the
    /// platform clock reaches `deadline` (the async workers'
    /// inter-attempt wait; replaces their old fixed wall-clock
    /// backoff).
    pub fn wait_for_change(&self, function: &str, deadline: Nanos) {
        let shard = self.shard_for(function);
        let mut pacer = VirtualWaitPacer::new();
        loop {
            let generation = *plock(&shard.waiters);
            if self.clock.now() >= deadline {
                return;
            }
            if Self::wait_for_generation(shard, &*self.clock, generation, deadline, &mut pacer) {
                return;
            }
        }
    }

    /// One bounded wait for the shard generation to move past `gen`;
    /// returns whether a change was observed. The
    /// [`VirtualWaitPacer`] keeps the wait live on virtual clocks: a
    /// plain deadline-capped condvar wait on a real clock, short wall
    /// slices plus a self-driven advance toward `deadline` on a
    /// virtual one (see its docs — the batch collector waits with the
    /// same pacer).
    fn wait_for_generation(
        shard: &PoolShard,
        clock: &dyn Clock,
        generation: u64,
        deadline: Nanos,
        pacer: &mut VirtualWaitPacer,
    ) -> bool {
        let changed = {
            let g = plock(&shard.waiters);
            if *g != generation {
                true
            } else {
                let timeout = pacer.next_timeout(clock, deadline);
                let (g, _) = pwait_timeout(&shard.waiter_cv, g, timeout);
                *g != generation
            }
        };
        pacer.on_wake(clock, changed, deadline);
        changed
    }

    /// Sweep every function's stack on every shard, reaping expired
    /// containers and dropping fully-drained map entries. Returns the
    /// number reaped. `total` is adjusted under each shard's lock (see
    /// [`Self::acquire`]); shards are swept one at a time — no two
    /// shard locks are ever held together.
    pub fn evict_expired(&self) -> usize {
        let now = self.clock.now();
        let ttl = self.keep_alive_ns;
        let mut dead = Vec::new();
        for shard in &self.shards {
            let mut g = plock(&shard.idle);
            let before = dead.len();
            for stack in g.values_mut() {
                let mut keep = Vec::with_capacity(stack.len());
                for c in stack.drain(..) {
                    if now.saturating_sub(c.last_used) > ttl {
                        dead.push(c);
                    } else {
                        keep.push(c);
                    }
                }
                *stack = keep;
            }
            g.retain(|_, stack| !stack.is_empty());
            let reaped_here = dead.len() - before;
            if reaped_here > 0 {
                self.total.fetch_sub(reaped_here, Ordering::SeqCst);
            }
        }
        let n = dead.len();
        for mut c in dead {
            c.reap();
        }
        if n > 0 {
            self.notify_waiters();
        }
        n
    }

    /// Evict every warm container of one function (undeploy /
    /// reconfigure: stale-spec containers must not serve again).
    /// Returns the number reaped; busy containers are untouched and
    /// retire through the normal release path.
    pub fn evict_function(&self, function: &str) -> usize {
        let shard = self.shard_for(function);
        let dead: Vec<Container> = {
            let mut g = plock(&shard.idle);
            let dead = g.remove(function).unwrap_or_default();
            if !dead.is_empty() {
                self.total.fetch_sub(dead.len(), Ordering::SeqCst);
            }
            dead
        };
        let n = dead.len();
        for mut c in dead {
            c.reap();
        }
        if n > 0 {
            self.notify_waiters();
        }
        n
    }

    /// Evict everything (tests / forced cold).
    pub fn evict_all(&self) -> usize {
        let mut dead = Vec::new();
        for shard in &self.shards {
            let mut g = plock(&shard.idle);
            let before = dead.len();
            for (_, mut stack) in std::mem::take(&mut *g) {
                dead.append(&mut stack);
            }
            let drained = dead.len() - before;
            if drained > 0 {
                self.total.fetch_sub(drained, Ordering::SeqCst);
            }
        }
        let n = dead.len();
        for mut c in dead {
            c.reap();
        }
        if n > 0 {
            self.notify_waiters();
        }
        n
    }

    /// Containers currently alive (warm + busy).
    pub fn total_alive(&self) -> usize {
        self.total.load(Ordering::SeqCst)
    }

    /// Warm containers for one function.
    pub fn warm_count(&self, function: &str) -> usize {
        plock(&self.shard_for(function).idle).get(function).map_or(0, Vec::len)
    }

    /// Warm (idle) containers across every shard.
    pub fn idle_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| plock(&s.idle).values().map(Vec::len).sum::<usize>())
            .sum()
    }

    /// Function entries currently tracked across the shards' idle maps
    /// (sweeps must drop drained entries so churned names don't leak).
    pub fn tracked_functions(&self) -> usize {
        self.shards.iter().map(|s| plock(&s.idle).len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configparse::BootstrapConfig;
    use crate::platform::registry::{FunctionRegistry, FunctionSpec};
    use crate::platform::throttle::CpuGovernor;
    use crate::runtime::{Engine as _, MockEngine};
    use crate::util::{ManualClock, SplitMix64, SystemClock};
    use std::time::Duration;

    struct Fixture {
        pool: WarmPool,
        engine: Arc<MockEngine>,
        registry: FunctionRegistry,
        spec: Arc<FunctionSpec>,
        gov: CpuGovernor,
        clock: Arc<ManualClock>,
        dyn_clock: Arc<dyn Clock>,
        rng: SplitMix64,
    }

    fn fixture(max: usize, keep_alive_s: f64) -> Fixture {
        fixture_sharded(max, keep_alive_s, 1)
    }

    fn fixture_sharded(max: usize, keep_alive_s: f64, shards: usize) -> Fixture {
        let engine = Arc::new(MockEngine::paper_zoo());
        let registry = FunctionRegistry::new(engine.clone());
        let spec = registry.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        let clock = ManualClock::new();
        let dyn_clock: Arc<dyn Clock> = clock.clone();
        Fixture {
            pool: WarmPool::sharded(max, keep_alive_s, dyn_clock.clone(), shards),
            engine,
            registry,
            spec,
            gov: CpuGovernor::new(1792, dyn_clock.clone()),
            clock,
            dyn_clock,
            rng: SplitMix64::new(0),
        }
    }

    /// Reserve + provision for an arbitrary spec; `None` at the cap.
    fn try_provision_for(f: &mut Fixture, spec: &Arc<FunctionSpec>) -> Option<Container> {
        if !f.pool.try_reserve() {
            return None;
        }
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        Some(
            Container::provision(
                spec.clone(),
                f.engine.clone(),
                &f.gov,
                &cfg,
                &f.dyn_clock,
                &mut f.rng,
            )
            .unwrap(),
        )
    }

    /// Reserve + provision; `None` when at the container cap.
    fn try_provision(f: &mut Fixture) -> Option<Container> {
        let spec = f.spec.clone();
        try_provision_for(f, &spec)
    }

    fn provision(f: &mut Fixture) -> Container {
        try_provision(f).expect("under cap")
    }

    /// Two function names guaranteed to live on different shards of
    /// `pool` (panics only if the hash maps 64 names to one bucket,
    /// which would be a broken hash).
    fn names_on_distinct_shards(pool: &WarmPool) -> (String, String) {
        let a = "fn0".to_string();
        let ia = pool.shard_index(&a);
        for i in 1..64 {
            let b = format!("fn{i}");
            if pool.shard_index(&b) != ia {
                return (a, b);
            }
        }
        panic!("hash mapped 64 names to one shard");
    }

    #[test]
    fn acquire_empty_returns_none() {
        let f = fixture(10, 600.0);
        assert!(f.pool.acquire("sq").is_none());
        assert!(f.pool.acquire("unknown").is_none());
    }

    #[test]
    fn release_then_acquire_reuses() {
        let mut f = fixture(10, 600.0);
        let c = provision(&mut f);
        let id = c.id;
        f.pool.release(c);
        assert_eq!(f.pool.warm_count("sq"), 1);
        let c2 = f.pool.acquire("sq").unwrap();
        assert_eq!(c2.id, id, "same container comes back");
        assert_eq!(f.pool.warm_count("sq"), 0);
        f.pool.retire(c2);
        assert_eq!(f.pool.total_alive(), 0);
    }

    #[test]
    fn lifo_order() {
        let mut f = fixture(10, 600.0);
        let c1 = provision(&mut f);
        let c2 = provision(&mut f);
        let (id1, id2) = (c1.id, c2.id);
        f.pool.release(c1);
        f.pool.release(c2);
        assert_eq!(f.pool.acquire("sq").map(|c| {
            let id = c.id;
            f.pool.retire(c);
            id
        }), Some(id2), "most recently used first");
        assert_eq!(f.pool.acquire("sq").map(|c| {
            let id = c.id;
            f.pool.retire(c);
            id
        }), Some(id1));
    }

    #[test]
    fn keep_alive_eviction_on_acquire() {
        let mut f = fixture(10, 600.0);
        let c = provision(&mut f);
        f.pool.release(c);
        // Advance past the TTL: the paper's 10-minute forced-cold gap.
        f.clock.sleep(Duration::from_secs(601));
        assert!(f.pool.acquire("sq").is_none(), "expired container not handed out");
        assert_eq!(f.pool.total_alive(), 0, "expired container reaped");
        assert_eq!(f.engine.live_instances(), 0);
    }

    #[test]
    fn keep_alive_survives_within_ttl() {
        let mut f = fixture(10, 600.0);
        let c = provision(&mut f);
        f.pool.release(c);
        f.clock.sleep(Duration::from_secs(599));
        let c = f.pool.acquire("sq");
        assert!(c.is_some(), "within TTL container is reused");
        f.pool.retire(c.unwrap());
    }

    #[test]
    fn evict_expired_sweep() {
        let mut f = fixture(10, 100.0);
        let c1 = provision(&mut f);
        f.pool.release(c1);
        f.clock.sleep(Duration::from_secs(50));
        let c2 = provision(&mut f);
        f.pool.release(c2);
        f.clock.sleep(Duration::from_secs(60)); // c1 is 110s idle, c2 is 60s
        assert_eq!(f.pool.evict_expired(), 1);
        assert_eq!(f.pool.warm_count("sq"), 1);
        assert_eq!(f.pool.total_alive(), 1);
    }

    #[test]
    fn capacity_reservations() {
        let f = fixture(2, 600.0);
        assert!(f.pool.try_reserve());
        assert!(f.pool.try_reserve());
        assert!(!f.pool.try_reserve(), "at cap");
        f.pool.cancel_reservation();
        assert!(f.pool.try_reserve(), "cancellation frees a slot");
        assert_eq!(f.pool.total_alive(), 2);
    }

    #[test]
    fn evict_function_reaps_only_that_stack() {
        let mut f = fixture(10, 600.0);
        let c = provision(&mut f);
        f.pool.release(c);
        let c = provision(&mut f);
        f.pool.release(c);
        assert_eq!(f.pool.evict_function("unknown"), 0);
        assert_eq!(f.pool.evict_function("sq"), 2);
        assert_eq!(f.pool.warm_count("sq"), 0);
        assert_eq!(f.pool.total_alive(), 0);
        assert_eq!(f.engine.live_instances(), 0);
    }

    #[test]
    fn evict_all() {
        let mut f = fixture(10, 600.0);
        for _ in 0..3 {
            let c = provision(&mut f);
            f.pool.release(c);
        }
        assert_eq!(f.pool.warm_count("sq"), 3);
        assert_eq!(f.pool.evict_all(), 3);
        assert_eq!(f.pool.total_alive(), 0);
        assert_eq!(f.engine.live_instances(), 0);
    }

    /// Regression (spurious 429): a thread that finds only expired
    /// containers must already have released their capacity by the
    /// time its `acquire` returns — and, because the sweep is now
    /// single-pass, at any point where another thread can observe the
    /// pool (the `idle` lock released), `total` no longer counts dead
    /// containers. With C expired containers at cap C, C concurrent
    /// acquire-then-reserve threads must therefore ALL get a slot;
    /// under the old drop-relock sweep this raced and spuriously
    /// exhausted capacity.
    #[test]
    fn expired_sweep_frees_capacity_atomically() {
        const CAP: usize = 4;
        for _round in 0..25 {
            let mut f = fixture(CAP, 100.0);
            for _ in 0..CAP {
                let c = provision(&mut f);
                f.pool.release(c);
            }
            f.clock.sleep(Duration::from_secs(101));
            std::thread::scope(|s| {
                for _ in 0..CAP {
                    s.spawn(|| {
                        assert!(f.pool.acquire("sq").is_none(), "expired, never handed out");
                        assert!(
                            f.pool.try_reserve(),
                            "reaped capacity visible to the thread that swept it"
                        );
                    });
                }
            });
            assert_eq!(f.pool.total_alive(), CAP, "all slots re-reserved");
            assert_eq!(f.engine.live_instances(), 0, "all expired instances reaped");
            for _ in 0..CAP {
                f.pool.cancel_reservation();
            }
        }
    }

    /// Regression: sweeps and acquire must drop fully-drained map
    /// entries, or an undeploy-heavy workload grows the idle map
    /// without bound.
    #[test]
    fn sweeps_drop_empty_map_entries() {
        let mut f = fixture(10, 100.0);
        // evict_expired path.
        let c = provision(&mut f);
        f.pool.release(c);
        assert_eq!(f.pool.tracked_functions(), 1);
        f.clock.sleep(Duration::from_secs(101));
        assert_eq!(f.pool.evict_expired(), 1);
        assert_eq!(f.pool.tracked_functions(), 0, "evict_expired drops drained entry");
        // acquire-sweep path.
        let c = provision(&mut f);
        f.pool.release(c);
        f.clock.sleep(Duration::from_secs(101));
        assert!(f.pool.acquire("sq").is_none());
        assert_eq!(f.pool.tracked_functions(), 0, "acquire drops drained entry");
        // acquire popping the last live container also drops the entry.
        let c = provision(&mut f);
        f.pool.release(c);
        let c = f.pool.acquire("sq").expect("live container");
        assert_eq!(f.pool.tracked_functions(), 0);
        f.pool.retire(c);
        // evict_all drains the whole map.
        let c = provision(&mut f);
        f.pool.release(c);
        f.pool.evict_all();
        assert_eq!(f.pool.tracked_functions(), 0, "evict_all drops all entries");
    }

    /// The waitable primitive: a thread that finds no capacity parks
    /// in `acquire_or_reserve` and is handed the container released by
    /// another thread — no polling, no 429.
    #[test]
    fn acquire_or_reserve_wakes_on_release() {
        let mut f = fixture(1, 600.0);
        let c = provision(&mut f);
        let id = c.id;
        // Pool at cap with the container "busy" (held by this test).
        std::thread::scope(|s| {
            let pool = &f.pool;
            let clock = &f.clock;
            let waiter = s.spawn(move || {
                // Far-future deadline: must return via wakeup, not expiry.
                match pool.acquire_or_reserve("sq", u64::MAX) {
                    AcquireOutcome::Container(c) => {
                        let got = c.id;
                        pool.retire(c);
                        got
                    }
                    _ => panic!("expected the released container"),
                }
            });
            // Let the waiter park, then free the container.
            std::thread::sleep(Duration::from_millis(20));
            clock.sleep(Duration::from_secs(1)); // virtual time moves too
            pool.release(c);
            assert_eq!(waiter.join().unwrap(), id, "parked thread got the released container");
        });
        assert_eq!(f.pool.total_alive(), 0);
    }

    /// A parked waiter whose (virtual) deadline passes times out — on
    /// a non-real clock the waiter itself advances time when nothing
    /// frees capacity, so the expiry needs no outside driver.
    #[test]
    fn acquire_or_reserve_times_out_on_virtual_deadline() {
        let mut f = fixture(1, 600.0);
        let _held = provision(&mut f); // cap consumed, never released
        let deadline = f.dyn_clock.now() + 200_000_000; // 200 ms virtual
        let t0 = std::time::Instant::now();
        assert!(matches!(f.pool.acquire_or_reserve("sq", deadline), AcquireOutcome::TimedOut));
        assert!(f.dyn_clock.now() >= deadline, "virtual clock reached the deadline");
        // The whole wait self-drove in a few wall milliseconds.
        assert!(t0.elapsed() < Duration::from_secs(5));
        f.pool.retire(_held);
    }

    /// The interrupt probe: a parked waiter returns `Interrupted` when
    /// the probe fires (woken by `notify_waiters`), but real capacity
    /// always wins over the interrupt.
    #[test]
    fn acquire_or_reserve_interrupt_probe() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let mut f = fixture(1, 600.0);
        let _held = provision(&mut f); // at cap
        let flag = AtomicBool::new(false);
        // Probe already true: immediate interrupt, no timeout burned.
        flag.store(true, Ordering::SeqCst);
        let deadline = f.dyn_clock.now() + 60_000_000_000;
        assert!(matches!(
            f.pool.acquire_or_reserve_or("sq", deadline, || flag.load(Ordering::SeqCst)),
            AcquireOutcome::Interrupted
        ));
        // Probe true but capacity free: capacity wins.
        f.pool.retire(_held);
        assert!(matches!(
            f.pool.acquire_or_reserve_or("sq", deadline, || true),
            AcquireOutcome::Reserved
        ));
        f.pool.cancel_reservation();
        // A parked waiter wakes into the interrupt when the flag flips
        // and the pool is notified.
        let held = provision(&mut f);
        std::thread::scope(|s| {
            let pool = &f.pool;
            let flag = &flag;
            flag.store(false, Ordering::SeqCst);
            let waiter = s.spawn(move || {
                pool.acquire_or_reserve_or("sq", u64::MAX, || flag.load(Ordering::SeqCst))
            });
            std::thread::sleep(Duration::from_millis(20));
            flag.store(true, Ordering::SeqCst);
            pool.notify_waiters();
            assert!(matches!(waiter.join().unwrap(), AcquireOutcome::Interrupted));
        });
        f.pool.retire(held);
    }

    /// Uncontended calls never park: a warm container or a free slot
    /// is taken on the first probe even with an already-passed
    /// deadline (try-once semantics for `queue_deadline_ms = 0`).
    #[test]
    fn acquire_or_reserve_uncontended_is_immediate() {
        let mut f = fixture(2, 600.0);
        let c = provision(&mut f);
        f.pool.release(c);
        match f.pool.acquire_or_reserve("sq", 0) {
            AcquireOutcome::Container(c) => f.pool.retire(c),
            _ => panic!("warm container expected"),
        }
        match f.pool.acquire_or_reserve("sq", 0) {
            AcquireOutcome::Reserved => f.pool.cancel_reservation(),
            _ => panic!("free slot expected"),
        }
        // At cap with a spent deadline: immediate timeout.
        let _a = provision(&mut f);
        let _b = provision(&mut f);
        assert!(matches!(f.pool.acquire_or_reserve("sq", 0), AcquireOutcome::TimedOut));
        f.pool.retire(_a);
        f.pool.retire(_b);
    }

    /// A thread that panics while holding the pool's mutexes (the
    /// batch-leader-crash blast radius) must not take the pool down
    /// with it: release, acquire, and the waitable path all recover
    /// through the poisoned locks.
    #[test]
    fn pool_survives_poisoned_mutexes() {
        let mut f = fixture(4, 600.0);
        let c = provision(&mut f);
        std::thread::scope(|s| {
            let shard = f.pool.shard_for("sq");
            let _ = s
                .spawn(|| {
                    let _idle = shard.idle.lock().unwrap();
                    let _gen = shard.waiters.lock().unwrap();
                    panic!("die holding both pool locks");
                })
                .join();
        });
        assert!(f.pool.shard_for("sq").idle.is_poisoned());
        assert!(f.pool.shard_for("sq").waiters.is_poisoned());
        let id = c.id;
        f.pool.release(c);
        assert_eq!(f.pool.warm_count("sq"), 1, "release works through poison");
        match f.pool.acquire_or_reserve("sq", u64::MAX) {
            AcquireOutcome::Container(c) => {
                assert_eq!(c.id, id, "waitable acquire works through poison");
                f.pool.retire(c);
            }
            _ => panic!("expected the released container"),
        }
        assert_eq!(f.pool.total_alive(), 0);
    }

    /// Sharded: one poisoned bucket must not wedge acquires, releases,
    /// or waits on any OTHER bucket (and the poisoned bucket itself
    /// still recovers through `plock`).
    #[test]
    fn poisoned_shard_does_not_wedge_other_buckets() {
        let mut f = fixture_sharded(8, 600.0, 8);
        let (fa, fb) = names_on_distinct_shards(&f.pool);
        let spec_a = f.registry.deploy(&fa, "squeezenet", "pallas", 512).unwrap();
        let spec_b = f.registry.deploy(&fb, "squeezenet", "pallas", 512).unwrap();
        let ca = try_provision_for(&mut f, &spec_a).unwrap();
        let cb = try_provision_for(&mut f, &spec_b).unwrap();
        // Poison fa's shard only.
        std::thread::scope(|s| {
            let shard = f.pool.shard_for(&fa);
            let _ = s
                .spawn(|| {
                    let _idle = shard.idle.lock().unwrap();
                    let _gen = shard.waiters.lock().unwrap();
                    panic!("die holding one shard's locks");
                })
                .join();
        });
        assert!(f.pool.shard_for(&fa).idle.is_poisoned());
        assert!(!f.pool.shard_for(&fb).idle.is_poisoned(), "blast radius is one bucket");
        // The other bucket works untouched...
        f.pool.release(cb);
        assert_eq!(f.pool.warm_count(&fb), 1);
        match f.pool.acquire_or_reserve(&fb, u64::MAX) {
            AcquireOutcome::Container(c) => f.pool.retire(c),
            _ => panic!("expected fb's container"),
        }
        // ...and the poisoned one recovers through plock.
        f.pool.release(ca);
        match f.pool.acquire_or_reserve(&fa, u64::MAX) {
            AcquireOutcome::Container(c) => f.pool.retire(c),
            _ => panic!("expected fa's container"),
        }
        assert_eq!(f.pool.total_alive(), 0);
    }

    /// The cross-function thundering herd, fixed: a release storm on
    /// one shard leaves a waiter parked on another shard asleep. The
    /// interrupt probe doubles as a spurious-wakeup counter — on a
    /// real clock a parked waiter only re-runs its loop (and thus the
    /// probe) when its own shard's condvar is signalled, so the count
    /// stays flat through the storm and moves only for the waiter's
    /// own release. Pre-sharding, the single `notify_all` re-ran the
    /// probe once per storm release.
    #[test]
    fn release_storm_leaves_other_shards_parked() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        const STORM: usize = 40;
        // Real clock: a parked waiter wakes only on a condvar signal
        // (no virtual-time pacer slices to muddy the count).
        let engine = Arc::new(MockEngine::paper_zoo());
        let registry = FunctionRegistry::new(engine.clone());
        let clock: Arc<dyn Clock> = Arc::new(SystemClock::new());
        let pool = WarmPool::sharded(2, 600.0, clock.clone(), 8);
        let (fa, fb) = names_on_distinct_shards(&pool);
        let spec_a = registry.deploy(&fa, "squeezenet", "pallas", 512).unwrap();
        let spec_b = registry.deploy(&fb, "squeezenet", "pallas", 512).unwrap();
        let gov = CpuGovernor::new(1792, clock.clone());
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        let mut rng = SplitMix64::new(0);
        let mut prov = |spec: &Arc<FunctionSpec>| {
            assert!(pool.try_reserve());
            Container::provision(spec.clone(), engine.clone(), &gov, &cfg, &clock, &mut rng)
                .unwrap()
        };
        let ca = prov(&spec_a); // fa's only container, held busy
        let cb = prov(&spec_b); // fb's container, released in the storm
        let wakeups = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let (pool, wakeups) = (&pool, &wakeups);
            let fa2 = fa.clone();
            let waiter = s.spawn(move || {
                let deadline = pool.clock.now() + 60_000_000_000; // 60 s real
                match pool.acquire_or_reserve_or(&fa2, deadline, || {
                    wakeups.fetch_add(1, Ordering::SeqCst);
                    false
                }) {
                    AcquireOutcome::Container(c) => pool.retire(c),
                    _ => panic!("expected fa's released container"),
                }
            });
            std::thread::sleep(Duration::from_millis(50)); // let it park
            let parked_baseline = wakeups.load(Ordering::SeqCst);
            // fb's release storm: every cycle signals fb's shard only.
            let mut c = cb;
            for _ in 0..STORM {
                pool.release(c);
                c = pool.acquire(&fb).expect("fb's container cycles");
            }
            std::thread::sleep(Duration::from_millis(50));
            let after_storm = wakeups.load(Ordering::SeqCst);
            // Flat modulo at most one OS-level spurious wakeup; the
            // pre-sharding pool re-ran the probe once per storm
            // release (~STORM times).
            assert!(
                after_storm <= parked_baseline + 1,
                "release storm on fb's shard woke fa's parked waiter \
                 ({} probe runs during the storm)",
                after_storm - parked_baseline
            );
            pool.release(ca); // fa's own release ends the wait
            waiter.join().unwrap();
            pool.retire(c);
        });
        assert_eq!(pool.total_alive(), 0);
    }

    /// Keep-alive sweeps, entry-drop hygiene, and the summed gauges
    /// all span shards: functions pinned to different buckets age out
    /// together under one `evict_expired`, and
    /// `tracked_functions`/`idle_count`/`evict_all` sum over shards.
    #[test]
    fn sweeps_and_counts_span_shards() {
        let mut f = fixture_sharded(16, 100.0, 8);
        let (fa, fb) = names_on_distinct_shards(&f.pool);
        let spec_a = f.registry.deploy(&fa, "squeezenet", "pallas", 512).unwrap();
        let spec_b = f.registry.deploy(&fb, "squeezenet", "pallas", 512).unwrap();
        let ca = try_provision_for(&mut f, &spec_a).unwrap();
        let cb = try_provision_for(&mut f, &spec_b).unwrap();
        f.pool.release(ca);
        f.pool.release(cb);
        assert_eq!(f.pool.tracked_functions(), 2, "entries summed across shards");
        assert_eq!(f.pool.idle_count(), 2, "idle containers summed across shards");
        assert_eq!(f.pool.warm_count(&fa), 1);
        assert_eq!(f.pool.warm_count(&fb), 1);
        // TTL expiry reaps across shards in one sweep.
        f.clock.sleep(Duration::from_secs(101));
        assert_eq!(f.pool.evict_expired(), 2, "one sweep reaps both shards");
        assert_eq!(f.pool.tracked_functions(), 0, "drained entries dropped on every shard");
        assert_eq!(f.pool.idle_count(), 0);
        assert_eq!(f.pool.total_alive(), 0);
        assert_eq!(f.engine.live_instances(), 0);
        // evict_all drains every shard too.
        let ca = try_provision_for(&mut f, &spec_a).unwrap();
        let cb = try_provision_for(&mut f, &spec_b).unwrap();
        f.pool.release(ca);
        f.pool.release(cb);
        assert_eq!(f.pool.evict_all(), 2);
        assert_eq!(f.pool.total_alive(), 0);
    }

    /// The capacity cap stays account-wide under sharding: shard
    /// locality never grants extra slots, and a retire on one shard
    /// unparks a capacity waiter whose function hashes elsewhere.
    #[test]
    fn capacity_is_global_across_shards() {
        let mut f = fixture_sharded(1, 600.0, 8);
        let (fa, fb) = names_on_distinct_shards(&f.pool);
        let spec_a = f.registry.deploy(&fa, "squeezenet", "pallas", 512).unwrap();
        let _ = f.registry.deploy(&fb, "squeezenet", "pallas", 512).unwrap();
        let ca = try_provision_for(&mut f, &spec_a).unwrap();
        assert!(!f.pool.try_reserve(), "cap of 1 is global, not per shard");
        std::thread::scope(|s| {
            let pool = &f.pool;
            let fb2 = fb.clone();
            let waiter = s.spawn(move || {
                matches!(pool.acquire_or_reserve(&fb2, u64::MAX), AcquireOutcome::Reserved)
            });
            std::thread::sleep(Duration::from_millis(20));
            // Retiring fa's container frees GLOBAL capacity: the
            // broadcast must reach fb's shard.
            pool.retire(ca);
            assert!(waiter.join().unwrap(), "cross-shard capacity wakeup");
        });
        f.pool.cancel_reservation();
        assert_eq!(f.pool.total_alive(), 0);
    }

    /// Property: through arbitrary interleavings of provision/release/
    /// acquire/advance, the pool never exceeds its cap and never leaks
    /// engine instances — including across shards.
    #[test]
    fn prop_pool_invariants() {
        crate::testkit::forall_cases("pool invariants", 60, |ops: &Vec<(u32, u64)>| {
            let mut f = fixture_sharded(4, 100.0, 4);
            let mut held: Vec<Container> = Vec::new();
            for (op, arg) in ops {
                match op % 4 {
                    0 => {
                        if let Some(c) = try_provision(&mut f) {
                            held.push(c);
                        }
                    }
                    1 => {
                        if let Some(c) = held.pop() {
                            f.pool.release(c);
                        }
                    }
                    2 => {
                        if let Some(c) = f.pool.acquire("sq") {
                            held.push(c);
                        }
                    }
                    _ => {
                        f.clock.sleep(Duration::from_secs(arg % 200));
                        f.pool.evict_expired();
                    }
                }
                let alive = f.pool.total_alive();
                if alive > 4 {
                    return Err(format!("cap exceeded: {alive}"));
                }
                let live = f.engine.live_instances();
                let pooled = f.pool.warm_count("sq");
                if live != pooled + held.len() {
                    return Err(format!(
                        "instance leak: engine={live} pooled={pooled} held={}",
                        held.len()
                    ));
                }
            }
            for c in held.drain(..) {
                f.pool.retire(c);
            }
            f.pool.evict_all();
            if f.engine.live_instances() != 0 {
                return Err("instances leaked at teardown".into());
            }
            Ok(())
        });
    }
}
