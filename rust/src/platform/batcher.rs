//! Dynamic micro-batching: coalesce concurrent invocations of one
//! function into a single batched forward pass on one warm container.
//!
//! The paper's throughput ceiling is requests-per-warm-container: once
//! cold starts are amortized, every request still pays a full forward
//! pass, so a parked burst (PR 3's admission queue) drains one pass at
//! a time. The [`Batcher`] turns that queue into a batching
//! opportunity: the first request of a function to hold a container
//! becomes the **batch leader** — it opens a batch, waits up to
//! `batch_window_ms` for **followers** (requests admitted meanwhile,
//! including capacity misses that would otherwise park for a container
//! of their own), then runs ONE [`Engine::predict_batch`] pass and
//! fans the per-request results back out. `max_batch_size` flushes a
//! full batch early; `max_batch_size = 1` (the default) disables the
//! whole path, leaving the pre-batching pipeline bit-for-bit intact.
//!
//! Billing splits across members: every member is charged
//! `effective_batch_duration / n` (the leader additionally pays its
//! cold-start handler time), while everyone's *response* includes the
//! full batched pass — you cannot bill n requests one pass and also
//! pretend each finished in a fraction of it.
//!
//! Waiting is ManualClock-safe with the same virtual-time self-advance
//! pattern as the waitable pool: a leader whose window nobody else
//! advances drives the virtual clock toward its own flush deadline, so
//! time-virtualized tests never hang. Followers never advance time —
//! their leader is live by construction (its RAII guard fails the
//! batch on any abnormal exit), so they only ever wait for real
//! progress.
//!
//! [`Engine::predict_batch`]: crate::runtime::Engine::predict_batch

use super::registry::FunctionSpec;
use crate::runtime::Prediction;
use crate::util::clock::Nanos;
use crate::util::{plock, pwait_timeout, Clock, VirtualWaitPacer};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Cap on one real-clock window wait slice: the leader re-evaluates
/// its early-flush predicate (starving non-joinable demand) this
/// often, so a held container never blocks a parked request for more
/// than one probe interval past the moment it could be serving.
const REAL_FLUSH_PROBE: Duration = Duration::from_millis(10);

/// Cap on one real-clock follower park: results are delivered by
/// notify, so this only bounds how long a lost wakeup (leader thread
/// killed between state write and notify) can strand a follower.
const FOLLOWER_PARK_SLICE: Duration = Duration::from_millis(50);

/// What each member of an executed batch gets back.
#[derive(Debug, Clone)]
pub struct BatchShare {
    /// This member's own classification result.
    pub prediction: Prediction,
    /// How many requests rode the batch (including the leader).
    pub batch_size: usize,
    /// Effective (CPU-governed) duration of the WHOLE batched pass —
    /// the latency component every member actually waited for.
    pub effective: Duration,
    /// This member's billed split: `effective / batch_size`.
    pub billed_share: Duration,
    /// Time this member spent parked in the collector before the
    /// batched pass started (the leader's is its window wait).
    pub batch_wait: Duration,
    /// Largest compiled batch-N kernel that served the flush (1 =
    /// batch-1 executables only), from the engine's
    /// [`crate::runtime::KernelReport`] — every member records it so
    /// the per-function `kernel_batch_n` histogram is request-weighted
    /// like `batch_size`.
    pub kernel_batch_n: usize,
    /// Trace id of the leader whose container ran the batched pass,
    /// when tracing is on — followers share the leader's execution
    /// span and annotate their own timelines with it.
    pub leader_trace: Option<String>,
}

#[derive(PartialEq)]
enum Phase {
    /// Open: followers may still join.
    Collecting,
    /// Flushed: the leader is executing; no more joins.
    Executing,
    /// Results distributed.
    Done,
    /// The batched execute (or the leader itself) failed.
    Failed,
}

struct BatchInner {
    phase: Phase,
    /// Member seeds; index 0 is the leader.
    seeds: Vec<u64>,
    /// Platform-clock join time per member (batch-wait accounting).
    joined_at: Vec<Nanos>,
    /// Flush-early bound for this batch.
    max: usize,
    /// Latest platform-clock time the leader will flush (window
    /// deadline). Joiners compare it against their own admission
    /// deadline: a request never commits to a batch that would hold
    /// it past the horizon at which admission control would have
    /// refused it with a 503.
    flush_by: Nanos,
    exec_started_at: Nanos,
    shares: Vec<Option<BatchShare>>,
    error: Option<String>,
    /// The leader's trace id, when tracing is on (see
    /// [`BatchShare::leader_trace`]).
    leader_trace: Option<String>,
}

struct BatchState {
    inner: Mutex<BatchInner>,
    cv: Condvar,
    clock: Arc<dyn Clock>,
    /// The spec the batch's container embodies (the leader's, at open
    /// time). Joiners whose current spec no longer matches it by
    /// content are refused — a reconfigure evicts stale warm
    /// containers precisely so no post-patch request runs on one, and
    /// an open batch must not smuggle them past that (same content
    /// comparison as the invoker's release-or-retire check).
    spec: Arc<FunctionSpec>,
}

/// The content identity a container embodies: a joiner may only ride
/// a batch whose container matches its own current spec.
fn same_embodiment(a: &FunctionSpec, b: &FunctionSpec) -> bool {
    a.model == b.model && a.variant == b.variant && a.memory_mb == b.memory_mb
}

/// Per-function batch collector. One open (Collecting) batch per
/// function at a time; a new leader can open the next batch as soon as
/// the previous one flushes, so batches pipeline back-to-back under
/// sustained load.
pub struct Batcher {
    default_max_batch: usize,
    default_window: Duration,
    clock: Arc<dyn Clock>,
    open: Mutex<BTreeMap<String, Arc<BatchState>>>,
    /// Batched passes executed (any size — a lone leader whose window
    /// expired still ran through the batch path). Per-request
    /// coalescing counts live in the metrics shards (`batched_requests`
    /// / the `batch_size` histogram), not here: one quantity, one
    /// owner.
    batches: AtomicU64,
    /// Histogram-free running peak, for quick telemetry.
    largest_batch: AtomicU64,
}

impl Batcher {
    pub fn new(max_batch_size: usize, batch_window_ms: u64, clock: Arc<dyn Clock>) -> Self {
        Self {
            default_max_batch: max_batch_size.max(1),
            default_window: Duration::from_millis(batch_window_ms),
            clock,
            open: Mutex::new(BTreeMap::new()),
            batches: AtomicU64::new(0),
            largest_batch: AtomicU64::new(0),
        }
    }

    /// The batch-size bound in effect for `spec`.
    pub fn effective_max_batch(&self, spec: &FunctionSpec) -> usize {
        spec.max_batch_size.unwrap_or(self.default_max_batch).max(1)
    }

    /// The collection window in effect for `spec`.
    pub fn effective_window(&self, spec: &FunctionSpec) -> Duration {
        spec.batch_window_ms.map(Duration::from_millis).unwrap_or(self.default_window)
    }

    /// True when the batching path applies to `spec` at all. With the
    /// defaults (`max_batch_size = 1`) this is false and `invoke`
    /// never touches the batcher — the PR 3 pipeline is preserved
    /// bit-for-bit.
    pub fn enabled(&self, spec: &FunctionSpec) -> bool {
        self.effective_max_batch(spec) > 1
    }

    /// Whether `inner` can accept a joiner whose own admission
    /// deadline is `deadline`: the batch must be collecting with
    /// room, and either it flushes before the joiner's deadline or
    /// the join itself fills it (an immediate flush waits for no
    /// window at all). Joining is a commitment — a member cannot be
    /// refused later — so a request never boards a batch that would
    /// hold it past the horizon at which admission control was
    /// allowed to 503 it.
    fn joinable(inner: &BatchInner, deadline: Nanos) -> bool {
        inner.phase == Phase::Collecting
            && inner.seeds.len() < inner.max
            && (inner.flush_by <= deadline || inner.seeds.len() + 1 >= inner.max)
    }

    /// True when `spec`'s function has an open batch this request
    /// could join right now (same container embodiment, flushes
    /// within the given admission deadline) — the parked-waiter
    /// interrupt probe (see `WarmPool::acquire_or_reserve_or`).
    pub fn has_open(&self, spec: &FunctionSpec, deadline: Nanos) -> bool {
        let open = plock(&self.open);
        match open.get(&spec.name) {
            None => false,
            Some(state) => {
                same_embodiment(&state.spec, spec)
                    && Self::joinable(&plock(&state.inner), deadline)
            }
        }
    }

    /// Join `spec`'s open batch as a follower, if one is collecting,
    /// has room, embodies the same spec content, and flushes within
    /// the joiner's own admission `deadline` (see [`Self::has_open`]).
    /// The returned member parks in [`BatchMember::wait`] until the
    /// leader distributes results.
    pub fn try_join(&self, spec: &FunctionSpec, seed: u64, deadline: Nanos) -> Option<BatchMember> {
        let open = plock(&self.open);
        let state = open.get(&spec.name)?.clone();
        if !same_embodiment(&state.spec, spec) {
            return None;
        }
        let mut g = plock(&state.inner);
        if !Self::joinable(&g, deadline) {
            return None;
        }
        g.seeds.push(seed);
        g.joined_at.push(state.clock.now());
        let index = g.seeds.len() - 1;
        let full = g.seeds.len() >= g.max;
        drop(g);
        drop(open);
        if full {
            // Wake the leader for an early flush.
            state.cv.notify_all();
        }
        Some(BatchMember { state, index })
    }

    /// Open a batch for `spec` with this request as leader (it holds
    /// the container). `None` when batching is off for the function or
    /// another batch is already collecting (the caller then executes
    /// solo — its container is in hand, following would waste it).
    pub fn lead(&self, spec: &Arc<FunctionSpec>, seed: u64) -> Option<BatchLeader<'_>> {
        self.lead_with_window(spec, seed, None)
    }

    /// [`Self::lead`] with an explicit collection window. `None`
    /// falls back to the static per-function/platform window; the
    /// adaptive window controller passes its current output here so
    /// the override lives entirely outside the batcher's own state.
    pub fn lead_with_window(
        &self,
        spec: &Arc<FunctionSpec>,
        seed: u64,
        window_override: Option<Duration>,
    ) -> Option<BatchLeader<'_>> {
        if !self.enabled(spec) {
            return None;
        }
        let mut open = plock(&self.open);
        if open.contains_key(&spec.name) {
            return None;
        }
        let now = self.clock.now();
        let window = window_override.unwrap_or_else(|| self.effective_window(spec));
        let state = Arc::new(BatchState {
            inner: Mutex::new(BatchInner {
                phase: Phase::Collecting,
                seeds: vec![seed],
                joined_at: vec![now],
                max: self.effective_max_batch(spec),
                flush_by: now + window.as_nanos() as Nanos,
                exec_started_at: 0,
                shares: Vec::new(),
                error: None,
                leader_trace: None,
            }),
            cv: Condvar::new(),
            clock: self.clock.clone(),
            spec: spec.clone(),
        });
        open.insert(spec.name.clone(), state.clone());
        Some(BatchLeader {
            batcher: self,
            state,
            function: spec.name.clone(),
            window,
            opened_at: now,
            closed: false,
            finished: false,
        })
    }

    /// Batched passes executed so far.
    pub fn batches_executed(&self) -> u64 {
        self.batches.load(Ordering::SeqCst)
    }

    /// Largest batch flushed so far.
    pub fn largest_batch(&self) -> u64 {
        self.largest_batch.load(Ordering::SeqCst)
    }

    /// Drop `function`'s open-batch slot if it holds `state`.
    fn release_slot(&self, function: &str, state: &Arc<BatchState>) {
        let mut open = plock(&self.open);
        if let Some(cur) = open.get(function) {
            if Arc::ptr_eq(cur, state) {
                open.remove(function);
            }
        }
    }
}

/// The leading request's handle on its open batch. RAII: a leader
/// dropped without [`BatchLeader::complete`] fails the batch so
/// followers surface an error instead of hanging.
pub struct BatchLeader<'a> {
    batcher: &'a Batcher,
    state: Arc<BatchState>,
    function: String,
    window: Duration,
    opened_at: Nanos,
    closed: bool,
    finished: bool,
}

impl BatchLeader<'_> {
    /// Park up to the window for followers; returns early once the
    /// batch is full — or once `flush_early` fires: the invoker wires
    /// it to "this function has requests parked for capacity", so a
    /// leader never holds its container through a window while demand
    /// that cannot board the batch is starving behind it (joinable
    /// demand boards within a probe slice and leaves the queue; what
    /// remains parked after that genuinely needs the container).
    /// ManualClock-safe via the shared [`VirtualWaitPacer`]: an
    /// undisturbed leader advances virtual time toward its own flush
    /// deadline, so a lone leader's window expires in
    /// wall-microseconds.
    pub fn wait_window(&self, flush_early: impl Fn() -> bool) {
        if self.window.is_zero() {
            return;
        }
        let deadline = self.opened_at + self.window.as_nanos() as Nanos;
        let clock = &self.state.clock;
        let mut pacer = VirtualWaitPacer::new();
        let mut waited_once = false;
        loop {
            let g = plock(&self.state.inner);
            if g.seeds.len() >= g.max {
                return;
            }
            if clock.now() >= deadline {
                return;
            }
            // Honored only after at least one wait slice, so joiners
            // woken by the batch opening get their chance to board
            // (and leave the queue) before the depth check fires.
            if waited_once && flush_early() {
                return;
            }
            let len_before = g.seeds.len();
            let timeout = pacer.next_timeout(&**clock, deadline).min(REAL_FLUSH_PROBE);
            let (g, _) = pwait_timeout(&self.state.cv, g, timeout);
            let progressed = g.seeds.len() != len_before;
            drop(g);
            waited_once = true;
            pacer.on_wake(&**clock, progressed, deadline);
        }
    }

    /// Flush: stop accepting followers, free the function's open-batch
    /// slot (the next leader can start collecting while this batch
    /// executes), and return the member seeds (index 0 = leader) for
    /// `Container::execute_batch`.
    pub fn close(&mut self) -> Vec<u64> {
        let mut g = plock(&self.state.inner);
        g.phase = Phase::Executing;
        g.exec_started_at = self.state.clock.now();
        let seeds = g.seeds.clone();
        drop(g);
        self.closed = true;
        self.batcher.release_slot(&self.function, &self.state);
        seeds
    }

    /// Size of the batch right now (after `close`: final size).
    pub fn size(&self) -> usize {
        plock(&self.state.inner).seeds.len()
    }

    /// Distribute the executed batch: per-member predictions (seed
    /// order), the effective duration of the whole pass, and the
    /// largest compiled batch-N kernel that served it. Returns the
    /// LEADER's own share; followers wake with theirs.
    pub fn complete(
        mut self,
        predictions: Vec<Prediction>,
        effective: Duration,
        kernel_batch_n: usize,
    ) -> BatchShare {
        let mut g = plock(&self.state.inner);
        assert_eq!(predictions.len(), g.seeds.len(), "one prediction per member");
        let n = g.seeds.len();
        let billed_share = effective / n as u32;
        let exec_started_at = g.exec_started_at;
        let joined_at = std::mem::take(&mut g.joined_at);
        let leader_trace = g.leader_trace.clone();
        g.shares = predictions
            .into_iter()
            .zip(joined_at)
            .map(|(prediction, joined)| {
                Some(BatchShare {
                    prediction,
                    batch_size: n,
                    effective,
                    billed_share,
                    batch_wait: Duration::from_nanos(exec_started_at.saturating_sub(joined)),
                    kernel_batch_n: kernel_batch_n.max(1),
                    leader_trace: leader_trace.clone(),
                })
            })
            .collect();
        g.phase = Phase::Done;
        let leader_share = g.shares[0].take().expect("leader share");
        drop(g);
        self.finished = true;
        if !self.closed {
            // A leader completing without an explicit close (size-1
            // shortcut paths) must still free the function's slot.
            self.closed = true;
            self.batcher.release_slot(&self.function, &self.state);
        }
        self.batcher.batches.fetch_add(1, Ordering::SeqCst);
        self.batcher.largest_batch.fetch_max(n as u64, Ordering::SeqCst);
        self.state.cv.notify_all();
        leader_share
    }

    /// Fail the batch (the batched execute errored): every follower's
    /// `wait` returns the error.
    pub fn fail(mut self, error: String) {
        self.fail_inner(error);
    }

    /// Record the leader's trace id on the collecting batch so every
    /// member's [`BatchShare`] carries it. Called by the invoker right
    /// after the lead is taken (tracing on only) — strictly before
    /// `complete`, which snapshots the id into the shares.
    pub fn set_trace(&self, trace_id: &str) {
        plock(&self.state.inner).leader_trace = Some(trace_id.to_string());
    }

    fn fail_inner(&mut self, error: String) {
        let mut g = plock(&self.state.inner);
        g.phase = Phase::Failed;
        g.error = Some(error);
        drop(g);
        self.finished = true;
        if !self.closed {
            self.closed = true;
            self.batcher.release_slot(&self.function, &self.state);
        }
        self.state.cv.notify_all();
    }
}

impl Drop for BatchLeader<'_> {
    fn drop(&mut self) {
        // Abnormal exit (error return, panic unwinding): never strand
        // the followers.
        if !self.finished {
            self.fail_inner("batch leader aborted before completing the batch".to_string());
        }
    }
}

/// A follower's handle: one slot in an open batch.
pub struct BatchMember {
    state: Arc<BatchState>,
    index: usize,
}

impl BatchMember {
    /// Park until the leader distributes results (or fails the
    /// batch). Followers never advance virtual time — the leader is
    /// live and does (its window wait and the batched execute both
    /// drive the clock); on non-real clocks this waits in bounded wall
    /// slices so cross-thread wakeups are never missed.
    pub fn wait(self) -> Result<BatchShare, String> {
        let mut g = plock(&self.state.inner);
        loop {
            match g.phase {
                Phase::Done => {
                    return Ok(g.shares[self.index].take().expect("each member taken once"));
                }
                Phase::Failed => {
                    return Err(g
                        .error
                        .clone()
                        .unwrap_or_else(|| "batched execution failed".to_string()));
                }
                Phase::Collecting | Phase::Executing => {
                    // Bounded park, never a naked wait: the phase is
                    // re-checked every slice, so a notify lost to a
                    // racing leader crash delays the follower by one
                    // slice instead of parking it forever.
                    let slice = if self.state.clock.is_real() {
                        FOLLOWER_PARK_SLICE
                    } else {
                        VirtualWaitPacer::WAIT_SLICE
                    };
                    g = pwait_timeout(&self.state.cv, g, slice).0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::registry::{FunctionPolicy, FunctionRegistry};
    use crate::runtime::MockEngine;
    use crate::util::ManualClock;

    fn spec(max_batch: Option<usize>, window_ms: Option<u64>) -> Arc<FunctionSpec> {
        let reg = FunctionRegistry::new(Arc::new(MockEngine::paper_zoo()));
        reg.deploy_full(
            "sq",
            "squeezenet",
            "pallas",
            512,
            FunctionPolicy {
                max_batch_size: max_batch,
                batch_window_ms: window_ms,
                ..Default::default()
            },
        )
        .unwrap()
    }

    fn pred(top1: i32, ms: u64) -> Prediction {
        Prediction { top1, top_prob: 0.9, compute: Duration::from_millis(ms) }
    }

    #[test]
    fn disabled_by_default_and_per_function_overrides() {
        let clock = ManualClock::new();
        let b = Batcher::new(1, 0, clock.clone());
        let s = spec(None, None);
        assert!(!b.enabled(&s), "platform default 1 = off");
        assert!(b.lead(&s, 1).is_none());
        assert!(b.try_join(&s, 1, u64::MAX).is_none());
        assert!(!b.has_open(&s, u64::MAX));
        // Per-function override turns it on; platform default window.
        let s = spec(Some(4), Some(10));
        assert!(b.enabled(&s));
        assert_eq!(b.effective_max_batch(&s), 4);
        assert_eq!(b.effective_window(&s), Duration::from_millis(10));
        // And a spec override of 1 turns it off under a batching-on
        // platform default.
        let b = Batcher::new(8, 5, clock);
        let s1 = spec(Some(1), None);
        assert!(!b.enabled(&s1));
        assert_eq!(b.effective_window(&spec(None, None)), Duration::from_millis(5));
    }

    /// Lone leader on a ManualClock: the window flushes at its
    /// (virtual) deadline with no outside time driver, and the batch
    /// stays size 1.
    #[test]
    fn window_flush_at_virtual_deadline() {
        let clock = ManualClock::new();
        let b = Batcher::new(8, 50, clock.clone());
        let s = spec(None, None);
        let mut leader = b.lead(&s, 7).expect("batching on");
        assert!(b.has_open(&s, u64::MAX));
        let wall0 = std::time::Instant::now();
        leader.wait_window(|| false);
        assert!(clock.now() >= 50_000_000, "virtual clock reached the window deadline");
        assert!(wall0.elapsed() < Duration::from_secs(5), "self-advanced in wall-microseconds");
        let seeds = leader.close();
        assert_eq!(seeds, vec![7]);
        assert!(!b.has_open(&s, u64::MAX), "flushed batch no longer joinable");
        let share = leader.complete(vec![pred(3, 100)], Duration::from_millis(100), 1);
        assert_eq!(share.batch_size, 1);
        assert_eq!(share.billed_share, Duration::from_millis(100));
        assert!(share.batch_wait >= Duration::from_millis(50), "leader waited the window");
        assert_eq!(b.batches_executed(), 1);
    }

    /// A full batch flushes early: the joining thread wakes the
    /// leader before the window deadline, and every member gets its
    /// own share with the billed split.
    #[test]
    fn early_flush_at_max_batch_size_with_shares() {
        let clock = ManualClock::new();
        let b = Arc::new(Batcher::new(2, 60_000, clock.clone()));
        let s = spec(None, None);
        let mut leader = b.lead(&s, 1).unwrap();
        let member = b.try_join(&s, 2, u64::MAX).expect("room for one follower");
        assert!(b.try_join(&s, 3, u64::MAX).is_none(), "batch full");
        // Window is 60 s of virtual time; the full batch must return
        // without consuming it.
        let t0 = clock.now();
        leader.wait_window(|| false);
        assert_eq!(clock.now(), t0, "early flush burned no (virtual) window time");
        let seeds = leader.close();
        assert_eq!(seeds, vec![1, 2]);
        let follower = std::thread::spawn(move || member.wait().unwrap());
        let effective = Duration::from_millis(120);
        let mine = leader.complete(vec![pred(10, 60), pred(20, 60)], effective, 2);
        let theirs = follower.join().unwrap();
        assert_eq!(mine.prediction.top1, 10);
        assert_eq!(theirs.prediction.top1, 20);
        for share in [&mine, &theirs] {
            assert_eq!(share.batch_size, 2);
            assert_eq!(share.effective, effective);
            assert_eq!(share.billed_share, Duration::from_millis(60), "billed split");
        }
        assert_eq!(b.batches_executed(), 1);
        assert_eq!(b.largest_batch(), 2);
    }

    /// A reconfigure evicts stale-spec warm containers so no
    /// post-patch request runs on one; an open batch (whose leader
    /// holds such a container) must enforce the same rule: joiners
    /// whose current spec no longer matches the batch's embodiment
    /// are refused and execute through the normal (fresh-container)
    /// path instead.
    #[test]
    fn stale_spec_batch_refuses_new_spec_joiners() {
        let clock = ManualClock::new();
        let b = Batcher::new(4, 60_000, clock);
        let old = spec(None, None); // 512 MB
        let _leader = b.lead(&old, 1).unwrap();
        // The function was PATCHed to a new memory size mid-window.
        let reg = FunctionRegistry::new(Arc::new(MockEngine::paper_zoo()));
        let new = reg.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        assert!(!b.has_open(&new, u64::MAX), "stale batch invisible to new-spec probes");
        assert!(b.try_join(&new, 2, u64::MAX).is_none(), "new-spec joiner refused");
        // A policy-only difference keeps the same embodiment (model/
        // variant/memory) and may still board, like the invoker's
        // release-or-retire content check.
        let same = reg
            .deploy_full(
                "sq",
                "squeezenet",
                "pallas",
                512,
                FunctionPolicy { max_concurrency: Some(9), ..Default::default() },
            )
            .unwrap();
        assert!(b.try_join(&same, 3, u64::MAX).is_some(), "same embodiment boards");
    }

    /// A leader must not starve parked demand that cannot board its
    /// batch: the early-flush predicate ends the window after one
    /// probe slice instead of holding the container for the full
    /// window.
    #[test]
    fn window_flushes_early_on_starving_demand() {
        let clock = ManualClock::new();
        let b = Batcher::new(8, 60_000, clock.clone());
        let s = spec(None, None);
        let mut leader = b.lead(&s, 1).unwrap();
        let wall0 = std::time::Instant::now();
        let t0 = clock.now();
        leader.wait_window(|| true); // parked demand that cannot board
        assert!(
            clock.now() - t0 < 60_000_000_000,
            "starved demand ends the window early, not at the 60 s deadline"
        );
        assert!(wall0.elapsed() < Duration::from_secs(5));
        let seeds = leader.close();
        leader.complete(vec![pred(1, 10)], Duration::from_millis(10), 1);
        assert_eq!(seeds, vec![1]);
    }

    /// A join is a commitment, so a request whose admission deadline
    /// lands before the batch's window flush refuses to board — unless
    /// its join fills the batch (which flushes immediately).
    #[test]
    fn join_refused_when_flush_lands_past_admission_deadline() {
        let clock = ManualClock::new();
        let b = Batcher::new(3, 1_000, clock.clone()); // flush_by = 1 s
        let s = spec(None, None);
        let _leader = b.lead(&s, 1).unwrap();
        let short = 500_000_000; // 0.5 s admission horizon
        let long = 2_000_000_000;
        assert!(!b.has_open(&s, short), "flush at 1 s exceeds a 0.5 s horizon");
        assert!(b.try_join(&s, 2, short).is_none());
        assert!(b.has_open(&s, long));
        let _m2 = b.try_join(&s, 2, long).expect("2 s horizon covers the window");
        // Now one slot left: a filling join flushes immediately, so
        // even the short-horizon request may board.
        assert!(b.has_open(&s, short), "filling join waits for no window");
        let _m3 = b.try_join(&s, 3, short).expect("filling join allowed");
        assert!(b.try_join(&s, 4, long).is_none(), "batch full");
    }

    #[test]
    fn failed_batch_propagates_to_followers() {
        let clock = ManualClock::new();
        let b = Batcher::new(4, 1_000, clock);
        let s = spec(None, None);
        let mut leader = b.lead(&s, 1).unwrap();
        let member = b.try_join(&s, 2, u64::MAX).unwrap();
        leader.close();
        let follower = std::thread::spawn(move || member.wait());
        leader.fail("engine exploded".to_string());
        let err = follower.join().unwrap().unwrap_err();
        assert!(err.contains("engine exploded"));
        assert_eq!(b.batches_executed(), 0, "failed batches are not counted as executed");
    }

    /// RAII: a leader that errors out (drops without complete/fail)
    /// must not strand its followers, and must free the open slot for
    /// the next leader.
    #[test]
    fn dropped_leader_fails_batch_and_frees_slot() {
        let clock = ManualClock::new();
        let b = Batcher::new(4, 1_000, clock);
        let s = spec(None, None);
        let leader = b.lead(&s, 1).unwrap();
        let member = b.try_join(&s, 2, u64::MAX).unwrap();
        let follower = std::thread::spawn(move || member.wait());
        drop(leader); // e.g. an early `?` return in the invoker
        let err = follower.join().unwrap().unwrap_err();
        assert!(err.contains("aborted"), "{err}");
        assert!(!b.has_open(&s, u64::MAX));
        assert!(b.lead(&s, 9).is_some(), "slot reusable after the abort");
    }

    /// A batch leader that panics mid-pass *while holding the batch
    /// mutex* poisons it — followers and the leader's own RAII fail
    /// path must shrug that off (plock semantics) instead of turning
    /// one crash into a platform-wide panic cascade.
    #[test]
    fn panicking_leader_does_not_wedge_or_panic_followers() {
        let clock = ManualClock::new();
        let b = Batcher::new(4, 60_000, clock);
        let s = spec(None, None);
        let leader = b.lead(&s, 1).unwrap();
        let member = b.try_join(&s, 2, u64::MAX).unwrap();
        let follower = std::thread::spawn(move || member.wait());
        // Worst-case crash: the mutex is poisoned AND the leader
        // unwinds without completing the batch.
        let state = leader.state.clone();
        let _ = std::thread::spawn(move || {
            let _g = state.inner.lock().unwrap();
            panic!("leader dies mid-batch");
        })
        .join();
        assert!(leader.state.inner.is_poisoned());
        drop(leader); // the RAII fail path must tolerate the poison
        let err = follower.join().expect("follower must not panic").unwrap_err();
        assert!(err.contains("aborted"), "{err}");
        // The slot was freed through the poisoned mutex: the next
        // leader opens and completes a batch normally.
        assert!(!b.has_open(&s, u64::MAX));
        let next = b.lead(&s, 9).expect("slot reusable after the crash");
        next.complete(vec![pred(1, 10)], Duration::from_millis(10), 1);
        assert_eq!(b.batches_executed(), 1);
    }

    /// One open batch per function: while one collects, a second
    /// would-be leader executes solo; once flushed, leading works
    /// again.
    #[test]
    fn single_open_batch_per_function() {
        let clock = ManualClock::new();
        let b = Batcher::new(4, 1_000, clock);
        let s = spec(None, None);
        let mut first = b.lead(&s, 1).unwrap();
        assert!(b.lead(&s, 2).is_none(), "slot taken");
        first.close();
        let second = b.lead(&s, 3);
        assert!(second.is_some(), "next leader can collect while the first executes");
        second.unwrap().complete(vec![pred(1, 10)], Duration::from_millis(10), 1);
        first.complete(vec![pred(0, 10)], Duration::from_millis(10), 1);
        assert_eq!(b.batches_executed(), 2);
    }
}
