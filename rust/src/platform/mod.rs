//! The FaaS platform core (the paper's measured system, built).

pub mod async_invoke;
pub mod batcher;
pub mod billing;
pub mod container;
pub mod dispatcher;
pub mod invoker;
pub mod maintainer;
pub mod metrics;
pub mod policy;
pub mod pool;
pub mod registry;
pub mod scaler;
pub mod snapshots;
pub mod throttle;
pub mod trace;

pub use async_invoke::{AsyncInvocation, AsyncInvoker, AsyncStatus, SubmitError};
pub use batcher::Batcher;
pub use billing::{BillingMeter, InvoiceLine};
pub use container::{Container, ContainerState};
pub use dispatcher::{Dispatcher, QueueTicket};
pub use invoker::{InvokeError, InvokeOutcome, Invoker, Platform, ReconfigurePatch, SaturationKind};
pub use maintainer::{MaintenanceReport, PoolMaintainer};
pub use metrics::{FnMetrics, InvocationRecord, MetricsSink, StartKind};
pub use policy::{PolicyEngine, PolicySnapshot, BATCH_WAIT_SLO_FRACTION};
pub use pool::{AcquireOutcome, WarmPool};
pub use registry::{FunctionPolicy, FunctionRegistry, FunctionSpec};
pub use scaler::Scaler;
pub use snapshots::{SnapshotKey, SnapshotStore};
pub use throttle::CpuGovernor;
pub use trace::{Span, Stage, Trace, TraceSink};
