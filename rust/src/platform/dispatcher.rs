//! Admission-controlled dispatch: bounded per-function wait queues.
//!
//! The paper's central finding is that cold starts skew the latency
//! distribution; rejecting every transient capacity miss with an
//! instant 429 makes the platform *worse* than real Lambda, which
//! absorbs bursts with brief queueing. The [`Dispatcher`] implements
//! the admission side of that trade: each function has a bounded wait
//! queue; a request that misses warm capacity takes a [`QueueTicket`]
//! and parks in the waitable [`super::pool::WarmPool`] until a
//! container or a capacity slot frees, up to a deadline. Admission
//! outcomes map to HTTP:
//!
//! * queue at its bound → refuse immediately (`503` queue saturated),
//! * deadline exhausted while parked → `503` + `Retry-After`,
//! * per-function concurrency cap → `429` (enforced before admission;
//!   the queue absorbs *capacity* misses, not cap violations).
//!
//! Both bounds come from `platform.queue_capacity` /
//! `platform.queue_deadline_ms`, overridable per function at
//! deploy/reconfigure time. The dispatcher also streams the
//! saturation telemetry the stats routes serve: current and peak
//! queue depth and the deadline-expired count.
//!
//! Micro-batching (see [`super::batcher::Batcher`]) composes with
//! admission rather than replacing it: a parked capacity waiter is
//! interrupted out of its pool wait when a joinable batch opens —
//! riding an existing container beats waiting for one — and resumes
//! the same wait (same ticket, same arrival-anchored deadline) if it
//! loses the join race. Two rules keep batching from degrading the
//! admission contract: a request only boards a batch whose window
//! flush lands within its own admission horizon (joining is a
//! commitment, so boarding a slower batch could otherwise outwait the
//! 503 the dispatcher owed), and a batch leader flushes its window
//! early while requests it cannot absorb sit parked in this queue —
//! a held container must not starve the demand behind it.

use super::registry::FunctionSpec;
use crate::util::plock;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

pub struct Dispatcher {
    /// Platform-default per-function queue bound (0 = no queueing).
    default_capacity: usize,
    /// Platform-default wait deadline (0 = try once, never park).
    default_deadline: Duration,
    /// Live queued-request count per function (entries removed at 0).
    depth_by_fn: Mutex<BTreeMap<String, usize>>,
    /// Total requests currently queued across all functions.
    depth: AtomicUsize,
    /// High-water mark of `depth`.
    peak_depth: AtomicUsize,
    /// Requests that exhausted their deadline while parked.
    expired: AtomicUsize,
}

/// RAII admission slot in one function's wait queue: holds the queue
/// depth accounting for exactly as long as the request is waiting or
/// being served, and carries the request's effective wait budget.
pub struct QueueTicket<'a> {
    dispatcher: &'a Dispatcher,
    function: String,
    /// Effective deadline for this request (per-function override or
    /// the platform default).
    pub deadline: Duration,
}

impl Dispatcher {
    pub fn new(queue_capacity: usize, queue_deadline_ms: u64) -> Self {
        Self {
            default_capacity: queue_capacity,
            default_deadline: Duration::from_millis(queue_deadline_ms),
            depth_by_fn: Mutex::new(BTreeMap::new()),
            depth: AtomicUsize::new(0),
            peak_depth: AtomicUsize::new(0),
            expired: AtomicUsize::new(0),
        }
    }

    /// The queue bound in effect for `spec`.
    pub fn effective_capacity(&self, spec: &FunctionSpec) -> usize {
        spec.queue_capacity.unwrap_or(self.default_capacity)
    }

    /// The wait deadline in effect for `spec`.
    pub fn effective_deadline(&self, spec: &FunctionSpec) -> Duration {
        spec.queue_deadline_ms.map(Duration::from_millis).unwrap_or(self.default_deadline)
    }

    /// The platform-default wait deadline (for callers with no spec
    /// at hand, e.g. the async workers' inter-attempt park).
    pub fn default_deadline(&self) -> Duration {
        self.default_deadline
    }

    /// Admit one request to `spec`'s wait queue. `None` when the
    /// queue is already at its bound (the saturation signal the
    /// gateway maps to 503) — including always, when the bound is 0
    /// (the invoker then falls back to one non-parking capacity
    /// probe, so "no queueing" cannot starve an idle platform).
    pub fn admit(&self, spec: &FunctionSpec) -> Option<QueueTicket<'_>> {
        let capacity = self.effective_capacity(spec);
        {
            let mut g = plock(&self.depth_by_fn);
            let count = g.entry(spec.name.clone()).or_insert(0);
            if *count >= capacity {
                if *count == 0 {
                    g.remove(&spec.name);
                }
                return None;
            }
            *count += 1;
        }
        let now = self.depth.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak_depth.fetch_max(now, Ordering::SeqCst);
        Some(QueueTicket {
            dispatcher: self,
            function: spec.name.clone(),
            deadline: self.effective_deadline(spec),
        })
    }

    /// Requests currently queued for `function`.
    pub fn queue_depth(&self, function: &str) -> usize {
        plock(&self.depth_by_fn).get(function).copied().unwrap_or(0)
    }

    /// Requests currently queued across all functions.
    pub fn total_depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    /// High-water mark of the total queue depth.
    pub fn peak_depth(&self) -> usize {
        self.peak_depth.load(Ordering::SeqCst)
    }

    /// Requests that exhausted their deadline while parked.
    pub fn expired_total(&self) -> usize {
        self.expired.load(Ordering::SeqCst)
    }

    pub fn note_expired(&self) {
        self.expired.fetch_add(1, Ordering::SeqCst);
    }
}

impl Drop for QueueTicket<'_> {
    fn drop(&mut self) {
        let mut g = plock(&self.dispatcher.depth_by_fn);
        if let Some(count) = g.get_mut(&self.function) {
            *count = count.saturating_sub(1);
            if *count == 0 {
                // Entries are dropped at zero so churned function
                // names don't grow the map without bound.
                g.remove(&self.function);
            }
        }
        drop(g);
        self.dispatcher.depth.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::registry::FunctionRegistry;
    use crate::runtime::MockEngine;
    use std::sync::Arc;

    fn spec(queue_capacity: Option<usize>, queue_deadline_ms: Option<u64>) -> Arc<FunctionSpec> {
        let reg = FunctionRegistry::new(Arc::new(MockEngine::paper_zoo()));
        reg.deploy_full(
            "sq",
            "squeezenet",
            "pallas",
            512,
            crate::platform::registry::FunctionPolicy {
                queue_capacity,
                queue_deadline_ms,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn admission_bounded_by_capacity() {
        let d = Dispatcher::new(2, 1000);
        let s = spec(None, None);
        let a = d.admit(&s).expect("first admitted");
        let b = d.admit(&s).expect("second admitted");
        assert!(d.admit(&s).is_none(), "queue at bound refuses");
        assert_eq!(d.queue_depth("sq"), 2);
        assert_eq!(d.total_depth(), 2);
        drop(a);
        assert_eq!(d.queue_depth("sq"), 1);
        let c = d.admit(&s).expect("slot freed by drop");
        drop(b);
        drop(c);
        assert_eq!(d.queue_depth("sq"), 0);
        assert_eq!(d.total_depth(), 0);
        assert_eq!(d.peak_depth(), 2, "peak sticks");
        // Drained entries are dropped from the per-function map.
        assert!(d.depth_by_fn.lock().unwrap().is_empty());
    }

    #[test]
    fn per_function_overrides_beat_defaults() {
        let d = Dispatcher::new(8, 2000);
        let s = spec(Some(1), Some(250));
        assert_eq!(d.effective_capacity(&s), 1);
        assert_eq!(d.effective_deadline(&s), Duration::from_millis(250));
        let t = d.admit(&s).unwrap();
        assert_eq!(t.deadline, Duration::from_millis(250));
        assert!(d.admit(&s).is_none(), "override bound of 1 enforced");
        let plain = spec(None, None);
        assert_eq!(d.effective_capacity(&plain), 8);
        assert_eq!(d.effective_deadline(&plain), Duration::from_millis(2000));
    }

    #[test]
    fn zero_capacity_disables_queueing() {
        let d = Dispatcher::new(0, 2000);
        let s = spec(None, None);
        assert!(d.admit(&s).is_none());
        assert_eq!(d.total_depth(), 0);
        assert!(d.depth_by_fn.lock().unwrap().is_empty(), "refusal leaves no entry behind");
        // A per-function override re-enables it.
        let s = spec(Some(1), None);
        assert!(d.admit(&s).is_some());
    }

    #[test]
    fn expired_counter() {
        let d = Dispatcher::new(1, 1);
        assert_eq!(d.expired_total(), 0);
        d.note_expired();
        d.note_expired();
        assert_eq!(d.expired_total(), 2);
    }
}
