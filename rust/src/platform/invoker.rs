//! The invocation pipeline: route -> admit (warm | queued wait |
//! cold provision) -> throttled execute -> meter -> release.
//!
//! [`Platform`] is the top-level façade the gateway, experiments, and
//! examples use: it owns the registry, warm pool, dispatcher, scaler,
//! CPU governor, billing meter, metrics sink, and the engine.
//! `invoke` is safe to call from many threads concurrently (the
//! scalability experiments do).
//!
//! Admission contract (replaces the old "synchronous acquire or
//! instant 429"): a request that misses warm capacity takes a bounded
//! per-function queue slot from the [`Dispatcher`] and parks in the
//! waitable [`WarmPool`] until a container or a capacity slot frees.
//! 429 ([`InvokeError::Throttled`]) now means exactly one thing — the
//! function's own `max_concurrency` cap; capacity pressure surfaces
//! as bounded queue wait, and only as 503
//! ([`InvokeError::Saturated`]) once the queue itself is full or the
//! wait deadline is exhausted.

use super::batcher::{BatchMember, Batcher};
use super::billing::BillingMeter;
use super::container::{Container, ProvisionCost};
use super::dispatcher::Dispatcher;
use super::maintainer::{MaintenanceReport, PoolMaintainer};
use super::metrics::{InvocationRecord, MetricsSink, StartKind};
use super::policy::PolicyEngine;
use super::pool::{AcquireOutcome, WarmPool};
use super::registry::{FunctionPolicy, FunctionRegistry, FunctionSpec};
use super::scaler::Scaler;
use super::snapshots::{SnapshotKey, SnapshotStore};
use super::throttle::CpuGovernor;
use super::trace::{Trace, TraceSink};
use crate::configparse::PlatformConfig;
use crate::runtime::{Engine, Prediction};
use crate::util::clock::Nanos;
use crate::util::{plock, Clock, SplitMix64, SystemClock};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Why an admitted request was refused with 503.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SaturationKind {
    /// The function's wait queue was already at its bound.
    QueueFull,
    /// The request parked but its dispatch deadline passed before a
    /// container or capacity slot freed.
    DeadlineExpired,
}

/// Error kind surfaced to the gateway (HTTP status mapping).
#[derive(Debug)]
pub enum InvokeError {
    NotFound(String),
    /// Per-function concurrency cap (HTTP 429).
    Throttled,
    /// Admission queue saturated or wait deadline exhausted (HTTP 503
    /// + `Retry-After`).
    Saturated(SaturationKind),
    Failed(anyhow::Error),
}

impl std::fmt::Display for InvokeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InvokeError::NotFound(name) => write!(f, "function not found: {name}"),
            InvokeError::Throttled => {
                write!(f, "throttled: per-function concurrency cap reached")
            }
            InvokeError::Saturated(SaturationKind::QueueFull) => {
                write!(f, "saturated: dispatch queue full")
            }
            InvokeError::Saturated(SaturationKind::DeadlineExpired) => {
                write!(f, "saturated: no capacity freed within the dispatch deadline")
            }
            InvokeError::Failed(e) => write!(f, "execution failed: {e:#}"),
        }
    }
}

impl std::error::Error for InvokeError {}

impl From<anyhow::Error> for InvokeError {
    fn from(e: anyhow::Error) -> Self {
        InvokeError::Failed(e)
    }
}

/// Successful invocation result.
#[derive(Debug, Clone)]
pub struct InvokeOutcome {
    pub record: InvocationRecord,
    pub prediction: Prediction,
}

pub struct Invoker {
    pub registry: FunctionRegistry,
    pub pool: WarmPool,
    pub dispatcher: Dispatcher,
    pub batcher: Batcher,
    pub scaler: Scaler,
    pub billing: BillingMeter,
    pub metrics: MetricsSink,
    /// Snapshot/checkpoint-restore store: every cold provision
    /// (demand and prewarm/maintainer) goes through it; disabled by
    /// default (`platform.snapshot.enabled` / per-function override).
    pub snapshots: Arc<SnapshotStore>,
    /// Adaptive hot-path controllers (batch window, kernel rungs,
    /// predictive pre-provisioning): disabled by default
    /// (`policy.enabled` / per-function `adaptive` override), in which
    /// case every read-back returns the static knob and the fixed
    /// pipeline is preserved bit-for-bit.
    pub policy: Arc<PolicyEngine>,
    /// End-to-end invocation tracing (`trace.enabled`, default off):
    /// one typed span timeline per invocation, tail-sampled into a
    /// bounded exemplar ring. Disabled, `begin()` returns `None` and
    /// no trace lock is ever acquired — the pipeline is preserved
    /// bit-for-bit.
    pub trace: TraceSink,
    governor: CpuGovernor,
    engine: Arc<dyn Engine>,
    config: PlatformConfig,
    clock: Arc<dyn Clock>,
    rng: Mutex<SplitMix64>,
    /// Per-function in-flight counters (enforces `max_concurrency`).
    fn_in_flight: Mutex<BTreeMap<String, usize>>,
    /// Background pool maintainer, when started (keep-alive sweeps +
    /// `min_warm` replenishment; see `platform/maintainer.rs`).
    maintainer: Mutex<Option<PoolMaintainer>>,
}

/// Partial update applied by [`Invoker::reconfigure`]; `None` fields
/// keep the current value. The cap and the queue/batch overrides are
/// doubly optional so a patch can explicitly clear them back to the
/// platform defaults (`Some(None)`, JSON `null`).
#[derive(Debug, Clone, Default)]
pub struct ReconfigurePatch {
    pub memory_mb: Option<u32>,
    pub variant: Option<String>,
    pub min_warm: Option<usize>,
    pub max_concurrency: Option<Option<usize>>,
    pub queue_capacity: Option<Option<usize>>,
    pub queue_deadline_ms: Option<Option<u64>>,
    pub max_batch_size: Option<Option<usize>>,
    pub batch_window_ms: Option<Option<u64>>,
    pub snapshot: Option<Option<bool>>,
    pub slo_target_ms: Option<Option<u64>>,
    pub adaptive: Option<Option<bool>>,
}

/// RAII decrement for one function's in-flight counter. The release
/// notifies the pool's waiters: async workers that backed off on a
/// 429 park on the same waitable primitive as capacity misses, so a
/// freed concurrency slot must wake them.
struct FnFlightGuard<'a> {
    map: &'a Mutex<BTreeMap<String, usize>>,
    pool: &'a WarmPool,
    name: String,
}

impl<'a> FnFlightGuard<'a> {
    /// Register one in-flight request for `name`; `None` when the
    /// function's concurrency cap is already saturated.
    fn acquire(
        map: &'a Mutex<BTreeMap<String, usize>>,
        pool: &'a WarmPool,
        name: &str,
        cap: Option<usize>,
    ) -> Option<Self> {
        let mut g = plock(&map);
        let count = g.entry(name.to_string()).or_insert(0);
        if let Some(cap) = cap {
            if *count >= cap {
                if *count == 0 {
                    g.remove(name);
                }
                return None;
            }
        }
        *count += 1;
        Some(FnFlightGuard { map, pool, name: name.to_string() })
    }
}

impl Drop for FnFlightGuard<'_> {
    fn drop(&mut self) {
        {
            let mut g = plock(&self.map);
            if let Some(count) = g.get_mut(&self.name) {
                *count = count.saturating_sub(1);
                if *count == 0 {
                    g.remove(&self.name);
                }
            }
        }
        // Targeted wake: only this function's parked waiters care that
        // one of ITS concurrency slots freed — a broadcast would stampede
        // every shard's waiters to re-probe a cap that never applied to
        // them (the thundering herd the sharded pool exists to avoid).
        self.pool.notify_function(&self.name);
    }
}

/// Per-invocation trace context threaded from admission to the record
/// site. `id` is `None` whenever tracing is off (the default), which
/// makes the whole bundle inert: every trace helper checks it first
/// and does nothing — no allocation, no lock, no rng draw.
struct TraceCtx {
    id: Option<String>,
    /// Async submit time (span `admission` stretches from here to the
    /// platform arrival); `None` for synchronous requests.
    submitted_at: Option<Nanos>,
    /// Platform arrival (queue-wait anchor).
    arrived_at: Nanos,
    /// Effective SLO budget for the trace's violation flag.
    slo_ms: u64,
}

/// Alias used across the crate: the assembled platform.
pub type Platform = Invoker;

impl Invoker {
    pub fn new(config: PlatformConfig, engine: Arc<dyn Engine>, clock: Arc<dyn Clock>) -> Self {
        Self {
            registry: FunctionRegistry::new(engine.clone()),
            pool: WarmPool::sharded(
                config.max_containers,
                config.keep_alive_s,
                clock.clone(),
                config.pool_shards,
            ),
            dispatcher: Dispatcher::new(config.queue_capacity, config.queue_deadline_ms),
            batcher: Batcher::new(config.max_batch_size, config.batch_window_ms, clock.clone()),
            scaler: Scaler::new(),
            billing: BillingMeter::new(config.pricing.clone()),
            metrics: MetricsSink::with_capacity(config.metrics_ring_capacity),
            governor: CpuGovernor::new(config.full_power_mem_mb, clock.clone()),
            snapshots: Arc::new(SnapshotStore::new(config.snapshot.clone())),
            policy: Arc::new(PolicyEngine::new(config.policy.clone())),
            // Salted so the sampling coin stream is independent of the
            // provision-jitter stream even though both derive from
            // `platform.seed`.
            trace: TraceSink::new(&config.trace, config.seed ^ 0x7472_6163_65),
            engine,
            rng: Mutex::new(SplitMix64::new(config.seed)),
            config,
            clock,
            fn_in_flight: Mutex::new(BTreeMap::new()),
            maintainer: Mutex::new(None),
        }
    }

    /// Platform on the system clock (live serving).
    pub fn live(config: PlatformConfig, engine: Arc<dyn Engine>) -> Self {
        Self::new(config, engine, Arc::new(SystemClock::new()))
    }

    pub fn config(&self) -> &PlatformConfig {
        &self.config
    }

    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    pub fn engine(&self) -> &Arc<dyn Engine> {
        &self.engine
    }

    pub fn governor(&self) -> &CpuGovernor {
        &self.governor
    }

    /// Deploy a function (name, model, variant, memory) with default
    /// policy.
    pub fn deploy(
        &self,
        name: &str,
        model: &str,
        variant: &str,
        memory_mb: u32,
    ) -> Result<Arc<FunctionSpec>> {
        let spec = self.registry.deploy(name, model, variant, memory_mb)?;
        self.eager_snapshot_capture(&spec);
        Ok(spec)
    }

    /// Deploy with the full v2 spec (warm-pool policy + concurrency
    /// cap + admission-queue and micro-batching overrides). `min_warm`
    /// containers are provisioned eagerly, best-effort: the target is
    /// a policy, not a transaction, so hitting the container cap
    /// mid-prewarm does not fail (or roll back) the deployment —
    /// callers can read the achieved count from the pool
    /// (`warm_containers` in the API resource).
    pub fn deploy_full(
        &self,
        name: &str,
        model: &str,
        variant: &str,
        memory_mb: u32,
        policy: FunctionPolicy,
    ) -> Result<Arc<FunctionSpec>> {
        let spec = self.registry.deploy_full(name, model, variant, memory_mb, policy)?;
        self.top_up_warm_pool(&spec);
        self.eager_snapshot_capture(&spec);
        Ok(spec)
    }

    /// Atomic create (v2 POST semantics): fails if the name is taken,
    /// so two racing creates cannot both succeed. Prewarm is
    /// best-effort, as in [`Self::deploy_full`].
    pub fn create_full(
        &self,
        name: &str,
        model: &str,
        variant: &str,
        memory_mb: u32,
        policy: FunctionPolicy,
    ) -> Result<Arc<FunctionSpec>> {
        let spec = self.registry.create_full(name, model, variant, memory_mb, policy)?;
        self.top_up_warm_pool(&spec);
        self.eager_snapshot_capture(&spec);
        Ok(spec)
    }

    /// Deploy-time eager checkpoint capture (the predictive
    /// pre-provisioning controller's deploy-side half): with the
    /// adaptive controllers AND the snapshot store on for `spec`, and
    /// no checkpoint for its shape yet, provision one container
    /// through the snapshot path so the capture happens NOW — before
    /// the first burst — instead of inside the first demand cold
    /// start. Keep-warm vs snapshot-restore per function falls out of
    /// the spec's own cost statement: a function that keeps no warm
    /// pool (`min_warm == 0`) has declared idle memory rent too
    /// expensive, so once the capture has landed the probe container
    /// is evicted again and bursts are absorbed by restores; a
    /// `min_warm > 0` function keeps the container — it doubles as
    /// warm capacity. Best-effort like every prewarm (capture probes
    /// with the shape-level cost map, not `lookup`, so hit/miss
    /// counters stay demand-only).
    fn eager_snapshot_capture(&self, spec: &Arc<FunctionSpec>) {
        if !self.policy.enabled_for(spec) || !self.snapshots.enabled_for(spec) {
            return;
        }
        let key = SnapshotKey::of(spec);
        if self.snapshots.capture_cost(&key).is_some() {
            return; // the shape is already captured
        }
        self.prewarm_up_to(spec, spec.min_warm.max(1));
        // Only drop the probe once the capture actually landed (under
        // `CapturePolicy::Background` it may still be in flight on the
        // live instance — keep-alive reaps the probe later either
        // way).
        if spec.min_warm == 0 && self.snapshots.capture_cost(&key).is_some() {
            self.pool.evict_function(&spec.name);
        }
    }

    /// Best-effort top-up to `target` warm containers for `spec`;
    /// returns how many were provisioned. One container per step
    /// (`Scaler::prewarm` fails a batch outright on a cap hit — the
    /// v1 prewarm route's contract — while a top-up must keep the
    /// partial count), re-checking the pool so a concurrent acquire
    /// can't turn this into a hot loop.
    fn prewarm_up_to(&self, spec: &Arc<FunctionSpec>, target: usize) -> usize {
        let mut done = 0;
        for _ in 0..target {
            if self.pool.warm_count(&spec.name) >= target {
                break;
            }
            match self.prewarm(&spec.name, 1) {
                Ok(n) => done += n,
                Err(_) => break, // container cap, or undeployed meanwhile
            }
        }
        done
    }

    /// Best-effort provision up to the spec's `min_warm` target.
    fn top_up_warm_pool(&self, spec: &Arc<FunctionSpec>) {
        self.prewarm_up_to(spec, spec.min_warm);
    }

    /// Remove a function: drop the registration, its metrics shard
    /// (platform totals keep the history), its shape's snapshot when
    /// it was the shape's last user (the checkpoint must not outlive
    /// every deployment that could have seeded it), and reap its warm
    /// containers. Returns the number of containers reaped. In-flight
    /// invocations complete; their containers age out via keep-alive.
    pub fn undeploy(&self, name: &str) -> Result<usize> {
        let Ok(spec) = self.registry.get(name) else {
            bail!("function {name:?} is not deployed");
        };
        if !self.registry.remove(name) {
            bail!("function {name:?} is not deployed");
        }
        self.metrics.remove_function(name);
        self.policy.remove_function(name);
        self.invalidate_snapshot_if_shape_unused(&SnapshotKey::of(&spec));
        Ok(self.pool.evict_function(name))
    }

    /// Invalidate `key`'s snapshot unless another deployed function
    /// still embodies the same shape: snapshots are shared per shape
    /// (model + variant + memory), so one function's lifecycle event
    /// must not drop a checkpoint its siblings are actively restoring
    /// from — the blob is function-agnostic and stays valid for them.
    fn invalidate_snapshot_if_shape_unused(&self, key: &SnapshotKey) {
        let still_used = self.registry.list().iter().any(|s| SnapshotKey::of(s) == *key);
        if !still_used {
            self.snapshots.invalidate(key);
        }
    }

    /// Apply a partial spec update. Warm containers are evicted only
    /// when the patch changes something a container embodies
    /// (memory/variant) — a cap- or policy-only patch keeps the pool
    /// warm. The new `min_warm` target is then topped up best-effort
    /// (see [`Self::deploy_full`]). Validation failures leave the
    /// current spec untouched.
    pub fn reconfigure(&self, name: &str, patch: &ReconfigurePatch) -> Result<Arc<FunctionSpec>> {
        let cur = self.registry.get(name)?;
        let spec = self.registry.deploy_full(
            name,
            &cur.model,
            patch.variant.as_deref().unwrap_or(&cur.variant),
            patch.memory_mb.unwrap_or(cur.memory_mb),
            FunctionPolicy {
                min_warm: patch.min_warm.unwrap_or(cur.min_warm),
                max_concurrency: patch.max_concurrency.unwrap_or(cur.max_concurrency),
                queue_capacity: patch.queue_capacity.unwrap_or(cur.queue_capacity),
                queue_deadline_ms: patch.queue_deadline_ms.unwrap_or(cur.queue_deadline_ms),
                max_batch_size: patch.max_batch_size.unwrap_or(cur.max_batch_size),
                batch_window_ms: patch.batch_window_ms.unwrap_or(cur.batch_window_ms),
                snapshot: patch.snapshot.unwrap_or(cur.snapshot),
                slo_target_ms: patch.slo_target_ms.unwrap_or(cur.slo_target_ms),
                adaptive: patch.adaptive.unwrap_or(cur.adaptive),
            },
        )?;
        if spec.memory_mb != cur.memory_mb || spec.variant != cur.variant {
            self.pool.evict_function(name);
            // A redeploy that changes what a container embodies also
            // obsoletes the old shape's checkpoint — unless a sibling
            // deployment still uses that shape.
            self.invalidate_snapshot_if_shape_unused(&SnapshotKey::of(&cur));
        }
        self.top_up_warm_pool(&spec);
        Ok(spec)
    }

    /// Pre-warm `n` containers for `function` (§5 "keep warm" knob).
    pub fn prewarm(&self, function: &str, n: usize) -> Result<usize> {
        let spec = self.registry.get(function)?;
        self.scaler.prewarm(
            &spec,
            n,
            &self.pool,
            &self.engine,
            &self.governor,
            &self.config.bootstrap,
            &self.snapshots,
            &self.clock,
            &self.rng,
        )
    }

    /// Invoke `function` on a (seeded) synthetic image.
    ///
    /// Admission order: the per-function concurrency cap is checked
    /// first (429 — the queue absorbs capacity pressure, not cap
    /// violations), then a warm container is tried, and only a miss
    /// takes a dispatcher queue slot and parks in the waitable pool.
    /// The park ends with a warm container (another request released
    /// one), a capacity reservation (this request cold-provisions —
    /// at most one provision per queued request, decided by the
    /// [`Scaler`]), or a 503 when the deadline passes.
    ///
    /// When micro-batching is enabled for the function
    /// (`max_batch_size > 1`), two extra doors open: a request joins
    /// an already-collecting batch instead of taking a container at
    /// all (including from inside the capacity wait — riding a batch
    /// beats waiting for a container), and a request that does hold a
    /// container leads a batch of its own: it collects followers for
    /// the window, runs ONE batched pass, and fans the results out.
    /// With `max_batch_size = 1` (the default) none of this code is
    /// reached and the pipeline is the pre-batching one, bit-for-bit.
    pub fn invoke(&self, function: &str, image_seed: u64) -> Result<InvokeOutcome, InvokeError> {
        self.invoke_from(function, image_seed, None)
    }

    /// [`Self::invoke`] with an explicit origin: `submitted_at` is the
    /// async submit time carried across the queue, so the trace's
    /// `admission` span covers the pre-platform wait. Synchronous
    /// callers pass `None` (admission is zero-width).
    pub fn invoke_from(
        &self,
        function: &str,
        image_seed: u64,
        submitted_at: Option<Nanos>,
    ) -> Result<InvokeOutcome, InvokeError> {
        let spec = self
            .registry
            .get(function)
            .map_err(|_| InvokeError::NotFound(function.to_string()))?;
        let _fn_flight = match FnFlightGuard::acquire(
            &self.fn_in_flight,
            &self.pool,
            function,
            spec.max_concurrency,
        ) {
            Some(guard) => guard,
            None => {
                self.scaler.note_throttled();
                self.metrics.note_throttled(function);
                return Err(InvokeError::Throttled);
            }
        };
        let t_queue_start = self.clock.now();
        let tctx = self.begin_trace(&spec, submitted_at, t_queue_start);
        // Feed the arrival forecast (admitted requests only — the
        // controllers steer capacity for traffic the cap lets in).
        // Gated so the default-off pipeline takes no policy lock, and
        // ordered after the in-flight guard released its map lock:
        // `policy.state` is only ever acquired standalone.
        if self.policy.enabled_for(&spec) {
            self.policy.on_arrival(function, t_queue_start);
        }
        // The horizon admission control may hold this request to: the
        // batcher compares open batches' flush deadlines against it,
        // so joining a batch never waits longer than parking for a
        // container would have been allowed to.
        let admission_deadline =
            t_queue_start + self.dispatcher.effective_deadline(&spec).as_nanos() as u64;

        // Batching door #1: an open batch for this function absorbs
        // the request outright — no container, no queue slot.
        if self.batcher.enabled(&spec) {
            if let Some(member) =
                self.batcher.try_join(&spec, image_seed, admission_deadline)
            {
                let wait = Duration::from_nanos(self.clock.now() - t_queue_start);
                return self.finish_batch_member(function, &spec, member, wait, &tctx);
            }
        }

        // Admit: warm hit, parked wait, or cold provision. The queue
        // wait ends when the request holds a container or a capacity
        // reservation — for cold starts that is BEFORE provisioning,
        // so the wait never double-counts the provision components
        // the record itemizes separately. The scaler's in-flight
        // guard is taken at the same point: a request parked in the
        // queue is visible as queue depth, not concurrency, so
        // `peak_concurrency` keeps measuring containers' worth of
        // demand (what the paper's Figure 7 ramp drives), provision
        // time included.
        let (mut container, start, queue_wait, _flight) = match self.pool.acquire(function) {
            Some(c) => {
                let wait = Duration::from_nanos(self.clock.now() - t_queue_start);
                (c, StartKind::Warm, wait, self.scaler.arrive())
            }
            None => {
                let outcome = match self.dispatcher.admit(&spec) {
                    Some(ticket) => {
                        // The deadline is anchored at the original
                        // arrival, and the SAME ticket is held across
                        // batch-join attempts: a lost join race goes
                        // back to waiting on the unchanged deadline —
                        // it can neither extend the wait nor forfeit
                        // the queue slot (which another request could
                        // steal, turning the retry into a spurious
                        // queue-full 503).
                        let deadline = t_queue_start + ticket.deadline.as_nanos() as u64;
                        let outcome = loop {
                            match self.pool.acquire_or_reserve_or(
                                function,
                                deadline,
                                || self.batcher.has_open(&spec, admission_deadline),
                            ) {
                                // Batching door #2: a batch opened
                                // while this request was parked for
                                // capacity — riding it beats waiting
                                // for a container.
                                AcquireOutcome::Interrupted => {
                                    if let Some(member) = self.batcher.try_join(
                                        &spec,
                                        image_seed,
                                        admission_deadline,
                                    ) {
                                        drop(ticket);
                                        let wait = Duration::from_nanos(
                                            self.clock.now() - t_queue_start,
                                        );
                                        return self.finish_batch_member(
                                            function, &spec, member, wait, &tctx,
                                        );
                                    }
                                    // Join race lost (batch flushed or
                                    // filled first): keep waiting.
                                }
                                other => break other,
                            }
                        };
                        // The wait is over either way: leave the
                        // queue accounting before serving (or
                        // refusing) the request.
                        drop(ticket);
                        outcome
                    }
                    None => {
                        // Queue at its bound — or queueing disabled
                        // (bound 0), where one immediate probe still
                        // runs: a request that can take a freed
                        // container or reserve a slot on the spot was
                        // never a capacity miss, so "no queueing"
                        // must not starve an idle platform.
                        let outcome = if self.dispatcher.effective_capacity(&spec) == 0 {
                            self.pool.acquire_or_reserve(function, self.clock.now())
                        } else {
                            AcquireOutcome::TimedOut
                        };
                        if matches!(outcome, AcquireOutcome::TimedOut) {
                            self.scaler.note_saturated();
                            self.metrics.note_queue_expired(function);
                            self.trace_refusal(&tctx, function, "saturated: dispatch queue full");
                            return Err(InvokeError::Saturated(SaturationKind::QueueFull));
                        }
                        outcome
                    }
                };
                let wait = Duration::from_nanos(self.clock.now() - t_queue_start);
                match outcome {
                    AcquireOutcome::Container(c) => {
                        (c, StartKind::Warm, wait, self.scaler.arrive())
                    }
                    AcquireOutcome::Reserved => {
                        let flight = self.scaler.arrive();
                        let provisioned = self.scaler.provision_demand(
                            &spec,
                            &self.pool,
                            &self.engine,
                            &self.governor,
                            &self.config.bootstrap,
                            &self.snapshots,
                            &self.clock,
                            &self.rng,
                        );
                        match provisioned {
                            // Cold, or Restored when the snapshot
                            // store served the provision.
                            Ok(c) => {
                                let start = c.start_kind_for_first_use();
                                (c, start, wait, flight)
                            }
                            Err(e) => {
                                self.trace_refusal(
                                    &tctx,
                                    function,
                                    &format!("provision failed: {e:#}"),
                                );
                                return Err(InvokeError::Failed(e));
                            }
                        }
                    }
                    AcquireOutcome::TimedOut => {
                        self.dispatcher.note_expired();
                        self.scaler.note_saturated();
                        self.metrics.note_queue_expired(function);
                        self.trace_refusal(
                            &tctx,
                            function,
                            "saturated: no capacity freed within the dispatch deadline",
                        );
                        return Err(InvokeError::Saturated(SaturationKind::DeadlineExpired));
                    }
                    AcquireOutcome::Interrupted => {
                        unreachable!("interrupts re-enter the admission loop")
                    }
                }
            }
        };

        // Batching door #3: the container holder leads a batch —
        // collect followers for the window, flush, one batched pass.
        // `lead` is `None` when batching is off for this function (the
        // default) or another batch is already collecting; either way
        // the solo path below is unchanged. With the adaptive window
        // controller on, the leader opens with the controller's
        // current window instead of the static knob (queue depth read
        // BEFORE taking the policy lock — standalone acquisition).
        let window_override = if self.policy.enabled_for(&spec) && self.batcher.enabled(&spec) {
            let depth = self.dispatcher.queue_depth(function);
            Some(self.policy.effective_window(
                &spec,
                self.batcher.effective_window(&spec),
                depth,
                self.clock.now(),
            ))
        } else {
            None
        };
        if let Some(leader) = self.batcher.lead_with_window(&spec, image_seed, window_override) {
            return self.execute_batch_leader(
                function, &spec, container, start, queue_wait, leader, &tctx,
            );
        }

        // Execute under the CPU governor.
        let executed = container.execute(&self.governor, &self.clock, image_seed);
        let (prediction, effective_predict) = match executed {
            Ok(v) => v,
            Err(e) => {
                // A failed container is not returned to the pool.
                let pc = container.provision_cost.attributed_to(start);
                self.trace_failure(&tctx, function, start, queue_wait, &pc, &format!("{e:#}"));
                self.pool.retire(container);
                return Err(InvokeError::Failed(e));
            }
        };

        // Meter: billed duration = handler time (cold init inside the
        // handler was billed in 2017-era Lambda) + prediction.
        let pc = container.provision_cost.attributed_to(start);
        let billed = pc.handler_time() + effective_predict;
        let line = match self.billing.charge(function, spec.memory_mb, billed) {
            Ok(line) => line,
            Err(e) => {
                // The container executed but cannot be billed: retire
                // it so its capacity slot is returned — dropping it
                // here used to leak the slot permanently (the pool's
                // `total` never decremented).
                self.trace_failure(&tctx, function, start, queue_wait, &pc, &format!("{e:#}"));
                self.pool.retire(container);
                return Err(InvokeError::Failed(e));
            }
        };

        let record = InvocationRecord {
            function: function.to_string(),
            memory_mb: spec.memory_mb,
            start,
            queue: queue_wait,
            sandbox: pc.sandbox,
            runtime_init: pc.runtime_init,
            package_fetch: pc.package_fetch,
            model_load: pc.model_load,
            restore: pc.restore,
            predict: effective_predict,
            predict_full_speed: prediction.compute,
            batch_size: 1,
            batch_wait: Duration::ZERO,
            kernel_batch_n: 1,
            batch_kernel_hits: 0,
            batch_kernel_misses: 0,
            billed,
            billed_ms: line.billed_ms,
            cost_dollars: line.total_dollars(),
            top1: prediction.top1,
            trace_id: tctx.id.clone(),
        };
        self.metrics.record(record.clone());
        self.note_policy_record(&spec, &record);
        self.finish_trace(&tctx, &record, None);

        self.release_or_retire(container, function);

        Ok(InvokeOutcome { record, prediction })
    }

    /// Mint this invocation's trace context. With tracing off this is
    /// a single `bool` load (`begin` returns `None`) and the SLO read
    /// is skipped — the context stays inert for the whole request.
    fn begin_trace(
        &self,
        spec: &FunctionSpec,
        submitted_at: Option<Nanos>,
        arrived_at: Nanos,
    ) -> TraceCtx {
        let id = self.trace.begin();
        let slo_ms = if id.is_some() { self.policy.slo_target_ms(spec) } else { 0 };
        TraceCtx { id, submitted_at, arrived_at, slo_ms }
    }

    /// Land a successful invocation's trace. Called strictly AFTER
    /// `MetricsSink::record` and the policy feed have both returned:
    /// `trace.ring` is the last rank in `PLATFORM_LOCK_ORDER` and is
    /// only ever taken standalone.
    fn finish_trace(
        &self,
        ctx: &TraceCtx,
        record: &InvocationRecord,
        shared_exec_with: Option<String>,
    ) {
        if let Some(id) = &ctx.id {
            self.trace.finish(Trace::from_record(
                id,
                record,
                ctx.arrived_at,
                ctx.submitted_at,
                ctx.slo_ms,
                shared_exec_with,
            ));
        }
    }

    /// Land a refusal trace (queue full, deadline expired, provision
    /// or batch failure before any container work was attributable).
    fn trace_refusal(&self, ctx: &TraceCtx, function: &str, error: &str) {
        if let Some(id) = &ctx.id {
            let waited = Duration::from_nanos(self.clock.now() - ctx.arrived_at);
            self.trace.finish(Trace::refused(
                id,
                function,
                ctx.arrived_at,
                ctx.submitted_at,
                waited,
                error.to_string(),
            ));
        }
    }

    /// Land a failure trace for a request that did hold a container:
    /// the provision components are known and itemized even though the
    /// execution (or its billing) failed.
    fn trace_failure(
        &self,
        ctx: &TraceCtx,
        function: &str,
        start: StartKind,
        queue: Duration,
        pc: &ProvisionCost,
        error: &str,
    ) {
        if let Some(id) = &ctx.id {
            self.trace.finish(Trace::failed(
                id,
                function,
                start,
                ctx.arrived_at,
                ctx.submitted_at,
                queue,
                pc,
                error.to_string(),
            ));
        }
    }

    /// Stream one finished record into the policy controllers. Called
    /// strictly AFTER `MetricsSink::record` returns (its shard/totals
    /// locks are released by then): `policy.state` ranks below the
    /// metrics locks in `PLATFORM_LOCK_ORDER` and is only ever taken
    /// standalone.
    fn note_policy_record(&self, spec: &FunctionSpec, record: &InvocationRecord) {
        if self.policy.enabled_for(spec) {
            self.policy.on_record(record, self.clock.now());
        }
    }

    /// Release a served container to the warm pool for reuse — unless
    /// the function was undeployed or reconfigured mid-flight: a
    /// container whose baked-in model/memory/variant no longer matches
    /// the current spec must not serve again (and must not hold a
    /// capacity slot). Compared by content, not Arc identity, so cap-
    /// or policy-only patches don't churn containers.
    fn release_or_retire(&self, container: Container, function: &str) {
        let reusable = match self.registry.get(function) {
            Ok(current) => {
                current.model == container.spec.model
                    && current.variant == container.spec.variant
                    && current.memory_mb == container.spec.memory_mb
            }
            Err(_) => false,
        };
        if reusable {
            self.pool.release(container);
        } else {
            self.pool.retire(container);
        }
    }

    /// Serve one request as the leader of a micro-batch: wake parked
    /// capacity waiters (they may prefer joining over waiting), hold
    /// the batch open for the window, flush, run ONE batched pass for
    /// every member, fan the results out, then meter the leader's own
    /// share. The leader alone pays the cold-start handler time (its
    /// container, its provision); every member — leader included — is
    /// billed `effective / batch_size` for the pass itself.
    fn execute_batch_leader(
        &self,
        function: &str,
        spec: &Arc<FunctionSpec>,
        mut container: Container,
        start: StartKind,
        queue_wait: Duration,
        mut leader: super::batcher::BatchLeader<'_>,
        tctx: &TraceCtx,
    ) -> Result<InvokeOutcome, InvokeError> {
        // Stamp the leader's trace id on the batch before any follower
        // can observe a completed share: followers annotate their
        // timelines with the id of the execution span they rode.
        if let Some(id) = &tctx.id {
            leader.set_trace(id);
        }
        // Targeted wake: the batch this leader just opened is joinable
        // by THIS function's parked requests only, so only its shard's
        // waiters need to re-probe for the join door.
        self.pool.notify_function(function);
        // Flush early when requests are parked for capacity and have
        // not boarded the batch: anyone who can join does so within a
        // probe slice of the notify above (dropping its queue ticket);
        // persistent queue depth means demand this held container is
        // starving, which outweighs a fuller batch.
        leader.wait_window(|| self.dispatcher.queue_depth(function) > 0);
        let seeds = leader.close();
        // Adaptive rung selection: cap the engine's batch-kernel
        // ladder at what recent flush sizes actually fill, so shards
        // stop compiling rungs no flush reaches. Off (or warming up),
        // the cap is the identity and the flush is bit-for-bit the
        // static pipeline's.
        let rung_cap = if self.policy.enabled_for(spec) {
            self.policy.rung_target(spec, self.config.batch_kernel_max, self.clock.now())
        } else {
            usize::MAX
        };
        let executed =
            container.execute_batch_capped(&self.governor, &self.clock, &seeds, rung_cap);
        let (predictions, effective, kernels) = match executed {
            Ok(v) => v,
            Err(e) => {
                // Fail the whole batch: followers surface the error,
                // and the broken container is not returned to the
                // pool (same as the solo path).
                leader.fail(format!("{e:#}"));
                let pc = container.provision_cost.attributed_to(start);
                self.trace_failure(tctx, function, start, queue_wait, &pc, &format!("{e:#}"));
                self.pool.retire(container);
                return Err(InvokeError::Failed(e));
            }
        };
        let share = leader.complete(predictions, effective, kernels.kernel_batch_n);

        // Same cold accounting as the solo path: the leader (whose
        // container this is) alone pays the handler-side provision
        // time on top of its billed split.
        let pc = container.provision_cost.attributed_to(start);
        let billed = pc.handler_time() + share.billed_share;
        let line = match self.billing.charge(function, spec.memory_mb, billed) {
            Ok(line) => line,
            Err(e) => {
                // Followers already hold their shares and bill
                // themselves; only the leader's charge failed, so only
                // its container slot is returned.
                self.trace_failure(tctx, function, start, queue_wait, &pc, &format!("{e:#}"));
                self.pool.retire(container);
                return Err(InvokeError::Failed(e));
            }
        };
        let record = InvocationRecord {
            function: function.to_string(),
            memory_mb: spec.memory_mb,
            start,
            queue: queue_wait,
            sandbox: pc.sandbox,
            runtime_init: pc.runtime_init,
            package_fetch: pc.package_fetch,
            model_load: pc.model_load,
            restore: pc.restore,
            predict: share.effective,
            predict_full_speed: share.prediction.compute,
            batch_size: share.batch_size,
            batch_wait: share.batch_wait,
            kernel_batch_n: share.kernel_batch_n,
            // One owner for the pass-level cache deltas: the leader ran
            // the flush, so its record alone carries the hit/miss counts
            // (followers would double-count them).
            batch_kernel_hits: kernels.batch_kernel_hits,
            batch_kernel_misses: kernels.batch_kernel_misses,
            billed,
            billed_ms: line.billed_ms,
            cost_dollars: line.total_dollars(),
            top1: share.prediction.top1,
            trace_id: tctx.id.clone(),
        };
        self.metrics.record(record.clone());
        self.note_policy_record(spec, &record);
        self.finish_trace(tctx, &record, None);
        self.release_or_retire(container, function);
        Ok(InvokeOutcome { record, prediction: share.prediction })
    }

    /// Finish a request that joined someone else's batch: park until
    /// the leader distributes results, then meter this member's own
    /// billed split. A follower never held a container, so its start
    /// kind is Warm and it pays no cold components; its response is
    /// its own admission wait + the batch wait + the full batched
    /// pass.
    fn finish_batch_member(
        &self,
        function: &str,
        spec: &Arc<FunctionSpec>,
        member: BatchMember,
        queue_wait: Duration,
        tctx: &TraceCtx,
    ) -> Result<InvokeOutcome, InvokeError> {
        let share = match member.wait() {
            Ok(share) => share,
            Err(msg) => {
                self.trace_refusal(tctx, function, &format!("batched execution failed: {msg}"));
                return Err(InvokeError::Failed(anyhow!("batched execution failed: {msg}")));
            }
        };
        let line = match self.billing.charge(function, spec.memory_mb, share.billed_share) {
            Ok(line) => line,
            Err(e) => {
                self.trace_refusal(tctx, function, &format!("{e:#}"));
                return Err(InvokeError::Failed(e));
            }
        };
        let record = InvocationRecord {
            function: function.to_string(),
            memory_mb: spec.memory_mb,
            start: StartKind::Warm,
            queue: queue_wait,
            sandbox: Duration::ZERO,
            runtime_init: Duration::ZERO,
            package_fetch: Duration::ZERO,
            model_load: Duration::ZERO,
            restore: Duration::ZERO,
            predict: share.effective,
            predict_full_speed: share.prediction.compute,
            batch_size: share.batch_size,
            batch_wait: share.batch_wait,
            kernel_batch_n: share.kernel_batch_n,
            batch_kernel_hits: 0,
            batch_kernel_misses: 0,
            billed: share.billed_share,
            billed_ms: line.billed_ms,
            cost_dollars: line.total_dollars(),
            top1: share.prediction.top1,
            trace_id: tctx.id.clone(),
        };
        self.metrics.record(record.clone());
        self.note_policy_record(spec, &record);
        // A follower never ran the pass itself: its timeline points at
        // the leader's execution span.
        self.finish_trace(tctx, &record, share.leader_trace.clone());
        Ok(InvokeOutcome { record, prediction: share.prediction })
    }

    /// Serve a pre-formed batch: the seeds arrive already grouped (an
    /// async worker drained consecutive same-function jobs from its
    /// queue), so the collection window is skipped entirely — one
    /// admission wait, one container, ONE batched pass, one record and
    /// one result per seed (in input order). The first admitted seed
    /// plays the leader role from the interactive path: its record
    /// carries the provision components and the pass's kernel-cache
    /// deltas; every member is billed the even `effective / n` split
    /// with `batch_wait = 0` (no window was held open).
    ///
    /// Admission is per seed for the concurrency cap — a pre-formed
    /// batch must not dodge `max_concurrency`, so seeds over the cap
    /// are refused with 429 while the rest proceed — and per batch for
    /// capacity: one container (or cold provision) serves the whole
    /// run, acquired through the same bounded queue wait as a solo
    /// request.
    pub fn invoke_preformed(
        &self,
        function: &str,
        seeds: &[u64],
    ) -> Vec<Result<InvokeOutcome, InvokeError>> {
        self.invoke_preformed_from(function, seeds, None)
    }

    /// [`Self::invoke_preformed`] with explicit origins: `origins[i]`
    /// is seed `i`'s async submit time, so each member's trace carries
    /// its own pre-platform `admission` wait (the group shares one
    /// queue wait, but its members may have queued at different
    /// times).
    pub fn invoke_preformed_from(
        &self,
        function: &str,
        seeds: &[u64],
        origins: Option<&[Nanos]>,
    ) -> Vec<Result<InvokeOutcome, InvokeError>> {
        let spec = match self.registry.get(function) {
            Ok(spec) => spec,
            Err(_) => {
                return seeds
                    .iter()
                    .map(|_| Err(InvokeError::NotFound(function.to_string())))
                    .collect();
            }
        };
        let mut results: Vec<Option<Result<InvokeOutcome, InvokeError>>> =
            seeds.iter().map(|_| None).collect();
        let mut guards = Vec::new();
        let mut admitted: Vec<(usize, u64)> = Vec::new();
        for (i, &seed) in seeds.iter().enumerate() {
            match FnFlightGuard::acquire(
                &self.fn_in_flight,
                &self.pool,
                function,
                spec.max_concurrency,
            ) {
                Some(g) => {
                    guards.push(g);
                    admitted.push((i, seed));
                }
                None => {
                    self.scaler.note_throttled();
                    self.metrics.note_throttled(function);
                    results[i] = Some(Err(InvokeError::Throttled));
                }
            }
        }
        let resolve = |results: Vec<Option<Result<InvokeOutcome, InvokeError>>>| {
            results.into_iter().map(|r| r.expect("every seed resolved")).collect()
        };
        if admitted.is_empty() {
            return resolve(results);
        }

        // The same admission machinery as the solo path, minus the
        // batch-join doors: this request group IS the batch already.
        let t_queue_start = self.clock.now();
        // One trace per admitted member (member 0 owns the execution
        // span; the rest share it) — all inert `None`s when tracing is
        // off.
        let tctxs: Vec<TraceCtx> = admitted
            .iter()
            .map(|&(i, _)| {
                let submitted = origins.and_then(|o| o.get(i)).copied();
                self.begin_trace(&spec, submitted, t_queue_start)
            })
            .collect();
        let trace_all_refused = |err: &str| {
            for ctx in &tctxs {
                self.trace_refusal(ctx, function, err);
            }
        };
        let outcome = match self.pool.acquire(function) {
            Some(c) => AcquireOutcome::Container(c),
            None => match self.dispatcher.admit(&spec) {
                Some(ticket) => {
                    let deadline = t_queue_start + ticket.deadline.as_nanos() as u64;
                    let o = self.pool.acquire_or_reserve(function, deadline);
                    drop(ticket);
                    if matches!(o, AcquireOutcome::TimedOut) {
                        self.dispatcher.note_expired();
                        self.scaler.note_saturated();
                        self.metrics.note_queue_expired(function);
                        trace_all_refused(
                            "saturated: no capacity freed within the dispatch deadline",
                        );
                        for &(i, _) in &admitted {
                            results[i] = Some(Err(InvokeError::Saturated(
                                SaturationKind::DeadlineExpired,
                            )));
                        }
                        return resolve(results);
                    }
                    o
                }
                None => {
                    // Queue at its bound, or queueing disabled — the
                    // solo path's immediate-probe contract applies.
                    let o = if self.dispatcher.effective_capacity(&spec) == 0 {
                        self.pool.acquire_or_reserve(function, self.clock.now())
                    } else {
                        AcquireOutcome::TimedOut
                    };
                    if matches!(o, AcquireOutcome::TimedOut) {
                        self.scaler.note_saturated();
                        self.metrics.note_queue_expired(function);
                        trace_all_refused("saturated: dispatch queue full");
                        for &(i, _) in &admitted {
                            results[i] =
                                Some(Err(InvokeError::Saturated(SaturationKind::QueueFull)));
                        }
                        return resolve(results);
                    }
                    o
                }
            },
        };
        let queue_wait = Duration::from_nanos(self.clock.now() - t_queue_start);
        let (mut container, start, _flight) = match outcome {
            AcquireOutcome::Container(c) => (c, StartKind::Warm, self.scaler.arrive()),
            AcquireOutcome::Reserved => {
                let flight = self.scaler.arrive();
                let provisioned = self.scaler.provision_demand(
                    &spec,
                    &self.pool,
                    &self.engine,
                    &self.governor,
                    &self.config.bootstrap,
                    &self.snapshots,
                    &self.clock,
                    &self.rng,
                );
                match provisioned {
                    Ok(c) => {
                        let start = c.start_kind_for_first_use();
                        (c, start, flight)
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        trace_all_refused(&format!("provision failed: {msg}"));
                        for &(i, _) in &admitted {
                            results[i] = Some(Err(InvokeError::Failed(anyhow!("{msg}"))));
                        }
                        return resolve(results);
                    }
                }
            }
            AcquireOutcome::TimedOut | AcquireOutcome::Interrupted => {
                unreachable!("refusals returned above; pre-formed waits take no interrupts")
            }
        };

        let batch: Vec<u64> = admitted.iter().map(|&(_, s)| s).collect();
        // Same adaptive rung cap as the interactive leader path: a
        // pre-formed batch is a flush like any other.
        let rung_cap = if self.policy.enabled_for(&spec) {
            self.policy.rung_target(&spec, self.config.batch_kernel_max, self.clock.now())
        } else {
            usize::MAX
        };
        let executed =
            container.execute_batch_capped(&self.governor, &self.clock, &batch, rung_cap);
        let (predictions, effective, kernels) = match executed {
            Ok(v) => v,
            Err(e) => {
                self.pool.retire(container);
                let msg = format!("{e:#}");
                trace_all_refused(&format!("batched execution failed: {msg}"));
                for &(i, _) in &admitted {
                    results[i] = Some(Err(InvokeError::Failed(anyhow!(
                        "batched execution failed: {msg}"
                    ))));
                }
                return resolve(results);
            }
        };
        let n = batch.len();
        let billed_share = effective / n as u32;
        let pc = container.provision_cost.attributed_to(start);
        let mut retire = false;
        for (member, (&(slot, _seed), prediction)) in
            admitted.iter().zip(predictions).enumerate()
        {
            let leader = member == 0;
            let tctx = &tctxs[member];
            let billed =
                if leader { pc.handler_time() + billed_share } else { billed_share };
            let line = match self.billing.charge(function, spec.memory_mb, billed) {
                Ok(line) => line,
                Err(e) => {
                    if leader {
                        // Unbillable leader: same as the solo path —
                        // the container's capacity slot is returned.
                        retire = true;
                    }
                    self.trace_refusal(tctx, function, &format!("{e:#}"));
                    results[slot] = Some(Err(InvokeError::Failed(e)));
                    continue;
                }
            };
            let record = InvocationRecord {
                function: function.to_string(),
                memory_mb: spec.memory_mb,
                start: if leader { start } else { StartKind::Warm },
                queue: queue_wait,
                sandbox: if leader { pc.sandbox } else { Duration::ZERO },
                runtime_init: if leader { pc.runtime_init } else { Duration::ZERO },
                package_fetch: if leader { pc.package_fetch } else { Duration::ZERO },
                model_load: if leader { pc.model_load } else { Duration::ZERO },
                restore: if leader { pc.restore } else { Duration::ZERO },
                predict: effective,
                predict_full_speed: prediction.compute,
                batch_size: n,
                batch_wait: Duration::ZERO,
                kernel_batch_n: kernels.kernel_batch_n,
                batch_kernel_hits: if leader { kernels.batch_kernel_hits } else { 0 },
                batch_kernel_misses: if leader { kernels.batch_kernel_misses } else { 0 },
                billed,
                billed_ms: line.billed_ms,
                cost_dollars: line.total_dollars(),
                top1: prediction.top1,
                trace_id: tctx.id.clone(),
            };
            self.metrics.record(record.clone());
            self.note_policy_record(&spec, &record);
            // Member 0 played the leader: its trace owns the shared
            // execution span, every other member points at it.
            let shared = if leader { None } else { tctxs[0].id.clone() };
            self.finish_trace(tctx, &record, shared);
            results[slot] = Some(Ok(InvokeOutcome { record, prediction }));
        }
        if retire {
            self.pool.retire(container);
        } else {
            self.release_or_retire(container, function);
        }
        resolve(results)
    }

    /// Force-evict every idle container (tests / forced cold).
    pub fn evict_all(&self) -> usize {
        self.pool.evict_all()
    }

    /// Run one keep-alive sweep.
    pub fn sweep(&self) -> usize {
        self.pool.evict_expired()
    }

    /// One maintenance tick: keep-alive eviction sweep, then top up
    /// every deployed function to its `min_warm` target through the
    /// prewarm path (best-effort: the container cap bounds the
    /// top-up). This is what the background [`PoolMaintainer`] runs;
    /// time-virtualized tests call it directly after advancing a
    /// `ManualClock`.
    pub fn maintain(&self) -> MaintenanceReport {
        let evicted = self.pool.evict_expired();
        let mut replenished = 0;
        for spec in self.registry.list() {
            let mut target = spec.min_warm;
            // Predictive pre-provisioning: with the controllers on,
            // the Holt forecast can raise (never lower) the top-up
            // target ahead of a burst. A shape with a checkpoint on
            // hand claims half as many warm containers — a restore
            // absorbs overflow at a fraction of the cold cost, so
            // keep-warm capacity is split with snapshot-restore.
            if self.policy.enabled_for(&spec) {
                let mut forecast = self.policy.warm_target(&spec, self.clock.now());
                if forecast > 0
                    && self.snapshots.enabled_for(&spec)
                    && self.snapshots.capture_cost(&SnapshotKey::of(&spec)).is_some()
                {
                    forecast = forecast.div_ceil(2);
                }
                target = target.max(forecast);
            }
            replenished += self.prewarm_up_to(&spec, target);
        }
        MaintenanceReport { evicted, replenished }
    }

    /// Start the background pool maintainer, ticking every `interval`.
    /// Returns `false` (and does nothing) when `interval` is zero or a
    /// maintainer is already running. An associated function because
    /// the thread needs a `Weak` handle to the platform `Arc`.
    pub fn start_maintainer(platform: &Arc<Platform>, interval: Duration) -> bool {
        if interval.is_zero() {
            return false;
        }
        let mut slot = plock(&platform.maintainer);
        if slot.is_some() {
            return false;
        }
        *slot = Some(PoolMaintainer::start(platform, interval));
        true
    }

    /// Stop and join the background maintainer, if running.
    pub fn stop_maintainer(&self) {
        let taken = plock(&self.maintainer).take();
        drop(taken); // joins on drop
    }

    /// Ticks completed by the running maintainer (0 when stopped).
    pub fn maintainer_ticks(&self) -> u64 {
        plock(&self.maintainer).as_ref().map_or(0, PoolMaintainer::ticks)
    }

    /// Containers replenished by the running maintainer (0 when
    /// stopped).
    pub fn maintainer_replenished(&self) -> usize {
        plock(&self.maintainer).as_ref().map_or(0, PoolMaintainer::replenished_total)
    }
}

impl Drop for Invoker {
    fn drop(&mut self) {
        // Join the maintainer thread before the platform's parts go
        // away (its Weak upgrade fails from here on anyway).
        self.stop_maintainer();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockEngine;
    use crate::util::ManualClock;

    fn platform() -> (Arc<Invoker>, Arc<ManualClock>, Arc<MockEngine>) {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig::default();
        let p = Arc::new(Invoker::new(cfg, engine.clone(), clock.clone()));
        (p, clock, engine)
    }

    #[test]
    fn first_invoke_cold_second_warm() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        let a = p.invoke("sq", 1).unwrap();
        assert_eq!(a.record.start, StartKind::Cold);
        assert!(a.record.cold_overhead() > Duration::ZERO);
        let b = p.invoke("sq", 2).unwrap();
        assert_eq!(b.record.start, StartKind::Warm);
        assert_eq!(b.record.cold_overhead(), Duration::ZERO);
        assert!(b.record.response() < a.record.response());
        assert_eq!(p.metrics.len(), 2);
        assert_eq!(p.scaler.cold_provision_count(), 1);
    }

    #[test]
    fn unknown_function_is_not_found() {
        let (p, _, _) = platform();
        assert!(matches!(p.invoke("nope", 0), Err(InvokeError::NotFound(_))));
    }

    #[test]
    fn keep_alive_expiry_forces_cold() {
        let (p, clock, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        // The paper's cold methodology: 10-minute gaps between requests.
        clock.sleep(Duration::from_secs(601));
        let r = p.invoke("sq", 2).unwrap();
        assert_eq!(r.record.start, StartKind::Cold);
        assert_eq!(p.scaler.cold_provision_count(), 2);
    }

    #[test]
    fn within_keep_alive_stays_warm() {
        let (p, clock, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        clock.sleep(Duration::from_secs(250));
        let r = p.invoke("sq", 2).unwrap();
        assert_eq!(r.record.start, StartKind::Warm);
    }

    #[test]
    fn memory_scales_prediction_time() {
        let (p, _, _) = platform();
        p.deploy("small", "squeezenet", "pallas", 128).unwrap();
        p.deploy("big", "squeezenet", "pallas", 1536).unwrap();
        // Warm both.
        p.invoke("small", 1).unwrap();
        p.invoke("big", 1).unwrap();
        let small = p.invoke("small", 2).unwrap().record;
        let big = p.invoke("big", 2).unwrap().record;
        // share(128)=128/1792, share(1536)=1536/1792 -> 12x ratio.
        let ratio = small.predict.as_secs_f64() / big.predict.as_secs_f64();
        assert!((ratio - 12.0).abs() < 0.8, "ratio={ratio}");
    }

    #[test]
    fn cold_billed_more_than_warm() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        let cold = p.invoke("sq", 1).unwrap().record;
        let warm = p.invoke("sq", 2).unwrap().record;
        assert!(cold.billed > warm.billed);
        assert!(cold.cost_dollars > warm.cost_dollars);
        // Sandbox time is NOT billed (platform-side).
        assert!(cold.billed < cold.response());
    }

    /// A capacity miss is no longer an instant 429: the request parks
    /// in the dispatcher queue; with nothing freeing capacity it
    /// exhausts its (virtual) deadline and surfaces a 503-mapped
    /// `Saturated` error, with the expiry counted in the dispatcher,
    /// the scaler, and the function's metrics shard.
    #[test]
    fn capacity_miss_parks_then_expires_as_saturated() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig { max_containers: 1, ..Default::default() };
        let p = Invoker::new(cfg, engine, clock.clone());
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        // The one container is busy (held); a second request parks,
        // self-drives virtual time to its deadline, and gets 503.
        let held = p.pool.acquire("sq").unwrap();
        let t0 = clock.now();
        let err = p.invoke("sq", 2).unwrap_err();
        assert!(matches!(err, InvokeError::Saturated(SaturationKind::DeadlineExpired)), "{err}");
        assert!(
            clock.now() - t0 >= 2_000_000_000,
            "waited the full default queue_deadline_ms in virtual time"
        );
        assert_eq!(p.scaler.saturated_count(), 1);
        assert_eq!(p.scaler.throttled_count(), 0, "capacity misses are not 429s anymore");
        assert_eq!(p.dispatcher.expired_total(), 1);
        assert_eq!(p.dispatcher.total_depth(), 0, "refused request left the queue");
        assert_eq!(p.metrics.function_metrics("sq").queue_expired, 1);
        p.pool.release(held);
        assert!(p.invoke("sq", 3).is_ok(), "released container serves again");
    }

    /// The queue absorbs a transient capacity miss: a parked request
    /// completes (zero 429s/503s) once the busy container releases.
    #[test]
    fn parked_request_completes_when_capacity_frees() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig { max_containers: 1, ..Default::default() };
        let p = Arc::new(Invoker::new(cfg, engine, clock.clone()));
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        let held = p.pool.acquire("sq").unwrap();
        let p2 = p.clone();
        let waiter = std::thread::spawn(move || p2.invoke("sq", 2));
        // Let the request park, then free the container.
        std::thread::sleep(Duration::from_millis(20));
        p.pool.release(held);
        let out = waiter.join().unwrap().expect("parked request served after release");
        assert_eq!(out.record.start, StartKind::Warm);
        assert_eq!(p.scaler.saturated_count(), 0);
        assert_eq!(p.scaler.throttled_count(), 0);
        // Every served request streams its queue wait (possibly zero).
        assert_eq!(p.metrics.function_metrics("sq").queue_wait.count(), 2);
    }

    /// `queue_capacity = 0` disables *parking*, not serving: a warm
    /// miss with free capacity still cold-provisions on the spot;
    /// only a genuine capacity shortage is refused — immediately,
    /// with 503 `queue_full`.
    #[test]
    fn queueing_disabled_still_serves_when_capacity_free() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg =
            PlatformConfig { queue_capacity: 0, max_containers: 1, ..Default::default() };
        let p = Invoker::new(cfg, engine, clock.clone());
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        // Idle platform, free slot: not a queue refusal.
        let r = p.invoke("sq", 1).unwrap();
        assert_eq!(r.record.start, StartKind::Cold);
        // At cap with the container held busy: immediate 503.
        let held = p.pool.acquire("sq").unwrap();
        let t0 = clock.now();
        let err = p.invoke("sq", 2).unwrap_err();
        assert!(matches!(err, InvokeError::Saturated(SaturationKind::QueueFull)), "{err}");
        assert_eq!(clock.now(), t0, "refusal is immediate — no (virtual) parking");
        assert_eq!(p.dispatcher.expired_total(), 0, "a refusal is not a deadline expiry");
        p.pool.release(held);
        assert!(p.invoke("sq", 3).is_ok());
    }

    /// Satellite regression: a billing failure after a successful
    /// execute must retire the container — the old `?` propagation
    /// dropped it without `pool.retire()`, permanently leaking a
    /// capacity slot (`total` never decremented) per occurrence.
    #[test]
    fn billing_failure_retires_container_and_frees_capacity() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let mut cfg = PlatformConfig { max_containers: 2, ..Default::default() };
        // Pricing table without the function's 512 MB tier: `charge`
        // fails after the execute succeeds.
        cfg.pricing.table = vec![(128, 1e-6), (256, 2e-6)];
        let p = Invoker::new(cfg, engine.clone(), clock);
        p.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        for i in 0..3 {
            let err = p.invoke("sq", i).unwrap_err();
            assert!(matches!(err, InvokeError::Failed(_)), "attempt {i}: {err}");
        }
        assert_eq!(p.pool.total_alive(), 0, "capacity slots all returned");
        assert_eq!(engine.live_instances(), 0, "engine instances reaped");
        assert_eq!(p.metrics.len(), 0, "unbillable invocations are not recorded");
    }

    #[test]
    fn failed_create_does_not_leak_capacity() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig { max_containers: 2, ..Default::default() };
        let p = Invoker::new(cfg, engine.clone(), clock.clone());
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        engine.fail_create.store(true, std::sync::atomic::Ordering::SeqCst);
        for _ in 0..5 {
            assert!(matches!(p.invoke("sq", 0), Err(InvokeError::Failed(_))));
        }
        engine.fail_create.store(false, std::sync::atomic::Ordering::SeqCst);
        // All reservations were cancelled; both slots still usable.
        assert!(p.invoke("sq", 1).is_ok());
        assert_eq!(p.pool.total_alive(), 1);
    }

    #[test]
    fn concurrent_invokes_spawn_containers() {
        let engine = Arc::new(MockEngine::paper_zoo());
        // Real clock so threads genuinely overlap.
        let cfg = PlatformConfig { max_containers: 64, ..Default::default() };
        let p = Arc::new(Invoker::live(cfg, engine));
        p.deploy("sq", "squeezenet", "pallas", 1536).unwrap();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let p = p.clone();
                std::thread::spawn(move || p.invoke("sq", i).unwrap().record.start)
            })
            .collect();
        let starts: Vec<StartKind> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // All 8 overlapped (mock predict reports >= 100 ms and the live
        // clock sleeps it), so all were cold provisions.
        assert_eq!(starts.iter().filter(|s| **s == StartKind::Cold).count(), 8);
        assert!(p.scaler.high_water_mark() >= 2);
        assert_eq!(p.pool.total_alive(), 8);
        // And they are all reusable now.
        let r = p.invoke("sq", 99).unwrap();
        assert_eq!(r.record.start, StartKind::Warm);
    }

    #[test]
    fn undeploy_removes_function_and_reaps_warm_pool() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        assert_eq!(p.pool.warm_count("sq"), 1);
        let reaped = p.undeploy("sq").unwrap();
        assert_eq!(reaped, 1);
        assert_eq!(p.pool.total_alive(), 0);
        // The metrics shard is released with the deployment (platform
        // totals keep the history).
        assert_eq!(p.metrics.function_metrics("sq").invocations, 0);
        assert_eq!(p.metrics.len(), 1);
        assert!(matches!(p.invoke("sq", 2), Err(InvokeError::NotFound(_))));
        assert!(p.undeploy("sq").is_err(), "double undeploy is an error");
    }

    #[test]
    fn deploy_full_prewarms_min_warm() {
        let (p, _, _) = platform();
        p.deploy_full(
            "sq",
            "squeezenet",
            "pallas",
            1024,
            FunctionPolicy { min_warm: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(p.pool.warm_count("sq"), 2);
        // First invocation finds a warm container immediately.
        let r = p.invoke("sq", 1).unwrap();
        assert_eq!(r.record.start, StartKind::Warm);
    }

    #[test]
    fn reconfigure_updates_spec_and_cycles_containers() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        p.invoke("sq", 1).unwrap();
        assert_eq!(p.pool.warm_count("sq"), 1);
        let patch = ReconfigurePatch { memory_mb: Some(1536), ..Default::default() };
        let spec = p.reconfigure("sq", &patch).unwrap();
        assert_eq!(spec.memory_mb, 1536);
        // Old 512 MB containers were evicted: next start is cold.
        assert_eq!(p.pool.warm_count("sq"), 0);
        let r = p.invoke("sq", 2).unwrap();
        assert_eq!(r.record.start, StartKind::Cold);
        assert_eq!(r.record.memory_mb, 1536);
        // Unknown function and invalid patch both error.
        assert!(p.reconfigure("nope", &Default::default()).is_err());
        let bad = ReconfigurePatch { memory_mb: Some(100), ..Default::default() };
        assert!(p.reconfigure("sq", &bad).is_err());
        assert_eq!(p.registry.get("sq").unwrap().memory_mb, 1536, "failed patch keeps spec");
    }

    #[test]
    fn cap_only_reconfigure_keeps_warm_pool() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        assert_eq!(p.pool.warm_count("sq"), 1);
        // Changing only the concurrency cap must not destroy warm
        // containers — they embody memory/variant, not the cap.
        let patch =
            ReconfigurePatch { max_concurrency: Some(Some(4)), ..Default::default() };
        let spec = p.reconfigure("sq", &patch).unwrap();
        assert_eq!(spec.max_concurrency, Some(4));
        assert_eq!(p.pool.warm_count("sq"), 1, "warm pool survives cap-only patch");
        let r = p.invoke("sq", 2).unwrap();
        assert_eq!(r.record.start, StartKind::Warm);
        // And the container is re-pooled after serving (content match,
        // not Arc identity).
        assert_eq!(p.pool.warm_count("sq"), 1);
    }

    #[test]
    fn container_in_flight_during_reconfigure_is_retired_not_pooled() {
        use crate::runtime::MockModelCosts;
        // Live clock so the in-flight invocation genuinely overlaps
        // the reconfigure (mock predict sleeps real time).
        let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
            "squeezenet",
            300,
            5.0,
            85,
        )]));
        let cfg = PlatformConfig {
            bootstrap: crate::configparse::BootstrapConfig {
                simulate_delays: false,
                ..Default::default()
            },
            ..Default::default()
        };
        let p = Arc::new(Invoker::live(cfg, engine));
        p.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        let p2 = p.clone();
        let t = std::thread::spawn(move || p2.invoke("sq", 1).unwrap());
        // Let the invocation start executing, then change the spec.
        std::thread::sleep(Duration::from_millis(80));
        p.reconfigure("sq", &ReconfigurePatch { memory_mb: Some(1536), ..Default::default() })
            .unwrap();
        let out = t.join().unwrap();
        assert_eq!(out.record.memory_mb, 512, "in-flight run billed at old spec");
        // The old-spec container must not have been parked for reuse.
        assert_eq!(p.pool.warm_count("sq"), 0);
        assert_eq!(p.pool.total_alive(), 0);
        let r = p.invoke("sq", 2).unwrap();
        assert_eq!(r.record.start, StartKind::Cold);
        assert_eq!(r.record.memory_mb, 1536);
    }

    #[test]
    fn container_in_flight_during_undeploy_is_retired() {
        use crate::runtime::MockModelCosts;
        let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
            "squeezenet",
            300,
            5.0,
            85,
        )]));
        let cfg = PlatformConfig {
            bootstrap: crate::configparse::BootstrapConfig {
                simulate_delays: false,
                ..Default::default()
            },
            ..Default::default()
        };
        let p = Arc::new(Invoker::live(cfg, engine));
        p.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        let p2 = p.clone();
        let t = std::thread::spawn(move || p2.invoke("sq", 1).unwrap());
        std::thread::sleep(Duration::from_millis(80));
        p.undeploy("sq").unwrap();
        t.join().unwrap();
        // No orphaned container may keep holding a capacity slot.
        assert_eq!(p.pool.total_alive(), 0);
        assert_eq!(p.pool.warm_count("sq"), 0);
    }

    #[test]
    fn per_function_concurrency_cap_throttles() {
        let (p, _, _) = platform();
        p.deploy_full(
            "sq",
            "squeezenet",
            "pallas",
            1024,
            FunctionPolicy { max_concurrency: Some(1), ..Default::default() },
        )
        .unwrap();
        // Saturate the single slot by holding the counter via a warm
        // container acquired mid-flight: simulate by taking the guard
        // path directly — first invoke succeeds (counter returns to 0).
        assert!(p.invoke("sq", 1).is_ok());
        // Hold one in-flight slot manually.
        let guard = FnFlightGuard::acquire(&p.fn_in_flight, &p.pool, "sq", Some(1)).unwrap();
        let err = p.invoke("sq", 2).unwrap_err();
        assert!(matches!(err, InvokeError::Throttled));
        assert_eq!(p.scaler.throttled_count(), 1);
        drop(guard);
        assert!(p.invoke("sq", 3).is_ok(), "slot freed after guard drop");
        // Other functions are unaffected by this function's cap.
        p.deploy("other", "squeezenet", "pallas", 1024).unwrap();
        assert!(p.invoke("other", 1).is_ok());
    }

    /// Batching off (`max_batch_size = 1`, the default): a lone
    /// request never touches the batcher — zero added latency, no
    /// batch telemetry, the PR 3 pipeline bit-for-bit.
    #[test]
    fn batching_off_lone_request_pays_zero_batch_latency() {
        let (p, clock, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap(); // warm the container
        let t0 = clock.now();
        let r = p.invoke("sq", 2).unwrap().record;
        assert_eq!(r.batch_size, 1);
        assert_eq!(r.batch_wait, Duration::ZERO);
        assert_eq!(r.queue, Duration::ZERO);
        assert_eq!(r.response(), r.predict, "warm solo response is exactly the predict time");
        assert_eq!(clock.now() - t0, r.predict.as_nanos() as u64, "no hidden clock time");
        assert_eq!(p.batcher.batches_executed(), 0);
        let m = p.metrics.function_metrics("sq");
        assert_eq!(m.batched_requests, 0);
        assert_eq!(m.batch_size.count(), 0);
    }

    /// `batch_window_ms = 0` with batching on: a lone request leads a
    /// batch that flushes immediately — still zero added latency.
    #[test]
    fn zero_window_lone_request_flushes_immediately() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig { max_batch_size: 8, batch_window_ms: 0, ..Default::default() };
        let p = Invoker::new(cfg, engine, clock.clone());
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap(); // warm
        let t0 = clock.now();
        let r = p.invoke("sq", 2).unwrap().record;
        assert_eq!(r.batch_size, 1);
        assert_eq!(r.batch_wait, Duration::ZERO, "zero window adds zero wait");
        assert_eq!(clock.now() - t0, r.predict.as_nanos() as u64);
        // Both invocations rode the batch path (size-1 flushes).
        assert_eq!(p.batcher.batches_executed(), 2);
    }

    /// ManualClock window flush: a lone leader's window expires on
    /// VIRTUAL time (self-advanced, no outside driver) and the wait is
    /// visible in the record and the metrics shard.
    #[test]
    fn batch_window_flushes_at_virtual_deadline() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig { max_batch_size: 8, batch_window_ms: 50, ..Default::default() };
        let p = Invoker::new(cfg, engine, clock.clone());
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap(); // warm
        let wall0 = std::time::Instant::now();
        let t0 = clock.now();
        let r = p.invoke("sq", 2).unwrap().record;
        assert!(wall0.elapsed() < Duration::from_secs(5), "virtual wait, not wall wait");
        assert_eq!(r.batch_size, 1, "nobody joined");
        assert!(r.batch_wait >= Duration::from_millis(50), "paid the full window");
        assert_eq!(r.response(), r.batch_wait + r.predict);
        assert_eq!(clock.now() - t0, r.response().as_nanos() as u64);
        let m = p.metrics.function_metrics("sq");
        // Both the warming invoke and the measured one were lone
        // leaders that paid (and recorded) the window.
        assert_eq!(m.batch_wait.count(), 2, "lone-leader waits are recorded");
        assert!(m.batch_wait.p99() >= 49_000_000);
    }

    /// The core batching contract on real threads: concurrent requests
    /// coalesce into ONE engine forward pass, everyone gets its own
    /// correct prediction, and the billed duration splits evenly
    /// across the members (sublinear total).
    #[test]
    fn concurrent_burst_coalesces_with_billed_split() {
        const MEMBERS: u64 = 3;
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig {
            max_batch_size: MEMBERS as usize,
            // Virtual milliseconds: a lone leader self-advances this in
            // about a second of wall time worst case, and the early
            // flush at MEMBERS normally ends the wait far sooner — the
            // size only buys slack for slow CI runners. The admission
            // deadline must exceed the window, or followers would
            // (correctly) refuse to board a batch that flushes past
            // their 503 horizon.
            batch_window_ms: 30_000,
            queue_deadline_ms: 60_000,
            ..Default::default()
        };
        let p = Arc::new(Invoker::new(cfg, engine.clone(), clock.clone()));
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 0).unwrap(); // warm one container
        let calls_before = engine.predict_calls.load(std::sync::atomic::Ordering::SeqCst);

        let leader = {
            let p = p.clone();
            std::thread::spawn(move || p.invoke("sq", 1).unwrap())
        };
        // Let the leader open its batch, then send the followers.
        std::thread::sleep(Duration::from_millis(20));
        let followers: Vec<_> = (2..=MEMBERS)
            .map(|i| {
                let p = p.clone();
                std::thread::spawn(move || p.invoke("sq", i).unwrap())
            })
            .collect();
        let mut outs = vec![leader.join().unwrap()];
        for f in followers {
            outs.push(f.join().unwrap());
        }

        assert_eq!(
            engine.predict_calls.load(std::sync::atomic::Ordering::SeqCst),
            calls_before + 1,
            "{MEMBERS} requests, ONE forward pass"
        );
        // Everyone rode the same batch and was billed an even split of
        // the one (sublinear) pass.
        let first = &outs[0].record;
        assert_eq!(first.batch_size, MEMBERS as usize);
        for out in &outs {
            assert_eq!(out.record.batch_size, MEMBERS as usize);
            assert_eq!(out.record.billed, first.billed, "even billed split");
            assert_eq!(out.record.predict, first.predict, "all waited the same pass");
        }
        // Correctness per member: the batch produced exactly the
        // classifications solo runs of seeds 1..=MEMBERS produce (the
        // mock is deterministic per seed), no mixups, none dropped.
        let solo = MockEngine::paper_zoo();
        let (h, _) = solo.create_instance("squeezenet", "pallas").unwrap();
        let mut expect: Vec<i32> =
            (1..=MEMBERS).map(|s| solo.predict(&h, s).unwrap().top1).collect();
        let mut got: Vec<i32> = outs.iter().map(|o| o.prediction.top1).collect();
        expect.sort_unstable();
        got.sort_unstable();
        assert_eq!(got, expect, "every member got its own seed's classification");
        // Split x members ≈ the whole effective pass; sublinear means
        // cheaper than members x solo cost (marginal 0.25 < 1).
        let total_billed: Duration = outs.iter().map(|o| o.record.billed).sum();
        let solo_billed = p.invoke("sq", 99).unwrap().record.billed;
        assert!(
            total_billed < solo_billed * MEMBERS as u32,
            "batch billed {total_billed:?} vs {MEMBERS}x solo {solo_billed:?}"
        );
        let m = p.metrics.function_metrics("sq");
        assert_eq!(m.batched_requests, MEMBERS);
        assert_eq!(m.batch_size.max(), MEMBERS);
        assert_eq!(p.batcher.largest_batch(), MEMBERS);
    }

    fn snapshot_platform() -> (Arc<Invoker>, Arc<ManualClock>, Arc<MockEngine>) {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig {
            snapshot: crate::configparse::SnapshotConfig {
                enabled: true,
                capture_policy: crate::configparse::CapturePolicy::Sync,
                ..Default::default()
            },
            ..Default::default()
        };
        let p = Arc::new(Invoker::new(cfg, engine.clone(), clock.clone()));
        (p, clock, engine)
    }

    /// Acceptance: on a ManualClock, a snapshot-restored provision is
    /// strictly cheaper than the full cold one — no runtime-init, no
    /// package-fetch, no compile/model-load, a restore component that
    /// scales with `weight_bytes / restore_bw` — and the restored
    /// container classifies identically to the cold one on the same
    /// seeds.
    #[test]
    fn snapshot_restore_beats_full_cold_with_identical_predictions() {
        let (p, _, engine) = snapshot_platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();

        let cold = p.invoke("sq", 1).unwrap();
        assert_eq!(cold.record.start, StartKind::Cold);
        assert_eq!(p.snapshots.captures(), 1, "sync capture after the first cold");
        assert_eq!(p.snapshots.misses(), 1);

        // Force the next provision to miss the warm pool.
        p.evict_all();
        let rest = p.invoke("sq", 2).unwrap();
        let r = &rest.record;
        assert_eq!(r.start, StartKind::Restored);
        assert_eq!(r.runtime_init, Duration::ZERO, "runtime state rode the snapshot");
        assert_eq!(r.package_fetch, Duration::ZERO, "blob fetch replaced the package");
        assert_eq!(r.model_load, Duration::ZERO, "no compile, no init run");
        assert!(r.restore > Duration::ZERO);
        // restore = bytes/restore_bw/share (simulated fetch) +
        // bytes/MOCK_RESTORE_BW/share (engine upload).
        let bytes = engine.manifest("squeezenet").unwrap().param_bytes as f64;
        let share = 1024.0 / 1792.0;
        let expect = bytes / p.config().snapshot.restore_bw / share
            + bytes / crate::runtime::MOCK_RESTORE_BW / share;
        assert!(
            (r.restore.as_secs_f64() - expect).abs() < 1e-9,
            "restore={:?} expect={expect}",
            r.restore
        );
        assert!(
            r.cold_overhead() < cold.record.cold_overhead(),
            "restored {:?} vs cold {:?}",
            r.cold_overhead(),
            cold.record.cold_overhead()
        );
        assert!(r.billed < cold.record.billed, "cheaper handler time bills less");
        assert_eq!(p.snapshots.hits(), 1);
        assert_eq!(p.scaler.cold_provision_count(), 1);
        assert_eq!(p.scaler.restored_provision_count(), 1);

        // Same seeds, same classifications as a snapshot-free platform.
        let (off, _, _) = platform();
        off.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        for seed in [2u64, 3, 4] {
            let a = p.invoke("sq", seed).unwrap().prediction;
            let b = off.invoke("sq", seed).unwrap().prediction;
            assert_eq!(a.top1, b.top1, "seed {seed}");
            assert_eq!(a.top_prob, b.top_prob);
            assert_eq!(a.compute, b.compute);
        }

        // The metrics shard streams the third mode + its components.
        let m = p.metrics.function_metrics("sq");
        assert_eq!(m.restored_starts, 1);
        assert_eq!(m.response_restored.count(), 1);
        assert_eq!(m.provision_restore.count(), 1);
        assert_eq!(m.provision_model_load.count(), 1, "only the real cold start");
        assert!(m.response_restored.p50() < m.response_cold.p50());
    }

    /// Satellite regression: `Engine::live_instances` returns to zero
    /// after undeploy + keep-alive sweep across every eviction path —
    /// including a failed restore mid-provision, which must fall back
    /// to the full cold path (request served, not errored) without
    /// leaking a half-created instance.
    #[test]
    fn engine_leak_free_across_eviction_paths_including_failed_restore() {
        let (p, clock, engine) = snapshot_platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();

        // Seed the snapshot, then break restores.
        assert_eq!(p.invoke("sq", 1).unwrap().record.start, StartKind::Cold);
        p.evict_all();
        assert_eq!(engine.live_instances(), 0, "evict_all reaps");
        engine.fail_restore.store(true, std::sync::atomic::Ordering::SeqCst);
        let out = p.invoke("sq", 2).unwrap();
        assert_eq!(out.record.start, StartKind::Cold, "failed restore falls back, not errors");
        assert_eq!(p.snapshots.restore_failures(), 1);
        engine.fail_restore.store(false, std::sync::atomic::Ordering::SeqCst);

        // A successful restore path, then keep-alive expiry.
        p.evict_all();
        assert_eq!(p.invoke("sq", 3).unwrap().record.start, StartKind::Restored);
        clock.sleep(Duration::from_secs(601));
        assert_eq!(p.sweep(), 1, "keep-alive sweep reaps the restored container");
        assert_eq!(engine.live_instances(), 0);

        // Reconfigure-eviction path: the restored-then-parked container
        // and the old shape's snapshot both go.
        assert_eq!(p.invoke("sq", 4).unwrap().record.start, StartKind::Restored);
        p.reconfigure("sq", &ReconfigurePatch { memory_mb: Some(1536), ..Default::default() })
            .unwrap();
        assert_eq!(p.pool.warm_count("sq"), 0);
        assert_eq!(engine.live_instances(), 0);
        assert_eq!(p.snapshots.stale(), 1, "old 1024 MB shape invalidated");

        // ...and undeploy drops the current shape's snapshot and reaps.
        assert_eq!(p.invoke("sq", 5).unwrap().record.start, StartKind::Cold);
        assert_eq!(p.snapshots.len(), 1, "the fresh 1536 MB shape is stored");
        p.undeploy("sq").unwrap();
        assert_eq!(p.pool.total_alive(), 0);
        assert_eq!(engine.live_instances(), 0, "no instance outlives its deployment");
        assert_eq!(p.snapshots.len(), 0, "undeployed shape's snapshot invalidated");
        assert_eq!(p.snapshots.stale(), 2);
    }

    /// Reconfiguring memory/variant obsoletes the OLD shape's
    /// snapshot; policy-only patches keep it.
    #[test]
    fn reconfigure_invalidates_old_shape_snapshot() {
        let (p, _, _) = snapshot_platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        assert_eq!(p.snapshots.len(), 1);
        // Cap-only patch: snapshot survives.
        p.reconfigure(
            "sq",
            &ReconfigurePatch { max_concurrency: Some(Some(4)), ..Default::default() },
        )
        .unwrap();
        assert_eq!(p.snapshots.len(), 1);
        assert_eq!(p.snapshots.stale(), 0);
        // Memory change: old shape invalidated.
        p.reconfigure("sq", &ReconfigurePatch { memory_mb: Some(1536), ..Default::default() })
            .unwrap();
        assert_eq!(p.snapshots.len(), 0);
        assert_eq!(p.snapshots.stale(), 1);
        // The per-function override patches tri-state like the rest.
        let off = ReconfigurePatch { snapshot: Some(Some(false)), ..Default::default() };
        let spec = p.reconfigure("sq", &off).unwrap();
        assert_eq!(spec.snapshot, Some(false));
        assert_eq!(p.invoke("sq", 2).unwrap().record.start, StartKind::Cold);
        assert!(p.snapshots.is_empty(), "snapshot=false override also skips captures");
        p.evict_all();
        assert_eq!(
            p.invoke("sq", 3).unwrap().record.start,
            StartKind::Cold,
            "snapshot=false override wins over the enabled platform default"
        );
        let spec = p
            .reconfigure("sq", &ReconfigurePatch { snapshot: Some(None), ..Default::default() })
            .unwrap();
        assert_eq!(spec.snapshot, None, "null clears back to the platform default");
    }

    /// Snapshots are shared per shape: one function's undeploy must
    /// not drop the checkpoint a sibling with the same
    /// model/variant/memory is restoring from — only the shape's LAST
    /// user invalidates it.
    #[test]
    fn shared_shape_snapshot_survives_sibling_undeploy() {
        let (p, _, _) = snapshot_platform();
        p.deploy("f1", "squeezenet", "pallas", 1024).unwrap();
        p.deploy("f2", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("f1", 1).unwrap(); // cold + capture of the shared shape
        assert_eq!(p.snapshots.len(), 1);
        // f2 restores from f1's checkpoint (shape-shared).
        assert_eq!(p.invoke("f2", 1).unwrap().record.start, StartKind::Restored);
        // f1 goes away: the shape still has a user — blob kept.
        p.undeploy("f1").unwrap();
        assert_eq!(p.snapshots.len(), 1, "sibling still uses the shape");
        assert_eq!(p.snapshots.stale(), 0);
        p.evict_all();
        assert_eq!(p.invoke("f2", 2).unwrap().record.start, StartKind::Restored);
        // The last user leaves: now the checkpoint goes too.
        p.undeploy("f2").unwrap();
        assert_eq!(p.snapshots.len(), 0);
        assert_eq!(p.snapshots.stale(), 1);
    }

    /// Default-off contract: with `snapshot.enabled = false` and no
    /// override, the snapshot machinery is never touched — the PR 4
    /// pipeline bit-for-bit.
    #[test]
    fn snapshots_disabled_by_default_never_touch_the_store() {
        let (p, clock, engine) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        clock.sleep(Duration::from_secs(601));
        p.invoke("sq", 2).unwrap(); // a second cold start
        p.prewarm("sq", 1).unwrap();
        assert_eq!(p.snapshots.hits() + p.snapshots.misses() + p.snapshots.captures(), 0);
        assert!(p.snapshots.is_empty());
        assert_eq!(engine.snapshot_calls.load(std::sync::atomic::Ordering::SeqCst), 0);
        assert_eq!(engine.restore_calls.load(std::sync::atomic::Ordering::SeqCst), 0);
        assert_eq!(p.scaler.restored_provision_count(), 0);
    }

    /// The prewarm/maintainer path consults the store too: a top-up
    /// after the first cold capture restores instead of full-colding.
    #[test]
    fn prewarm_path_restores_from_snapshot() {
        let (p, _, engine) = snapshot_platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap(); // cold + sync capture
        p.evict_all();
        let n = p.prewarm("sq", 2).unwrap();
        assert_eq!(n, 2);
        assert_eq!(p.snapshots.hits(), 2, "both prewarms restored");
        assert_eq!(
            engine.restore_calls.load(std::sync::atomic::Ordering::SeqCst),
            2,
            "prewarm went through the restore path"
        );
        // Prewarm accounting is unchanged: operator-paid, not a
        // request-visible cold start.
        assert_eq!(p.scaler.prewarm_provision_count(), 2);
        assert_eq!(p.scaler.cold_provision_count(), 1);
        assert_eq!(p.scaler.restored_provision_count(), 0, "prewarms are not demand restores");
        assert_eq!(p.invoke("sq", 2).unwrap().record.start, StartKind::Warm);
    }

    #[test]
    fn records_accumulate_costs() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        for i in 0..5 {
            p.invoke("sq", i).unwrap();
        }
        assert_eq!(p.billing.lines().len(), 5);
        assert!((p.metrics.total_cost() - p.billing.total_dollars()).abs() < 1e-12);
    }

    /// `pool_shards > 1`: deployment prewarm and the maintainer's
    /// `min_warm` top-up land containers on each function's own shard
    /// while the capacity ledger stays global across shards.
    #[test]
    fn min_warm_top_up_spans_pool_shards() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig { pool_shards: 4, ..Default::default() };
        let p = Invoker::new(cfg, engine, clock.clone());
        assert_eq!(p.pool.shard_count(), 4);
        for name in ["f0", "f1", "f2"] {
            p.deploy_full(
                name,
                "squeezenet",
                "pallas",
                1024,
                FunctionPolicy { min_warm: 2, ..Default::default() },
            )
            .unwrap();
            assert_eq!(p.pool.warm_count(name), 2, "{name} prewarmed on deploy");
        }
        assert_eq!(p.pool.total_alive(), 6, "global capacity count spans shards");
        // Keep-alive expiry empties every shard; ONE maintenance tick
        // replenishes every function back to its target.
        clock.sleep(Duration::from_secs(601));
        let report = p.maintain();
        assert_eq!(report.evicted, 6);
        assert_eq!(report.replenished, 6);
        for name in ["f0", "f1", "f2"] {
            assert_eq!(p.pool.warm_count(name), 2, "{name} topped back up");
        }
        // And invokes find their function's warm shard, whichever one
        // the name hashes to.
        for (i, name) in ["f0", "f1", "f2"].iter().enumerate() {
            assert_eq!(p.invoke(name, i as u64).unwrap().record.start, StartKind::Warm);
        }
    }

    /// Pre-formed batches (the async drain path): one admission, ONE
    /// engine pass, per-member records with zero batch wait, and the
    /// kernel ladder visible in the records — hit/miss deltas on the
    /// leader's record only.
    #[test]
    fn preformed_batch_one_pass_with_kernel_report() {
        let (p, _, engine) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 0).unwrap(); // warm one container
        engine.set_batch_kernel_max(2);
        let calls_before = engine.predict_calls.load(std::sync::atomic::Ordering::SeqCst);
        let outs = p.invoke_preformed("sq", &[1, 2, 3, 4]);
        assert_eq!(
            engine.predict_calls.load(std::sync::atomic::Ordering::SeqCst),
            calls_before + 1,
            "4 drained jobs, ONE forward pass"
        );
        let outs: Vec<InvokeOutcome> = outs.into_iter().map(|r| r.unwrap()).collect();
        // Flush of 4 through the N<=2 ladder: chunks [2, 2] — the
        // first compiles the rung (miss), the second reuses it (hit) —
        // and only the leader's record owns those deltas.
        assert_eq!(outs[0].record.kernel_batch_n, 2);
        assert_eq!(outs[0].record.batch_kernel_misses, 1);
        assert_eq!(outs[0].record.batch_kernel_hits, 1);
        for out in &outs[1..] {
            assert_eq!(out.record.start, StartKind::Warm);
            assert_eq!(
                out.record.batch_kernel_hits + out.record.batch_kernel_misses,
                0,
                "pass-level deltas have one owner"
            );
        }
        for out in &outs {
            assert_eq!(out.record.batch_size, 4);
            assert_eq!(out.record.batch_wait, Duration::ZERO, "no collection window");
            assert_eq!(out.record.kernel_batch_n, 2, "request-weighted like batch_size");
            assert_eq!(out.record.billed, outs[0].record.billed, "even billed split");
        }
        // Per-member correctness: each seed classifies exactly as a
        // solo run would (the mock is deterministic per seed).
        let solo = MockEngine::paper_zoo();
        let (h, _) = solo.create_instance("squeezenet", "pallas").unwrap();
        for (out, seed) in outs.iter().zip([1u64, 2, 3, 4]) {
            assert_eq!(out.prediction.top1, solo.predict(&h, seed).unwrap().top1, "seed {seed}");
        }
    }

    /// A pre-formed batch takes one concurrency slot PER member — it
    /// must not dodge `max_concurrency` by arriving pre-grouped. Seeds
    /// over the cap are refused with 429; the rest ride one pass.
    #[test]
    fn preformed_batch_respects_concurrency_cap_per_seed() {
        let (p, _, _) = platform();
        p.deploy_full(
            "sq",
            "squeezenet",
            "pallas",
            1024,
            FunctionPolicy { max_concurrency: Some(2), ..Default::default() },
        )
        .unwrap();
        let outs = p.invoke_preformed("sq", &[1, 2, 3]);
        assert!(matches!(outs[2], Err(InvokeError::Throttled)), "third seed over the cap");
        assert_eq!(p.scaler.throttled_count(), 1);
        for r in &outs[..2] {
            assert_eq!(r.as_ref().unwrap().record.batch_size, 2, "admitted pair rode one pass");
        }
    }

    // ---- adaptive controllers (policy.enabled / per-function `adaptive`) ----

    /// With everything at defaults the policy layer is inert: no
    /// controller state is even created (the hot path takes no policy
    /// lock), and the pipeline is the fixed one bit-for-bit.
    #[test]
    fn adaptive_off_by_default_creates_no_policy_state() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        for i in 0..5 {
            p.invoke("sq", i).unwrap();
        }
        assert!(p.policy.snapshot_view("sq").is_none(), "no arrivals/records streamed");
        assert_eq!(p.policy.platform_view().policy_adjustments, 0);
    }

    /// End-to-end window shrink on virtual time: lone leaders pay the
    /// static 50 ms window, which blows a 100 ms SLO's batch-wait
    /// budget (25 ms) — the controller halves the window each flush
    /// until the tail fits, and the shorter window is visible in the
    /// records themselves.
    #[test]
    fn adaptive_window_shrinks_to_defend_the_slo() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig {
            max_batch_size: 8,
            batch_window_ms: 50,
            policy: crate::configparse::PolicyConfig { enabled: true, ..Default::default() },
            ..Default::default()
        };
        let p = Invoker::new(cfg, engine, clock.clone());
        p.deploy_full(
            "sq",
            "squeezenet",
            "pallas",
            1024,
            FunctionPolicy { slo_target_ms: Some(100), ..Default::default() },
        )
        .unwrap();
        let first = p.invoke("sq", 0).unwrap().record;
        assert!(first.batch_wait >= Duration::from_millis(50), "first leader pays the knob");
        let mut waits = Vec::new();
        for i in 1..=8 {
            waits.push(p.invoke("sq", i).unwrap().record.batch_wait);
        }
        assert!(
            waits.last().unwrap() < &Duration::from_millis(50),
            "window shrank within a few flushes: {waits:?}"
        );
        let v = p.policy.snapshot_view("sq").unwrap();
        assert!(v.policy_adjustments > 0, "adjustments counted");
        assert!(v.effective_batch_window_ms < 50, "read-back shows the shrunken window");
        // The same trace with the controller off pays the full static
        // window every single time.
        let engine2 = Arc::new(MockEngine::paper_zoo());
        let clock2 = ManualClock::new();
        let cfg2 = PlatformConfig { max_batch_size: 8, batch_window_ms: 50, ..Default::default() };
        let p2 = Invoker::new(cfg2, engine2, clock2);
        p2.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        for i in 0..=8 {
            let r = p2.invoke("sq", i).unwrap().record;
            assert!(r.batch_wait >= Duration::from_millis(50), "static window never adapts");
        }
    }

    /// The rung controller stops a rare jumbo flush from compiling the
    /// ladder's top rung once the recent flush-size p99 says typical
    /// flushes are pairs: the jumbo flush runs through capped (batch-2)
    /// kernels. Fixed mode compiles batch-8 for the same trace.
    #[test]
    fn adaptive_rung_cap_follows_observed_flush_sizes() {
        let run = |adaptive: bool| {
            let engine = Arc::new(MockEngine::paper_zoo());
            engine.set_batch_kernel_max(8);
            let clock = ManualClock::new();
            let cfg = PlatformConfig {
                max_batch_size: 8,
                batch_kernel_max: 8,
                policy: crate::configparse::PolicyConfig {
                    enabled: adaptive,
                    ..Default::default()
                },
                ..Default::default()
            };
            let p = Invoker::new(cfg, engine, clock);
            p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
            // Typical traffic: pair flushes (rung 2 is all demand needs).
            for i in 0..10u64 {
                for r in p.invoke_preformed("sq", &[i * 2, i * 2 + 1]) {
                    r.unwrap();
                }
            }
            // One jumbo flush.
            let outs = p.invoke_preformed("sq", &[100, 101, 102, 103, 104, 105, 106, 107]);
            outs.into_iter().map(|r| r.unwrap().record.kernel_batch_n).max().unwrap()
        };
        assert_eq!(run(false), 8, "fixed mode chases the full ladder");
        assert_eq!(run(true), 2, "adaptive mode serves the jumbo flush through learned rungs");
    }

    /// Predictive pre-provisioning: after sustained traffic, one
    /// maintenance tick tops the pool up to the forecast, so a burst
    /// arriving on cold ground pays strictly fewer cold starts than
    /// fixed mode (whose `min_warm = 0` tick provisions nothing).
    #[test]
    fn forecast_top_up_cuts_burst_cold_starts() {
        let run = |adaptive: bool| {
            let engine = Arc::new(MockEngine::paper_zoo());
            let clock = ManualClock::new();
            let cfg = PlatformConfig {
                policy: crate::configparse::PolicyConfig {
                    enabled: adaptive,
                    ..Default::default()
                },
                ..Default::default()
            };
            let p = Arc::new(Invoker::new(cfg, engine, clock.clone()));
            p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
            // Sustained traffic builds the arrival-rate forecast.
            for i in 0..60u64 {
                p.invoke("sq", i).unwrap();
                clock.sleep(Duration::from_millis(20));
            }
            // The pool goes cold (scale-to-zero moment)...
            p.evict_all();
            // ...then the maintainer ticks BEFORE the burst lands.
            p.maintain();
            let warm_ahead = p.pool.warm_count("sq");
            let cold_before = p.scaler.cold_provision_count();
            // Burst: 4 simultaneous requests on real threads.
            let burst: Vec<_> = (0..4u64)
                .map(|i| {
                    let p = p.clone();
                    std::thread::spawn(move || p.invoke("sq", 200 + i).unwrap())
                })
                .collect();
            for t in burst {
                t.join().unwrap();
            }
            (warm_ahead, p.scaler.cold_provision_count() - cold_before)
        };
        let (warm_fixed, cold_fixed) = run(false);
        let (warm_adaptive, cold_adaptive) = run(true);
        assert_eq!(warm_fixed, 0, "min_warm 0: fixed tick provisions nothing");
        assert!(cold_fixed >= 1, "the fixed burst opens on cold ground");
        assert!(warm_adaptive >= 4, "forecast topped the pool up ahead of the burst");
        assert_eq!(cold_adaptive, 0, "the adaptive burst lands on warm containers");
        assert!(cold_adaptive < cold_fixed, "strictly fewer burst cold starts");
    }

    /// Deploy-time eager capture: with the controllers and the
    /// snapshot store on, deploying a `min_warm = 0` function captures
    /// its shape's checkpoint immediately (and releases the probe
    /// container — this function chose restore-over-keep-warm), so
    /// the FIRST demand cold start already restores.
    #[test]
    fn eager_capture_on_deploy_makes_first_provision_restored() {
        use crate::configparse::{CapturePolicy, SnapshotConfig};
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig {
            policy: crate::configparse::PolicyConfig { enabled: true, ..Default::default() },
            snapshot: SnapshotConfig {
                enabled: true,
                capture_policy: CapturePolicy::Sync,
                ..Default::default()
            },
            ..Default::default()
        };
        let p = Invoker::new(cfg, engine, clock);
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        assert_eq!(p.snapshots.captures(), 1, "checkpoint captured at deploy time");
        assert_eq!(p.pool.warm_count("sq"), 0, "min_warm 0: probe container released");
        let r = p.invoke("sq", 1).unwrap().record;
        assert_eq!(r.start, StartKind::Restored, "first demand provision restores");
        assert_eq!(p.scaler.cold_provision_count(), 0);
    }

    /// A `min_warm > 0` function keeps its eager-capture probe as warm
    /// capacity instead of evicting it (keep-warm stays primary when
    /// the operator already pays for it).
    #[test]
    fn eager_capture_keeps_probe_when_min_warm_positive() {
        use crate::configparse::{CapturePolicy, SnapshotConfig};
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig {
            policy: crate::configparse::PolicyConfig { enabled: true, ..Default::default() },
            snapshot: SnapshotConfig {
                enabled: true,
                capture_policy: CapturePolicy::Sync,
                ..Default::default()
            },
            ..Default::default()
        };
        let p = Invoker::new(cfg, engine, clock);
        p.deploy_full(
            "sq",
            "squeezenet",
            "pallas",
            1024,
            FunctionPolicy { min_warm: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(p.snapshots.captures(), 1);
        assert_eq!(p.pool.warm_count("sq"), 1, "probe doubles as the min_warm container");
    }

    /// Reconfigure round-trips the new tri-state policy fields: set,
    /// keep (absent), clear (explicit null).
    #[test]
    fn reconfigure_patches_slo_and_adaptive_tri_state() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        let patched = p
            .reconfigure(
                "sq",
                &ReconfigurePatch {
                    slo_target_ms: Some(Some(750)),
                    adaptive: Some(Some(true)),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(patched.slo_target_ms, Some(750));
        assert_eq!(patched.adaptive, Some(true));
        // Absent fields keep the current values.
        let kept =
            p.reconfigure("sq", &ReconfigurePatch { min_warm: Some(0), ..Default::default() })
                .unwrap();
        assert_eq!(kept.slo_target_ms, Some(750));
        assert_eq!(kept.adaptive, Some(true));
        // Explicit null clears back to the platform defaults.
        let cleared = p
            .reconfigure(
                "sq",
                &ReconfigurePatch {
                    slo_target_ms: Some(None),
                    adaptive: Some(None),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(cleared.slo_target_ms, None);
        assert_eq!(cleared.adaptive, None);
    }

    /// Undeploy drops the function's controller shard along with its
    /// metrics shard.
    #[test]
    fn undeploy_drops_policy_state() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig {
            policy: crate::configparse::PolicyConfig { enabled: true, ..Default::default() },
            ..Default::default()
        };
        let p = Invoker::new(cfg, engine, clock);
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        assert!(p.policy.snapshot_view("sq").is_some());
        p.undeploy("sq").unwrap();
        assert!(p.policy.snapshot_view("sq").is_none());
    }

    // ---- invocation tracing (trace.enabled / the exemplar ring) ----

    use super::super::trace::Stage;

    fn traced_platform(sample_rate: f64) -> (Arc<Invoker>, Arc<ManualClock>, Arc<MockEngine>) {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig {
            trace: crate::configparse::TraceConfig {
                enabled: true,
                sample_rate,
                ..Default::default()
            },
            ..Default::default()
        };
        let p = Arc::new(Invoker::new(cfg, engine.clone(), clock.clone()));
        (p, clock, engine)
    }

    /// Acceptance: with everything at defaults the trace layer is
    /// inert — no trace ids minted, no ring entries, every gauge zero.
    /// The pipeline is the untraced one bit-for-bit.
    #[test]
    fn tracing_off_by_default_is_inert() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        for i in 0..3 {
            let out = p.invoke("sq", i).unwrap();
            assert_eq!(out.record.trace_id, None, "no trace id minted while off");
        }
        assert!(!p.trace.enabled());
        assert_eq!(p.trace.ring_len(), 0);
        assert_eq!(p.trace.retained(), 0);
        assert_eq!(p.trace.sampled_out(), 0);
        assert_eq!(p.trace.ring_bytes(), 0);
    }

    /// Acceptance: on a ManualClock the cold trace's span durations
    /// are exact — each provision child equals the record's
    /// per-component cost, and the duration-bearing spans sum to the
    /// record's response. The warm trace drops the provision subtree
    /// and holds the same identity.
    #[test]
    fn cold_and_warm_traces_hold_span_sum_identity() {
        let (p, _, _) = traced_platform(1.0);
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();

        let cold = p.invoke("sq", 1).unwrap().record;
        let t = p.trace.get(cold.trace_id.as_deref().unwrap()).unwrap();
        assert_eq!(t.start, StartKind::Cold);
        assert!(t.matches_kind("cold"));
        assert_eq!(t.stage_sum(), cold.response());
        for (stage, dur) in [
            (Stage::Sandbox, cold.sandbox),
            (Stage::RuntimeInit, cold.runtime_init),
            (Stage::PackageFetch, cold.package_fetch),
            (Stage::ModelLoad, cold.model_load),
            (Stage::Restore, cold.restore),
        ] {
            assert_eq!(t.span(stage).unwrap().dur, dur, "{stage:?}");
        }
        assert_eq!(t.span(Stage::Provision).unwrap().dur, cold.cold_overhead());
        assert_eq!(t.span(Stage::KernelExec).unwrap().dur, cold.predict);

        let warm = p.invoke("sq", 2).unwrap().record;
        let t = p.trace.get(warm.trace_id.as_deref().unwrap()).unwrap();
        assert_eq!(t.start, StartKind::Warm);
        assert_eq!(t.kind(), "steady", "warm under the default SLO");
        assert_eq!(t.stage_sum(), warm.response());
        assert!(t.span(Stage::Provision).is_none(), "warm start never provisioned");
        assert_eq!(p.trace.retained(), 2);
        assert_eq!(p.trace.sampled_out(), 0);
    }

    /// A snapshot-restored provision traces as `restored` with a real
    /// restore child and zeroed cold-only components, and the span-sum
    /// identity still holds.
    #[test]
    fn restored_trace_has_restore_child_and_identity() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig {
            snapshot: crate::configparse::SnapshotConfig {
                enabled: true,
                capture_policy: crate::configparse::CapturePolicy::Sync,
                ..Default::default()
            },
            trace: crate::configparse::TraceConfig { enabled: true, ..Default::default() },
            ..Default::default()
        };
        let p = Arc::new(Invoker::new(cfg, engine, clock));
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        p.evict_all();
        let rest = p.invoke("sq", 2).unwrap().record;
        assert_eq!(rest.start, StartKind::Restored);
        let t = p.trace.get(rest.trace_id.as_deref().unwrap()).unwrap();
        assert!(t.matches_kind("restored"));
        assert_eq!(t.stage_sum(), rest.response());
        assert_eq!(t.span(Stage::Restore).unwrap().dur, rest.restore);
        assert!(t.span(Stage::Restore).unwrap().dur > Duration::ZERO);
        assert_eq!(t.span(Stage::ModelLoad).unwrap().dur, Duration::ZERO);
        // Restored starts are always interesting: retained even at the
        // default sample_rate of 0.
        assert_eq!(p.trace.recent("sq", Some("restored"), 10).len(), 1);
    }

    /// Batch members each own a trace: the leader's holds the real
    /// `kernel_exec` pass, each follower's links back to it via
    /// `shared_exec_with` (and the exec-span note), and every member
    /// still satisfies its own span-sum identity.
    #[test]
    fn batch_followers_share_the_leader_exec_span() {
        let (p, _, _) = traced_platform(1.0);
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 0).unwrap(); // warm one container
        let outs: Vec<InvokeOutcome> =
            p.invoke_preformed("sq", &[1, 2, 3]).into_iter().map(|r| r.unwrap()).collect();
        let leader_id = outs[0].record.trace_id.clone().unwrap();
        let leader = p.trace.get(&leader_id).unwrap();
        assert_eq!(leader.shared_exec_with, None);
        assert_eq!(leader.batch_size, 3);
        for out in &outs[1..] {
            let fid = out.record.trace_id.as_deref().unwrap();
            assert_ne!(fid, leader_id, "each member owns a distinct trace");
            let follower = p.trace.get(fid).unwrap();
            assert_eq!(follower.shared_exec_with.as_deref(), Some(leader_id.as_str()));
            assert_eq!(follower.stage_sum(), out.record.response());
            let note = &follower.span(Stage::KernelExec).unwrap().note;
            assert!(note.contains(&format!("shared_with={leader_id}")), "{note}");
        }
    }

    /// A queue refusal leaves an always-retained error trace carrying
    /// the full (virtual) wait, even with steady sampling at zero.
    #[test]
    fn queue_expiry_leaves_an_error_trace() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig {
            max_containers: 1,
            trace: crate::configparse::TraceConfig { enabled: true, ..Default::default() },
            ..Default::default()
        };
        let p = Invoker::new(cfg, engine, clock.clone());
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        let held = p.pool.acquire("sq").unwrap();
        assert!(matches!(p.invoke("sq", 2), Err(InvokeError::Saturated(_))));
        p.pool.release(held);
        let errors = p.trace.recent("sq", Some("error"), 10);
        assert_eq!(errors.len(), 1);
        let t = &errors[0];
        assert_eq!(t.kind(), "error");
        assert!(t.error.as_deref().unwrap().contains("deadline"), "{:?}", t.error);
        assert!(
            t.span(Stage::QueueWait).unwrap().dur >= Duration::from_secs(2),
            "refusal trace carries the virtual queue wait"
        );
    }

    /// Tail-based sampling: interesting traces (cold, SLO-violating)
    /// bypass the coin; steady warm traffic is dropped at
    /// `sample_rate = 0` and counted in `traces_sampled_out`.
    #[test]
    fn steady_traffic_sampled_out_but_tail_always_kept() {
        let (p, _, _) = traced_platform(0.0);
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 0).unwrap(); // cold: always kept
        for i in 1..=5 {
            let out = p.invoke("sq", i).unwrap();
            assert!(out.record.trace_id.is_some(), "ids minted even when sampled out");
        }
        assert_eq!(p.trace.retained(), 1, "only the cold exemplar survived");
        assert_eq!(p.trace.sampled_out(), 5);
        assert_eq!(p.trace.ring_len(), 1);

        // A tight SLO turns the same steady traffic into violators —
        // all retained despite the zero rate.
        let (p, _, _) = traced_platform(0.0);
        p.deploy_full(
            "sq",
            "squeezenet",
            "pallas",
            1024,
            FunctionPolicy { slo_target_ms: Some(1), ..Default::default() },
        )
        .unwrap();
        for i in 0..4 {
            p.invoke("sq", i).unwrap();
        }
        assert_eq!(p.trace.retained(), 4, "every SLO violator kept");
        assert_eq!(p.trace.sampled_out(), 0);
        let slow = p.trace.recent("sq", Some("slow"), 10);
        assert_eq!(slow.len(), 4);
        assert!(slow.iter().all(|t| t.slo_violation));
    }
}
