//! The invocation pipeline: route -> acquire (warm | cold provision)
//! -> throttled execute -> meter -> release.
//!
//! [`Platform`] is the top-level façade the gateway, experiments, and
//! examples use: it owns the registry, warm pool, scaler, CPU
//! governor, billing meter, metrics sink, and the engine. `invoke` is
//! safe to call from many threads concurrently (the scalability
//! experiments do).

use super::billing::BillingMeter;
use super::container::Container;
use super::metrics::{InvocationRecord, MetricsSink, StartKind};
use super::pool::WarmPool;
use super::registry::{FunctionRegistry, FunctionSpec};
use super::scaler::Scaler;
use super::throttle::CpuGovernor;
use crate::configparse::PlatformConfig;
use crate::runtime::{Engine, Prediction};
use crate::util::{Clock, SplitMix64, SystemClock};
use anyhow::Result;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Error kind surfaced to the gateway (HTTP status mapping).
#[derive(Debug, thiserror::Error)]
pub enum InvokeError {
    #[error("function not found: {0}")]
    NotFound(String),
    #[error("throttled: container capacity exhausted")]
    Throttled,
    #[error("execution failed: {0}")]
    Failed(#[from] anyhow::Error),
}

/// Successful invocation result.
#[derive(Debug, Clone)]
pub struct InvokeOutcome {
    pub record: InvocationRecord,
    pub prediction: Prediction,
}

pub struct Invoker {
    pub registry: FunctionRegistry,
    pub pool: WarmPool,
    pub scaler: Scaler,
    pub billing: BillingMeter,
    pub metrics: MetricsSink,
    governor: CpuGovernor,
    engine: Arc<dyn Engine>,
    config: PlatformConfig,
    clock: Arc<dyn Clock>,
    rng: Mutex<SplitMix64>,
}

/// Alias used across the crate: the assembled platform.
pub type Platform = Invoker;

impl Invoker {
    pub fn new(config: PlatformConfig, engine: Arc<dyn Engine>, clock: Arc<dyn Clock>) -> Self {
        Self {
            registry: FunctionRegistry::new(engine.clone()),
            pool: WarmPool::new(config.max_containers, config.keep_alive_s, clock.clone()),
            scaler: Scaler::new(),
            billing: BillingMeter::new(config.pricing.clone()),
            metrics: MetricsSink::new(),
            governor: CpuGovernor::new(config.full_power_mem_mb, clock.clone()),
            engine,
            rng: Mutex::new(SplitMix64::new(config.seed)),
            config,
            clock,
        }
    }

    /// Platform on the system clock (live serving).
    pub fn live(config: PlatformConfig, engine: Arc<dyn Engine>) -> Self {
        Self::new(config, engine, Arc::new(SystemClock::new()))
    }

    pub fn config(&self) -> &PlatformConfig {
        &self.config
    }

    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    pub fn engine(&self) -> &Arc<dyn Engine> {
        &self.engine
    }

    pub fn governor(&self) -> &CpuGovernor {
        &self.governor
    }

    /// Deploy a function (name, model, variant, memory).
    pub fn deploy(
        &self,
        name: &str,
        model: &str,
        variant: &str,
        memory_mb: u32,
    ) -> Result<Arc<FunctionSpec>> {
        self.registry.deploy(name, model, variant, memory_mb)
    }

    /// Pre-warm `n` containers for `function` (§5 "keep warm" knob).
    pub fn prewarm(&self, function: &str, n: usize) -> Result<usize> {
        let spec = self.registry.get(function)?;
        self.scaler.prewarm(
            &spec,
            n,
            &self.pool,
            &self.engine,
            &self.governor,
            &self.config.bootstrap,
            &self.clock,
            &self.rng,
        )
    }

    /// Invoke `function` on a (seeded) synthetic image.
    pub fn invoke(&self, function: &str, image_seed: u64) -> Result<InvokeOutcome, InvokeError> {
        let spec = self
            .registry
            .get(function)
            .map_err(|_| InvokeError::NotFound(function.to_string()))?;
        let _flight = self.scaler.arrive();
        let t_queue_start = self.clock.now();

        // Acquire: warm hit or cold provision.
        let (mut container, start, queue_wait) = match self.pool.acquire(function) {
            Some(c) => {
                let wait = Duration::from_nanos(self.clock.now() - t_queue_start);
                (c, StartKind::Warm, wait)
            }
            None => {
                if !self.pool.try_reserve() {
                    self.scaler.note_throttled();
                    return Err(InvokeError::Throttled);
                }
                let provisioned = {
                    // Hold the RNG lock only to draw the bootstrap
                    // sample, not for the whole provision.
                    let mut rng = self.rng.lock().unwrap();
                    Container::provision(
                        spec.clone(),
                        self.engine.clone(),
                        &self.governor,
                        &self.config.bootstrap,
                        &self.clock,
                        &mut rng,
                    )
                };
                match provisioned {
                    Ok(c) => {
                        self.scaler.note_cold_provision();
                        let wait = Duration::from_nanos(self.clock.now() - t_queue_start);
                        (c, StartKind::Cold, wait)
                    }
                    Err(e) => {
                        self.pool.cancel_reservation();
                        return Err(InvokeError::Failed(e));
                    }
                }
            }
        };

        // Execute under the CPU governor.
        let executed = container.execute(&self.governor, &self.clock, image_seed);
        let (prediction, effective_predict) = match executed {
            Ok(v) => v,
            Err(e) => {
                // A failed container is not returned to the pool.
                self.pool.retire(container);
                return Err(InvokeError::Failed(e));
            }
        };

        // Meter: billed duration = handler time (cold init inside the
        // handler was billed in 2017-era Lambda) + prediction.
        let pc = container.provision_cost.clone();
        let cold_handler = if start == StartKind::Cold {
            pc.runtime_init + pc.package_fetch + pc.model_load
        } else {
            Duration::ZERO
        };
        let billed = cold_handler + effective_predict;
        let line = self
            .billing
            .charge(function, spec.memory_mb, billed)
            .map_err(InvokeError::Failed)?;

        let queue = match start {
            // Queue wait for cold starts is reported inside the
            // provision components; avoid double counting.
            StartKind::Cold => Duration::ZERO,
            StartKind::Warm => queue_wait,
        };
        let record = InvocationRecord {
            function: function.to_string(),
            memory_mb: spec.memory_mb,
            start,
            queue,
            sandbox: if start == StartKind::Cold { pc.sandbox } else { Duration::ZERO },
            runtime_init: if start == StartKind::Cold { pc.runtime_init } else { Duration::ZERO },
            package_fetch: if start == StartKind::Cold { pc.package_fetch } else { Duration::ZERO },
            model_load: if start == StartKind::Cold { pc.model_load } else { Duration::ZERO },
            predict: effective_predict,
            predict_full_speed: prediction.compute,
            billed,
            billed_ms: line.billed_ms,
            cost_dollars: line.total_dollars(),
            top1: prediction.top1,
        };
        self.metrics.record(record.clone());

        // Release to the warm pool for reuse.
        self.pool.release(container);

        Ok(InvokeOutcome { record, prediction })
    }

    /// Force-evict every idle container (tests / forced cold).
    pub fn evict_all(&self) -> usize {
        self.pool.evict_all()
    }

    /// Run one keep-alive sweep.
    pub fn sweep(&self) -> usize {
        self.pool.evict_expired()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockEngine;
    use crate::util::ManualClock;

    fn platform() -> (Arc<Invoker>, Arc<ManualClock>, Arc<MockEngine>) {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig::default();
        let p = Arc::new(Invoker::new(cfg, engine.clone(), clock.clone()));
        (p, clock, engine)
    }

    #[test]
    fn first_invoke_cold_second_warm() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        let a = p.invoke("sq", 1).unwrap();
        assert_eq!(a.record.start, StartKind::Cold);
        assert!(a.record.cold_overhead() > Duration::ZERO);
        let b = p.invoke("sq", 2).unwrap();
        assert_eq!(b.record.start, StartKind::Warm);
        assert_eq!(b.record.cold_overhead(), Duration::ZERO);
        assert!(b.record.response() < a.record.response());
        assert_eq!(p.metrics.len(), 2);
        assert_eq!(p.scaler.cold_provision_count(), 1);
    }

    #[test]
    fn unknown_function_is_not_found() {
        let (p, _, _) = platform();
        assert!(matches!(p.invoke("nope", 0), Err(InvokeError::NotFound(_))));
    }

    #[test]
    fn keep_alive_expiry_forces_cold() {
        let (p, clock, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        // The paper's cold methodology: 10-minute gaps between requests.
        clock.sleep(Duration::from_secs(601));
        let r = p.invoke("sq", 2).unwrap();
        assert_eq!(r.record.start, StartKind::Cold);
        assert_eq!(p.scaler.cold_provision_count(), 2);
    }

    #[test]
    fn within_keep_alive_stays_warm() {
        let (p, clock, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        clock.sleep(Duration::from_secs(250));
        let r = p.invoke("sq", 2).unwrap();
        assert_eq!(r.record.start, StartKind::Warm);
    }

    #[test]
    fn memory_scales_prediction_time() {
        let (p, _, _) = platform();
        p.deploy("small", "squeezenet", "pallas", 128).unwrap();
        p.deploy("big", "squeezenet", "pallas", 1536).unwrap();
        // Warm both.
        p.invoke("small", 1).unwrap();
        p.invoke("big", 1).unwrap();
        let small = p.invoke("small", 2).unwrap().record;
        let big = p.invoke("big", 2).unwrap().record;
        // share(128)=128/1792, share(1536)=1536/1792 -> 12x ratio.
        let ratio = small.predict.as_secs_f64() / big.predict.as_secs_f64();
        assert!((ratio - 12.0).abs() < 0.8, "ratio={ratio}");
    }

    #[test]
    fn cold_billed_more_than_warm() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        let cold = p.invoke("sq", 1).unwrap().record;
        let warm = p.invoke("sq", 2).unwrap().record;
        assert!(cold.billed > warm.billed);
        assert!(cold.cost_dollars > warm.cost_dollars);
        // Sandbox time is NOT billed (platform-side).
        assert!(cold.billed < cold.response());
    }

    #[test]
    fn throttles_at_container_cap() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig { max_containers: 1, ..Default::default() };
        let p = Invoker::new(cfg, engine, clock.clone());
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 1).unwrap();
        // The one container is warm in the pool; a concurrent second
        // request would need another container. Simulate by holding
        // the warm one.
        let held = p.pool.acquire("sq").unwrap();
        let err = p.invoke("sq", 2).unwrap_err();
        assert!(matches!(err, InvokeError::Throttled));
        assert_eq!(p.scaler.throttled_count(), 1);
        p.pool.release(held);
        assert!(p.invoke("sq", 3).is_ok(), "released container serves again");
    }

    #[test]
    fn failed_create_does_not_leak_capacity() {
        let engine = Arc::new(MockEngine::paper_zoo());
        let clock = ManualClock::new();
        let cfg = PlatformConfig { max_containers: 2, ..Default::default() };
        let p = Invoker::new(cfg, engine.clone(), clock.clone());
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        engine.fail_create.store(true, std::sync::atomic::Ordering::SeqCst);
        for _ in 0..5 {
            assert!(matches!(p.invoke("sq", 0), Err(InvokeError::Failed(_))));
        }
        engine.fail_create.store(false, std::sync::atomic::Ordering::SeqCst);
        // All reservations were cancelled; both slots still usable.
        assert!(p.invoke("sq", 1).is_ok());
        assert_eq!(p.pool.total_alive(), 1);
    }

    #[test]
    fn concurrent_invokes_spawn_containers() {
        let engine = Arc::new(MockEngine::paper_zoo());
        // Real clock so threads genuinely overlap.
        let cfg = PlatformConfig { max_containers: 64, ..Default::default() };
        let p = Arc::new(Invoker::live(cfg, engine));
        p.deploy("sq", "squeezenet", "pallas", 1536).unwrap();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let p = p.clone();
                std::thread::spawn(move || p.invoke("sq", i).unwrap().record.start)
            })
            .collect();
        let starts: Vec<StartKind> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // All 8 overlapped (mock predict reports >= 100 ms and the live
        // clock sleeps it), so all were cold provisions.
        assert_eq!(starts.iter().filter(|s| **s == StartKind::Cold).count(), 8);
        assert!(p.scaler.high_water_mark() >= 2);
        assert_eq!(p.pool.total_alive(), 8);
        // And they are all reusable now.
        let r = p.invoke("sq", 99).unwrap();
        assert_eq!(r.record.start, StartKind::Warm);
    }

    #[test]
    fn records_accumulate_costs() {
        let (p, _, _) = platform();
        p.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        for i in 0..5 {
            p.invoke("sq", i).unwrap();
        }
        assert_eq!(p.billing.lines().len(), 5);
        assert!((p.metrics.total_cost() - p.billing.total_dollars()).abs() < 1e-12);
    }
}
