//! Memory-proportional CPU governor.
//!
//! AWS Lambda "allocates CPU power proportional to the memory" — the
//! paper attributes its latency-vs-memory curves to exactly this
//! (§3.2: peak usage is 85/229/429 MB, so extra memory is *only*
//! buying CPU). We model a cgroup-style duty-cycle governor: a
//! compute-bound task that takes `t` at full speed takes `t / share`
//! under share `< 1`. The governor scales *measured real compute* into
//! *effective platform time* and advances the platform clock by the
//! difference, so real engines stay honest (their wall time is
//! already consumed) and virtual clocks account identically.

use crate::configparse::MemorySize;
use crate::util::Clock;
use std::sync::Arc;
use std::time::Duration;

#[derive(Clone)]
pub struct CpuGovernor {
    /// Memory that buys one full vCPU (AWS-documented ~1792 MB).
    full_power_mem_mb: u32,
    clock: Arc<dyn Clock>,
}

impl CpuGovernor {
    pub fn new(full_power_mem_mb: u32, clock: Arc<dyn Clock>) -> Self {
        assert!(full_power_mem_mb > 0);
        Self { full_power_mem_mb, clock }
    }

    /// CPU share in `(0, 1]` for a container of `mem` MB.
    pub fn share(&self, mem: MemorySize) -> f64 {
        (mem as f64 / self.full_power_mem_mb as f64).min(1.0)
    }

    /// Effective duration of a compute-bound task measured at full
    /// speed, when run under `mem`'s CPU share.
    pub fn scale(&self, full_speed: Duration, mem: MemorySize) -> Duration {
        Duration::from_secs_f64(full_speed.as_secs_f64() / self.share(mem))
    }

    /// Account a task that already consumed `real_elapsed` of wall time
    /// (real engine) but should appear to take `scale(full_speed)`:
    /// sleeps the clock for the remainder and returns the effective
    /// duration. With a virtual/manual clock the sleep is instant.
    pub fn throttle(
        &self,
        full_speed: Duration,
        real_elapsed: Duration,
        mem: MemorySize,
    ) -> Duration {
        let effective = self.scale(full_speed, mem);
        let already = if self.clock.is_real() { real_elapsed } else { Duration::ZERO };
        if effective > already {
            self.clock.sleep(effective - already);
        }
        effective
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;
    use crate::util::{ManualClock, SystemClock};

    fn gov() -> (CpuGovernor, Arc<ManualClock>) {
        let clock = ManualClock::new();
        (CpuGovernor::new(1792, clock.clone()), clock)
    }

    #[test]
    fn share_matches_lambda_rule() {
        let (g, _) = gov();
        assert!((g.share(128) - 128.0 / 1792.0).abs() < 1e-12);
        assert!((g.share(896) - 0.5).abs() < 1e-12);
        assert_eq!(g.share(1792), 1.0);
        assert_eq!(g.share(3008), 1.0, "share is capped at 1");
    }

    #[test]
    fn scale_is_inverse_share() {
        let (g, _) = gov();
        let t = Duration::from_millis(100);
        assert_eq!(g.scale(t, 1792), t);
        let scaled = g.scale(t, 128);
        assert!((scaled.as_secs_f64() - 1.4).abs() < 1e-9, "{scaled:?}");
        // 896 MB = half speed = double time.
        assert!((g.scale(t, 896).as_secs_f64() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn throttle_advances_manual_clock_by_full_effective() {
        let (g, clock) = gov();
        let eff = g.throttle(Duration::from_millis(100), Duration::from_millis(100), 896);
        assert!((eff.as_secs_f64() - 0.2).abs() < 1e-9);
        // Manual clock is not real: full effective duration is slept.
        assert_eq!(clock.now(), eff.as_nanos() as u64);
    }

    #[test]
    fn throttle_real_clock_sleeps_only_remainder() {
        let clock = Arc::new(SystemClock::new());
        let g = CpuGovernor::new(1000, clock.clone());
        let t0 = std::time::Instant::now();
        // Full speed 20 ms, already consumed 20 ms, share 0.5 ->
        // effective 40 ms -> sleep ~20 ms more.
        let eff = g.throttle(Duration::from_millis(20), Duration::from_millis(20), 500);
        let wall = t0.elapsed();
        assert!((eff.as_secs_f64() - 0.04).abs() < 1e-9);
        assert!(wall >= Duration::from_millis(15), "slept remainder, {wall:?}");
        assert!(wall < Duration::from_millis(45), "did not sleep full effective");
    }

    #[test]
    fn full_power_no_extra_sleep() {
        let (g, clock) = gov();
        let eff = g.throttle(Duration::from_millis(50), Duration::from_millis(50), 1792);
        assert_eq!(eff, Duration::from_millis(50));
        assert_eq!(clock.now(), 50_000_000);
    }

    #[test]
    fn prop_effective_time_monotone_decreasing_in_memory() {
        // The paper's headline warm curve: more memory, less latency.
        forall("scale(t, mem) decreasing in mem", |(ms, i): &(u64, u32)| {
            let (g, _) = gov();
            let t = Duration::from_millis(1 + ms % 10_000);
            let mems = crate::configparse::MEMORY_SIZES_2017;
            let idx = (*i as usize) % (mems.len() - 1);
            g.scale(t, mems[idx]) >= g.scale(t, mems[idx + 1])
        });
    }

    #[test]
    fn prop_effective_never_faster_than_full_speed() {
        forall("scale >= full speed", |(ms, i): &(u64, u32)| {
            let (g, _) = gov();
            let t = Duration::from_millis(ms % 100_000);
            let mems = crate::configparse::MEMORY_SIZES_2017;
            let m = mems[(*i as usize) % mems.len()];
            g.scale(t, m) >= t
        });
    }
}
