//! End-to-end invocation tracing: typed span timelines with
//! tail-based exemplar retention.
//!
//! Every invocation that crosses the platform while `trace.enabled`
//! is on gets ONE [`Trace`]: an ordered span timeline — `admission`
//! (pre-dispatch wait; the async queue for submitted invocations),
//! `queue_wait`, `provision` (with `sandbox` / `runtime_init` /
//! `package_fetch` / `model_load` / `restore` child spans),
//! `batch_collect`, `kernel_exec` (annotated with the kernel rung and
//! rung-cache hits/misses), and a zero-width `billing` marker. The
//! timeline is assembled lock-free on the invoking thread from the
//! finished [`InvocationRecord`], whose components the hot-path
//! modules already measure, so span durations are the *same numbers*
//! the metrics sink aggregates: the duration-bearing spans sum
//! exactly to [`InvocationRecord::response`] by construction
//! ([`Trace::stage_sum`]), and the `provision` children equal the
//! container's per-component provision costs exactly.
//!
//! Batch followers share the leader's execution span — their
//! `kernel_exec` carries the leader's trace id
//! ([`Trace::shared_exec_with`]) — but own their `queue_wait` and
//! `batch_collect` spans. Async invocations carry trace context
//! across the queue: the worker threads the submit timestamp through,
//! and it becomes the `admission` span.
//!
//! Completed traces land in a capacity-bounded ring with
//! **tail-based sampling**: "interesting" traces (cold/restored
//! starts, SLO-budget violations, errors, queue expiries) are always
//! retained, the rest pass a `trace.sample_rate` coin flip drawn from
//! a seeded [`SplitMix64`] — exemplars for the paper's cold-start
//! tail are never lost, steady-state overhead stays O(1), and a
//! `ManualClock` run is fully deterministic. With `trace.enabled`
//! off (the default) [`TraceSink::begin`] returns `None` and no
//! trace lock is ever acquired: the pipeline is preserved
//! bit-for-bit.
//!
//! Lock discipline: the only tracked lock is `ring` (ranked
//! `trace.ring` in `PLATFORM_LOCK_ORDER`), taken *standalone* at the
//! very end of an invocation — strictly after the metrics sink's
//! `record` and the policy feed return — and never held across any
//! call back into the platform. The sampling `rng` rides the
//! `platform.rng` rank and is likewise drawn-and-dropped before the
//! ring is touched.

use super::container::ProvisionCost;
use super::metrics::{InvocationRecord, StartKind};
use crate::configparse::TraceConfig;
use crate::util::clock::Nanos;
use crate::util::json::{obj, Json};
use crate::util::{plock, SplitMix64};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// The typed span vocabulary — every stage of the serving path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Pre-dispatch wait: zero-width for a sync arrival, the queue
    /// residency for an async invocation whose context crossed the
    /// worker queue. NOT part of the platform response time.
    Admission,
    /// Admission/dispatch-queue wait (the record's `queue`).
    QueueWait,
    /// Container provisioning (cold or restored); parent of the five
    /// component child spans below.
    Provision,
    Sandbox,
    RuntimeInit,
    PackageFetch,
    ModelLoad,
    Restore,
    /// Batch-collector residency: the leader's window wait, a
    /// follower's join-to-flush wait.
    BatchCollect,
    /// The forward pass (solo or the whole batched pass).
    KernelExec,
    /// Zero-width marker carrying the billed split.
    Billing,
}

impl Stage {
    pub fn as_str(&self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::Provision => "provision",
            Stage::Sandbox => "sandbox",
            Stage::RuntimeInit => "runtime_init",
            Stage::PackageFetch => "package_fetch",
            Stage::ModelLoad => "model_load",
            Stage::Restore => "restore",
            Stage::BatchCollect => "batch_collect",
            Stage::KernelExec => "kernel_exec",
            Stage::Billing => "billing",
        }
    }

    /// The five provision components nested under [`Stage::Provision`].
    pub fn is_provision_child(&self) -> bool {
        matches!(
            self,
            Stage::Sandbox
                | Stage::RuntimeInit
                | Stage::PackageFetch
                | Stage::ModelLoad
                | Stage::Restore
        )
    }
}

/// One span of a trace timeline. `start` is an absolute platform
/// clock reading; rendering subtracts the trace's `started_at`.
#[derive(Debug, Clone)]
pub struct Span {
    pub stage: Stage,
    pub start: Nanos,
    pub dur: Duration,
    /// Stage annotation (kernel rung, rung-cache hits, billed split);
    /// empty = none.
    pub note: String,
}

impl Span {
    fn to_json(&self, trace_start: Nanos) -> Json {
        obj(vec![
            ("stage", Json::Str(self.stage.as_str().to_string())),
            (
                "parent",
                if self.stage.is_provision_child() {
                    Json::Str("provision".to_string())
                } else {
                    Json::Null
                },
            ),
            (
                "offset_s",
                Json::Num(self.start.saturating_sub(trace_start) as f64 / 1e9),
            ),
            ("duration_s", Json::Num(self.dur.as_secs_f64())),
            (
                "note",
                if self.note.is_empty() { Json::Null } else { Json::Str(self.note.clone()) },
            ),
        ])
    }
}

/// One invocation's complete causal timeline.
#[derive(Debug, Clone)]
pub struct Trace {
    pub trace_id: String,
    pub function: String,
    /// Provisioning class of the serving container. Refusals (queue
    /// expiry, batch failure) never touched a container and report
    /// `Warm` here; their `error` drives classification instead.
    pub start: StartKind,
    /// When the request entered the platform: the async submit time
    /// when the context crossed the queue, otherwise dispatch arrival.
    pub started_at: Nanos,
    pub spans: Vec<Span>,
    /// Platform-side response time (the record's decomposition sum);
    /// for a refusal, how long the client was held before the error.
    pub response: Duration,
    /// The SLO budget this trace was judged against (0 = none).
    pub slo_target_ms: u64,
    pub slo_violation: bool,
    pub error: Option<String>,
    pub batch_size: usize,
    /// For a batch follower: the leader trace that owns the shared
    /// `kernel_exec` span.
    pub shared_exec_with: Option<String>,
}

impl Trace {
    /// Assemble the timeline from a finished invocation record. The
    /// record's components ARE the span durations, so the identity
    /// `stage_sum() == record.response()` holds by construction.
    pub fn from_record(
        trace_id: &str,
        r: &InvocationRecord,
        arrived_at: Nanos,
        submitted_at: Option<Nanos>,
        slo_target_ms: u64,
        shared_exec_with: Option<String>,
    ) -> Trace {
        let started_at = submitted_at.unwrap_or(arrived_at);
        let mut spans = Vec::with_capacity(11);
        spans.push(Span {
            stage: Stage::Admission,
            start: started_at,
            dur: Duration::from_nanos(arrived_at.saturating_sub(started_at)),
            note: String::new(),
        });
        let mut cursor = arrived_at;
        spans.push(Span {
            stage: Stage::QueueWait,
            start: cursor,
            dur: r.queue,
            note: String::new(),
        });
        cursor += r.queue.as_nanos() as Nanos;
        if r.start != StartKind::Warm {
            spans.push(Span {
                stage: Stage::Provision,
                start: cursor,
                dur: r.cold_overhead(),
                note: String::new(),
            });
            for (stage, dur) in [
                (Stage::Sandbox, r.sandbox),
                (Stage::RuntimeInit, r.runtime_init),
                (Stage::PackageFetch, r.package_fetch),
                (Stage::ModelLoad, r.model_load),
                (Stage::Restore, r.restore),
            ] {
                spans.push(Span { stage, start: cursor, dur, note: String::new() });
                cursor += dur.as_nanos() as Nanos;
            }
        }
        if r.batch_wait > Duration::ZERO || r.batch_size > 1 {
            spans.push(Span {
                stage: Stage::BatchCollect,
                start: cursor,
                dur: r.batch_wait,
                note: String::new(),
            });
            cursor += r.batch_wait.as_nanos() as Nanos;
        }
        let mut exec_note = format!(
            "kernel_batch_n={} batch={} rung_hits={} rung_misses={}",
            r.kernel_batch_n, r.batch_size, r.batch_kernel_hits, r.batch_kernel_misses
        );
        if let Some(leader) = &shared_exec_with {
            exec_note.push_str(&format!(" shared_with={leader}"));
        }
        spans.push(Span {
            stage: Stage::KernelExec,
            start: cursor,
            dur: r.predict,
            note: exec_note,
        });
        cursor += r.predict.as_nanos() as Nanos;
        spans.push(Span {
            stage: Stage::Billing,
            start: cursor,
            dur: Duration::ZERO,
            note: format!("billed_ms={} cost=${:.8}", r.billed_ms, r.cost_dollars),
        });
        let response = r.response();
        Trace {
            trace_id: trace_id.to_string(),
            function: r.function.clone(),
            start: r.start,
            started_at,
            spans,
            response,
            slo_target_ms,
            slo_violation: slo_target_ms > 0
                && response > Duration::from_millis(slo_target_ms),
            error: None,
            batch_size: r.batch_size,
            shared_exec_with,
        }
    }

    /// A refusal timeline: the request waited `waited` in the
    /// dispatch queue (or batch collector) and got an error instead
    /// of a container. Always retained (errors are interesting).
    pub fn refused(
        trace_id: &str,
        function: &str,
        arrived_at: Nanos,
        submitted_at: Option<Nanos>,
        waited: Duration,
        error: String,
    ) -> Trace {
        let started_at = submitted_at.unwrap_or(arrived_at);
        let spans = vec![
            Span {
                stage: Stage::Admission,
                start: started_at,
                dur: Duration::from_nanos(arrived_at.saturating_sub(started_at)),
                note: String::new(),
            },
            Span { stage: Stage::QueueWait, start: arrived_at, dur: waited, note: String::new() },
        ];
        Trace {
            trace_id: trace_id.to_string(),
            function: function.to_string(),
            start: StartKind::Warm,
            started_at,
            spans,
            response: waited,
            slo_target_ms: 0,
            slo_violation: false,
            error: Some(error),
            batch_size: 1,
            shared_exec_with: None,
        }
    }

    /// An execution-failure timeline: the container was provisioned
    /// (its per-component costs are real) but the forward pass or the
    /// billing step failed.
    pub fn failed(
        trace_id: &str,
        function: &str,
        start: StartKind,
        arrived_at: Nanos,
        submitted_at: Option<Nanos>,
        queue: Duration,
        pc: &ProvisionCost,
        error: String,
    ) -> Trace {
        let started_at = submitted_at.unwrap_or(arrived_at);
        let mut spans = vec![
            Span {
                stage: Stage::Admission,
                start: started_at,
                dur: Duration::from_nanos(arrived_at.saturating_sub(started_at)),
                note: String::new(),
            },
            Span { stage: Stage::QueueWait, start: arrived_at, dur: queue, note: String::new() },
        ];
        let mut cursor = arrived_at + queue.as_nanos() as Nanos;
        if start != StartKind::Warm {
            spans.push(Span {
                stage: Stage::Provision,
                start: cursor,
                dur: pc.total(),
                note: String::new(),
            });
            for (stage, dur) in [
                (Stage::Sandbox, pc.sandbox),
                (Stage::RuntimeInit, pc.runtime_init),
                (Stage::PackageFetch, pc.package_fetch),
                (Stage::ModelLoad, pc.model_load),
                (Stage::Restore, pc.restore),
            ] {
                spans.push(Span { stage, start: cursor, dur, note: String::new() });
                cursor += dur.as_nanos() as Nanos;
            }
        }
        Trace {
            trace_id: trace_id.to_string(),
            function: function.to_string(),
            start,
            started_at,
            spans,
            response: queue + pc.total(),
            slo_target_ms: 0,
            slo_violation: false,
            error: Some(error),
            batch_size: 1,
            shared_exec_with: None,
        }
    }

    /// Sum of the duration-bearing spans — everything the client
    /// waited for platform-side. Excludes the `provision` parent (the
    /// sum of its children), the `admission` span (pre-platform
    /// wait), and the zero-width `billing` marker; equals
    /// [`InvocationRecord::response`] exactly for record-built traces.
    pub fn stage_sum(&self) -> Duration {
        self.spans
            .iter()
            .filter(|s| !matches!(s.stage, Stage::Provision | Stage::Admission | Stage::Billing))
            .map(|s| s.dur)
            .sum()
    }

    pub fn span(&self, stage: Stage) -> Option<&Span> {
        self.spans.iter().find(|s| s.stage == stage)
    }

    /// Tail-based retention predicate: cold/restored starts, SLO
    /// violations, and errors (including queue expiries) are always
    /// kept; everything else is subject to `trace.sample_rate`.
    pub fn interesting(&self) -> bool {
        self.error.is_some() || self.start != StartKind::Warm || self.slo_violation
    }

    /// Primary classification label (display; filters check the
    /// individual flags via [`Trace::matches_kind`]).
    pub fn kind(&self) -> &'static str {
        if self.error.is_some() {
            "error"
        } else if self.start == StartKind::Cold {
            "cold"
        } else if self.start == StartKind::Restored {
            "restored"
        } else if self.slo_violation {
            "slow"
        } else {
            "steady"
        }
    }

    /// Query-filter match: a cold trace that also blew its SLO budget
    /// matches both `cold` and `slow`.
    pub fn matches_kind(&self, kind: &str) -> bool {
        match kind {
            "cold" => self.start == StartKind::Cold,
            "restored" => self.start == StartKind::Restored,
            "slow" => self.slo_violation,
            "error" => self.error.is_some(),
            _ => false,
        }
    }

    /// Approximate heap + inline footprint, the unit of the
    /// `trace_ring_bytes` gauge.
    pub fn approx_bytes(&self) -> usize {
        let strings = self.trace_id.len()
            + self.function.len()
            + self.error.as_ref().map_or(0, String::len)
            + self.shared_exec_with.as_ref().map_or(0, String::len)
            + self.spans.iter().map(|s| s.note.len()).sum::<usize>();
        std::mem::size_of::<Trace>() + self.spans.len() * std::mem::size_of::<Span>() + strings
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("trace_id", Json::Str(self.trace_id.clone())),
            ("function", Json::Str(self.function.clone())),
            ("start", Json::Str(self.start.to_string())),
            ("kind", Json::Str(self.kind().to_string())),
            ("started_at_s", Json::Num(self.started_at as f64 / 1e9)),
            ("response_s", Json::Num(self.response.as_secs_f64())),
            ("slo_target_ms", Json::Num(self.slo_target_ms as f64)),
            ("slo_violation", Json::Bool(self.slo_violation)),
            ("batch_size", Json::Num(self.batch_size as f64)),
            (
                "shared_exec_with",
                match &self.shared_exec_with {
                    Some(t) => Json::Str(t.clone()),
                    None => Json::Null,
                },
            ),
            (
                "error",
                match &self.error {
                    Some(e) => Json::Str(e.clone()),
                    None => Json::Null,
                },
            ),
            (
                "spans",
                Json::Arr(self.spans.iter().map(|s| s.to_json(self.started_at)).collect()),
            ),
        ])
    }

    /// One greppable JSON line per finished invocation
    /// (`trace.log_events`): trace id, function, start kind, and the
    /// per-stage duration breakdown.
    pub fn event_line(&self) -> String {
        let stages: Vec<(&str, Json)> = self
            .spans
            .iter()
            .filter(|s| s.stage != Stage::Provision)
            .map(|s| (s.stage.as_str(), Json::Num(s.dur.as_secs_f64())))
            .collect();
        obj(vec![
            ("event", Json::Str("invocation".to_string())),
            ("trace_id", Json::Str(self.trace_id.clone())),
            ("function", Json::Str(self.function.clone())),
            ("start", Json::Str(self.start.to_string())),
            ("kind", Json::Str(self.kind().to_string())),
            ("response_s", Json::Num(self.response.as_secs_f64())),
            ("batch_size", Json::Num(self.batch_size as f64)),
            (
                "error",
                match &self.error {
                    Some(e) => Json::Str(e.clone()),
                    None => Json::Null,
                },
            ),
            ("stages", obj(stages)),
        ])
        .to_string()
    }

    /// ASCII waterfall, one bar per span scaled to the trace's total
    /// extent (used by `examples/sla_analysis.rs`; the CLI renders
    /// the same shape from the route JSON).
    pub fn waterfall(&self) -> String {
        const WIDTH: f64 = 40.0;
        let total = self
            .spans
            .iter()
            .map(|s| s.start.saturating_sub(self.started_at) + s.dur.as_nanos() as Nanos)
            .max()
            .unwrap_or(1)
            .max(1) as f64;
        let mut out = format!(
            "{}  {}  {}  response {:.3}s{}{}\n",
            self.trace_id,
            self.function,
            self.kind(),
            self.response.as_secs_f64(),
            if self.slo_target_ms > 0 {
                format!(
                    "  slo {}ms {}",
                    self.slo_target_ms,
                    if self.slo_violation { "VIOLATED" } else { "ok" }
                )
            } else {
                String::new()
            },
            match &self.error {
                Some(e) => format!("  error: {e}"),
                None => String::new(),
            },
        );
        for s in &self.spans {
            let off = s.start.saturating_sub(self.started_at) as f64;
            let pad = ((off / total) * WIDTH).round() as usize;
            let bar = (((s.dur.as_nanos() as f64) / total) * WIDTH).round().max(
                if s.dur > Duration::ZERO { 1.0 } else { 0.0 },
            ) as usize;
            let indent = if s.stage.is_provision_child() { "    " } else { "  " };
            out.push_str(&format!(
                "{indent}{:<14} {}{} {:.3}s{}\n",
                s.stage.as_str(),
                " ".repeat(pad.min(WIDTH as usize)),
                "#".repeat(bar.min(WIDTH as usize + 1)),
                s.dur.as_secs_f64(),
                if s.note.is_empty() { String::new() } else { format!("  [{}]", s.note) },
            ));
        }
        out
    }
}

/// The completed-trace sink: a capacity-bounded exemplar ring with
/// tail-based sampling and O(1) gauges. One per [`super::Invoker`].
pub struct TraceSink {
    enabled: bool,
    log_events: bool,
    sample_rate: f64,
    ring_capacity: usize,
    /// Retained-exemplar ring, newest at the back. Ranked
    /// `trace.ring` in `PLATFORM_LOCK_ORDER`: taken standalone at
    /// invocation end, never held across a platform call.
    ring: Mutex<VecDeque<Trace>>,
    /// Sampling stream (rides the `platform.rng` rank); drawn and
    /// dropped before the ring is touched.
    rng: Mutex<SplitMix64>,
    seq: AtomicU64,
    retained: AtomicU64,
    sampled_out: AtomicU64,
    ring_bytes: AtomicU64,
}

impl TraceSink {
    pub fn new(config: &TraceConfig, seed: u64) -> Self {
        Self {
            enabled: config.enabled,
            log_events: config.log_events,
            sample_rate: config.sample_rate,
            ring_capacity: config.ring_capacity,
            ring: Mutex::new(VecDeque::new()),
            rng: Mutex::new(SplitMix64::new(seed)),
            seq: AtomicU64::new(1),
            retained: AtomicU64::new(0),
            sampled_out: AtomicU64::new(0),
            ring_bytes: AtomicU64::new(0),
        }
    }

    /// The bit-for-bit gate: plain bool, no lock.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Mint a trace id for a new invocation, or `None` when tracing
    /// is off — the single gate every instrumentation site checks.
    pub fn begin(&self) -> Option<String> {
        if !self.enabled {
            return None;
        }
        Some(format!("tr-{:08x}", self.seq.fetch_add(1, Ordering::Relaxed)))
    }

    /// Land a completed trace: log it (if `trace.log_events`), apply
    /// tail-based retention, and push survivors into the ring.
    pub fn finish(&self, trace: Trace) {
        if !self.enabled {
            return;
        }
        if self.log_events {
            println!("{}", trace.event_line());
        }
        // Interesting traces short-circuit the coin flip, so the rng
        // stream is consumed only by steady-state traffic.
        let keep = trace.interesting()
            || (self.sample_rate > 0.0 && plock(&self.rng).next_f64() < self.sample_rate);
        if !keep {
            self.sampled_out.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.retained.fetch_add(1, Ordering::Relaxed);
        if self.ring_capacity == 0 {
            return;
        }
        let bytes = trace.approx_bytes() as u64;
        let mut ring = plock(&self.ring);
        if ring.len() == self.ring_capacity {
            if let Some(old) = ring.pop_front() {
                self.ring_bytes.fetch_sub(old.approx_bytes() as u64, Ordering::Relaxed);
            }
        }
        self.ring_bytes.fetch_add(bytes, Ordering::Relaxed);
        ring.push_back(trace);
    }

    pub fn get(&self, trace_id: &str) -> Option<Trace> {
        plock(&self.ring).iter().find(|t| t.trace_id == trace_id).cloned()
    }

    /// Newest-first retained traces for one function, optionally
    /// filtered by kind (`cold` | `restored` | `slow` | `error`).
    pub fn recent(&self, function: &str, kind: Option<&str>, limit: usize) -> Vec<Trace> {
        plock(&self.ring)
            .iter()
            .rev()
            .filter(|t| t.function == function)
            .filter(|t| kind.map_or(true, |k| t.matches_kind(k)))
            .take(limit)
            .cloned()
            .collect()
    }

    /// Slowest retained traces across every function, by response.
    pub fn slowest(&self, limit: usize) -> Vec<Trace> {
        let mut all: Vec<Trace> = plock(&self.ring).iter().cloned().collect();
        all.sort_by(|a, b| b.response.cmp(&a.response));
        all.truncate(limit);
        all
    }

    /// Traces that passed retention (interesting or sampled in) —
    /// counts survivors even after ring eviction.
    pub fn retained(&self) -> u64 {
        self.retained.load(Ordering::Relaxed)
    }

    /// Steady-state traces dropped by the sampling coin flip.
    pub fn sampled_out(&self) -> u64 {
        self.sampled_out.load(Ordering::Relaxed)
    }

    /// Approximate bytes currently held by the exemplar ring.
    pub fn ring_bytes(&self) -> u64 {
        self.ring_bytes.load(Ordering::Relaxed)
    }

    pub fn ring_len(&self) -> usize {
        plock(&self.ring).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(start: StartKind) -> InvocationRecord {
        InvocationRecord {
            function: "fn".to_string(),
            memory_mb: 1024,
            start,
            queue: Duration::from_millis(5),
            sandbox: if start != StartKind::Warm { Duration::from_millis(50) } else { Duration::ZERO },
            runtime_init: if start == StartKind::Cold { Duration::from_millis(120) } else { Duration::ZERO },
            package_fetch: if start == StartKind::Cold { Duration::from_millis(300) } else { Duration::ZERO },
            model_load: if start == StartKind::Cold { Duration::from_millis(800) } else { Duration::ZERO },
            restore: if start == StartKind::Restored { Duration::from_millis(90) } else { Duration::ZERO },
            predict: Duration::from_millis(40),
            predict_full_speed: Duration::from_millis(40),
            batch_size: 1,
            batch_wait: Duration::ZERO,
            kernel_batch_n: 1,
            batch_kernel_hits: 0,
            batch_kernel_misses: 0,
            billed: Duration::from_millis(40),
            billed_ms: 40,
            cost_dollars: 1e-6,
            top1: 3,
            trace_id: None,
        }
    }

    fn sink(enabled: bool, capacity: usize, rate: f64) -> TraceSink {
        let cfg = TraceConfig {
            enabled,
            ring_capacity: capacity,
            sample_rate: rate,
            log_events: false,
        };
        TraceSink::new(&cfg, 42)
    }

    #[test]
    fn stage_sum_matches_response_for_every_start_kind() {
        for start in [StartKind::Cold, StartKind::Warm, StartKind::Restored] {
            let r = record(start);
            let t = Trace::from_record("tr-1", &r, 1_000, None, 0, None);
            assert_eq!(t.stage_sum(), r.response(), "start={start}");
        }
    }

    #[test]
    fn provision_children_equal_record_components() {
        let r = record(StartKind::Cold);
        let t = Trace::from_record("tr-1", &r, 0, None, 0, None);
        assert_eq!(t.span(Stage::Sandbox).unwrap().dur, r.sandbox);
        assert_eq!(t.span(Stage::RuntimeInit).unwrap().dur, r.runtime_init);
        assert_eq!(t.span(Stage::PackageFetch).unwrap().dur, r.package_fetch);
        assert_eq!(t.span(Stage::ModelLoad).unwrap().dur, r.model_load);
        assert_eq!(t.span(Stage::Restore).unwrap().dur, r.restore);
        assert_eq!(t.span(Stage::Provision).unwrap().dur, r.cold_overhead());
        // The parent is the exact sum of its children.
        let children: Duration = t
            .spans
            .iter()
            .filter(|s| s.stage.is_provision_child())
            .map(|s| s.dur)
            .sum();
        assert_eq!(children, t.span(Stage::Provision).unwrap().dur);
    }

    #[test]
    fn warm_record_has_no_provision_spans_and_async_context_sets_admission() {
        let r = record(StartKind::Warm);
        let t = Trace::from_record("tr-1", &r, 7_000_000, Some(2_000_000), 0, None);
        assert!(t.span(Stage::Provision).is_none());
        assert!(t.span(Stage::Sandbox).is_none());
        let adm = t.span(Stage::Admission).unwrap();
        assert_eq!(adm.dur, Duration::from_nanos(5_000_000));
        assert_eq!(t.started_at, 2_000_000);
        // Pre-platform wait stays out of the response identity.
        assert_eq!(t.stage_sum(), r.response());
    }

    #[test]
    fn batched_record_gets_collect_span_and_follower_is_annotated() {
        let mut r = record(StartKind::Warm);
        r.batch_size = 4;
        r.batch_wait = Duration::from_millis(12);
        let t = Trace::from_record("tr-9", &r, 0, None, 0, Some("tr-2".to_string()));
        assert_eq!(t.span(Stage::BatchCollect).unwrap().dur, r.batch_wait);
        assert_eq!(t.stage_sum(), r.response());
        assert_eq!(t.shared_exec_with.as_deref(), Some("tr-2"));
        assert!(t.span(Stage::KernelExec).unwrap().note.contains("shared_with=tr-2"));
    }

    #[test]
    fn slo_violation_and_kind_classification() {
        let r = record(StartKind::Warm); // response = 45 ms
        let fast = Trace::from_record("tr-1", &r, 0, None, 100, None);
        assert!(!fast.slo_violation);
        assert_eq!(fast.kind(), "steady");
        assert!(!fast.interesting());
        let slow = Trace::from_record("tr-2", &r, 0, None, 10, None);
        assert!(slow.slo_violation);
        assert_eq!(slow.kind(), "slow");
        assert!(slow.interesting() && slow.matches_kind("slow"));
        let cold = Trace::from_record("tr-3", &record(StartKind::Cold), 0, None, 10, None);
        assert_eq!(cold.kind(), "cold");
        // A cold trace over budget matches BOTH filters.
        assert!(cold.matches_kind("cold") && cold.matches_kind("slow"));
        let refused = Trace::refused("tr-4", "fn", 0, None, Duration::from_secs(1), "full".into());
        assert_eq!(refused.kind(), "error");
        assert!(refused.interesting() && refused.matches_kind("error"));
    }

    #[test]
    fn disabled_sink_mints_no_ids_and_never_touches_the_ring() {
        let s = sink(false, 16, 1.0);
        assert!(s.begin().is_none());
        s.finish(Trace::from_record("tr-1", &record(StartKind::Cold), 0, None, 0, None));
        assert_eq!(s.retained(), 0);
        assert_eq!(s.sampled_out(), 0);
        assert_eq!(s.ring_len(), 0);
        assert_eq!(s.ring_bytes(), 0);
    }

    #[test]
    fn interesting_always_retained_steady_sampled() {
        let s = sink(true, 64, 0.0);
        for i in 0..10 {
            let kind = if i % 2 == 0 { StartKind::Cold } else { StartKind::Warm };
            s.finish(Trace::from_record(&format!("tr-{i}"), &record(kind), 0, None, 0, None));
        }
        // rate 0: every warm/steady trace dropped, every cold kept.
        assert_eq!(s.retained(), 5);
        assert_eq!(s.sampled_out(), 5);
        let s = sink(true, 64, 1.0);
        for i in 0..10 {
            s.finish(Trace::from_record(&format!("tr-{i}"), &record(StartKind::Warm), 0, None, 0, None));
        }
        assert_eq!(s.retained(), 10);
        assert_eq!(s.sampled_out(), 0);
    }

    #[test]
    fn fractional_sampling_is_seeded_and_partial() {
        let run = || {
            let s = sink(true, 1024, 0.5);
            for i in 0..200 {
                s.finish(Trace::from_record(
                    &format!("tr-{i}"),
                    &record(StartKind::Warm),
                    0,
                    None,
                    0,
                    None,
                ));
            }
            (s.retained(), s.sampled_out())
        };
        let (kept, dropped) = run();
        assert_eq!(kept + dropped, 200);
        assert!(kept > 0 && dropped > 0, "rate 0.5 must split the stream ({kept}/{dropped})");
        // Same seed, same stream, same decisions.
        assert_eq!(run(), (kept, dropped));
    }

    #[test]
    fn ring_bounds_capacity_and_byte_gauge_tracks_contents() {
        let s = sink(true, 4, 0.0);
        for i in 0..10 {
            s.finish(Trace::from_record(&format!("tr-{i}"), &record(StartKind::Cold), 0, None, 0, None));
        }
        assert_eq!(s.ring_len(), 4);
        assert_eq!(s.retained(), 10);
        let expected: u64 = plock(&s.ring).iter().map(|t| t.approx_bytes() as u64).sum();
        assert_eq!(s.ring_bytes(), expected);
        // Eviction kept the NEWEST four.
        assert!(s.get("tr-9").is_some() && s.get("tr-5").is_none());
    }

    #[test]
    fn recent_filters_by_function_and_kind_newest_first() {
        let s = sink(true, 64, 1.0);
        s.finish(Trace::from_record("tr-1", &record(StartKind::Cold), 0, None, 0, None));
        s.finish(Trace::from_record("tr-2", &record(StartKind::Warm), 0, None, 0, None));
        let mut other = record(StartKind::Cold);
        other.function = "other".to_string();
        s.finish(Trace::from_record("tr-3", &other, 0, None, 0, None));
        s.finish(Trace::refused("tr-4", "fn", 0, None, Duration::from_secs(1), "expired".into()));
        let all = s.recent("fn", None, 10);
        assert_eq!(
            all.iter().map(|t| t.trace_id.as_str()).collect::<Vec<_>>(),
            ["tr-4", "tr-2", "tr-1"]
        );
        assert_eq!(s.recent("fn", Some("cold"), 10).len(), 1);
        assert_eq!(s.recent("fn", Some("error"), 10)[0].trace_id, "tr-4");
        assert_eq!(s.recent("fn", None, 1).len(), 1);
        assert_eq!(s.slowest(1)[0].trace_id, "tr-4");
    }

    #[test]
    fn event_line_and_trace_json_round_trip() {
        let r = record(StartKind::Cold);
        let t = Trace::from_record("tr-1", &r, 0, None, 1000, None);
        let line = Json::parse(&t.event_line()).expect("event line parses");
        assert_eq!(line.get("trace_id").and_then(Json::as_str), Some("tr-1"));
        assert_eq!(line.get("start").and_then(Json::as_str), Some("cold"));
        let stages = line.get("stages").expect("stages");
        assert!(stages.get("kernel_exec").is_some());
        assert!(stages.get("model_load").is_some());
        let json = Json::parse(&t.to_json().to_string()).expect("trace json parses");
        let spans = json.get("spans").and_then(Json::as_arr).expect("spans");
        assert_eq!(spans.len(), t.spans.len());
        assert_eq!(
            spans[2].get("parent").and_then(Json::as_str),
            None,
            "provision parent row has no parent"
        );
        assert_eq!(spans[3].get("parent").and_then(Json::as_str), Some("provision"));
        // The waterfall renders one row per span.
        assert_eq!(t.waterfall().lines().count(), 1 + t.spans.len());
    }
}
