//! Async (fire-and-forget) invocation: bounded queue + worker threads
//! + TTL'd result store.
//!
//! `POST /v2/functions/:name/invocations?mode=async` enqueues a job
//! and returns `202` with an invocation id; workers drain the queue
//! through the normal [`Platform::invoke`] pipeline (so cold/warm
//! accounting, billing, and metrics are identical to sync calls); the
//! outcome is kept in a result store for `result_ttl` after completion
//! and served by `GET /v2/invocations/:id`.
//!
//! Backpressure: a full queue rejects the submit (HTTP 429). A job
//! the API already accepted with 202 is NOT failed on a transient
//! capacity shortage: the worker's `invoke` itself parks in the
//! platform's admission queue (the same waitable dispatch path the
//! sync route uses), and when an attempt still comes back throttled
//! (per-function cap) or saturated (dispatch deadline exhausted) the
//! worker parks on the pool's capacity condvar until something frees
//! and requeues the job — no blind fixed-interval backoff polling.
//! The retry budget counts *admission attempts* (each worth a full
//! dispatch deadline of waiting); a job that exhausts it surfaces a
//! terminal `failed` status rather than vanishing. Shutdown drops
//! queued jobs (fire-and-forget semantics) but joins workers
//! mid-invocation.

use super::invoker::{InvokeError, InvokeOutcome, Platform, SaturationKind};
use super::metrics::InvocationRecord;
use crate::runtime::Prediction;
use crate::util::clock::Nanos;
use crate::util::{plock, pwait_timeout};
use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AsyncStatus {
    Queued,
    Running,
    Done,
    Failed,
}

impl AsyncStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            AsyncStatus::Queued => "queued",
            AsyncStatus::Running => "running",
            AsyncStatus::Done => "done",
            AsyncStatus::Failed => "failed",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, AsyncStatus::Done | AsyncStatus::Failed)
    }
}

/// Snapshot of one async invocation's lifecycle.
#[derive(Debug, Clone)]
pub struct AsyncInvocation {
    pub id: String,
    pub function: String,
    pub status: AsyncStatus,
    pub record: Option<InvocationRecord>,
    pub prediction: Option<Prediction>,
    pub error: Option<String>,
    pub submitted_at: Nanos,
    pub finished_at: Option<Nanos>,
}

#[derive(Debug)]
pub enum SubmitError {
    /// The bounded queue is at capacity (HTTP 429).
    QueueFull { capacity: usize },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull { capacity } => {
                write!(f, "async queue full ({capacity} pending invocations)")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Admission attempts per accepted job before it is failed for real.
/// Each attempt waits up to the function's effective dispatch
/// deadline — inside `Platform::invoke` when it parks, or on the
/// capacity condvar before the requeue when the refusal was instant
/// (cap hit, queue full) — so 30 attempts bound a job's life at
/// roughly `2 x 30 x queue_deadline`: a minute at the 2 s default,
/// in line with the old ~60 s cumulative-backoff budget.
const MAX_ADMISSION_ATTEMPTS: u32 = 30;

/// Cap on one idle worker park: jobs arrive with a notify, so this
/// only bounds how long a lost wakeup (submitter crashing between
/// enqueue and notify) can delay pickup or shutdown.
const WORKER_PARK_SLICE: Duration = Duration::from_millis(100);

struct Job {
    id: String,
    function: String,
    seed: u64,
    attempts: u32,
    /// Original submit time, preserved across requeues: the trace's
    /// `admission` span stretches from here to the platform arrival,
    /// so queue-crossing (and every retry) shows up as admission wait.
    submitted_at: Nanos,
}

struct Shared {
    platform: Arc<Platform>,
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    results: Mutex<BTreeMap<String, AsyncInvocation>>,
    capacity: usize,
    ttl_ns: u64,
    shutdown: AtomicBool,
}

impl Shared {
    /// Drop finished entries older than the TTL. Unfinished entries
    /// are always kept (a queued job must stay pollable).
    fn purge(&self) {
        let now = self.platform.clock().now();
        let ttl = self.ttl_ns;
        plock(&self.results).retain(|_, entry| match entry.finished_at {
            Some(done) => now.saturating_sub(done) <= ttl,
            None => true,
        });
    }
}

pub struct AsyncInvoker {
    shared: Arc<Shared>,
    seq: AtomicU64,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl AsyncInvoker {
    pub fn start(
        platform: Arc<Platform>,
        workers: usize,
        capacity: usize,
        result_ttl: Duration,
    ) -> Self {
        let shared = Arc::new(Shared {
            platform,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            results: Mutex::new(BTreeMap::new()),
            capacity: capacity.max(1),
            ttl_ns: result_ttl.as_nanos() as u64,
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("async-invoke-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn async-invoke worker")
            })
            .collect();
        Self { shared, seq: AtomicU64::new(1), workers: Mutex::new(handles) }
    }

    /// Enqueue an invocation; returns its id, or an error when the
    /// queue is full. The function's existence is NOT checked here —
    /// an unknown function surfaces as a `failed` result, exactly as a
    /// queued job for a just-undeployed function would.
    pub fn submit(&self, function: &str, seed: u64) -> Result<String, SubmitError> {
        let now = self.shared.platform.clock().now();
        let id = format!("inv-{:08x}", self.seq.fetch_add(1, Ordering::Relaxed));
        {
            let mut queue = plock(&self.shared.queue);
            if queue.len() >= self.shared.capacity {
                return Err(SubmitError::QueueFull { capacity: self.shared.capacity });
            }
            queue.push_back(Job {
                id: id.clone(),
                function: function.to_string(),
                seed,
                attempts: 0,
                submitted_at: now,
            });
            plock(&self.shared.results).insert(
                id.clone(),
                AsyncInvocation {
                    id: id.clone(),
                    function: function.to_string(),
                    status: AsyncStatus::Queued,
                    record: None,
                    prediction: None,
                    error: None,
                    submitted_at: now,
                    finished_at: None,
                },
            );
        }
        self.shared.cv.notify_one();
        Ok(id)
    }

    /// Snapshot of one invocation; `None` when unknown or expired.
    pub fn get(&self, id: &str) -> Option<AsyncInvocation> {
        self.shared.purge();
        plock(&self.shared.results).get(id).cloned()
    }

    /// Jobs waiting in the queue (not yet picked up by a worker).
    pub fn queued(&self) -> usize {
        plock(&self.shared.queue).len()
    }

    /// Entries currently in the result store (any status).
    pub fn stored(&self) -> usize {
        plock(&self.shared.results).len()
    }

    /// Force a TTL sweep (the store also self-purges on access).
    pub fn purge_expired(&self) {
        self.shared.purge();
    }
}

impl Drop for AsyncInvoker {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        // Drain under the lock, join outside it: a worker mid-job must
        // not find the handle list locked while we wait on a sibling.
        let workers: Vec<_> = plock(&self.workers).drain(..).collect();
        for handle in workers {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &Arc<Shared>) {
    loop {
        let mut batch = {
            let mut queue = plock(&shared.queue);
            let job = loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(job) = queue.pop_front() {
                    break job;
                }
                // Bounded park, never a naked wait: shutdown and new
                // work are re-checked every slice, so a notify racing
                // a worker crash can only delay a job by one slice.
                queue = pwait_timeout(&shared.cv, queue, WORKER_PARK_SLICE).0;
            };
            let mut batch = vec![job];
            // Pre-formed batching: while still under the queue lock,
            // drain CONSECUTIVE same-function jobs (up to the
            // function's effective max batch size) into one run —
            // these members are already here, so the whole run becomes
            // ONE batched pass via `invoke_preformed` with no
            // collection window to wait out. Batching off (the
            // default) leaves the one-job-per-dequeue path untouched.
            if let Ok(spec) = shared.platform.registry.get(&batch[0].function) {
                let cap = shared.platform.batcher.effective_max_batch(&spec);
                while batch.len() < cap
                    && queue.front().is_some_and(|next| next.function == batch[0].function)
                {
                    batch.push(queue.pop_front().expect("front checked"));
                }
            }
            batch
        };
        for job in &batch {
            if let Some(entry) = plock(&shared.results).get_mut(&job.id) {
                entry.status = AsyncStatus::Running;
            }
        }
        // The invoke rides the shared admission path either way: a
        // capacity miss parks in the dispatcher's bounded per-function
        // queue until a container frees or the deadline passes.
        let settled: Vec<(Job, Result<InvokeOutcome, InvokeError>)> = if batch.len() >= 2 {
            let function = batch[0].function.clone();
            let seeds: Vec<u64> = batch.iter().map(|j| j.seed).collect();
            let origins: Vec<Nanos> = batch.iter().map(|j| j.submitted_at).collect();
            let outcomes =
                shared.platform.invoke_preformed_from(&function, &seeds, Some(&origins));
            batch.into_iter().zip(outcomes).collect()
        } else {
            let job = batch.pop().expect("dequeued one job");
            let outcome =
                shared.platform.invoke_from(&job.function, job.seed, Some(job.submitted_at));
            vec![(job, outcome)]
        };
        let mut parked_this_round = false;
        for (job, outcome) in settled {
            // Transient shortage: the caller already got a 202, so an
            // attempt that came back throttled (per-function cap) or
            // saturated (deadline exhausted / queue full) is retried
            // rather than failed — until the attempt budget runs out.
            let transient = matches!(
                outcome,
                Err(InvokeError::Throttled) | Err(InvokeError::Saturated(_))
            );
            if transient && job.attempts + 1 < MAX_ADMISSION_ATTEMPTS {
                if let Some(entry) = plock(&shared.results).get_mut(&job.id) {
                    entry.status = AsyncStatus::Queued;
                }
                // Park on the function's pool-shard condvar — the same
                // waitable primitive the dispatcher uses — until
                // something of THIS function's frees (a released
                // container, a finished in-flight request) or one
                // dispatch deadline passes, UNLESS the attempt already
                // waited a nonzero dispatch deadline inside invoke.
                // Throttled (cap precedes admission) and queue-full
                // refusals return instantly, and so does a
                // DeadlineExpired under try-once (deadline 0)
                // semantics — without the park any of them would burn
                // the whole attempt budget in a hot spin. One park per
                // settled batch: the wakeup that ends it speaks for
                // every transient member of the same run.
                let effective_deadline = match shared.platform.registry.get(&job.function) {
                    Ok(spec) => shared.platform.dispatcher.effective_deadline(&spec),
                    Err(_) => shared.platform.dispatcher.default_deadline(),
                };
                let waited_inside = matches!(
                    outcome,
                    Err(InvokeError::Saturated(SaturationKind::DeadlineExpired))
                ) && !effective_deadline.is_zero();
                if !waited_inside && !parked_this_round {
                    parked_this_round = true;
                    // Floor the park so a zero-deadline config cannot
                    // turn contention into a hot requeue spin.
                    let park = effective_deadline.max(Duration::from_millis(10));
                    let deadline = shared.platform.clock().now() + park.as_nanos() as u64;
                    shared.platform.pool.wait_for_change(&job.function, deadline);
                }
                {
                    let mut queue = plock(&shared.queue);
                    queue.push_back(Job { attempts: job.attempts + 1, ..job });
                }
                shared.cv.notify_one();
                continue;
            }
            let now = shared.platform.clock().now();
            let mut results = plock(&shared.results);
            if let Some(entry) = results.get_mut(&job.id) {
                entry.finished_at = Some(now);
                match outcome {
                    Ok(out) => {
                        entry.status = AsyncStatus::Done;
                        entry.record = Some(out.record);
                        entry.prediction = Some(out.prediction);
                    }
                    Err(InvokeError::NotFound(name)) => {
                        entry.status = AsyncStatus::Failed;
                        entry.error = Some(format!("function not found: {name}"));
                    }
                    Err(e) if transient => {
                        entry.status = AsyncStatus::Failed;
                        entry.error = Some(format!(
                            "admission retry budget exhausted after {} attempts: {e}",
                            job.attempts + 1
                        ));
                    }
                    Err(e) => {
                        entry.status = AsyncStatus::Failed;
                        entry.error = Some(e.to_string());
                    }
                }
            }
        }
        shared.purge();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configparse::PlatformConfig;
    use crate::platform::{Invoker, StartKind};
    use crate::runtime::{MockEngine, MockModelCosts};
    use std::time::Instant;

    fn live_platform() -> Arc<Platform> {
        let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
            "squeezenet",
            2,
            5.0,
            85,
        )]));
        let config = PlatformConfig {
            bootstrap: crate::configparse::BootstrapConfig {
                simulate_delays: false,
                ..Default::default()
            },
            ..Default::default()
        };
        Arc::new(Invoker::live(config, engine))
    }

    fn wait_terminal(inv: &AsyncInvoker, id: &str) -> AsyncInvocation {
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            if let Some(s) = inv.get(id) {
                if s.status.is_terminal() {
                    return s;
                }
            }
            assert!(Instant::now() < deadline, "invocation {id} never finished");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn submit_executes_and_stores_result() {
        let p = live_platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        let inv = AsyncInvoker::start(p.clone(), 2, 16, Duration::from_secs(600));
        let id = inv.submit("sq", 7).unwrap();
        assert!(id.starts_with("inv-"));
        let done = wait_terminal(&inv, &id);
        assert_eq!(done.status, AsyncStatus::Done);
        let record = done.record.expect("record present");
        assert_eq!(record.start, StartKind::Cold);
        assert!(record.billed_ms > 0);
        assert!(done.prediction.is_some());
        assert!(done.finished_at.unwrap() >= done.submitted_at);
        // Platform-side accounting went through the normal pipeline.
        assert_eq!(p.metrics.len(), 1);
    }

    #[test]
    fn unknown_function_fails_the_job() {
        let p = live_platform();
        let inv = AsyncInvoker::start(p, 1, 16, Duration::from_secs(600));
        let id = inv.submit("ghost", 1).unwrap();
        let done = wait_terminal(&inv, &id);
        assert_eq!(done.status, AsyncStatus::Failed);
        assert!(done.error.unwrap().contains("not found"));
    }

    #[test]
    fn queue_capacity_rejects_submit() {
        let p = live_platform();
        // No workers draining quickly enough to matter: capacity 2 and
        // a platform with a deployed fn; fill the queue before workers
        // start by using capacity that the submit loop can outrun is
        // racy, so instead use an undeployed fn: jobs still drain, but
        // we only assert the immediate-full case by submitting with a
        // single worker blocked on a first slow job.
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        let inv = AsyncInvoker::start(p, 1, 1, Duration::from_secs(600));
        // Saturate: at most 1 queued at a time; keep submitting until
        // one lands while the previous is still queued, then expect
        // QueueFull on the immediate next submit.
        let mut saw_full = false;
        for i in 0..200 {
            match inv.submit("sq", i) {
                Ok(_) => {}
                Err(SubmitError::QueueFull { capacity }) => {
                    assert_eq!(capacity, 1);
                    saw_full = true;
                    break;
                }
            }
        }
        assert!(saw_full, "queue never reported full");
    }

    #[test]
    fn throttled_jobs_requeue_until_capacity_frees() {
        let p = live_platform();
        // Per-function cap of 1 with 4 workers: concurrent dequeues
        // hit the cap constantly, but every accepted job must still
        // complete via backoff + requeue.
        p.deploy_full(
            "sq",
            "squeezenet",
            "pallas",
            1024,
            crate::platform::FunctionPolicy { max_concurrency: Some(1), ..Default::default() },
        )
        .unwrap();
        let inv = AsyncInvoker::start(p, 4, 64, Duration::from_secs(600));
        let ids: Vec<String> = (0..6).map(|i| inv.submit("sq", i).unwrap()).collect();
        for id in &ids {
            let done = wait_terminal(&inv, id);
            assert_eq!(done.status, AsyncStatus::Done, "{:?}", done.error);
        }
    }

    /// Satellite regression (ManualClock): workers hitting account-cap
    /// exhaustion must complete once capacity frees. The worker's
    /// invoke parks in the admission queue; the release of the held
    /// container notifies the pool condvar and the parked worker
    /// serves the job — no wall-clock backoff involved.
    #[test]
    fn account_cap_exhaustion_completes_once_capacity_frees() {
        use crate::configparse::BootstrapConfig;
        use crate::util::ManualClock;
        let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
            "squeezenet",
            2,
            5.0,
            85,
        )]));
        let clock = ManualClock::new();
        let config = PlatformConfig {
            max_containers: 1,
            bootstrap: BootstrapConfig { simulate_delays: false, ..Default::default() },
            ..Default::default()
        };
        let p = Arc::new(Invoker::new(config, engine, clock.clone()));
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 0).unwrap();
        // Account cap (1) exhausted: the only container is held busy.
        let held = p.pool.acquire("sq").unwrap();
        let inv = AsyncInvoker::start(p.clone(), 2, 16, Duration::from_secs(600));
        let id = inv.submit("sq", 1).unwrap();
        // Let the worker pick the job up and park against the cap,
        // then free the capacity.
        std::thread::sleep(Duration::from_millis(30));
        p.pool.release(held);
        let done = wait_terminal(&inv, &id);
        assert_eq!(done.status, AsyncStatus::Done, "{:?}", done.error);
        assert_eq!(done.record.expect("record").start, StartKind::Warm);
    }

    /// Satellite regression: a job whose admission-retry budget runs
    /// out must surface a terminal `failed` status — not vanish, not
    /// sit `queued` forever.
    #[test]
    fn retry_budget_exhaustion_is_terminal_failed_status() {
        use crate::configparse::BootstrapConfig;
        use crate::util::ManualClock;
        let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
            "squeezenet",
            2,
            5.0,
            85,
        )]));
        let clock = ManualClock::new();
        let config = PlatformConfig {
            max_containers: 1,
            // Short (virtual) dispatch deadline so the 30 attempts
            // burn down in milliseconds of wall time.
            queue_deadline_ms: 40,
            bootstrap: BootstrapConfig { simulate_delays: false, ..Default::default() },
            ..Default::default()
        };
        let p = Arc::new(Invoker::new(config, engine, clock.clone()));
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        p.invoke("sq", 0).unwrap();
        // Capacity permanently exhausted: never released.
        let _held = p.pool.acquire("sq").unwrap();
        let inv = AsyncInvoker::start(p.clone(), 1, 16, Duration::from_secs(600));
        let id = inv.submit("sq", 1).unwrap();
        let done = wait_terminal(&inv, &id);
        assert_eq!(done.status, AsyncStatus::Failed);
        let err = done.error.expect("terminal error recorded");
        assert!(err.contains("retry budget"), "{err}");
        assert_eq!(inv.queued(), 0, "the job left the queue");
        assert!(done.finished_at.is_some());
    }

    #[test]
    fn results_expire_after_ttl() {
        let p = live_platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        let inv = AsyncInvoker::start(p, 1, 16, Duration::from_millis(20));
        let id = inv.submit("sq", 1).unwrap();
        wait_terminal(&inv, &id);
        // Live SystemClock: wait past the TTL, then the entry is gone.
        std::thread::sleep(Duration::from_millis(40));
        assert!(inv.get(&id).is_none(), "entry should have expired");
        assert_eq!(inv.stored(), 0);
    }

    #[test]
    fn ids_are_unique_and_results_isolated() {
        let p = live_platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        let inv = AsyncInvoker::start(p, 4, 64, Duration::from_secs(600));
        let ids: Vec<String> = (0..10).map(|i| inv.submit("sq", i).unwrap()).collect();
        let unique: std::collections::BTreeSet<_> = ids.iter().collect();
        assert_eq!(unique.len(), ids.len());
        for id in &ids {
            let done = wait_terminal(&inv, id);
            assert_eq!(done.status, AsyncStatus::Done);
            assert_eq!(done.id, *id);
        }
    }

    #[test]
    fn shutdown_joins_workers() {
        let p = live_platform();
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        let inv = AsyncInvoker::start(p, 2, 16, Duration::from_secs(600));
        inv.submit("sq", 1).unwrap();
        drop(inv); // must not hang
    }
}
