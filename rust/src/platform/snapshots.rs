//! Snapshot/checkpoint-restore cold-start mitigation.
//!
//! The paper's cold start is dominated by runtime init + model compile
//! + weight materialization; the serverless-inference literature's
//! state-of-the-art answer is checkpoint/restore: capture a warmed
//! instance ONCE, then provision future cold starts from the snapshot
//! so they pay `sandbox + restore I/O` instead of the whole trio.
//!
//! [`SnapshotStore`] owns the snapshot artifacts — one per
//! model + variant + memory class ([`SnapshotKey`]), bounded by
//! `snapshot.capacity_bytes` with LRU eviction — and the provisioning
//! policy around them: [`SnapshotStore::provision`] is the single
//! provision entry point the demand cold path (scaler) and the
//! prewarm/maintainer path go through. A store hit restores; a failed
//! restore falls back to the full cold path (never an error surfaced
//! to the request); a full cold provision schedules a capture per the
//! configured [`CapturePolicy`] so the NEXT cold start for this shape
//! can restore. With `snapshot.enabled = false` (the default) and no
//! per-function override, `provision` is exactly
//! [`Container::provision`] — same RNG draws, same costs, bit-for-bit.

use super::container::Container;
use super::registry::FunctionSpec;
use super::throttle::CpuGovernor;
use crate::configparse::{BootstrapConfig, CapturePolicy, MemorySize, SnapshotConfig};
use crate::runtime::{Engine, InstanceHandle, SnapshotBlob};
use crate::util::{plock, Clock, SplitMix64};
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Identity of one snapshot artifact: the model + artifact variant +
/// memory class a restored container embodies — the same tuple a warm
/// container is matched on, minus the function name, so functions
/// sharing a deployment shape share snapshots.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct SnapshotKey {
    pub model: String,
    pub variant: String,
    pub memory_mb: MemorySize,
}

impl SnapshotKey {
    /// The snapshot shape `spec`'s containers embody.
    pub fn of(spec: &FunctionSpec) -> Self {
        Self {
            model: spec.model.clone(),
            variant: spec.variant.clone(),
            memory_mb: spec.memory_mb,
        }
    }
}

/// Consecutive restore failures after which a stored snapshot is
/// dropped: failures are usually transient (the blob survives them),
/// but a shape whose restores fail persistently must not pay a doomed
/// restore attempt on every cold start forever — dropping the entry
/// lets the next full cold provision re-capture fresh state.
const MAX_RESTORE_FAILURES: u32 = 3;

struct Entry {
    blob: Arc<SnapshotBlob>,
    /// Wall time the capture cost (observability only: captures run
    /// off the request path, so no request waits this).
    capture_cost: Duration,
    /// LRU clock of the last hit (or the insert).
    last_used: u64,
    /// Consecutive restore failures against this entry (reset by a
    /// successful restore; the entry is dropped at
    /// [`MAX_RESTORE_FAILURES`]).
    failures: u32,
}

#[derive(Default)]
struct StoreInner {
    entries: BTreeMap<SnapshotKey, Entry>,
    /// Sum of stored blob sizes (the capacity accounting).
    bytes: u64,
    /// Monotonic LRU clock, bumped per lookup/insert.
    tick: u64,
    /// Keys with a capture currently running (background dedupe).
    in_flight: BTreeSet<SnapshotKey>,
    /// Per-shape invalidation generations, bumped by
    /// [`SnapshotStore::invalidate`]: a background capture that began
    /// before its shape's redeploy/undeploy cannot land its (now
    /// obsolete) blob afterwards — without fencing unrelated shapes'
    /// captures. Absent key = generation 0.
    invalidations: BTreeMap<SnapshotKey, u64>,
}

impl StoreInner {
    fn generation_of(&self, key: &SnapshotKey) -> u64 {
        self.invalidations.get(key).copied().unwrap_or(0)
    }
}

/// See the module docs. Counters are monotonic except `bytes`, which
/// is the live gauge of stored snapshot bytes.
pub struct SnapshotStore {
    config: SnapshotConfig,
    inner: Mutex<StoreInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    captures: AtomicU64,
    evictions: AtomicU64,
    stale: AtomicU64,
    restore_failures: AtomicU64,
}

impl SnapshotStore {
    pub fn new(config: SnapshotConfig) -> Self {
        Self {
            config,
            inner: Mutex::new(StoreInner::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            captures: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            stale: AtomicU64::new(0),
            restore_failures: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &SnapshotConfig {
        &self.config
    }

    /// Whether snapshot/restore applies to `spec`: its own `snapshot`
    /// override when set, else the platform-wide `snapshot.enabled`.
    pub fn enabled_for(&self, spec: &FunctionSpec) -> bool {
        spec.snapshot.unwrap_or(self.config.enabled)
    }

    /// Look up a restorable snapshot, counting hit/miss and touching
    /// the LRU clock.
    pub fn lookup(&self, key: &SnapshotKey) -> Option<Arc<SnapshotBlob>> {
        let mut g = plock(&self.inner);
        g.tick += 1;
        let tick = g.tick;
        match g.entries.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::SeqCst);
                Some(e.blob.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::SeqCst);
                None
            }
        }
    }

    /// Store a captured snapshot under `key`, evicting
    /// least-recently-used entries until it fits; replaces any
    /// existing entry for the key. Returns `false` (and stores
    /// nothing) when the blob alone exceeds the whole capacity.
    pub fn insert(&self, key: SnapshotKey, blob: SnapshotBlob, capture_cost: Duration) -> bool {
        let mut g = plock(&self.inner);
        self.insert_locked(&mut g, key, blob, capture_cost)
    }

    /// [`Self::insert`], but only when no invalidation of THIS shape
    /// has landed since the capture began: a background capture racing
    /// a redeploy or undeploy must not resurrect a checkpoint the
    /// lifecycle event just obsoleted (unrelated shapes' lifecycle
    /// events don't fence it).
    fn insert_captured(
        &self,
        key: SnapshotKey,
        blob: SnapshotBlob,
        capture_cost: Duration,
        began_at_generation: u64,
    ) -> bool {
        let mut g = plock(&self.inner);
        if g.generation_of(&key) != began_at_generation {
            return false;
        }
        self.insert_locked(&mut g, key, blob, capture_cost)
    }

    fn insert_locked(
        &self,
        g: &mut StoreInner,
        key: SnapshotKey,
        blob: SnapshotBlob,
        capture_cost: Duration,
    ) -> bool {
        if blob.size_bytes > self.config.capacity_bytes {
            return false;
        }
        if let Some(old) = g.entries.remove(&key) {
            g.bytes -= old.blob.size_bytes;
        }
        while g.bytes + blob.size_bytes > self.config.capacity_bytes {
            let victim =
                g.entries.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| k.clone());
            let Some(victim) = victim else { break };
            if let Some(e) = g.entries.remove(&victim) {
                g.bytes -= e.blob.size_bytes;
                self.evictions.fetch_add(1, Ordering::SeqCst);
            }
        }
        g.tick += 1;
        let tick = g.tick;
        g.bytes += blob.size_bytes;
        g.entries.insert(
            key,
            Entry { blob: Arc::new(blob), capture_cost, last_used: tick, failures: 0 },
        );
        self.captures.fetch_add(1, Ordering::SeqCst);
        true
    }

    /// Drop the snapshot for one exact shape (redeploy/undeploy of a
    /// function with that shape: the state behind the stored blob may
    /// no longer match what a fresh deployment would build). Counted
    /// as stale; also fences any capture currently in flight (its late
    /// insert is discarded). Returns whether an entry was dropped.
    pub fn invalidate(&self, key: &SnapshotKey) -> bool {
        let mut g = plock(&self.inner);
        // Bumped even when nothing is stored yet: the capture that
        // WOULD have stored this shape may still be running.
        *g.invalidations.entry(key.clone()).or_insert(0) += 1;
        match g.entries.remove(key) {
            Some(e) => {
                g.bytes -= e.blob.size_bytes;
                self.stale.fetch_add(1, Ordering::SeqCst);
                true
            }
            None => false,
        }
    }

    /// Record one failed restore against `key`; the entry survives
    /// (most failures are transient) until [`MAX_RESTORE_FAILURES`]
    /// consecutive ones, at which point it is dropped (counted stale)
    /// so the next full cold provision re-captures fresh state.
    fn note_restore_failure(&self, key: &SnapshotKey) {
        let mut g = plock(&self.inner);
        let Some(e) = g.entries.get_mut(key) else { return };
        e.failures += 1;
        if e.failures >= MAX_RESTORE_FAILURES {
            if let Some(e) = g.entries.remove(key) {
                g.bytes -= e.blob.size_bytes;
                self.stale.fetch_add(1, Ordering::SeqCst);
            }
        }
    }

    /// A successful restore proves the blob healthy again.
    fn note_restore_success(&self, key: &SnapshotKey) {
        if let Some(e) = plock(&self.inner).entries.get_mut(key) {
            e.failures = 0;
        }
    }

    /// Provision a container for `spec`, preferring a snapshot
    /// restore when the store holds one for its shape. A hit restores
    /// (the container's start kind is `Restored`); a failed restore
    /// falls back to the full cold path — never an error surfaced to
    /// the request; and a full cold provision schedules a capture per
    /// the capture policy. When snapshots are off for `spec` this is
    /// exactly [`Container::provision`].
    pub fn provision(
        self: &Arc<Self>,
        spec: &Arc<FunctionSpec>,
        engine: &Arc<dyn Engine>,
        governor: &CpuGovernor,
        bootstrap: &BootstrapConfig,
        clock: &Arc<dyn Clock>,
        rng: &mut SplitMix64,
    ) -> Result<Container> {
        let enabled = self.enabled_for(spec);
        if enabled {
            let key = SnapshotKey::of(spec);
            if let Some(blob) = self.lookup(&key) {
                let restored = Container::provision_from_snapshot(
                    spec.clone(),
                    engine.clone(),
                    governor,
                    bootstrap,
                    self.config.restore_bw,
                    &blob,
                    clock,
                    rng,
                );
                match restored {
                    Ok(c) => {
                        self.note_restore_success(&key);
                        return Ok(c);
                    }
                    Err(_) => {
                        // Fall through to the full cold path: a restore
                        // failure must cost the request a slower start,
                        // not an error. The entry survives transient
                        // failures but is dropped after persistent
                        // ones (see `note_restore_failure`).
                        self.restore_failures.fetch_add(1, Ordering::SeqCst);
                        self.note_restore_failure(&key);
                    }
                }
            }
        }
        let container =
            Container::provision(spec.clone(), engine.clone(), governor, bootstrap, clock, rng)?;
        if enabled {
            self.schedule_capture(spec, engine, &container, clock);
        }
        Ok(container)
    }

    /// Capture `container`'s instance into the store per the capture
    /// policy: at most one capture per key at a time, and none when
    /// the key is already stored.
    fn schedule_capture(
        self: &Arc<Self>,
        spec: &Arc<FunctionSpec>,
        engine: &Arc<dyn Engine>,
        container: &Container,
        clock: &Arc<dyn Clock>,
    ) {
        if self.config.capture_policy == CapturePolicy::Off {
            return;
        }
        let key = SnapshotKey::of(spec);
        let generation = {
            let mut g = plock(&self.inner);
            if g.entries.contains_key(&key) || !g.in_flight.insert(key.clone()) {
                return;
            }
            g.generation_of(&key)
        };
        let handle = container.handle().clone();
        match self.config.capture_policy {
            CapturePolicy::Sync => self.run_capture(&key, engine, &handle, generation, clock),
            CapturePolicy::Background => {
                let store = self.clone();
                let engine = engine.clone();
                let thread_key = key.clone();
                let clock = Arc::clone(clock);
                // Short-lived detached worker holding only the store
                // and engine Arcs. Racing the container's teardown is
                // benign: a dead instance fails the capture, which is
                // best-effort and simply dropped; racing an undeploy/
                // redeploy is fenced by the generation.
                let spawned = std::thread::Builder::new()
                    .name("snapshot-capture".into())
                    .spawn(move || {
                        store.run_capture(&thread_key, &engine, &handle, generation, &clock)
                    });
                if let Err(e) = spawned {
                    log::warn!("snapshot capture thread failed to spawn: {e}");
                    plock(&self.inner).in_flight.remove(&key);
                }
            }
            CapturePolicy::Off => unreachable!("filtered above"),
        }
    }

    /// One capture attempt: serialize the instance and store the blob
    /// (unless an invalidation landed since `generation` was read).
    /// Best-effort — a failed capture (or a blob over capacity) costs
    /// nothing and leaves the store unchanged. The capture cost is
    /// measured on the platform clock so ManualClock runs stay fully
    /// virtualized.
    fn run_capture(
        &self,
        key: &SnapshotKey,
        engine: &Arc<dyn Engine>,
        handle: &InstanceHandle,
        generation: u64,
        clock: &Arc<dyn Clock>,
    ) {
        let t0 = clock.now();
        if let Ok(blob) = engine.snapshot_instance(handle) {
            let cost = Duration::from_nanos(clock.now().saturating_sub(t0));
            self.insert_captured(key.clone(), blob, cost, generation);
        }
        plock(&self.inner).in_flight.remove(key);
    }

    /// Successful lookups.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::SeqCst)
    }

    /// Lookups that found no snapshot for the shape.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::SeqCst)
    }

    /// Snapshots stored (inserts, including replacements).
    pub fn captures(&self) -> u64 {
        self.captures.load(Ordering::SeqCst)
    }

    /// Entries evicted by the LRU capacity bound.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::SeqCst)
    }

    /// Entries dropped by redeploy/undeploy invalidation.
    pub fn stale(&self) -> u64 {
        self.stale.load(Ordering::SeqCst)
    }

    /// Restores that failed and fell back to the full cold path.
    pub fn restore_failures(&self) -> u64 {
        self.restore_failures.load(Ordering::SeqCst)
    }

    /// Live gauge: bytes currently stored.
    pub fn bytes(&self) -> u64 {
        plock(&self.inner).bytes
    }

    /// Snapshots currently stored.
    pub fn len(&self) -> usize {
        plock(&self.inner).entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Wall cost of the stored capture for `key`, if present.
    pub fn capture_cost(&self, key: &SnapshotKey) -> Option<Duration> {
        plock(&self.inner).entries.get(key).map(|e| e.capture_cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::metrics::StartKind;
    use crate::platform::registry::FunctionRegistry;
    use crate::runtime::{MockEngine, SnapshotPayload};
    use crate::util::ManualClock;
    use std::time::Instant;

    fn store(config: SnapshotConfig) -> Arc<SnapshotStore> {
        Arc::new(SnapshotStore::new(config))
    }

    fn on_sync() -> SnapshotConfig {
        SnapshotConfig {
            enabled: true,
            capture_policy: CapturePolicy::Sync,
            ..Default::default()
        }
    }

    fn blob(model: &str, bytes: u64) -> SnapshotBlob {
        SnapshotBlob {
            model: model.to_string(),
            variant: "pallas".to_string(),
            size_bytes: bytes,
            payload: SnapshotPayload::Synthetic,
        }
    }

    fn key(model: &str, mem: MemorySize) -> SnapshotKey {
        SnapshotKey { model: model.to_string(), variant: "pallas".to_string(), memory_mb: mem }
    }

    struct Fixture {
        engine: Arc<MockEngine>,
        dyn_engine: Arc<dyn Engine>,
        spec: Arc<FunctionSpec>,
        gov: CpuGovernor,
        clock: Arc<dyn Clock>,
        rng: SplitMix64,
        bootstrap: BootstrapConfig,
    }

    fn fixture() -> Fixture {
        let engine = Arc::new(MockEngine::paper_zoo());
        let dyn_engine: Arc<dyn Engine> = engine.clone();
        let reg = FunctionRegistry::new(dyn_engine.clone());
        let spec = reg.deploy("sq", "squeezenet", "pallas", 896).unwrap();
        let clock: Arc<dyn Clock> = ManualClock::new();
        Fixture {
            engine,
            dyn_engine,
            spec,
            gov: CpuGovernor::new(1792, clock.clone()),
            clock,
            rng: SplitMix64::new(7),
            bootstrap: BootstrapConfig { simulate_delays: false, ..Default::default() },
        }
    }

    #[test]
    fn lookup_insert_counters_and_lru_eviction() {
        let s = store(SnapshotConfig { capacity_bytes: 100, ..Default::default() });
        assert!(s.is_empty());
        assert!(s.lookup(&key("a", 512)).is_none());
        assert_eq!(s.misses(), 1);

        assert!(s.insert(key("a", 512), blob("a", 60), Duration::from_millis(5)));
        assert!(s.insert(key("b", 512), blob("b", 40), Duration::ZERO));
        assert_eq!(s.len(), 2);
        assert_eq!(s.bytes(), 100);
        assert_eq!(s.captures(), 2);
        assert_eq!(s.capture_cost(&key("a", 512)), Some(Duration::from_millis(5)));

        // Touch "a" so "b" is the LRU victim when "c" needs room.
        assert!(s.lookup(&key("a", 512)).is_some());
        assert_eq!(s.hits(), 1);
        assert!(s.insert(key("c", 512), blob("c", 30), Duration::ZERO));
        assert_eq!(s.evictions(), 1);
        assert!(s.lookup(&key("b", 512)).is_none(), "LRU entry evicted");
        assert!(s.lookup(&key("a", 512)).is_some(), "recently used survives");
        assert_eq!(s.bytes(), 90);

        // A blob over the whole capacity is refused outright.
        assert!(!s.insert(key("huge", 512), blob("huge", 101), Duration::ZERO));
        assert_eq!(s.len(), 2);

        // Replacement swaps bytes, not duplicates.
        assert!(s.insert(key("a", 512), blob("a", 10), Duration::ZERO));
        assert_eq!(s.bytes(), 40);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn invalidation_counts_stale_and_is_shape_exact() {
        let s = store(SnapshotConfig::default());
        s.insert(key("m", 512), blob("m", 10), Duration::ZERO);
        s.insert(key("m", 1024), blob("m", 10), Duration::ZERO);
        s.insert(key("other", 512), blob("other", 10), Duration::ZERO);
        assert!(s.invalidate(&key("m", 512)));
        assert!(!s.invalidate(&key("m", 512)), "second invalidate is a no-op");
        assert_eq!(s.stale(), 1);
        assert_eq!(s.len(), 2, "other shapes (same model included) untouched");
        assert_eq!(s.bytes(), 20);
    }

    /// The undeploy/redeploy race: a capture that began before its
    /// shape's invalidation must not land afterwards — the fence is
    /// the shape's invalidation generation read when the capture was
    /// scheduled, and it fences only that shape.
    #[test]
    fn capture_racing_invalidation_is_discarded_per_shape() {
        let s = store(SnapshotConfig { enabled: true, ..Default::default() });
        let k = key("m", 512);
        let began = s.inner.lock().unwrap().generation_of(&k);
        // The deployment goes away while the capture is in flight
        // (nothing stored yet — the generation alone is the fence).
        s.invalidate(&k);
        assert!(!s.insert_captured(k.clone(), blob("m", 10), Duration::ZERO, began));
        assert!(s.is_empty(), "obsolete capture discarded");
        assert_eq!(s.captures(), 0);
        // An UNRELATED shape's in-flight capture is not fenced.
        let other = key("other", 512);
        assert!(s.insert_captured(other, blob("other", 10), Duration::ZERO, 0));
        assert_eq!(s.len(), 1);
        // A capture of the invalidated shape begun after the
        // invalidation lands normally.
        let began = s.inner.lock().unwrap().generation_of(&k);
        assert!(s.insert_captured(k, blob("m", 10), Duration::ZERO, began));
        assert_eq!(s.len(), 2);
    }

    /// Persistent restore failures drop the entry (so cold starts stop
    /// paying doomed restore attempts and the next cold re-captures);
    /// a success in between resets the count.
    #[test]
    fn repeated_restore_failures_drop_the_entry() {
        let s = store(on_sync());
        let mut f = fixture();
        s.provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(s.len(), 1);
        let fail = &f.engine.fail_restore;
        // Two failures, then a success: the count resets.
        fail.store(true, std::sync::atomic::Ordering::SeqCst);
        for _ in 0..2 {
            s.provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
                .unwrap();
        }
        fail.store(false, std::sync::atomic::Ordering::SeqCst);
        s.provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(s.len(), 1, "2 failures + success keep the entry");
        // Three consecutive failures: the broken entry is dropped
        // (stale) and the dropping provision's own cold fallback
        // immediately re-captures fresh state in its place.
        fail.store(true, std::sync::atomic::Ordering::SeqCst);
        for _ in 0..3 {
            let c = s
                .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
                .unwrap();
            assert_eq!(c.start_kind_for_first_use(), StartKind::Cold);
        }
        assert_eq!(s.stale(), 1, "persistently failing blob dropped");
        assert_eq!(s.captures(), 2, "cold fallback re-captured a fresh blob");
        assert_eq!(s.len(), 1);
        assert_eq!(s.restore_failures(), 5);
        // The replacement blob restores once the engine recovers.
        fail.store(false, std::sync::atomic::Ordering::SeqCst);
        let c = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(c.start_kind_for_first_use(), StartKind::Restored);
    }

    #[test]
    fn enabled_for_override_beats_platform_default() {
        let off = store(SnapshotConfig::default());
        let on = store(SnapshotConfig { enabled: true, ..Default::default() });
        let f = fixture();
        assert!(!off.enabled_for(&f.spec));
        assert!(on.enabled_for(&f.spec));
        let mut forced = (*f.spec).clone();
        forced.snapshot = Some(true);
        assert!(off.enabled_for(&forced));
        forced.snapshot = Some(false);
        assert!(!on.enabled_for(&forced));
    }

    #[test]
    fn provision_captures_then_restores() {
        let s = store(on_sync());
        let mut f = fixture();
        // First provision: miss, full cold, sync capture.
        let c1 = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(c1.start_kind_for_first_use(), StartKind::Cold);
        assert_eq!(s.misses(), 1);
        assert_eq!(s.captures(), 1);
        assert_eq!(s.bytes(), f.engine.manifest("squeezenet").unwrap().param_bytes);
        // Second provision: hit, restored, strictly cheaper.
        let c2 = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(c2.start_kind_for_first_use(), StartKind::Restored);
        assert_eq!(s.hits(), 1);
        assert!(c2.provision_cost.total() < c1.provision_cost.total());
        assert_eq!(c2.provision_cost.model_load, Duration::ZERO);
        // One snapshot per shape: the second cold didn't re-capture.
        assert_eq!(s.captures(), 1);
        assert_eq!(f.engine.live_instances(), 2);
    }

    #[test]
    fn failed_restore_falls_back_to_full_cold() {
        let s = store(on_sync());
        let mut f = fixture();
        s.provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        f.engine.fail_restore.store(true, std::sync::atomic::Ordering::SeqCst);
        let c = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(c.start_kind_for_first_use(), StartKind::Cold, "fell back, not errored");
        assert_eq!(s.restore_failures(), 1);
        assert_eq!(s.len(), 1, "the blob survives a transient failure");
        assert_eq!(f.engine.live_instances(), 2, "no leaked half-restore");
        // Recovered engine: the same blob restores again.
        f.engine.fail_restore.store(false, std::sync::atomic::Ordering::SeqCst);
        let c = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(c.start_kind_for_first_use(), StartKind::Restored);
    }

    #[test]
    fn disabled_store_never_looks_up_or_captures() {
        let s = store(SnapshotConfig::default());
        let mut f = fixture();
        let c = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(c.start_kind_for_first_use(), StartKind::Cold);
        assert_eq!(s.hits() + s.misses() + s.captures(), 0, "store untouched when off");
        assert_eq!(f.engine.snapshot_calls.load(std::sync::atomic::Ordering::SeqCst), 0);
    }

    #[test]
    fn capture_policy_off_restores_preseeded_but_never_captures() {
        let s = store(SnapshotConfig {
            enabled: true,
            capture_policy: CapturePolicy::Off,
            ..Default::default()
        });
        let mut f = fixture();
        let c = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(c.start_kind_for_first_use(), StartKind::Cold);
        assert_eq!(s.captures(), 0, "off policy never captures");
        // Pre-seed by hand: restores work.
        let b = f.dyn_engine.snapshot_instance(c.handle()).unwrap();
        s.insert(SnapshotKey::of(&f.spec), b, Duration::ZERO);
        let c2 = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(c2.start_kind_for_first_use(), StartKind::Restored);
    }

    #[test]
    fn background_capture_lands_off_the_critical_path() {
        let s = store(SnapshotConfig { enabled: true, ..Default::default() });
        let mut f = fixture();
        let _c = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        // The detached worker finishes on its own wall-clock schedule.
        let deadline = Instant::now() + Duration::from_secs(10);
        while s.captures() < 1 {
            assert!(Instant::now() < deadline, "background capture never landed");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn failed_capture_is_best_effort() {
        let s = store(on_sync());
        let mut f = fixture();
        f.engine.fail_snapshot.store(true, std::sync::atomic::Ordering::SeqCst);
        let c = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(c.start_kind_for_first_use(), StartKind::Cold);
        assert_eq!(s.captures(), 0, "failed capture stores nothing");
        assert!(s.is_empty());
        // And the in-flight guard was released: a later capture works.
        f.engine.fail_snapshot.store(false, std::sync::atomic::Ordering::SeqCst);
        let _c2 = s
            .provision(&f.spec, &f.dyn_engine, &f.gov, &f.bootstrap, &f.clock, &mut f.rng)
            .unwrap();
        assert_eq!(s.captures(), 1);
    }
}
