//! Billing meter: AWS Lambda 2017 pricing (Table 1 of the paper).
//!
//! Execution is billed in 100 ms units, **rounded up**, at a per-unit
//! price proportional to the configured memory size, plus a flat
//! per-request charge. The paper's cost curves (Figures 1-3) fall out
//! of `units(mem) * price(mem)`: the per-unit price rises linearly with
//! memory while execution time falls, so total cost is non-monotone.

use crate::configparse::{MemorySize, PricingConfig};
use crate::util::plock;
use anyhow::Result;
use std::sync::Mutex;
use std::time::Duration;

/// One billed invocation.
#[derive(Debug, Clone, PartialEq)]
pub struct InvoiceLine {
    pub function: String,
    pub memory_mb: MemorySize,
    /// Raw billed duration before rounding.
    pub duration: Duration,
    /// Duration rounded up to the billing quantum, in ms.
    pub billed_ms: u64,
    /// Execution dollars (units x per-unit price).
    pub execution_dollars: f64,
    /// Flat request charge.
    pub request_dollars: f64,
}

/// GB-seconds consumed by `billed_ms` at `memory_mb` (the unit AWS
/// aggregates free tier in). One definition shared by the invoice
/// lines and the streaming metrics shards, so the per-function
/// `gb_seconds_total` can never diverge from the meter's.
pub fn gb_seconds(memory_mb: MemorySize, billed_ms: u64) -> f64 {
    (memory_mb as f64 / 1024.0) * (billed_ms as f64 / 1000.0)
}

impl InvoiceLine {
    pub fn total_dollars(&self) -> f64 {
        self.execution_dollars + self.request_dollars
    }

    /// GB-seconds consumed by this line.
    pub fn gb_seconds(&self) -> f64 {
        gb_seconds(self.memory_mb, self.billed_ms)
    }
}

/// Thread-safe accumulator of invoice lines.
pub struct BillingMeter {
    pricing: PricingConfig,
    lines: Mutex<Vec<InvoiceLine>>,
}

impl BillingMeter {
    pub fn new(pricing: PricingConfig) -> Self {
        Self { pricing, lines: Mutex::new(Vec::new()) }
    }

    /// Round `duration` up to billing units.
    pub fn round_up_ms(&self, duration: Duration) -> u64 {
        let g = self.pricing.granularity_ms;
        let ms = duration.as_nanos().div_ceil(1_000_000) as u64;
        ms.div_ceil(g) * g
    }

    /// Price one invocation and record it.
    pub fn charge(
        &self,
        function: &str,
        memory_mb: MemorySize,
        duration: Duration,
    ) -> Result<InvoiceLine> {
        let billed_ms = self.round_up_ms(duration);
        let units = billed_ms / self.pricing.granularity_ms;
        let per_unit = self.pricing.price_per_unit(memory_mb)?;
        let line = InvoiceLine {
            function: function.to_string(),
            memory_mb,
            duration,
            billed_ms,
            execution_dollars: units as f64 * per_unit,
            request_dollars: self.pricing.per_request_dollars,
        };
        plock(&self.lines).push(line.clone());
        Ok(line)
    }

    pub fn lines(&self) -> Vec<InvoiceLine> {
        plock(&self.lines).clone()
    }

    pub fn total_dollars(&self) -> f64 {
        plock(&self.lines).iter().map(InvoiceLine::total_dollars).sum()
    }

    pub fn total_gb_seconds(&self) -> f64 {
        plock(&self.lines).iter().map(InvoiceLine::gb_seconds).sum()
    }

    pub fn reset(&self) {
        plock(&self.lines).clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Prop};

    fn meter() -> BillingMeter {
        BillingMeter::new(PricingConfig::default())
    }

    #[test]
    fn rounds_up_to_100ms() {
        let m = meter();
        assert_eq!(m.round_up_ms(Duration::from_millis(1)), 100);
        assert_eq!(m.round_up_ms(Duration::from_millis(100)), 100);
        assert_eq!(m.round_up_ms(Duration::from_millis(101)), 200);
        assert_eq!(m.round_up_ms(Duration::from_micros(100_001)), 200);
        assert_eq!(m.round_up_ms(Duration::ZERO), 0);
    }

    #[test]
    fn table1_example_charges() {
        let m = meter();
        // 1 second at 128 MB = 10 units x $0.000000208.
        let line = m.charge("f", 128, Duration::from_secs(1)).unwrap();
        assert!((line.execution_dollars - 10.0 * 0.000000208).abs() < 1e-15);
        assert_eq!(line.billed_ms, 1000);
        // 250 ms at 1536 MB rounds to 3 units.
        let line = m.charge("f", 1536, Duration::from_millis(250)).unwrap();
        assert_eq!(line.billed_ms, 300);
        assert!((line.execution_dollars - 3.0 * 0.000002501).abs() < 1e-15);
    }

    #[test]
    fn gb_seconds() {
        let m = meter();
        let line = m.charge("f", 1024, Duration::from_secs(2)).unwrap();
        assert!((line.gb_seconds() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn accumulates_and_resets() {
        let m = meter();
        m.charge("a", 128, Duration::from_millis(100)).unwrap();
        m.charge("b", 256, Duration::from_millis(100)).unwrap();
        assert_eq!(m.lines().len(), 2);
        let total = m.total_dollars();
        assert!((total - (0.000000208 + 0.000000417 + 2.0 * 0.2e-6)).abs() < 1e-15);
        m.reset();
        assert_eq!(m.lines().len(), 0);
        assert_eq!(m.total_dollars(), 0.0);
    }

    #[test]
    fn unknown_memory_errors() {
        let m = meter();
        assert!(m.charge("f", 64, Duration::from_millis(100)).is_err());
    }

    // ------------------------- properties -------------------------

    #[test]
    fn prop_billed_never_less_than_duration() {
        let m = meter();
        forall("billed_ms >= duration_ms", move |ms: &u64| {
            let ms = ms % 10_000_000; // up to ~3h
            let billed = m.round_up_ms(Duration::from_millis(ms));
            billed >= ms && billed - ms < 100
        });
    }

    #[test]
    fn prop_billing_monotone_in_duration() {
        let m = meter();
        forall("longer runs never cost less", move |(a, b): &(u64, u64)| {
            let (a, b) = (a % 1_000_000, b % 1_000_000);
            let (lo, hi) = (a.min(b), a.max(b));
            let c_lo =
                m.charge("f", 512, Duration::from_millis(lo)).unwrap().total_dollars();
            let c_hi =
                m.charge("f", 512, Duration::from_millis(hi)).unwrap().total_dollars();
            Prop::from(c_lo <= c_hi)
        });
    }

    #[test]
    fn prop_billing_monotone_in_memory_at_fixed_duration() {
        // Per-unit price (and hence fixed-duration cost) rises with
        // memory: Table 1's structure.
        let m = meter();
        forall("more memory costs more per unit time", move |(i, j): &(u32, u32)| {
            let mems = crate::configparse::MEMORY_SIZES_2017;
            let a = mems[(*i as usize) % mems.len()];
            let b = mems[(*j as usize) % mems.len()];
            if a == b {
                return Prop::Discard;
            }
            let (lo, hi) = (a.min(b), a.max(b));
            let c_lo = m.charge("f", lo, Duration::from_secs(1)).unwrap().execution_dollars;
            let c_hi = m.charge("f", hi, Duration::from_secs(1)).unwrap().execution_dollars;
            Prop::from(c_lo < c_hi)
        });
    }
}
