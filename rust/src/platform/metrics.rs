//! Per-invocation records and aggregation.
//!
//! Each invocation yields an [`InvocationRecord`] with the full latency
//! decomposition the paper measures: client-observed response time,
//! in-function prediction time, cold/warm tag, billed duration, and
//! cost. Experiments aggregate records into the rows of each figure.

use crate::configparse::MemorySize;
use crate::stats::{Histogram, Summary};
use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StartKind {
    Cold,
    Warm,
}

impl std::fmt::Display for StartKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StartKind::Cold => write!(f, "cold"),
            StartKind::Warm => write!(f, "warm"),
        }
    }
}

/// The measured decomposition of one invocation (platform-side; the
/// workload driver adds the client<->gateway network component).
#[derive(Debug, Clone)]
pub struct InvocationRecord {
    pub function: String,
    pub memory_mb: MemorySize,
    pub start: StartKind,
    /// Queue/dispatch wait before a container was available.
    pub queue: Duration,
    /// Sandbox provisioning (cold only; simulated).
    pub sandbox: Duration,
    /// Language-runtime init, CPU-scaled (cold only; simulated).
    pub runtime_init: Duration,
    /// Package (code+model) fetch, I/O-scaled (cold only; simulated).
    pub package_fetch: Duration,
    /// Model compile + weight materialization (cold only; REAL work,
    /// CPU-scaled into effective time).
    pub model_load: Duration,
    /// Effective (CPU-share-scaled) forward-pass time — the paper's
    /// "prediction time".
    pub predict: Duration,
    /// Raw full-speed compute measured by the engine.
    pub predict_full_speed: Duration,
    /// Billed handler duration (prediction + cold init work).
    pub billed: Duration,
    pub billed_ms: u64,
    pub cost_dollars: f64,
    /// Classification output (sanity checks).
    pub top1: i32,
}

impl InvocationRecord {
    /// Platform-side response time (everything the client waits for,
    /// minus the network leg).
    pub fn response(&self) -> Duration {
        self.queue
            + self.sandbox
            + self.runtime_init
            + self.package_fetch
            + self.model_load
            + self.predict
    }

    /// Total cold-start overhead (response minus what a warm start
    /// would have cost).
    pub fn cold_overhead(&self) -> Duration {
        self.sandbox + self.runtime_init + self.package_fetch + self.model_load
    }
}

/// Thread-safe collector.
#[derive(Default)]
pub struct MetricsSink {
    records: Mutex<Vec<InvocationRecord>>,
}

impl MetricsSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, r: InvocationRecord) {
        self.records.lock().unwrap().push(r);
    }

    pub fn records(&self) -> Vec<InvocationRecord> {
        self.records.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.records.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn reset(&self) {
        self.records.lock().unwrap().clear();
    }

    /// Count of cold starts observed.
    pub fn cold_count(&self) -> usize {
        self.records.lock().unwrap().iter().filter(|r| r.start == StartKind::Cold).count()
    }

    /// Summary of response times (seconds) over `filter`ed records.
    pub fn response_summary<F: Fn(&InvocationRecord) -> bool>(&self, filter: F) -> Summary {
        let xs: Vec<f64> = self
            .records
            .lock()
            .unwrap()
            .iter()
            .filter(|r| filter(r))
            .map(|r| r.response().as_secs_f64())
            .collect();
        Summary::from_samples(&xs)
    }

    /// Summary of prediction times (seconds).
    pub fn predict_summary<F: Fn(&InvocationRecord) -> bool>(&self, filter: F) -> Summary {
        let xs: Vec<f64> = self
            .records
            .lock()
            .unwrap()
            .iter()
            .filter(|r| filter(r))
            .map(|r| r.predict.as_secs_f64())
            .collect();
        Summary::from_samples(&xs)
    }

    /// Response-time histogram in nanoseconds (bimodality analysis).
    pub fn response_histogram(&self) -> Histogram {
        let mut h = Histogram::new();
        for r in self.records.lock().unwrap().iter() {
            h.record(r.response().as_nanos() as u64);
        }
        h
    }

    /// Total cost over all records.
    pub fn total_cost(&self) -> f64 {
        self.records.lock().unwrap().iter().map(|r| r.cost_dollars).sum()
    }
}

#[cfg(test)]
pub(crate) fn test_record(
    function: &str,
    mem: MemorySize,
    start: StartKind,
    predict_ms: u64,
) -> InvocationRecord {
    let cold = start == StartKind::Cold;
    InvocationRecord {
        function: function.to_string(),
        memory_mb: mem,
        start,
        queue: Duration::ZERO,
        sandbox: if cold { Duration::from_millis(250) } else { Duration::ZERO },
        runtime_init: if cold { Duration::from_millis(1200) } else { Duration::ZERO },
        package_fetch: if cold { Duration::from_millis(60) } else { Duration::ZERO },
        model_load: if cold { Duration::from_millis(400) } else { Duration::ZERO },
        predict: Duration::from_millis(predict_ms),
        predict_full_speed: Duration::from_millis(predict_ms / 2),
        billed: Duration::from_millis(predict_ms),
        billed_ms: predict_ms.div_ceil(100) * 100,
        cost_dollars: 1e-6,
        top1: 42,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_is_component_sum() {
        let r = test_record("f", 512, StartKind::Cold, 500);
        assert_eq!(r.response(), Duration::from_millis(250 + 1200 + 60 + 400 + 500));
        assert_eq!(r.cold_overhead(), Duration::from_millis(1910));
        let w = test_record("f", 512, StartKind::Warm, 500);
        assert_eq!(w.response(), Duration::from_millis(500));
        assert_eq!(w.cold_overhead(), Duration::ZERO);
    }

    #[test]
    fn sink_aggregation() {
        let s = MetricsSink::new();
        s.record(test_record("f", 512, StartKind::Cold, 1000));
        s.record(test_record("f", 512, StartKind::Warm, 500));
        s.record(test_record("g", 1024, StartKind::Warm, 300));
        assert_eq!(s.len(), 3);
        assert_eq!(s.cold_count(), 1);
        let warm = s.response_summary(|r| r.start == StartKind::Warm);
        assert_eq!(warm.n, 2);
        assert!((warm.mean - 0.4).abs() < 1e-9);
        let f_only = s.predict_summary(|r| r.function == "f");
        assert_eq!(f_only.n, 2);
        assert!((s.total_cost() - 3e-6).abs() < 1e-15);
        s.reset();
        assert!(s.is_empty());
    }

    #[test]
    fn histogram_captures_bimodality() {
        let s = MetricsSink::new();
        for _ in 0..95 {
            s.record(test_record("f", 512, StartKind::Warm, 100));
        }
        for _ in 0..5 {
            s.record(test_record("f", 512, StartKind::Cold, 100));
        }
        let h = s.response_histogram();
        // Warm ~100ms, cold ~2s; fraction above 1s equals cold share.
        let frac = h.fraction_above(1_000_000_000);
        assert!((frac - 0.05).abs() < 0.001, "frac={frac}");
    }
}
