//! Per-invocation records and streaming per-function aggregation.
//!
//! Each invocation yields an [`InvocationRecord`] with the full latency
//! decomposition the paper measures: client-observed response time,
//! in-function prediction time, cold/warm tag, billed duration, and
//! cost.
//!
//! Aggregation is *streaming*: every function owns a [`FnMetrics`]
//! shard — cold/warm-split response and prediction [`Histogram`]s plus
//! invocation/cold/throttle counters and billed/cost/GB-second
//! accumulators — updated once at record time under a per-function
//! lock. Stats readers clone one shard under one lock acquisition, so
//! a snapshot is internally consistent (`invocations == cold + warm`,
//! histogram counts match the counters) and costs O(1) in the number
//! of invocations. A bounded ring of recent raw records keeps the
//! experiment/report tooling working; total memory is bounded by
//! `functions x fixed histogram footprint + ring capacity`.

use crate::configparse::MemorySize;
use crate::stats::{Histogram, Summary};
use crate::util::plock;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Default capacity of the recent-records ring buffer.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StartKind {
    Cold,
    Warm,
    /// Provisioned from a snapshot: a new container, but one that paid
    /// sandbox + restore I/O instead of the full cold trio (runtime
    /// init + package fetch + model load).
    Restored,
}

impl std::fmt::Display for StartKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StartKind::Cold => write!(f, "cold"),
            StartKind::Warm => write!(f, "warm"),
            StartKind::Restored => write!(f, "restored"),
        }
    }
}

/// The measured decomposition of one invocation (platform-side; the
/// workload driver adds the client<->gateway network component).
#[derive(Debug, Clone)]
pub struct InvocationRecord {
    pub function: String,
    pub memory_mb: MemorySize,
    pub start: StartKind,
    /// Queue/dispatch wait before a container was available.
    pub queue: Duration,
    /// Sandbox provisioning (cold only; simulated).
    pub sandbox: Duration,
    /// Language-runtime init, CPU-scaled (cold only; simulated).
    pub runtime_init: Duration,
    /// Package (code+model) fetch, I/O-scaled (cold only; simulated).
    pub package_fetch: Duration,
    /// Model compile + weight materialization (cold only; REAL work,
    /// CPU-scaled into effective time).
    pub model_load: Duration,
    /// Snapshot restore — blob fetch (I/O-scaled) + weight re-upload
    /// (CPU-scaled) — paid by restored provisions INSTEAD of
    /// `runtime_init + package_fetch + model_load`; zero otherwise.
    pub restore: Duration,
    /// Effective (CPU-share-scaled) forward-pass time — the paper's
    /// "prediction time". For a batched request this is the WHOLE
    /// batched pass (what the request actually waited for); the
    /// billing split lives in `billed`.
    pub predict: Duration,
    /// Raw full-speed compute measured by the engine (for a batched
    /// request: this member's share of the batched pass).
    pub predict_full_speed: Duration,
    /// Requests coalesced into the forward pass that served this one
    /// (1 = solo execution; also 1 for a batch leader whose window
    /// attracted no followers).
    pub batch_size: usize,
    /// Time parked in the batch collector before the batched pass
    /// started: the leader's window wait, a follower's join-to-flush
    /// wait. Zero off the batching path.
    pub batch_wait: Duration,
    /// Largest compiled batch-N kernel that served the forward pass
    /// (1 = batch-1 executables only, including the whole solo path).
    pub kernel_batch_n: usize,
    /// Batch-N (N >= 2) kernel-cache hits charged to this record. The
    /// deltas of one batched pass have ONE owner — the leader that ran
    /// the flush — so followers always carry zero here.
    pub batch_kernel_hits: u64,
    /// Batch-N kernel-cache misses charged to this record (leader
    /// only, as above).
    pub batch_kernel_misses: u64,
    /// Billed handler duration (prediction + cold init work).
    pub billed: Duration,
    pub billed_ms: u64,
    pub cost_dollars: f64,
    /// Classification output (sanity checks).
    pub top1: i32,
    /// Trace id minted by the tracing subsystem (`trace.enabled`);
    /// `None` whenever tracing is off, so the default pipeline carries
    /// no extra allocation.
    pub trace_id: Option<String>,
}

impl InvocationRecord {
    /// Platform-side response time (everything the client waits for,
    /// minus the network leg).
    pub fn response(&self) -> Duration {
        self.queue
            + self.sandbox
            + self.runtime_init
            + self.package_fetch
            + self.model_load
            + self.restore
            + self.batch_wait
            + self.predict
    }

    /// Total provisioning overhead (response minus what a warm start
    /// would have cost) — the full trio for a cold start, sandbox +
    /// restore for a snapshot-restored one.
    pub fn cold_overhead(&self) -> Duration {
        self.sandbox + self.runtime_init + self.package_fetch + self.model_load + self.restore
    }

    /// GB-seconds consumed — the billing meter's own definition, so
    /// the streamed accumulator matches the invoice lines exactly.
    pub fn gb_seconds(&self) -> f64 {
        super::billing::gb_seconds(self.memory_mb, self.billed_ms)
    }
}

/// One function's streaming aggregates: everything the stats routes
/// serve, updated incrementally at record time and snapshotted by
/// value under a single lock.
#[derive(Clone, Default)]
pub struct FnMetrics {
    pub invocations: u64,
    pub cold_starts: u64,
    /// Snapshot-restored provisions (the third start kind: a new
    /// container that skipped the full cold path).
    pub restored_starts: u64,
    /// Requests rejected with 429 for this function (per-function
    /// concurrency cap).
    pub throttled: u64,
    /// Requests refused with 503: admission queue at its bound, or a
    /// parked request's dispatch deadline exhausted.
    pub queue_expired: u64,
    pub billed_ms_total: u64,
    pub cost_dollars_total: f64,
    pub gb_seconds_total: f64,
    /// Response-time histograms in nanoseconds, split by start kind
    /// (the paper's bimodality analysis).
    pub response_cold: Histogram,
    pub response_warm: Histogram,
    /// Response times of snapshot-restored starts — the middle mode
    /// the restore path carves out of the cold distribution.
    pub response_restored: Histogram,
    /// Prediction-time histograms in nanoseconds.
    pub predict_cold: Histogram,
    pub predict_warm: Histogram,
    pub predict_restored: Histogram,
    /// Per-component provision-cost histograms in nanoseconds, each
    /// recorded by the requests that actually paid the component:
    /// sandbox by every provisioned (cold or restored) request, the
    /// runtime-init/package-fetch/model-load trio by full cold starts,
    /// restore by snapshot-restored starts. This is the cold-start
    /// decomposition served as percentiles, so the restore win is
    /// observable without parsing raw records.
    pub provision_sandbox: Histogram,
    pub provision_runtime_init: Histogram,
    pub provision_package_fetch: Histogram,
    pub provision_model_load: Histogram,
    pub provision_restore: Histogram,
    /// True dispatch-queue wait in nanoseconds, every served request
    /// (cold and warm): the latency component the admission queue
    /// trades for availability.
    pub queue_wait: Histogram,
    /// Requests served by a coalesced forward pass of size >= 2 (the
    /// batched-request share is this over `invocations`).
    pub batched_requests: u64,
    /// Batch sizes, recorded once per request that rode the batching
    /// path (request-weighted: a size-8 batch contributes 8 samples
    /// of value 8 — what the *average request* experienced, which is
    /// the batching win per request).
    pub batch_size: Histogram,
    /// Per-request batch-collector wait in nanoseconds (leaders'
    /// window wait, followers' join-to-flush wait) — the latency the
    /// batching path trades for throughput.
    pub batch_wait: Histogram,
    /// Largest compiled batch-N kernel per request on the batching
    /// path (request-weighted like `batch_size`: every member of a
    /// flush records the rung that served it).
    pub kernel_batch_n: Histogram,
    /// Batch-N kernel-cache hits across all passes (leader-owned
    /// deltas summed — each pass counted once).
    pub batch_kernel_hits: u64,
    /// Batch-N kernel-cache misses across all passes.
    pub batch_kernel_misses: u64,
}

impl FnMetrics {
    pub fn warm_starts(&self) -> u64 {
        self.invocations - self.cold_starts - self.restored_starts
    }

    /// Merged cold+warm+restored response histogram.
    pub fn response_all(&self) -> Histogram {
        let mut h = self.response_cold.clone();
        h.merge(&self.response_warm);
        h.merge(&self.response_restored);
        h
    }

    /// Merged cold+warm+restored prediction histogram.
    pub fn predict_all(&self) -> Histogram {
        let mut h = self.predict_cold.clone();
        h.merge(&self.predict_warm);
        h.merge(&self.predict_restored);
        h
    }

    fn apply(&mut self, r: &InvocationRecord, response_ns: u64, predict_ns: u64) {
        self.invocations += 1;
        self.queue_wait.record(r.queue.as_nanos() as u64);
        // Requests that rode the batcher (a member of a real batch, or
        // a lone leader that paid a window wait) stream the batching
        // telemetry; the solo path records nothing here, so the batch
        // percentiles describe the batching path only.
        if r.batch_size > 1 || r.batch_wait > Duration::ZERO {
            if r.batch_size > 1 {
                self.batched_requests += 1;
            }
            self.batch_size.record(r.batch_size as u64);
            self.batch_wait.record(r.batch_wait.as_nanos() as u64);
            self.kernel_batch_n.record(r.kernel_batch_n.max(1) as u64);
        }
        // Pass-level cache deltas: zero on every record except the
        // leader's, so summing unconditionally counts each pass once.
        self.batch_kernel_hits += r.batch_kernel_hits;
        self.batch_kernel_misses += r.batch_kernel_misses;
        match r.start {
            StartKind::Cold => {
                self.cold_starts += 1;
                self.response_cold.record(response_ns);
                self.predict_cold.record(predict_ns);
                self.provision_sandbox.record(r.sandbox.as_nanos() as u64);
                self.provision_runtime_init.record(r.runtime_init.as_nanos() as u64);
                self.provision_package_fetch.record(r.package_fetch.as_nanos() as u64);
                self.provision_model_load.record(r.model_load.as_nanos() as u64);
            }
            StartKind::Restored => {
                self.restored_starts += 1;
                self.response_restored.record(response_ns);
                self.predict_restored.record(predict_ns);
                self.provision_sandbox.record(r.sandbox.as_nanos() as u64);
                self.provision_restore.record(r.restore.as_nanos() as u64);
            }
            StartKind::Warm => {
                self.response_warm.record(response_ns);
                self.predict_warm.record(predict_ns);
            }
        }
        self.billed_ms_total += r.billed_ms;
        self.cost_dollars_total += r.cost_dollars;
        self.gb_seconds_total += r.gb_seconds();
    }
}

/// Thread-safe collector: per-function shards + platform totals +
/// bounded recent-records ring.
pub struct MetricsSink {
    shards: RwLock<BTreeMap<String, Arc<Mutex<FnMetrics>>>>,
    totals: Mutex<FnMetrics>,
    recent: Mutex<VecDeque<InvocationRecord>>,
    ring_capacity: usize,
}

impl Default for MetricsSink {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_RING_CAPACITY)
    }
}

impl MetricsSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sink whose recent-records ring keeps at most `ring_capacity`
    /// raw records (aggregates are never truncated).
    pub fn with_capacity(ring_capacity: usize) -> Self {
        Self {
            shards: RwLock::new(BTreeMap::new()),
            totals: Mutex::new(FnMetrics::default()),
            recent: Mutex::new(VecDeque::with_capacity(ring_capacity.min(1024))),
            ring_capacity,
        }
    }

    fn shard(&self, function: &str) -> Arc<Mutex<FnMetrics>> {
        if let Some(s) = self.shards.read().unwrap().get(function) {
            return s.clone();
        }
        self.shards.write().unwrap().entry(function.to_string()).or_default().clone()
    }

    pub fn record(&self, r: InvocationRecord) {
        let response_ns = r.response().as_nanos() as u64;
        let predict_ns = r.predict.as_nanos() as u64;
        plock(&self.shard(&r.function)).apply(&r, response_ns, predict_ns);
        plock(&self.totals).apply(&r, response_ns, predict_ns);
        if self.ring_capacity == 0 {
            return;
        }
        let mut ring = plock(&self.recent);
        if ring.len() == self.ring_capacity {
            ring.pop_front();
        }
        ring.push_back(r);
    }

    /// Count a 429 against `function`'s shard (and the totals).
    pub fn note_throttled(&self, function: &str) {
        plock(&self.shard(function)).throttled += 1;
        plock(&self.totals).throttled += 1;
    }

    /// Count a 503 (queue saturated or deadline exhausted) against
    /// `function`'s shard (and the totals).
    pub fn note_queue_expired(&self, function: &str) {
        plock(&self.shard(function)).queue_expired += 1;
        plock(&self.totals).queue_expired += 1;
    }

    /// One-lock consistent snapshot of a function's aggregates
    /// (default-empty when the function has never been invoked).
    pub fn function_metrics(&self, function: &str) -> FnMetrics {
        self.shards
            .read()
            .unwrap()
            .get(function)
            .map(|s| plock(&s).clone())
            .unwrap_or_default()
    }

    /// Run `read` against the live shard under its lock — same
    /// consistency as [`Self::function_metrics`] without copying the
    /// histograms (a shard is ~256 KiB). `None` when the function has
    /// never been invoked.
    pub fn with_function<R>(
        &self,
        function: &str,
        read: impl FnOnce(&FnMetrics) -> R,
    ) -> Option<R> {
        let shard = self.shards.read().unwrap().get(function).cloned()?;
        let g = plock(&shard);
        Some(read(&g))
    }

    /// One-lock consistent snapshot of the platform-wide aggregates.
    pub fn platform_metrics(&self) -> FnMetrics {
        plock(&self.totals).clone()
    }

    /// Run `read` against the live platform totals under their lock
    /// (no histogram copy).
    pub fn with_totals<R>(&self, read: impl FnOnce(&FnMetrics) -> R) -> R {
        read(&plock(&self.totals))
    }

    /// Drop `function`'s shard (undeploy). Per-function stats are only
    /// served for deployed functions, and shards are ~256 KiB each, so
    /// keeping them for undeployed names would grow memory without
    /// bound under deploy/undeploy churn. Platform totals retain the
    /// history; an invocation still in flight may recreate a (fresh)
    /// shard when it completes, which the next undeploy drops again.
    pub fn remove_function(&self, function: &str) {
        self.shards.write().unwrap().remove(function);
    }

    /// The recent raw records (bounded by the ring capacity; the
    /// counters/histograms above are the unbounded-horizon truth).
    pub fn records(&self) -> Vec<InvocationRecord> {
        plock(&self.recent).iter().cloned().collect()
    }

    pub fn ring_capacity(&self) -> usize {
        self.ring_capacity
    }

    /// Total invocations recorded (NOT the ring length).
    pub fn len(&self) -> usize {
        plock(&self.totals).invocations as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn reset(&self) {
        self.shards.write().unwrap().clear();
        *plock(&self.totals) = FnMetrics::default();
        plock(&self.recent).clear();
    }

    /// Count of cold starts observed.
    pub fn cold_count(&self) -> usize {
        plock(&self.totals).cold_starts as usize
    }

    /// Summary of response times (seconds) over `filter`ed recent
    /// records (ring-bounded; experiment tooling).
    pub fn response_summary<F: Fn(&InvocationRecord) -> bool>(&self, filter: F) -> Summary {
        let xs: Vec<f64> = plock(&self.recent)
            .iter()
            .filter(|r| filter(r))
            .map(|r| r.response().as_secs_f64())
            .collect();
        Summary::from_samples(&xs)
    }

    /// Summary of prediction times (seconds) over `filter`ed recent
    /// records (ring-bounded).
    pub fn predict_summary<F: Fn(&InvocationRecord) -> bool>(&self, filter: F) -> Summary {
        let xs: Vec<f64> = plock(&self.recent)
            .iter()
            .filter(|r| filter(r))
            .map(|r| r.predict.as_secs_f64())
            .collect();
        Summary::from_samples(&xs)
    }

    /// Platform-wide response-time histogram in nanoseconds
    /// (bimodality analysis); streamed, not ring-bounded.
    pub fn response_histogram(&self) -> Histogram {
        plock(&self.totals).response_all()
    }

    /// Total cost over all recorded invocations.
    pub fn total_cost(&self) -> f64 {
        plock(&self.totals).cost_dollars_total
    }
}

#[cfg(test)]
pub(crate) fn test_record(
    function: &str,
    mem: MemorySize,
    start: StartKind,
    predict_ms: u64,
) -> InvocationRecord {
    let cold = start == StartKind::Cold;
    InvocationRecord {
        function: function.to_string(),
        memory_mb: mem,
        start,
        queue: Duration::ZERO,
        sandbox: if cold { Duration::from_millis(250) } else { Duration::ZERO },
        runtime_init: if cold { Duration::from_millis(1200) } else { Duration::ZERO },
        package_fetch: if cold { Duration::from_millis(60) } else { Duration::ZERO },
        model_load: if cold { Duration::from_millis(400) } else { Duration::ZERO },
        restore: Duration::ZERO,
        predict: Duration::from_millis(predict_ms),
        predict_full_speed: Duration::from_millis(predict_ms / 2),
        batch_size: 1,
        batch_wait: Duration::ZERO,
        kernel_batch_n: 1,
        batch_kernel_hits: 0,
        batch_kernel_misses: 0,
        billed: Duration::from_millis(predict_ms),
        billed_ms: predict_ms.div_ceil(100) * 100,
        cost_dollars: 1e-6,
        top1: 42,
        trace_id: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_is_component_sum() {
        let r = test_record("f", 512, StartKind::Cold, 500);
        assert_eq!(r.response(), Duration::from_millis(250 + 1200 + 60 + 400 + 500));
        assert_eq!(r.cold_overhead(), Duration::from_millis(1910));
        let w = test_record("f", 512, StartKind::Warm, 500);
        assert_eq!(w.response(), Duration::from_millis(500));
        assert_eq!(w.cold_overhead(), Duration::ZERO);
    }

    #[test]
    fn sink_aggregation() {
        let s = MetricsSink::new();
        s.record(test_record("f", 512, StartKind::Cold, 1000));
        s.record(test_record("f", 512, StartKind::Warm, 500));
        s.record(test_record("g", 1024, StartKind::Warm, 300));
        assert_eq!(s.len(), 3);
        assert_eq!(s.cold_count(), 1);
        let warm = s.response_summary(|r| r.start == StartKind::Warm);
        assert_eq!(warm.n, 2);
        assert!((warm.mean - 0.4).abs() < 1e-9);
        let f_only = s.predict_summary(|r| r.function == "f");
        assert_eq!(f_only.n, 2);
        assert!((s.total_cost() - 3e-6).abs() < 1e-15);
        s.reset();
        assert!(s.is_empty());
        assert_eq!(s.function_metrics("f").invocations, 0, "reset drops shards");
    }

    #[test]
    fn histogram_captures_bimodality() {
        let s = MetricsSink::new();
        for _ in 0..95 {
            s.record(test_record("f", 512, StartKind::Warm, 100));
        }
        for _ in 0..5 {
            s.record(test_record("f", 512, StartKind::Cold, 100));
        }
        let h = s.response_histogram();
        // Warm ~100ms, cold ~2s; fraction above 1s equals cold share.
        let frac = h.fraction_above(1_000_000_000);
        assert!((frac - 0.05).abs() < 0.001, "frac={frac}");
    }

    #[test]
    fn shard_snapshot_is_consistent_and_split_by_start() {
        let s = MetricsSink::new();
        s.record(test_record("f", 512, StartKind::Cold, 1000));
        s.record(test_record("f", 512, StartKind::Warm, 500));
        s.record(test_record("f", 512, StartKind::Warm, 500));
        s.record(test_record("g", 1024, StartKind::Warm, 300));
        s.note_throttled("f");
        s.note_queue_expired("f");
        let m = s.function_metrics("f");
        assert_eq!(m.invocations, 3);
        assert_eq!(m.cold_starts, 1);
        assert_eq!(m.warm_starts(), 2);
        assert_eq!(m.throttled, 1);
        assert_eq!(m.queue_expired, 1);
        assert_eq!(m.queue_wait.count(), 3, "every served request records queue wait");
        assert_eq!(m.response_cold.count(), 1);
        assert_eq!(m.response_warm.count(), 2);
        assert_eq!(m.response_all().count(), 3);
        assert_eq!(m.predict_all().count(), 3);
        assert_eq!(m.billed_ms_total, 1000 + 500 + 500);
        // Cold response (~2.91s) dwarfs warm (~0.5s) in the split.
        assert!(m.response_cold.p50() > m.response_warm.p50() * 4);
        // gb_seconds matches the billing formula per record.
        let expect = (512.0 / 1024.0) * (2000.0 / 1000.0);
        assert!((m.gb_seconds_total - expect).abs() < 1e-12);
        // Unknown functions read as empty, not a panic.
        let empty = s.function_metrics("nope");
        assert_eq!(empty.invocations, 0);
        assert_eq!(empty.response_all().p99(), 0);
        // Totals see every function.
        let t = s.platform_metrics();
        assert_eq!(t.invocations, 4);
        assert_eq!(t.throttled, 1);
        assert_eq!(t.queue_expired, 1);
        assert_eq!(t.queue_wait.count(), 4);
    }

    #[test]
    fn restored_records_split_and_component_histograms_stream() {
        let s = MetricsSink::new();
        s.record(test_record("f", 512, StartKind::Cold, 100));
        s.record(test_record("f", 512, StartKind::Warm, 100));
        // A snapshot-restored provision: sandbox + restore only.
        let mut r = test_record("f", 512, StartKind::Restored, 100);
        r.sandbox = Duration::from_millis(250);
        r.restore = Duration::from_millis(80);
        assert_eq!(r.response(), Duration::from_millis(250 + 80 + 100));
        assert_eq!(r.cold_overhead(), Duration::from_millis(330));
        s.record(r);
        let m = s.function_metrics("f");
        assert_eq!(m.invocations, 3);
        assert_eq!(m.cold_starts, 1);
        assert_eq!(m.restored_starts, 1);
        assert_eq!(m.warm_starts(), 1, "restored is not warm");
        assert_eq!(m.response_restored.count(), 1);
        assert_eq!(m.predict_restored.count(), 1);
        assert_eq!(m.response_all().count(), 3, "merged view sees all three kinds");
        // Component histograms: sandbox from both provisioned kinds,
        // the cold trio from the cold start only, restore from the
        // restored start only — each percentile describes exactly the
        // requests that paid the component.
        assert_eq!(m.provision_sandbox.count(), 2);
        assert_eq!(m.provision_runtime_init.count(), 1);
        assert_eq!(m.provision_package_fetch.count(), 1);
        assert_eq!(m.provision_model_load.count(), 1);
        assert_eq!(m.provision_restore.count(), 1);
        assert!(m.provision_restore.p50() >= 79_000_000);
        assert!(m.provision_runtime_init.p50() >= 1_180_000_000);
        // The restored mode sits between warm and cold.
        assert!(m.response_restored.p50() > m.response_warm.p50());
        assert!(m.response_restored.p50() < m.response_cold.p50());
        // Totals stream the same split.
        assert_eq!(s.platform_metrics().restored_starts, 1);
    }

    #[test]
    fn queue_wait_histogram_tracks_parked_time() {
        let s = MetricsSink::new();
        let mut r = test_record("f", 512, StartKind::Warm, 100);
        r.queue = Duration::from_millis(40);
        s.record(r);
        let mut r = test_record("f", 512, StartKind::Cold, 100);
        r.queue = Duration::from_millis(400);
        s.record(r);
        let m = s.function_metrics("f");
        assert_eq!(m.queue_wait.count(), 2, "cold requests record queue wait too");
        // Log-bucketed: quantiles are bucket lower edges, ~1% under.
        assert!(m.queue_wait.p99() >= 390_000_000, "p99={}", m.queue_wait.p99());
        assert!(m.queue_wait.p50() >= 39_000_000, "p50={}", m.queue_wait.p50());
    }

    #[test]
    fn batch_telemetry_streams_for_batched_requests_only() {
        let s = MetricsSink::new();
        // Two solo requests: no batch telemetry at all.
        s.record(test_record("f", 512, StartKind::Warm, 100));
        s.record(test_record("f", 512, StartKind::Cold, 100));
        // A batch of 3 (leader cold, 2 followers warm), 40 ms waits,
        // served by a batch-2 kernel; the leader alone owns the
        // pass-level cache deltas.
        for (i, start) in [StartKind::Cold, StartKind::Warm, StartKind::Warm]
            .into_iter()
            .enumerate()
        {
            let mut r = test_record("f", 512, start, 100);
            r.batch_size = 3;
            r.batch_wait = Duration::from_millis(40);
            r.kernel_batch_n = 2;
            if i == 0 {
                r.batch_kernel_hits = 1;
                r.batch_kernel_misses = 1;
            }
            s.record(r);
        }
        // A lone leader whose window expired: size 1 but a real wait.
        let mut r = test_record("f", 512, StartKind::Warm, 100);
        r.batch_wait = Duration::from_millis(25);
        s.record(r);
        let m = s.function_metrics("f");
        assert_eq!(m.invocations, 6);
        assert_eq!(m.batched_requests, 3, "only real coalescing counts as batched");
        assert_eq!(m.batch_size.count(), 4, "batch path requests incl. the lone leader");
        assert_eq!(m.batch_size.max(), 3);
        assert_eq!(m.batch_wait.count(), 4);
        assert!(m.batch_wait.p50() >= 24_000_000, "p50={}", m.batch_wait.p50());
        // Kernel telemetry: request-weighted rung histogram on the
        // batching path only; pass-level deltas counted once (the
        // followers carried zeros).
        assert_eq!(m.kernel_batch_n.count(), 4);
        assert_eq!(m.kernel_batch_n.max(), 2);
        assert_eq!(m.batch_kernel_hits, 1);
        assert_eq!(m.batch_kernel_misses, 1);
        // batch_wait is a response component.
        let batched = {
            let mut r = test_record("g", 512, StartKind::Warm, 100);
            r.batch_wait = Duration::from_millis(40);
            r
        };
        assert_eq!(batched.response(), Duration::from_millis(140));
        // Totals see the same stream.
        assert_eq!(s.platform_metrics().batched_requests, 3);
    }

    #[test]
    fn remove_function_drops_shard_but_keeps_totals() {
        let s = MetricsSink::new();
        s.record(test_record("f", 512, StartKind::Cold, 100));
        s.record(test_record("g", 512, StartKind::Warm, 100));
        s.remove_function("f");
        assert_eq!(s.function_metrics("f").invocations, 0, "shard memory released");
        assert_eq!(s.function_metrics("g").invocations, 1, "other shards untouched");
        assert_eq!(s.len(), 2, "platform totals keep the history");
        assert_eq!(s.cold_count(), 1);
        // Locked reads see the same data without copying the shard.
        assert_eq!(s.with_function("g", |m| m.invocations), Some(1));
        assert_eq!(s.with_function("f", |m| m.invocations), None);
        assert_eq!(s.with_totals(|m| m.invocations), 2);
    }

    #[test]
    fn ring_is_bounded_but_aggregates_are_not() {
        let s = MetricsSink::with_capacity(8);
        for i in 0..100 {
            let kind = if i % 10 == 0 { StartKind::Cold } else { StartKind::Warm };
            s.record(test_record("f", 512, kind, 100));
        }
        assert_eq!(s.records().len(), 8, "ring keeps only the newest 8");
        assert_eq!(s.len(), 100, "aggregate counters keep the full horizon");
        assert_eq!(s.cold_count(), 10);
        assert_eq!(s.function_metrics("f").invocations, 100);
        // Zero-capacity ring records aggregates only.
        let z = MetricsSink::with_capacity(0);
        z.record(test_record("f", 512, StartKind::Warm, 100));
        assert!(z.records().is_empty());
        assert_eq!(z.len(), 1);
    }
}
