//! Container lifecycle: the unit of cold/warm state.
//!
//! A container binds one live model instance (weights resident in its
//! engine shard) to one deployed function. Cold start = provisioning a
//! new container: simulated sandbox + runtime-init + package-fetch
//! delays (calibrated, CPU/IO-scaled) plus the *real* model compile +
//! weight materialization done by the engine. Warm start = reusing a
//! container from the pool, paying only the forward pass.

use super::metrics::StartKind;
use super::registry::FunctionSpec;
use super::throttle::CpuGovernor;
use crate::configparse::BootstrapConfig;
use crate::runtime::{Engine, InstanceHandle, KernelReport, Prediction, SnapshotBlob};
use crate::util::{Clock, SplitMix64};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

static NEXT_CONTAINER_ID: AtomicU64 = AtomicU64::new(0);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerState {
    /// Executing a request.
    Busy,
    /// Idle in the warm pool.
    Warm,
    /// Evicted; instance freed.
    Reaped,
}

/// Cost breakdown of a cold (or snapshot-restored) provision.
#[derive(Debug, Clone, Default)]
pub struct ProvisionCost {
    pub sandbox: Duration,
    pub runtime_init: Duration,
    pub package_fetch: Duration,
    /// Effective (CPU-scaled) model compile + weight materialization.
    pub model_load: Duration,
    /// Effective snapshot restore — blob fetch (I/O-scaled by the
    /// CPU/memory share, like the package fetch) plus the engine's
    /// weight re-upload (CPU-scaled, like the model load) — paid by a
    /// snapshot-restored provision INSTEAD of the
    /// `runtime_init`/`package_fetch`/`model_load` trio; zero on the
    /// full cold path.
    pub restore: Duration,
}

impl ProvisionCost {
    pub fn total(&self) -> Duration {
        self.sandbox + self.runtime_init + self.package_fetch + self.model_load + self.restore
    }

    /// The components attributed to a request of the given start
    /// kind: the real costs for the (cold or restored) request that
    /// provisioned the container, all-zero for warm reuse — so record
    /// builders copy fields instead of re-gating each one.
    pub fn attributed_to(&self, start: StartKind) -> ProvisionCost {
        match start {
            StartKind::Cold | StartKind::Restored => self.clone(),
            StartKind::Warm => ProvisionCost::default(),
        }
    }

    /// The provision components that ran INSIDE the handler — billed
    /// in 2017-era Lambda (the platform-side sandbox is not).
    pub fn handler_time(&self) -> Duration {
        self.runtime_init + self.package_fetch + self.model_load + self.restore
    }
}

pub struct Container {
    pub id: u64,
    pub spec: Arc<FunctionSpec>,
    handle: InstanceHandle,
    engine: Arc<dyn Engine>,
    state: ContainerState,
    /// Platform-clock time of last use (keep-alive eviction).
    pub last_used: u64,
    /// Requests served by this container.
    pub served: u64,
    pub provision_cost: ProvisionCost,
    /// How this container came to exist: [`StartKind::Cold`] (full
    /// provision) or [`StartKind::Restored`] (from a snapshot).
    origin: StartKind,
}

impl Container {
    /// Cold-provision a container: simulate the platform-side
    /// bootstrap, then do the real model load through the engine.
    /// Sleeps the platform clock for each component (instant on
    /// virtual clocks) and returns the container plus its cost.
    pub fn provision(
        spec: Arc<FunctionSpec>,
        engine: Arc<dyn Engine>,
        governor: &CpuGovernor,
        bootstrap: &BootstrapConfig,
        clock: &Arc<dyn Clock>,
        rng: &mut SplitMix64,
    ) -> Result<Self> {
        let mem = spec.memory_mb;
        let share = governor.share(mem);

        // 1. Sandbox provisioning: platform-side, memory-independent.
        let sandbox = if bootstrap.simulate_delays {
            Duration::from_secs_f64(rng.lognormal(bootstrap.sandbox_median_s, bootstrap.sandbox_sigma))
        } else {
            Duration::ZERO
        };
        clock.sleep(sandbox);

        // 2. Language-runtime init: CPU-bound inside the container,
        //    scaled by the CPU share.
        let runtime_init = if bootstrap.simulate_delays {
            Duration::from_secs_f64(bootstrap.runtime_init_s / share)
        } else {
            Duration::ZERO
        };
        clock.sleep(runtime_init);

        // 3. Package fetch: I/O-bound; Lambda scales disk/network I/O
        //    with memory as well.
        let package_fetch = if bootstrap.simulate_delays {
            Duration::from_secs_f64(spec.package_bytes as f64 / bootstrap.package_read_bw / share)
        } else {
            Duration::ZERO
        };
        clock.sleep(package_fetch);

        // 4. REAL model load: compile (per-shard cache) + init run.
        //    Measured wall time, scaled into effective time.
        // lint:allow(wall-clock: measuring REAL engine wall time for CpuGovernor::throttle, which ignores it on virtual clocks)
        let t0 = Instant::now();
        let (handle, stats) = engine.create_instance(&spec.model, &spec.variant)?;
        let real = t0.elapsed();
        let model_load = governor.throttle(stats.compile + stats.init_run, real, mem);

        Ok(Self {
            id: NEXT_CONTAINER_ID.fetch_add(1, Ordering::Relaxed),
            spec,
            handle,
            engine,
            state: ContainerState::Busy,
            last_used: clock.now(),
            served: 0,
            provision_cost: ProvisionCost {
                sandbox,
                runtime_init,
                package_fetch,
                model_load,
                restore: Duration::ZERO,
            },
            origin: StartKind::Cold,
        })
    }

    /// Provision a container from an instance snapshot: simulate the
    /// sandbox (a restore still needs one to restore INTO), fetch the
    /// blob (I/O-bound, scaled by the CPU/memory share exactly like
    /// the package fetch it replaces), and run the engine's restore —
    /// no language-runtime init, no package fetch, no compile, no init
    /// execution. This is the saving the checkpoint/restore literature
    /// promises; everything skipped shows up as zeros in the cost.
    #[allow(clippy::too_many_arguments)]
    pub fn provision_from_snapshot(
        spec: Arc<FunctionSpec>,
        engine: Arc<dyn Engine>,
        governor: &CpuGovernor,
        bootstrap: &BootstrapConfig,
        restore_bw: f64,
        blob: &SnapshotBlob,
        clock: &Arc<dyn Clock>,
        rng: &mut SplitMix64,
    ) -> Result<Self> {
        let mem = spec.memory_mb;
        let share = governor.share(mem);

        // 1. Sandbox provisioning: platform-side, memory-independent —
        //    unchanged from the cold path.
        let sandbox = if bootstrap.simulate_delays {
            Duration::from_secs_f64(rng.lognormal(bootstrap.sandbox_median_s, bootstrap.sandbox_sigma))
        } else {
            Duration::ZERO
        };
        clock.sleep(sandbox);

        // 2. Snapshot fetch: I/O-bound, share-scaled like package
        //    fetch (simulated; the engine pays the real upload below).
        let fetch = if bootstrap.simulate_delays {
            Duration::from_secs_f64(blob.size_bytes as f64 / restore_bw / share)
        } else {
            Duration::ZERO
        };
        clock.sleep(fetch);

        // 3. REAL engine restore: weight upload from the blob, compile
        //    skipped via the capturing shard's cache. Measured wall
        //    time, scaled into effective time like the model load.
        // lint:allow(wall-clock: measuring REAL engine wall time for CpuGovernor::throttle, which ignores it on virtual clocks)
        let t0 = Instant::now();
        let (handle, stats) = engine.restore_instance(&spec.model, &spec.variant, blob)?;
        let real = t0.elapsed();
        let upload = governor.throttle(stats.compile + stats.init_run, real, mem);

        Ok(Self {
            id: NEXT_CONTAINER_ID.fetch_add(1, Ordering::Relaxed),
            spec,
            handle,
            engine,
            state: ContainerState::Busy,
            last_used: clock.now(),
            served: 0,
            provision_cost: ProvisionCost {
                sandbox,
                runtime_init: Duration::ZERO,
                package_fetch: Duration::ZERO,
                model_load: Duration::ZERO,
                restore: fetch + upload,
            },
            origin: StartKind::Restored,
        })
    }

    pub fn state(&self) -> ContainerState {
        self.state
    }

    /// The engine instance this container runs (snapshot capture).
    pub fn handle(&self) -> &InstanceHandle {
        &self.handle
    }

    /// Execute one prediction under the CPU governor; returns the raw
    /// engine prediction and the effective (throttled) duration.
    pub fn execute(
        &mut self,
        governor: &CpuGovernor,
        clock: &Arc<dyn Clock>,
        image_seed: u64,
    ) -> Result<(Prediction, Duration)> {
        assert_eq!(self.state, ContainerState::Busy, "execute on non-busy container");
        // lint:allow(wall-clock: measuring REAL engine wall time for CpuGovernor::throttle, which ignores it on virtual clocks)
        let t0 = Instant::now();
        let pred = self.engine.predict(&self.handle, image_seed)?;
        let real = t0.elapsed();
        let effective = governor.throttle(pred.compute, real, self.spec.memory_mb);
        self.served += 1;
        self.last_used = clock.now();
        Ok((pred, effective))
    }

    /// Execute one *batched* forward pass for `seeds.len()` coalesced
    /// requests under the CPU governor. Returns one raw prediction per
    /// seed (in order), the effective (throttled) duration of the
    /// whole batched pass — the caller splits billing across members
    /// (each is charged `effective / n`; everyone waits the full
    /// pass) — and the engine's [`KernelReport`] saying which compiled
    /// batch-N kernels served the flush. Counts every member in
    /// `served`: the batch is one forward pass but `n` requests of
    /// container work.
    pub fn execute_batch(
        &mut self,
        governor: &CpuGovernor,
        clock: &Arc<dyn Clock>,
        seeds: &[u64],
    ) -> Result<(Vec<Prediction>, Duration, KernelReport)> {
        self.execute_batch_capped(governor, clock, seeds, usize::MAX)
    }

    /// [`Self::execute_batch`] with the engine's batch-kernel ladder
    /// capped at `rung_cap` for this pass (the adaptive rung
    /// controller's output; `usize::MAX` is the identity, which is
    /// exactly what `execute_batch` passes).
    pub fn execute_batch_capped(
        &mut self,
        governor: &CpuGovernor,
        clock: &Arc<dyn Clock>,
        seeds: &[u64],
        rung_cap: usize,
    ) -> Result<(Vec<Prediction>, Duration, KernelReport)> {
        assert_eq!(self.state, ContainerState::Busy, "execute_batch on non-busy container");
        assert!(!seeds.is_empty(), "empty batch");
        // lint:allow(wall-clock: measuring REAL engine wall time for CpuGovernor::throttle, which ignores it on virtual clocks)
        let t0 = Instant::now();
        let (preds, kernels) =
            self.engine.predict_batch_report_capped(&self.handle, seeds, rung_cap)?;
        let real = t0.elapsed();
        let full_speed: Duration = preds.iter().map(|p| p.compute).sum();
        let effective = governor.throttle(full_speed, real, self.spec.memory_mb);
        self.served += seeds.len() as u64;
        self.last_used = clock.now();
        Ok((preds, effective, kernels))
    }

    /// Move Busy -> Warm (returned to the pool).
    pub fn park(&mut self, clock: &Arc<dyn Clock>) {
        assert_eq!(self.state, ContainerState::Busy);
        self.state = ContainerState::Warm;
        self.last_used = clock.now();
    }

    /// Move Warm -> Busy (acquired from the pool).
    pub fn activate(&mut self) {
        assert_eq!(self.state, ContainerState::Warm);
        self.state = ContainerState::Busy;
    }

    /// Evict: frees the engine instance.
    pub fn reap(&mut self) {
        if self.state != ContainerState::Reaped {
            self.engine.drop_instance(&self.handle);
            self.state = ContainerState::Reaped;
        }
    }

    /// Start kind for the request that provisioned this container:
    /// [`StartKind::Cold`] for a full provision, [`StartKind::Restored`]
    /// for a snapshot restore.
    pub fn start_kind_for_first_use(&self) -> StartKind {
        self.origin
    }
}

impl Drop for Container {
    fn drop(&mut self) {
        self.reap();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::registry::FunctionRegistry;
    use crate::runtime::{Engine as _, MockEngine};
    use crate::util::ManualClock;

    fn setup() -> (Arc<FunctionSpec>, Arc<MockEngine>, CpuGovernor, Arc<dyn Clock>) {
        let engine = Arc::new(MockEngine::paper_zoo());
        let reg = FunctionRegistry::new(engine.clone());
        let spec = reg.deploy("sq", "squeezenet", "pallas", 896).unwrap();
        let clock: Arc<dyn Clock> = ManualClock::new();
        let gov = CpuGovernor::new(1792, clock.clone());
        (spec, engine, gov, clock)
    }

    #[test]
    fn provision_accounts_all_components() {
        let (spec, engine, gov, clock) = setup();
        let mut rng = SplitMix64::new(1);
        let cfg = BootstrapConfig::default();
        let c = Container::provision(spec, engine.clone(), &gov, &cfg, &clock, &mut rng).unwrap();
        assert_eq!(c.state(), ContainerState::Busy);
        let pc = &c.provision_cost;
        assert!(pc.sandbox > Duration::ZERO);
        // runtime_init = 1.2s / 0.5 share = 2.4s.
        assert!((pc.runtime_init.as_secs_f64() - 2.4).abs() < 1e-9);
        assert!(pc.package_fetch > Duration::ZERO);
        assert!(pc.model_load > Duration::ZERO, "compile + init run");
        // The platform clock advanced by the simulated components.
        assert!(clock.now() > 0);
        assert_eq!(engine.live_instances(), 1);
    }

    #[test]
    fn provision_without_simulated_delays() {
        let (spec, engine, gov, clock) = setup();
        let mut rng = SplitMix64::new(1);
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        let c = Container::provision(spec, engine, &gov, &cfg, &clock, &mut rng).unwrap();
        assert_eq!(c.provision_cost.sandbox, Duration::ZERO);
        assert_eq!(c.provision_cost.runtime_init, Duration::ZERO);
        assert!(c.provision_cost.model_load > Duration::ZERO, "real work still counted");
    }

    #[test]
    fn provision_from_snapshot_pays_sandbox_plus_restore_only() {
        use crate::runtime::MOCK_RESTORE_BW;
        let (spec, engine, gov, clock) = setup();
        let mut rng = SplitMix64::new(1);
        let cfg = BootstrapConfig::default();
        // Capture a blob from a cold-provisioned container.
        let cold = Container::provision(
            spec.clone(), engine.clone(), &gov, &cfg, &clock, &mut rng,
        )
        .unwrap();
        assert_eq!(cold.start_kind_for_first_use(), StartKind::Cold);
        let blob = engine.snapshot_instance(cold.handle()).unwrap();

        const RESTORE_BW: f64 = 200e6;
        let t0 = clock.now();
        let c = Container::provision_from_snapshot(
            spec, engine.clone(), &gov, &cfg, RESTORE_BW, &blob, &clock, &mut rng,
        )
        .unwrap();
        assert_eq!(c.start_kind_for_first_use(), StartKind::Restored);
        let pc = &c.provision_cost;
        assert!(pc.sandbox > Duration::ZERO, "a restore still needs a sandbox");
        assert_eq!(pc.runtime_init, Duration::ZERO, "runtime state rides the snapshot");
        assert_eq!(pc.package_fetch, Duration::ZERO, "the blob replaces the package");
        assert_eq!(pc.model_load, Duration::ZERO, "no compile, no init run");
        // restore = blob fetch / share + engine upload / share, both
        // scaled by the 896 MB half share.
        let share = 0.5;
        let expect = blob.size_bytes as f64 / RESTORE_BW / share
            + blob.size_bytes as f64 / MOCK_RESTORE_BW / share;
        assert!((pc.restore.as_secs_f64() - expect).abs() < 1e-9, "restore={:?}", pc.restore);
        assert!(pc.total() < cold.provision_cost.total(), "strictly cheaper than cold");
        // The platform clock advanced by sandbox + restore exactly.
        assert_eq!(clock.now() - t0, (pc.sandbox + pc.restore).as_nanos() as u64);
        assert_eq!(engine.live_instances(), 2);
    }

    #[test]
    fn failed_restore_leaves_no_instance_but_spends_sandbox() {
        let (spec, engine, gov, clock) = setup();
        let mut rng = SplitMix64::new(2);
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        let cold =
            Container::provision(spec.clone(), engine.clone(), &gov, &cfg, &clock, &mut rng)
                .unwrap();
        let blob = engine.snapshot_instance(cold.handle()).unwrap();
        engine.fail_restore.store(true, std::sync::atomic::Ordering::SeqCst);
        let err = Container::provision_from_snapshot(
            spec, engine.clone(), &gov, &cfg, 200e6, &blob, &clock, &mut rng,
        );
        assert!(err.is_err());
        assert_eq!(engine.live_instances(), 1, "no half-created instance leaks");
    }

    #[test]
    fn execute_throttles_by_memory_share() {
        let (spec, engine, gov, clock) = setup();
        let mut rng = SplitMix64::new(2);
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        let mut c = Container::provision(spec, engine, &gov, &cfg, &clock, &mut rng).unwrap();
        let (pred, effective) = c.execute(&gov, &clock, 7).unwrap();
        // 896 MB = half share: effective = 2x full-speed compute.
        let expect = pred.compute.as_secs_f64() * 2.0;
        assert!((effective.as_secs_f64() - expect).abs() < 1e-9);
        assert_eq!(c.served, 1);
    }

    #[test]
    fn execute_batch_one_pass_shared_cost_per_request_served() {
        let (spec, engine, gov, clock) = setup();
        let mut rng = SplitMix64::new(5);
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        let mut c =
            Container::provision(spec, engine.clone(), &gov, &cfg, &clock, &mut rng).unwrap();
        let before = engine.predict_calls.load(std::sync::atomic::Ordering::SeqCst);
        let t0 = clock.now();
        let (preds, effective, kernels) = c.execute_batch(&gov, &clock, &[1, 2, 3, 4]).unwrap();
        assert_eq!(preds.len(), 4);
        assert_eq!(kernels.kernel_batch_n, 1, "mock ladder disabled by default");
        assert_eq!(
            engine.predict_calls.load(std::sync::atomic::Ordering::SeqCst),
            before + 1,
            "a batch is one engine forward pass"
        );
        assert_eq!(c.served, 4, "every member counts as served");
        // Effective = governor-scaled sum of the members' shares; the
        // platform clock advanced by exactly that (896 MB = 2x).
        let full: f64 = preds.iter().map(|p| p.compute.as_secs_f64()).sum();
        assert!((effective.as_secs_f64() - full * 2.0).abs() < 1e-9);
        assert_eq!(clock.now() - t0, effective.as_nanos() as u64);
        // Sublinear: the batch of 4 costs less than 4 solo passes.
        let solo = c.execute(&gov, &clock, 1).unwrap().1;
        assert!(effective < solo * 4, "batched {effective:?} vs 4x solo {solo:?}");
    }

    #[test]
    fn state_machine_roundtrip() {
        let (spec, engine, gov, clock) = setup();
        let mut rng = SplitMix64::new(3);
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        let mut c =
            Container::provision(spec, engine.clone(), &gov, &cfg, &clock, &mut rng).unwrap();
        c.park(&clock);
        assert_eq!(c.state(), ContainerState::Warm);
        c.activate();
        assert_eq!(c.state(), ContainerState::Busy);
        c.reap();
        assert_eq!(c.state(), ContainerState::Reaped);
        assert_eq!(engine.live_instances(), 0);
        // Reap is idempotent.
        c.reap();
        assert_eq!(engine.live_instances(), 0);
    }

    #[test]
    fn drop_reaps_instance() {
        let (spec, engine, gov, clock) = setup();
        let mut rng = SplitMix64::new(4);
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        {
            let _c = Container::provision(spec, engine.clone(), &gov, &cfg, &clock, &mut rng)
                .unwrap();
            assert_eq!(engine.live_instances(), 1);
        }
        assert_eq!(engine.live_instances(), 0, "drop frees the instance");
    }
}
