//! Adaptive hot-path controllers: SLO-driven batch windows,
//! kernel-rung selection, and predictive pre-provisioning.
//!
//! The static knobs (`batch_window_ms`, `batch_kernel_max`,
//! `min_warm`) force one operating point onto traffic that shifts by
//! the minute. This module closes the loop: telemetry that already
//! streams through [`crate::platform::metrics::FnMetrics`] feeds a
//! per-function [`PolicyEngine`] shard, and three controllers read it
//! back out on the hot path:
//!
//! - **Adaptive batch window.** Grow the leader's hold-open window
//!   toward `policy.window_cap_ms` while the dispatcher queue is
//!   non-empty and the arrival-rate forecast says followers will show
//!   up; halve it the moment the recent `batch_wait` p99 eats more
//!   than [`BATCH_WAIT_SLO_FRACTION`] of the function's SLO budget.
//!   Classic AIMD: additive increase chases throughput, multiplicative
//!   decrease defends the tail.
//! - **Adaptive kernel-rung selection.** Shards compile one batch-N
//!   executable per power-of-two rung up to `batch_kernel_max` —
//!   whether or not any flush ever fills the top rungs. The controller
//!   watches the recent flush-size distribution and caps the ladder at
//!   `next_power_of_two(p99)`, so a function whose flushes top out at
//!   3 stops paying compile time and executable cache for batch-8.
//! - **Predictive pre-provisioning.** A Holt (level + trend) forecast
//!   of the arrival rate projects demand one `forecast_horizon_s`
//!   ahead; the maintainer tops the warm pool up to the forecast
//!   before the burst lands instead of eating cold starts during it.
//!
//! Controllers default **off** (`policy.enabled = false`, per-function
//! `adaptive` override): with everything off, every read-back returns
//! the static value and the fixed pipeline is preserved bit-for-bit.
//!
//! Lock discipline: `state` is rank `policy.state` in
//! `PLATFORM_LOCK_ORDER`, ordered after `snapshots.inner` and before
//! the metrics locks. Every acquisition in this module is standalone —
//! callers feed the engine *after* releasing their own locks (arrival
//! after admission returns, record after `FnMetrics::record` returns),
//! never from inside a metrics shard section.

use crate::configparse::PolicyConfig;
use crate::platform::metrics::InvocationRecord;
use crate::platform::registry::FunctionSpec;
use crate::stats::WindowedHistogram;
use crate::util::{plock, Nanos};
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Share of the end-to-end SLO the batch-wait tail is allowed to
/// consume before the window controller backs off. Queueing, cold
/// starts, and the forward pass need the rest of the budget; a window
/// that alone burns a quarter of the SLO is already too greedy.
pub const BATCH_WAIT_SLO_FRACTION: f64 = 0.25;

/// Flush-size samples required inside the decay window before the rung
/// controller trusts the p99; below this it falls back to the static
/// ladder so a cold function is never under-provisioned on rungs.
const MIN_RUNG_SAMPLES: u64 = 4;

/// Ring slots in each decaying histogram (smoothness of expiry vs one
/// 64 KiB bucket vector per slot).
const WINDOW_SLICES: usize = 8;

/// Per-function controller state. One entry per function, created
/// lazily on first arrival/record and dropped on undeploy.
struct FnState {
    /// Previous arrival timestamp; `None` until the first request.
    last_arrival: Option<Nanos>,
    /// Holt level: smoothed arrival rate, requests/second.
    rate: f64,
    /// Holt trend: change of `rate` per second; projects bursts while
    /// they are still ramping.
    trend: f64,
    /// Recent batch-collector waits (ns), batching path only —
    /// mirrors the `FnMetrics` gate so solo traffic cannot dilute the
    /// tail the controller defends.
    batch_wait: WindowedHistogram,
    /// Recent flush sizes (requests per batched pass). Demand, not
    /// service: fed from `batch_size` rather than the served
    /// `kernel_batch_n`, so a capped ladder can still observe demand
    /// above the cap and grow back.
    flush_n: WindowedHistogram,
    /// Current controller-owned window; `None` until the first
    /// `effective_window` call seeds it from the static base.
    window_ms: Option<u64>,
    /// Times any controller changed its output for this function.
    adjustments: u64,
}

/// Read-only view of one function's controller state, surfaced through
/// the stats API.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PolicySnapshot {
    /// Smoothed arrival rate, requests/second (Holt level).
    pub arrival_rate_ewma: f64,
    /// The batch window the controller is currently handing to
    /// leaders, ms (the static base until the first adjustment).
    pub effective_batch_window_ms: u64,
    /// Cumulative controller output changes.
    pub policy_adjustments: u64,
}

/// The per-function controller layer. One instance per platform,
/// shared by the invoker hot path, the maintainer, and the stats API.
pub struct PolicyEngine {
    config: PolicyConfig,
    /// Per-function controller shards. Rank `policy.state` in
    /// `PLATFORM_LOCK_ORDER`: acquired standalone only — never while
    /// holding a metrics lock.
    state: Mutex<BTreeMap<String, FnState>>,
}

impl PolicyEngine {
    pub fn new(config: PolicyConfig) -> Self {
        Self { config, state: Mutex::new(BTreeMap::new()) }
    }

    pub fn config(&self) -> &PolicyConfig {
        &self.config
    }

    /// Whether the controllers steer this function: the per-function
    /// `adaptive` override wins, else the platform default.
    pub fn enabled_for(&self, spec: &FunctionSpec) -> bool {
        spec.adaptive.unwrap_or(self.config.enabled)
    }

    /// The latency target the window controller defends, ms.
    pub fn slo_target_ms(&self, spec: &FunctionSpec) -> u64 {
        spec.slo_target_ms.unwrap_or(self.config.slo_target_ms)
    }

    fn fresh_state(&self) -> FnState {
        let window = Duration::from_secs_f64(self.config.decay_window_s);
        FnState {
            last_arrival: None,
            rate: 0.0,
            trend: 0.0,
            batch_wait: WindowedHistogram::new(window, WINDOW_SLICES),
            flush_n: WindowedHistogram::new(window, WINDOW_SLICES),
            window_ms: None,
            adjustments: 0,
        }
    }

    /// Feed one admission into the Holt arrival forecast. Called once
    /// per request, after admission bookkeeping has released its own
    /// locks.
    pub fn on_arrival(&self, function: &str, now: Nanos) {
        let mut g = plock(&self.state);
        let st = match g.get_mut(function) {
            Some(st) => st,
            None => {
                g.insert(function.to_string(), self.fresh_state());
                g.get_mut(function).expect("just inserted")
            }
        };
        if let Some(prev) = st.last_arrival {
            // Holt's linear method on the instantaneous rate: the
            // level damps inter-arrival jitter, the trend projects a
            // ramp so the forecast leads a burst instead of trailing
            // it. dt clamps to 1 ns (same virtual-clock tick).
            let dt_s = now.saturating_sub(prev).max(1) as f64 / 1e9;
            let inst = 1.0 / dt_s;
            let a = self.config.ewma_alpha;
            let b = self.config.holt_beta;
            let prev_level = st.rate;
            let level = a * inst + (1.0 - a) * (st.rate + st.trend * dt_s);
            st.trend = b * ((level - prev_level) / dt_s) + (1.0 - b) * st.trend;
            st.rate = level;
        }
        st.last_arrival = Some(now);
    }

    /// Feed one finished invocation's telemetry into the decaying
    /// histograms. Called after `FnMetrics::record` returns (the
    /// metrics locks are released by then).
    pub fn on_record(&self, r: &InvocationRecord, now: Nanos) {
        let mut g = plock(&self.state);
        let st = match g.get_mut(&r.function) {
            Some(st) => st,
            None => {
                g.insert(r.function.clone(), self.fresh_state());
                g.get_mut(&r.function).expect("just inserted")
            }
        };
        // Same gate as FnMetrics::apply: only traffic that rode the
        // batcher describes the batching path.
        if r.batch_size > 1 || r.batch_wait > Duration::ZERO {
            st.batch_wait.record(now, r.batch_wait.as_nanos() as u64);
            st.flush_n.record(now, r.batch_size.max(1) as u64);
        }
    }

    /// The batch window a leader should hold open right now. `base` is
    /// the static per-function/platform window; with the controller
    /// off it is returned untouched (bit-for-bit fixed pipeline).
    ///
    /// AIMD: halve when the recent batch-wait p99 exceeds
    /// [`BATCH_WAIT_SLO_FRACTION`] of the SLO budget; otherwise grow
    /// by a quarter (at least 1 ms) toward the cap while the queue is
    /// backed up and the forecast expects at least one follower within
    /// a cap-sized window.
    pub fn effective_window(
        &self,
        spec: &FunctionSpec,
        base: Duration,
        queue_depth: usize,
        now: Nanos,
    ) -> Duration {
        if !self.enabled_for(spec) {
            return base;
        }
        let base_ms = base.as_millis() as u64;
        // Never cap below the operator's static setting: an explicit
        // large window is a floor on ambition, not an error.
        let cap_ms = self.config.window_cap_ms.max(base_ms);
        let mut g = plock(&self.state);
        let st = match g.get_mut(spec.name.as_str()) {
            Some(st) => st,
            None => {
                g.insert(spec.name.clone(), self.fresh_state());
                g.get_mut(spec.name.as_str()).expect("just inserted")
            }
        };
        let cur = st.window_ms.unwrap_or(base_ms);
        let budget_ns =
            (self.slo_target_ms(spec) as f64 * 1e6 * BATCH_WAIT_SLO_FRACTION) as u64;
        let wait = st.batch_wait.merged(now);
        let next = if wait.count() > 0 && wait.p99() > budget_ns {
            // Multiplicative decrease: the window is eating the SLO.
            cur / 2
        } else if queue_depth > 0 && st.rate * (cap_ms as f64 / 1e3) >= 1.0 {
            // Additive-ish increase: demand is queued and the forecast
            // says a cap-sized window would catch a follower.
            (cur + (cur / 4).max(1)).min(cap_ms)
        } else {
            cur
        };
        if next != cur {
            st.adjustments += 1;
        }
        st.window_ms = Some(next);
        Duration::from_millis(next)
    }

    /// The batch-kernel rung ladder this function's flushes should
    /// target: `next_power_of_two(recent flush-size p99)`, clamped to
    /// the engine ladder. Falls back to `ladder_max` with the
    /// controller off or fewer than [`MIN_RUNG_SAMPLES`] recent
    /// flushes.
    pub fn rung_target(&self, spec: &FunctionSpec, ladder_max: usize, now: Nanos) -> usize {
        if !self.enabled_for(spec) || ladder_max <= 1 {
            return ladder_max;
        }
        let g = plock(&self.state);
        let Some(st) = g.get(spec.name.as_str()) else {
            return ladder_max;
        };
        let h = st.flush_n.merged(now);
        if h.count() < MIN_RUNG_SAMPLES {
            return ladder_max;
        }
        (h.p99().max(1) as usize).next_power_of_two().min(ladder_max)
    }

    /// Warm containers the forecast wants standing by: the Holt rate
    /// projected one horizon ahead, integrated over the horizon,
    /// decayed by idle time so a function that went quiet releases its
    /// claim. Capped at `policy.max_prewarm`; returns 0 with the
    /// controller off (the maintainer then sees only `min_warm`).
    pub fn warm_target(&self, spec: &FunctionSpec, now: Nanos) -> usize {
        if !self.enabled_for(spec) {
            return 0;
        }
        let g = plock(&self.state);
        let Some(st) = g.get(spec.name.as_str()) else {
            return 0;
        };
        let Some(last) = st.last_arrival else {
            return 0;
        };
        let horizon = self.config.forecast_horizon_s;
        let idle_s = now.saturating_sub(last) as f64 / 1e9;
        let decay = (-idle_s / self.config.decay_window_s).exp();
        let forecast = (st.rate + st.trend * horizon).max(0.0) * decay;
        let target = (forecast * horizon).round() as usize;
        target.min(self.config.max_prewarm)
    }

    /// One function's controller view for the stats API; `None` if the
    /// function has no recorded traffic yet.
    pub fn snapshot_view(&self, function: &str) -> Option<PolicySnapshot> {
        let g = plock(&self.state);
        g.get(function).map(|st| PolicySnapshot {
            arrival_rate_ewma: st.rate,
            effective_batch_window_ms: st.window_ms.unwrap_or(0),
            policy_adjustments: st.adjustments,
        })
    }

    /// Platform-wide aggregate: summed arrival rate and adjustment
    /// count, max effective window (the most aggressive shard).
    pub fn platform_view(&self) -> PolicySnapshot {
        let g = plock(&self.state);
        let mut out = PolicySnapshot {
            arrival_rate_ewma: 0.0,
            effective_batch_window_ms: 0,
            policy_adjustments: 0,
        };
        for st in g.values() {
            out.arrival_rate_ewma += st.rate;
            out.effective_batch_window_ms =
                out.effective_batch_window_ms.max(st.window_ms.unwrap_or(0));
            out.policy_adjustments += st.adjustments;
        }
        out
    }

    /// Drop a function's controller state (undeploy).
    pub fn remove_function(&self, function: &str) {
        plock(&self.state).remove(function);
    }
}

impl std::fmt::Debug for PolicyEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = plock(&self.state);
        write!(f, "PolicyEngine(enabled={}, functions={})", self.config.enabled, g.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::metrics::StartKind;
    use crate::platform::registry::{FunctionPolicy, FunctionRegistry};
    use crate::runtime::MockEngine;
    use std::sync::Arc;

    const MS: Nanos = 1_000_000;
    const S: Nanos = 1_000_000_000;

    fn spec(policy: FunctionPolicy) -> Arc<FunctionSpec> {
        let reg = FunctionRegistry::new(Arc::new(MockEngine::paper_zoo()));
        reg.deploy_full("sq", "squeezenet", "pallas", 512, policy).unwrap()
    }

    fn adaptive_spec() -> Arc<FunctionSpec> {
        spec(FunctionPolicy { adaptive: Some(true), ..Default::default() })
    }

    fn engine() -> PolicyEngine {
        PolicyEngine::new(PolicyConfig::default())
    }

    fn record(batch_size: usize, batch_wait_ms: u64) -> InvocationRecord {
        let mut r = crate::platform::metrics::test_record("sq", 512, StartKind::Warm, 10);
        r.batch_size = batch_size;
        r.batch_wait = Duration::from_millis(batch_wait_ms);
        r
    }

    #[test]
    fn disabled_is_the_identity() {
        let p = engine();
        let s = spec(FunctionPolicy::default());
        assert!(!p.enabled_for(&s), "policy.enabled defaults off");
        for i in 0..50u64 {
            p.on_arrival("sq", i * MS);
            p.on_record(&record(4, 90), i * MS);
        }
        let base = Duration::from_millis(7);
        assert_eq!(p.effective_window(&s, base, 10, 60 * MS), base, "window untouched");
        assert_eq!(p.rung_target(&s, 8, 60 * MS), 8, "ladder untouched");
        assert_eq!(p.warm_target(&s, 60 * MS), 0, "no forecast top-up");
        let v = p.snapshot_view("sq").unwrap();
        assert_eq!(v.policy_adjustments, 0);
    }

    #[test]
    fn per_function_override_beats_platform_default() {
        let mut cfg = PolicyConfig::default();
        cfg.enabled = true;
        let p = PolicyEngine::new(cfg);
        let forced_off = spec(FunctionPolicy { adaptive: Some(false), ..Default::default() });
        assert!(!p.enabled_for(&forced_off));
        assert!(p.enabled_for(&spec(FunctionPolicy::default())), "platform default on");
        let p2 = engine();
        assert!(p2.enabled_for(&adaptive_spec()), "forced on over default-off");
    }

    #[test]
    fn slo_target_prefers_the_spec_override() {
        let p = engine();
        assert_eq!(p.slo_target_ms(&spec(FunctionPolicy::default())), 1_000);
        let s = spec(FunctionPolicy { slo_target_ms: Some(250), ..Default::default() });
        assert_eq!(p.slo_target_ms(&s), 250);
    }

    #[test]
    fn window_grows_under_sustained_queue_depth() {
        let p = engine();
        let s = adaptive_spec();
        // 1 kHz arrivals: rate ~1000/s, far above 1 follower per
        // 100 ms cap window.
        for i in 0..200u64 {
            p.on_arrival("sq", i * MS);
        }
        let base = Duration::from_millis(4);
        let mut last = base;
        for i in 0..40u64 {
            last = p.effective_window(&s, base, 3, (200 + i) * MS);
        }
        assert_eq!(last, Duration::from_millis(100), "grew to the cap");
        let v = p.snapshot_view("sq").unwrap();
        assert!(v.policy_adjustments > 0);
        assert!(v.arrival_rate_ewma > 500.0, "rate ewma tracked, got {}", v.arrival_rate_ewma);
    }

    #[test]
    fn window_does_not_grow_without_queue_depth_or_rate() {
        let p = engine();
        let s = adaptive_spec();
        for i in 0..200u64 {
            p.on_arrival("sq", i * MS);
        }
        let base = Duration::from_millis(4);
        // Queue empty: no growth even at high rate.
        assert_eq!(p.effective_window(&s, base, 0, 300 * MS), base);
        // Queue backed up but trickle traffic (one arrival per 10 s,
        // rate ~0.1/s): a 100 ms cap window cannot catch a follower,
        // so the window holds at base.
        let p2 = engine();
        for i in 0..5u64 {
            p2.on_arrival("sq", i * 10 * S);
        }
        assert_eq!(p2.effective_window(&s, base, 5, 50 * S), base);
        assert_eq!(p2.snapshot_view("sq").unwrap().policy_adjustments, 0);
    }

    #[test]
    fn window_shrinks_when_batch_wait_eats_the_slo() {
        let p = engine();
        let s = adaptive_spec();
        for i in 0..200u64 {
            p.on_arrival("sq", i * MS);
        }
        let base = Duration::from_millis(4);
        let mut w = base;
        for i in 0..40u64 {
            w = p.effective_window(&s, base, 3, (200 + i) * MS);
        }
        assert_eq!(w, Duration::from_millis(100));
        // Default SLO 1000 ms, budget 250 ms: 300 ms waits breach it.
        let t0 = 300 * MS;
        for i in 0..20u64 {
            p.on_record(&record(4, 300), t0 + i * MS);
        }
        let shrunk = p.effective_window(&s, base, 3, t0 + 21 * MS);
        assert_eq!(shrunk, Duration::from_millis(50), "halved within one tick");
        let mut w = shrunk;
        for i in 0..12u64 {
            w = p.effective_window(&s, base, 3, t0 + (22 + i) * MS);
        }
        assert_eq!(w, Duration::ZERO, "repeated breach collapses the window");
    }

    #[test]
    fn window_recovers_after_the_breach_ages_out() {
        let p = engine();
        let s = adaptive_spec();
        for i in 0..200u64 {
            p.on_arrival("sq", i * MS);
        }
        let base = Duration::from_millis(4);
        for i in 0..5u64 {
            p.on_record(&record(2, 400), (200 + i) * MS);
        }
        let w = p.effective_window(&s, base, 3, 210 * MS);
        assert!(w < base, "shrank on breach");
        // 10 minutes later the decaying window has dropped the breach
        // samples; growth resumes (rate EWMA is stale but the Holt
        // state persists, so re-arrivals restore it).
        let later = 600 * S;
        for i in 0..200u64 {
            p.on_arrival("sq", later + i * MS);
        }
        let mut w2 = w;
        for i in 0..40u64 {
            w2 = p.effective_window(&s, base, 3, later + (200 + i) * MS);
        }
        assert_eq!(w2, Duration::from_millis(100), "reclimbed to the cap");
    }

    #[test]
    fn rung_target_tracks_observed_flush_sizes() {
        let p = engine();
        let s = adaptive_spec();
        // Below the sample floor: static ladder.
        p.on_record(&record(2, 1), 0);
        assert_eq!(p.rung_target(&s, 8, MS), 8, "too few samples, fall back");
        for i in 0..50u64 {
            p.on_record(&record(3, 1), i * MS);
        }
        assert_eq!(p.rung_target(&s, 8, 60 * MS), 4, "p99=3 rounds up to rung 4");
        assert_eq!(p.rung_target(&s, 2, 60 * MS), 2, "never above the engine ladder");
        // Demand grows: the target follows (records carry demand, not
        // the capped served rung, so there is no feedback trap).
        let t1 = 60 * MS;
        for i in 0..300u64 {
            p.on_record(&record(8, 1), t1 + i * MS);
        }
        assert_eq!(p.rung_target(&s, 8, t1 + 301 * MS), 8);
        // Ladder 1 short-circuits (no batch kernels at all).
        assert_eq!(p.rung_target(&s, 1, t1 + 301 * MS), 1);
    }

    #[test]
    fn warm_target_forecasts_bursts_and_decays_when_idle() {
        let p = engine();
        let s = adaptive_spec();
        assert_eq!(p.warm_target(&s, 0), 0, "no state, no claim");
        // Steady 10 rps: forecast 10/s * 2 s horizon = 20, capped at 8.
        for i in 0..100u64 {
            p.on_arrival("sq", i * 100 * MS);
        }
        let now = 100 * 100 * MS;
        assert_eq!(p.warm_target(&s, now), 8, "burst claim capped at max_prewarm");
        // Five minutes idle: exp(-300/60) decays the claim to zero.
        assert_eq!(p.warm_target(&s, now + 300 * S), 0, "idle function releases its claim");
    }

    #[test]
    fn trend_leads_a_ramp() {
        let p = engine();
        // Inter-arrival gap shrinking 100 ms -> ~9 ms over 90
        // arrivals: the Holt trend should be positive, projecting the
        // ramp onward.
        let mut t = 0u64;
        for i in 0..90u64 {
            t += (100 - i) * MS;
            p.on_arrival("sq", t);
        }
        let g = plock(&p.state);
        let st = g.get("sq").unwrap();
        assert!(st.trend > 0.0, "ramp detected, trend={}", st.trend);
        assert!(st.rate > 10.0, "level climbing, rate={}", st.rate);
    }

    #[test]
    fn remove_function_drops_state() {
        let p = engine();
        p.on_arrival("sq", 0);
        assert!(p.snapshot_view("sq").is_some());
        p.remove_function("sq");
        assert!(p.snapshot_view("sq").is_none());
        assert_eq!(p.platform_view().policy_adjustments, 0);
    }

    #[test]
    fn platform_view_aggregates_across_functions() {
        let p = engine();
        for i in 1..=100u64 {
            p.on_arrival("a", i * 10 * MS);
            p.on_arrival("b", i * 10 * MS + MS);
        }
        let v = p.platform_view();
        assert!(v.arrival_rate_ewma > 150.0, "summed rates, got {}", v.arrival_rate_ewma);
        assert_eq!(v.policy_adjustments, 0);
    }
}
