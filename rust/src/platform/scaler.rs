//! Demand-driven scaling.
//!
//! Lambda's model (and ours): concurrency = number of in-flight
//! requests; each in-flight request needs its own container, so a
//! request that finds no warm container triggers a cold provision,
//! bounded by the account-level container cap. The scaler tracks
//! in-flight concurrency (the paper's Figure 7 ramp drives this up),
//! exposes a high-water mark, and supports *pre-warming* — the
//! "declarative keep-warm" capability the paper's §5 asks for, used by
//! the keep-alive/provisioned ablations.

use super::container::Container;
use super::metrics::StartKind;
use super::pool::WarmPool;
use super::registry::FunctionSpec;
use super::snapshots::SnapshotStore;
use super::throttle::CpuGovernor;
use crate::configparse::BootstrapConfig;
use crate::runtime::Engine;
use crate::util::{plock, Clock, SplitMix64};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

#[derive(Default)]
pub struct Scaler {
    in_flight: AtomicUsize,
    high_water: AtomicUsize,
    throttled: AtomicUsize,
    /// Requests refused with 503: admission queue at its bound, or a
    /// parked request's deadline exhausted. Kept apart from
    /// `throttled` (429: per-function concurrency cap) because the
    /// two signals ask the caller for different remedies.
    saturated: AtomicUsize,
    /// Demand-driven FULL cold provisions only: a request arrived,
    /// found no warm container, and no snapshot restored. This is the
    /// request-visible cold-start supply side the paper's analysis
    /// keys on.
    cold_provisions: AtomicUsize,
    /// Demand-driven provisions served from a snapshot restore — kept
    /// apart from `cold_provisions` so the snapshot-vs-cold ablation
    /// reads straight off the counters.
    restored_provisions: AtomicUsize,
    /// Operator/maintainer-initiated provisions (deploy-time
    /// `min_warm`, `/v1/prewarm`, pool-maintainer top-ups). Kept
    /// separate so pre-warming does not inflate the cold-start rate.
    prewarm_provisions: AtomicUsize,
}

/// RAII guard for one in-flight request.
pub struct FlightGuard<'a>(&'a Scaler);

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Scaler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an arriving request.
    pub fn arrive(&self) -> FlightGuard<'_> {
        let now = self.in_flight.fetch_add(1, Ordering::SeqCst) + 1;
        self.high_water.fetch_max(now, Ordering::SeqCst);
        FlightGuard(self)
    }

    pub fn note_throttled(&self) {
        self.throttled.fetch_add(1, Ordering::SeqCst);
    }

    pub fn note_saturated(&self) {
        self.saturated.fetch_add(1, Ordering::SeqCst);
    }

    pub fn note_cold_provision(&self) {
        self.cold_provisions.fetch_add(1, Ordering::SeqCst);
    }

    pub fn note_restored_provision(&self) {
        self.restored_provisions.fetch_add(1, Ordering::SeqCst);
    }

    pub fn note_prewarm_provision(&self) {
        self.prewarm_provisions.fetch_add(1, Ordering::SeqCst);
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Peak concurrency observed (the scalability experiments report
    /// this against the request ramp).
    pub fn high_water_mark(&self) -> usize {
        self.high_water.load(Ordering::SeqCst)
    }

    pub fn throttled_count(&self) -> usize {
        self.throttled.load(Ordering::SeqCst)
    }

    pub fn saturated_count(&self) -> usize {
        self.saturated.load(Ordering::SeqCst)
    }

    pub fn cold_provision_count(&self) -> usize {
        self.cold_provisions.load(Ordering::SeqCst)
    }

    pub fn restored_provision_count(&self) -> usize {
        self.restored_provisions.load(Ordering::SeqCst)
    }

    pub fn prewarm_provision_count(&self) -> usize {
        self.prewarm_provisions.load(Ordering::SeqCst)
    }

    /// Demand-driven cold provision for one admitted request that
    /// already holds a capacity reservation (granted by the waitable
    /// pool's `acquire_or_reserve`). This is the single place the
    /// cold-provision decision lives: exactly one provision per
    /// admitted request, so N requests missing warm capacity
    /// simultaneously provision N containers — never a stampede of
    /// retries per request. The provision goes through the snapshot
    /// store, which restores from a checkpoint when it holds one for
    /// the function's shape (and schedules a capture after a full
    /// cold otherwise). On failure the reservation is returned to the
    /// pool (waking a parked waiter) before the error propagates.
    #[allow(clippy::too_many_arguments)]
    pub fn provision_demand(
        &self,
        spec: &Arc<FunctionSpec>,
        pool: &WarmPool,
        engine: &Arc<dyn Engine>,
        governor: &CpuGovernor,
        bootstrap: &BootstrapConfig,
        snapshots: &Arc<SnapshotStore>,
        clock: &Arc<dyn Clock>,
        rng: &Mutex<SplitMix64>,
    ) -> Result<Container> {
        // Draw a child seed under the lock, then provision with a
        // local RNG: concurrent cold starts (and maintainer
        // replenishment) must never serialize on the multi-second
        // bootstrap sleeps.
        let mut local = SplitMix64::new(plock(&rng).next_u64());
        let provisioned =
            snapshots.provision(spec, engine, governor, bootstrap, clock, &mut local);
        match provisioned {
            Ok(c) => {
                if c.start_kind_for_first_use() == StartKind::Restored {
                    self.note_restored_provision();
                } else {
                    self.note_cold_provision();
                }
                Ok(c)
            }
            Err(e) => {
                pool.cancel_reservation();
                Err(e)
            }
        }
    }

    /// Pre-warm `n` containers for `spec` into the pool (the paper's
    /// requested "minimum time to keep warm containers" knob). Like
    /// the demand path, each provision goes through the snapshot
    /// store: a maintainer top-up restores from a checkpoint when one
    /// exists, and the first full cold prewarm seeds one.
    #[allow(clippy::too_many_arguments)]
    pub fn prewarm(
        &self,
        spec: &Arc<FunctionSpec>,
        n: usize,
        pool: &WarmPool,
        engine: &Arc<dyn Engine>,
        governor: &CpuGovernor,
        bootstrap: &BootstrapConfig,
        snapshots: &Arc<SnapshotStore>,
        clock: &Arc<dyn Clock>,
        rng: &Mutex<SplitMix64>,
    ) -> Result<usize> {
        let mut done = 0;
        for _ in 0..n {
            if !pool.try_reserve() {
                bail!("container cap hit after pre-warming {done} of {n}");
            }
            // Child-seed a local RNG so the shared lock is not held
            // across the (possibly multi-second) provisioning sleeps —
            // a background top-up must not stall request-path cold
            // starts waiting on the same RNG.
            let mut r = SplitMix64::new(plock(&rng).next_u64());
            match snapshots.provision(spec, engine, governor, bootstrap, clock, &mut r) {
                Ok(c) => {
                    // Operator-initiated: NOT a request-visible cold
                    // start (that counter feeds the cold-start rate).
                    self.note_prewarm_provision();
                    pool.release(c);
                    done += 1;
                }
                Err(e) => {
                    pool.cancel_reservation();
                    return Err(e);
                }
            }
        }
        Ok(done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configparse::{CapturePolicy, SnapshotConfig};
    use crate::platform::registry::FunctionRegistry;
    use crate::runtime::MockEngine;
    use crate::util::ManualClock;

    fn no_snapshots() -> Arc<SnapshotStore> {
        Arc::new(SnapshotStore::new(SnapshotConfig::default()))
    }

    #[test]
    fn flight_accounting() {
        let s = Scaler::new();
        assert_eq!(s.in_flight(), 0);
        {
            let _a = s.arrive();
            let _b = s.arrive();
            assert_eq!(s.in_flight(), 2);
            assert_eq!(s.high_water_mark(), 2);
        }
        assert_eq!(s.in_flight(), 0);
        assert_eq!(s.high_water_mark(), 2, "high water sticks");
        let _c = s.arrive();
        assert_eq!(s.high_water_mark(), 2);
    }

    #[test]
    fn counters() {
        let s = Scaler::new();
        s.note_throttled();
        s.note_throttled();
        s.note_saturated();
        s.note_cold_provision();
        s.note_prewarm_provision();
        assert_eq!(s.throttled_count(), 2);
        assert_eq!(s.saturated_count(), 1);
        assert_eq!(s.cold_provision_count(), 1);
        assert_eq!(s.prewarm_provision_count(), 1);
    }

    #[test]
    fn provision_demand_counts_cold_and_returns_reservation_on_failure() {
        let mock = Arc::new(MockEngine::paper_zoo());
        let engine: Arc<dyn Engine> = mock.clone();
        let reg = FunctionRegistry::new(engine.clone());
        let spec = reg.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        let clock: Arc<dyn Clock> = ManualClock::new();
        let pool = WarmPool::new(4, 600.0, clock.clone());
        let gov = CpuGovernor::new(1792, clock.clone());
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        let s = Scaler::new();
        let rng = Mutex::new(SplitMix64::new(0));
        let snaps = no_snapshots();

        assert!(pool.try_reserve());
        let c = s
            .provision_demand(&spec, &pool, &engine, &gov, &cfg, &snaps, &clock, &rng)
            .unwrap();
        assert_eq!(s.cold_provision_count(), 1);
        assert_eq!(s.prewarm_provision_count(), 0, "demand provisions are not prewarms");
        assert_eq!(s.restored_provision_count(), 0);
        pool.retire(c);
        assert_eq!(pool.total_alive(), 0);

        // A failed provision hands the reserved slot back.
        mock.fail_create.store(true, std::sync::atomic::Ordering::SeqCst);
        assert!(pool.try_reserve());
        assert!(s
            .provision_demand(&spec, &pool, &engine, &gov, &cfg, &snaps, &clock, &rng)
            .is_err());
        assert_eq!(pool.total_alive(), 0, "reservation cancelled on failure");
        assert_eq!(s.cold_provision_count(), 1, "failed provision not counted");
    }

    /// Snapshot-aware demand provisioning: the first demand provision
    /// is a full cold (captured), the second restores — and the two
    /// land in their own counters.
    #[test]
    fn provision_demand_splits_cold_and_restored_counters() {
        let engine: Arc<dyn Engine> = Arc::new(MockEngine::paper_zoo());
        let reg = FunctionRegistry::new(engine.clone());
        let spec = reg.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        let clock: Arc<dyn Clock> = ManualClock::new();
        let pool = WarmPool::new(4, 600.0, clock.clone());
        let gov = CpuGovernor::new(1792, clock.clone());
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        let s = Scaler::new();
        let rng = Mutex::new(SplitMix64::new(0));
        let snaps = Arc::new(SnapshotStore::new(SnapshotConfig {
            enabled: true,
            capture_policy: CapturePolicy::Sync,
            ..Default::default()
        }));
        assert!(pool.try_reserve());
        let c1 = s
            .provision_demand(&spec, &pool, &engine, &gov, &cfg, &snaps, &clock, &rng)
            .unwrap();
        assert!(pool.try_reserve());
        let c2 = s
            .provision_demand(&spec, &pool, &engine, &gov, &cfg, &snaps, &clock, &rng)
            .unwrap();
        assert_eq!(s.cold_provision_count(), 1);
        assert_eq!(s.restored_provision_count(), 1);
        assert_eq!(c2.start_kind_for_first_use(), StartKind::Restored);
        pool.retire(c1);
        pool.retire(c2);
    }

    #[test]
    fn prewarm_fills_pool() {
        let engine: Arc<dyn Engine> = Arc::new(MockEngine::paper_zoo());
        let reg = FunctionRegistry::new(engine.clone());
        let spec = reg.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        let clock: Arc<dyn Clock> = ManualClock::new();
        let pool = WarmPool::new(8, 600.0, clock.clone());
        let gov = CpuGovernor::new(1792, clock.clone());
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        let s = Scaler::new();
        let rng = Mutex::new(SplitMix64::new(0));
        let n = s
            .prewarm(&spec, 3, &pool, &engine, &gov, &cfg, &no_snapshots(), &clock, &rng)
            .unwrap();
        assert_eq!(n, 3);
        assert_eq!(pool.warm_count("sq"), 3);
        assert_eq!(pool.total_alive(), 3);
        // Regression: pre-warms are tracked separately and must not
        // inflate the request-visible cold-start rate.
        assert_eq!(s.prewarm_provision_count(), 3);
        assert_eq!(s.cold_provision_count(), 0);
    }

    #[test]
    fn prewarm_respects_cap() {
        let engine: Arc<dyn Engine> = Arc::new(MockEngine::paper_zoo());
        let reg = FunctionRegistry::new(engine.clone());
        let spec = reg.deploy("sq", "squeezenet", "pallas", 512).unwrap();
        let clock: Arc<dyn Clock> = ManualClock::new();
        let pool = WarmPool::new(2, 600.0, clock.clone());
        let gov = CpuGovernor::new(1792, clock.clone());
        let cfg = BootstrapConfig { simulate_delays: false, ..Default::default() };
        let s = Scaler::new();
        let rng = Mutex::new(SplitMix64::new(0));
        let err = s
            .prewarm(&spec, 5, &pool, &engine, &gov, &cfg, &no_snapshots(), &clock, &rng)
            .unwrap_err();
        assert!(err.to_string().contains("cap"));
        assert_eq!(pool.warm_count("sq"), 2);
    }
}
