//! Workload generators — the JMeter analog.
//!
//! The paper's three request schedules (§3.1, §3.4):
//!
//! * **warm probe**: one discarded warm-up request, then 25 sequential
//!   requests at 1 s intervals;
//! * **cold probe**: 5 sequential requests separated by 10-minute gaps
//!   (beyond the keep-alive TTL, forcing a cold start each time);
//! * **step ramp** (Figure 7): request rate increases by `increment`
//!   req/s every `step` seconds for `steps` steps.
//!
//! plus a Poisson open-loop generator for the ablations. Drivers run
//! against a [`crate::platform::Platform`] and add the client<->gateway
//! network model to the platform-side response to produce the
//! client-observed latency (what JMeter measured).

mod diurnal;
mod driver;
mod schedule;

pub use diurnal::DiurnalTrace;
pub use driver::{run_closed_loop, run_open_loop, ClientSample, DriverReport};
pub use schedule::{ColdProbe, PoissonArrivals, Schedule, StepRamp, WarmProbe};
