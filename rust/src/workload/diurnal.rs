//! Diurnal + bursty trace generator.
//!
//! The paper's §4 argues dedicated serving systems "are not designed to
//! minimize operational costs when demand ... is quickly changing or
//! even unpredictable", and §5 proposes VM+serverless mixes. This
//! schedule models that regime: a sinusoidal day/night rate profile
//! with Poisson micro-structure plus random short bursts — the workload
//! where serverless economics shine. Used by `abl-provisioned`.

use super::schedule::Schedule;
use crate::util::SplitMix64;
use std::time::Duration;

pub struct DiurnalTrace {
    /// Mean request rate over the whole trace, req/s.
    pub mean_rps: f64,
    /// Peak-to-trough ratio of the sinusoid (>= 1).
    pub swing: f64,
    /// Trace duration.
    pub duration: Duration,
    /// Period of the sinusoid (24 h for a literal day; shorter for
    /// compressed simulations).
    pub period: Duration,
    /// Expected number of bursts over the trace.
    pub bursts: usize,
    /// Burst intensity: multiple of the base rate during a burst.
    pub burst_factor: f64,
    /// Burst length.
    pub burst_len: Duration,
    pub seed: u64,
}

impl DiurnalTrace {
    /// A compressed "day": 1 h trace with a 1 h period.
    pub fn compressed_day(mean_rps: f64, seed: u64) -> Self {
        Self {
            mean_rps,
            swing: 4.0,
            duration: Duration::from_secs(3600),
            period: Duration::from_secs(3600),
            bursts: 3,
            burst_factor: 6.0,
            burst_len: Duration::from_secs(60),
            seed,
        }
    }

    /// Instantaneous rate at offset `t` seconds (before bursts).
    fn base_rate(&self, t: f64) -> f64 {
        // Sinusoid with mean `mean_rps` and min/max ratio `swing`:
        // rate(t) = mean * (1 + a*sin) with a = (swing-1)/(swing+1).
        let a = (self.swing - 1.0) / (self.swing + 1.0);
        let phase = t / self.period.as_secs_f64() * std::f64::consts::TAU;
        self.mean_rps * (1.0 + a * phase.sin())
    }
}

impl Schedule for DiurnalTrace {
    fn arrivals(&self) -> Vec<Duration> {
        let mut rng = SplitMix64::new(self.seed);
        let total = self.duration.as_secs_f64();

        // Burst windows.
        let bursts: Vec<(f64, f64)> = (0..self.bursts)
            .map(|_| {
                let start = rng.next_f64() * total;
                (start, start + self.burst_len.as_secs_f64())
            })
            .collect();

        // Thinning algorithm for the inhomogeneous Poisson process.
        let rate_at = |t: f64| {
            let mut r = self.base_rate(t);
            for (s, e) in &bursts {
                if t >= *s && t < *e {
                    r *= self.burst_factor;
                }
            }
            r
        };
        let a = (self.swing - 1.0) / (self.swing + 1.0);
        let max_rate = self.mean_rps * (1.0 + a) * self.burst_factor;

        let mut out = Vec::new();
        let mut t = 0.0;
        loop {
            t += rng.exponential(1.0 / max_rate);
            if t >= total {
                break;
            }
            if rng.next_f64() < rate_at(t) / max_rate {
                out.push(Duration::from_secs_f64(t));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(seed: u64) -> DiurnalTrace {
        DiurnalTrace::compressed_day(1.0, seed)
    }

    #[test]
    fn mean_rate_close_to_target() {
        let a = trace(1).arrivals();
        let rate = a.len() as f64 / 3600.0;
        // Bursts push the mean above the sinusoid's 1.0 baseline, but
        // with 3 x 60 s x 6x bursts the inflation is bounded (~+30%).
        assert!(rate > 0.8 && rate < 1.8, "rate={rate}");
    }

    #[test]
    fn sorted_and_in_range() {
        let a = trace(2).arrivals();
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert!(a.iter().all(|t| *t < Duration::from_secs(3600)));
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(trace(3).arrivals(), trace(3).arrivals());
        assert_ne!(trace(3).arrivals(), trace(4).arrivals());
    }

    #[test]
    fn diurnal_swing_visible() {
        // Compare first-quarter (rising sinusoid) vs third-quarter
        // (trough) arrival counts; they must differ substantially.
        let t = DiurnalTrace { bursts: 0, ..trace(5) };
        let a = t.arrivals();
        let q = |lo: f64, hi: f64| {
            a.iter()
                .filter(|x| {
                    let s = x.as_secs_f64();
                    s >= lo * 3600.0 && s < hi * 3600.0
                })
                .count() as f64
        };
        let peak = q(0.0, 0.5); // sin positive half
        let trough = q(0.5, 1.0);
        assert!(peak > trough * 1.8, "peak={peak} trough={trough}");
    }

    #[test]
    fn bursts_add_arrivals() {
        let without = DiurnalTrace { bursts: 0, ..trace(6) }.arrivals().len();
        let with = DiurnalTrace { bursts: 5, ..trace(6) }.arrivals().len();
        assert!(with > without, "bursts add load: {with} vs {without}");
    }

    #[test]
    fn base_rate_bounds() {
        let t = trace(7);
        let a = (t.swing - 1.0) / (t.swing + 1.0);
        for i in 0..100 {
            let r = t.base_rate(i as f64 * 36.0);
            assert!(r >= t.mean_rps * (1.0 - a) - 1e-9);
            assert!(r <= t.mean_rps * (1.0 + a) + 1e-9);
        }
    }
}
