//! Request schedules: when does each request start, relative to t=0.

use crate::util::SplitMix64;
use std::time::Duration;

/// A schedule yields the absolute start offset of each request.
pub trait Schedule {
    /// Offsets (sorted, from experiment start) of every request.
    fn arrivals(&self) -> Vec<Duration>;

    /// Requests whose measurements should be discarded (JMeter-style
    /// warm-up). Indices into `arrivals()`.
    fn discard_prefix(&self) -> usize {
        0
    }
}

/// §3.1: "send a request, disregard it, then send 25 sequential
/// requests separated by one-second intervals".
pub struct WarmProbe {
    pub requests: usize,
    pub interval: Duration,
}

impl Default for WarmProbe {
    fn default() -> Self {
        Self { requests: 25, interval: Duration::from_secs(1) }
    }
}

impl Schedule for WarmProbe {
    fn arrivals(&self) -> Vec<Duration> {
        // +1 for the discarded warm-up request at t=0.
        (0..=self.requests).map(|i| self.interval * i as u32).collect()
    }

    fn discard_prefix(&self) -> usize {
        1
    }
}

/// §3.1: "5 sequential requests separated by 10 minutes of wait time".
pub struct ColdProbe {
    pub requests: usize,
    pub gap: Duration,
}

impl Default for ColdProbe {
    fn default() -> Self {
        Self { requests: 5, gap: Duration::from_secs(600) }
    }
}

impl Schedule for ColdProbe {
    fn arrivals(&self) -> Vec<Duration> {
        (0..self.requests).map(|i| self.gap * i as u32).collect()
    }
}

/// Figure 7: start at `initial_rps`, add `increment_rps` every
/// `step` seconds, for `steps` steps. Arrivals are uniformly spaced
/// within each step.
pub struct StepRamp {
    pub initial_rps: f64,
    pub increment_rps: f64,
    pub step: Duration,
    pub steps: usize,
}

impl StepRamp {
    /// The paper's configuration: 10 req/s initial, +10 req/s per
    /// 10-second step, 10 steps.
    pub fn paper() -> Self {
        Self { initial_rps: 10.0, increment_rps: 10.0, step: Duration::from_secs(10), steps: 10 }
    }

    /// A scaled-down ramp with the same shape for quick benches.
    pub fn scaled(factor: f64) -> Self {
        Self {
            initial_rps: 10.0 * factor,
            increment_rps: 10.0 * factor,
            step: Duration::from_secs(2),
            steps: 5,
        }
    }

    /// Request rate during step `k` (0-based).
    pub fn rate_at_step(&self, k: usize) -> f64 {
        self.initial_rps + self.increment_rps * k as f64
    }
}

impl Schedule for StepRamp {
    fn arrivals(&self) -> Vec<Duration> {
        let mut out = Vec::new();
        let step_s = self.step.as_secs_f64();
        for k in 0..self.steps {
            let rate = self.rate_at_step(k);
            let n = (rate * step_s).round() as usize;
            let t0 = step_s * k as f64;
            for i in 0..n {
                out.push(Duration::from_secs_f64(t0 + step_s * i as f64 / n.max(1) as f64));
            }
        }
        out
    }
}

/// Open-loop Poisson arrivals at `rps` for `duration` (ablations).
pub struct PoissonArrivals {
    pub rps: f64,
    pub duration: Duration,
    pub seed: u64,
}

impl Schedule for PoissonArrivals {
    fn arrivals(&self) -> Vec<Duration> {
        let mut rng = SplitMix64::new(self.seed);
        let mut t = 0.0;
        let mut out = Vec::new();
        while t < self.duration.as_secs_f64() {
            t += rng.exponential(1.0 / self.rps);
            if t < self.duration.as_secs_f64() {
                out.push(Duration::from_secs_f64(t));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warm_probe_matches_paper() {
        let s = WarmProbe::default();
        let a = s.arrivals();
        assert_eq!(a.len(), 26, "1 discarded + 25 measured");
        assert_eq!(s.discard_prefix(), 1);
        assert_eq!(a[1] - a[0], Duration::from_secs(1));
        assert_eq!(*a.last().unwrap(), Duration::from_secs(25));
    }

    #[test]
    fn cold_probe_matches_paper() {
        let s = ColdProbe::default();
        let a = s.arrivals();
        assert_eq!(a.len(), 5);
        assert_eq!(a[4], Duration::from_secs(2400), "10-minute gaps");
        assert_eq!(s.discard_prefix(), 0);
    }

    #[test]
    fn step_ramp_paper_counts() {
        let s = StepRamp::paper();
        let a = s.arrivals();
        // 10*10 + 20*10 + ... + 100*10 = 10s * (10+...+100) = 5500.
        assert_eq!(a.len(), 5500);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted");
        assert_eq!(s.rate_at_step(0), 10.0);
        assert_eq!(s.rate_at_step(9), 100.0);
        // Last step's arrivals all within [90s, 100s).
        let last_step: Vec<_> =
            a.iter().filter(|t| **t >= Duration::from_secs(90)).collect();
        assert_eq!(last_step.len(), 1000);
    }

    #[test]
    fn step_ramp_scaled_preserves_shape() {
        let s = StepRamp::scaled(0.5);
        assert_eq!(s.steps, 5);
        assert_eq!(s.rate_at_step(1) / s.rate_at_step(0), 2.0);
    }

    #[test]
    fn poisson_rate_close() {
        let s = PoissonArrivals { rps: 50.0, duration: Duration::from_secs(100), seed: 1 };
        let a = s.arrivals();
        let rate = a.len() as f64 / 100.0;
        assert!((rate - 50.0).abs() < 5.0, "rate={rate}");
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // Deterministic for a seed.
        assert_eq!(a, PoissonArrivals { rps: 50.0, duration: Duration::from_secs(100), seed: 1 }.arrivals());
    }
}
