//! Drivers: replay a schedule against a platform and collect
//! client-observed samples (platform response + network model).

use super::schedule::Schedule;
use crate::configparse::NetworkConfig;
use crate::exec::ThreadPool;
use crate::platform::{InvokeError, Platform, StartKind};
use crate::util::SplitMix64;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One client-side measurement.
#[derive(Debug, Clone)]
pub struct ClientSample {
    /// Schedule offset at which the request was issued.
    pub at: Duration,
    /// Client-observed latency (network + platform response).
    pub latency: Duration,
    /// In-function prediction time (the paper's second series).
    pub predict: Duration,
    pub start: StartKind,
    pub cost_dollars: f64,
    /// `None` on success; `Some(kind)` on failure.
    pub error: Option<String>,
}

/// Aggregated driver output.
#[derive(Debug, Default)]
pub struct DriverReport {
    pub samples: Vec<ClientSample>,
    pub discarded: usize,
    /// 429s: per-function concurrency cap.
    pub throttled: usize,
    /// 503s: admission queue full or dispatch deadline exhausted —
    /// the request waited its bounded queue delay and still found no
    /// capacity.
    pub saturated: usize,
    pub failed: usize,
}

impl DriverReport {
    pub fn ok_samples(&self) -> Vec<&ClientSample> {
        self.samples.iter().filter(|s| s.error.is_none()).collect()
    }

    pub fn latencies_s(&self) -> Vec<f64> {
        self.ok_samples().iter().map(|s| s.latency.as_secs_f64()).collect()
    }

    pub fn predicts_s(&self) -> Vec<f64> {
        self.ok_samples().iter().map(|s| s.predict.as_secs_f64()).collect()
    }

    pub fn total_cost(&self) -> f64 {
        self.ok_samples().iter().map(|s| s.cost_dollars).sum()
    }

    pub fn cold_count(&self) -> usize {
        self.ok_samples().iter().filter(|s| s.start == StartKind::Cold).count()
    }

    /// Requests served by a snapshot-restored provision.
    pub fn restored_count(&self) -> usize {
        self.ok_samples().iter().filter(|s| s.start == StartKind::Restored).count()
    }
}

fn network_delay(net: &NetworkConfig, rng: &mut SplitMix64) -> Duration {
    Duration::from_secs_f64(net.rtt_s + rng.exponential(net.jitter_mean_s))
}

/// Sequential (closed-loop) replay: used by the warm and cold probes,
/// where the paper issues one request at a time. Between requests the
/// platform clock is advanced by the schedule gap (so keep-alive
/// eviction sees the paper's 10-minute waits without wall-clock cost on
/// virtual/manual clocks).
pub fn run_closed_loop(
    platform: &Platform,
    function: &str,
    schedule: &dyn Schedule,
    seed: u64,
) -> DriverReport {
    let arrivals = schedule.arrivals();
    let discard = schedule.discard_prefix();
    let mut rng = SplitMix64::new(seed);
    let mut report = DriverReport { discarded: discard, ..Default::default() };
    let clock = platform.clock().clone();
    let t0 = clock.now();
    // Closed-loop runs are usually time-virtualized, where a real
    // background maintainer thread would never see the schedule's
    // (virtual) idle gaps — so the driver itself ticks the maintainer
    // inline whenever the platform clock has advanced past the
    // configured interval (the ManualClock-driven mode).
    let maintain_every_ns = (platform.config().maintainer_interval_s * 1e9) as u64;
    let mut last_maintain = t0;

    for (i, at) in arrivals.iter().enumerate() {
        // Advance the platform clock to the scheduled offset (noop on
        // the real clock if time has already passed).
        let target = t0 + at.as_nanos() as u64;
        let now = clock.now();
        if target > now {
            clock.sleep(Duration::from_nanos(target - now));
        }
        if maintain_every_ns > 0 {
            let now = clock.now();
            if now.saturating_sub(last_maintain) >= maintain_every_ns {
                platform.maintain();
                last_maintain = now;
            }
        }

        let net = network_delay(&platform.config().network, &mut rng);
        let t_invoke = clock.now();
        let sample = match platform.invoke(function, seed.wrapping_add(i as u64)) {
            Ok(out) => ClientSample {
                at: *at,
                latency: net + out.record.response(),
                predict: out.record.predict,
                start: out.record.start,
                cost_dollars: out.record.cost_dollars,
                error: None,
            },
            Err(e) => {
                match e {
                    InvokeError::Throttled => report.throttled += 1,
                    InvokeError::Saturated(_) => report.saturated += 1,
                    _ => report.failed += 1,
                }
                ClientSample {
                    at: *at,
                    // A refused request still WAITED: a 503 after a
                    // parked dispatch deadline held the client for the
                    // whole deadline. Fold the measured platform-clock
                    // wait into the client-observed latency — before
                    // the admission queue existed, errors really were
                    // instant, and charging refusals only the network
                    // leg undercounted end-to-end response time.
                    latency: net + Duration::from_nanos(clock.now() - t_invoke),
                    predict: Duration::ZERO,
                    start: StartKind::Cold,
                    cost_dollars: 0.0,
                    error: Some(e.to_string()),
                }
            }
        };
        if i >= discard {
            report.samples.push(sample);
        }
    }
    report
}

/// Open-loop replay on the real clock: requests fire at their scheduled
/// offsets regardless of completion (the paper's scalability setup).
/// `workers` bounds client-side concurrency (JMeter thread pool).
pub fn run_open_loop(
    platform: &Arc<Platform>,
    function: &str,
    schedule: &dyn Schedule,
    seed: u64,
    workers: usize,
) -> DriverReport {
    let arrivals = schedule.arrivals();
    // Real-time run: the background maintainer keeps min_warm pools
    // topped up across the schedule. Stop it again at the end only if
    // this call started it (a serving gateway may own one already).
    let started_maintainer = Platform::start_maintainer(
        platform,
        Duration::try_from_secs_f64(platform.config().maintainer_interval_s)
            .unwrap_or(Duration::ZERO), // unrepresentable ≈ never ticks ≈ off
    );
    let pool = ThreadPool::new(workers, "client");
    /// Error classification carried out of the worker closure, so the
    /// report never re-derives it from display strings.
    enum SampleKind {
        Ok,
        Throttled,
        Saturated,
        Failed,
    }
    let results: Arc<Mutex<Vec<(ClientSample, SampleKind)>>> = Arc::new(Mutex::new(Vec::new()));
    let t_start = std::time::Instant::now();

    let mut handles = Vec::new();
    for (i, at) in arrivals.iter().enumerate() {
        let at = *at;
        let platform = platform.clone();
        let function = function.to_string();
        let results = results.clone();
        // Pace dispatch: wait until the scheduled offset.
        let elapsed = t_start.elapsed();
        if at > elapsed {
            std::thread::sleep(at - elapsed);
        }
        handles.push(pool.submit(move || {
            let mut rng = SplitMix64::new(seed.wrapping_add(i as u64).wrapping_mul(0x9E37));
            let net = network_delay(&platform.config().network, &mut rng);
            let t_invoke = platform.clock().now();
            let entry = match platform.invoke(&function, seed.wrapping_add(i as u64)) {
                Ok(out) => (
                    ClientSample {
                        at,
                        latency: net + out.record.response(),
                        predict: out.record.predict,
                        start: out.record.start,
                        cost_dollars: out.record.cost_dollars,
                        error: None,
                    },
                    SampleKind::Ok,
                ),
                Err(e) => {
                    let kind = match &e {
                        InvokeError::Throttled => SampleKind::Throttled,
                        InvokeError::Saturated(_) => SampleKind::Saturated,
                        _ => SampleKind::Failed,
                    };
                    (
                        ClientSample {
                            at,
                            // Fold the measured admission wait into a
                            // refusal's latency (see run_closed_loop).
                            latency: net
                                + Duration::from_nanos(
                                    platform.clock().now().saturating_sub(t_invoke),
                                ),
                            predict: Duration::ZERO,
                            start: StartKind::Cold,
                            cost_dollars: 0.0,
                            error: Some(e.to_string()),
                        },
                        kind,
                    )
                }
            };
            results.lock().unwrap().push(entry);
        }));
    }
    for h in handles {
        h.join();
    }
    if started_maintainer {
        platform.stop_maintainer();
    }

    let entries = Arc::try_unwrap(results).unwrap().into_inner().unwrap();
    let mut report = DriverReport::default();
    for (sample, kind) in entries {
        match kind {
            SampleKind::Ok => {}
            SampleKind::Throttled => report.throttled += 1,
            SampleKind::Saturated => report.saturated += 1,
            SampleKind::Failed => report.failed += 1,
        }
        report.samples.push(sample);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configparse::PlatformConfig;
    use crate::platform::Invoker;
    use crate::runtime::MockEngine;
    use crate::util::{Clock as _, ManualClock};
    use crate::workload::{ColdProbe, StepRamp, WarmProbe};

    fn platform_manual() -> (Arc<Platform>, Arc<ManualClock>) {
        let clock = ManualClock::new();
        let p = Arc::new(Invoker::new(
            PlatformConfig::default(),
            Arc::new(MockEngine::paper_zoo()),
            clock.clone(),
        ));
        p.deploy("sq", "squeezenet", "pallas", 1024).unwrap();
        (p, clock)
    }

    #[test]
    fn warm_probe_discards_first_and_measures_25() {
        let (p, _) = platform_manual();
        let report = run_closed_loop(&p, "sq", &WarmProbe::default(), 1);
        assert_eq!(report.samples.len(), 25);
        assert_eq!(report.discarded, 1);
        // The discarded request absorbed the cold start.
        assert_eq!(report.cold_count(), 0, "all measured requests warm");
        assert!(report.latencies_s().iter().all(|l| *l > 0.0));
        // Latency strictly exceeds prediction (network component).
        for s in report.ok_samples() {
            assert!(s.latency > s.predict);
        }
    }

    #[test]
    fn cold_probe_all_cold() {
        let (p, _) = platform_manual();
        let report = run_closed_loop(&p, "sq", &ColdProbe::default(), 2);
        assert_eq!(report.samples.len(), 5);
        assert_eq!(report.cold_count(), 5, "10-minute gaps exceed keep-alive");
        // Cold latencies dominated by bootstrap.
        let lat = report.latencies_s();
        assert!(lat.iter().all(|l| *l > 1.0), "{lat:?}");
    }

    #[test]
    fn closed_loop_advances_clock_by_schedule() {
        let (p, clock) = platform_manual();
        run_closed_loop(&p, "sq", &ColdProbe::default(), 3);
        // 4 gaps of 600 s plus execution time.
        assert!(clock.now() >= 4 * 600 * 1_000_000_000);
    }

    /// The paper's §5 ask, end-to-end on virtual time: a min_warm pool
    /// survives the cold probe's 10-minute gaps because the closed
    /// loop ticks the maintainer inline — without it, every gap
    /// exceeds the 300 s keep-alive and all requests would be cold.
    #[test]
    fn closed_loop_maintains_min_warm_across_gaps() {
        let clock = ManualClock::new();
        let p = Arc::new(Invoker::new(
            PlatformConfig::default(),
            Arc::new(MockEngine::paper_zoo()),
            clock.clone(),
        ));
        p.deploy_full(
            "sq",
            "squeezenet",
            "pallas",
            1024,
            crate::platform::FunctionPolicy { min_warm: 1, ..Default::default() },
        )
        .unwrap();
        let report = run_closed_loop(&p, "sq", &ColdProbe::default(), 9);
        assert_eq!(report.samples.len(), 5);
        assert_eq!(report.cold_count(), 0, "maintained min_warm pool absorbs every gap");
        // Replenishment is operator-paid prewarm, not request cold
        // starts.
        assert_eq!(p.scaler.cold_provision_count(), 0);
        assert!(p.scaler.prewarm_provision_count() >= 4);
    }

    #[test]
    fn unknown_function_counts_failed() {
        let (p, _) = platform_manual();
        let report = run_closed_loop(&p, "nope", &WarmProbe::default(), 4);
        // All 26 attempts fail (the discarded warm-up request too);
        // only 25 samples are kept.
        assert_eq!(report.failed, 26);
        assert_eq!(report.ok_samples().len(), 0);
    }

    #[test]
    fn open_loop_serves_ramp() {
        // Real clock; tiny ramp so the test is fast.
        let p = Arc::new(Invoker::live(
            PlatformConfig {
                bootstrap: crate::configparse::BootstrapConfig {
                    simulate_delays: false,
                    ..Default::default()
                },
                ..Default::default()
            },
            Arc::new(MockEngine::new(vec![crate::runtime::MockModelCosts::paper_like(
                "fast", 5, 5.0, 85,
            )])),
        ));
        p.deploy("f", "fast", "pallas", 1536).unwrap();
        let ramp = StepRamp {
            initial_rps: 20.0,
            increment_rps: 20.0,
            step: Duration::from_millis(500),
            steps: 2,
        };
        let report = run_open_loop(&p, "f", &ramp, 5, 64);
        assert_eq!(report.samples.len(), 30); // 10 + 20 arrivals
        assert_eq!(report.failed, 0);
        assert!(report.cold_count() >= 1);
        // Some containers were reused across the ramp.
        assert!(p.pool.total_alive() <= 30);
    }
}
