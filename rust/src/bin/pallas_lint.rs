//! `pallas-lint` — the platform's concurrency / virtual-clock /
//! doc-drift checker as a standalone binary (CI entry point; the same
//! pass also runs as the `repo_tree_is_lint_clean` unit test).
//!
//! ```text
//! pallas_lint [--json] [--timing] [-D] [ROOT]
//! ```
//!
//! `ROOT` is the `rust/` crate root (defaults to the compiled-in
//! `CARGO_MANIFEST_DIR`). Exits 1 when any finding survives
//! suppressions. `-D` (deny) is accepted for CI-invocation clarity;
//! findings are always fatal, so it changes nothing. `--timing`
//! prints per-rule wall time to stderr (stdout stays parseable, so
//! `--json --timing` composes).

use lambdaserve::lints;
use lambdaserve::util::json::Json;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut json = false;
    let mut timing = false;
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--timing" => timing = true,
            "-D" | "--deny" => {}
            "-h" | "--help" => {
                println!("usage: pallas_lint [--json] [--timing] [-D] [ROOT]");
                println!("lints the lambdaserve tree for concurrency & clock invariants");
                return ExitCode::SUCCESS;
            }
            other if root.is_none() && !other.starts_with('-') => {
                root = Some(PathBuf::from(other));
            }
            other => {
                eprintln!("pallas_lint: unknown argument {other:?}");
                return ExitCode::from(2);
            }
        }
    }
    let root = root.unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    let (findings, times) = lints::run_timed(&root);
    if json {
        let arr = Json::Arr(findings.iter().map(lints::Finding::to_json).collect());
        println!("{arr}");
    } else {
        for f in &findings {
            println!("{f}");
        }
        if findings.is_empty() {
            eprintln!("pallas-lint: clean ({} rules)", lints::ALL_RULES.len());
        } else {
            eprintln!("pallas-lint: {} finding(s)", findings.len());
        }
    }
    if timing {
        let width = times.iter().map(|(r, _)| r.len()).max().unwrap_or(0);
        let total: std::time::Duration = times.iter().map(|(_, d)| *d).sum();
        eprintln!("pallas-lint timing:");
        for (rule, d) in &times {
            eprintln!("  {rule:width$}  {:>9.3} ms", d.as_secs_f64() * 1e3);
        }
        eprintln!("  {:width$}  {:>9.3} ms", "(total)", total.as_secs_f64() * 1e3);
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
