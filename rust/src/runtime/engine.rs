//! The thread-safe engine abstraction the platform codes against.

use super::manifest::ModelManifest;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Duration;

/// Handle to a live model instance (weights resident on the device of
/// one engine shard). Dropping the handle does NOT free the instance —
/// call [`Engine::drop_instance`] (container reaping does).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct InstanceHandle {
    pub model: String,
    pub variant: String,
    pub shard: usize,
    pub id: u64,
}

/// Cost breakdown of instance creation — the *real* components of a
/// cold start (the platform adds the simulated sandbox/runtime parts).
#[derive(Debug, Clone, Default)]
pub struct InitStats {
    /// HLO parse + PJRT compile time actually spent for this instance's
    /// executables (zero when the shard compile cache hit).
    pub compile: Duration,
    /// Weight materialization (init executable run + upload).
    pub init_run: Duration,
    /// Bytes of parameters now resident.
    pub weight_bytes: u64,
}

/// Serialized restorable state of a warmed instance, captured by
/// [`Engine::snapshot_instance`] and replayed by
/// [`Engine::restore_instance`]: the weights (and, engine-dependent, a
/// pointer to already-compiled executables) that let a fresh provision
/// pay I/O instead of compile + init.
#[derive(Debug, Clone)]
pub struct SnapshotBlob {
    /// Model the snapshot was captured from.
    pub model: String,
    /// Artifact variant of the captured instance.
    pub variant: String,
    /// Serialized size in bytes (weights dominate): what a restore
    /// must move, and what a snapshot store's capacity accounting
    /// charges.
    pub size_bytes: u64,
    /// Engine-specific payload.
    pub payload: SnapshotPayload,
}

/// Engine-specific contents of a [`SnapshotBlob`].
#[derive(Debug, Clone)]
pub enum SnapshotPayload {
    /// No real state: the engine recreates the instance from its own
    /// (cached) artifacts at restore-I/O cost ([`super::MockEngine`]).
    Synthetic,
    /// Host copy of the flat `f32` parameter vector
    /// ([`super::PjrtEngine`]): restore re-uploads the weights to a
    /// round-robin-chosen shard, skipping the init execution (and the
    /// HLO compile too whenever that shard's cache already holds the
    /// model — restores re-seed the batch-N kernel ladder on the
    /// receiving shard so batched flushes stay warm wherever the
    /// restore lands).
    PjrtWeights {
        /// Shard the instance was captured on. Diagnostic only since
        /// restores went round-robin: routing every restore back to
        /// the capturing shard would hotspot it under restore storms.
        shard: usize,
        /// Flat parameter vector, shared so a stored blob is not
        /// copied per restore.
        flat: Arc<Vec<f32>>,
    },
}

/// How a batched forward pass was actually executed: which compiled
/// kernels served it and how the engine's batch-N compile cache fared.
/// Produced by [`Engine::predict_batch_report`]; the platform streams
/// it into the per-function metrics (one owner: the batch leader's
/// invocation record carries the hit/miss deltas).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KernelReport {
    /// Largest compiled batch-N kernel that served a chunk of this
    /// pass (1 = every input went through the batch-1 executable).
    pub kernel_batch_n: usize,
    /// Batch-N (N >= 2) kernel-cache hits while serving this pass.
    pub batch_kernel_hits: u64,
    /// Batch-N (N >= 2) kernel-cache misses (a chunk wanted a ladder
    /// kernel that was not compiled yet, or compiled it on the spot).
    pub batch_kernel_misses: u64,
}

/// Largest power of two `<= n` (`n >= 1`).
pub fn prev_power_of_two(n: usize) -> usize {
    let mut p = 1usize;
    while p * 2 <= n {
        p *= 2;
    }
    p
}

/// Greedy power-of-two decomposition of a flush of `n` inputs into
/// kernel-launch chunk sizes bounded by `ladder_max`: each chunk is the
/// largest power of two that fits the remainder, so `n = 7,
/// ladder_max = 4` yields `[4, 2, 1]`. This is the shared "pick the
/// largest compiled N <= batch size, fold the remainder through smaller
/// kernels" policy both engines implement.
pub fn ladder_chunks(mut n: usize, ladder_max: usize) -> Vec<usize> {
    let ladder_max = ladder_max.max(1);
    let mut chunks = Vec::new();
    while n > 0 {
        let c = prev_power_of_two(n).min(ladder_max);
        chunks.push(c);
        n -= c;
    }
    chunks
}

/// One inference result.
#[derive(Debug, Clone)]
pub struct Prediction {
    /// Argmax class.
    pub top1: i32,
    /// Probability of the argmax class.
    pub top_prob: f32,
    /// Real compute time of the forward pass (full speed, unthrottled).
    pub compute: Duration,
}

/// Thread-safe inference engine: the only interface the platform uses
/// to touch models, implemented by [`super::PjrtEngine`] (real XLA) and
/// [`super::MockEngine`] (synthetic costs).
pub trait Engine: Send + Sync {
    /// Manifest lookup (deploy-time validation, billing floors).
    fn manifest(&self, model: &str) -> Result<ModelManifest>;

    /// Create a live instance: ensure the artifacts are compiled on a
    /// shard (cached per shard) and run the init executable (weight
    /// materialization). This is the real work behind a cold start.
    fn create_instance(&self, model: &str, variant: &str) -> Result<(InstanceHandle, InitStats)>;

    /// Run one forward pass on a live instance. `image_seed`
    /// deterministically generates the input image (the paper bundled
    /// a fixed image with the function; a seed keeps runs reproducible
    /// while letting workloads vary inputs).
    fn predict(&self, handle: &InstanceHandle, image_seed: u64) -> Result<Prediction>;

    /// Run one *batched* forward pass: `image_seeds.len()` inputs
    /// coalesced into a single engine execution on `handle`. Returns
    /// exactly one [`Prediction`] per seed, in seed order; each
    /// member's `compute` is its share of the batched pass, so the
    /// sum over members is the real compute the batch cost (sublinear
    /// in the batch size for engines with a true batched path). The
    /// default implementation loops [`Self::predict`] — correct for
    /// any engine, with no batching win.
    fn predict_batch(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
    ) -> Result<Vec<Prediction>> {
        image_seeds.iter().map(|&seed| self.predict(handle, seed)).collect()
    }

    /// [`Self::predict_batch`] plus a [`KernelReport`] describing which
    /// compiled batch-N kernels served the pass. The default delegates
    /// to `predict_batch` and reports a batch-1 execution (no ladder),
    /// so engines without batch-N kernels stay correct; engines with a
    /// kernel ladder override this method (keeping `predict_batch` as
    /// the real implementation the default would otherwise recurse
    /// into).
    fn predict_batch_report(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
    ) -> Result<(Vec<Prediction>, KernelReport)> {
        let preds = self.predict_batch(handle, image_seeds)?;
        Ok((preds, KernelReport { kernel_batch_n: 1, ..Default::default() }))
    }

    /// [`Self::predict_batch_report`] with the kernel ladder capped at
    /// `rung_cap` for this pass: the engine behaves as if its largest
    /// compiled batch-N rung were `min(configured ladder, rung_cap)`
    /// rounded down to a power of two. The adaptive rung controller
    /// passes the recent flush-size p99 here so shards stop compiling
    /// (and caching) rungs no flush ever fills; `usize::MAX` (or any
    /// cap at/above the configured ladder) is the identity. The
    /// default ignores the cap — correct for engines without a ladder,
    /// whose report is batch-1 regardless.
    fn predict_batch_report_capped(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
        rung_cap: usize,
    ) -> Result<(Vec<Prediction>, KernelReport)> {
        let _ = rung_cap;
        self.predict_batch_report(handle, image_seeds)
    }

    /// Serialize a live instance's restorable state (weights plus a
    /// pointer to its compiled executables) into a [`SnapshotBlob`].
    /// The instance stays live and usable; capture is read-only.
    /// Engines without a snapshot path keep the default, which
    /// reports the capability as unsupported — callers treat any
    /// error as "no snapshot" and stay on the full cold path.
    fn snapshot_instance(&self, handle: &InstanceHandle) -> Result<SnapshotBlob> {
        bail!("engine does not support snapshotting instance {handle:?}")
    }

    /// Create a live instance from a snapshot instead of the full
    /// compile + init path: the blob's weights are materialized
    /// directly, so the returned [`InitStats`] carries no compile and
    /// a (much cheaper) weight-transfer `init_run`. Fails when the
    /// blob does not match `model`/`variant` or the engine cannot
    /// honor it; callers fall back to [`Self::create_instance`]. The
    /// default reports the capability as unsupported.
    fn restore_instance(
        &self,
        model: &str,
        variant: &str,
        blob: &SnapshotBlob,
    ) -> Result<(InstanceHandle, InitStats)> {
        let _ = blob;
        bail!("engine does not support restoring {model}/{variant} from a snapshot")
    }

    /// Free a live instance (container reaped / evicted).
    fn drop_instance(&self, handle: &InstanceHandle);

    /// Number of live instances (leak checks in tests).
    fn live_instances(&self) -> usize;
}
