//! The thread-safe engine abstraction the platform codes against.

use super::manifest::ModelManifest;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Duration;

/// Handle to a live model instance (weights resident on the device of
/// one engine shard). Dropping the handle does NOT free the instance —
/// call [`Engine::drop_instance`] (container reaping does).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct InstanceHandle {
    pub model: String,
    pub variant: String,
    pub shard: usize,
    pub id: u64,
}

/// Cost breakdown of instance creation — the *real* components of a
/// cold start (the platform adds the simulated sandbox/runtime parts).
#[derive(Debug, Clone, Default)]
pub struct InitStats {
    /// HLO parse + PJRT compile time actually spent for this instance's
    /// executables (zero when the shard compile cache hit).
    pub compile: Duration,
    /// Weight materialization (init executable run + upload).
    pub init_run: Duration,
    /// Bytes of parameters now resident.
    pub weight_bytes: u64,
}

/// Serialized restorable state of a warmed instance, captured by
/// [`Engine::snapshot_instance`] and replayed by
/// [`Engine::restore_instance`]: the weights (and, engine-dependent, a
/// pointer to already-compiled executables) that let a fresh provision
/// pay I/O instead of compile + init.
#[derive(Debug, Clone)]
pub struct SnapshotBlob {
    /// Model the snapshot was captured from.
    pub model: String,
    /// Artifact variant of the captured instance.
    pub variant: String,
    /// Serialized size in bytes (weights dominate): what a restore
    /// must move, and what a snapshot store's capacity accounting
    /// charges.
    pub size_bytes: u64,
    /// Engine-specific payload.
    pub payload: SnapshotPayload,
}

/// Engine-specific contents of a [`SnapshotBlob`].
#[derive(Debug, Clone)]
pub enum SnapshotPayload {
    /// No real state: the engine recreates the instance from its own
    /// (cached) artifacts at restore-I/O cost ([`super::MockEngine`]).
    Synthetic,
    /// Host copy of the flat `f32` parameter vector plus the shard
    /// whose compile cache already holds this model's executables
    /// ([`super::PjrtEngine`]): restore re-uploads the weights to that
    /// shard, skipping both the HLO compile and the init execution.
    PjrtWeights {
        /// Shard the instance was captured on (its compile cache is
        /// the "seeded" one a restore routes back to).
        shard: usize,
        /// Flat parameter vector, shared so a stored blob is not
        /// copied per restore.
        flat: Arc<Vec<f32>>,
    },
}

/// One inference result.
#[derive(Debug, Clone)]
pub struct Prediction {
    /// Argmax class.
    pub top1: i32,
    /// Probability of the argmax class.
    pub top_prob: f32,
    /// Real compute time of the forward pass (full speed, unthrottled).
    pub compute: Duration,
}

/// Thread-safe inference engine: the only interface the platform uses
/// to touch models, implemented by [`super::PjrtEngine`] (real XLA) and
/// [`super::MockEngine`] (synthetic costs).
pub trait Engine: Send + Sync {
    /// Manifest lookup (deploy-time validation, billing floors).
    fn manifest(&self, model: &str) -> Result<ModelManifest>;

    /// Create a live instance: ensure the artifacts are compiled on a
    /// shard (cached per shard) and run the init executable (weight
    /// materialization). This is the real work behind a cold start.
    fn create_instance(&self, model: &str, variant: &str) -> Result<(InstanceHandle, InitStats)>;

    /// Run one forward pass on a live instance. `image_seed`
    /// deterministically generates the input image (the paper bundled
    /// a fixed image with the function; a seed keeps runs reproducible
    /// while letting workloads vary inputs).
    fn predict(&self, handle: &InstanceHandle, image_seed: u64) -> Result<Prediction>;

    /// Run one *batched* forward pass: `image_seeds.len()` inputs
    /// coalesced into a single engine execution on `handle`. Returns
    /// exactly one [`Prediction`] per seed, in seed order; each
    /// member's `compute` is its share of the batched pass, so the
    /// sum over members is the real compute the batch cost (sublinear
    /// in the batch size for engines with a true batched path). The
    /// default implementation loops [`Self::predict`] — correct for
    /// any engine, with no batching win.
    fn predict_batch(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
    ) -> Result<Vec<Prediction>> {
        image_seeds.iter().map(|&seed| self.predict(handle, seed)).collect()
    }

    /// Serialize a live instance's restorable state (weights plus a
    /// pointer to its compiled executables) into a [`SnapshotBlob`].
    /// The instance stays live and usable; capture is read-only.
    /// Engines without a snapshot path keep the default, which
    /// reports the capability as unsupported — callers treat any
    /// error as "no snapshot" and stay on the full cold path.
    fn snapshot_instance(&self, handle: &InstanceHandle) -> Result<SnapshotBlob> {
        bail!("engine does not support snapshotting instance {handle:?}")
    }

    /// Create a live instance from a snapshot instead of the full
    /// compile + init path: the blob's weights are materialized
    /// directly, so the returned [`InitStats`] carries no compile and
    /// a (much cheaper) weight-transfer `init_run`. Fails when the
    /// blob does not match `model`/`variant` or the engine cannot
    /// honor it; callers fall back to [`Self::create_instance`]. The
    /// default reports the capability as unsupported.
    fn restore_instance(
        &self,
        model: &str,
        variant: &str,
        blob: &SnapshotBlob,
    ) -> Result<(InstanceHandle, InitStats)> {
        let _ = blob;
        bail!("engine does not support restoring {model}/{variant} from a snapshot")
    }

    /// Free a live instance (container reaped / evicted).
    fn drop_instance(&self, handle: &InstanceHandle);

    /// Number of live instances (leak checks in tests).
    fn live_instances(&self) -> usize;
}
