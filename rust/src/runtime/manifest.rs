//! AOT manifest parsing (`artifacts/<model>.json`, `artifacts/zoo.json`).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed `<model>.json` manifest emitted by `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    /// NHWC input shape, `[1, H, W, 3]`.
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub param_count: usize,
    pub param_elements: u64,
    pub param_bytes: u64,
    pub flops: u64,
    /// Paper-reported model file size (MB): 5 / 45 / 98.
    pub paper_size_mb: f64,
    /// Paper-reported peak function memory (MB): 85 / 229 / 429 — the
    /// platform's deployability floor.
    pub paper_peak_mem_mb: u32,
    /// Ordered parameter shapes (the artifact calling convention).
    pub param_shapes: Vec<Vec<usize>>,
    /// variant -> (init artifact file, infer artifact file).
    pub artifacts: BTreeMap<String, (String, String)>,
    /// Directory the artifact files live in.
    pub dir: PathBuf,
}

impl ModelManifest {
    pub fn load(dir: &Path, name: &str) -> Result<Self> {
        let path = dir.join(format!("{name}.json"));
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::from_json(&src, dir)
    }

    pub fn from_json(src: &str, dir: &Path) -> Result<Self> {
        let j = Json::parse(src).context("parsing manifest json")?;
        let req_u64 = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("manifest missing numeric field {k:?}"))
        };
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .context("manifest missing name")?
            .to_string();
        let input_shape: Vec<usize> = j
            .get("input_shape")
            .and_then(Json::as_arr)
            .context("manifest missing input_shape")?
            .iter()
            .map(|v| v.as_u64().map(|x| x as usize).context("bad input_shape entry"))
            .collect::<Result<_>>()?;
        if input_shape.len() != 4 || input_shape[0] != 1 || input_shape[3] != 3 {
            bail!("unsupported input shape {input_shape:?} (want [1, H, W, 3])");
        }
        let param_shapes: Vec<Vec<usize>> = j
            .get("params")
            .and_then(Json::as_arr)
            .context("manifest missing params")?
            .iter()
            .map(|p| {
                p.get("shape")
                    .and_then(Json::as_arr)
                    .context("param missing shape")?
                    .iter()
                    .map(|v| v.as_u64().map(|x| x as usize).context("bad shape entry"))
                    .collect::<Result<Vec<usize>>>()
            })
            .collect::<Result<_>>()?;
        let mut artifacts = BTreeMap::new();
        if let Some(Json::Obj(vars)) = j.get("artifacts") {
            for (variant, files) in vars {
                let init = files
                    .get("init")
                    .and_then(Json::as_str)
                    .with_context(|| format!("artifact variant {variant} missing init"))?;
                let infer = files
                    .get("infer")
                    .and_then(Json::as_str)
                    .with_context(|| format!("artifact variant {variant} missing infer"))?;
                artifacts.insert(variant.clone(), (init.to_string(), infer.to_string()));
            }
        }
        if artifacts.is_empty() {
            bail!("manifest {name} lists no artifact variants");
        }
        let m = Self {
            name,
            input_shape,
            num_classes: req_u64("num_classes")? as usize,
            param_count: req_u64("param_count")? as usize,
            param_elements: req_u64("param_elements")?,
            param_bytes: req_u64("param_bytes")?,
            flops: req_u64("flops")?,
            paper_size_mb: j
                .get("paper_size_mb")
                .and_then(Json::as_f64)
                .context("manifest missing paper_size_mb")?,
            paper_peak_mem_mb: req_u64("paper_peak_mem_mb")? as u32,
            param_shapes,
            artifacts,
            dir: dir.to_path_buf(),
        };
        if m.param_shapes.len() != m.param_count {
            bail!("manifest {}: params list length {} != param_count {}", m.name,
                  m.param_shapes.len(), m.param_count);
        }
        Ok(m)
    }

    /// Image pixel count (H * W * 3).
    pub fn image_elements(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Absolute paths of `(init, infer)` artifacts for `variant`.
    pub fn artifact_paths(&self, variant: &str) -> Result<(PathBuf, PathBuf)> {
        let (init, infer) = self
            .artifacts
            .get(variant)
            .with_context(|| {
                format!("model {} has no variant {variant:?} (have: {:?})",
                        self.name, self.artifacts.keys().collect::<Vec<_>>())
            })?;
        Ok((self.dir.join(init), self.dir.join(infer)))
    }

    /// Deployment package size in bytes (model weights dominate; the
    /// paper bundled model + code into the function zip).
    pub fn package_bytes(&self) -> u64 {
        self.param_bytes + 2_000_000 // + code/framework baseline
    }
}

/// The artifact directory index (`zoo.json`).
#[derive(Debug, Clone)]
pub struct Zoo {
    pub height: usize,
    pub width: usize,
    pub seed: u64,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Zoo {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("zoo.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading zoo index {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&src).context("parsing zoo.json")?;
        let mut models = BTreeMap::new();
        for entry in j.get("models").and_then(Json::as_arr).context("zoo missing models")? {
            let name = entry
                .get("name")
                .and_then(Json::as_str)
                .context("zoo entry missing name")?;
            let m = ModelManifest::load(dir, name)?;
            models.insert(name.to_string(), m);
        }
        Ok(Self {
            height: j.get("height").and_then(Json::as_u64).context("zoo missing height")? as usize,
            width: j.get("width").and_then(Json::as_u64).context("zoo missing width")? as usize,
            seed: j.get("seed").and_then(Json::as_u64).context("zoo missing seed")?,
            models,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| format!("unknown model {name:?} (zoo: {:?})",
                                     self.models.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
pub(crate) fn test_manifest_json() -> &'static str {
    r#"{
      "name": "tiny",
      "input_shape": [1, 8, 8, 3],
      "num_classes": 10,
      "param_count": 2,
      "param_elements": 100,
      "param_bytes": 400,
      "flops": 12345,
      "paper_size_mb": 5.0,
      "paper_peak_mem_mb": 85,
      "params": [
        {"name": "a.w", "shape": [3, 4]},
        {"name": "a.b", "shape": [4]}
      ],
      "artifacts": {
        "pallas": {"init": "tiny_init.hlo.txt", "infer": "tiny_infer.hlo.txt"},
        "ref": {"init": "tiny_ref_init.hlo.txt", "infer": "tiny_ref_infer.hlo.txt"}
      }
    }"#
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest() {
        let m = ModelManifest::from_json(test_manifest_json(), Path::new("/tmp/a")).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.input_shape, vec![1, 8, 8, 3]);
        assert_eq!(m.image_elements(), 192);
        assert_eq!(m.param_shapes, vec![vec![3, 4], vec![4]]);
        assert_eq!(m.paper_peak_mem_mb, 85);
        let (init, infer) = m.artifact_paths("pallas").unwrap();
        assert_eq!(init, Path::new("/tmp/a/tiny_init.hlo.txt"));
        assert_eq!(infer, Path::new("/tmp/a/tiny_infer.hlo.txt"));
        assert!(m.artifact_paths("nope").is_err());
        assert!(m.package_bytes() > m.param_bytes);
    }

    #[test]
    fn rejects_bad_manifests() {
        let dir = Path::new("/tmp");
        assert!(ModelManifest::from_json("{}", dir).is_err());
        // wrong input rank
        let bad = test_manifest_json().replace("[1, 8, 8, 3]", "[8, 8, 3]");
        assert!(ModelManifest::from_json(&bad, dir).is_err());
        // params/param_count mismatch
        let bad = test_manifest_json().replace("\"param_count\": 2", "\"param_count\": 3");
        assert!(ModelManifest::from_json(&bad, dir).is_err());
        // no artifacts
        let bad = test_manifest_json().replace("\"pallas\"", "\"_ignored\"")
            .replace("\"ref\"", "\"_ignored2\"");
        // (renaming keys keeps variants — instead drop the object)
        let bad2 = {
            let mut s = bad;
            let start = s.find("\"artifacts\"").unwrap();
            let end = s.rfind('}').unwrap();
            s.replace_range(start..end, "\"artifacts\": {}\n");
            s
        };
        assert!(ModelManifest::from_json(&bad2, dir).is_err());
    }

    #[test]
    fn zoo_load_missing_dir_errors() {
        let err = Zoo::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
