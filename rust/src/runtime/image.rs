//! Deterministic synthetic input images.
//!
//! The paper bundles one fixed JPEG with the Lambda function and
//! classifies it on every request. Pixel values do not affect inference
//! *cost*, so we generate a procedural image (smooth gradients + seeded
//! noise, roughly ImageNet-normalized) instead of shipping binary image
//! assets; the seed varies per request so caching cannot hide work.

use crate::util::SplitMix64;

/// Generate an NHWC `[1, h, w, 3]` image as a flat f32 vector.
///
/// Hot path: called on every predict (the image upload is part of the
/// request), so the generator is vectorizable — sin/cos are hoisted
/// into per-row/column tables and one `u64` draw yields the noise for
/// all three channels of a pixel (§Perf: 1.35 ms -> ~0.2 ms at 224²).
pub fn synthetic_image(h: usize, w: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed ^ 0x1839_7cb1);
    // Random low-frequency phase offsets make images differ smoothly.
    let (px, py) = (rng.next_f32() * 6.28, rng.next_f32() * 6.28);
    let col: Vec<f32> = (0..w)
        .map(|x| ((x as f32 / w.max(1) as f32) * 6.28 + px).sin() * 0.5)
        .collect();
    let row: Vec<f32> = (0..h)
        .map(|y| ((y as f32 / h.max(1) as f32) * 6.28 + py).cos() * 0.5)
        .collect();
    let mut out = Vec::with_capacity(h * w * 3);
    const INV: f32 = 1.0 / 2097152.0; // 2^-21
    for &ry in &row {
        for &cx in &col {
            let base = cx + ry;
            // One draw -> three 21-bit channel noises in [-0.5, 0.5).
            let bits = rng.next_u64();
            let n0 = ((bits & 0x1F_FFFF) as f32) * INV - 0.5;
            let n1 = (((bits >> 21) & 0x1F_FFFF) as f32) * INV - 0.5;
            let n2 = (((bits >> 42) & 0x1F_FFFF) as f32) * INV - 0.5;
            // ~N(0, 1)-ish after ImageNet-style normalization.
            out.push(base + 0.3 * n0);
            out.push(base + 0.3 * n1 + 0.1);
            out.push(base + 0.3 * n2 + 0.2);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correct_length() {
        assert_eq!(synthetic_image(224, 224, 0).len(), 224 * 224 * 3);
        assert_eq!(synthetic_image(8, 4, 1).len(), 96);
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(synthetic_image(16, 16, 7), synthetic_image(16, 16, 7));
    }

    #[test]
    fn differs_across_seeds() {
        assert_ne!(synthetic_image(16, 16, 1), synthetic_image(16, 16, 2));
    }

    #[test]
    fn values_bounded() {
        let img = synthetic_image(32, 32, 3);
        assert!(img.iter().all(|v| v.is_finite() && v.abs() < 4.0));
        // Non-degenerate: some spread.
        let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
        let var: f32 = img.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / img.len() as f32;
        assert!(var > 0.01, "var={var}");
    }
}
