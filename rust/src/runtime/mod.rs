//! Model execution runtime: load AOT HLO-text artifacts, compile them
//! on the PJRT CPU client, and serve predictions from Rust.
//!
//! The `xla` crate's PJRT types are `Rc`-based (thread-confined), so
//! [`PjrtEngine`] runs one or more *engine shard* threads, each owning
//! a `PjRtClient`, a compile cache, and the live model instances;
//! the rest of the platform talks to shards through channels via the
//! thread-safe [`Engine`] trait. [`MockEngine`] implements the same
//! trait with configurable synthetic costs for platform tests and
//! fast simulation sweeps.

mod engine;
mod image;
mod manifest;
mod mock;
mod pjrt;

pub use engine::{
    ladder_chunks, prev_power_of_two, Engine, InitStats, InstanceHandle, KernelReport, Prediction,
    SnapshotBlob, SnapshotPayload,
};
pub use image::synthetic_image;
pub use manifest::{ModelManifest, Zoo};
pub use mock::{
    MockEngine, MockModelCosts, BATCH_COST_MARGINAL, KERNEL_COST_MARGINAL, MOCK_RESTORE_BW,
};
pub use pjrt::PjrtEngine;
