//! The real engine: PJRT CPU client behind engine-shard threads.
//!
//! `xla::PjRtClient` is `Rc`-based (thread-confined), so each shard is
//! a dedicated thread owning a client, a compile cache keyed by
//! `(model, variant)`, and the shard's live instances (weights resident
//! as device buffers). Other threads talk to shards over channels; one
//! in-flight command per shard at a time, so shard count bounds
//! compute parallelism (containers are distributed round-robin).
//!
//! Artifact loading follows /opt/xla-example/load_hlo:
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. HLO **text** is the interchange
//! format (jax >= 0.5 protos are rejected by xla_extension 0.5.1).
//!
//! Calling convention (tuple-free — 0.5.1's C API segfaults converting
//! tuple buffers to literals): `init() -> flat f32[N]` which the shard
//! slices into per-parameter device buffers using the manifest's shape
//! list, and `infer(param_0.., image) -> probs f32[1, C]` with argmax
//! computed here.

use super::engine::{
    ladder_chunks, prev_power_of_two, Engine, InitStats, InstanceHandle, KernelReport, Prediction,
    SnapshotBlob, SnapshotPayload,
};
use super::image::synthetic_image;
use super::manifest::{ModelManifest, Zoo};
use crate::exec::channel::{bounded, unbounded, Receiver, Sender};
use crate::util::plock;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

enum Cmd {
    CreateInstance {
        model: String,
        variant: String,
        reply: Sender<Result<(u64, InitStats)>>,
    },
    Predict {
        instance: u64,
        image_seed: u64,
        reply: Sender<Result<Prediction>>,
    },
    PredictBatch {
        instance: u64,
        image_seeds: Vec<u64>,
        /// Top of the power-of-two batch-kernel ladder the flush may
        /// use (1 = batch-1 executables only).
        ladder_max: usize,
        reply: Sender<Result<(Vec<Prediction>, KernelReport)>>,
    },
    SnapshotInstance {
        instance: u64,
        reply: Sender<Result<Vec<f32>>>,
    },
    RestoreInstance {
        model: String,
        variant: String,
        flat: Arc<Vec<f32>>,
        /// Ladder rungs to best-effort re-seed on the receiving shard.
        ladder_max: usize,
        reply: Sender<Result<(u64, InitStats)>>,
    },
    DropInstance {
        instance: u64,
    },
    Shutdown,
}

/// Thread-safe multi-shard PJRT engine.
pub struct PjrtEngine {
    zoo: Zoo,
    shards: Vec<Sender<Cmd>>,
    joins: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next_shard: AtomicUsize,
    live: AtomicU64,
    /// Top of the power-of-two batch-kernel ladder (1 = batch-1 only).
    /// Rungs above 1 require `<infer>_b<N>` artifacts in the zoo; a
    /// missing artifact just keeps that rung on the batch-1 path.
    batch_kernel_max: AtomicUsize,
}

impl PjrtEngine {
    /// Load the zoo index from `artifacts_dir` and spin up `shards`
    /// engine threads.
    pub fn new(artifacts_dir: &std::path::Path, shards: usize) -> Result<Self> {
        assert!(shards > 0, "need at least one engine shard");
        let zoo = Zoo::load(artifacts_dir)?;
        let mut senders = Vec::new();
        let mut joins = Vec::new();
        for i in 0..shards {
            let (tx, rx) = unbounded::<Cmd>();
            let zoo_c = zoo.clone();
            let handle = std::thread::Builder::new()
                .name(format!("engine-shard-{i}"))
                .spawn(move || shard_main(zoo_c, rx))
                .context("spawning engine shard")?;
            senders.push(tx);
            joins.push(handle);
        }
        Ok(Self {
            zoo,
            shards: senders,
            joins: Mutex::new(joins),
            next_shard: AtomicUsize::new(0),
            live: AtomicU64::new(0),
            batch_kernel_max: AtomicUsize::new(1),
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn zoo(&self) -> &Zoo {
        &self.zoo
    }

    /// Set the top of the power-of-two batch-kernel ladder (clamped to
    /// at least 1; non-powers round down).
    pub fn set_batch_kernel_max(&self, n: usize) {
        self.batch_kernel_max.store(prev_power_of_two(n.max(1)), Ordering::SeqCst);
    }

    /// Current top of the batch-kernel ladder.
    pub fn batch_kernel_max(&self) -> usize {
        self.batch_kernel_max.load(Ordering::SeqCst)
    }
}

impl Drop for PjrtEngine {
    fn drop(&mut self) {
        for tx in &self.shards {
            let _ = tx.send(Cmd::Shutdown);
        }
        // Drain under the lock, join outside it — never hold the
        // handle list's mutex across a shard's shutdown.
        let joins: Vec<_> = plock(&self.joins).drain(..).collect();
        for j in joins {
            let _ = j.join();
        }
    }
}

impl Engine for PjrtEngine {
    fn manifest(&self, model: &str) -> Result<ModelManifest> {
        self.zoo.get(model).cloned()
    }

    fn create_instance(&self, model: &str, variant: &str) -> Result<(InstanceHandle, InitStats)> {
        // Validate before crossing the channel for a friendlier error.
        let m = self.zoo.get(model)?;
        m.artifact_paths(variant)?;
        let shard = self.next_shard.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[shard]
            .send(Cmd::CreateInstance {
                model: model.to_string(),
                variant: variant.to_string(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine shard {shard} is down"))?;
        let (id, stats) = reply_rx
            .recv()
            .map_err(|_| anyhow!("engine shard {shard} dropped reply"))??;
        self.live.fetch_add(1, Ordering::SeqCst);
        Ok((
            InstanceHandle { model: model.to_string(), variant: variant.to_string(), shard, id },
            stats,
        ))
    }

    fn predict(&self, handle: &InstanceHandle, image_seed: u64) -> Result<Prediction> {
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[handle.shard]
            .send(Cmd::Predict { instance: handle.id, image_seed, reply: reply_tx })
            .map_err(|_| anyhow!("engine shard {} is down", handle.shard))?;
        reply_rx.recv().map_err(|_| anyhow!("engine shard {} dropped reply", handle.shard))?
    }

    fn predict_batch(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
    ) -> Result<Vec<Prediction>> {
        Ok(self.predict_batch_report(handle, image_seeds)?.0)
    }

    fn predict_batch_report(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
    ) -> Result<(Vec<Prediction>, KernelReport)> {
        // One command crosses the channel for the whole batch: the
        // inputs run back-to-back on the owning shard without a
        // per-request cross-thread round trip in between, and without
        // interleaved commands evicting the instance's buffers from
        // cache mid-batch. The shard decomposes the flush over its
        // compiled batch-N kernel ladder (largest compiled N <= batch
        // size, remainder folded through smaller kernels), falling
        // back to the batch-1 executable for rungs the zoo does not
        // ship — so the win ranges from amortized dispatch (ladder
        // disabled) to genuinely fused batched passes.
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[handle.shard]
            .send(Cmd::PredictBatch {
                instance: handle.id,
                image_seeds: image_seeds.to_vec(),
                ladder_max: self.batch_kernel_max.load(Ordering::SeqCst),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine shard {} is down", handle.shard))?;
        reply_rx.recv().map_err(|_| anyhow!("engine shard {} dropped reply", handle.shard))?
    }

    fn predict_batch_report_capped(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
        rung_cap: usize,
    ) -> Result<(Vec<Prediction>, KernelReport)> {
        // Same one-command-per-flush path as `predict_batch_report`,
        // with the ladder clamped for this pass: the shard then never
        // ensures (= compiles and caches) a batch-N executable above
        // the cap. The configured engine rung stays the ceiling, so
        // `usize::MAX` is the identity.
        let ladder_max = self
            .batch_kernel_max
            .load(Ordering::SeqCst)
            .min(prev_power_of_two(rung_cap.max(1)));
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[handle.shard]
            .send(Cmd::PredictBatch {
                instance: handle.id,
                image_seeds: image_seeds.to_vec(),
                ladder_max,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine shard {} is down", handle.shard))?;
        reply_rx.recv().map_err(|_| anyhow!("engine shard {} dropped reply", handle.shard))?
    }

    fn snapshot_instance(&self, handle: &InstanceHandle) -> Result<SnapshotBlob> {
        let manifest = self.zoo.get(&handle.model)?;
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[handle.shard]
            .send(Cmd::SnapshotInstance { instance: handle.id, reply: reply_tx })
            .map_err(|_| anyhow!("engine shard {} is down", handle.shard))?;
        let flat = reply_rx
            .recv()
            .map_err(|_| anyhow!("engine shard {} dropped reply", handle.shard))??;
        Ok(SnapshotBlob {
            model: handle.model.clone(),
            variant: handle.variant.clone(),
            size_bytes: manifest.param_bytes,
            payload: SnapshotPayload::PjrtWeights { shard: handle.shard, flat: Arc::new(flat) },
        })
    }

    fn restore_instance(
        &self,
        model: &str,
        variant: &str,
        blob: &SnapshotBlob,
    ) -> Result<(InstanceHandle, InitStats)> {
        if blob.model != model || blob.variant != variant {
            bail!(
                "snapshot of {}/{} cannot restore {model}/{variant}",
                blob.model,
                blob.variant
            );
        }
        let SnapshotPayload::PjrtWeights { shard: _captured_on, flat } = &blob.payload else {
            bail!("snapshot payload is not restorable by the PJRT engine");
        };
        // Round-robin like `create_instance` — NOT back to the
        // capturing shard. Routing every restore to the shard that
        // captured the snapshot hotspots it under a restore storm
        // (every cold provision of a popular model lands on one
        // thread) while the other shards idle. A compile-cache miss on
        // the receiving shard is honestly charged to `InitStats`, and
        // the shard re-seeds its batch-N kernel ladder right after, so
        // later restores and batched flushes there are warm.
        let shard = self.next_shard.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[shard]
            .send(Cmd::RestoreInstance {
                model: model.to_string(),
                variant: variant.to_string(),
                flat: flat.clone(),
                ladder_max: self.batch_kernel_max.load(Ordering::SeqCst),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine shard {shard} is down"))?;
        let (id, stats) = reply_rx
            .recv()
            .map_err(|_| anyhow!("engine shard {shard} dropped reply"))??;
        self.live.fetch_add(1, Ordering::SeqCst);
        Ok((
            InstanceHandle { model: model.to_string(), variant: variant.to_string(), shard, id },
            stats,
        ))
    }

    fn drop_instance(&self, handle: &InstanceHandle) {
        if self.shards[handle.shard].send(Cmd::DropInstance { instance: handle.id }).is_ok() {
            self.live.fetch_sub(1, Ordering::SeqCst);
        }
    }

    fn live_instances(&self) -> usize {
        self.live.load(Ordering::SeqCst) as usize
    }
}

// ------------------------------------------------------------- shard

struct CompiledModel {
    /// Weight-materialization executable. `Some` for the batch-1 entry
    /// (instance creation runs it); batch-N kernel entries share the
    /// batch-1 instance's weights and carry no init of their own.
    init_exe: Option<xla::PjRtLoadedExecutable>,
    infer_exe: xla::PjRtLoadedExecutable,
    input_shape: Vec<usize>,
}

struct Instance {
    key: (String, String),
    params: Vec<xla::PjRtBuffer>,
}

struct Shard {
    client: xla::PjRtClient,
    zoo: Zoo,
    /// Compile cache keyed `(model, variant, batch_n)`: `batch_n = 1`
    /// is the classic init+infer pair, `batch_n >= 2` an infer-only
    /// batch-N kernel compiled from the `<infer>_b<N>` artifact.
    compiled: HashMap<(String, String, usize), CompiledModel>,
    /// Ladder rungs the zoo ships no artifact for (or whose compile
    /// failed): remembered so each absent rung is probed — and counted
    /// as a miss — exactly once per shard, not per flush.
    batch_unavailable: std::collections::HashSet<(String, String, usize)>,
    instances: HashMap<u64, Instance>,
    next_id: u64,
}

fn shard_main(zoo: Zoo, rx: Receiver<Cmd>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            log::error!("engine shard failed to create PJRT client: {e}");
            // Drain commands with errors so callers do not hang.
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    Cmd::CreateInstance { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client: {e}")));
                    }
                    Cmd::Predict { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client: {e}")));
                    }
                    Cmd::PredictBatch { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client: {e}")));
                    }
                    Cmd::SnapshotInstance { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client: {e}")));
                    }
                    Cmd::RestoreInstance { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client: {e}")));
                    }
                    Cmd::DropInstance { .. } => {}
                    Cmd::Shutdown => return,
                }
            }
            return;
        }
    };
    let mut shard = Shard {
        client,
        zoo,
        compiled: HashMap::new(),
        batch_unavailable: std::collections::HashSet::new(),
        instances: HashMap::new(),
        next_id: 0,
    };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::CreateInstance { model, variant, reply } => {
                let _ = reply.send(shard.create_instance(&model, &variant));
            }
            Cmd::Predict { instance, image_seed, reply } => {
                let _ = reply.send(shard.predict(instance, image_seed));
            }
            Cmd::PredictBatch { instance, image_seeds, ladder_max, reply } => {
                let _ = reply.send(shard.predict_batch(instance, &image_seeds, ladder_max));
            }
            Cmd::SnapshotInstance { instance, reply } => {
                let _ = reply.send(shard.snapshot(instance));
            }
            Cmd::RestoreInstance { model, variant, flat, ladder_max, reply } => {
                let _ = reply.send(shard.restore(&model, &variant, &flat, ladder_max));
            }
            Cmd::DropInstance { instance } => {
                shard.instances.remove(&instance);
            }
            Cmd::Shutdown => break,
        }
    }
}

impl Shard {
    fn compile(&mut self, model: &str, variant: &str) -> Result<Duration> {
        let key = (model.to_string(), variant.to_string(), 1usize);
        if self.compiled.contains_key(&key) {
            return Ok(Duration::ZERO);
        }
        let manifest = self.zoo.get(model)?;
        let (init_path, infer_path) = manifest.artifact_paths(variant)?;
        // lint:allow(wall-clock: PJRT engine work is inherently real; wall timings feed InitStats/Prediction, not platform scheduling)
        let t0 = Instant::now();
        let init_exe = self.compile_file(&init_path)?;
        let infer_exe = self.compile_file(&infer_path)?;
        let dt = t0.elapsed();
        self.compiled.insert(
            key,
            CompiledModel {
                init_exe: Some(init_exe),
                infer_exe,
                input_shape: manifest.input_shape.clone(),
            },
        );
        Ok(dt)
    }

    /// Ensure the batch-`n` infer kernel for `(model, variant)` is in
    /// the compile cache. `Ok(true)` = cache hit, `Ok(false)` =
    /// compiled on the spot (a miss the caller reports); `Err` = the
    /// zoo ships no batch-`n` artifact or its compile failed, in which
    /// case the rung is remembered as unavailable and never probed
    /// again on this shard.
    fn ensure_batch_kernel(&mut self, model: &str, variant: &str, n: usize) -> Result<bool> {
        let key = (model.to_string(), variant.to_string(), n);
        if self.compiled.contains_key(&key) {
            return Ok(true);
        }
        if self.batch_unavailable.contains(&key) {
            bail!("batch-{n} kernel for {model}/{variant} is unavailable on this shard");
        }
        let attempt = (|| -> Result<CompiledModel> {
            let manifest = self.zoo.get(model)?;
            let (_, infer_path) = manifest.artifact_paths(variant)?;
            let batch_path = batch_artifact_path(&infer_path, n);
            if !batch_path.is_file() {
                bail!("no batch-{n} artifact at {}", batch_path.display());
            }
            let infer_exe = self.compile_file(&batch_path)?;
            let mut input_shape = manifest.input_shape.clone();
            input_shape[0] = n;
            Ok(CompiledModel { init_exe: None, infer_exe, input_shape })
        })();
        match attempt {
            Ok(cm) => {
                self.compiled.insert(key, cm);
                Ok(false)
            }
            Err(e) => {
                self.batch_unavailable.insert(key);
                Err(e)
            }
        }
    }

    fn compile_file(&self, path: &PathBuf) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("PJRT compile of {}: {e}", path.display()))
    }

    fn create_instance(&mut self, model: &str, variant: &str) -> Result<(u64, InitStats)> {
        let compile = self.compile(model, variant)?;
        let key = (model.to_string(), variant.to_string(), 1usize);
        let cm = self.compiled.get(&key).expect("just compiled");
        let init_exe = cm.init_exe.as_ref().expect("batch-1 entry always carries init");
        let manifest = self.zoo.get(model)?;

        // Run init() -> flat f32[N], pull it to the host, then slice
        // and pin each parameter as a device buffer so warm
        // predictions skip the host round-trip. (The host hop is the
        // "read model into memory" cost MXNet pays on every cold
        // start.)
        // lint:allow(wall-clock: PJRT engine work is inherently real; wall timings feed InitStats/Prediction, not platform scheduling)
        let t0 = Instant::now();
        let out = init_exe
            .execute::<xla::Literal>(&[])
            .map_err(|e| anyhow!("init execute for {model}: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("init literal sync: {e}"))?;
        let flat: Vec<f32> =
            lit.to_vec::<f32>().map_err(|e| anyhow!("init to_vec: {e}"))?;
        if flat.len() as u64 != manifest.param_elements {
            bail!(
                "init for {model} returned {} elements, manifest says {}",
                flat.len(),
                manifest.param_elements
            );
        }
        let mut params = Vec::with_capacity(manifest.param_count);
        let mut off = 0usize;
        for shape in &manifest.param_shapes {
            let n: usize = shape.iter().product();
            params.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&flat[off..off + n], shape, None)
                    .map_err(|e| anyhow!("uploading param: {e}"))?,
            );
            off += n;
        }
        let init_run = t0.elapsed();

        let id = self.next_id;
        self.next_id += 1;
        self.instances
            .insert(id, Instance { key: (model.to_string(), variant.to_string()), params });
        Ok((id, InitStats { compile, init_run, weight_bytes: manifest.param_bytes }))
    }

    /// Pull a live instance's parameter buffers back to the host as
    /// one flat `f32` vector (manifest order) — the restorable state a
    /// snapshot stores. Read-only: the instance keeps serving.
    fn snapshot(&mut self, instance: u64) -> Result<Vec<f32>> {
        let inst = self
            .instances
            .get(&instance)
            .ok_or_else(|| anyhow!("no such instance {instance} on this shard"))?;
        let manifest = self.zoo.get(&inst.key.0)?;
        let mut flat: Vec<f32> = Vec::with_capacity(manifest.param_elements as usize);
        for p in &inst.params {
            let lit = p
                .to_literal_sync()
                .map_err(|e| anyhow!("snapshot literal sync: {e}"))?;
            flat.extend(lit.to_vec::<f32>().map_err(|e| anyhow!("snapshot to_vec: {e}"))?);
        }
        if flat.len() as u64 != manifest.param_elements {
            bail!(
                "snapshot of {} captured {} elements, manifest says {}",
                inst.key.0,
                flat.len(),
                manifest.param_elements
            );
        }
        Ok(flat)
    }

    /// Create an instance from snapshotted weights: the init execution
    /// is skipped entirely in favor of uploading the blob's
    /// parameters. Restores route round-robin, so the compile may hit
    /// (this shard served the model before) or honestly miss — after
    /// which this shard re-seeds its batch-N kernel ladder up to
    /// `ladder_max` best-effort, so the warmed state a snapshot
    /// represents includes the batched kernels wherever it lands.
    fn restore(
        &mut self,
        model: &str,
        variant: &str,
        flat: &[f32],
        ladder_max: usize,
    ) -> Result<(u64, InitStats)> {
        let compile = self.compile(model, variant)?;
        let mut n = 2usize;
        while n <= ladder_max {
            // Best-effort: an absent rung artifact is not an error.
            let _ = self.ensure_batch_kernel(model, variant, n);
            n *= 2;
        }
        let manifest = self.zoo.get(model)?;
        if flat.len() as u64 != manifest.param_elements {
            bail!(
                "snapshot for {model} holds {} elements, manifest says {}",
                flat.len(),
                manifest.param_elements
            );
        }
        // lint:allow(wall-clock: PJRT engine work is inherently real; wall timings feed InitStats/Prediction, not platform scheduling)
        let t0 = Instant::now();
        let mut params = Vec::with_capacity(manifest.param_count);
        let mut off = 0usize;
        for shape in &manifest.param_shapes {
            let n: usize = shape.iter().product();
            params.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&flat[off..off + n], shape, None)
                    .map_err(|e| anyhow!("uploading restored param: {e}"))?,
            );
            off += n;
        }
        let init_run = t0.elapsed();

        let id = self.next_id;
        self.next_id += 1;
        self.instances
            .insert(id, Instance { key: (model.to_string(), variant.to_string()), params });
        Ok((id, InitStats { compile, init_run, weight_bytes: manifest.param_bytes }))
    }

    fn predict(&mut self, instance: u64, image_seed: u64) -> Result<Prediction> {
        let inst = self
            .instances
            .get(&instance)
            .ok_or_else(|| anyhow!("no such instance {instance} on this shard"))?;
        let cm = self
            .compiled
            .get(&(inst.key.0.clone(), inst.key.1.clone(), 1usize))
            .expect("instance without compiled model");
        let (h, w) = (cm.input_shape[1], cm.input_shape[2]);

        // lint:allow(wall-clock: PJRT engine work is inherently real; wall timings feed InitStats/Prediction, not platform scheduling)
        let t0 = Instant::now();
        let pixels = synthetic_image(h, w, image_seed);
        let image = self
            .client
            .buffer_from_host_buffer::<f32>(&pixels, &cm.input_shape, None)
            .map_err(|e| anyhow!("uploading image: {e}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = inst.params.iter().collect();
        args.push(&image);
        let out = cm
            .infer_exe
            .execute_b(&args)
            .map_err(|e| anyhow!("infer execute: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("infer literal sync: {e}"))?;
        let probs: Vec<f32> =
            lit.to_vec::<f32>().map_err(|e| anyhow!("reading probs: {e}"))?;
        let compute = t0.elapsed();

        // Argmax on the host (the paper's handler also post-processed
        // the forward pass output in-function).
        let (top1, top_prob) = probs
            .iter()
            .enumerate()
            .fold((0usize, f32::NEG_INFINITY), |(bi, bv), (i, &v)| {
                if v > bv {
                    (i, v)
                } else {
                    (bi, bv)
                }
            });
        Ok(Prediction { top1: top1 as i32, top_prob, compute })
    }

    /// Serve one batched flush by decomposing it over the compiled
    /// batch-N kernel ladder: largest compiled `N <= remaining`, the
    /// remainder folded through smaller kernels, and any rung the zoo
    /// does not ship falling back to the batch-1 executable for that
    /// chunk. The report tells the platform which kernels actually ran.
    fn predict_batch(
        &mut self,
        instance: u64,
        image_seeds: &[u64],
        ladder_max: usize,
    ) -> Result<(Vec<Prediction>, KernelReport)> {
        let inst_key = self
            .instances
            .get(&instance)
            .ok_or_else(|| anyhow!("no such instance {instance} on this shard"))?
            .key
            .clone();
        let mut preds = Vec::with_capacity(image_seeds.len());
        let mut report = KernelReport { kernel_batch_n: 1, ..Default::default() };
        let mut off = 0usize;
        for c in ladder_chunks(image_seeds.len(), ladder_max) {
            let chunk = &image_seeds[off..off + c];
            off += c;
            if c >= 2 {
                match self.ensure_batch_kernel(&inst_key.0, &inst_key.1, c) {
                    Ok(hit) => {
                        if hit {
                            report.batch_kernel_hits += 1;
                        } else {
                            report.batch_kernel_misses += 1;
                        }
                        match self.predict_chunk_batched(instance, chunk, c) {
                            Ok(mut ps) => {
                                report.kernel_batch_n = report.kernel_batch_n.max(c);
                                preds.append(&mut ps);
                                continue;
                            }
                            Err(e) => log::warn!(
                                "batch-{c} kernel run failed for {}/{}; batch-1 fallback: {e}",
                                inst_key.0,
                                inst_key.1
                            ),
                        }
                    }
                    Err(e) => {
                        // First probe of an absent rung counts as the
                        // one honest miss; later flushes skip it.
                        if !report_probe_was_cached(&e) {
                            report.batch_kernel_misses += 1;
                        }
                        log::debug!("batch-{c} kernel unavailable: {e}");
                    }
                }
            }
            for &seed in chunk {
                preds.push(self.predict(instance, seed)?);
            }
        }
        Ok((preds, report))
    }

    /// Run one chunk through its compiled batch-`n` kernel: inputs
    /// stacked into a single `[n, h, w, c]` device buffer, one
    /// `execute`, per-row argmax, compute split evenly across members.
    fn predict_chunk_batched(
        &mut self,
        instance: u64,
        seeds: &[u64],
        batch_n: usize,
    ) -> Result<Vec<Prediction>> {
        let inst = self
            .instances
            .get(&instance)
            .ok_or_else(|| anyhow!("no such instance {instance} on this shard"))?;
        let cm = self
            .compiled
            .get(&(inst.key.0.clone(), inst.key.1.clone(), batch_n))
            .ok_or_else(|| anyhow!("batch-{batch_n} kernel not compiled"))?;
        let (h, w) = (cm.input_shape[1], cm.input_shape[2]);

        // lint:allow(wall-clock: PJRT engine work is inherently real; wall timings feed InitStats/Prediction, not platform scheduling)
        let t0 = Instant::now();
        let mut pixels = Vec::with_capacity(seeds.len() * h * w * 3);
        for &seed in seeds {
            pixels.extend(synthetic_image(h, w, seed));
        }
        let image = self
            .client
            .buffer_from_host_buffer::<f32>(&pixels, &cm.input_shape, None)
            .map_err(|e| anyhow!("uploading batched image: {e}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = inst.params.iter().collect();
        args.push(&image);
        let out = cm
            .infer_exe
            .execute_b(&args)
            .map_err(|e| anyhow!("batch-{batch_n} infer execute: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("batched infer literal sync: {e}"))?;
        let probs: Vec<f32> =
            lit.to_vec::<f32>().map_err(|e| anyhow!("reading batched probs: {e}"))?;
        let compute = t0.elapsed();

        if probs.is_empty() || probs.len() % batch_n != 0 {
            bail!(
                "batch-{batch_n} kernel returned {} probabilities (not divisible)",
                probs.len()
            );
        }
        let classes = probs.len() / batch_n;
        let share = compute / batch_n as u32;
        Ok(probs
            .chunks_exact(classes)
            .map(|row| {
                let (top1, top_prob) = row.iter().enumerate().fold(
                    (0usize, f32::NEG_INFINITY),
                    |(bi, bv), (i, &v)| if v > bv { (i, v) } else { (bi, bv) },
                );
                Prediction { top1: top1 as i32, top_prob, compute: share }
            })
            .collect())
    }
}

/// `true` when an `ensure_batch_kernel` error came from the
/// remembered-unavailable set (already counted as a miss once) rather
/// than a fresh probe.
fn report_probe_was_cached(e: &anyhow::Error) -> bool {
    e.to_string().contains("unavailable on this shard")
}

/// Derive the batch-`n` infer artifact path from the batch-1 path:
/// `squeezenet_infer.hlo.txt` -> `squeezenet_infer_b4.hlo.txt` (the
/// `_b<N>` tag goes before the first extension dot).
fn batch_artifact_path(infer_path: &std::path::Path, n: usize) -> PathBuf {
    let name = infer_path.file_name().and_then(|s| s.to_str()).unwrap_or_default();
    let tagged = match name.split_once('.') {
        Some((stem, rest)) => format!("{stem}_b{n}.{rest}"),
        None => format!("{name}_b{n}"),
    };
    infer_path.with_file_name(tagged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_artifact_path_tags_before_first_dot() {
        let p = PathBuf::from("/zoo/squeezenet/squeezenet_infer.hlo.txt");
        assert_eq!(
            batch_artifact_path(&p, 4),
            PathBuf::from("/zoo/squeezenet/squeezenet_infer_b4.hlo.txt")
        );
        let bare = PathBuf::from("/zoo/m/infer");
        assert_eq!(batch_artifact_path(&bare, 2), PathBuf::from("/zoo/m/infer_b2"));
    }

    #[test]
    fn cached_unavailability_is_distinguishable() {
        let fresh = anyhow!("no batch-4 artifact at /zoo/x_infer_b4.hlo.txt");
        let cached = anyhow!("batch-4 kernel for m/pallas is unavailable on this shard");
        assert!(!report_probe_was_cached(&fresh));
        assert!(report_probe_was_cached(&cached));
    }
}
