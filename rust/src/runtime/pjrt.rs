//! The real engine: PJRT CPU client behind engine-shard threads.
//!
//! `xla::PjRtClient` is `Rc`-based (thread-confined), so each shard is
//! a dedicated thread owning a client, a compile cache keyed by
//! `(model, variant)`, and the shard's live instances (weights resident
//! as device buffers). Other threads talk to shards over channels; one
//! in-flight command per shard at a time, so shard count bounds
//! compute parallelism (containers are distributed round-robin).
//!
//! Artifact loading follows /opt/xla-example/load_hlo:
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. HLO **text** is the interchange
//! format (jax >= 0.5 protos are rejected by xla_extension 0.5.1).
//!
//! Calling convention (tuple-free — 0.5.1's C API segfaults converting
//! tuple buffers to literals): `init() -> flat f32[N]` which the shard
//! slices into per-parameter device buffers using the manifest's shape
//! list, and `infer(param_0.., image) -> probs f32[1, C]` with argmax
//! computed here.

use super::engine::{Engine, InitStats, InstanceHandle, Prediction, SnapshotBlob, SnapshotPayload};
use super::image::synthetic_image;
use super::manifest::{ModelManifest, Zoo};
use crate::exec::channel::{bounded, unbounded, Receiver, Sender};
use crate::util::plock;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

enum Cmd {
    CreateInstance {
        model: String,
        variant: String,
        reply: Sender<Result<(u64, InitStats)>>,
    },
    Predict {
        instance: u64,
        image_seed: u64,
        reply: Sender<Result<Prediction>>,
    },
    PredictBatch {
        instance: u64,
        image_seeds: Vec<u64>,
        reply: Sender<Result<Vec<Prediction>>>,
    },
    SnapshotInstance {
        instance: u64,
        reply: Sender<Result<Vec<f32>>>,
    },
    RestoreInstance {
        model: String,
        variant: String,
        flat: Arc<Vec<f32>>,
        reply: Sender<Result<(u64, InitStats)>>,
    },
    DropInstance {
        instance: u64,
    },
    Shutdown,
}

/// Thread-safe multi-shard PJRT engine.
pub struct PjrtEngine {
    zoo: Zoo,
    shards: Vec<Sender<Cmd>>,
    joins: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next_shard: AtomicUsize,
    live: AtomicU64,
}

impl PjrtEngine {
    /// Load the zoo index from `artifacts_dir` and spin up `shards`
    /// engine threads.
    pub fn new(artifacts_dir: &std::path::Path, shards: usize) -> Result<Self> {
        assert!(shards > 0, "need at least one engine shard");
        let zoo = Zoo::load(artifacts_dir)?;
        let mut senders = Vec::new();
        let mut joins = Vec::new();
        for i in 0..shards {
            let (tx, rx) = unbounded::<Cmd>();
            let zoo_c = zoo.clone();
            let handle = std::thread::Builder::new()
                .name(format!("engine-shard-{i}"))
                .spawn(move || shard_main(zoo_c, rx))
                .context("spawning engine shard")?;
            senders.push(tx);
            joins.push(handle);
        }
        Ok(Self {
            zoo,
            shards: senders,
            joins: Mutex::new(joins),
            next_shard: AtomicUsize::new(0),
            live: AtomicU64::new(0),
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn zoo(&self) -> &Zoo {
        &self.zoo
    }
}

impl Drop for PjrtEngine {
    fn drop(&mut self) {
        for tx in &self.shards {
            let _ = tx.send(Cmd::Shutdown);
        }
        // Drain under the lock, join outside it — never hold the
        // handle list's mutex across a shard's shutdown.
        let joins: Vec<_> = plock(&self.joins).drain(..).collect();
        for j in joins {
            let _ = j.join();
        }
    }
}

impl Engine for PjrtEngine {
    fn manifest(&self, model: &str) -> Result<ModelManifest> {
        self.zoo.get(model).cloned()
    }

    fn create_instance(&self, model: &str, variant: &str) -> Result<(InstanceHandle, InitStats)> {
        // Validate before crossing the channel for a friendlier error.
        let m = self.zoo.get(model)?;
        m.artifact_paths(variant)?;
        let shard = self.next_shard.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[shard]
            .send(Cmd::CreateInstance {
                model: model.to_string(),
                variant: variant.to_string(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine shard {shard} is down"))?;
        let (id, stats) = reply_rx
            .recv()
            .map_err(|_| anyhow!("engine shard {shard} dropped reply"))??;
        self.live.fetch_add(1, Ordering::SeqCst);
        Ok((
            InstanceHandle { model: model.to_string(), variant: variant.to_string(), shard, id },
            stats,
        ))
    }

    fn predict(&self, handle: &InstanceHandle, image_seed: u64) -> Result<Prediction> {
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[handle.shard]
            .send(Cmd::Predict { instance: handle.id, image_seed, reply: reply_tx })
            .map_err(|_| anyhow!("engine shard {} is down", handle.shard))?;
        reply_rx.recv().map_err(|_| anyhow!("engine shard {} dropped reply", handle.shard))?
    }

    fn predict_batch(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
    ) -> Result<Vec<Prediction>> {
        // One command crosses the channel for the whole batch: the
        // inputs run back-to-back on the owning shard without a
        // per-request cross-thread round trip in between, and without
        // interleaved commands evicting the instance's buffers from
        // cache mid-batch. The artifacts are batch-1 executables, so
        // the per-input compute is unchanged — the batching win here
        // is the amortized dispatch, not a fused kernel.
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[handle.shard]
            .send(Cmd::PredictBatch {
                instance: handle.id,
                image_seeds: image_seeds.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine shard {} is down", handle.shard))?;
        reply_rx.recv().map_err(|_| anyhow!("engine shard {} dropped reply", handle.shard))?
    }

    fn snapshot_instance(&self, handle: &InstanceHandle) -> Result<SnapshotBlob> {
        let manifest = self.zoo.get(&handle.model)?;
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[handle.shard]
            .send(Cmd::SnapshotInstance { instance: handle.id, reply: reply_tx })
            .map_err(|_| anyhow!("engine shard {} is down", handle.shard))?;
        let flat = reply_rx
            .recv()
            .map_err(|_| anyhow!("engine shard {} dropped reply", handle.shard))??;
        Ok(SnapshotBlob {
            model: handle.model.clone(),
            variant: handle.variant.clone(),
            size_bytes: manifest.param_bytes,
            payload: SnapshotPayload::PjrtWeights { shard: handle.shard, flat: Arc::new(flat) },
        })
    }

    fn restore_instance(
        &self,
        model: &str,
        variant: &str,
        blob: &SnapshotBlob,
    ) -> Result<(InstanceHandle, InitStats)> {
        if blob.model != model || blob.variant != variant {
            bail!(
                "snapshot of {}/{} cannot restore {model}/{variant}",
                blob.model,
                blob.variant
            );
        }
        let SnapshotPayload::PjrtWeights { shard, flat } = &blob.payload else {
            bail!("snapshot payload is not restorable by the PJRT engine");
        };
        // Route back to the capturing shard: its compile cache already
        // holds this model's executables, so the restore pays weight
        // upload only.
        let shard = *shard;
        if shard >= self.shards.len() {
            bail!("snapshot references unknown engine shard {shard}");
        }
        let (reply_tx, reply_rx) = bounded(1);
        self.shards[shard]
            .send(Cmd::RestoreInstance {
                model: model.to_string(),
                variant: variant.to_string(),
                flat: flat.clone(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine shard {shard} is down"))?;
        let (id, stats) = reply_rx
            .recv()
            .map_err(|_| anyhow!("engine shard {shard} dropped reply"))??;
        self.live.fetch_add(1, Ordering::SeqCst);
        Ok((
            InstanceHandle { model: model.to_string(), variant: variant.to_string(), shard, id },
            stats,
        ))
    }

    fn drop_instance(&self, handle: &InstanceHandle) {
        if self.shards[handle.shard].send(Cmd::DropInstance { instance: handle.id }).is_ok() {
            self.live.fetch_sub(1, Ordering::SeqCst);
        }
    }

    fn live_instances(&self) -> usize {
        self.live.load(Ordering::SeqCst) as usize
    }
}

// ------------------------------------------------------------- shard

struct CompiledModel {
    init_exe: xla::PjRtLoadedExecutable,
    infer_exe: xla::PjRtLoadedExecutable,
    input_shape: Vec<usize>,
}

struct Instance {
    key: (String, String),
    params: Vec<xla::PjRtBuffer>,
}

struct Shard {
    client: xla::PjRtClient,
    zoo: Zoo,
    compiled: HashMap<(String, String), CompiledModel>,
    instances: HashMap<u64, Instance>,
    next_id: u64,
}

fn shard_main(zoo: Zoo, rx: Receiver<Cmd>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            log::error!("engine shard failed to create PJRT client: {e}");
            // Drain commands with errors so callers do not hang.
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    Cmd::CreateInstance { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client: {e}")));
                    }
                    Cmd::Predict { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client: {e}")));
                    }
                    Cmd::PredictBatch { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client: {e}")));
                    }
                    Cmd::SnapshotInstance { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client: {e}")));
                    }
                    Cmd::RestoreInstance { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("no PJRT client: {e}")));
                    }
                    Cmd::DropInstance { .. } => {}
                    Cmd::Shutdown => return,
                }
            }
            return;
        }
    };
    let mut shard =
        Shard { client, zoo, compiled: HashMap::new(), instances: HashMap::new(), next_id: 0 };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::CreateInstance { model, variant, reply } => {
                let _ = reply.send(shard.create_instance(&model, &variant));
            }
            Cmd::Predict { instance, image_seed, reply } => {
                let _ = reply.send(shard.predict(instance, image_seed));
            }
            Cmd::PredictBatch { instance, image_seeds, reply } => {
                let _ = reply.send(
                    image_seeds.iter().map(|&seed| shard.predict(instance, seed)).collect(),
                );
            }
            Cmd::SnapshotInstance { instance, reply } => {
                let _ = reply.send(shard.snapshot(instance));
            }
            Cmd::RestoreInstance { model, variant, flat, reply } => {
                let _ = reply.send(shard.restore(&model, &variant, &flat));
            }
            Cmd::DropInstance { instance } => {
                shard.instances.remove(&instance);
            }
            Cmd::Shutdown => break,
        }
    }
}

impl Shard {
    fn compile(&mut self, model: &str, variant: &str) -> Result<Duration> {
        let key = (model.to_string(), variant.to_string());
        if self.compiled.contains_key(&key) {
            return Ok(Duration::ZERO);
        }
        let manifest = self.zoo.get(model)?;
        let (init_path, infer_path) = manifest.artifact_paths(variant)?;
        // lint:allow(wall-clock: PJRT engine work is inherently real; wall timings feed InitStats/Prediction, not platform scheduling)
        let t0 = Instant::now();
        let init_exe = self.compile_file(&init_path)?;
        let infer_exe = self.compile_file(&infer_path)?;
        let dt = t0.elapsed();
        self.compiled.insert(
            key,
            CompiledModel { init_exe, infer_exe, input_shape: manifest.input_shape.clone() },
        );
        Ok(dt)
    }

    fn compile_file(&self, path: &PathBuf) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("PJRT compile of {}: {e}", path.display()))
    }

    fn create_instance(&mut self, model: &str, variant: &str) -> Result<(u64, InitStats)> {
        let compile = self.compile(model, variant)?;
        let key = (model.to_string(), variant.to_string());
        let cm = self.compiled.get(&key).expect("just compiled");
        let manifest = self.zoo.get(model)?;

        // Run init() -> flat f32[N], pull it to the host, then slice
        // and pin each parameter as a device buffer so warm
        // predictions skip the host round-trip. (The host hop is the
        // "read model into memory" cost MXNet pays on every cold
        // start.)
        // lint:allow(wall-clock: PJRT engine work is inherently real; wall timings feed InitStats/Prediction, not platform scheduling)
        let t0 = Instant::now();
        let out = cm
            .init_exe
            .execute::<xla::Literal>(&[])
            .map_err(|e| anyhow!("init execute for {model}: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("init literal sync: {e}"))?;
        let flat: Vec<f32> =
            lit.to_vec::<f32>().map_err(|e| anyhow!("init to_vec: {e}"))?;
        if flat.len() as u64 != manifest.param_elements {
            bail!(
                "init for {model} returned {} elements, manifest says {}",
                flat.len(),
                manifest.param_elements
            );
        }
        let mut params = Vec::with_capacity(manifest.param_count);
        let mut off = 0usize;
        for shape in &manifest.param_shapes {
            let n: usize = shape.iter().product();
            params.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&flat[off..off + n], shape, None)
                    .map_err(|e| anyhow!("uploading param: {e}"))?,
            );
            off += n;
        }
        let init_run = t0.elapsed();

        let id = self.next_id;
        self.next_id += 1;
        self.instances.insert(id, Instance { key, params });
        Ok((id, InitStats { compile, init_run, weight_bytes: manifest.param_bytes }))
    }

    /// Pull a live instance's parameter buffers back to the host as
    /// one flat `f32` vector (manifest order) — the restorable state a
    /// snapshot stores. Read-only: the instance keeps serving.
    fn snapshot(&mut self, instance: u64) -> Result<Vec<f32>> {
        let inst = self
            .instances
            .get(&instance)
            .ok_or_else(|| anyhow!("no such instance {instance} on this shard"))?;
        let manifest = self.zoo.get(&inst.key.0)?;
        let mut flat: Vec<f32> = Vec::with_capacity(manifest.param_elements as usize);
        for p in &inst.params {
            let lit = p
                .to_literal_sync()
                .map_err(|e| anyhow!("snapshot literal sync: {e}"))?;
            flat.extend(lit.to_vec::<f32>().map_err(|e| anyhow!("snapshot to_vec: {e}"))?);
        }
        if flat.len() as u64 != manifest.param_elements {
            bail!(
                "snapshot of {} captured {} elements, manifest says {}",
                inst.key.0,
                flat.len(),
                manifest.param_elements
            );
        }
        Ok(flat)
    }

    /// Create an instance from snapshotted weights: the compile is a
    /// cache hit when the blob lands on the shard that captured it
    /// (the normal routing — "cache seeding"; a miss still compiles,
    /// honestly reported), and the init execution is skipped entirely
    /// in favor of uploading the blob's parameters.
    fn restore(&mut self, model: &str, variant: &str, flat: &[f32]) -> Result<(u64, InitStats)> {
        let compile = self.compile(model, variant)?;
        let manifest = self.zoo.get(model)?;
        if flat.len() as u64 != manifest.param_elements {
            bail!(
                "snapshot for {model} holds {} elements, manifest says {}",
                flat.len(),
                manifest.param_elements
            );
        }
        // lint:allow(wall-clock: PJRT engine work is inherently real; wall timings feed InitStats/Prediction, not platform scheduling)
        let t0 = Instant::now();
        let mut params = Vec::with_capacity(manifest.param_count);
        let mut off = 0usize;
        for shape in &manifest.param_shapes {
            let n: usize = shape.iter().product();
            params.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&flat[off..off + n], shape, None)
                    .map_err(|e| anyhow!("uploading restored param: {e}"))?,
            );
            off += n;
        }
        let init_run = t0.elapsed();

        let id = self.next_id;
        self.next_id += 1;
        self.instances
            .insert(id, Instance { key: (model.to_string(), variant.to_string()), params });
        Ok((id, InitStats { compile, init_run, weight_bytes: manifest.param_bytes }))
    }

    fn predict(&mut self, instance: u64, image_seed: u64) -> Result<Prediction> {
        let inst = self
            .instances
            .get(&instance)
            .ok_or_else(|| anyhow!("no such instance {instance} on this shard"))?;
        let cm = self.compiled.get(&inst.key).expect("instance without compiled model");
        let (h, w) = (cm.input_shape[1], cm.input_shape[2]);

        // lint:allow(wall-clock: PJRT engine work is inherently real; wall timings feed InitStats/Prediction, not platform scheduling)
        let t0 = Instant::now();
        let pixels = synthetic_image(h, w, image_seed);
        let image = self
            .client
            .buffer_from_host_buffer::<f32>(&pixels, &cm.input_shape, None)
            .map_err(|e| anyhow!("uploading image: {e}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = inst.params.iter().collect();
        args.push(&image);
        let out = cm
            .infer_exe
            .execute_b(&args)
            .map_err(|e| anyhow!("infer execute: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("infer literal sync: {e}"))?;
        let probs: Vec<f32> =
            lit.to_vec::<f32>().map_err(|e| anyhow!("reading probs: {e}"))?;
        let compute = t0.elapsed();

        // Argmax on the host (the paper's handler also post-processed
        // the forward pass output in-function).
        let (top1, top_prob) = probs
            .iter()
            .enumerate()
            .fold((0usize, f32::NEG_INFINITY), |(bi, bv), (i, &v)| {
                if v > bv {
                    (i, v)
                } else {
                    (bi, bv)
                }
            });
        Ok(Prediction { top1: top1 as i32, top_prob, compute })
    }
}
