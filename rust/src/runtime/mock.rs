//! Synthetic engine for platform tests and fast simulation sweeps.
//!
//! Costs are configured per model; `predict` does not burn CPU — it
//! just *reports* the configured compute duration, which the platform
//! then scales/bills exactly like a real one (the CPU governor and the
//! virtual clock treat reported compute uniformly).

use super::engine::{Engine, InitStats, InstanceHandle, Prediction};
use super::manifest::ModelManifest;
use crate::util::SplitMix64;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Configured costs for one mock model.
#[derive(Debug, Clone)]
pub struct MockModelCosts {
    /// Full-speed forward-pass time.
    pub predict: Duration,
    /// Weight materialization at instance creation.
    pub init_run: Duration,
    /// First-compile cost (per engine, like a shard cache miss).
    pub compile: Duration,
    pub manifest: ModelManifest,
}

impl MockModelCosts {
    /// A mock model mirroring one of the paper's three, with costs
    /// roughly proportional to its FLOPs.
    pub fn paper_like(name: &str, predict_ms: u64, size_mb: f64, peak_mem_mb: u32) -> Self {
        let manifest = ModelManifest {
            name: name.to_string(),
            input_shape: vec![1, 224, 224, 3],
            num_classes: 1000,
            param_count: 2,
            param_elements: (size_mb * 1e6 / 4.0) as u64,
            param_bytes: (size_mb * 1e6) as u64,
            flops: predict_ms * 2_000_000, // ~2 GFLOPS full speed
            paper_size_mb: size_mb,
            paper_peak_mem_mb: peak_mem_mb,
            param_shapes: vec![vec![1], vec![1]],
            artifacts: [(
                "pallas".to_string(),
                ("mock_init.hlo.txt".to_string(), "mock_infer.hlo.txt".to_string()),
            )]
            .into_iter()
            .collect(),
            dir: PathBuf::from("/nonexistent"),
        };
        Self {
            predict: Duration::from_millis(predict_ms),
            init_run: Duration::from_millis((size_mb * 2.0) as u64),
            compile: Duration::from_millis(150),
            manifest,
        }
    }
}

/// See module docs.
pub struct MockEngine {
    models: BTreeMap<String, MockModelCosts>,
    compiled: Mutex<std::collections::BTreeSet<String>>,
    instances: Mutex<std::collections::BTreeSet<(usize, u64)>>,
    next_id: AtomicU64,
    /// Calls observed (assertions in tests).
    pub predict_calls: AtomicU64,
    pub create_calls: AtomicU64,
    /// When true, `create_instance` fails (failure-injection tests).
    pub fail_create: std::sync::atomic::AtomicBool,
}

impl MockEngine {
    pub fn new(models: Vec<MockModelCosts>) -> Self {
        Self {
            models: models.into_iter().map(|m| (m.manifest.name.clone(), m)).collect(),
            compiled: Mutex::new(Default::default()),
            instances: Mutex::new(Default::default()),
            next_id: AtomicU64::new(0),
            predict_calls: AtomicU64::new(0),
            create_calls: AtomicU64::new(0),
            fail_create: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// The three paper models with full-speed costs in the measured
    /// ballpark of the real artifacts on this machine.
    pub fn paper_zoo() -> Self {
        Self::new(vec![
            MockModelCosts::paper_like("squeezenet", 105, 5.0, 85),
            MockModelCosts::paper_like("resnet18", 130, 46.7, 229),
            MockModelCosts::paper_like("resnext50", 2220, 100.0, 429),
        ])
    }

    fn costs(&self, model: &str) -> Result<&MockModelCosts> {
        self.models.get(model).ok_or_else(|| anyhow!("mock engine: unknown model {model:?}"))
    }
}

impl Engine for MockEngine {
    fn manifest(&self, model: &str) -> Result<ModelManifest> {
        Ok(self.costs(model)?.manifest.clone())
    }

    fn create_instance(&self, model: &str, variant: &str) -> Result<(InstanceHandle, InitStats)> {
        self.create_calls.fetch_add(1, Ordering::SeqCst);
        if self.fail_create.load(Ordering::SeqCst) {
            return Err(anyhow!("mock engine: injected create failure"));
        }
        let costs = self.costs(model)?;
        if variant != "pallas" && variant != "ref" {
            return Err(anyhow!("mock engine: unknown variant {variant:?}"));
        }
        let compile = {
            let mut c = self.compiled.lock().unwrap();
            if c.insert(model.to_string()) {
                costs.compile
            } else {
                Duration::ZERO
            }
        };
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.instances.lock().unwrap().insert((0, id));
        Ok((
            InstanceHandle { model: model.to_string(), variant: variant.to_string(), shard: 0, id },
            InitStats { compile, init_run: costs.init_run, weight_bytes: costs.manifest.param_bytes },
        ))
    }

    fn predict(&self, handle: &InstanceHandle, image_seed: u64) -> Result<Prediction> {
        self.predict_calls.fetch_add(1, Ordering::SeqCst);
        if !self.instances.lock().unwrap().contains(&(handle.shard, handle.id)) {
            return Err(anyhow!("mock engine: predict on dead instance {:?}", handle));
        }
        let costs = self.costs(&handle.model)?;
        // Deterministic pseudo-classification + ±5% compute jitter.
        let mut rng = SplitMix64::new(image_seed);
        let top1 = rng.gen_range(0, costs.manifest.num_classes as u64) as i32;
        let jitter = 0.95 + 0.1 * rng.next_f64();
        Ok(Prediction {
            top1,
            top_prob: 0.5 + 0.5 * rng.next_f32(),
            compute: Duration::from_secs_f64(costs.predict.as_secs_f64() * jitter),
        })
    }

    fn drop_instance(&self, handle: &InstanceHandle) {
        self.instances.lock().unwrap().remove(&(handle.shard, handle.id));
    }

    fn live_instances(&self) -> usize {
        self.instances.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let e = MockEngine::paper_zoo();
        let (h, stats) = e.create_instance("squeezenet", "pallas").unwrap();
        assert!(stats.compile > Duration::ZERO, "first create compiles");
        assert_eq!(e.live_instances(), 1);

        let (h2, stats2) = e.create_instance("squeezenet", "pallas").unwrap();
        assert_eq!(stats2.compile, Duration::ZERO, "second create hits cache");
        assert_eq!(e.live_instances(), 2);

        let p = e.predict(&h, 42).unwrap();
        assert!(p.compute > Duration::ZERO);
        assert!((0..1000).contains(&p.top1));

        // Determinism per seed.
        let p2 = e.predict(&h, 42).unwrap();
        assert_eq!(p.top1, p2.top1);
        assert_eq!(p.compute, p2.compute);

        e.drop_instance(&h);
        assert_eq!(e.live_instances(), 1);
        assert!(e.predict(&h, 1).is_err(), "predict on dropped instance fails");
        e.drop_instance(&h2);
        assert_eq!(e.live_instances(), 0);
    }

    #[test]
    fn unknown_model_and_variant() {
        let e = MockEngine::paper_zoo();
        assert!(e.create_instance("vgg", "pallas").is_err());
        assert!(e.create_instance("resnet18", "cuda").is_err());
        assert!(e.manifest("nope").is_err());
    }

    #[test]
    fn failure_injection() {
        let e = MockEngine::paper_zoo();
        e.fail_create.store(true, Ordering::SeqCst);
        assert!(e.create_instance("squeezenet", "pallas").is_err());
        e.fail_create.store(false, Ordering::SeqCst);
        assert!(e.create_instance("squeezenet", "pallas").is_ok());
    }

    #[test]
    fn paper_zoo_cost_ordering() {
        let e = MockEngine::paper_zoo();
        let s = e.manifest("squeezenet").unwrap();
        let r = e.manifest("resnet18").unwrap();
        let x = e.manifest("resnext50").unwrap();
        assert!(s.param_bytes < r.param_bytes && r.param_bytes < x.param_bytes);
        assert!(s.paper_peak_mem_mb < r.paper_peak_mem_mb);
        assert_eq!(x.paper_peak_mem_mb, 429);
    }
}
