//! Synthetic engine for platform tests and fast simulation sweeps.
//!
//! Costs are configured per model; `predict` does not burn CPU — it
//! just *reports* the configured compute duration, which the platform
//! then scales/bills exactly like a real one (the CPU governor and the
//! virtual clock treat reported compute uniformly).

use super::engine::{
    ladder_chunks, prev_power_of_two, Engine, InitStats, InstanceHandle, KernelReport, Prediction,
    SnapshotBlob, SnapshotPayload,
};
use super::manifest::ModelManifest;
use crate::util::{plock, SplitMix64};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Marginal full-speed cost of each extra input in a batched forward
/// pass, as a fraction of a solo pass: a batch of `n` costs
/// `predict * (1 + BATCH_COST_MARGINAL * (n - 1))` in total — sublinear
/// in `n`, modeling the weight-reuse/amortization a real batched
/// kernel gets (activations grow with `n`, weight traffic does not).
pub const BATCH_COST_MARGINAL: f64 = 0.25;

/// Marginal full-speed cost of an extra input served by a *compiled
/// batch-N kernel* (as a fraction of a solo pass). A flush of `n`
/// inputs decomposed into `k` kernel launches costs
/// `predict * (1 + BATCH_COST_MARGINAL * (k - 1)
///            + KERNEL_COST_MARGINAL * (n - k))`
/// in total: every launch past the first pays the launch margin, and
/// every input that rides *inside* a batch-N kernel (rather than being
/// its own launch) pays only this smaller kernel margin. With the
/// ladder disabled (`batch_kernel_max = 1`) every input is its own
/// launch (`k = n`), which reduces the formula to the pre-ladder
/// `predict * (1 + BATCH_COST_MARGINAL * (n - 1))` exactly — so the
/// single-kernel configuration reproduces the old cost bit-for-bit,
/// and larger compiled kernels strictly lower the modeled cost.
pub const KERNEL_COST_MARGINAL: f64 = 0.10;

/// Engine-side restore bandwidth of the mock (bytes/s): the mock's
/// [`Engine::restore_instance`] costs `weight_bytes / MOCK_RESTORE_BW`
/// of `init_run` and no compile at all — the weight upload a snapshot
/// restore pays instead of the init execution.
pub const MOCK_RESTORE_BW: f64 = 400e6;

/// Configured costs for one mock model.
#[derive(Debug, Clone)]
pub struct MockModelCosts {
    /// Full-speed forward-pass time.
    pub predict: Duration,
    /// Weight materialization at instance creation.
    pub init_run: Duration,
    /// First-compile cost (per engine, like a shard cache miss).
    pub compile: Duration,
    pub manifest: ModelManifest,
}

impl MockModelCosts {
    /// A mock model mirroring one of the paper's three, with costs
    /// roughly proportional to its FLOPs.
    pub fn paper_like(name: &str, predict_ms: u64, size_mb: f64, peak_mem_mb: u32) -> Self {
        let manifest = ModelManifest {
            name: name.to_string(),
            input_shape: vec![1, 224, 224, 3],
            num_classes: 1000,
            param_count: 2,
            param_elements: (size_mb * 1e6 / 4.0) as u64,
            param_bytes: (size_mb * 1e6) as u64,
            flops: predict_ms * 2_000_000, // ~2 GFLOPS full speed
            paper_size_mb: size_mb,
            paper_peak_mem_mb: peak_mem_mb,
            param_shapes: vec![vec![1], vec![1]],
            artifacts: [(
                "pallas".to_string(),
                ("mock_init.hlo.txt".to_string(), "mock_infer.hlo.txt".to_string()),
            )]
            .into_iter()
            .collect(),
            dir: PathBuf::from("/nonexistent"),
        };
        Self {
            predict: Duration::from_millis(predict_ms),
            init_run: Duration::from_millis((size_mb * 2.0) as u64),
            compile: Duration::from_millis(150),
            manifest,
        }
    }
}

/// See module docs.
pub struct MockEngine {
    models: BTreeMap<String, MockModelCosts>,
    compiled: Mutex<std::collections::BTreeSet<String>>,
    /// Compiled batch-N kernels: `(model, batch_n)` entries for
    /// `batch_n >= 2` (the batch-1 executable lives in `compiled`).
    /// Seeded on first use (a miss "compiles on the spot") and by
    /// snapshot restores, mirroring the PJRT shard-cache seeding.
    compiled_batch: Mutex<std::collections::BTreeSet<(String, usize)>>,
    /// Top of the power-of-two kernel ladder this engine will use for
    /// batched passes (1 = ladder disabled, batch-1 kernels only).
    batch_kernel_max: AtomicUsize,
    instances: Mutex<std::collections::BTreeSet<(usize, u64)>>,
    next_id: AtomicU64,
    /// Calls observed (assertions in tests).
    pub predict_calls: AtomicU64,
    pub create_calls: AtomicU64,
    pub snapshot_calls: AtomicU64,
    pub restore_calls: AtomicU64,
    /// When true, `create_instance` fails (failure-injection tests).
    pub fail_create: std::sync::atomic::AtomicBool,
    /// When true, `snapshot_instance` fails (capture must be
    /// best-effort: a failed capture costs the request nothing).
    pub fail_snapshot: std::sync::atomic::AtomicBool,
    /// When true, `restore_instance` fails (a failed restore must fall
    /// back to the full cold path without leaking an instance).
    pub fail_restore: std::sync::atomic::AtomicBool,
}

impl MockEngine {
    pub fn new(models: Vec<MockModelCosts>) -> Self {
        Self {
            models: models.into_iter().map(|m| (m.manifest.name.clone(), m)).collect(),
            compiled: Mutex::new(Default::default()),
            compiled_batch: Mutex::new(Default::default()),
            batch_kernel_max: AtomicUsize::new(1),
            instances: Mutex::new(Default::default()),
            next_id: AtomicU64::new(0),
            predict_calls: AtomicU64::new(0),
            create_calls: AtomicU64::new(0),
            snapshot_calls: AtomicU64::new(0),
            restore_calls: AtomicU64::new(0),
            fail_create: std::sync::atomic::AtomicBool::new(false),
            fail_snapshot: std::sync::atomic::AtomicBool::new(false),
            fail_restore: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// The three paper models with full-speed costs in the measured
    /// ballpark of the real artifacts on this machine.
    pub fn paper_zoo() -> Self {
        Self::new(vec![
            MockModelCosts::paper_like("squeezenet", 105, 5.0, 85),
            MockModelCosts::paper_like("resnet18", 130, 46.7, 229),
            MockModelCosts::paper_like("resnext50", 2220, 100.0, 429),
        ])
    }

    fn costs(&self, model: &str) -> Result<&MockModelCosts> {
        self.models.get(model).ok_or_else(|| anyhow!("mock engine: unknown model {model:?}"))
    }

    /// Set the top of the power-of-two batch-kernel ladder (clamped to
    /// at least 1; non-powers round down to the previous power of two,
    /// matching what a real artifact zoo would actually ship).
    pub fn set_batch_kernel_max(&self, n: usize) {
        let n = n.max(1);
        self.batch_kernel_max.store(prev_power_of_two(n), Ordering::SeqCst);
    }

    /// Current top of the batch-kernel ladder.
    pub fn batch_kernel_max(&self) -> usize {
        self.batch_kernel_max.load(Ordering::SeqCst)
    }

    /// Count of compiled batch-N (N >= 2) kernels (test assertions).
    pub fn compiled_batch_kernels(&self) -> usize {
        plock(&self.compiled_batch).len()
    }
}

impl Engine for MockEngine {
    fn manifest(&self, model: &str) -> Result<ModelManifest> {
        Ok(self.costs(model)?.manifest.clone())
    }

    fn create_instance(&self, model: &str, variant: &str) -> Result<(InstanceHandle, InitStats)> {
        self.create_calls.fetch_add(1, Ordering::SeqCst);
        if self.fail_create.load(Ordering::SeqCst) {
            return Err(anyhow!("mock engine: injected create failure"));
        }
        let costs = self.costs(model)?;
        if variant != "pallas" && variant != "ref" {
            return Err(anyhow!("mock engine: unknown variant {variant:?}"));
        }
        let compile = {
            let mut c = plock(&self.compiled);
            if c.insert(model.to_string()) {
                costs.compile
            } else {
                Duration::ZERO
            }
        };
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        plock(&self.instances).insert((0, id));
        Ok((
            InstanceHandle { model: model.to_string(), variant: variant.to_string(), shard: 0, id },
            InitStats { compile, init_run: costs.init_run, weight_bytes: costs.manifest.param_bytes },
        ))
    }

    fn predict(&self, handle: &InstanceHandle, image_seed: u64) -> Result<Prediction> {
        self.predict_calls.fetch_add(1, Ordering::SeqCst);
        if !plock(&self.instances).contains(&(handle.shard, handle.id)) {
            return Err(anyhow!("mock engine: predict on dead instance {:?}", handle));
        }
        let costs = self.costs(&handle.model)?;
        // Deterministic pseudo-classification + ±5% compute jitter.
        let mut rng = SplitMix64::new(image_seed);
        let top1 = rng.gen_range(0, costs.manifest.num_classes as u64) as i32;
        let jitter = 0.95 + 0.1 * rng.next_f64();
        Ok(Prediction {
            top1,
            top_prob: 0.5 + 0.5 * rng.next_f32(),
            compute: Duration::from_secs_f64(costs.predict.as_secs_f64() * jitter),
        })
    }

    fn predict_batch(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
    ) -> Result<Vec<Prediction>> {
        if image_seeds.is_empty() {
            return Ok(Vec::new());
        }
        // A singleton "batch" is exactly a solo pass (same jitter, same
        // cost), so `max_batch_size = 1` and lone flushes reproduce
        // today's behavior bit-for-bit.
        if image_seeds.len() == 1 {
            return Ok(vec![self.predict(handle, image_seeds[0])?]);
        }
        // One batched forward pass, however many inputs ride it.
        self.predict_calls.fetch_add(1, Ordering::SeqCst);
        if !plock(&self.instances).contains(&(handle.shard, handle.id)) {
            return Err(anyhow!("mock engine: batched predict on dead instance {:?}", handle));
        }
        let costs = self.costs(&handle.model)?;
        let n = image_seeds.len() as f64;
        let total = costs.predict.as_secs_f64() * (1.0 + BATCH_COST_MARGINAL * (n - 1.0));
        let share = Duration::from_secs_f64(total / n);
        Ok(image_seeds
            .iter()
            .map(|&seed| {
                // Same per-seed stream as `predict` (top1, jitter draw,
                // top_prob) so a batched member classifies identically
                // to a solo invocation of the same seed; only the
                // compute is the shared (jitter-free) batch split.
                let mut rng = SplitMix64::new(seed);
                let top1 = rng.gen_range(0, costs.manifest.num_classes as u64) as i32;
                let _jitter = rng.next_f64();
                Prediction { top1, top_prob: 0.5 + 0.5 * rng.next_f32(), compute: share }
            })
            .collect())
    }

    fn predict_batch_report(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
    ) -> Result<(Vec<Prediction>, KernelReport)> {
        self.predict_batch_report_capped(handle, image_seeds, usize::MAX)
    }

    fn predict_batch_report_capped(
        &self,
        handle: &InstanceHandle,
        image_seeds: &[u64],
        rung_cap: usize,
    ) -> Result<(Vec<Prediction>, KernelReport)> {
        let n = image_seeds.len();
        // The per-pass cap shrinks the ladder, never grows it: the
        // configured engine rung stays the hard ceiling, and a cap of
        // `usize::MAX` (the plain `predict_batch_report` path) is the
        // identity.
        let ladder_max = self
            .batch_kernel_max
            .load(Ordering::SeqCst)
            .min(prev_power_of_two(rung_cap.max(1)));
        // Ladder disabled (or nothing to ladder): exactly the
        // pre-ladder batched pass, bit-for-bit — including the
        // singleton's solo jitter.
        if ladder_max <= 1 || n <= 1 {
            let preds = self.predict_batch(handle, image_seeds)?;
            return Ok((preds, KernelReport { kernel_batch_n: 1, ..Default::default() }));
        }
        // One batched flush, decomposed into compiled batch-N kernel
        // launches. Still ONE observable forward pass platform-side.
        self.predict_calls.fetch_add(1, Ordering::SeqCst);
        if !plock(&self.instances).contains(&(handle.shard, handle.id)) {
            return Err(anyhow!("mock engine: batched predict on dead instance {:?}", handle));
        }
        let costs = self.costs(&handle.model)?;
        let chunks = ladder_chunks(n, ladder_max);
        let mut report = KernelReport { kernel_batch_n: 1, ..Default::default() };
        {
            let mut cache = plock(&self.compiled_batch);
            for &c in &chunks {
                if c < 2 {
                    continue; // batch-1 executable: base compile cache.
                }
                report.kernel_batch_n = report.kernel_batch_n.max(c);
                if cache.insert((handle.model.clone(), c)) {
                    // Miss: the shard compiles the batch-c kernel on
                    // the spot and caches it. Like `create_instance`'s
                    // compile, the cost is charged platform-side (the
                    // miss is visible in the report), not to this
                    // pass's compute.
                    report.batch_kernel_misses += 1;
                } else {
                    report.batch_kernel_hits += 1;
                }
            }
        }
        let k = chunks.len() as f64;
        let nf = n as f64;
        let total = costs.predict.as_secs_f64()
            * (1.0 + BATCH_COST_MARGINAL * (k - 1.0) + KERNEL_COST_MARGINAL * (nf - k));
        let share = Duration::from_secs_f64(total / nf);
        let preds = image_seeds
            .iter()
            .map(|&seed| {
                // Same per-seed stream as `predict`/`predict_batch`, so
                // classification is independent of the kernel ladder.
                let mut rng = SplitMix64::new(seed);
                let top1 = rng.gen_range(0, costs.manifest.num_classes as u64) as i32;
                let _jitter = rng.next_f64();
                Prediction { top1, top_prob: 0.5 + 0.5 * rng.next_f32(), compute: share }
            })
            .collect();
        Ok((preds, report))
    }

    fn snapshot_instance(&self, handle: &InstanceHandle) -> Result<SnapshotBlob> {
        self.snapshot_calls.fetch_add(1, Ordering::SeqCst);
        if self.fail_snapshot.load(Ordering::SeqCst) {
            return Err(anyhow!("mock engine: injected snapshot failure"));
        }
        if !plock(&self.instances).contains(&(handle.shard, handle.id)) {
            return Err(anyhow!("mock engine: snapshot of dead instance {:?}", handle));
        }
        let costs = self.costs(&handle.model)?;
        Ok(SnapshotBlob {
            model: handle.model.clone(),
            variant: handle.variant.clone(),
            size_bytes: costs.manifest.param_bytes,
            payload: SnapshotPayload::Synthetic,
        })
    }

    fn restore_instance(
        &self,
        model: &str,
        variant: &str,
        blob: &SnapshotBlob,
    ) -> Result<(InstanceHandle, InitStats)> {
        self.restore_calls.fetch_add(1, Ordering::SeqCst);
        if self.fail_restore.load(Ordering::SeqCst) {
            return Err(anyhow!("mock engine: injected restore failure"));
        }
        if blob.model != model || blob.variant != variant {
            return Err(anyhow!(
                "mock engine: snapshot of {}/{} cannot restore {model}/{variant}",
                blob.model,
                blob.variant
            ));
        }
        let costs = self.costs(model)?;
        if variant != "pallas" && variant != "ref" {
            return Err(anyhow!("mock engine: unknown variant {variant:?}"));
        }
        // A snapshot carries the compiled code with it: restoring also
        // seeds the compile cache (the mock's analog of the PJRT shard
        // cache seeding), so the restore itself pays only the weight
        // upload — never a compile.
        plock(&self.compiled).insert(model.to_string());
        // And the batch-N ladder rides along: the receiving shard's
        // first batched flush after a restore hits the kernel cache
        // instead of paying ladder compiles all over again.
        let ladder_max = self.batch_kernel_max.load(Ordering::SeqCst);
        if ladder_max >= 2 {
            let mut cache = plock(&self.compiled_batch);
            let mut c = 2usize;
            while c <= ladder_max {
                cache.insert((model.to_string(), c));
                c *= 2;
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        plock(&self.instances).insert((0, id));
        Ok((
            InstanceHandle { model: model.to_string(), variant: variant.to_string(), shard: 0, id },
            InitStats {
                compile: Duration::ZERO,
                init_run: Duration::from_secs_f64(blob.size_bytes as f64 / MOCK_RESTORE_BW),
                weight_bytes: costs.manifest.param_bytes,
            },
        ))
    }

    fn drop_instance(&self, handle: &InstanceHandle) {
        plock(&self.instances).remove(&(handle.shard, handle.id));
    }

    fn live_instances(&self) -> usize {
        plock(&self.instances).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let e = MockEngine::paper_zoo();
        let (h, stats) = e.create_instance("squeezenet", "pallas").unwrap();
        assert!(stats.compile > Duration::ZERO, "first create compiles");
        assert_eq!(e.live_instances(), 1);

        let (h2, stats2) = e.create_instance("squeezenet", "pallas").unwrap();
        assert_eq!(stats2.compile, Duration::ZERO, "second create hits cache");
        assert_eq!(e.live_instances(), 2);

        let p = e.predict(&h, 42).unwrap();
        assert!(p.compute > Duration::ZERO);
        assert!((0..1000).contains(&p.top1));

        // Determinism per seed.
        let p2 = e.predict(&h, 42).unwrap();
        assert_eq!(p.top1, p2.top1);
        assert_eq!(p.compute, p2.compute);

        e.drop_instance(&h);
        assert_eq!(e.live_instances(), 1);
        assert!(e.predict(&h, 1).is_err(), "predict on dropped instance fails");
        e.drop_instance(&h2);
        assert_eq!(e.live_instances(), 0);
    }

    #[test]
    fn unknown_model_and_variant() {
        let e = MockEngine::paper_zoo();
        assert!(e.create_instance("vgg", "pallas").is_err());
        assert!(e.create_instance("resnet18", "cuda").is_err());
        assert!(e.manifest("nope").is_err());
    }

    #[test]
    fn failure_injection() {
        let e = MockEngine::paper_zoo();
        e.fail_create.store(true, Ordering::SeqCst);
        assert!(e.create_instance("squeezenet", "pallas").is_err());
        e.fail_create.store(false, Ordering::SeqCst);
        assert!(e.create_instance("squeezenet", "pallas").is_ok());
    }

    #[test]
    fn batched_predict_is_one_sublinear_pass() {
        let e = MockEngine::paper_zoo();
        let (h, _) = e.create_instance("squeezenet", "pallas").unwrap();
        let solo = e.predict(&h, 7).unwrap();
        let calls_before = e.predict_calls.load(Ordering::SeqCst);

        let seeds = [7u64, 8, 9, 10];
        let preds = e.predict_batch(&h, &seeds).unwrap();
        assert_eq!(preds.len(), 4, "one prediction per seed");
        assert_eq!(
            e.predict_calls.load(Ordering::SeqCst),
            calls_before + 1,
            "a batch is ONE forward pass"
        );
        // Classification matches the solo run of the same seed.
        assert_eq!(preds[0].top1, solo.top1);
        assert_eq!(preds[0].top_prob, solo.top_prob);
        // Batch total is sublinear: 4x inputs cost (1 + 0.25*3) = 1.75x
        // a solo pass, split evenly across members.
        let total: f64 = preds.iter().map(|p| p.compute.as_secs_f64()).sum();
        let solo_full = e.costs("squeezenet").unwrap().predict.as_secs_f64();
        assert!((total - solo_full * 1.75).abs() < 1e-9, "total={total}");
        assert!(preds.windows(2).all(|w| w[0].compute == w[1].compute), "even split");

        // A singleton batch is exactly a solo pass (jitter included).
        let single = e.predict_batch(&h, &[7]).unwrap();
        assert_eq!(single[0].compute, solo.compute);

        e.drop_instance(&h);
        assert!(e.predict_batch(&h, &seeds).is_err(), "dead instance refused");
    }

    #[test]
    fn ladder_disabled_report_reproduces_batch1_path_bit_for_bit() {
        let e = MockEngine::paper_zoo();
        assert_eq!(e.batch_kernel_max(), 1, "ladder off by default");
        let (h, _) = e.create_instance("squeezenet", "pallas").unwrap();
        let seeds = [7u64, 8, 9, 10];
        let plain = e.predict_batch(&h, &seeds).unwrap();
        let (preds, report) = e.predict_batch_report(&h, &seeds).unwrap();
        assert_eq!(report, KernelReport { kernel_batch_n: 1, ..Default::default() });
        for (a, b) in plain.iter().zip(&preds) {
            assert_eq!(a.top1, b.top1);
            assert_eq!(a.top_prob, b.top_prob);
            assert_eq!(a.compute, b.compute);
        }
        // Singleton through the report path keeps the solo jitter.
        let solo = e.predict(&h, 7).unwrap();
        let (single, r1) = e.predict_batch_report(&h, &[7]).unwrap();
        assert_eq!(single[0].compute, solo.compute);
        assert_eq!(r1.kernel_batch_n, 1);
        assert_eq!(e.compiled_batch_kernels(), 0, "no ladder entries ever compiled");
    }

    #[test]
    fn kernel_ladder_cost_strictly_decreases() {
        let e = MockEngine::paper_zoo();
        let (h, _) = e.create_instance("squeezenet", "pallas").unwrap();
        let solo_full = e.costs("squeezenet").unwrap().predict.as_secs_f64();
        let seeds: Vec<u64> = (0..8).collect();
        let solo = e.predict(&h, 0).unwrap();
        // Modeled totals for n = 8 as the ladder grows:
        //   L=1: k=8 launches -> 1 + 0.25*7            = 2.75x
        //   L=2: k=4          -> 1 + 0.25*3 + 0.10*4   = 2.15x
        //   L=4: k=2          -> 1 + 0.25*1 + 0.10*6   = 1.85x
        //   L=8: k=1          -> 1          + 0.10*7   = 1.70x
        let mut prev = f64::INFINITY;
        for (ladder, expect) in [(1usize, 2.75), (2, 2.15), (4, 1.85), (8, 1.70)] {
            e.set_batch_kernel_max(ladder);
            let calls_before = e.predict_calls.load(Ordering::SeqCst);
            let (preds, first) = e.predict_batch_report(&h, &seeds).unwrap();
            assert_eq!(preds.len(), 8);
            assert_eq!(
                e.predict_calls.load(Ordering::SeqCst),
                calls_before + 1,
                "one observable pass regardless of kernel decomposition"
            );
            let total: f64 = preds.iter().map(|p| p.compute.as_secs_f64()).sum();
            assert!((total - solo_full * expect).abs() < 1e-9, "L={ladder} total={total}");
            assert!(total < prev, "cost strictly decreases as the ladder grows");
            prev = total;
            assert!(preds.windows(2).all(|w| w[0].compute == w[1].compute), "even split");
            // Classification is ladder-independent.
            assert_eq!(preds[0].top1, solo.top1);
            assert_eq!(preds[0].top_prob, solo.top_prob);
            assert_eq!(first.kernel_batch_n, ladder);
            if ladder >= 2 {
                assert_eq!(first.batch_kernel_misses, 1, "new rung compiled on first use");
                // Second flush hits every ladder kernel it needs.
                let (_, again) = e.predict_batch_report(&h, &seeds).unwrap();
                assert_eq!(again.batch_kernel_misses, 0);
                assert_eq!(again.batch_kernel_hits, 8 / ladder as u64);
            }
        }
        // Non-power-of-two flush folds the remainder through smaller
        // kernels: n=7 @ L=4 -> chunks [4, 2, 1], largest kernel 4.
        let (preds7, r7) = e.predict_batch_report(&h, &(0..7).collect::<Vec<_>>()).unwrap();
        assert_eq!(r7.kernel_batch_n, 4);
        assert_eq!(r7.batch_kernel_hits + r7.batch_kernel_misses, 2, "chunk 1 is not a ladder hit");
        let total7: f64 = preds7.iter().map(|p| p.compute.as_secs_f64()).sum();
        assert!((total7 - solo_full * (1.0 + 0.25 * 2.0 + 0.10 * 4.0)).abs() < 1e-9);
    }

    #[test]
    fn restore_reseeds_batch_kernel_ladder() {
        let e = MockEngine::paper_zoo();
        e.set_batch_kernel_max(4);
        let (h, _) = e.create_instance("resnet18", "pallas").unwrap();
        let blob = e.snapshot_instance(&h).unwrap();
        assert_eq!(e.compiled_batch_kernels(), 0, "snapshot capture compiles nothing");
        let (h2, _) = e.restore_instance("resnet18", "pallas", &blob).unwrap();
        assert_eq!(e.compiled_batch_kernels(), 2, "restore seeds the {{2, 4}} rungs");
        let (_, report) = e.predict_batch_report(&h2, &[1, 2, 3, 4]).unwrap();
        assert_eq!(report.batch_kernel_misses, 0, "first post-restore flush hits the cache");
        assert_eq!(report.batch_kernel_hits, 1);
        assert_eq!(report.kernel_batch_n, 4);
        e.drop_instance(&h);
        e.drop_instance(&h2);
    }

    #[test]
    fn ladder_chunks_decomposition() {
        assert_eq!(ladder_chunks(8, 8), vec![8]);
        assert_eq!(ladder_chunks(8, 4), vec![4, 4]);
        assert_eq!(ladder_chunks(7, 4), vec![4, 2, 1]);
        assert_eq!(ladder_chunks(5, 1), vec![1, 1, 1, 1, 1]);
        assert_eq!(ladder_chunks(1, 64), vec![1]);
        assert_eq!(prev_power_of_two(1), 1);
        assert_eq!(prev_power_of_two(6), 4);
        assert_eq!(prev_power_of_two(64), 64);
        // The setter rounds non-powers down.
        let e = MockEngine::paper_zoo();
        e.set_batch_kernel_max(6);
        assert_eq!(e.batch_kernel_max(), 4);
        e.set_batch_kernel_max(0);
        assert_eq!(e.batch_kernel_max(), 1);
    }

    #[test]
    fn default_trait_batch_loops_predict() {
        // The trait's default impl (exercised through a &dyn Engine
        // whose concrete type overrides it — so call the default
        // explicitly on a throwaway wrapper).
        struct Looper(MockEngine);
        impl Engine for Looper {
            fn manifest(&self, m: &str) -> Result<ModelManifest> {
                self.0.manifest(m)
            }
            fn create_instance(&self, m: &str, v: &str) -> Result<(InstanceHandle, InitStats)> {
                self.0.create_instance(m, v)
            }
            fn predict(&self, h: &InstanceHandle, s: u64) -> Result<Prediction> {
                self.0.predict(h, s)
            }
            fn drop_instance(&self, h: &InstanceHandle) {
                self.0.drop_instance(h)
            }
            fn live_instances(&self) -> usize {
                self.0.live_instances()
            }
        }
        let e = Looper(MockEngine::paper_zoo());
        let (h, _) = e.create_instance("squeezenet", "pallas").unwrap();
        let preds = e.predict_batch(&h, &[1, 2, 3]).unwrap();
        assert_eq!(preds.len(), 3);
        // No batching win: three full solo passes.
        assert_eq!(e.0.predict_calls.load(Ordering::SeqCst), 3);
        for (seed, p) in [1u64, 2, 3].iter().zip(&preds) {
            assert_eq!(p.top1, e.predict(&h, *seed).unwrap().top1);
        }
    }

    #[test]
    fn snapshot_restore_roundtrip_skips_compile() {
        let e = MockEngine::paper_zoo();
        let (h, cold) = e.create_instance("resnet18", "pallas").unwrap();
        let blob = e.snapshot_instance(&h).unwrap();
        assert_eq!(blob.model, "resnet18");
        assert_eq!(blob.size_bytes, e.manifest("resnet18").unwrap().param_bytes);
        assert!(matches!(blob.payload, SnapshotPayload::Synthetic));
        // The source instance stays live and usable after capture.
        let solo = e.predict(&h, 9).unwrap();

        let (h2, restored) = e.restore_instance("resnet18", "pallas", &blob).unwrap();
        assert_eq!(e.live_instances(), 2);
        assert_eq!(restored.compile, Duration::ZERO, "restore never compiles");
        assert!(restored.init_run < cold.init_run, "weight upload beats the init run");
        let expect = blob.size_bytes as f64 / MOCK_RESTORE_BW;
        assert!((restored.init_run.as_secs_f64() - expect).abs() < 1e-12);
        // A restored instance predicts exactly like the original.
        let p = e.predict(&h2, 9).unwrap();
        assert_eq!(p.top1, solo.top1);
        assert_eq!(p.compute, solo.compute);
        e.drop_instance(&h);
        e.drop_instance(&h2);
        assert_eq!(e.live_instances(), 0);
    }

    #[test]
    fn snapshot_restore_failure_injection_and_mismatch() {
        let e = MockEngine::paper_zoo();
        let (h, _) = e.create_instance("squeezenet", "pallas").unwrap();
        let blob = e.snapshot_instance(&h).unwrap();
        // Mismatched model/variant is refused, nothing leaks.
        assert!(e.restore_instance("resnet18", "pallas", &blob).is_err());
        assert!(e.restore_instance("squeezenet", "ref", &blob).is_err());
        assert_eq!(e.live_instances(), 1);
        // Injected failures: capture and restore both fail cleanly.
        e.fail_snapshot.store(true, Ordering::SeqCst);
        assert!(e.snapshot_instance(&h).is_err());
        e.fail_snapshot.store(false, Ordering::SeqCst);
        e.fail_restore.store(true, Ordering::SeqCst);
        assert!(e.restore_instance("squeezenet", "pallas", &blob).is_err());
        assert_eq!(e.live_instances(), 1, "failed restore creates nothing");
        e.fail_restore.store(false, Ordering::SeqCst);
        assert!(e.restore_instance("squeezenet", "pallas", &blob).is_ok());
        // A dead instance cannot be captured.
        e.drop_instance(&h);
        assert!(e.snapshot_instance(&h).is_err());
    }

    #[test]
    fn paper_zoo_cost_ordering() {
        let e = MockEngine::paper_zoo();
        let s = e.manifest("squeezenet").unwrap();
        let r = e.manifest("resnet18").unwrap();
        let x = e.manifest("resnext50").unwrap();
        assert!(s.param_bytes < r.param_bytes && r.param_bytes < x.param_bytes);
        assert!(s.paper_peak_mem_mb < r.paper_peak_mem_mb);
        assert_eq!(x.paper_peak_mem_mb, 429);
    }
}
