//! TOML-subset config parser + the typed platform configuration.
//!
//! No `serde`/`toml` in the offline dep closure, so this implements the
//! subset the configs use: `[section]` and `[section.sub]` headers,
//! `key = value` with string / integer / float / bool / homogeneous
//! array values, `#` comments, and inline errors with line numbers.

mod platform_config;
mod toml;

pub use platform_config::{
    BootstrapConfig, CapturePolicy, MemorySize, ModelConfig, NetworkConfig, PlatformConfig,
    PolicyConfig, PricingConfig, SnapshotConfig, TraceConfig, MAX_QUEUE_DEADLINE_MS,
    MEMORY_SIZES_2017,
};
pub use toml::{parse_toml, TomlError, TomlValue};
