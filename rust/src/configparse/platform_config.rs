//! Typed platform configuration with the paper's calibration constants.
//!
//! Defaults reproduce the paper's setup (AWS Lambda, 2017): Table 1
//! pricing, 128..1536 MB memory tiers in 128 MB steps, 100 ms billing
//! granularity, ~10 min container keep-alive. `PlatformConfig::load`
//! overlays a TOML file (see `configs/platform.toml`) on the defaults.

use super::toml::{parse_toml, TomlValue};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Lambda memory size in MB. Tiers go 128..=1536 in 64 MB increments;
/// the paper sweeps the 128 MB multiples.
pub type MemorySize = u32;

/// The paper's swept memory sizes (x-axis of every figure).
pub const MEMORY_SIZES_2017: [MemorySize; 12] =
    [128, 256, 384, 512, 640, 768, 896, 1024, 1152, 1280, 1408, 1536];

/// Ceiling on any dispatch deadline — the platform default and every
/// per-function override (a parked request holds a gateway worker
/// thread for the wait): one hour.
pub const MAX_QUEUE_DEADLINE_MS: u64 = 3_600_000;

/// Table 1: price per 100 ms for each memory size, in dollars.
const PRICE_TABLE_2017: [(MemorySize, f64); 12] = [
    (128, 0.000000208),
    (256, 0.000000417),
    (384, 0.000000625),
    (512, 0.000000834),
    (640, 0.000001042),
    (768, 0.00000125),
    (896, 0.000001459),
    (1024, 0.000001667),
    (1152, 0.000001875),
    (1280, 0.000002084),
    (1408, 0.000002292),
    (1536, 0.000002501),
];

#[derive(Debug, Clone)]
pub struct PricingConfig {
    /// `(memory_mb, dollars per 100ms)` rows, ascending by memory.
    pub table: Vec<(MemorySize, f64)>,
    /// Billing quantum (AWS 2017: 100 ms).
    pub granularity_ms: u64,
    /// Per-request surcharge (AWS: $0.20 per 1M requests).
    pub per_request_dollars: f64,
}

impl Default for PricingConfig {
    fn default() -> Self {
        Self {
            table: PRICE_TABLE_2017.to_vec(),
            granularity_ms: 100,
            per_request_dollars: 0.2e-6,
        }
    }
}

impl PricingConfig {
    /// Price per 100 ms for `mem`, linearly interpolated between table
    /// rows for non-tabulated 64 MB tiers.
    pub fn price_per_unit(&self, mem: MemorySize) -> Result<f64> {
        if let Some(&(_, p)) = self.table.iter().find(|(m, _)| *m == mem) {
            return Ok(p);
        }
        let below = self.table.iter().rev().find(|(m, _)| *m < mem);
        let above = self.table.iter().find(|(m, _)| *m > mem);
        match (below, above) {
            (Some(&(m0, p0)), Some(&(m1, p1))) => {
                let t = (mem - m0) as f64 / (m1 - m0) as f64;
                Ok(p0 + t * (p1 - p0))
            }
            _ => bail!("memory size {mem} MB outside the price table"),
        }
    }
}

/// Cold-start bootstrap model (everything that is NOT the function
/// body): sandbox provisioning + language-runtime init + code/model
/// fetch. Calibrated against 2017-era Lambda measurements; the *model
/// load* component is real work (PJRT compile + weight materialization)
/// measured, not simulated — see `platform/container.rs`.
#[derive(Debug, Clone)]
pub struct BootstrapConfig {
    /// Median sandbox (container) provisioning delay, seconds.
    pub sandbox_median_s: f64,
    /// Log-normal shape for the sandbox delay.
    pub sandbox_sigma: f64,
    /// Language-runtime (python+mxnet in the paper) init, seconds.
    pub runtime_init_s: f64,
    /// Deployment-package read bandwidth, bytes/s (code+model fetch
    /// from local zip: the paper bundled models into the function).
    pub package_read_bw: f64,
    /// True: sandbox/runtime delays consume (virtual) clock time.
    pub simulate_delays: bool,
}

impl Default for BootstrapConfig {
    fn default() -> Self {
        Self {
            // 2017-era Lambda cold starts: a few hundred ms of sandbox
            // setup + O(1s) runtime+framework import.
            sandbox_median_s: 0.25,
            sandbox_sigma: 0.35,
            runtime_init_s: 1.2,
            package_read_bw: 80e6,
            simulate_delays: true,
        }
    }
}

/// When the platform captures instance snapshots (see
/// [`SnapshotConfig::capture_policy`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CapturePolicy {
    /// Capture after a full cold provision, on a detached worker —
    /// off the request's critical path (the default).
    Background,
    /// Capture inline before the provisioning request is served:
    /// deterministic, for tests/benches and eager pre-seeding.
    Sync,
    /// Never capture; the store only serves pre-seeded snapshots.
    Off,
}

impl std::str::FromStr for CapturePolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "background" => Ok(Self::Background),
            "sync" => Ok(Self::Sync),
            "off" => Ok(Self::Off),
            other => bail!("unknown snapshot.capture_policy {other:?} (background|sync|off)"),
        }
    }
}

/// Snapshot/restore cold-start mitigation (`[snapshot]` in the TOML):
/// checkpoint a warmed instance once, then provision future cold
/// starts from the checkpoint — paying sandbox + restore I/O instead
/// of runtime init + package fetch + compile + weight init.
#[derive(Debug, Clone)]
pub struct SnapshotConfig {
    /// Master switch, default off (the per-function `snapshot` policy
    /// field overrides it either way).
    pub enabled: bool,
    /// Bound on total stored snapshot bytes; least-recently-used
    /// snapshots are evicted beyond it.
    pub capacity_bytes: u64,
    /// Simulated snapshot-fetch bandwidth, bytes/s: the platform-side
    /// I/O a restore pays instead of the package fetch, scaled by the
    /// CPU/memory share exactly like `bootstrap.package_read_bw`.
    pub restore_bw: f64,
    /// When captures happen (`"background"` | `"sync"` | `"off"`).
    pub capture_policy: CapturePolicy,
}

impl Default for SnapshotConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            capacity_bytes: 1 << 30,
            // Snapshot artifacts live on fast local/zonal storage, not
            // the 2017 package path: restores move bytes ~2.5x faster
            // than the package fetch they replace.
            restore_bw: 200e6,
            capture_policy: CapturePolicy::Background,
        }
    }
}

/// Adaptive hot-path controllers (`[policy]` in the TOML): per-function
/// feedback loops that steer the batch window, the batch-kernel rung
/// target, and predictive pre-provisioning from live telemetry.
/// Disabled by default — with `enabled = false` (and no per-function
/// `adaptive` override) the static-knob pipeline is preserved
/// bit-for-bit.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// Master switch, default off (the per-function `adaptive` policy
    /// field overrides it either way).
    pub enabled: bool,
    /// Default end-to-end latency SLO, milliseconds; the batch-window
    /// controller shrinks the window once the recent `batch_wait_p99`
    /// consumes too much of this budget. Per-function override: the
    /// deploy/reconfigure `slo_target_ms`.
    pub slo_target_ms: u64,
    /// Ceiling the adaptive batch window may grow to, milliseconds.
    pub window_cap_ms: u64,
    /// EWMA smoothing factor in `(0, 1]` for the per-function
    /// arrival-rate level (higher = reacts faster, forgets faster).
    pub ewma_alpha: f64,
    /// Holt trend smoothing factor in `(0, 1]` for the arrival-rate
    /// slope the pre-provisioning forecast extrapolates.
    pub holt_beta: f64,
    /// Span of the decaying sliding window the controllers read
    /// percentiles from, seconds — recent traffic, not all-time.
    pub decay_window_s: f64,
    /// How far ahead the arrival-rate forecast projects when sizing
    /// the pre-provisioned warm target, seconds (roughly one cold
    /// provision's worth of lead time).
    pub forecast_horizon_s: f64,
    /// Cap on forecast-driven warm containers per function, on top of
    /// `min_warm` (bounds what a runaway forecast can provision).
    pub max_prewarm: usize,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            // The paper's mid-range SLA target (1 s) as the default
            // budget the batch-window controller defends.
            slo_target_ms: 1_000,
            window_cap_ms: 100,
            ewma_alpha: 0.3,
            holt_beta: 0.1,
            decay_window_s: 60.0,
            forecast_horizon_s: 2.0,
            max_prewarm: 8,
        }
    }
}

/// End-to-end invocation tracing (`platform/trace.rs`): per-request
/// span timelines in a tail-sampled exemplar ring. Disabled by
/// default — with `enabled = false` no trace id is minted and no
/// trace lock is ever acquired, so the serving pipeline is preserved
/// bit-for-bit.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Master switch, default off.
    pub enabled: bool,
    /// Capacity of the retained-trace exemplar ring (oldest evicted
    /// first; `0` keeps counters only).
    pub ring_capacity: usize,
    /// Probability in `[0, 1]` that a steady-state (warm, in-budget,
    /// error-free) trace is retained. Interesting traces —
    /// cold/restored starts, SLO violations, errors, queue expiries —
    /// are always retained regardless of this rate.
    pub sample_rate: f64,
    /// Emit one structured JSON line per finished invocation to
    /// stdout (trace id, function, start kind, per-stage durations).
    pub log_events: bool,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { enabled: false, ring_capacity: 512, sample_rate: 0.0, log_events: false }
    }
}

/// Client<->gateway network model (the JMeter<->API-Gateway leg).
#[derive(Debug, Clone)]
pub struct NetworkConfig {
    /// Fixed round-trip component, seconds.
    pub rtt_s: f64,
    /// Mean of the exponential jitter component, seconds.
    pub jitter_mean_s: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        Self { rtt_s: 0.035, jitter_mean_s: 0.005 }
    }
}

/// Per-model deployment config (overrides manifest defaults).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    /// Artifact variant: "pallas" (default) or "ref".
    pub variant: String,
}

#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Memory at which a container owns one full vCPU; Lambda allocates
    /// CPU share proportionally below it (documented ~1792 MB).
    pub full_power_mem_mb: u32,
    /// Idle container keep-alive before eviction, seconds. 2017-era
    /// Lambda reaped idle containers after ~5 minutes — below the
    /// paper's 10-minute probe gap, which is what forces its cold
    /// starts.
    pub keep_alive_s: f64,
    /// Hard cap on concurrently provisioned containers per function
    /// (AWS account default: 1000 across the account).
    pub max_containers: usize,
    /// Admission control: default bound on each function's dispatch
    /// wait queue. A request that misses capacity parks here instead
    /// of being rejected; when the queue for its function is already
    /// this deep the request is refused with HTTP 503. `0` disables
    /// parking only: a miss that can still take a freed container or
    /// reserve a capacity slot on the spot is served; a genuine
    /// shortage is refused immediately.
    /// Per-function override: the deploy/reconfigure `queue_capacity`.
    pub queue_capacity: usize,
    /// Admission control: default deadline a parked request may wait
    /// for capacity, in milliseconds, before it is failed with HTTP
    /// 503 + `Retry-After`. `0` degenerates to try-once semantics.
    /// Per-function override: the deploy/reconfigure
    /// `queue_deadline_ms`.
    pub queue_deadline_ms: u64,
    /// Micro-batching: default max number of concurrent invocations of
    /// one function coalesced into a single batched forward pass on
    /// one warm container. `1` (the default) disables batching — the
    /// execution path is then bit-for-bit the pre-batching pipeline.
    /// Per-function override: the deploy/reconfigure `max_batch_size`.
    pub max_batch_size: usize,
    /// Micro-batching: default window, in milliseconds, a batch
    /// leader holds its container open to absorb followers before
    /// flushing (an under-sized batch flushes at the window; a full
    /// batch flushes early). `0` means a leader never waits — only
    /// requests that arrive while a batch is already executing its
    /// admission can coalesce. Per-function override: the
    /// deploy/reconfigure `batch_window_ms`.
    pub batch_window_ms: u64,
    /// Warm-pool shard count: the idle map and waiter condvar are
    /// split into this many function-hash buckets so one hot
    /// function's release traffic doesn't contend with — or wake —
    /// waiters of functions hashing elsewhere. `1` (the default) is
    /// the single-lock pool, bit-for-bit. The container cap stays
    /// global regardless of shard count.
    pub pool_shards: usize,
    /// Batch-N compiled kernels: largest batch size the engine
    /// compiles a dedicated executable for, over a power-of-two
    /// ladder (`4` means kernels for batch 1, 2, and 4). A flush
    /// picks the largest compiled N ≤ the batch size and folds the
    /// remainder through smaller kernels. `1` (the default) keeps
    /// batched passes on per-member batch-1 kernels — the
    /// pre-ladder pipeline, bit-for-bit. Must be a power of two.
    pub batch_kernel_max: usize,
    /// Background pool-maintainer tick interval, seconds: each tick
    /// runs the keep-alive eviction sweep and replenishes `min_warm`
    /// targets. `0` disables the maintainer.
    pub maintainer_interval_s: f64,
    /// Capacity of the metrics sink's recent-records ring buffer (raw
    /// records for the experiment/report tooling; aggregates are
    /// streamed and never truncated). `0` keeps aggregates only.
    pub metrics_ring_capacity: usize,
    /// CPU throttle quantum, seconds (cgroup cfs_period-like).
    pub throttle_quantum_s: f64,
    /// Worker threads executing containers.
    pub executor_threads: usize,
    pub pricing: PricingConfig,
    pub bootstrap: BootstrapConfig,
    pub network: NetworkConfig,
    /// Snapshot/restore cold-start mitigation (default: disabled).
    pub snapshot: SnapshotConfig,
    /// Adaptive hot-path controllers (default: disabled).
    pub policy: PolicyConfig,
    /// End-to-end invocation tracing (default: disabled).
    pub trace: TraceConfig,
    /// Deterministic seed for every stochastic component.
    pub seed: u64,
    /// Directory of AOT artifacts.
    pub artifacts_dir: String,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        Self {
            full_power_mem_mb: 1792,
            keep_alive_s: 300.0,
            max_containers: 1000,
            queue_capacity: 64,
            queue_deadline_ms: 2_000,
            max_batch_size: 1,
            batch_window_ms: 0,
            pool_shards: 1,
            batch_kernel_max: 1,
            maintainer_interval_s: 5.0,
            metrics_ring_capacity: 4096,
            throttle_quantum_s: 0.02,
            executor_threads: 8,
            pricing: PricingConfig::default(),
            bootstrap: BootstrapConfig::default(),
            network: NetworkConfig::default(),
            snapshot: SnapshotConfig::default(),
            policy: PolicyConfig::default(),
            trace: TraceConfig::default(),
            seed: 20171001,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl PlatformConfig {
    /// Parse a TOML file over the defaults.
    pub fn load(path: &Path) -> Result<Self> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&src)
    }

    pub fn from_toml(src: &str) -> Result<Self> {
        let doc = parse_toml(src)?;
        let mut cfg = Self::default();
        let get_f64 = |k: &str| doc.get(k).and_then(TomlValue::as_f64);
        let get_u64 = |k: &str| doc.get(k).and_then(TomlValue::as_i64).map(|v| v as u64);

        if let Some(v) = get_u64("platform.full_power_mem_mb") {
            cfg.full_power_mem_mb = v as u32;
        }
        if let Some(v) = get_f64("platform.keep_alive_s") {
            cfg.keep_alive_s = v;
        }
        if let Some(v) = get_u64("platform.max_containers") {
            cfg.max_containers = v as usize;
        }
        if let Some(v) = get_u64("platform.queue_capacity") {
            cfg.queue_capacity = v as usize;
        }
        if let Some(v) = get_u64("platform.queue_deadline_ms") {
            cfg.queue_deadline_ms = v;
        }
        if let Some(v) = get_u64("platform.max_batch_size") {
            cfg.max_batch_size = v as usize;
        }
        if let Some(v) = get_u64("platform.batch_window_ms") {
            cfg.batch_window_ms = v;
        }
        if let Some(v) = get_u64("platform.pool_shards") {
            cfg.pool_shards = v as usize;
        }
        if let Some(v) = get_u64("platform.batch_kernel_max") {
            cfg.batch_kernel_max = v as usize;
        }
        if let Some(v) = get_f64("platform.maintainer_interval_s") {
            cfg.maintainer_interval_s = v;
        }
        if let Some(v) = get_u64("platform.metrics_ring_capacity") {
            cfg.metrics_ring_capacity = v as usize;
        }
        if let Some(v) = get_f64("platform.throttle_quantum_s") {
            cfg.throttle_quantum_s = v;
        }
        if let Some(v) = get_u64("platform.executor_threads") {
            cfg.executor_threads = v as usize;
        }
        if let Some(v) = get_u64("platform.seed") {
            cfg.seed = v;
        }
        if let Some(v) = doc.get("platform.artifacts_dir").and_then(TomlValue::as_str) {
            cfg.artifacts_dir = v.to_string();
        }

        if let Some(v) = get_u64("pricing.granularity_ms") {
            cfg.pricing.granularity_ms = v;
        }
        if let Some(v) = get_f64("pricing.per_request_dollars") {
            cfg.pricing.per_request_dollars = v;
        }
        if let (Some(mems), Some(prices)) = (
            doc.get("pricing.memory_mb").and_then(TomlValue::as_array),
            doc.get("pricing.dollars_per_unit").and_then(TomlValue::as_array),
        ) {
            if mems.len() != prices.len() {
                bail!("pricing.memory_mb and pricing.dollars_per_unit length mismatch");
            }
            cfg.pricing.table = mems
                .iter()
                .zip(prices)
                .map(|(m, p)| {
                    Ok((
                        m.as_i64().context("memory_mb must be int")? as MemorySize,
                        p.as_f64().context("dollars_per_unit must be number")?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
        }

        if let Some(v) = get_f64("bootstrap.sandbox_median_s") {
            cfg.bootstrap.sandbox_median_s = v;
        }
        if let Some(v) = get_f64("bootstrap.sandbox_sigma") {
            cfg.bootstrap.sandbox_sigma = v;
        }
        if let Some(v) = get_f64("bootstrap.runtime_init_s") {
            cfg.bootstrap.runtime_init_s = v;
        }
        if let Some(v) = get_f64("bootstrap.package_read_bw") {
            cfg.bootstrap.package_read_bw = v;
        }
        if let Some(v) = doc.get("bootstrap.simulate_delays").and_then(TomlValue::as_bool) {
            cfg.bootstrap.simulate_delays = v;
        }

        if let Some(v) = get_f64("network.rtt_s") {
            cfg.network.rtt_s = v;
        }
        if let Some(v) = get_f64("network.jitter_mean_s") {
            cfg.network.jitter_mean_s = v;
        }

        if let Some(v) = doc.get("snapshot.enabled").and_then(TomlValue::as_bool) {
            cfg.snapshot.enabled = v;
        }
        if let Some(v) = get_u64("snapshot.capacity_bytes") {
            cfg.snapshot.capacity_bytes = v;
        }
        if let Some(v) = get_f64("snapshot.restore_bw") {
            cfg.snapshot.restore_bw = v;
        }
        if let Some(v) = doc.get("snapshot.capture_policy").and_then(TomlValue::as_str) {
            cfg.snapshot.capture_policy = v.parse()?;
        }

        if let Some(v) = doc.get("policy.enabled").and_then(TomlValue::as_bool) {
            cfg.policy.enabled = v;
        }
        if let Some(v) = get_u64("policy.slo_target_ms") {
            cfg.policy.slo_target_ms = v;
        }
        if let Some(v) = get_u64("policy.window_cap_ms") {
            cfg.policy.window_cap_ms = v;
        }
        if let Some(v) = get_f64("policy.ewma_alpha") {
            cfg.policy.ewma_alpha = v;
        }
        if let Some(v) = get_f64("policy.holt_beta") {
            cfg.policy.holt_beta = v;
        }
        if let Some(v) = get_f64("policy.decay_window_s") {
            cfg.policy.decay_window_s = v;
        }
        if let Some(v) = get_f64("policy.forecast_horizon_s") {
            cfg.policy.forecast_horizon_s = v;
        }
        if let Some(v) = get_u64("policy.max_prewarm") {
            cfg.policy.max_prewarm = v as usize;
        }

        if let Some(v) = doc.get("trace.enabled").and_then(TomlValue::as_bool) {
            cfg.trace.enabled = v;
        }
        if let Some(v) = get_u64("trace.ring_capacity") {
            cfg.trace.ring_capacity = v as usize;
        }
        if let Some(v) = get_f64("trace.sample_rate") {
            cfg.trace.sample_rate = v;
        }
        if let Some(v) = doc.get("trace.log_events").and_then(TomlValue::as_bool) {
            cfg.trace.log_events = v;
        }

        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.full_power_mem_mb == 0 {
            bail!("full_power_mem_mb must be positive");
        }
        if self.pricing.granularity_ms == 0 {
            bail!("pricing.granularity_ms must be positive");
        }
        if self.pricing.table.is_empty() {
            bail!("pricing table is empty");
        }
        if self.pricing.table.windows(2).any(|w| w[0].0 >= w[1].0) {
            bail!("pricing table must be ascending in memory");
        }
        if self.throttle_quantum_s <= 0.0 {
            bail!("throttle_quantum_s must be positive");
        }
        if self.keep_alive_s < 0.0 {
            bail!("keep_alive_s must be non-negative");
        }
        if !self.maintainer_interval_s.is_finite()
            || self.maintainer_interval_s < 0.0
            || self.maintainer_interval_s > 1e9
        {
            bail!("maintainer_interval_s must be in [0, 1e9] seconds (0 disables)");
        }
        // A deadline past the ceiling is almost certainly a unit
        // mistake (seconds in a milliseconds field) and would park
        // requests — and their gateway worker threads — for that long.
        if self.queue_deadline_ms > MAX_QUEUE_DEADLINE_MS {
            bail!("queue_deadline_ms must be at most {MAX_QUEUE_DEADLINE_MS} (one hour)");
        }
        if self.max_batch_size == 0 {
            bail!("max_batch_size must be at least 1 (1 disables batching)");
        }
        // A batch leader holds a container and a gateway worker thread
        // open for the window: same unit-mistake ceiling as the
        // dispatch deadline.
        if self.batch_window_ms > MAX_QUEUE_DEADLINE_MS {
            bail!("batch_window_ms must be at most {MAX_QUEUE_DEADLINE_MS} (one hour)");
        }
        if self.pool_shards == 0 || self.pool_shards > 4096 {
            bail!("pool_shards must be in [1, 4096] (1 is the single-lock pool)");
        }
        // The kernel ladder is powers of two up to this cap; a
        // non-power value would silently waste the top kernel.
        if self.batch_kernel_max == 0
            || !self.batch_kernel_max.is_power_of_two()
            || self.batch_kernel_max > 64
        {
            bail!("batch_kernel_max must be a power of two in [1, 64] (1 disables the ladder)");
        }
        if !self.snapshot.restore_bw.is_finite() || self.snapshot.restore_bw <= 0.0 {
            bail!("snapshot.restore_bw must be a positive number of bytes/s");
        }
        if self.policy.slo_target_ms == 0 || self.policy.slo_target_ms > MAX_QUEUE_DEADLINE_MS {
            bail!("policy.slo_target_ms must be in [1, {MAX_QUEUE_DEADLINE_MS}] (one hour)");
        }
        // The adaptive window is still a window a leader holds a
        // container open for: same unit-mistake ceiling.
        if self.policy.window_cap_ms > MAX_QUEUE_DEADLINE_MS {
            bail!("policy.window_cap_ms must be at most {MAX_QUEUE_DEADLINE_MS} (one hour)");
        }
        for (name, v) in
            [("policy.ewma_alpha", self.policy.ewma_alpha), ("policy.holt_beta", self.policy.holt_beta)]
        {
            if !v.is_finite() || v <= 0.0 || v > 1.0 {
                bail!("{name} must be in (0, 1]");
            }
        }
        for (name, v) in [
            ("policy.decay_window_s", self.policy.decay_window_s),
            ("policy.forecast_horizon_s", self.policy.forecast_horizon_s),
        ] {
            if !v.is_finite() || v <= 0.0 || v > 1e9 {
                bail!("{name} must be a positive number of seconds (at most 1e9)");
            }
        }
        if self.policy.max_prewarm > 4096 {
            bail!("policy.max_prewarm must be at most 4096 (0 disables forecast top-up)");
        }
        if !self.trace.sample_rate.is_finite()
            || !(0.0..=1.0).contains(&self.trace.sample_rate)
        {
            bail!("trace.sample_rate must be in [0, 1]");
        }
        // Each retained trace is a few hundred bytes; a ring past a
        // million entries is a unit mistake, not an exemplar buffer.
        if self.trace.ring_capacity > 1_048_576 {
            bail!("trace.ring_capacity must be at most 1048576 (0 keeps counters only)");
        }
        Ok(())
    }

    /// Non-fatal configuration smells: combinations that validate but
    /// almost certainly do not mean what the operator intended.
    /// Surfaced at startup (the CLI prints them to stderr) instead of
    /// being silently ignored.
    pub fn warnings(&self) -> Vec<String> {
        let mut out = Vec::new();
        if self.batch_window_ms > 0 && self.max_batch_size == 1 {
            out.push(format!(
                "batch_window_ms = {} has no effect while max_batch_size = 1 \
                 (batching is disabled; no leader ever opens a window)",
                self.batch_window_ms
            ));
        }
        if self.batch_kernel_max > 1 && self.max_batch_size == 1 {
            out.push(format!(
                "batch_kernel_max = {} compiles a kernel ladder no flush can ever \
                 fill while max_batch_size = 1",
                self.batch_kernel_max
            ));
        }
        if self.policy.enabled && self.policy.window_cap_ms < self.batch_window_ms {
            out.push(format!(
                "policy.window_cap_ms = {} is below batch_window_ms = {}: the adaptive \
                 controller can only shrink the window, never restore the static default",
                self.policy.window_cap_ms, self.batch_window_ms
            ));
        }
        if !self.trace.enabled && (self.trace.sample_rate > 0.0 || self.trace.log_events) {
            out.push(
                "trace.sample_rate / trace.log_events have no effect while trace.enabled \
                 = false (tracing is disabled; no trace is ever assembled)"
                    .to_string(),
            );
        }
        if self.trace.enabled && self.trace.ring_capacity == 0 {
            out.push(
                "trace.ring_capacity = 0 keeps tracing counters but retains no exemplar \
                 traces (the trace routes will always 404)"
                    .to_string(),
            );
        }
        out
    }

    /// CPU share in `(0, 1]` for a container of `mem` MB — Lambda's
    /// "CPU power proportional to memory" rule.
    pub fn cpu_share(&self, mem: MemorySize) -> f64 {
        (mem as f64 / self.full_power_mem_mb as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table1() {
        let cfg = PlatformConfig::default();
        assert_eq!(cfg.pricing.price_per_unit(128).unwrap(), 0.000000208);
        assert_eq!(cfg.pricing.price_per_unit(1536).unwrap(), 0.000002501);
        assert_eq!(cfg.pricing.table.len(), 12);
        assert_eq!(cfg.pricing.granularity_ms, 100);
    }

    #[test]
    fn table1_price_monotone_in_memory() {
        let p = PricingConfig::default();
        let mut last = 0.0;
        for m in MEMORY_SIZES_2017 {
            let v = p.price_per_unit(m).unwrap();
            assert!(v > last);
            last = v;
        }
    }

    #[test]
    fn interpolates_64mb_tiers() {
        let p = PricingConfig::default();
        let v = p.price_per_unit(192).unwrap();
        let expect = (0.000000208 + 0.000000417) / 2.0;
        assert!((v - expect).abs() < 1e-12);
    }

    #[test]
    fn rejects_out_of_table() {
        let p = PricingConfig::default();
        assert!(p.price_per_unit(64).is_err());
        assert!(p.price_per_unit(4096).is_err());
    }

    #[test]
    fn cpu_share_proportional_and_capped() {
        let cfg = PlatformConfig::default();
        assert!((cfg.cpu_share(128) - 128.0 / 1792.0).abs() < 1e-12);
        assert!((cfg.cpu_share(896) - 0.5).abs() < 1e-12);
        assert_eq!(cfg.cpu_share(1792), 1.0);
        assert_eq!(cfg.cpu_share(3008), 1.0);
    }

    #[test]
    fn toml_overlay() {
        let cfg = PlatformConfig::from_toml(
            r#"
[platform]
full_power_mem_mb = 2048
keep_alive_s = 300.0
maintainer_interval_s = 2.5
metrics_ring_capacity = 128
queue_capacity = 16
queue_deadline_ms = 750
max_batch_size = 8
batch_window_ms = 15
pool_shards = 16
batch_kernel_max = 4
seed = 7

[bootstrap]
runtime_init_s = 0.5
simulate_delays = false

[network]
rtt_s = 0.01
"#,
        )
        .unwrap();
        assert_eq!(cfg.full_power_mem_mb, 2048);
        assert_eq!(cfg.keep_alive_s, 300.0);
        assert_eq!(cfg.maintainer_interval_s, 2.5);
        assert_eq!(cfg.metrics_ring_capacity, 128);
        assert_eq!(cfg.queue_capacity, 16);
        assert_eq!(cfg.queue_deadline_ms, 750);
        assert_eq!(cfg.max_batch_size, 8);
        assert_eq!(cfg.batch_window_ms, 15);
        assert_eq!(cfg.pool_shards, 16);
        assert_eq!(cfg.batch_kernel_max, 4);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.bootstrap.runtime_init_s, 0.5);
        assert!(!cfg.bootstrap.simulate_delays);
        assert_eq!(cfg.network.rtt_s, 0.01);
        // untouched defaults survive
        assert_eq!(cfg.pricing.table.len(), 12);
    }

    #[test]
    fn snapshot_toml_overlay_and_defaults() {
        let cfg = PlatformConfig::default();
        assert!(!cfg.snapshot.enabled, "snapshots are opt-in");
        assert_eq!(cfg.snapshot.capacity_bytes, 1 << 30);
        assert_eq!(cfg.snapshot.capture_policy, CapturePolicy::Background);

        let cfg = PlatformConfig::from_toml(
            r#"
[snapshot]
enabled = true
capacity_bytes = 67108864
restore_bw = 5e7
capture_policy = "sync"
"#,
        )
        .unwrap();
        assert!(cfg.snapshot.enabled);
        assert_eq!(cfg.snapshot.capacity_bytes, 64 << 20);
        assert_eq!(cfg.snapshot.restore_bw, 5e7);
        assert_eq!(cfg.snapshot.capture_policy, CapturePolicy::Sync);

        assert!(PlatformConfig::from_toml("[snapshot]\nrestore_bw = 0.0").is_err());
        assert!(PlatformConfig::from_toml("[snapshot]\nrestore_bw = -1.0").is_err());
        assert!(PlatformConfig::from_toml("[snapshot]\ncapture_policy = \"eager\"").is_err());
        assert_eq!("off".parse::<CapturePolicy>().unwrap(), CapturePolicy::Off);
        assert_eq!("background".parse::<CapturePolicy>().unwrap(), CapturePolicy::Background);
    }

    #[test]
    fn policy_toml_overlay_and_defaults() {
        let cfg = PlatformConfig::default();
        assert!(!cfg.policy.enabled, "controllers are opt-in");
        assert_eq!(cfg.policy.slo_target_ms, 1_000);
        assert_eq!(cfg.policy.window_cap_ms, 100);
        assert_eq!(cfg.policy.max_prewarm, 8);

        let cfg = PlatformConfig::from_toml(
            r#"
[policy]
enabled = true
slo_target_ms = 500
window_cap_ms = 40
ewma_alpha = 0.5
holt_beta = 0.2
decay_window_s = 30.0
forecast_horizon_s = 1.5
max_prewarm = 16
"#,
        )
        .unwrap();
        assert!(cfg.policy.enabled);
        assert_eq!(cfg.policy.slo_target_ms, 500);
        assert_eq!(cfg.policy.window_cap_ms, 40);
        assert_eq!(cfg.policy.ewma_alpha, 0.5);
        assert_eq!(cfg.policy.holt_beta, 0.2);
        assert_eq!(cfg.policy.decay_window_s, 30.0);
        assert_eq!(cfg.policy.forecast_horizon_s, 1.5);
        assert_eq!(cfg.policy.max_prewarm, 16);

        assert!(PlatformConfig::from_toml("[policy]\nslo_target_ms = 0").is_err());
        assert!(PlatformConfig::from_toml("[policy]\nslo_target_ms = 7200000").is_err());
        assert!(PlatformConfig::from_toml("[policy]\nwindow_cap_ms = 7200000").is_err());
        assert!(PlatformConfig::from_toml("[policy]\newma_alpha = 0.0").is_err());
        assert!(PlatformConfig::from_toml("[policy]\newma_alpha = 1.5").is_err());
        assert!(PlatformConfig::from_toml("[policy]\nholt_beta = -0.1").is_err());
        assert!(PlatformConfig::from_toml("[policy]\ndecay_window_s = 0.0").is_err());
        assert!(PlatformConfig::from_toml("[policy]\nforecast_horizon_s = -1.0").is_err());
        assert!(PlatformConfig::from_toml("[policy]\nmax_prewarm = 100000").is_err());
    }

    #[test]
    fn trace_toml_overlay_and_defaults() {
        let cfg = PlatformConfig::default();
        assert!(!cfg.trace.enabled, "tracing is opt-in");
        assert_eq!(cfg.trace.ring_capacity, 512);
        assert_eq!(cfg.trace.sample_rate, 0.0);
        assert!(!cfg.trace.log_events);

        let cfg = PlatformConfig::from_toml(
            r#"
[trace]
enabled = true
ring_capacity = 64
sample_rate = 0.25
log_events = true
"#,
        )
        .unwrap();
        assert!(cfg.trace.enabled);
        assert_eq!(cfg.trace.ring_capacity, 64);
        assert_eq!(cfg.trace.sample_rate, 0.25);
        assert!(cfg.trace.log_events);

        assert!(PlatformConfig::from_toml("[trace]\nsample_rate = 1.5").is_err());
        assert!(PlatformConfig::from_toml("[trace]\nsample_rate = -0.1").is_err());
        assert!(PlatformConfig::from_toml("[trace]\nring_capacity = 2000000").is_err());

        // Knobs set while tracing is off warn instead of silently
        // doing nothing; a zero-capacity ring with tracing on warns
        // that no exemplars can be served.
        let cfg = PlatformConfig::from_toml("[trace]\nsample_rate = 0.5").unwrap();
        assert!(cfg.warnings().iter().any(|w| w.contains("trace.enabled")), "{:?}", cfg.warnings());
        let cfg = PlatformConfig::from_toml("[trace]\nenabled = true\nring_capacity = 0").unwrap();
        assert!(cfg.warnings().iter().any(|w| w.contains("trace.ring_capacity")));
        let cfg = PlatformConfig::from_toml("[trace]\nenabled = true\nsample_rate = 0.5").unwrap();
        assert!(cfg.warnings().is_empty(), "{:?}", cfg.warnings());
    }

    #[test]
    fn warnings_flag_window_without_batching() {
        let cfg = PlatformConfig::default();
        assert!(cfg.warnings().is_empty(), "defaults are clean");

        // A window with batching off validates but does nothing —
        // that must be warned about, not silently ignored.
        let cfg =
            PlatformConfig { batch_window_ms: 25, ..Default::default() };
        let w = cfg.warnings();
        assert_eq!(w.len(), 1, "{w:?}");
        assert!(w[0].contains("batch_window_ms"), "{w:?}");
        assert!(w[0].contains("max_batch_size"), "{w:?}");
        // And it still parses/validates fine.
        let cfg = PlatformConfig::from_toml("[platform]\nbatch_window_ms = 25").unwrap();
        assert_eq!(cfg.batch_window_ms, 25);
        assert_eq!(cfg.warnings().len(), 1);

        // Same for a kernel ladder no flush can fill.
        let cfg = PlatformConfig { batch_kernel_max: 4, ..Default::default() };
        assert!(cfg.warnings().iter().any(|w| w.contains("batch_kernel_max")));

        // With batching actually on, both warnings clear.
        let cfg = PlatformConfig {
            batch_window_ms: 25,
            batch_kernel_max: 4,
            max_batch_size: 8,
            ..Default::default()
        };
        assert!(cfg.warnings().is_empty());
    }

    #[test]
    fn custom_price_table() {
        let cfg = PlatformConfig::from_toml(
            r#"
[pricing]
memory_mb = [128, 256]
dollars_per_unit = [1.0, 2.0]
"#,
        )
        .unwrap();
        assert_eq!(cfg.pricing.price_per_unit(128).unwrap(), 1.0);
        assert_eq!(cfg.pricing.price_per_unit(192).unwrap(), 1.5);
    }

    #[test]
    fn validation_failures() {
        assert!(PlatformConfig::from_toml("[platform]\nfull_power_mem_mb = 0").is_err());
        assert!(PlatformConfig::from_toml("[platform]\nmaintainer_interval_s = -1.0").is_err());
        assert!(PlatformConfig::from_toml("[platform]\nqueue_deadline_ms = 7200000").is_err());
        assert!(PlatformConfig::from_toml("[platform]\nmax_batch_size = 0").is_err());
        assert!(PlatformConfig::from_toml("[platform]\nbatch_window_ms = 7200000").is_err());
        assert!(PlatformConfig::from_toml("[platform]\npool_shards = 0").is_err());
        assert!(PlatformConfig::from_toml("[platform]\npool_shards = 5000").is_err());
        assert!(PlatformConfig::from_toml("[platform]\nbatch_kernel_max = 0").is_err());
        assert!(PlatformConfig::from_toml("[platform]\nbatch_kernel_max = 3").is_err());
        assert!(PlatformConfig::from_toml("[platform]\nbatch_kernel_max = 128").is_err());
        assert!(PlatformConfig::from_toml("[pricing]\ngranularity_ms = 0").is_err());
        assert!(PlatformConfig::from_toml(
            "[pricing]\nmemory_mb = [256, 128]\ndollars_per_unit = [1.0, 2.0]"
        )
        .is_err());
        assert!(PlatformConfig::from_toml(
            "[pricing]\nmemory_mb = [128]\ndollars_per_unit = [1.0, 2.0]"
        )
        .is_err());
    }
}
