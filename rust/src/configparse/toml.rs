//! The TOML-subset parser. See module docs in `configparse`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Numeric coercion: integers widen to f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Flat map: `section.key` (or `section.sub.key`) -> value.
pub type TomlDoc = BTreeMap<String, TomlValue>;

pub fn parse_toml(src: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = lineno + 1;
        let text = strip_comment(raw).trim();
        if text.is_empty() {
            continue;
        }
        if let Some(rest) = text.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or(TomlError { line, msg: "unterminated section header".into() })?
                .trim();
            if name.is_empty() {
                return Err(TomlError { line, msg: "empty section name".into() });
            }
            if !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-')
            {
                return Err(TomlError { line, msg: format!("invalid section name {name:?}") });
            }
            section = name.to_string();
            continue;
        }
        let eq = text
            .find('=')
            .ok_or(TomlError { line, msg: format!("expected key = value, got {text:?}") })?;
        let key = text[..eq].trim();
        if key.is_empty() {
            return Err(TomlError { line, msg: "empty key".into() });
        }
        let value = parse_value(text[eq + 1..].trim(), line)?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        if doc.insert(full.clone(), value).is_some() {
            return Err(TomlError { line, msg: format!("duplicate key {full:?}") });
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, line: usize) -> Result<TomlValue, TomlError> {
    if text.is_empty() {
        return Err(TomlError { line, msg: "missing value".into() });
    }
    if let Some(rest) = text.strip_prefix('"') {
        let end = rest
            .find('"')
            .ok_or(TomlError { line, msg: "unterminated string".into() })?;
        if !rest[end + 1..].trim().is_empty() {
            return Err(TomlError { line, msg: "trailing data after string".into() });
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or(TomlError { line, msg: "unterminated array".into() })?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim(), line)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = text.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(TomlError { line, msg: format!("cannot parse value {text:?}") })
}

/// Split a (non-nested) array body on commas; strings may contain commas.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_document() {
        let doc = parse_toml(
            r#"
# platform config
top = 1

[platform]
full_power_mem_mb = 1792
keep_alive_secs = 600.5
name = "lambda-sim"
enabled = true

[pricing.tiers]
mems = [128, 256, 384]
"#,
        )
        .unwrap();
        assert_eq!(doc["top"], TomlValue::Int(1));
        assert_eq!(doc["platform.full_power_mem_mb"], TomlValue::Int(1792));
        assert_eq!(doc["platform.keep_alive_secs"], TomlValue::Float(600.5));
        assert_eq!(doc["platform.name"].as_str(), Some("lambda-sim"));
        assert_eq!(doc["platform.enabled"].as_bool(), Some(true));
        let arr = doc["pricing.tiers.mems"].as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_i64(), Some(128));
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = parse_toml("k = \"a # b\"").unwrap();
        assert_eq!(doc["k"].as_str(), Some("a # b"));
    }

    #[test]
    fn underscored_numbers() {
        let doc = parse_toml("n = 1_000_000").unwrap();
        assert_eq!(doc["n"].as_i64(), Some(1_000_000));
    }

    #[test]
    fn float_array() {
        let doc = parse_toml("xs = [0.5, 1.5, 2.0]").unwrap();
        let xs: Vec<f64> = doc["xs"].as_array().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(xs, vec![0.5, 1.5, 2.0]);
    }

    #[test]
    fn empty_array() {
        let doc = parse_toml("xs = []").unwrap();
        assert_eq!(doc["xs"].as_array().unwrap().len(), 0);
    }

    #[test]
    fn string_array_with_commas() {
        let doc = parse_toml(r#"xs = ["a,b", "c"]"#).unwrap();
        let xs = doc["xs"].as_array().unwrap();
        assert_eq!(xs[0].as_str(), Some("a,b"));
        assert_eq!(xs[1].as_str(), Some("c"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_toml("ok = 1\nbad line").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(parse_toml("[unterminated").is_err());
        assert!(parse_toml("k = ").is_err());
        assert!(parse_toml("k = \"open").is_err());
        assert!(parse_toml("k = nope").is_err());
    }

    #[test]
    fn duplicate_key_rejected() {
        let err = parse_toml("a = 1\na = 2").unwrap_err();
        assert!(err.msg.contains("duplicate"));
    }

    #[test]
    fn int_float_coercion() {
        let doc = parse_toml("i = 3\nf = 3.5").unwrap();
        assert_eq!(doc["i"].as_f64(), Some(3.0));
        assert_eq!(doc["f"].as_f64(), Some(3.5));
        assert_eq!(doc["f"].as_i64(), None);
    }
}
