//! Tiny CLI argument parser (no clap in the offline dep closure).
//!
//! Supports the launcher's needs: subcommands, `--flag value`,
//! `--flag=value`, boolean `--flag`, positional args, defaults, and a
//! generated usage string.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_bool: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(n) => Ok(Some(n)),
                Err(_) => bail!("--{name} expects an integer, got {v:?}"),
            },
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(n) => Ok(Some(n)),
                Err(_) => bail!("--{name} expects a number, got {v:?}"),
            },
        }
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
    }
}

pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, flags: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.flags.push(FlagSpec { name, help, default, is_bool: false });
        self
    }

    pub fn bool_flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_bool: true });
        self
    }

    /// Parse args after the subcommand name.
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        for spec in &self.flags {
            if let Some(d) = spec.default {
                out.flags.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown flag --{name}\n{}", self.usage()))?;
                if spec.is_bool {
                    if inline.is_some() {
                        bail!("--{name} is a boolean flag");
                    }
                    out.bools.insert(name.to_string(), true);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                                .clone()
                        }
                    };
                    out.flags.insert(name.to_string(), value);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn usage(&self) -> String {
        let mut s = format!("usage: lambdaserve {} [flags]\n  {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            let kind = if f.is_bool { "" } else { " <value>" };
            let def = f.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
            s.push_str(&format!("  --{}{kind}\n      {}{def}\n", f.name, f.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("experiment", "run a paper experiment")
            .flag("id", "experiment id", Some("fig1"))
            .flag("mems", "memory sizes", None)
            .flag("reps", "repetitions", Some("25"))
            .bool_flag("verbose", "chatty output")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("id"), Some("fig1"));
        assert_eq!(a.get_u64("reps").unwrap(), Some(25));
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd().parse(&argv(&["--id", "fig4", "--reps=5", "--verbose"])).unwrap();
        assert_eq!(a.get("id"), Some("fig4"));
        assert_eq!(a.get_u64("reps").unwrap(), Some(5));
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn list_flag() {
        let a = cmd().parse(&argv(&["--mems", "128, 256,1536"])).unwrap();
        assert_eq!(a.get_list("mems").unwrap(), vec!["128", "256", "1536"]);
    }

    #[test]
    fn positional_collected() {
        let a = cmd().parse(&argv(&["table1", "--verbose"])).unwrap();
        assert_eq!(a.positional, vec!["table1"]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(cmd().parse(&argv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&argv(&["--id"])).is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let a = cmd().parse(&argv(&["--reps", "many"])).unwrap();
        assert!(a.get_u64("reps").is_err());
    }

    #[test]
    fn bool_with_value_rejected() {
        assert!(cmd().parse(&argv(&["--verbose=yes"])).is_err());
    }

    #[test]
    fn usage_mentions_flags() {
        let u = cmd().usage();
        assert!(u.contains("--id"));
        assert!(u.contains("default: 25"));
    }
}
