//! HTTP gateway — the API-Gateway analog fronting the platform.
//!
//! Routes:
//!   GET  /v1/functions                      — list deployments
//!   POST /v1/functions?name=&model=&mem=    — deploy
//!   GET  /v1/invoke/<function>[?seed=N]     — invoke (the paper's GET)
//!   POST /v1/prewarm/<function>?n=N         — keep-warm knob (§5)
//!   GET  /v1/stats                          — metrics snapshot
//!   GET  /healthz
//!
//! Responses are JSON; invocation responses mirror what the paper's
//! Lambda returned (prediction + timing), with the latency
//! decomposition added.

use crate::httpd::{HttpRequest, HttpServer, Responder};
use crate::platform::{InvokeError, Platform};
use crate::util::json::{obj, Json};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

pub struct Gateway {
    server: HttpServer,
}

impl Gateway {
    pub fn bind(addr: &str, threads: usize, platform: Arc<Platform>) -> Result<Self> {
        let seq = Arc::new(AtomicU64::new(1));
        let server = HttpServer::bind(addr, threads, move |req| {
            route(&platform, &seq, req)
        })?;
        Ok(Self { server })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.server.local_addr()
    }

    pub fn shutdown_handle(&self) -> crate::httpd::ShutdownHandle {
        self.server.shutdown_handle()
    }

    /// Blocking accept loop.
    pub fn serve(&self) -> Result<()> {
        self.server.serve()
    }
}

fn route(platform: &Arc<Platform>, seq: &AtomicU64, req: HttpRequest) -> Responder {
    let path = req.path.clone();
    let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => Responder::text(200, "ok"),
        ("GET", ["v1", "functions"]) => list_functions(platform),
        ("POST", ["v1", "functions"]) => deploy(platform, &req),
        ("GET", ["v1", "invoke", func]) => invoke(platform, seq, func, &req),
        ("POST", ["v1", "prewarm", func]) => prewarm(platform, func, &req),
        ("GET", ["v1", "stats"]) => stats(platform),
        _ => Responder::json(404, err_json("no such route")),
    }
}

fn err_json(msg: &str) -> String {
    obj(vec![("error", Json::Str(msg.into()))]).to_string()
}

fn list_functions(platform: &Arc<Platform>) -> Responder {
    let fns: Vec<Json> = platform
        .registry
        .list()
        .into_iter()
        .map(|f| {
            obj(vec![
                ("name", Json::Str(f.name.clone())),
                ("model", Json::Str(f.model.clone())),
                ("variant", Json::Str(f.variant.clone())),
                ("memory_mb", Json::Num(f.memory_mb as f64)),
            ])
        })
        .collect();
    Responder::json(200, Json::Arr(fns).to_string())
}

fn deploy(platform: &Arc<Platform>, req: &HttpRequest) -> Responder {
    let name = req.query_param("name").unwrap_or_default().to_string();
    let model = req.query_param("model").unwrap_or_default().to_string();
    let variant = req.query_param("variant").unwrap_or("pallas").to_string();
    let mem: u32 = match req.query_param("mem").unwrap_or("1024").parse() {
        Ok(m) => m,
        Err(_) => return Responder::json(400, err_json("mem must be an integer")),
    };
    match platform.deploy(&name, &model, &variant, mem) {
        Ok(spec) => Responder::json(
            200,
            obj(vec![
                ("deployed", Json::Str(spec.name.clone())),
                ("memory_mb", Json::Num(spec.memory_mb as f64)),
            ])
            .to_string(),
        ),
        Err(e) => Responder::json(400, err_json(&e.to_string())),
    }
}

fn invoke(platform: &Arc<Platform>, seq: &AtomicU64, func: &str, req: &HttpRequest) -> Responder {
    let seed = req
        .query_param("seed")
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| seq.fetch_add(1, Ordering::Relaxed));
    match platform.invoke(func, seed) {
        Ok(out) => {
            let r = &out.record;
            Responder::json(
                200,
                obj(vec![
                    ("function", Json::Str(r.function.clone())),
                    ("top1", Json::Num(out.prediction.top1 as f64)),
                    ("top_prob", Json::Num(out.prediction.top_prob as f64)),
                    ("start", Json::Str(r.start.to_string())),
                    ("prediction_s", Json::Num(r.predict.as_secs_f64())),
                    ("response_s", Json::Num(r.response().as_secs_f64())),
                    ("billed_ms", Json::Num(r.billed_ms as f64)),
                    ("cost_dollars", Json::Num(r.cost_dollars)),
                ])
                .to_string(),
            )
        }
        Err(InvokeError::NotFound(f)) => {
            Responder::json(404, err_json(&format!("function {f} not deployed")))
        }
        Err(InvokeError::Throttled) => Responder::json(429, err_json("throttled")),
        Err(InvokeError::Failed(e)) => Responder::json(500, err_json(&e.to_string())),
    }
}

fn prewarm(platform: &Arc<Platform>, func: &str, req: &HttpRequest) -> Responder {
    let n: usize = match req.query_param("n").unwrap_or("1").parse() {
        Ok(n) => n,
        Err(_) => return Responder::json(400, err_json("n must be an integer")),
    };
    match platform.prewarm(func, n) {
        Ok(done) => Responder::json(200, obj(vec![("prewarmed", Json::Num(done as f64))]).to_string()),
        Err(e) => Responder::json(400, err_json(&e.to_string())),
    }
}

fn stats(platform: &Arc<Platform>) -> Responder {
    let m = &platform.metrics;
    Responder::json(
        200,
        obj(vec![
            ("invocations", Json::Num(m.len() as f64)),
            ("cold_starts", Json::Num(m.cold_count() as f64)),
            ("containers_alive", Json::Num(platform.pool.total_alive() as f64)),
            ("in_flight", Json::Num(platform.scaler.in_flight() as f64)),
            ("peak_concurrency", Json::Num(platform.scaler.high_water_mark() as f64)),
            ("throttled", Json::Num(platform.scaler.throttled_count() as f64)),
            ("total_cost_dollars", Json::Num(platform.billing.total_dollars())),
            ("total_gb_seconds", Json::Num(platform.billing.total_gb_seconds())),
        ])
        .to_string(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configparse::PlatformConfig;
    use crate::httpd::http_get;
    use crate::httpd::http_post;
    use crate::platform::Invoker;
    use crate::runtime::{MockEngine, MockModelCosts};
    use crate::util::json::Json;
    use std::time::Duration;

    fn fast_platform() -> Arc<Platform> {
        let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
            "squeezenet",
            2,
            5.0,
            85,
        )]));
        let config = PlatformConfig {
            bootstrap: crate::configparse::BootstrapConfig {
                simulate_delays: false,
                ..Default::default()
            },
            ..Default::default()
        };
        Arc::new(Invoker::live(config, engine))
    }

    fn start() -> (String, crate::httpd::ShutdownHandle, std::thread::JoinHandle<()>) {
        let gw = Gateway::bind("127.0.0.1:0", 4, fast_platform()).unwrap();
        let addr = gw.local_addr().to_string();
        let sh = gw.shutdown_handle();
        let t = std::thread::spawn(move || {
            gw.serve().unwrap();
        });
        (addr, sh, t)
    }

    #[test]
    fn full_http_lifecycle() {
        let (addr, sh, t) = start();
        let tmo = Duration::from_secs(10);

        // health
        assert_eq!(http_get(&addr, "/healthz", tmo).unwrap().status, 200);

        // deploy
        let r = http_post(&addr, "/v1/functions?name=sq&model=squeezenet&mem=1024", b"", tmo)
            .unwrap();
        assert_eq!(r.status, 200, "{}", r.body_str());

        // list
        let r = http_get(&addr, "/v1/functions", tmo).unwrap();
        assert!(r.body_str().contains("\"sq\""));

        // invoke: cold then warm
        let r = http_get(&addr, "/v1/invoke/sq?seed=7", tmo).unwrap();
        assert_eq!(r.status, 200);
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.get("start").unwrap().as_str(), Some("cold"));
        assert!(j.get("response_s").unwrap().as_f64().unwrap() > 0.0);
        let r = http_get(&addr, "/v1/invoke/sq?seed=8", tmo).unwrap();
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.get("start").unwrap().as_str(), Some("warm"));

        // prewarm
        let r = http_post(&addr, "/v1/prewarm/sq?n=2", b"", tmo).unwrap();
        assert_eq!(r.status, 200, "{}", r.body_str());

        // stats
        let r = http_get(&addr, "/v1/stats", tmo).unwrap();
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.get("invocations").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("cold_starts").unwrap().as_u64(), Some(1));
        assert!(j.get("containers_alive").unwrap().as_u64().unwrap() >= 3);

        // errors
        assert_eq!(http_get(&addr, "/v1/invoke/nope", tmo).unwrap().status, 404);
        assert_eq!(http_get(&addr, "/nope", tmo).unwrap().status, 404);
        assert_eq!(
            http_post(&addr, "/v1/functions?name=x&model=squeezenet&mem=abc", b"", tmo)
                .unwrap()
                .status,
            400
        );
        assert_eq!(
            http_post(&addr, "/v1/functions?name=x&model=vgg&mem=512", b"", tmo)
                .unwrap()
                .status,
            400
        );

        sh.shutdown();
        t.join().unwrap();
    }
}
