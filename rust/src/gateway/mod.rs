//! HTTP gateway — the API-Gateway analog fronting the platform.
//!
//! v2 resource-oriented surface (JSON bodies, structured errors):
//!
//!   POST   /v2/functions                     — deploy (full spec), 201 / 409
//!   GET    /v2/functions                     — list
//!   GET    /v2/functions/:name               — inspect
//!   PATCH  /v2/functions/:name               — reconfigure (partial)
//!   DELETE /v2/functions/:name               — undeploy
//!   POST   /v2/functions/:name/invocations   — invoke; `?mode=async`
//!                                              returns 202 + id
//!   GET    /v2/invocations/:id               — poll an async result
//!   GET    /v2/invocations/:id/trace         — span timeline (trace or
//!                                              async id)
//!   GET    /v2/functions/:name/traces        — retained trace exemplars
//!   GET    /v2/functions/:name/stats         — per-function breakdown
//!   GET    /v2/stats                         — platform snapshot
//!   GET    /healthz
//!
//! The original `/v1` query-string routes remain as shims that are
//! byte-compatible on previously-valid requests (see [`api::v1`] for
//! the two intentional error-path differences); full reference in
//! `API.md`.

pub mod api;
pub mod client;

pub use client::{
    ApiClient, ApiError, ApiResult, AsyncInvocationStatus, DeploySpec, FunctionInfo,
    FunctionStats, InvocationResult, PlatformStats, ReconfigureSpec, SpanView, TraceView,
};

use crate::httpd::{HttpServer, Router};
use crate::platform::{AsyncInvoker, Platform};
use anyhow::Result;
use api::ApiCtx;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Duration;

/// Sizing for the async invocation subsystem.
#[derive(Debug, Clone)]
pub struct AsyncConfig {
    /// Worker threads draining the queue.
    pub workers: usize,
    /// Bounded queue depth; a full queue rejects submits with 429.
    pub queue_capacity: usize,
    /// How long completed results stay pollable.
    pub result_ttl: Duration,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        Self { workers: 4, queue_capacity: 256, result_ttl: Duration::from_secs(900) }
    }
}

pub struct Gateway {
    // Field order matters for drop: the server (and the router closure
    // holding an ApiCtx clone) goes first, then the last ApiCtx ref
    // releases the AsyncInvoker, which joins its workers.
    server: HttpServer,
    ctx: Arc<ApiCtx>,
}

impl Gateway {
    pub fn bind(addr: &str, threads: usize, platform: Arc<Platform>) -> Result<Self> {
        Self::bind_with(addr, threads, platform, AsyncConfig::default())
    }

    pub fn bind_with(
        addr: &str,
        threads: usize,
        platform: Arc<Platform>,
        async_config: AsyncConfig,
    ) -> Result<Self> {
        let async_inv = Arc::new(AsyncInvoker::start(
            platform.clone(),
            async_config.workers,
            async_config.queue_capacity,
            async_config.result_ttl,
        ));
        let ctx = Arc::new(ApiCtx { platform, async_inv, seq: AtomicU64::new(1) });
        // Keep warm pools maintained while serving: keep-alive sweeps
        // + min_warm replenishment on the configured tick (0 = off).
        // No-op if the embedding application already started one; the
        // thread is joined when the platform is dropped.
        let interval = Duration::try_from_secs_f64(ctx.platform.config().maintainer_interval_s)
            .unwrap_or(Duration::ZERO); // unrepresentable ≈ never ticks ≈ off
        Platform::start_maintainer(&ctx.platform, interval);
        let router: Arc<Router> = Arc::new(api::build_router(&ctx));
        let server = HttpServer::bind(addr, threads, move |req| router.dispatch(&req))?;
        Ok(Self { server, ctx })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.server.local_addr()
    }

    pub fn shutdown_handle(&self) -> crate::httpd::ShutdownHandle {
        self.server.shutdown_handle()
    }

    /// The async subsystem (tests / stats).
    pub fn async_invoker(&self) -> &Arc<AsyncInvoker> {
        &self.ctx.async_inv
    }

    /// Blocking accept loop.
    pub fn serve(&self) -> Result<()> {
        self.server.serve()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configparse::PlatformConfig;
    use crate::httpd::http_get;
    use crate::httpd::http_post;
    use crate::platform::Invoker;
    use crate::runtime::{MockEngine, MockModelCosts};
    use crate::util::json::Json;
    use std::time::Duration;

    fn fast_platform() -> Arc<Platform> {
        let engine = Arc::new(MockEngine::new(vec![MockModelCosts::paper_like(
            "squeezenet",
            2,
            5.0,
            85,
        )]));
        let config = PlatformConfig {
            bootstrap: crate::configparse::BootstrapConfig {
                simulate_delays: false,
                ..Default::default()
            },
            ..Default::default()
        };
        Arc::new(Invoker::live(config, engine))
    }

    fn start() -> (String, crate::httpd::ShutdownHandle, std::thread::JoinHandle<()>) {
        let gw = Gateway::bind("127.0.0.1:0", 4, fast_platform()).unwrap();
        let addr = gw.local_addr().to_string();
        let sh = gw.shutdown_handle();
        let t = std::thread::spawn(move || {
            gw.serve().unwrap();
        });
        (addr, sh, t)
    }

    #[test]
    fn full_http_lifecycle() {
        let (addr, sh, t) = start();
        let tmo = Duration::from_secs(10);

        // health
        assert_eq!(http_get(&addr, "/healthz", tmo).unwrap().status, 200);

        // deploy
        let r = http_post(&addr, "/v1/functions?name=sq&model=squeezenet&mem=1024", b"", tmo)
            .unwrap();
        assert_eq!(r.status, 200, "{}", r.body_str());

        // list
        let r = http_get(&addr, "/v1/functions", tmo).unwrap();
        assert!(r.body_str().contains("\"sq\""));

        // invoke: cold then warm
        let r = http_get(&addr, "/v1/invoke/sq?seed=7", tmo).unwrap();
        assert_eq!(r.status, 200);
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.get("start").unwrap().as_str(), Some("cold"));
        assert!(j.get("response_s").unwrap().as_f64().unwrap() > 0.0);
        let r = http_get(&addr, "/v1/invoke/sq?seed=8", tmo).unwrap();
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.get("start").unwrap().as_str(), Some("warm"));

        // prewarm
        let r = http_post(&addr, "/v1/prewarm/sq?n=2", b"", tmo).unwrap();
        assert_eq!(r.status, 200, "{}", r.body_str());

        // stats
        let r = http_get(&addr, "/v1/stats", tmo).unwrap();
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.get("invocations").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("cold_starts").unwrap().as_u64(), Some(1));
        assert!(j.get("containers_alive").unwrap().as_u64().unwrap() >= 3);

        // errors
        assert_eq!(http_get(&addr, "/v1/invoke/nope", tmo).unwrap().status, 404);
        assert_eq!(http_get(&addr, "/nope", tmo).unwrap().status, 404);
        assert_eq!(
            http_post(&addr, "/v1/functions?name=x&model=squeezenet&mem=abc", b"", tmo)
                .unwrap()
                .status,
            400
        );
        assert_eq!(
            http_post(&addr, "/v1/functions?name=x&model=vgg&mem=512", b"", tmo)
                .unwrap()
                .status,
            400
        );

        sh.shutdown();
        t.join().unwrap();
    }

    #[test]
    fn v2_deploy_invoke_conflict_and_errors() {
        let (addr, sh, t) = start();
        let tmo = Duration::from_secs(10);

        // JSON-body deploy -> 201 with the function resource.
        let body = br#"{"name": "sq", "model": "squeezenet", "memory_mb": 1024}"#;
        let r = http_post(&addr, "/v2/functions", body, tmo).unwrap();
        assert_eq!(r.status, 201, "{}", r.body_str());
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("sq"));
        assert_eq!(j.get("memory_mb").unwrap().as_u64(), Some(1024));
        assert_eq!(j.get("max_concurrency"), Some(&Json::Null));

        // Duplicate deploy -> 409 conflict envelope.
        let r = http_post(&addr, "/v2/functions", body, tmo).unwrap();
        assert_eq!(r.status, 409);
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.path(&["error", "code"]).unwrap().as_str(), Some("already_exists"));

        // Sync invoke with JSON body.
        let r = http_post(&addr, "/v2/functions/sq/invocations", br#"{"seed": 3}"#, tmo).unwrap();
        assert_eq!(r.status, 200, "{}", r.body_str());
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.get("start").unwrap().as_str(), Some("cold"));
        assert!(j.get("billed_ms").unwrap().as_u64().unwrap() > 0);

        // Malformed JSON body -> 400 envelope.
        let r = http_post(&addr, "/v2/functions", b"{not json", tmo).unwrap();
        assert_eq!(r.status, 400);
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.path(&["error", "code"]).unwrap().as_str(), Some("invalid_json"));

        // memory_mb beyond u32 must 400, not silently truncate into a
        // valid tier (4294968320 = 2^32 + 1024).
        let r = http_post(
            &addr,
            "/v2/functions",
            br#"{"name": "big", "model": "squeezenet", "memory_mb": 4294968320}"#,
            tmo,
        )
        .unwrap();
        assert_eq!(r.status, 400, "{}", r.body_str());
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(j.path(&["error", "code"]).unwrap().as_str(), Some("invalid_field"));

        // Known path, wrong method -> 405 (not 404).
        let r = crate::httpd::http_request(&addr, "PUT", "/v2/functions", b"", tmo).unwrap();
        assert_eq!(r.status, 405);
        let j = Json::parse(&r.body_str()).unwrap();
        assert_eq!(
            j.path(&["error", "code"]).unwrap().as_str(),
            Some("method_not_allowed")
        );

        // Unknown invocation id -> 404.
        let r = http_get(&addr, "/v2/invocations/inv-doesnotexist", tmo).unwrap();
        assert_eq!(r.status, 404);

        sh.shutdown();
        t.join().unwrap();
    }

    #[test]
    fn v2_async_invocation_roundtrip_over_http() {
        let (addr, sh, t) = start();
        let tmo = Duration::from_secs(10);

        let r = http_post(
            &addr,
            "/v2/functions",
            br#"{"name": "sq", "model": "squeezenet", "memory_mb": 1024}"#,
            tmo,
        )
        .unwrap();
        assert_eq!(r.status, 201, "{}", r.body_str());

        // Async submit -> 202 + id.
        let r = http_post(&addr, "/v2/functions/sq/invocations?mode=async", b"", tmo).unwrap();
        assert_eq!(r.status, 202, "{}", r.body_str());
        let j = Json::parse(&r.body_str()).unwrap();
        let id = j.get("invocation_id").unwrap().as_str().unwrap().to_string();
        assert!(id.starts_with("inv-"));

        // Poll to completion.
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        let done = loop {
            let r = http_get(&addr, &format!("/v2/invocations/{id}"), tmo).unwrap();
            assert_eq!(r.status, 200);
            let j = Json::parse(&r.body_str()).unwrap();
            let status = j.get("status").unwrap().as_str().unwrap().to_string();
            if status == "done" || status == "failed" {
                break j;
            }
            assert!(std::time::Instant::now() < deadline, "async invocation stuck");
            std::thread::sleep(Duration::from_millis(2));
        };
        assert_eq!(done.get("status").unwrap().as_str(), Some("done"));
        let result = done.get("result").unwrap();
        assert_eq!(result.get("start").unwrap().as_str(), Some("cold"));
        assert!(result.get("billed_ms").unwrap().as_u64().unwrap() > 0);

        // Async submit for an unknown function -> 404 at submit time.
        let r = http_post(&addr, "/v2/functions/ghost/invocations?mode=async", b"", tmo).unwrap();
        assert_eq!(r.status, 404);

        sh.shutdown();
        t.join().unwrap();
    }
}
