//! Typed Rust client SDK for the v2 gateway API.
//!
//! Wraps the blocking [`crate::httpd`] client with typed requests and
//! responses; used by the CLI client subcommands, the examples, and
//! the end-to-end integration tests.
//!
//! ```no_run
//! use lambdaserve::gateway::{ApiClient, DeploySpec};
//! let api = ApiClient::new("127.0.0.1:8080");
//! api.deploy(&DeploySpec::new("sq", "squeezenet").memory_mb(1024)).unwrap();
//! let out = api.invoke("sq", Some(7)).unwrap();
//! println!("top1={} in {:.3}s ({})", out.top1, out.response_s, out.start);
//! ```

use crate::httpd::http_request;
use crate::util::clock::Nanos;
use crate::util::json::{obj, Json};
use crate::util::{Clock, SystemClock};
use std::fmt;
use std::sync::Arc;
use std::time::Duration;

/// Error from an API call: HTTP envelope errors keep their status and
/// `code`; transport failures use status 0 / code `"transport"`.
#[derive(Debug, Clone)]
pub struct ApiError {
    pub status: u16,
    pub code: String,
    pub message: String,
}

impl ApiError {
    fn transport(message: String) -> Self {
        Self { status: 0, code: "transport".to_string(), message }
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "api error ({} {}): {}", self.status, self.code, self.message)
    }
}

impl std::error::Error for ApiError {}

pub type ApiResult<T> = Result<T, ApiError>;

/// Full deployment spec for `POST /v2/functions`.
#[derive(Debug, Clone, Default)]
pub struct DeploySpec {
    pub name: String,
    pub model: String,
    pub variant: Option<String>,
    pub memory_mb: Option<u32>,
    pub min_warm: Option<usize>,
    pub max_concurrency: Option<usize>,
    /// Admission-queue depth override (`platform.queue_capacity`
    /// applies when unset).
    pub queue_capacity: Option<usize>,
    /// Admission-deadline override in ms (`platform.queue_deadline_ms`
    /// applies when unset).
    pub queue_deadline_ms: Option<u64>,
    /// Micro-batching override: max coalesced requests per forward
    /// pass (`platform.max_batch_size` applies when unset; 1 = off).
    pub max_batch_size: Option<usize>,
    /// Micro-batching override: collection window in ms
    /// (`platform.batch_window_ms` applies when unset).
    pub batch_window_ms: Option<u64>,
    /// Snapshot/restore override (`platform.snapshot.enabled` applies
    /// when unset).
    pub snapshot: Option<bool>,
    /// SLO target override in ms (`policy.slo_target_ms` applies when
    /// unset) — the latency budget the adaptive controllers defend.
    pub slo_target_ms: Option<u64>,
    /// Adaptive-controller override (`policy.enabled` applies when
    /// unset).
    pub adaptive: Option<bool>,
}

impl DeploySpec {
    pub fn new(name: &str, model: &str) -> Self {
        Self { name: name.to_string(), model: model.to_string(), ..Default::default() }
    }

    pub fn variant(mut self, variant: &str) -> Self {
        self.variant = Some(variant.to_string());
        self
    }

    pub fn memory_mb(mut self, memory_mb: u32) -> Self {
        self.memory_mb = Some(memory_mb);
        self
    }

    pub fn min_warm(mut self, min_warm: usize) -> Self {
        self.min_warm = Some(min_warm);
        self
    }

    pub fn max_concurrency(mut self, cap: usize) -> Self {
        self.max_concurrency = Some(cap);
        self
    }

    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.queue_capacity = Some(capacity);
        self
    }

    pub fn queue_deadline_ms(mut self, deadline_ms: u64) -> Self {
        self.queue_deadline_ms = Some(deadline_ms);
        self
    }

    pub fn max_batch_size(mut self, max_batch_size: usize) -> Self {
        self.max_batch_size = Some(max_batch_size);
        self
    }

    pub fn batch_window_ms(mut self, window_ms: u64) -> Self {
        self.batch_window_ms = Some(window_ms);
        self
    }

    pub fn snapshot(mut self, enabled: bool) -> Self {
        self.snapshot = Some(enabled);
        self
    }

    pub fn slo_target_ms(mut self, slo_target_ms: u64) -> Self {
        self.slo_target_ms = Some(slo_target_ms);
        self
    }

    pub fn adaptive(mut self, enabled: bool) -> Self {
        self.adaptive = Some(enabled);
        self
    }
}

/// Partial update for `PATCH /v2/functions/:name`. Everything after
/// `min_warm` is doubly optional: `Some(None)` clears the
/// cap/override back to the platform default (JSON `null`).
#[derive(Debug, Clone, Default)]
pub struct ReconfigureSpec {
    pub memory_mb: Option<u32>,
    pub variant: Option<String>,
    pub min_warm: Option<usize>,
    pub max_concurrency: Option<Option<usize>>,
    pub queue_capacity: Option<Option<usize>>,
    pub queue_deadline_ms: Option<Option<u64>>,
    pub max_batch_size: Option<Option<usize>>,
    pub batch_window_ms: Option<Option<u64>>,
    pub snapshot: Option<Option<bool>>,
    pub slo_target_ms: Option<Option<u64>>,
    pub adaptive: Option<Option<bool>>,
}

/// One deployed function, as reported by the API.
#[derive(Debug, Clone)]
pub struct FunctionInfo {
    pub name: String,
    pub model: String,
    pub variant: String,
    pub memory_mb: u32,
    pub min_warm: usize,
    pub max_concurrency: Option<usize>,
    /// Admission-queue overrides; `None` = platform default applies.
    pub queue_capacity: Option<usize>,
    pub queue_deadline_ms: Option<u64>,
    /// Micro-batching overrides; `None` = platform default applies.
    pub max_batch_size: Option<usize>,
    pub batch_window_ms: Option<u64>,
    /// Snapshot/restore override; `None` = platform default applies.
    pub snapshot: Option<bool>,
    /// Adaptive-controller overrides; `None` = platform default applies.
    pub slo_target_ms: Option<u64>,
    pub adaptive: Option<bool>,
    pub warm_containers: usize,
}

/// One completed invocation.
#[derive(Debug, Clone)]
pub struct InvocationResult {
    pub function: String,
    /// "cold" | "warm".
    pub start: String,
    pub top1: i64,
    pub top_prob: f64,
    pub predict_s: f64,
    pub response_s: f64,
    pub billed_ms: u64,
    pub cost_dollars: f64,
    /// Requests coalesced into the forward pass that served this one
    /// (1 = solo execution).
    pub batch_size: u64,
    /// Time parked in the batch collector before the pass started.
    pub batch_wait_s: f64,
    /// Largest compiled kernel rung the serving pass ran (1 = the
    /// batch-1 executable; see `platform.batch_kernel_max`).
    pub kernel_batch_n: u64,
    /// Trace id minted for this invocation (`None` when
    /// `trace.enabled` is off); feed it to
    /// [`ApiClient::invocation_trace`].
    pub trace_id: Option<String>,
}

impl InvocationResult {
    pub fn is_cold(&self) -> bool {
        self.start == "cold"
    }
}

/// Poll snapshot of an async invocation.
#[derive(Debug, Clone)]
pub struct AsyncInvocationStatus {
    pub id: String,
    pub function: String,
    /// "queued" | "running" | "done" | "failed".
    pub status: String,
    pub result: Option<InvocationResult>,
    pub error: Option<String>,
}

impl AsyncInvocationStatus {
    pub fn is_terminal(&self) -> bool {
        self.status == "done" || self.status == "failed"
    }
}

/// One span in a trace timeline (`GET /v2/invocations/:id/trace`).
#[derive(Debug, Clone)]
pub struct SpanView {
    /// Stage name: "admission", "queue_wait", "batch_collect",
    /// "provision" (+ children "sandbox", "runtime_init",
    /// "package_fetch", "model_load", "restore"), "kernel_exec",
    /// "billing".
    pub stage: String,
    /// `Some("provision")` for provision child spans, else `None`.
    pub parent: Option<String>,
    /// Start offset from the trace origin, seconds.
    pub offset_s: f64,
    pub duration_s: f64,
    /// Stage annotation (e.g. `kernel_batch_n=4 rung=hit` on
    /// `kernel_exec`), `None` when empty.
    pub note: Option<String>,
}

/// One invocation's span timeline, as returned by the trace routes.
#[derive(Debug, Clone)]
pub struct TraceView {
    pub trace_id: String,
    pub function: String,
    /// "cold" | "warm" | "restored".
    pub start: String,
    /// Exemplar class: "cold" | "restored" | "slow" | "error" |
    /// "steady".
    pub kind: String,
    pub response_s: f64,
    pub slo_target_ms: u64,
    pub slo_violation: bool,
    pub batch_size: u64,
    /// For a batch follower: the leader trace owning the shared
    /// `kernel_exec` span.
    pub shared_exec_with: Option<String>,
    pub error: Option<String>,
    pub spans: Vec<SpanView>,
}

/// Per-function stats breakdown.
#[derive(Debug, Clone)]
pub struct FunctionStats {
    pub function: String,
    pub invocations: u64,
    pub cold_starts: u64,
    /// Snapshot-restored provisions (the third start kind).
    pub restored_starts: u64,
    pub warm_starts: u64,
    /// 429s observed for this function (per-function concurrency cap).
    pub throttled: u64,
    /// 503s observed: admission queue full or dispatch deadline
    /// exhausted while parked.
    pub queue_expired: u64,
    /// Requests currently parked in this function's wait queue.
    pub queue_depth: u64,
    /// True dispatch-queue wait percentiles (cold and warm requests).
    pub queue_wait_p50_s: f64,
    pub queue_wait_p95_s: f64,
    pub queue_wait_p99_s: f64,
    /// Requests served by a coalesced batch of size >= 2, and their
    /// share of all invocations.
    pub batched_requests: u64,
    pub batched_share: f64,
    /// Request-weighted batch-size percentiles over the batching path.
    pub batch_size_p50: u64,
    pub batch_size_p95: u64,
    pub batch_size_p99: u64,
    /// Per-request batch-collector wait percentiles.
    pub batch_wait_p50_s: f64,
    pub batch_wait_p95_s: f64,
    pub batch_wait_p99_s: f64,
    pub response_mean_s: f64,
    pub response_p50_s: f64,
    pub response_p95_s: f64,
    pub response_p99_s: f64,
    /// Cold-start-only response percentiles (the slow mode of the
    /// paper's bimodal distribution).
    pub response_cold_p50_s: f64,
    pub response_cold_p95_s: f64,
    pub response_cold_p99_s: f64,
    /// Warm-start-only response percentiles (the fast mode).
    pub response_warm_p50_s: f64,
    pub response_warm_p95_s: f64,
    pub response_warm_p99_s: f64,
    /// Snapshot-restored-only response percentiles (the middle mode).
    pub response_restored_p50_s: f64,
    pub response_restored_p95_s: f64,
    pub response_restored_p99_s: f64,
    /// Per-component provision-cost percentiles: each fed by the
    /// requests that paid the component (sandbox by cold + restored,
    /// the runtime-init/package-fetch/model-load trio by full cold
    /// starts, restore by restored starts).
    pub provision_sandbox_p50_s: f64,
    pub provision_sandbox_p99_s: f64,
    pub provision_runtime_init_p50_s: f64,
    pub provision_runtime_init_p99_s: f64,
    pub provision_package_fetch_p50_s: f64,
    pub provision_package_fetch_p99_s: f64,
    pub provision_model_load_p50_s: f64,
    pub provision_model_load_p99_s: f64,
    pub provision_restore_p50_s: f64,
    pub provision_restore_p99_s: f64,
    /// Snapshot-store gauges (platform-wide; repeated here so the
    /// restore win is inspectable from a single function's route).
    pub snapshot_hits: u64,
    pub snapshot_misses: u64,
    pub snapshot_captures: u64,
    pub snapshot_evictions: u64,
    pub snapshot_bytes: u64,
    pub predict_mean_s: f64,
    pub predict_p50_s: f64,
    pub predict_p99_s: f64,
    pub billed_ms_total: u64,
    pub cost_dollars_total: f64,
    pub gb_seconds_total: f64,
    pub warm_containers: u64,
    /// Adaptive-controller gauges (all zero while controllers are off):
    /// the Holt arrival-rate level, the batch window the controller is
    /// commanding, and how many times it has moved a knob.
    pub arrival_rate_ewma: f64,
    pub effective_batch_window_ms: u64,
    pub policy_adjustments: u64,
    /// Trace exemplar-ring gauges (platform-wide; all zero while
    /// `trace.enabled` is off).
    pub traces_retained: u64,
    pub traces_sampled_out: u64,
    pub trace_ring_bytes: u64,
}

/// Platform-wide snapshot (`GET /v2/stats`): the totals shard plus
/// capacity, provisioning-source, dispatcher-saturation, and
/// async-subsystem gauges.
#[derive(Debug, Clone)]
pub struct PlatformStats {
    pub invocations: u64,
    pub cold_starts: u64,
    /// Snapshot-restored provisions observed platform-wide.
    pub restored_starts: u64,
    pub warm_starts: u64,
    pub throttled: u64,
    /// Requests refused with 503 (queue full + deadline expired).
    pub saturated: u64,
    pub queue_expired: u64,
    pub queue_wait_p50_s: f64,
    pub queue_wait_p95_s: f64,
    pub queue_wait_p99_s: f64,
    /// Micro-batching totals (see `FunctionStats` for the per-request
    /// percentiles): batched passes executed, the largest flush
    /// observed, and the total requests served by size >= 2 batches.
    pub batches_executed: u64,
    pub largest_batch: u64,
    pub batched_requests: u64,
    pub cold_provisions: u64,
    /// Demand provisions served from a snapshot restore.
    pub restored_provisions: u64,
    pub prewarm_provisions: u64,
    /// Snapshot-store totals: lookups that hit/missed, snapshots
    /// stored, LRU evictions, live stored bytes, and entries dropped
    /// by redeploy/undeploy invalidation.
    pub snapshot_hits: u64,
    pub snapshot_misses: u64,
    pub snapshot_captures: u64,
    pub snapshot_evictions: u64,
    pub snapshot_bytes: u64,
    pub snapshot_stale: u64,
    pub functions: u64,
    pub containers_alive: u64,
    pub in_flight: u64,
    pub peak_concurrency: u64,
    /// Requests currently parked across all dispatch queues.
    pub queue_depth: u64,
    /// All-time high-water mark of the total queue depth.
    pub queue_depth_peak: u64,
    /// Parked requests that exhausted their deadline (503s).
    pub queue_deadline_expired: u64,
    pub total_cost_dollars: f64,
    pub total_gb_seconds: f64,
    pub async_queued: u64,
    pub async_results_stored: u64,
    /// Adaptive-controller aggregates: summed arrival rates and knob
    /// adjustments, and the widest commanded batch window.
    pub arrival_rate_ewma: f64,
    pub effective_batch_window_ms: u64,
    pub policy_adjustments: u64,
    /// Trace exemplar-ring gauges (all zero while `trace.enabled` is
    /// off): traces kept, traces dropped by the sampler, and the
    /// ring's approximate resident size.
    pub traces_retained: u64,
    pub traces_sampled_out: u64,
    pub trace_ring_bytes: u64,
}

/// Blocking typed client for one gateway address.
pub struct ApiClient {
    addr: String,
    timeout: Duration,
    /// Drives [`Self::wait_invocation`] polling; a virtual clock makes
    /// the wait deterministic in tests.
    clock: Arc<dyn Clock>,
}

impl ApiClient {
    pub fn new(addr: &str) -> Self {
        Self {
            addr: addr.to_string(),
            timeout: Duration::from_secs(600),
            clock: Arc::new(SystemClock::new()),
        }
    }

    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    /// Replace the polling clock (tests pass a `ManualClock` so
    /// `wait_invocation` deadlines run on virtual time).
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.clock = clock;
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One call; returns (status, parsed body). Envelope errors (>=
    /// 400) become `ApiError` with the envelope's code/message.
    fn call(&self, method: &str, path: &str, body: Option<&Json>) -> ApiResult<(u16, Json)> {
        let bytes = body.map(|j| j.to_string().into_bytes()).unwrap_or_default();
        let resp = http_request(&self.addr, method, path, &bytes, self.timeout)
            .map_err(|e| ApiError::transport(format!("{e:#}")))?;
        let text = resp.body_str();
        let json = Json::parse(&text).unwrap_or(Json::Null);
        if resp.status >= 400 {
            let code = json
                .path(&["error", "code"])
                .and_then(Json::as_str)
                .unwrap_or("error")
                .to_string();
            let message = json
                .path(&["error", "message"])
                .and_then(Json::as_str)
                .map(str::to_string)
                .or_else(|| json.get("error").and_then(Json::as_str).map(str::to_string))
                .unwrap_or(text);
            return Err(ApiError { status: resp.status, code, message });
        }
        Ok((resp.status, json))
    }

    /// `GET /healthz`.
    pub fn health(&self) -> ApiResult<()> {
        self.call("GET", "/healthz", None).map(|_| ())
    }

    /// `POST /v2/functions`.
    pub fn deploy(&self, spec: &DeploySpec) -> ApiResult<FunctionInfo> {
        let mut fields = vec![
            ("name", Json::Str(spec.name.clone())),
            ("model", Json::Str(spec.model.clone())),
        ];
        if let Some(v) = &spec.variant {
            fields.push(("variant", Json::Str(v.clone())));
        }
        if let Some(m) = spec.memory_mb {
            fields.push(("memory_mb", Json::Num(m as f64)));
        }
        if let Some(w) = spec.min_warm {
            fields.push(("min_warm", Json::Num(w as f64)));
        }
        if let Some(c) = spec.max_concurrency {
            fields.push(("max_concurrency", Json::Num(c as f64)));
        }
        if let Some(q) = spec.queue_capacity {
            fields.push(("queue_capacity", Json::Num(q as f64)));
        }
        if let Some(d) = spec.queue_deadline_ms {
            fields.push(("queue_deadline_ms", Json::Num(d as f64)));
        }
        if let Some(b) = spec.max_batch_size {
            fields.push(("max_batch_size", Json::Num(b as f64)));
        }
        if let Some(w) = spec.batch_window_ms {
            fields.push(("batch_window_ms", Json::Num(w as f64)));
        }
        if let Some(s) = spec.snapshot {
            fields.push(("snapshot", Json::Bool(s)));
        }
        if let Some(t) = spec.slo_target_ms {
            fields.push(("slo_target_ms", Json::Num(t as f64)));
        }
        if let Some(a) = spec.adaptive {
            fields.push(("adaptive", Json::Bool(a)));
        }
        let (_, json) = self.call("POST", "/v2/functions", Some(&obj(fields)))?;
        Ok(parse_function(&json))
    }

    /// `GET /v2/functions`.
    pub fn functions(&self) -> ApiResult<Vec<FunctionInfo>> {
        let (_, json) = self.call("GET", "/v2/functions", None)?;
        Ok(json
            .get("functions")
            .and_then(Json::as_arr)
            .map(|fns| fns.iter().map(parse_function).collect())
            .unwrap_or_default())
    }

    /// `GET /v2/functions/:name`.
    pub fn function(&self, name: &str) -> ApiResult<FunctionInfo> {
        let (_, json) = self.call("GET", &format!("/v2/functions/{name}"), None)?;
        Ok(parse_function(&json))
    }

    /// `PATCH /v2/functions/:name`.
    pub fn reconfigure(&self, name: &str, patch: &ReconfigureSpec) -> ApiResult<FunctionInfo> {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if let Some(m) = patch.memory_mb {
            fields.push(("memory_mb", Json::Num(m as f64)));
        }
        if let Some(v) = &patch.variant {
            fields.push(("variant", Json::Str(v.clone())));
        }
        if let Some(w) = patch.min_warm {
            fields.push(("min_warm", Json::Num(w as f64)));
        }
        if let Some(c) = patch.max_concurrency {
            fields.push((
                "max_concurrency",
                match c {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ));
        }
        if let Some(q) = patch.queue_capacity {
            fields.push((
                "queue_capacity",
                match q {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ));
        }
        if let Some(d) = patch.queue_deadline_ms {
            fields.push((
                "queue_deadline_ms",
                match d {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ));
        }
        if let Some(b) = patch.max_batch_size {
            fields.push((
                "max_batch_size",
                match b {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ));
        }
        if let Some(w) = patch.batch_window_ms {
            fields.push((
                "batch_window_ms",
                match w {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ));
        }
        if let Some(s) = patch.snapshot {
            fields.push((
                "snapshot",
                match s {
                    Some(b) => Json::Bool(b),
                    None => Json::Null,
                },
            ));
        }
        if let Some(t) = patch.slo_target_ms {
            fields.push((
                "slo_target_ms",
                match t {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ));
        }
        if let Some(a) = patch.adaptive {
            fields.push((
                "adaptive",
                match a {
                    Some(b) => Json::Bool(b),
                    None => Json::Null,
                },
            ));
        }
        let (_, json) = self.call("PATCH", &format!("/v2/functions/{name}"), Some(&obj(fields)))?;
        Ok(parse_function(&json))
    }

    /// `DELETE /v2/functions/:name`; returns containers reaped.
    pub fn undeploy(&self, name: &str) -> ApiResult<usize> {
        let (_, json) = self.call("DELETE", &format!("/v2/functions/{name}"), None)?;
        Ok(json.get("reaped_containers").and_then(Json::as_u64).unwrap_or(0) as usize)
    }

    /// Synchronous invocation (`POST /v2/functions/:name/invocations`).
    pub fn invoke(&self, function: &str, seed: Option<u64>) -> ApiResult<InvocationResult> {
        let body = seed.map(|s| obj(vec![("seed", Json::Num(s as f64))]));
        let (_, json) = self.call(
            "POST",
            &format!("/v2/functions/{function}/invocations"),
            body.as_ref(),
        )?;
        Ok(parse_invocation(&json))
    }

    /// Fire-and-forget invocation; returns the invocation id from the
    /// 202 response.
    pub fn invoke_async(&self, function: &str, seed: Option<u64>) -> ApiResult<String> {
        let body = seed.map(|s| obj(vec![("seed", Json::Num(s as f64))]));
        let (status, json) = self.call(
            "POST",
            &format!("/v2/functions/{function}/invocations?mode=async"),
            body.as_ref(),
        )?;
        if status != 202 {
            return Err(ApiError {
                status,
                code: "unexpected_status".to_string(),
                message: format!("expected 202 Accepted for async invoke, got {status}"),
            });
        }
        let id = str_field(&json, "invocation_id");
        if id.is_empty() {
            return Err(ApiError::transport("202 response missing invocation_id".to_string()));
        }
        Ok(id)
    }

    /// `GET /v2/invocations/:id`.
    pub fn invocation(&self, id: &str) -> ApiResult<AsyncInvocationStatus> {
        let (_, json) = self.call("GET", &format!("/v2/invocations/{id}"), None)?;
        Ok(AsyncInvocationStatus {
            id: str_field(&json, "id"),
            function: str_field(&json, "function"),
            status: str_field(&json, "status"),
            result: match json.get("result") {
                Some(Json::Null) | None => None,
                Some(r) => Some(parse_invocation(r)),
            },
            error: json.get("error").and_then(Json::as_str).map(str::to_string),
        })
    }

    /// Poll `GET /v2/invocations/:id` until it reaches a terminal
    /// status ("done" / "failed") or `timeout` elapses.
    pub fn wait_invocation(
        &self,
        id: &str,
        poll_every: Duration,
        timeout: Duration,
    ) -> ApiResult<AsyncInvocationStatus> {
        let deadline = self.clock.now().saturating_add(timeout.as_nanos() as Nanos);
        loop {
            let status = self.invocation(id)?;
            if status.is_terminal() {
                return Ok(status);
            }
            if self.clock.now() >= deadline {
                return Err(ApiError {
                    status: 0,
                    code: "timeout".to_string(),
                    message: format!(
                        "invocation {id} still {:?} after {timeout:?}",
                        status.status
                    ),
                });
            }
            self.clock.sleep(poll_every);
        }
    }

    /// `GET /v2/functions/:name/stats`.
    pub fn stats(&self, function: &str) -> ApiResult<FunctionStats> {
        let (_, json) = self.call("GET", &format!("/v2/functions/{function}/stats"), None)?;
        Ok(FunctionStats {
            function: str_field(&json, "function"),
            invocations: u64_field(&json, "invocations"),
            cold_starts: u64_field(&json, "cold_starts"),
            restored_starts: u64_field(&json, "restored_starts"),
            warm_starts: u64_field(&json, "warm_starts"),
            throttled: u64_field(&json, "throttled"),
            queue_expired: u64_field(&json, "queue_expired"),
            queue_depth: u64_field(&json, "queue_depth"),
            queue_wait_p50_s: num_field(&json, "queue_wait_p50_s"),
            queue_wait_p95_s: num_field(&json, "queue_wait_p95_s"),
            queue_wait_p99_s: num_field(&json, "queue_wait_p99_s"),
            batched_requests: u64_field(&json, "batched_requests"),
            batched_share: num_field(&json, "batched_share"),
            batch_size_p50: u64_field(&json, "batch_size_p50"),
            batch_size_p95: u64_field(&json, "batch_size_p95"),
            batch_size_p99: u64_field(&json, "batch_size_p99"),
            batch_wait_p50_s: num_field(&json, "batch_wait_p50_s"),
            batch_wait_p95_s: num_field(&json, "batch_wait_p95_s"),
            batch_wait_p99_s: num_field(&json, "batch_wait_p99_s"),
            response_mean_s: num_field(&json, "response_mean_s"),
            response_p50_s: num_field(&json, "response_p50_s"),
            response_p95_s: num_field(&json, "response_p95_s"),
            response_p99_s: num_field(&json, "response_p99_s"),
            response_cold_p50_s: num_field(&json, "response_cold_p50_s"),
            response_cold_p95_s: num_field(&json, "response_cold_p95_s"),
            response_cold_p99_s: num_field(&json, "response_cold_p99_s"),
            response_warm_p50_s: num_field(&json, "response_warm_p50_s"),
            response_warm_p95_s: num_field(&json, "response_warm_p95_s"),
            response_warm_p99_s: num_field(&json, "response_warm_p99_s"),
            response_restored_p50_s: num_field(&json, "response_restored_p50_s"),
            response_restored_p95_s: num_field(&json, "response_restored_p95_s"),
            response_restored_p99_s: num_field(&json, "response_restored_p99_s"),
            provision_sandbox_p50_s: num_field(&json, "provision_sandbox_p50_s"),
            provision_sandbox_p99_s: num_field(&json, "provision_sandbox_p99_s"),
            provision_runtime_init_p50_s: num_field(&json, "provision_runtime_init_p50_s"),
            provision_runtime_init_p99_s: num_field(&json, "provision_runtime_init_p99_s"),
            provision_package_fetch_p50_s: num_field(&json, "provision_package_fetch_p50_s"),
            provision_package_fetch_p99_s: num_field(&json, "provision_package_fetch_p99_s"),
            provision_model_load_p50_s: num_field(&json, "provision_model_load_p50_s"),
            provision_model_load_p99_s: num_field(&json, "provision_model_load_p99_s"),
            provision_restore_p50_s: num_field(&json, "provision_restore_p50_s"),
            provision_restore_p99_s: num_field(&json, "provision_restore_p99_s"),
            snapshot_hits: u64_field(&json, "snapshot_hits"),
            snapshot_misses: u64_field(&json, "snapshot_misses"),
            snapshot_captures: u64_field(&json, "snapshot_captures"),
            snapshot_evictions: u64_field(&json, "snapshot_evictions"),
            snapshot_bytes: u64_field(&json, "snapshot_bytes"),
            predict_mean_s: num_field(&json, "predict_mean_s"),
            predict_p50_s: num_field(&json, "predict_p50_s"),
            predict_p99_s: num_field(&json, "predict_p99_s"),
            billed_ms_total: u64_field(&json, "billed_ms_total"),
            cost_dollars_total: num_field(&json, "cost_dollars_total"),
            gb_seconds_total: num_field(&json, "gb_seconds_total"),
            warm_containers: u64_field(&json, "warm_containers"),
            arrival_rate_ewma: num_field(&json, "arrival_rate_ewma"),
            effective_batch_window_ms: u64_field(&json, "effective_batch_window_ms"),
            policy_adjustments: u64_field(&json, "policy_adjustments"),
            traces_retained: u64_field(&json, "traces_retained"),
            traces_sampled_out: u64_field(&json, "traces_sampled_out"),
            trace_ring_bytes: u64_field(&json, "trace_ring_bytes"),
        })
    }

    /// `GET /v2/stats`.
    pub fn platform_stats(&self) -> ApiResult<PlatformStats> {
        let (_, json) = self.call("GET", "/v2/stats", None)?;
        Ok(PlatformStats {
            invocations: u64_field(&json, "invocations"),
            cold_starts: u64_field(&json, "cold_starts"),
            restored_starts: u64_field(&json, "restored_starts"),
            warm_starts: u64_field(&json, "warm_starts"),
            throttled: u64_field(&json, "throttled"),
            saturated: u64_field(&json, "saturated"),
            queue_expired: u64_field(&json, "queue_expired"),
            queue_wait_p50_s: num_field(&json, "queue_wait_p50_s"),
            queue_wait_p95_s: num_field(&json, "queue_wait_p95_s"),
            queue_wait_p99_s: num_field(&json, "queue_wait_p99_s"),
            batches_executed: u64_field(&json, "batches_executed"),
            largest_batch: u64_field(&json, "largest_batch"),
            batched_requests: u64_field(&json, "batched_requests"),
            cold_provisions: u64_field(&json, "cold_provisions"),
            restored_provisions: u64_field(&json, "restored_provisions"),
            prewarm_provisions: u64_field(&json, "prewarm_provisions"),
            snapshot_hits: u64_field(&json, "snapshot_hits"),
            snapshot_misses: u64_field(&json, "snapshot_misses"),
            snapshot_captures: u64_field(&json, "snapshot_captures"),
            snapshot_evictions: u64_field(&json, "snapshot_evictions"),
            snapshot_bytes: u64_field(&json, "snapshot_bytes"),
            snapshot_stale: u64_field(&json, "snapshot_stale"),
            functions: u64_field(&json, "functions"),
            containers_alive: u64_field(&json, "containers_alive"),
            in_flight: u64_field(&json, "in_flight"),
            peak_concurrency: u64_field(&json, "peak_concurrency"),
            queue_depth: u64_field(&json, "queue_depth"),
            queue_depth_peak: u64_field(&json, "queue_depth_peak"),
            queue_deadline_expired: u64_field(&json, "queue_deadline_expired"),
            total_cost_dollars: num_field(&json, "total_cost_dollars"),
            total_gb_seconds: num_field(&json, "total_gb_seconds"),
            async_queued: u64_field(&json, "async_queued"),
            async_results_stored: u64_field(&json, "async_results_stored"),
            arrival_rate_ewma: num_field(&json, "arrival_rate_ewma"),
            effective_batch_window_ms: u64_field(&json, "effective_batch_window_ms"),
            policy_adjustments: u64_field(&json, "policy_adjustments"),
            traces_retained: u64_field(&json, "traces_retained"),
            traces_sampled_out: u64_field(&json, "traces_sampled_out"),
            trace_ring_bytes: u64_field(&json, "trace_ring_bytes"),
        })
    }

    /// `GET /v2/invocations/:id/trace` — the span timeline for one
    /// invocation. `id` is either a trace id (`tr-…`, from
    /// [`InvocationResult::trace_id`]) or an async invocation id
    /// (`inv-…`).
    pub fn invocation_trace(&self, id: &str) -> ApiResult<TraceView> {
        let (_, json) = self.call("GET", &format!("/v2/invocations/{id}/trace"), None)?;
        Ok(parse_trace(&json))
    }

    /// `GET /v2/functions/:name/traces` — newest-first retained trace
    /// exemplars. `kind` filters to one exemplar class
    /// (`cold|restored|slow|error`); `limit` caps the result count
    /// (server default 10, max 100).
    pub fn function_traces(
        &self,
        name: &str,
        kind: Option<&str>,
        limit: Option<usize>,
    ) -> ApiResult<Vec<TraceView>> {
        let mut path = format!("/v2/functions/{name}/traces");
        let mut sep = '?';
        if let Some(k) = kind {
            path.push(sep);
            path.push_str(&format!("kind={k}"));
            sep = '&';
        }
        if let Some(n) = limit {
            path.push(sep);
            path.push_str(&format!("limit={n}"));
        }
        let (_, json) = self.call("GET", &path, None)?;
        Ok(json
            .get("traces")
            .and_then(Json::as_arr)
            .map(|ts| ts.iter().map(parse_trace).collect())
            .unwrap_or_default())
    }
}

fn str_field(json: &Json, key: &str) -> String {
    json.get(key).and_then(Json::as_str).unwrap_or_default().to_string()
}

fn num_field(json: &Json, key: &str) -> f64 {
    json.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

fn u64_field(json: &Json, key: &str) -> u64 {
    json.get(key).and_then(Json::as_u64).unwrap_or(0)
}

fn parse_function(json: &Json) -> FunctionInfo {
    FunctionInfo {
        name: str_field(json, "name"),
        model: str_field(json, "model"),
        variant: str_field(json, "variant"),
        memory_mb: u64_field(json, "memory_mb") as u32,
        min_warm: u64_field(json, "min_warm") as usize,
        max_concurrency: json.get("max_concurrency").and_then(Json::as_u64).map(|v| v as usize),
        queue_capacity: json.get("queue_capacity").and_then(Json::as_u64).map(|v| v as usize),
        queue_deadline_ms: json.get("queue_deadline_ms").and_then(Json::as_u64),
        max_batch_size: json.get("max_batch_size").and_then(Json::as_u64).map(|v| v as usize),
        batch_window_ms: json.get("batch_window_ms").and_then(Json::as_u64),
        snapshot: json.get("snapshot").and_then(Json::as_bool),
        slo_target_ms: json.get("slo_target_ms").and_then(Json::as_u64),
        adaptive: json.get("adaptive").and_then(Json::as_bool),
        warm_containers: u64_field(json, "warm_containers") as usize,
    }
}

fn parse_invocation(json: &Json) -> InvocationResult {
    InvocationResult {
        function: str_field(json, "function"),
        start: str_field(json, "start"),
        top1: num_field(json, "top1") as i64,
        top_prob: num_field(json, "top_prob"),
        predict_s: num_field(json, "predict_s"),
        response_s: num_field(json, "response_s"),
        billed_ms: u64_field(json, "billed_ms"),
        cost_dollars: num_field(json, "cost_dollars"),
        batch_size: json.get("batch_size").and_then(Json::as_u64).unwrap_or(1),
        batch_wait_s: num_field(json, "batch_wait_s"),
        kernel_batch_n: json.get("kernel_batch_n").and_then(Json::as_u64).unwrap_or(1),
        trace_id: json.get("trace_id").and_then(Json::as_str).map(str::to_string),
    }
}

fn parse_trace(json: &Json) -> TraceView {
    TraceView {
        trace_id: str_field(json, "trace_id"),
        function: str_field(json, "function"),
        start: str_field(json, "start"),
        kind: str_field(json, "kind"),
        response_s: num_field(json, "response_s"),
        slo_target_ms: u64_field(json, "slo_target_ms"),
        slo_violation: json.get("slo_violation").and_then(Json::as_bool).unwrap_or(false),
        batch_size: u64_field(json, "batch_size"),
        shared_exec_with: json.get("shared_exec_with").and_then(Json::as_str).map(str::to_string),
        error: json.get("error").and_then(Json::as_str).map(str::to_string),
        spans: json
            .get("spans")
            .and_then(Json::as_arr)
            .map(|spans| {
                spans
                    .iter()
                    .map(|s| SpanView {
                        stage: str_field(s, "stage"),
                        parent: s.get("parent").and_then(Json::as_str).map(str::to_string),
                        offset_s: num_field(s, "offset_s"),
                        duration_s: num_field(s, "duration_s"),
                        note: s.get("note").and_then(Json::as_str).map(str::to_string),
                    })
                    .collect()
            })
            .unwrap_or_default(),
    }
}
