//! Trace query handlers: one invocation's span timeline (`GET
//! /v2/invocations/:id/trace`) and a function's retained exemplars
//! (`GET /v2/functions/:name/traces`).
//!
//! Both routes read the platform's tail-sampled exemplar ring
//! (`platform/trace.rs`). With `trace.enabled` off (the default) they
//! answer 404 with a `tracing_disabled` code rather than an empty
//! result, so a client can tell "not retained" from "never traced".

use super::{err, ApiCtx};
use crate::httpd::{HttpRequest, Params, Responder};
use crate::util::json::{obj, Json};

const KINDS: [&str; 4] = ["cold", "restored", "slow", "error"];
const DEFAULT_LIMIT: usize = 10;
const MAX_LIMIT: usize = 100;

/// `GET /v2/invocations/:id/trace` — the span timeline for one
/// invocation. Accepts either a trace id (`tr-…`, as returned in the
/// invocation's `trace_id` field) or an async invocation id (`inv-…`,
/// resolved through the result store to the trace its record carried).
pub fn invocation_trace(ctx: &ApiCtx, _req: &HttpRequest, params: &Params) -> Responder {
    let id = params.require("id");
    if !ctx.platform.trace.enabled() {
        return err(404, "tracing_disabled", "tracing is disabled (`trace.enabled = false`)");
    }
    let trace_id = if id.starts_with("inv-") {
        match ctx.async_inv.get(id) {
            Some(entry) => match entry.record.as_ref().and_then(|r| r.trace_id.clone()) {
                Some(tid) => tid,
                None => {
                    return err(
                        404,
                        "not_found",
                        &format!("invocation {id:?} has no trace (not finished, or untraced)"),
                    );
                }
            },
            None => {
                return err(
                    404,
                    "not_found",
                    &format!("invocation {id:?} is unknown or its result expired"),
                );
            }
        }
    } else {
        id.to_string()
    };
    match ctx.platform.trace.get(&trace_id) {
        Some(trace) => Responder::json(200, trace.to_json().to_string()),
        None => err(
            404,
            "not_found",
            &format!("trace {trace_id:?} is not retained (evicted or sampled out)"),
        ),
    }
}

/// `GET /v2/functions/:name/traces?kind=cold|restored|slow|error&limit=N`
/// — newest-first retained exemplars for one function.
pub fn function_traces(ctx: &ApiCtx, req: &HttpRequest, params: &Params) -> Responder {
    let name = params.require("name");
    if ctx.platform.registry.get(name).is_err() {
        return err(404, "not_found", &format!("function {name:?} is not deployed"));
    }
    if !ctx.platform.trace.enabled() {
        return err(404, "tracing_disabled", "tracing is disabled (`trace.enabled = false`)");
    }
    let kind = match req.query_param("kind") {
        Some(k) if KINDS.contains(&k) => Some(k),
        Some(k) => {
            return err(
                400,
                "invalid_kind",
                &format!("kind must be one of cold|restored|slow|error, got {k:?}"),
            );
        }
        None => None,
    };
    let limit = match req.query_param("limit") {
        Some(l) => match l.parse::<usize>() {
            Ok(n) if n >= 1 => n.min(MAX_LIMIT),
            _ => return err(400, "invalid_limit", "limit must be a positive integer"),
        },
        None => DEFAULT_LIMIT,
    };
    let traces = ctx.platform.trace.recent(name, kind, limit);
    Responder::json(
        200,
        obj(vec![
            ("function", Json::Str(name.to_string())),
            ("count", Json::Num(traces.len() as f64)),
            ("traces", Json::Arr(traces.iter().map(|t| t.to_json()).collect())),
        ])
        .to_string(),
    )
}
