//! Stats handlers: per-function latency/cold-start/billing breakdown
//! (`GET /v2/functions/:name/stats`) and the platform-wide snapshot
//! (`GET /v2/stats`).
//!
//! Both routes read one consistent [`FnMetrics`] shard snapshot from
//! the streaming metrics sink — a single lock acquisition and O(1)
//! cost regardless of how many invocations have been recorded (the
//! old implementation cloned and re-scanned the full record vector
//! under four separate locks per request).

use super::{err, ApiCtx};
use crate::httpd::{HttpRequest, Params, Responder};
use crate::platform::{FnMetrics, Platform};
use crate::util::json::{obj, Json};

const NS: f64 = 1e9;

fn secs(ns: u64) -> Json {
    Json::Num(ns as f64 / NS)
}

/// Counters, cold/warm-split percentiles, and cost accumulators of
/// one shard, read under its lock — everything here is one consistent
/// view (`invocations == cold_starts + warm_starts`, always). The two
/// transient merges (`response_all`/`predict_all`) are the only
/// allocations; the shard itself is never copied.
fn shard_fields(m: &FnMetrics) -> Vec<(&'static str, Json)> {
    let response = m.response_all();
    let predict = m.predict_all();
    vec![
        ("invocations", Json::Num(m.invocations as f64)),
        ("cold_starts", Json::Num(m.cold_starts as f64)),
        ("restored_starts", Json::Num(m.restored_starts as f64)),
        ("warm_starts", Json::Num(m.warm_starts() as f64)),
        ("throttled", Json::Num(m.throttled as f64)),
        ("queue_expired", Json::Num(m.queue_expired as f64)),
        ("queue_wait_p50_s", secs(m.queue_wait.p50())),
        ("queue_wait_p95_s", secs(m.queue_wait.p95())),
        ("queue_wait_p99_s", secs(m.queue_wait.p99())),
        // Micro-batching: how many requests were coalesced, what size
        // batch the average request rode (request-weighted), and what
        // the collector wait cost per request.
        ("batched_requests", Json::Num(m.batched_requests as f64)),
        (
            "batched_share",
            Json::Num(if m.invocations == 0 {
                0.0
            } else {
                m.batched_requests as f64 / m.invocations as f64
            }),
        ),
        ("batch_size_p50", Json::Num(m.batch_size.p50() as f64)),
        ("batch_size_p95", Json::Num(m.batch_size.p95() as f64)),
        ("batch_size_p99", Json::Num(m.batch_size.p99() as f64)),
        ("batch_wait_p50_s", secs(m.batch_wait.p50())),
        ("batch_wait_p95_s", secs(m.batch_wait.p95())),
        ("batch_wait_p99_s", secs(m.batch_wait.p99())),
        // Batch-N kernel ladder: which compiled rung the average
        // batched request rode (request-weighted, like batch_size) and
        // how the engine's kernel cache fared across flushes (deltas
        // counted once per pass — the leader's record owns them).
        ("kernel_batch_n_p50", Json::Num(m.kernel_batch_n.p50() as f64)),
        ("kernel_batch_n_p99", Json::Num(m.kernel_batch_n.p99() as f64)),
        ("kernel_batch_n_max", Json::Num(m.kernel_batch_n.max() as f64)),
        ("batch_kernel_hits", Json::Num(m.batch_kernel_hits as f64)),
        ("batch_kernel_misses", Json::Num(m.batch_kernel_misses as f64)),
        ("response_mean_s", Json::Num(response.mean() / NS)),
        ("response_p50_s", secs(response.p50())),
        ("response_p95_s", secs(response.p95())),
        ("response_p99_s", secs(response.p99())),
        ("response_cold_p50_s", secs(m.response_cold.p50())),
        ("response_cold_p95_s", secs(m.response_cold.p95())),
        ("response_cold_p99_s", secs(m.response_cold.p99())),
        ("response_warm_p50_s", secs(m.response_warm.p50())),
        ("response_warm_p95_s", secs(m.response_warm.p95())),
        ("response_warm_p99_s", secs(m.response_warm.p99())),
        // Snapshot-restored-only response percentiles (the middle mode
        // the restore path carves out of the cold distribution).
        ("response_restored_p50_s", secs(m.response_restored.p50())),
        ("response_restored_p95_s", secs(m.response_restored.p95())),
        ("response_restored_p99_s", secs(m.response_restored.p99())),
        // Per-component provision-cost percentiles: each histogram is
        // fed by the requests that actually paid the component (the
        // trio by full cold starts, restore by restored starts,
        // sandbox by both), so the restore win reads straight off the
        // route — no raw-record parsing.
        ("provision_sandbox_p50_s", secs(m.provision_sandbox.p50())),
        ("provision_sandbox_p99_s", secs(m.provision_sandbox.p99())),
        ("provision_runtime_init_p50_s", secs(m.provision_runtime_init.p50())),
        ("provision_runtime_init_p99_s", secs(m.provision_runtime_init.p99())),
        ("provision_package_fetch_p50_s", secs(m.provision_package_fetch.p50())),
        ("provision_package_fetch_p99_s", secs(m.provision_package_fetch.p99())),
        ("provision_model_load_p50_s", secs(m.provision_model_load.p50())),
        ("provision_model_load_p99_s", secs(m.provision_model_load.p99())),
        ("provision_restore_p50_s", secs(m.provision_restore.p50())),
        ("provision_restore_p99_s", secs(m.provision_restore.p99())),
        ("predict_mean_s", Json::Num(predict.mean() / NS)),
        ("predict_p50_s", secs(predict.p50())),
        ("predict_p99_s", secs(predict.p99())),
        ("billed_ms_total", Json::Num(m.billed_ms_total as f64)),
        ("cost_dollars_total", Json::Num(m.cost_dollars_total)),
        ("gb_seconds_total", Json::Num(m.gb_seconds_total)),
    ]
}

/// The rendered all-zero shard block, built once — a never-invoked
/// function must not cost four zeroed 64 KiB histograms per request
/// just to emit constant zeros.
fn zero_shard_fields() -> Vec<(&'static str, Json)> {
    static ZERO: std::sync::OnceLock<Vec<(&'static str, Json)>> = std::sync::OnceLock::new();
    ZERO.get_or_init(|| shard_fields(&FnMetrics::default())).clone()
}

/// Adaptive-controller gauges (PR 9): the Holt arrival-rate level the
/// forecaster is tracking, the batch window the controller is
/// currently commanding, and how many times it has moved a knob.
/// Served on both stats routes — per-function from `snapshot_view`,
/// platform-wide from the aggregated `platform_view`.
fn policy_fields(s: &crate::platform::PolicySnapshot) -> [(&'static str, Json); 3] {
    [
        ("arrival_rate_ewma", Json::Num(s.arrival_rate_ewma)),
        ("effective_batch_window_ms", Json::Num(s.effective_batch_window_ms as f64)),
        ("policy_adjustments", Json::Num(s.policy_adjustments as f64)),
    ]
}

/// Snapshot-store gauges, served identically on both stats routes
/// (the store is a platform-wide resource shared by every function of
/// the same deployment shape, like the dispatcher's totals).
fn snapshot_fields(p: &Platform) -> [(&'static str, Json); 5] {
    let s = &p.snapshots;
    [
        ("snapshot_hits", Json::Num(s.hits() as f64)),
        ("snapshot_misses", Json::Num(s.misses() as f64)),
        ("snapshot_captures", Json::Num(s.captures() as f64)),
        ("snapshot_evictions", Json::Num(s.evictions() as f64)),
        ("snapshot_bytes", Json::Num(s.bytes() as f64)),
    ]
}

/// Trace-derived gauges, served identically on both stats routes (the
/// exemplar ring is a platform-wide resource, like the snapshot
/// store). All-zero with `trace.enabled` off — the reads are plain
/// atomics, no trace lock.
fn trace_fields(p: &Platform) -> [(&'static str, Json); 3] {
    let t = &p.trace;
    [
        ("traces_retained", Json::Num(t.retained() as f64)),
        ("traces_sampled_out", Json::Num(t.sampled_out() as f64)),
        ("trace_ring_bytes", Json::Num(t.ring_bytes() as f64)),
    ]
}

/// `GET /v2/functions/:name/stats`.
pub fn function_stats(ctx: &ApiCtx, _req: &HttpRequest, params: &Params) -> Responder {
    let name = params.require("name");
    if ctx.platform.registry.get(name).is_err() {
        return err(404, "not_found", &format!("function {name:?} is not deployed"));
    }
    let mut fields = vec![("function", Json::Str(name.to_string()))];
    fields.extend(match ctx.platform.metrics.with_function(name, shard_fields) {
        Some(shard) => shard,
        // Deployed but never invoked: all-zero block.
        None => zero_shard_fields(),
    });
    fields.push(("warm_containers", Json::Num(ctx.platform.pool.warm_count(name) as f64)));
    // Live dispatcher saturation for this function.
    fields.push(("queue_depth", Json::Num(ctx.platform.dispatcher.queue_depth(name) as f64)));
    // Adaptive-controller gauges: all-zero until the policy layer has
    // seen an arrival for this function (controllers default off).
    let policy = ctx.platform.policy.snapshot_view(name).unwrap_or_default();
    fields.extend(policy_fields(&policy));
    fields.extend(snapshot_fields(&ctx.platform));
    fields.extend(trace_fields(&ctx.platform));
    Responder::json(200, obj(fields).to_string())
}

/// `GET /v2/stats` — platform-wide snapshot (superset of `/v1/stats`
/// with async-subsystem depth, provision-source split, and the
/// cold/warm latency percentiles).
pub fn platform_stats(ctx: &ApiCtx, _req: &HttpRequest, _params: &Params) -> Responder {
    let p = &ctx.platform;
    let mut fields = p.metrics.with_totals(shard_fields);
    fields.extend([
        // Demand-driven provisions vs operator/maintainer pre-warms:
        // kept separate so pre-warming does not inflate the
        // request-visible cold-start rate.
        ("cold_provisions", Json::Num(p.scaler.cold_provision_count() as f64)),
        // Demand provisions served from a snapshot restore — the
        // keep-warm-vs-snapshot-vs-pure-cold ablation's third column.
        ("restored_provisions", Json::Num(p.scaler.restored_provision_count() as f64)),
        ("prewarm_provisions", Json::Num(p.scaler.prewarm_provision_count() as f64)),
        ("functions", Json::Num(p.registry.list().len() as f64)),
        ("containers_alive", Json::Num(p.pool.total_alive() as f64)),
        // Warm-pool sharding in effect (the `platform.pool_shards`
        // knob): 1 = the single-lock pool.
        ("pool_shards", Json::Num(p.pool.shard_count() as f64)),
        ("in_flight", Json::Num(p.scaler.in_flight() as f64)),
        ("peak_concurrency", Json::Num(p.scaler.high_water_mark() as f64)),
        ("total_cost_dollars", Json::Num(p.billing.total_dollars())),
        ("total_gb_seconds", Json::Num(p.billing.total_gb_seconds())),
        // Dispatcher saturation: live depth, all-time peak, requests
        // refused with 503 (queue full or deadline exhausted).
        ("queue_depth", Json::Num(p.dispatcher.total_depth() as f64)),
        ("queue_depth_peak", Json::Num(p.dispatcher.peak_depth() as f64)),
        ("queue_deadline_expired", Json::Num(p.dispatcher.expired_total() as f64)),
        ("saturated", Json::Num(p.scaler.saturated_count() as f64)),
        // Micro-batching: executed batched passes and the largest
        // flush so far (per-request coalescing counts come from the
        // shared shard block above — `batched_requests` et al.).
        ("batches_executed", Json::Num(p.batcher.batches_executed() as f64)),
        ("largest_batch", Json::Num(p.batcher.largest_batch() as f64)),
        ("async_queued", Json::Num(ctx.async_inv.queued() as f64)),
        ("async_results_stored", Json::Num(ctx.async_inv.stored() as f64)),
    ]);
    fields.extend(policy_fields(&p.policy.platform_view()));
    fields.extend(snapshot_fields(p));
    fields.extend(trace_fields(p));
    // Redeploy/undeploy invalidations, platform route only (a store
    // lifecycle detail, not a per-function signal).
    fields.push(("snapshot_stale", Json::Num(p.snapshots.stale() as f64)));
    Responder::json(200, obj(fields).to_string())
}
