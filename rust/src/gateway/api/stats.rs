//! Stats handlers: per-function latency/cold-start/billing breakdown
//! (`GET /v2/functions/:name/stats`) and the platform-wide snapshot
//! (`GET /v2/stats`).

use super::{err, ApiCtx};
use crate::httpd::{HttpRequest, Params, Responder};
use crate::platform::StartKind;
use crate::util::json::{obj, Json};

/// `GET /v2/functions/:name/stats`.
pub fn function_stats(ctx: &ApiCtx, _req: &HttpRequest, params: &Params) -> Responder {
    let name = params.require("name");
    if ctx.platform.registry.get(name).is_err() {
        return err(404, "not_found", &format!("function {name:?} is not deployed"));
    }
    let metrics = &ctx.platform.metrics;
    let records = metrics.records();
    let recs: Vec<_> = records.iter().filter(|r| r.function == name).collect();
    let cold = recs.iter().filter(|r| r.start == StartKind::Cold).count();
    let response = metrics.response_summary(|r| r.function == name);
    let predict = metrics.predict_summary(|r| r.function == name);
    let billed_ms: u64 = recs.iter().map(|r| r.billed_ms).sum();
    let cost: f64 = recs.iter().map(|r| r.cost_dollars).sum();
    let gb_seconds: f64 = ctx
        .platform
        .billing
        .lines()
        .iter()
        .filter(|l| l.function == name)
        .map(|l| l.gb_seconds())
        .sum();
    Responder::json(
        200,
        obj(vec![
            ("function", Json::Str(name.to_string())),
            ("invocations", Json::Num(recs.len() as f64)),
            ("cold_starts", Json::Num(cold as f64)),
            ("warm_starts", Json::Num((recs.len() - cold) as f64)),
            ("response_mean_s", Json::Num(response.mean)),
            ("response_p50_s", Json::Num(response.p50)),
            ("response_p95_s", Json::Num(response.p95)),
            ("response_p99_s", Json::Num(response.p99)),
            ("predict_mean_s", Json::Num(predict.mean)),
            ("billed_ms_total", Json::Num(billed_ms as f64)),
            ("cost_dollars_total", Json::Num(cost)),
            ("gb_seconds_total", Json::Num(gb_seconds)),
            ("warm_containers", Json::Num(ctx.platform.pool.warm_count(name) as f64)),
        ])
        .to_string(),
    )
}

/// `GET /v2/stats` — platform-wide snapshot (superset of `/v1/stats`
/// with async-subsystem depth).
pub fn platform_stats(ctx: &ApiCtx, _req: &HttpRequest, _params: &Params) -> Responder {
    let p = &ctx.platform;
    let m = &p.metrics;
    Responder::json(
        200,
        obj(vec![
            ("invocations", Json::Num(m.len() as f64)),
            ("cold_starts", Json::Num(m.cold_count() as f64)),
            ("functions", Json::Num(p.registry.list().len() as f64)),
            ("containers_alive", Json::Num(p.pool.total_alive() as f64)),
            ("in_flight", Json::Num(p.scaler.in_flight() as f64)),
            ("peak_concurrency", Json::Num(p.scaler.high_water_mark() as f64)),
            ("throttled", Json::Num(p.scaler.throttled_count() as f64)),
            ("total_cost_dollars", Json::Num(p.billing.total_dollars())),
            ("total_gb_seconds", Json::Num(p.billing.total_gb_seconds())),
            ("async_queued", Json::Num(ctx.async_inv.queued() as f64)),
            ("async_results_stored", Json::Num(ctx.async_inv.stored() as f64)),
        ])
        .to_string(),
    )
}
